"""Table 6: PageRank, 5 iterations.

  eh-datalog   the engine's recursive datalog program (paper Table 2)
  spmv-jnp     vectorized SpMV fixpoint (the engine's compiled hot loop)
  spmv-pallas  ELL Pallas kernel path (interpret mode on CPU)
Derived column: L1 distance to the datalog result (must be ~0).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graphs, row, timeit
from repro.core.engine import Engine
from repro.kernels.spmv_ell.ops import csr_to_ell, spmv_ell
from repro.kernels.spmv_ell.ref import spmv_ell_ref

PR_QUERY = (
    "N(;w:int) :- Edge(x,y); w=<<COUNT(x)>>.\n"
    "InvDeg(x;y:float) :- Edge(x,z); y=1.0/<<COUNT(z)>>.\n"
    "PageRank(x;y:float) :- Edge(x,z); y=1.0/N.\n"
    "PageRank(x;y:float)*[i=5] :- Edge(x,z),PageRank(z),InvDeg(z); "
    "y=0.15/N+0.85*<<SUM(z)>>.")


def run() -> list:
    rows = []
    for gname, g in bench_graphs().items():
        src = np.repeat(np.arange(g.n), g.degrees)
        eng = Engine()
        eng.load_edges("Edge", src, g.neighbors)

        def datalog():
            return eng.query(PR_QUERY)

        res = datalog()
        pr_ref = np.zeros(g.n)
        for k, v in res.as_dict().items():
            pr_ref[k] = v
        t_dl = timeit(datalog, repeats=5)

        # SpMV fixpoint: x' = 0.15/n + 0.85 * A^T (x / deg)
        deg = np.maximum(g.degrees, 1).astype(np.float32)
        nodes = g.degrees > 0
        n_act = int(nodes.sum())
        # transpose graph for pull-style SpMV
        dst_offsets = np.zeros(g.n + 1, np.int64)
        counts = np.bincount(g.neighbors, minlength=g.n)
        np.cumsum(counts, out=dst_offsets[1:])
        order = np.argsort(g.neighbors, kind="stable")
        in_src = src[order].astype(np.int32)
        cols, vals = csr_to_ell(dst_offsets, in_src)

        def spmv_iters(fn):
            x = jnp.full(g.n, 1.0 / n_act, jnp.float32)
            for _ in range(5):
                y = fn(jnp.asarray(cols), jnp.asarray(vals),
                       x / jnp.asarray(deg))
                x = jnp.where(jnp.asarray(nodes),
                              0.15 / n_act + 0.85 * y, 0.0)
            return np.asarray(x)

        pr_jnp = spmv_iters(spmv_ell_ref)
        t_jnp = timeit(lambda: spmv_iters(spmv_ell_ref), repeats=5)
        pr_pl = spmv_iters(lambda c, v, x: spmv_ell(c, v, x, interpret=True))
        t_pl = timeit(lambda: spmv_iters(
            lambda c, v, x: spmv_ell(c, v, x, interpret=True)), repeats=3)

        err_jnp = float(np.abs(pr_jnp[nodes] - pr_ref[nodes]).sum())
        err_pl = float(np.abs(pr_pl[nodes] - pr_ref[nodes]).sum())
        rows.append(row(f"table6/{gname}/eh-datalog", t_dl, "ref"))
        rows.append(row(f"table6/{gname}/spmv-jnp", t_jnp,
                        f"l1={err_jnp:.2e}"))
        rows.append(row(f"table6/{gname}/spmv-pallas", t_pl,
                        f"l1={err_pl:.2e}"))
        assert err_jnp < 1e-3 and err_pl < 1e-3
    return rows
