"""Table 5: triangle counting.

Engines compared (in-container stand-ins for the paper's competitor set):
  eh          EmptyHeaded: set-level layout optimizer + hybrid intersections
  eh-uint     relation-level uint only ("-R", what low-level engines do)
  eh-mxu      beyond-paper MXU masked-matmul path on the dense cohort
  numpy-A3    trace(A^3)/6 dense-linear-algebra baseline
The derived column reports the triangle count (all must agree) and the
relative slowdown vs eh.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_graphs, pruned_degree_ordered, row, timeit
from repro.core.layouts import (HybridSetStore, decide_relation_level,
                                decide_set_level)
from repro.kernels.triangle_mm.ops import densify_csr, triangle_count_dense


def triangle_count_store(store, csr) -> int:
    src = np.repeat(np.arange(csr.n), csr.degrees)
    return int(store.intersect_count(src, csr.neighbors).sum())


def run() -> list:
    rows = []
    for gname, g in bench_graphs().items():
        csr = pruned_degree_ordered(g)
        store_set = HybridSetStore.build(csr)
        store_uint = HybridSetStore.build(
            csr, decision=decide_relation_level(csr, "uint"))
        dense = densify_csr(csr.offsets, csr.neighbors, csr.n)

        count = triangle_count_store(store_set, csr)
        t_eh = timeit(lambda: triangle_count_store(store_set, csr))
        t_uint = timeit(lambda: triangle_count_store(store_uint, csr))
        # interpret-mode Pallas executes block-by-block in Python: time 2
        # calls with a larger block so the CPU benchmark stays bounded
        # (the kernel targets the MXU; see EXPERIMENTS.md §Perf notes)
        t_mxu = timeit(lambda: float(triangle_count_dense(
            dense, symmetric=False, block=1024)), repeats=2)
        # float64 keeps counts exact (< 2^53) and BLAS-fast; int64 matmul
        # has no BLAS path and is ~100x slower
        t_np = timeit(lambda: int(round(np.trace(
            dense.astype(np.float64) @ dense @ dense))), repeats=3)

        c_mxu = int(triangle_count_dense(dense, symmetric=False,
                                         block=1024))
        assert c_mxu == count, (c_mxu, count)
        assert triangle_count_store(store_uint, csr) == count

        frac_dense = store_set.stats()["frac_dense"]
        rows.append(row(f"table5/{gname}/eh", t_eh,
                        f"count={count};frac_dense={frac_dense:.2f}"))
        rows.append(row(f"table5/{gname}/eh-uint(-R)", t_uint,
                        f"rel={t_uint / t_eh:.2f}x"))
        rows.append(row(f"table5/{gname}/eh-mxu", t_mxu,
                        f"rel={t_mxu / t_eh:.2f}x"))
        rows.append(row(f"table5/{gname}/numpy-A3", t_np,
                        f"rel={t_np / t_eh:.2f}x"))
    return rows
