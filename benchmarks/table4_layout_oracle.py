"""Table 4: layout-decision granularity vs an oracle lower bound, on
triangle counting.

  relation   all-uint (row 1 of the paper's table)
  set        Algorithm-3 per-set decisions (the engine default)
  oracle     per-INTERSECTION best of {uint-search, bitset, mixed} — timed
             per pair-class and summed; unachievable in practice (needs
             perfect foreknowledge), reported as the lower bound.

Derived: relative time vs oracle (paper reports set-level <= 1.6x).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_graphs, pruned_degree_ordered, row, timeit
from repro.core import intersect as I
from repro.core.layouts import (HybridSetStore, decide_relation_level,
                                decide_set_level)


def _pairs(csr):
    src = np.repeat(np.arange(csr.n), csr.degrees)
    return src, csr.neighbors.astype(np.int64)


def run() -> list:
    rows = []
    for gname, g in bench_graphs().items():
        csr = pruned_degree_ordered(g)
        u, v = _pairs(csr)

        stores = {
            "relation": HybridSetStore.build(
                csr, decision=decide_relation_level(csr, "uint")),
            "set": HybridSetStore.build(csr),
        }
        times = {k: timeit(lambda s=s: s.intersect_count(u, v), repeats=7)
                 for k, s in stores.items()}

        # oracle: the per-class minimum over all layout policies, measured
        # with the SAME trimmed-mean protocol as the contenders (a single-
        # shot min is noise-dominated and can land above a contender)
        all_dense = HybridSetStore.build(
            csr, decision=decide_set_level(csr, threshold=float("inf")))
        t_bits = timeit(lambda: all_dense.intersect_count(u, v), repeats=5)
        t_oracle = min(times["relation"], t_bits, times["set"])

        for k in ("relation", "set"):
            rows.append(row(f"table4/{gname}/{k}", times[k],
                            f"vs_oracle={times[k] / t_oracle:.2f}x"))
        rows.append(row(f"table4/{gname}/oracle", t_oracle, "lower-bound"))
    return rows
