"""Benchmark harness utilities.

Paper protocol (Section 5.1.3): repeat each measurement 7 times, drop the
min and max, report the mean of the remaining 5. CSV rows are
``name,us_per_call,derived`` — ``derived`` carries the table's comparison
quantity (relative slowdown, counts, ...).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np

from repro.data import powerlaw_graph
from repro.graph import apply_ordering, order_nodes, prune_symmetric

REPEATS = 7


def timeit(fn: Callable, repeats: int = REPEATS) -> float:
    """Microseconds per call, trimmed mean (drop min+max of 7)."""
    times = []
    fn()  # warmup / compile
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times = sorted(times)[1:-1] if len(times) > 2 else times
    return float(np.mean(times))


def row(name: str, us: float, derived="") -> str:
    return f"{name},{us:.1f},{derived}"


def bench_graphs(seed: int = 0):
    """Synthetic stand-ins matched to the paper's density-skew regimes
    (Table 3): high-skew (Google+-like), modest (Higgs/Twitter-like),
    low (LiveJournal/Patents-like). Sized for CPU benchmarking."""
    return {
        "highskew": powerlaw_graph(2000, 14, 1.7, seed=seed),
        "midskew": powerlaw_graph(2000, 12, 2.1, seed=seed + 1),
        "lowskew": powerlaw_graph(2000, 10, 2.8, seed=seed + 2),
    }


def pruned_degree_ordered(g):
    """The paper's standard preprocessing for symmetric queries: order by
    degree, keep src > dst."""
    g2 = apply_ordering(g, order_nodes(g, "degree"))
    return prune_symmetric(g2)
