"""Table 7: Single-Source Shortest Paths (seminaive datalog vs vectorized
Bellman-Ford frontier relaxation). Start node = highest-degree node (paper
protocol). Derived: number of reached nodes (must agree)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_graphs, row, timeit
from repro.core.engine import Engine


def sssp_frontier(csr, start: int) -> np.ndarray:
    """Vectorized seminaive relaxation over the CSR graph."""
    dist = np.full(csr.n, np.inf)
    dist[start] = 0.0
    frontier = np.array([start])
    while len(frontier):
        lo = csr.offsets[frontier]
        hi = csr.offsets[frontier + 1]
        cnt = (hi - lo).astype(np.int64)
        tgt = csr.neighbors[np.concatenate(
            [np.arange(l, h) for l, h in zip(lo, hi)])] \
            if cnt.sum() else np.zeros(0, np.int64)
        cand = np.repeat(dist[frontier] + 1, cnt)
        best = np.full(csr.n, np.inf)
        np.minimum.at(best, tgt, cand)
        improved = best < dist
        dist = np.where(improved, best, dist)
        frontier = np.flatnonzero(improved)
    return dist


def run() -> list:
    rows = []
    for gname, g in bench_graphs().items():
        start = int(np.argmax(g.degrees))
        src = np.repeat(np.arange(g.n), g.degrees)
        eng = Engine()
        eng.load_edges("Edge", src, g.neighbors)
        q = (f"SSSP(x;y:int) :- Edge({start},x); y=1.\n"
             "SSSP(x;y:int)* :- Edge(w,x),SSSP(w); y=<<MIN(w)>>+1.")

        res = eng.query(q)
        d_eng = res.as_dict()
        t_dl = timeit(lambda: eng.query(q), repeats=5)
        d_vec = sssp_frontier(g, start)
        t_vec = timeit(lambda: sssp_frontier(g, start), repeats=5)

        reached_eng = len(d_eng)
        reached_vec = int(np.isfinite(d_vec).sum())
        for k, v in list(d_eng.items())[:200]:
            if k != start:
                assert d_vec[k] == v, (k, v, d_vec[k])
        rows.append(row(f"table7/{gname}/eh-seminaive", t_dl,
                        f"reached={reached_eng}"))
        rows.append(row(f"table7/{gname}/frontier-vec", t_vec,
                        f"reached={reached_vec}"))
    return rows
