"""Serving-layer benchmark + CI latency gate.

``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]``

Measures the query-serving path (``repro.serve.QueryServer``) on both
backends:

  * **point-query latency** — N prepared re-binds of the anchored
    triangle query, reported as p50/p99 seconds and QPS.  Every request
    after the first must hit the cached physical plan and traced bag
    program; the gate checks the no-recompile counters EXACTLY
    (``compile.plan_searches == 0``, ``trace_count`` delta 0 during the
    serving phase).
  * **batched vs sequential throughput** — the same B bindings through
    ``PreparedQuery.run_batch`` (one fused vmapped launch per same-shape
    chunk on the device backend) vs the per-binding loop, with EXACT
    result parity and EXACT batch-launch counters
    (``pipeline.batched_launches`` / ``pipeline.batched_queries``).

Writes ``SERVE_results.json`` (next to ``BENCH_results.json``).  The CI
gate mirrors ``benchmarks/run.py``: walls compare against the committed
``benchmarks/serve_baseline.json`` within ``--tolerance`` (default 3x)
plus a fixed absolute slack — shared-runner throughput swings wildly, so
the wall check only catches gross regressions, while the counter and
parity comparisons are exact and machine-independent.

``--write-baseline PATH`` refreshes the baseline from this run.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.run import BASELINE_ABS_SLACK_S

# dispatch counters gated EXACTLY per backend: the serving invariants
# (zero recompiles, fused batch launches) stated as machine-independent
# integers rather than timing
GATED_COUNTERS = (
    "compile.plan_searches",
    "compile.logical_compiles",
    "compile.physical_builds",
    "pipeline.batched_launches",
    "pipeline.batched_queries",
)


def _digest(res) -> float:
    if not res.vars:
        return float(np.asarray(res.scalar()))
    ann = res.annotation
    if ann is None:
        return float(res.num_rows)
    return float(np.asarray(ann, dtype=np.float64).sum())


def run_suite(smoke: bool) -> list:
    from repro.data import powerlaw_graph
    from repro.serve import QueryServer

    n, deg, n_point, batch = (150, 6, 32, 16) if smoke \
        else (2000, 12, 128, 64)
    g = powerlaw_graph(n, deg, 2.0, seed=0)
    src = np.repeat(np.arange(g.n), g.degrees)
    query = "C(;w:long) :- R(0,y),S(y,z),T(0,z); w=<<COUNT(*)>>."
    vertices = [int(v) for v in
                np.argsort(g.degrees)[::-1][:max(n_point, batch)]]

    out = []
    for backend in ("numpy", "device"):
        srv = QueryServer(backend=backend)
        srv.load_graph("bench", "R", src, g.neighbors)
        for al in ("S", "T"):
            srv.alias("bench", al, "R")
        from repro.core.executor import BagResultCache

        pq = srv.prepare("bench", query)
        bindings = [vertices[i % len(vertices)] for i in range(n_point)]
        pq.run(vertices[0])   # warm: plan search + codegen + trace
        pq.run_batch(bindings)  # warm the batched trace at serving shape

        eng = srv.engine("bench")
        stats = srv.backend.stats
        before = dict(stats)
        traces_before = srv.backend.trace_count()

        # ---- point-query latency: prepared re-binds, one at a time
        # (fresh bag cache: measure the join work, not warmup reuse)
        eng.bag_cache = BagResultCache()
        lat = []
        seq_results = []
        t_seq0 = time.perf_counter()
        for v in bindings:
            t0 = time.perf_counter()
            seq_results.append(pq.run(v))
            lat.append(time.perf_counter() - t0)
        seq_wall = time.perf_counter() - t_seq0
        lat = np.sort(np.asarray(lat))

        # ---- batched throughput: same bindings, one run_batch call
        # (fresh bag cache again so the host fallback loop cannot ride
        # on the sequential phase's cached per-binding results)
        eng.bag_cache = BagResultCache()
        t0 = time.perf_counter()
        batched = pq.run_batch(bindings)
        batched_wall = time.perf_counter() - t0

        parity = all(
            _digest(a) == _digest(b)    # EXACT, not approximate
            for a, b in zip(batched, seq_results))
        delta = {k: int(stats.get(k, 0) - before.get(k, 0))
                 for k in GATED_COUNTERS}
        retraces = srv.backend.trace_count() - traces_before

        # ---- static-vs-measured HBM: the memory_budget model against
        # the live device caches, per tenant (zeros on the numpy leg —
        # nothing is device-resident there).  Drift past the model
        # tolerance is a gate failure, same as parity.
        from repro.analysis.memory_budget import (MemoryBudgetError,
                                                  check_store)
        try:
            hbm = check_store(srv)
            hbm_ok = True
        except MemoryBudgetError as e:
            hbm = {"error": str(e)}
            hbm_ok = False

        out.append({
            "backend": backend,
            "n_queries": n_point,
            "p50_s": float(lat[len(lat) // 2]),
            "p99_s": float(lat[min(len(lat) - 1,
                                   int(len(lat) * 0.99))]),
            "seq_wall_s": seq_wall,
            "seq_qps": n_point / max(seq_wall, 1e-9),
            "batched_wall_s": batched_wall,
            "batched_qps": n_point / max(batched_wall, 1e-9),
            "batched_speedup": seq_wall / max(batched_wall, 1e-9),
            "parity": bool(parity),
            "retraces": int(retraces),
            "hbm": hbm,
            "hbm_ok": bool(hbm_ok),
            "dispatch": delta,
            "counters": {k: int(v)
                         for k, v in sorted(srv.counters.items())},
        })
    return out


# ------------------------------------------------- baseline gate
def _gate_summary(suite: list) -> dict:
    return {r["backend"]: {
        "p50_s": r["p50_s"],
        "batched_wall_s": r["batched_wall_s"],
        "parity": r["parity"],
        "retraces": r["retraces"],
        "dispatch": r["dispatch"],
    } for r in suite}


def write_baseline(suite: list, path: str, smoke: bool) -> None:
    payload = {
        "meta": {"smoke": bool(smoke), "unix_time": time.time(),
                 "note": "refresh with: python -m benchmarks.serve_bench "
                         "--smoke --write-baseline "
                         "benchmarks/serve_baseline.json"},
        "backends": _gate_summary(suite),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote serve baseline {path}")


def check_baseline(suite: list, path: str, tolerance: float,
                   smoke: bool) -> list:
    with open(path) as f:
        base = json.load(f)
    cur = _gate_summary(suite)
    failures = []
    base_smoke = base.get("meta", {}).get("smoke")
    if base_smoke is not None and bool(base_smoke) != bool(smoke):
        return [f"serve baseline {path} recorded with smoke={base_smoke} "
                f"but this run has smoke={smoke}"]
    for key, b in sorted(base["backends"].items()):
        c = cur.get(key)
        if c is None:
            failures.append(f"{key}: in baseline but not in this run")
            continue
        if not c["parity"]:
            failures.append(f"{key}: batched vs sequential parity FAILED")
        if c["retraces"] != b["retraces"]:
            failures.append(f"{key}: serving-phase retraces "
                            f"{c['retraces']} != baseline {b['retraces']}")
        for wall_key in ("p50_s", "batched_wall_s"):
            limit = b[wall_key] * tolerance + BASELINE_ABS_SLACK_S
            if c[wall_key] > limit:
                failures.append(
                    f"{key}: {wall_key} {c[wall_key]:.4f}s exceeds "
                    f"baseline {b[wall_key]:.4f}s * {tolerance:g} + "
                    f"{BASELINE_ABS_SLACK_S:g}s = {limit:.4f}s")
        if c["dispatch"] != b["dispatch"]:
            diff = sorted(set(c["dispatch"].items())
                          ^ set(b["dispatch"].items()))
            keys = sorted({k for k, _ in diff})
            failures.append(
                f"{key}: serving counters changed ({', '.join(keys)}) — "
                f"if intended, refresh with --write-baseline")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, few queries (CI lane)")
    ap.add_argument("--json", default="SERVE_results.json")
    ap.add_argument("--check-baseline", default=None, metavar="PATH")
    ap.add_argument("--write-baseline", default=None, metavar="PATH")
    ap.add_argument("--tolerance", type=float, default=3.0)
    args = ap.parse_args()

    suite = run_suite(args.smoke)
    print("backend,p50_ms,p99_ms,seq_qps,batched_qps,speedup,"
          "batched_launches,parity")
    for r in suite:
        print(f"{r['backend']},{r['p50_s'] * 1e3:.2f},"
              f"{r['p99_s'] * 1e3:.2f},{r['seq_qps']:.0f},"
              f"{r['batched_qps']:.0f},{r['batched_speedup']:.2f},"
              f"{r['dispatch']['pipeline.batched_launches']},"
              f"{r['parity']}")

    with open(args.json, "w") as f:
        json.dump({"meta": {"smoke": bool(args.smoke),
                            "argv": sys.argv[1:],
                            "unix_time": time.time()},
                   "suite": suite}, f, indent=2)
    print(f"# wrote {args.json}")

    # exact gates, baseline-independent
    bad = [r for r in suite if not r["parity"]]
    if bad:
        print(f"# SERVE PARITY FAILURES: {[r['backend'] for r in bad]}")
        sys.exit(1)
    drifted = [r for r in suite if not r["hbm_ok"]]
    if drifted:
        print("# HBM MODEL DRIFT (static footprint model vs live device "
              "caches):")
        for r in drifted:
            print(f"#   {r['backend']}: {r['hbm'].get('error')}")
        sys.exit(1)
    for r in suite:
        for tenant, h in sorted(r["hbm"].items()):
            print(f"# hbm[{r['backend']}/{tenant}]: "
                  f"model={h['model_bytes']}B live={h['live_bytes']}B "
                  f"delta={h['delta_bytes']}B")
    recompiles = [r for r in suite
                  if any(r["dispatch"].get(k, 0)
                         for k in ("compile.plan_searches",
                                   "compile.logical_compiles",
                                   "compile.physical_builds"))
                  or r["retraces"]]
    if recompiles:
        print("# NO-RECOMPILE VIOLATIONS (plan searches / builds / "
              "retraces during the serving phase):")
        for r in recompiles:
            print(f"#   {r['backend']}: {r['dispatch']} "
                  f"retraces={r['retraces']}")
        sys.exit(1)

    if args.write_baseline:
        write_baseline(suite, args.write_baseline, args.smoke)
    if args.check_baseline:
        failures = check_baseline(suite, args.check_baseline,
                                  args.tolerance, args.smoke)
        if failures:
            print("# SERVE BASELINE REGRESSIONS:")
            for fail in failures:
                print(f"#   {fail}")
            sys.exit(1)
        print(f"# serve baseline check OK ({args.check_baseline}, "
              f"tolerance {args.tolerance:g}x)")


if __name__ == "__main__":
    main()
