"""Benchmark harness: one module per paper table/figure, plus the
cross-backend suite that tracks the perf trajectory across PRs.

``PYTHONPATH=src python -m benchmarks.run [--only tableN] [--smoke]``

prints ``name,us_per_call,derived`` CSV rows (paper protocol: 7 runs,
trimmed mean) and writes ``BENCH_results.json`` — machine-readable
per-query × per-backend wall times plus the backend's kernel-dispatch
counters, so regressions in *where* intersections execute are visible,
not just regressions in time.

``--smoke`` runs only the backend suite on tiny graphs (one repetition),
for CI's bench-smoke lane. ``--only`` restricts the run to the matching
table/figure module and skips the backend suite (unless the filter
mentions "backend").
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


# ----------------------------------------------------- backend suite
def _result_digest(res):
    if not res.vars:
        return float(np.asarray(res.scalar()))
    ann = res.annotation
    if ann is None:
        return int(res.num_rows)
    return float(np.asarray(ann, dtype=np.float64).sum())


def run_backend_suite(smoke: bool) -> list:
    """Every paper query on every backend: wall time + dispatch counters.

    Also asserts cross-backend result parity (the differential-testing
    invariant of the backend layer) — a mismatch is reported in the row
    rather than silently timed.
    """
    from repro.core.engine import Engine
    from repro.core.executor import BagResultCache
    from repro.core.workload import ALIASES, paper_query_set
    from repro.data import powerlaw_graph

    n, deg, reps = (150, 6, 1) if smoke else (2000, 12, 3)
    g = powerlaw_graph(n, deg, 2.0, seed=0)
    src = np.repeat(np.arange(g.n), g.degrees)
    hub = int(np.argmax(g.degrees))

    out = []
    digests = {}
    for backend in ("numpy", "device"):
        eng = Engine(backend=backend)
        eng.load_edges("Edge", src, g.neighbors)
        for al in ALIASES:
            eng.alias(al, "Edge")
        for qname, q in paper_query_set(source=hub):
            walls = []
            res = None
            dispatch = {}
            for _ in range(reps):
                # fresh engine-lifetime bag cache per rep: the suite times
                # the join work (paper protocol), not cross-query reuse —
                # within-query cross-rule hits still occur and are counted
                eng.bag_cache = BagResultCache()
                before = dict(eng.backend.stats)
                t0 = time.perf_counter()
                res = eng.query(q)
                walls.append(time.perf_counter() - t0)
                # last rep's delta: per-execution counts, comparable
                # between --smoke (1 rep) and full (3 reps) artifacts
                dispatch = {k: v - before.get(k, 0)
                            for k, v in eng.backend.stats.items()
                            if v != before.get(k, 0)}
            digest = _result_digest(res)
            digests.setdefault(qname, digest)
            out.append({
                "query": qname,
                "backend": backend,
                "wall_s": min(walls),
                "result": digest,
                "parity": bool(np.isclose(digest, digests[qname],
                                          rtol=1e-5, atol=1e-6)),
                "dispatch": dispatch,
                # optimizer choices per executed rule: fhw, attribute
                # order, per-level layout routing + threshold, estimated
                # vs actual cardinalities — so plan-quality regressions
                # are visible in the artifact, not just wall time.
                "plan": eng.plan_metadata(),
            })
    return out


# ------------------------------------------------------------- driver
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    ap.add_argument("--smoke", action="store_true",
                    help="backend suite only, tiny graphs, 1 rep (CI lane)")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="output path for the machine-readable results")
    args = ap.parse_args()

    module_rows = []
    if not args.smoke:
        from benchmarks import (appc_orderings, fig4_intersect_micro,
                                table4_layout_oracle, table5_triangle,
                                table6_pagerank, table7_sssp,
                                table8_ablations)
        modules = [table5_triangle, table6_pagerank, table7_sssp,
                   table8_ablations, table4_layout_oracle,
                   fig4_intersect_micro, appc_orderings]

        print("name,us_per_call,derived")
        for mod in modules:
            name = mod.__name__.split(".")[-1]
            if args.only and args.only not in name:
                continue
            t0 = time.monotonic()
            try:
                for r in mod.run():
                    print(r)
                    module_rows.append(r)
                    sys.stdout.flush()
            except Exception as e:  # report and continue
                print(f"{name},ERROR,{e!r}")
                module_rows.append(f"{name},ERROR,{e!r}")
            print(f"# {name} finished in {time.monotonic() - t0:.1f}s")

    if args.only and not args.smoke and "backend" not in args.only:
        # a filtered single-module run: skip the cross-backend suite
        payload = {"meta": {"smoke": False, "argv": sys.argv[1:],
                            "unix_time": time.time()},
                   "backend_suite": [], "module_rows": module_rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json} (backend suite skipped: --only)")
        return

    suite = run_backend_suite(args.smoke)
    print("query,backend,wall_ms,parity,top_dispatch")
    for row_ in suite:
        top = sorted((k for k in row_["dispatch"]
                      if k.startswith("intersect.")),
                     key=lambda k: -row_["dispatch"][k])
        print(f"{row_['query']},{row_['backend']},"
              f"{row_['wall_s'] * 1e3:.1f},{row_['parity']},"
              f"{top[0] if top else '-'}")

    payload = {
        "meta": {"smoke": bool(args.smoke),
                 "argv": sys.argv[1:],
                 "unix_time": time.time()},
        "backend_suite": suite,
        "module_rows": module_rows,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.json}")

    bad = [r for r in suite if not r["parity"]]
    if bad:
        print(f"# PARITY FAILURES: {[r['query'] for r in bad]}")
        sys.exit(1)


if __name__ == "__main__":
    main()
