"""Benchmark harness: one module per paper table/figure, plus the
cross-backend suite that tracks the perf trajectory across PRs.

``PYTHONPATH=src python -m benchmarks.run [--only tableN] [--smoke]``

prints ``name,us_per_call,derived`` CSV rows (paper protocol: 7 runs,
trimmed mean) and writes ``BENCH_results.json`` — machine-readable
per-query × per-backend wall times plus the backend's kernel-dispatch
counters, so regressions in *where* intersections execute are visible,
not just regressions in time.  The suite runs with the static
verification layer fully on (``verify_plans`` default + dispatch
``sanitize``), and records the ``analysis.*`` counters — in the
exact-compared dispatch deltas and as per-row engine-lifetime totals —
so the baseline gate also proves verification stayed on.  Queries whose cost-based plan search
(``core.plan_search``) picked a non-appearance-order plan are ALSO timed
with ``REPRO_PLAN_SEARCH=off`` semantics, recording the wall-time win
and result parity against the seed plan in the artifact.

``--smoke`` runs only the backend suite on tiny graphs (one repetition),
for CI's bench-smoke lane. ``--only`` restricts the run to the matching
table/figure module and skips the backend suite (unless the filter
mentions "backend").

Device rows additionally carry the whole-bag-fusion A/B
(``bag_fusion.speedup_vs_unfused`` with exact parity, one launch per
bag vs one per attribute step), the per-query jit-launch budget
(``pipeline.launches == extend.closing_syncs`` — gated EXACTLY below),
and the engine-lifetime compile-vs-steady dispatch-wall split
(``pipeline_wall_split`` — timing, so outside the exact-gated dict).

Bench-regression gate (CI): ``--check-baseline benchmarks/baseline.json``
compares the suite against the committed baseline — wall times within a
generous ``--tolerance`` (default 3x plus a fixed absolute slack: smoke
walls are sub-second and shared-runner throughput swings 2-3x, so the
wall check only catches gross regressions; the EXACT dispatch-counter
and parity comparison is the sharp, machine-independent half of the
gate) — and exits nonzero on regression.  ``--write-baseline PATH`` refreshes the file.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

# absolute wall slack (seconds) under --check-baseline: smoke runs are
# jit-compile dominated and tiny, so a pure ratio would flag noise
BASELINE_ABS_SLACK_S = 0.25


# ----------------------------------------------------- backend suite
def _result_digest(res):
    if not res.vars:
        return float(np.asarray(res.scalar()))
    ann = res.annotation
    if ann is None:
        return int(res.num_rows)
    return float(np.asarray(ann, dtype=np.float64).sum())


def _ab_walls(eng, q, reps, set_mode, capture_counters=False):
    """Warmed, interleaved A/B timing shared by the plan-search and
    device-recursion comparisons: ``set_mode(False|True)`` toggles the
    engine feature, one untimed execution per mode absorbs plan search /
    jit / codegen / store builds, then ``max(reps, 2)`` interleaved
    timed pairs (machine-speed drift hits both modes).  Returns
    ``(walls dict, last off-mode result, last off-mode counter delta)``
    and leaves the feature switched back on."""
    from repro.core.executor import BagResultCache

    def one(mode_on):
        set_mode(mode_on)
        eng.bag_cache = BagResultCache()
        before = dict(eng.backend.stats) if capture_counters else None
        t0 = time.perf_counter()
        res = eng.query(q)
        wall = time.perf_counter() - t0
        delta = ({k: v - before.get(k, 0)
                  for k, v in eng.backend.stats.items()}
                 if capture_counters else None)
        return wall, res, delta

    ws = {False: [], True: []}
    off_res = off_delta = None
    for mode in (False, True):      # untimed warmup
        one(mode)
    for _ in range(max(reps, 2)):   # interleaved timed pairs
        for mode in (False, True):
            w, res, d = one(mode)
            ws[mode].append(w)
            if mode is False:
                off_res, off_delta = res, d
    set_mode(True)
    return ws, off_res, off_delta


def run_backend_suite(smoke: bool) -> list:
    """Every paper query on every backend: wall time + dispatch counters.

    Also asserts cross-backend result parity (the differential-testing
    invariant of the backend layer) — a mismatch is reported in the row
    rather than silently timed.
    """
    from repro.core.engine import Engine
    from repro.core.executor import BagResultCache
    from repro.core.workload import ALIASES, paper_query_set
    from repro.data import powerlaw_graph

    n, deg, reps = (150, 6, 1) if smoke else (2000, 12, 3)
    g = powerlaw_graph(n, deg, 2.0, seed=0)
    src = np.repeat(np.arange(g.n), g.degrees)
    hub = int(np.argmax(g.degrees))

    out = []
    digests = {}
    for backend in ("numpy", "device"):
        # sanitize=True: every suite execution runs the dispatch
        # sanitizer (repro.analysis.kernel_check.check_dispatch), and the
        # analysis.* counters land in each row's exact-compared dispatch
        # delta — the baseline gate thereby proves verification stayed on
        eng = Engine(backend=backend, sanitize=True)
        eng.load_edges("Edge", src, g.neighbors)
        for al in ALIASES:
            eng.alias(al, "Edge")
        # untimed process warmup: one throwaway query absorbs the
        # per-process jax/XLA init so the FIRST suite entry's wall
        # measures the query, not interpreter startup (matters for the
        # --check-baseline gate, which compares absolute walls)
        eng.query("Warm(;w:long) :- Edge(x,y); w=<<COUNT(*)>>.")
        for qname, q in paper_query_set(source=hub):
            walls = []
            res = None
            dispatch = {}
            for _ in range(reps):
                # fresh engine-lifetime bag cache per rep: the suite times
                # the join work (paper protocol), not cross-query reuse —
                # within-query cross-rule hits still occur and are counted
                eng.bag_cache = BagResultCache()
                before = dict(eng.backend.stats)
                t0 = time.perf_counter()
                res = eng.query(q)
                walls.append(time.perf_counter() - t0)
                # last rep's delta: per-execution counts, comparable
                # between --smoke (1 rep) and full (3 reps) artifacts
                dispatch = {k: v - before.get(k, 0)
                            for k, v in eng.backend.stats.items()
                            if v != before.get(k, 0)}
            digest = _result_digest(res)
            digests.setdefault(qname, digest)
            plan_md = eng.plan_metadata()
            row = {
                "query": qname,
                "backend": backend,
                "wall_s": min(walls),
                "result": digest,
                "parity": bool(np.isclose(digest, digests[qname],
                                          rtol=1e-5, atol=1e-6)),
                # the zero-sync invariant, stated per query: the device
                # pipeline must never take a per-extension host round-
                # trip (gated EXACTLY at zero below), and lands once per
                # executed join (closing_syncs)
                "host_syncs": int(dispatch.get("extend.host_syncs", 0)),
                "closing_syncs": int(dispatch.get("extend.closing_syncs",
                                                  0)),
                "pipeline_on": bool(getattr(eng.backend,
                                            "pipeline_enabled", False)),
                # whole-bag fusion launch budget: with fusion on, every
                # executed join bag is ONE jit launch, so launches ==
                # closing_syncs (gated EXACTLY below)
                "launches": int(dispatch.get("pipeline.launches", 0)),
                "fused_on": bool(getattr(eng.backend, "fuse_bags",
                                         False)),
                "dispatch": dispatch,
                # cumulative static-verification counters (plans and
                # search candidates validated, sanitize assertions run):
                # the per-rep delta above can miss plans_verified on
                # warm physical-plan-cache reps, so the artifact also
                # carries the engine-lifetime totals
                "analysis": {k: int(v)
                             for k, v in sorted(eng.backend.stats.items())
                             if k.startswith("analysis.")},
                # optimizer choices per executed rule: fhw, attribute
                # order, per-level layout routing + threshold, estimated
                # vs actual cardinalities — so plan-quality regressions
                # are visible in the artifact, not just wall time.
                "plan": plan_md,
            }
            # Cost-based search changed this query's plan: time BOTH modes
            # warmed (one untimed execution each absorbs plan search,
            # codegen and store builds — with reps=1 in --smoke the main
            # wall is compile-contaminated, which would bias the
            # comparison either way) and record the win + parity.
            changed = any(r.get("plan_search", {}).get("order_changed")
                          for r in plan_md)
            if changed and eng.plan_search:
                ws, off_res, _ = _ab_walls(
                    eng, q, reps,
                    lambda m: setattr(eng, "plan_search", m))
                on_wall, off_wall = min(ws[True]), min(ws[False])
                row["plan_search"] = {
                    "order_changed": True,
                    "wall_s_warm": on_wall,
                    "baseline_wall_s": off_wall,
                    "speedup_vs_off": off_wall / max(on_wall, 1e-9),
                    "parity_vs_off": bool(np.isclose(
                        digest, _result_digest(off_res),
                        rtol=1e-5, atol=1e-6)),
                }
            # Recursion ran as a device-resident fixpoint: ALSO time the
            # pre-PR per-round host loop (device_recursion off) warmed,
            # recording the per-round wall-time win + result parity — the
            # recursion half of the bench gate.
            rec_rounds = int(dispatch.get("recursion.device_rounds", 0))
            if backend == "device" and rec_rounds:
                ws, host_res, host_delta = _ab_walls(
                    eng, q, reps,
                    lambda m: setattr(eng, "device_recursion", m),
                    capture_counters=True)
                host_rounds = int(host_delta.get("recursion.host_rounds",
                                                 rec_rounds))
                dev_w, host_w = min(ws[True]), min(ws[False])
                row["device_recursion"] = {
                    "rounds": rec_rounds,
                    "wall_s_warm": dev_w,
                    "host_loop_wall_s": host_w,
                    "host_loop_rounds": host_rounds,
                    # whole-query walls divided by rounds: approximate
                    # (non-recursive rules amortized in), comparable
                    # between the two modes on the same query
                    "per_round_wall_s": dev_w / max(rec_rounds, 1),
                    "per_round_host_wall_s": host_w / max(host_rounds, 1),
                    "speedup_vs_host_loop": host_w / max(dev_w, 1e-9),
                    "parity_vs_host_loop": bool(np.isclose(
                        digest, _result_digest(host_res),
                        rtol=1e-5, atol=1e-6)),
                }
            # Zero-sync pipeline A/B: time the pinned per-extension-sync
            # path (pipeline off) warmed against the device-resident
            # count-then-fill path on the same query — the perf half of
            # ROADMAP item 3's acceptance (device wall no worse than the
            # sync path), plus an extra differential-parity check.
            if (backend == "device"
                    and row["pipeline_on"]
                    and dispatch.get("extend.pipeline_extends", 0)):
                ws, sync_res, sync_delta = _ab_walls(
                    eng, q, reps,
                    lambda m: setattr(eng.backend, "pipeline_enabled", m),
                    capture_counters=True)
                pipe_w, sync_w = min(ws[True]), min(ws[False])
                row["device_pipeline"] = {
                    "wall_s_warm": pipe_w,
                    "sync_path_wall_s": sync_w,
                    "sync_path_host_syncs": int(
                        sync_delta.get("extend.host_syncs", 0)),
                    "speedup_vs_sync_path": sync_w / max(pipe_w, 1e-9),
                    "parity_vs_sync_path": bool(np.isclose(
                        digest, _result_digest(sync_res),
                        rtol=1e-5, atol=1e-6)),
                }
            # Whole-bag fusion A/B: time the per-attribute-step pipeline
            # (fusion off, one launch per step) warmed against the
            # one-launch-per-bag fused program on the same query — the
            # perf half of the fusion acceptance, plus exact parity.
            if (backend == "device"
                    and row["pipeline_on"] and row["fused_on"]
                    and dispatch.get("pipeline.launches", 0)):
                ws, unf_res, unf_delta = _ab_walls(
                    eng, q, reps,
                    lambda m: setattr(eng.backend, "fuse_bags", m),
                    capture_counters=True)
                fus_w, unf_w = min(ws[True]), min(ws[False])
                row["bag_fusion"] = {
                    "wall_s_warm": fus_w,
                    "unfused_wall_s": unf_w,
                    "unfused_launches": int(
                        unf_delta.get("pipeline.launches", 0)),
                    "speedup_vs_unfused": unf_w / max(fus_w, 1e-9),
                    "parity_vs_unfused": bool(np.isclose(
                        digest, _result_digest(unf_res),
                        rtol=1e-5, atol=1e-6)),
                }
            # Compile-vs-steady wall split (engine-lifetime, seconds):
            # timing, so it lives OUTSIDE the exact-gated dispatch dict
            if hasattr(eng.backend, "wall_split"):
                row["pipeline_wall_split"] = dict(eng.backend.wall_split())
            out.append(row)
    return out


# ------------------------------------------------- bench-regression gate
def _gate_summary(suite: list) -> dict:
    """The comparable slice of a suite run: wall + parity + EXACT dispatch
    counters per query × backend.  Recursion queries on the device
    backend additionally gate on host-loop parity — the dispatch
    counters (``recursion.device_rounds`` / ``recursion.host_trie_
    rebuilds``) are already part of the exact comparison, so a recursion
    round silently falling back to the host loop fails the gate."""
    out = {}
    for r in suite:
        entry = {
            "wall_s": float(r["wall_s"]),
            "parity": bool(r["parity"]),
            "host_syncs": int(r.get("host_syncs", 0)),
            "dispatch": {k: int(v) for k, v in sorted(r["dispatch"].items())},
        }
        rec = r.get("device_recursion")
        if rec is not None:
            entry["recursion_parity"] = bool(rec["parity_vs_host_loop"])
        pipe = r.get("device_pipeline")
        if pipe is not None:
            entry["pipeline_parity"] = bool(pipe["parity_vs_sync_path"])
        fus = r.get("bag_fusion")
        if fus is not None:
            entry["fusion_parity"] = bool(fus["parity_vs_unfused"])
        out[f"{r['query']}/{r['backend']}"] = entry
    return out


def write_baseline(suite: list, path: str, smoke: bool) -> None:
    payload = {
        "meta": {"smoke": bool(smoke), "unix_time": time.time(),
                 "note": "refresh with: python -m benchmarks.run --smoke "
                         "--write-baseline benchmarks/baseline.json"},
        "queries": _gate_summary(suite),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote baseline {path} ({len(payload['queries'])} entries)")


def check_baseline(suite: list, path: str, tolerance: float,
                   smoke: bool) -> list:
    """Compare ``suite`` against the committed baseline; returns the list
    of human-readable violations (empty = gate passes)."""
    with open(path) as f:
        base = json.load(f)
    cur = _gate_summary(suite)
    failures = []
    base_smoke = base.get("meta", {}).get("smoke")
    if base_smoke is not None and bool(base_smoke) != bool(smoke):
        return [f"baseline {path} was recorded with smoke={base_smoke} but "
                f"this run has smoke={smoke} — walls/dispatch are not "
                f"comparable across suite sizes"]
    for key in sorted(set(cur) - set(base["queries"])):
        failures.append(f"{key}: present in this run but not in the "
                        f"baseline — refresh with --write-baseline to gate it")
    for key, b in sorted(base["queries"].items()):
        c = cur.get(key)
        if c is None:
            failures.append(f"{key}: present in baseline but not in this run")
            continue
        if not c["parity"]:
            failures.append(f"{key}: cross-backend parity FAILED")
        if b.get("recursion_parity") and not c.get("recursion_parity", True):
            failures.append(f"{key}: device-recursion vs host-loop parity "
                            f"FAILED")
        if b.get("pipeline_parity") and not c.get("pipeline_parity", True):
            failures.append(f"{key}: pipeline vs pinned-sync-path parity "
                            f"FAILED")
        if b.get("fusion_parity") and not c.get("fusion_parity", True):
            failures.append(f"{key}: fused-bag vs per-step-pipeline parity "
                            f"FAILED")
        limit = b["wall_s"] * tolerance + BASELINE_ABS_SLACK_S
        if c["wall_s"] > limit:
            failures.append(
                f"{key}: wall {c['wall_s']:.3f}s exceeds baseline "
                f"{b['wall_s']:.3f}s * {tolerance:g} + "
                f"{BASELINE_ABS_SLACK_S:g}s = {limit:.3f}s")
        if c["dispatch"] != b["dispatch"]:
            diff = sorted(set(c["dispatch"].items())
                          ^ set(b["dispatch"].items()))
            keys = sorted({k for k, _ in diff})
            failures.append(
                f"{key}: dispatch counters changed ({', '.join(keys)}) — "
                f"if intended, refresh with --write-baseline")
    return failures


# ------------------------------------------------------------- driver
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    ap.add_argument("--smoke", action="store_true",
                    help="backend suite only, tiny graphs, 1 rep (CI lane)")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="output path for the machine-readable results")
    ap.add_argument("--check-baseline", default=None, metavar="PATH",
                    help="compare the backend suite against a committed "
                         "baseline; exit nonzero on regression")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write/refresh the bench baseline from this run")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="wall-time regression tolerance factor for "
                         "--check-baseline (default 3x)")
    args = ap.parse_args()

    module_rows = []
    if not args.smoke:
        from benchmarks import (appc_orderings, fig4_intersect_micro,
                                table4_layout_oracle, table5_triangle,
                                table6_pagerank, table7_sssp,
                                table8_ablations)
        modules = [table5_triangle, table6_pagerank, table7_sssp,
                   table8_ablations, table4_layout_oracle,
                   fig4_intersect_micro, appc_orderings]

        print("name,us_per_call,derived")
        for mod in modules:
            name = mod.__name__.split(".")[-1]
            if args.only and args.only not in name:
                continue
            t0 = time.monotonic()
            try:
                for r in mod.run():
                    print(r)
                    module_rows.append(r)
                    sys.stdout.flush()
            except Exception as e:  # report and continue
                print(f"{name},ERROR,{e!r}")
                module_rows.append(f"{name},ERROR,{e!r}")
            print(f"# {name} finished in {time.monotonic() - t0:.1f}s")

    if args.only and not args.smoke and "backend" not in args.only:
        if args.check_baseline or args.write_baseline:
            print("# ERROR: --check-baseline/--write-baseline need the "
                  "backend suite, which --only skips")
            sys.exit(2)
        # a filtered single-module run: skip the cross-backend suite
        payload = {"meta": {"smoke": False, "argv": sys.argv[1:],
                            "unix_time": time.time()},
                   "backend_suite": [], "module_rows": module_rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json} (backend suite skipped: --only)")
        return

    suite = run_backend_suite(args.smoke)
    print("query,backend,wall_ms,parity,top_dispatch")
    for row_ in suite:
        top = sorted((k for k in row_["dispatch"]
                      if k.startswith("intersect.")),
                     key=lambda k: -row_["dispatch"][k])
        extra = ""
        ps = row_.get("plan_search")
        if ps:
            extra = (f"  # plan changed: {ps['speedup_vs_off']:.2f}x vs "
                     f"search-off (parity={ps['parity_vs_off']})")
        pipe = row_.get("device_pipeline")
        if pipe:
            extra += (f"  # pipeline: 0 host syncs, "
                      f"{pipe['speedup_vs_sync_path']:.2f}x vs sync path "
                      f"({pipe['sync_path_host_syncs']} syncs, "
                      f"parity={pipe['parity_vs_sync_path']})")
        fus = row_.get("bag_fusion")
        if fus:
            extra += (f"  # fused bags: {row_['launches']} launches "
                      f"(vs {fus['unfused_launches']} unfused), "
                      f"{fus['speedup_vs_unfused']:.2f}x, "
                      f"parity={fus['parity_vs_unfused']}")
        rec = row_.get("device_recursion")
        if rec:
            extra += (f"  # device recursion: {rec['rounds']} rounds, "
                      f"{rec['speedup_vs_host_loop']:.2f}x vs host loop "
                      f"({rec['per_round_host_wall_s'] * 1e3:.1f} -> "
                      f"{rec['per_round_wall_s'] * 1e3:.1f} ms/round, "
                      f"parity={rec['parity_vs_host_loop']})")
        print(f"{row_['query']},{row_['backend']},"
              f"{row_['wall_s'] * 1e3:.1f},{row_['parity']},"
              f"{top[0] if top else '-'}{extra}")

    payload = {
        "meta": {"smoke": bool(args.smoke),
                 "argv": sys.argv[1:],
                 "unix_time": time.time()},
        "backend_suite": suite,
        "module_rows": module_rows,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.json}")

    # parity gates BEFORE the baseline is (re)written: a run with a
    # cross-backend mismatch must never produce a reference file
    bad = [r for r in suite if not r["parity"]]
    if bad:
        print(f"# PARITY FAILURES: {[r['query'] for r in bad]}")
        sys.exit(1)

    # zero-sync gate, EXACT and baseline-independent: with the pipeline
    # on, a device-backend query taking ANY per-extension host sync is a
    # regression (the invariant is ==0, not "few")
    leaky = [r for r in suite
             if r["backend"] == "device" and r.get("pipeline_on")
             and r.get("host_syncs", 0) != 0]
    if leaky:
        print("# ZERO-SYNC VIOLATIONS (extend.host_syncs != 0 with the "
              "device pipeline on):")
        for r in leaky:
            print(f"#   {r['query']}: {r['host_syncs']}")
        sys.exit(1)

    # launch-budget gate, EXACT and baseline-independent: with whole-bag
    # fusion on, every executed join bag is ONE jit launch, so
    # pipeline.launches must equal extend.closing_syncs (one landing per
    # join attempt — overflow retries count one launch per attempt)
    over = [r for r in suite
            if r["backend"] == "device" and r.get("pipeline_on")
            and r.get("fused_on")
            and r.get("launches", 0) != r.get("closing_syncs", 0)]
    if over:
        print("# LAUNCH-BUDGET VIOLATIONS (pipeline.launches != "
              "extend.closing_syncs with whole-bag fusion on):")
        for r in over:
            print(f"#   {r['query']}: {r['launches']} launches, "
                  f"{r['closing_syncs']} landings")
        sys.exit(1)

    if args.write_baseline:
        write_baseline(suite, args.write_baseline, args.smoke)

    if args.check_baseline:
        failures = check_baseline(suite, args.check_baseline,
                                  args.tolerance, args.smoke)
        if failures:
            print("# BENCH BASELINE REGRESSIONS:")
            for fail in failures:
                print(f"#   {fail}")
            sys.exit(1)
        print(f"# baseline check OK ({args.check_baseline}, "
              f"tolerance {args.tolerance:g}x)")


if __name__ == "__main__":
    main()
