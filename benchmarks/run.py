"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only tableN]``
prints ``name,us_per_call,derived`` CSV rows (paper protocol: 7 runs,
trimmed mean).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    args = ap.parse_args()

    from benchmarks import (appc_orderings, fig4_intersect_micro,
                            table4_layout_oracle, table5_triangle,
                            table6_pagerank, table7_sssp, table8_ablations)
    modules = [table5_triangle, table6_pagerank, table7_sssp,
               table8_ablations, table4_layout_oracle,
               fig4_intersect_micro, appc_orderings]

    print("name,us_per_call,derived")
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        t0 = time.monotonic()
        try:
            for r in mod.run():
                print(r)
                sys.stdout.flush()
        except Exception as e:  # report and continue
            print(f"{name},ERROR,{e!r}")
        print(f"# {name} finished in {time.monotonic() - t0:.1f}s")


if __name__ == "__main__":
    main()
