"""Appendix C.2: node orderings x triangle counting (Tables 11-13).

For each ordering: preprocessing cost, then triangle-count time with the
set-level optimizer, on symmetrically-filtered (pruned) data. Derived:
relative time vs degree ordering + dense-cohort fraction (orderings change
neighbor-set ranges and hence layout decisions).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_graphs, row, timeit
from repro.core.layouts import HybridSetStore
from repro.graph import ORDERINGS, apply_ordering, order_nodes, prune_symmetric


def _tri_time(csr):
    store = HybridSetStore.build(csr)
    src = np.repeat(np.arange(csr.n), csr.degrees)

    def count():
        return int(store.intersect_count(src, csr.neighbors).sum())

    c = count()
    return timeit(count, repeats=3), c, store.stats()["frac_dense"]


def run() -> list:
    rows = []
    g = bench_graphs()["midskew"]
    base_t = None
    for method in ("degree", "random", "bfs", "revdegree", "strongruns",
                   "shingle", "hybrid"):
        t_order = timeit(lambda: order_nodes(g, method), repeats=3)
        g2 = apply_ordering(g, order_nodes(g, method))
        pruned = prune_symmetric(g2)
        t, count, frac = _tri_time(pruned)
        if method == "degree":
            base_t = t
        rows.append(row(f"appc/{method}/count", t,
                        f"rel={t / base_t:.2f}x;frac_dense={frac:.2f};"
                        f"count={count}"))
        rows.append(row(f"appc/{method}/ordering-cost", t_order, ""))
    return rows
