"""Table 8: 4-Clique (K4), Lollipop (L31), Barbell (B31) with feature
ablations:

  eh     full engine (GHD plans + set-level layouts + hybrid algorithms)
  -R     layout optimizer forced to relation-level uint
  -GHD   single-bag WCOJ plan (no early aggregation) — the LogicBlox mode

Derived: COUNT(*) (all variants must agree) and relative slowdown vs eh.
K4 runs on pruned data (symmetric query); L31/B31 on undirected (paper
protocol). Graphs are smaller for B31: its -GHD plan is O(N^3)-ish by
design — that blowup IS the measurement.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_graphs, pruned_degree_ordered, row, timeit
from repro.core.engine import Engine
from repro.core.layouts import set_engine_layout_mode
from repro.data import powerlaw_graph

QUERIES = {
    "K4": ("K4(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),X(y,a),Y(z,a); "
           "w=<<COUNT(*)>>.", True),
    "L31": ("L(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a); w=<<COUNT(*)>>.",
            False),
    "B31": ("B(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),R2(a,b),S2(b,c),"
            "T2(a,c); w=<<COUNT(*)>>.", False),
}
ALIASES = ("R", "S", "T", "U", "X", "Y", "R2", "S2", "T2")


def engine_for(csr, use_ghd=True) -> Engine:
    eng = Engine(use_ghd=use_ghd)
    src = np.repeat(np.arange(csr.n), csr.degrees)
    eng.load_edges("Edge", src, csr.neighbors)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


def run() -> list:
    rows = []
    graphs = {
        "midskew": powerlaw_graph(400, 7, 2.1, seed=11),
        "lowskew": powerlaw_graph(400, 6, 2.8, seed=12),
    }
    for gname, g in graphs.items():
        pruned = pruned_degree_ordered(g)
        for qname, (q, symmetric) in QUERIES.items():
            csr = pruned if symmetric else g
            eng = engine_for(csr, use_ghd=True)
            eng_noghd = engine_for(csr, use_ghd=False)
            count = int(eng.query(q).scalar())
            assert int(eng_noghd.query(q).scalar()) == count
            t_eh = timeit(lambda: eng.query(q), repeats=3)
            set_engine_layout_mode("uint")
            assert int(eng.query(q).scalar()) == count
            t_nor = timeit(lambda: eng.query(q), repeats=3)
            set_engine_layout_mode("set")
            t_noghd = timeit(lambda: eng_noghd.query(q), repeats=2)
            rows.append(row(f"table8/{gname}/{qname}/eh", t_eh,
                            f"count={count}"))
            rows.append(row(f"table8/{gname}/{qname}/-R", t_nor,
                            f"rel={t_nor / t_eh:.2f}x"))
            rows.append(row(f"table8/{gname}/{qname}/-GHD", t_noghd,
                            f"rel={t_noghd / t_eh:.2f}x"))
    return rows
