"""Figures 4/5/8/9: set-intersection micro-benchmarks.

  density sweep  (fig 4/5): uint search vs blocked bitset at fixed range,
                 varying density — the crossover motivates Algorithm 3.
  cardinality-skew sweep (fig 8): lockstep search (min-property /
                 SIMDGalloping analogue) vs the membership-test kernel
                 (SIMDShuffling analogue) at ratios 1:1 .. 1:256 — the
                 crossover motivates Algorithm 2's 32:1 switch.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import intersect as I
from repro.core.trie import CSRGraph
from repro.kernels.uint_intersect.ops import uint_intersect_count


def _set_pair_csr(a: np.ndarray, b: np.ndarray, n: int) -> CSRGraph:
    offsets = np.array([0, len(a), len(a) + len(b)], np.int64)
    return CSRGraph(2, offsets, np.concatenate([a, b]).astype(np.int32))


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    rangev = 1 << 16

    # ---- density sweep (fixed range, vary |S|)
    for density in (0.001, 0.01, 0.05, 0.2, 0.5):
        card = max(4, int(rangev * density))
        a = np.sort(rng.choice(rangev, card, replace=False))
        b = np.sort(rng.choice(rangev, card, replace=False))
        csr = _set_pair_csr(a, b, rangev)
        u = np.zeros(64, np.int64)
        v = np.ones(64, np.int64)
        t_uint = timeit(lambda: I.intersect_count_uint(
            csr.offsets, csr.neighbors, u, v), repeats=3)
        bs = I.build_blocked_bitset(csr.offsets, csr.neighbors,
                                    np.array([0, 1]), rangev, 256)
        t_bits = timeit(lambda: I.bitset_intersect_count(
            bs, np.zeros(64, np.int64), np.ones(64, np.int64)), repeats=3)
        rows.append(row(f"fig4/density={density}/uint", t_uint, ""))
        rows.append(row(f"fig4/density={density}/bitset", t_bits,
                        f"rel={t_bits / t_uint:.2f}x"))

    # ---- cardinality-skew sweep (fig 8)
    small_card = 64
    for ratio in (1, 4, 32, 128, 256):
        big = np.sort(rng.choice(1 << 20, small_card * ratio, replace=False))
        small = np.sort(rng.choice(big, small_card, replace=False))
        csr = _set_pair_csr(small, big, 1 << 20)
        u = np.zeros(32, np.int64)
        v = np.ones(32, np.int64)
        t_search = timeit(lambda: I.intersect_count_uint(
            csr.offsets, csr.neighbors, u, v), repeats=3)
        a_pad = np.broadcast_to(small, (32, small_card))
        b_pad = np.broadcast_to(big, (32, len(big)))
        t_member = timeit(lambda: np.asarray(uint_intersect_count(
            a_pad, b_pad, interpret=True)), repeats=3)
        rows.append(row(f"fig8/ratio=1:{ratio}/search", t_search, ""))
        rows.append(row(f"fig8/ratio=1:{ratio}/membership", t_member,
                        f"rel={t_member / t_search:.2f}x"))
    return rows
