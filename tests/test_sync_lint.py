"""Host-sync linter (repro.analysis.sync_lint) tests.

Locks the ROADMAP item-3 discipline: the committed baseline exactly
enumerates today's known host syncs (one per fused device extension, the
materialize path's one device_get + one np.nonzero, one closing
device_get per recursion fixpoint), injected hazards in traced code are
caught statically, and the baseline comparison fails in BOTH directions.
"""
import pathlib
import textwrap

from repro.analysis import sync_lint

BACKEND_PATH = (pathlib.Path(sync_lint._REPRO_ROOT) / "core" / "backend.py")


def kinds(findings):
    return [f.kind for f in findings]


# ------------------------------------------------------------- the tree
def test_tree_matches_committed_baseline_exactly():
    findings = sync_lint.lint_tree()
    baseline = sync_lint.load_baseline()
    new, removed = sync_lint.compare(findings, baseline)
    assert new == [], f"new host-sync hazards: {new}"
    assert removed == [], (f"syncs removed but baseline not shrunk: "
                           f"{removed}")


def test_baseline_enumerates_exactly_the_known_syncs():
    """The zero-sync pipeline leaves exactly ONE audited transfer point
    in the whole device path: ``kernels.common.host_get``, the single
    choke point every closing sync (pipeline landing, fixpoint exit,
    materialize extraction, legacy per-extension oracle) routes
    through — nothing else."""
    baseline = sync_lint.load_baseline()
    assert baseline == {
        "kernels/common.py::host_get::device_get": 1,
    }


def test_no_traced_context_hazards_in_tree():
    """jit/Pallas-traced code is clean today and must stay clean — these
    finding kinds never legitimately enter the baseline."""
    traced = [f for f in sync_lint.lint_tree()
              if f.kind in sync_lint.TRACED_KINDS]
    assert traced == [], [str(f) for f in traced]


# ------------------------------------------------------------ injection
def test_injected_item_in_jitted_extension_caught():
    """The acceptance scenario: inject a ``.item()`` into the REAL
    device backend's jitted fused-probe path; the linter must flag it."""
    source = BACKEND_PATH.read_text()
    needle = "poss.append(pos)"
    assert needle in source  # _fused_probe body (jitted)
    injected = source.replace(needle, "poss.append(pos.item())")
    findings = sync_lint.lint_source(injected, "core/backend.py")
    items = [f for f in findings if f.kind == "item"]
    assert len(items) == 1
    assert items[0].qualname == "_fused_probe"
    # and the both-direction gate fails on it
    new, _removed = sync_lint.compare(findings, sync_lint.load_baseline())
    assert any("_fused_probe::item" in k for k in new)


def test_coercions_numpy_and_implicit_bool_flagged():
    src = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp

        @jax.jit
        def traced(x):
            n = int(x.shape[0])
            y = np.searchsorted(x, 3)
            if jnp.any(x > 0):
                return y
            return x.item()
    """)
    got = kinds(sync_lint.lint_source(src, "core/fake.py"))
    assert sorted(got) == ["coerce", "implicit_bool", "item", "np_call"]


def test_pallas_kernel_fns_are_traced_including_partial():
    """Kernels reach pallas_call bare or functools.partial-wrapped (the
    triangle_mm idiom) — both must be treated as traced."""
    src = textwrap.dedent("""
        import functools
        import numpy as np
        from jax.experimental import pallas as pl

        def _kernel(a_ref, o_ref, *, n_k):
            o_ref[...] = np.asarray(a_ref[...])

        def _plain(a_ref, o_ref):
            bad = a_ref[...].item()

        def run(a, nb):
            f = pl.pallas_call(functools.partial(_kernel, n_k=nb),
                               out_shape=None)
            g = pl.pallas_call(_plain, out_shape=None)
            return f(a), g(a)
    """)
    findings = sync_lint.lint_source(src, "kernels/fake/kernel.py")
    by_fn = {(f.qualname, f.kind) for f in findings}
    assert ("_kernel", "np_call") in by_fn
    assert ("_plain", "item") in by_fn


def test_untraced_host_code_not_flagged():
    src = textwrap.dedent("""
        import numpy as np

        def host(x):
            n = int(x.shape[0])
            return np.asarray(x).item()
    """)
    assert sync_lint.lint_source(src, "core/fake.py") == []


def test_transfer_points_budgeted_only_in_device_modules():
    src = textwrap.dedent("""
        import jax, numpy as np

        def pull(x):
            y = jax.device_get(x)
            return np.nonzero(y)
    """)
    # device-path module: both transfers accounted
    got = kinds(sync_lint.lint_source(src, "kernels/fake/ops.py"))
    assert sorted(got) == ["device_get", "np_nonzero"]
    # host-side module: out of scope (host oracles use np.nonzero freely)
    assert sync_lint.lint_source(src, "core/intersect.py") == []


# ------------------------------------------------------------- baseline
def test_compare_fails_both_directions():
    findings = sync_lint.lint_tree()
    baseline = sync_lint.baseline_counts(findings)
    # regression direction
    k = next(iter(baseline))
    shrunk = dict(baseline)
    shrunk[k] -= 1
    new, removed = sync_lint.compare(findings, shrunk)
    assert new and not removed
    # improvement direction: baseline demands a sync that no longer exists
    grown = dict(baseline)
    grown["core/fake.py::gone::device_get"] = 1
    new, removed = sync_lint.compare(findings, grown)
    assert removed and not new


def test_write_baseline_roundtrip(tmp_path):
    findings = sync_lint.lint_tree()
    path = tmp_path / "baseline.json"
    sync_lint.write_baseline(findings, path)
    assert sync_lint.load_baseline(path) == \
        sync_lint.baseline_counts(findings)


def test_cli_green_on_committed_baseline():
    assert sync_lint.main([]) == 0
