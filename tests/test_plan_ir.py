"""Physical plan IR (core.plan_ir) + statistics-driven optimizer tests.

The acceptance invariant of the planner layer: ``codegen`` and
``executor`` are thin lowerings of ONE shared IR, so results must be
identical across every paper query x both backends x both lowerings;
physical decisions (Algorithm-3 layout thresholds, terminal-fold
routing, engine-lifetime bag reuse) are made once, in the IR, from the
statistics catalog."""
import numpy as np
import pytest

from conftest import brute_triangle_count, random_undirected_graph
from repro.core import workload as W
from repro.core.engine import Engine
from repro.core.layouts import SIMD_REGISTER_BITS
from repro.core.plan_ir import (BagScan, Extend, MaterializeShared,
                                TerminalFold, TopDownJoin)

ALIASES = W.ALIASES

PAPER_QUERIES = {
    "triangle_count": W.TRIANGLE_COUNT,
    "triangle_list": W.TRIANGLE_LIST,
    "4clique": W.FOUR_CLIQUE,
    "lollipop": W.LOLLIPOP,
    "barbell": W.BARBELL,
    "pagerank": W.pagerank_program(iters=4),
    "sssp": W.sssp_program("{s}"),
}


def make_engine(src, dst, backend="numpy", **kw):
    eng = Engine(backend=backend, **kw)
    eng.load_edges("Edge", src, dst)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


def assert_same_result(r1, r2):
    assert r1.vars == r2.vars
    for v in r1.vars:
        np.testing.assert_array_equal(r1.columns[v], r2.columns[v])
    if r1.annotation is None:
        assert r2.annotation is None
    else:
        np.testing.assert_allclose(np.asarray(r1.annotation),
                                   np.asarray(r2.annotation),
                                   rtol=1e-6, atol=1e-7)


# ------------------------------------------------------------- IR structure
def test_physical_plan_operator_dag_triangle():
    src, dst, _ = random_undirected_graph(24, 0.3, 1)
    eng = make_engine(src, dst)
    eng.query(PAPER_QUERIES["triangle_count"])
    pp = eng.last_physical
    assert len(pp.bag_ops) == 1
    bops = pp.bag_ops[0]
    assert isinstance(bops.scan, BagScan)
    assert isinstance(bops.materialize, MaterializeShared)
    # x, y extend; z is the early-aggregation terminal fold
    assert [type(s) for s in bops.steps] == [Extend, Extend, TerminalFold]
    fold = bops.steps[-1]
    assert fold.semiring == "count"
    assert fold.routing == "pair_kernel"
    # Algorithm-3 threshold is statistics-driven, not the fixed constant
    assert fold.layout_threshold is not None
    assert fold.layout_threshold != SIMD_REGISTER_BITS
    # every step carries a positive cardinality estimate
    assert all(s.est_rows > 0 for s in bops.steps)
    assert pp.final is None  # aggregate: top-down elided
    assert "extend" in pp.pretty()


def test_estimated_vs_actual_cardinalities_recorded():
    src, dst, _ = random_undirected_graph(24, 0.3, 2)
    eng = make_engine(src, dst)
    eng.query(PAPER_QUERIES["lollipop"])
    md = eng.plan_metadata()
    assert len(md) == 1
    for bag in md[0]["bags"]:
        assert bag["est_rows"] > 0
        assert "actual_rows" in bag and bag["actual_rows"] >= 0
        assert any(s["op"] in ("extend", "terminal_fold")
                   for s in bag["steps"])


@pytest.mark.parametrize("backend", ["numpy", "device"])
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_q_error_scorecard_populated_and_finite(qname, backend):
    """``plan_metadata()``'s optimizer scorecard: every paper query on
    both backends records finite est-vs-actual fields — per-bag
    ``est_rows``/``actual_rows`` and the geometric-mean q-error in
    ``est_error``.  Device-resident fixpoint records carry no bags (the
    recursion never leaves the device), so their scorecard is the empty
    one; every other record must have scored at least one bag."""
    import math
    src, dst, _ = random_undirected_graph(18, 0.3, 7)
    eng = make_engine(src, dst, backend)
    eng.query(PAPER_QUERIES[qname].replace("{s}", str(int(src[0]))))
    md = eng.plan_metadata()
    assert md
    for rec in md:
        ee = rec["est_error"]
        assert set(ee) == {"n_bags", "geo_mean_q"}
        if rec.get("recursion", {}).get("mode") == "device":
            assert ee == {"n_bags": 0, "geo_mean_q": None}
            continue
        assert ee["n_bags"] >= 1
        assert math.isfinite(ee["geo_mean_q"]) and ee["geo_mean_q"] >= 1.0
        scored = 0
        for bag in rec["bags"]:
            assert math.isfinite(bag["est_rows"]) and bag["est_rows"] > 0
            if "actual_rows" in bag:  # cache-hit bags are not re-scored
                scored += 1
                assert math.isfinite(float(bag["actual_rows"]))
                assert bag["actual_rows"] >= 0
            for step in bag["steps"]:
                assert math.isfinite(step["cost"])
                if step["op"] == "extend":  # folds estimate cost only
                    assert math.isfinite(step["est_rows"])
        assert scored == ee["n_bags"]
    assert any(rec["est_error"]["n_bags"] >= 1 for rec in md)


# ------------------------------------- shared-IR parity (acceptance gate)
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_paper_query_parity_across_lowerings_and_backends(qname):
    """codegen x interpreter x numpy x device all lower the same IR and
    must agree exactly on every paper query."""
    src, dst, adj = random_undirected_graph(20, 0.3, 11)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    ref = None
    for backend in ("numpy", "device"):
        for use_codegen in (True, False):
            eng = make_engine(src, dst, backend, use_codegen=use_codegen)
            res = eng.query(q)
            if ref is None:
                ref = res
            else:
                assert_same_result(ref, res)
    if qname == "triangle_count":
        assert int(ref.scalar()) == 6 * brute_triangle_count(adj)


# --------------------------------------------- top-down (listing spanning)
def brute_span(adj):
    n = adj.shape[0]
    a = adj.astype(bool)
    want = set()
    for x in range(n):
        for y in range(n):
            if not a[x, y]:
                continue
            for z in range(n):
                if not (a[y, z] and a[x, z]):
                    continue
                for w in range(n):
                    if a[x, w]:
                        want.add((y, w))
    return want

SPAN_QUERY = "P(y,a) :- R(x,y),S(y,z),T(x,z),U(x,a)."


@pytest.mark.parametrize("use_codegen", [True, False])
def test_listing_outputs_spanning_bags(use_codegen):
    """Regression: outputs spanning bags must join on the connector
    attributes — the seed projected them away and produced a cross
    product."""
    src, dst, adj = random_undirected_graph(14, 0.3, 3)
    eng = make_engine(src, dst, use_codegen=use_codegen)
    res = eng.query(SPAN_QUERY)
    got = set(zip(res.columns["y"].tolist(), res.columns["a"].tolist()))
    assert got == brute_span(adj)


def test_topdown_joins_every_reduced_bag_exactly_once():
    """The final collect references each reduced bag STRUCTURALLY (by
    MaterializeShared op id) exactly once — the invariant the old
    source-scraping ``codegen._bag_names`` maintained by accident."""
    src, dst, _ = random_undirected_graph(14, 0.3, 3)
    eng = make_engine(src, dst)
    eng.query(SPAN_QUERY)
    pp = eng.last_physical
    td = pp.final
    assert isinstance(td, TopDownJoin)
    reduced = [b.materialize.op_id for b in pp.bag_ops
               if b.materialize.output_vars]
    assert sorted(td.inputs) == sorted(reduced)
    assert len(set(td.inputs)) == len(td.inputs)
    # and the generated source joins exactly those bag variables
    src_text = eng.generated_source()
    join_line = [ln for ln in src_text.splitlines()
                 if ln.strip().startswith("_atoms = [")][0]
    for op_id in td.inputs:
        assert join_line.count(f"_result_to_trie(bag{op_id},") == 1


# ----------------------------------------------- engine-lifetime bag cache
def test_cross_rule_bag_cache_hit_renamed_vars():
    """Appendix A.1 generalized to engine lifetime: the same sub-bag in a
    LATER rule (different variable names) is served from cache."""
    src, dst, _ = random_undirected_graph(20, 0.3, 5)
    eng = make_engine(src, dst)
    prog = ("A(;w:long) :- R(x,y),S(y,z),T(x,z); w=<<COUNT(*)>>.\n"
            "B(;w:long) :- R(a,b),S(b,c),T(a,c); w=<<COUNT(*)>>.")
    res = eng.query(prog)
    st = eng.dispatch_summary()
    assert st["bag_cache.hits"] >= 1, st
    # and across separate query() calls on the same engine
    hits0 = st["bag_cache.hits"]
    res2 = eng.query("C(;w:long) :- R(p,q),S(q,r),T(p,r); w=<<COUNT(*)>>.")
    assert eng.dispatch_summary()["bag_cache.hits"] > hits0
    assert int(res.scalar()) == int(res2.scalar())


def test_bag_cache_alias_resolution_barbell():
    """Barbell's two triangle bags read R,S,T vs R2,S2,T2 — all aliases
    of Edge — and must share one cached result (the paper's 2x)."""
    src, dst, _ = random_undirected_graph(16, 0.3, 7)
    eng = make_engine(src, dst)
    eng.query(PAPER_QUERIES["barbell"])
    st = eng.dispatch_summary()
    assert st["bag_cache.hits"] >= 1, st


def test_bag_cache_invalidated_on_reload():
    """Catalog versions gate reuse: reloading a relation must invalidate
    every cached bag that read it."""
    src1, dst1, adj1 = random_undirected_graph(18, 0.35, 9)
    src2, dst2, adj2 = random_undirected_graph(18, 0.15, 10)
    eng = make_engine(src1, dst1)
    q = PAPER_QUERIES["triangle_count"]
    r1 = eng.query(q)
    assert int(r1.scalar()) == 6 * brute_triangle_count(adj1)
    eng.load_edges("Edge", src2, dst2)
    r2 = eng.query(q)
    assert int(r2.scalar()) == 6 * brute_triangle_count(adj2)


# ------------------------------------------- statistics-driven layout route
@pytest.mark.parametrize("backend", ["numpy", "device"])
def test_dispatch_summary_shows_stats_driven_layout(backend):
    src, dst, _ = random_undirected_graph(40, 0.3, 3)
    eng = make_engine(src, dst, backend)
    eng.query(PAPER_QUERIES["triangle_count"])
    st = eng.dispatch_summary()
    assert st.get("layout.stats_driven", 0) > 0, st
    # the threshold actually used differs from the old fixed constant
    assert st.get("layout.threshold_bits") != SIMD_REGISTER_BITS, st


def test_executor_accepts_logical_plan_directly():
    """Back-compat: Executor.run(QueryPlan) annotates on the fly."""
    from repro.core.compile import compile_rule
    from repro.core.datalog import parse
    from repro.core.executor import Executor

    src, dst, adj = random_undirected_graph(18, 0.3, 13)
    eng = make_engine(src, dst)
    rule = parse(PAPER_QUERIES["triangle_count"]).rules[0]
    plan = compile_rule(rule)
    ex = Executor(eng.catalog, eng.encode, backend=eng.backend)
    res = ex.run(plan)
    assert int(np.asarray(res.annotation)) == 6 * brute_triangle_count(adj)
    assert ex.stats.bags_run == 1


def test_physical_plan_metadata_is_json_serializable():
    import json

    src, dst, _ = random_undirected_graph(16, 0.3, 15)
    eng = make_engine(src, dst)
    eng.query(PAPER_QUERIES["barbell"])
    md = eng.plan_metadata()
    json.dumps(md)  # must not raise
    assert md[0]["fhw"] == pytest.approx(1.5)
    assert md[0]["search_exhausted"] is False


def test_build_physical_plan_estimates_capped_by_agm():
    """Cardinality estimates stay within the bag's AGM bound computed
    from real relation sizes."""
    import math

    src, dst, _ = random_undirected_graph(24, 0.3, 17)
    eng = make_engine(src, dst)
    eng.query(PAPER_QUERIES["triangle_count"])
    pp = eng.last_physical
    m = eng.catalog.get("Edge").num_tuples
    agm_bound = m ** 1.5  # triangle fhw = 3/2
    for s in pp.bag_ops[0].steps:
        assert s.est_rows <= agm_bound * (1 + 1e-9)
    assert math.isfinite(pp.bag_ops[0].materialize.est_rows)
