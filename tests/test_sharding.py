"""Shape-aware sharding resolver unit tests (AbstractMesh — no devices)."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.dist.sharding import (GNN_RULES, LM_RULES, RECSYS_RULES,
                                 _resolve_one, resolve_batch_specs,
                                 resolve_param_specs, zero1_specs)

SDS = jax.ShapeDtypeStruct
MESH = AbstractMesh((16, 16), ("data", "model"))
MESH3 = AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def test_heads_divisible_shards_on_model():
    # qwen2-like: 64 heads % 16 == 0 -> heads take 'model'
    spec = _resolve_one(("layer", "embed", "heads", "head_dim"),
                        (80, 8192, 64, 128), MESH, LM_RULES, fsdp=True)
    assert spec[2] == "model"
    assert "data" in spec  # FSDP binds data somewhere


def test_heads_fallback_to_embed():
    # arctic-like: 56 heads % 16 != 0 -> 'model' falls back to embed dim
    spec = _resolve_one(("layer", "embed", "heads", "head_dim"),
                        (35, 7168, 56, 128), MESH, LM_RULES, fsdp=False)
    assert spec[2] is None
    assert spec[1] == "model"


def test_no_duplicate_mesh_axes():
    spec = _resolve_one(("layer", "embed", "mlp"),
                        (32, 4096, 14336), MESH, LM_RULES, fsdp=True)
    used = [a for a in spec if a is not None]
    assert len(used) == len(set(used))


def test_fsdp_threshold():
    small = _resolve_one(("layer", "embed"), (2, 64), MESH, LM_RULES,
                         fsdp=True)
    assert all(a is None for a in small)  # below fsdp_min_size: replicated


def test_expert_sharding():
    spec = _resolve_one(("layer", "expert", "embed", "mlp"),
                        (35, 128, 7168, 4864), MESH, LM_RULES, fsdp=True)
    assert spec[1] == "model"  # 128 % 16 == 0 -> EP
    assert "data" in spec      # FSDP on a remaining dim


def test_expert_not_divisible():
    spec = _resolve_one(("layer", "expert", "embed", "mlp"),
                        (32, 8, 4096, 14336), MESH, LM_RULES, fsdp=False)
    assert spec[1] is None     # 8 % 16 != 0
    assert spec[3] == "model"  # falls to mlp (higher priority than embed)


def test_zero1_adds_data_axis():
    params = {"w": SDS((64, 14336), np.float32)}
    pspecs = {"w": P(None, "model")}
    z = zero1_specs(pspecs, params, MESH, LM_RULES)
    assert z["w"] == P("data", "model") or z["w"][0] == "data"


def test_batch_specs_compose_pod_data():
    specs = resolve_batch_specs({"tokens": ("batch", None)},
                                {"tokens": SDS((256, 4096), np.int32)},
                                MESH3, LM_RULES)
    assert specs["tokens"][0] == ("pod", "data")


def test_batch_specs_indivisible_replicates():
    specs = resolve_batch_specs({"tokens": ("batch", None)},
                                {"tokens": SDS((3, 4096), np.int32)},
                                MESH3, LM_RULES)
    assert specs["tokens"][0] is None


def test_cache_spec_no_duplicates():
    axes = {"ckv": ("layer", "batch", "cache_seq", "qk_lora")}
    sds = {"ckv": SDS((62, 128, 32768, 256), np.float32)}
    specs = resolve_batch_specs(axes, sds, MESH, LM_RULES)
    used = [a for a in specs["ckv"] if a is not None]
    flat = []
    for a in used:
        flat.extend(a if isinstance(a, tuple) else (a,))
    assert len(flat) == len(set(flat))


def test_recsys_table_rows():
    spec = _resolve_one(("table_rows", "embed"), (39_000_000, 10), MESH,
                        RECSYS_RULES, fsdp=False)
    assert spec[0] == "model"


def test_resolve_param_specs_tree():
    axes = {"a": ("embed", "mlp"), "b": None,
            "nested": {"c": ("vocab", "embed")}}
    shapes = {"a": SDS((4096, 12800), np.float32),
              "b": SDS((7,), np.float32),
              "nested": {"c": SDS((152064, 8192), np.float32)}}
    specs = resolve_param_specs(axes, shapes, MESH, LM_RULES, fsdp=False)
    assert specs["a"][1] == "model"
    assert specs["nested"]["c"][0] == "model"
    assert all(x is None for x in specs["b"])
