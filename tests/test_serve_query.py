"""Query serving layer: parameterized plans, batched execution, graph store.

Three invariant families from the serving PR:

  * **No recompile on re-bind** — a prepared query re-bound to a new
    constant performs ZERO plan searches, logical compiles, physical
    builds, and device retraces; the ``compile.*`` counters and
    ``backend.trace_count()`` prove it (not timing).
  * **Batched == sequential, exactly** — ``run_batch`` over B bindings
    returns bit-identical results to the per-binding loop on every
    parameterized paper pattern query, on both backends; on the device
    backend a same-shape batch is ONE fused launch
    (``pipeline.batched_launches``).
  * **LRU eviction** — a graph store holding more tenants than its
    residency budget evicts the coldest tenant's device caches (and only
    those); the evicted tenant keeps answering correctly.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis.plan_verify import verify_physical_plan
from repro.core.datalog import Param
from repro.core.engine import Engine
from repro.serve import QueryServer

from conftest import random_undirected_graph

BACKENDS = ("numpy", "device")

# Parameterized variants of the paper's Table 2 pattern queries: the same
# join shapes, anchored at a bind-parameter vertex (the serving workload —
# "triangles through v", "cliques through v", ...).
PARAM_QUERIES = [
    ("triangle_at",
     "C(;w:long) :- R(0,y),S(y,z),T(0,z); w=<<COUNT(*)>>."),
    ("triangle_list_at",
     "L(y,z) :- R(0,y),S(y,z),T(0,z)."),
    ("4clique_at",
     "C(;w:long) :- R(0,y),S(y,z),T(0,z),U(0,a),X(y,a),Y(z,a); "
     "w=<<COUNT(*)>>."),
    ("lollipop_at",
     "C(;w:long) :- R(0,y),S(y,z),T(0,z),U(0,a); w=<<COUNT(*)>>."),
    ("barbell_at",
     "C(;w:long) :- R(0,y),S(y,z),T(0,z),U(0,a),R2(a,b),S2(b,c),T2(a,c); "
     "w=<<COUNT(*)>>."),
]
ALIASES = ("S", "T", "U", "X", "Y", "R2", "S2", "T2")


def make_engine(backend, n=24, p=0.3, seed=0) -> Engine:
    src, dst, _ = random_undirected_graph(n, p, seed=seed)
    eng = Engine(backend=backend)
    eng.load_edges("R", src, dst)
    for al in ALIASES:
        eng.alias(al, "R")
    return eng


def assert_same_result(a, b):
    """Exact equality — the batched path must be bit-identical to the
    sequential oracle (jnp reference fill, per the kernel contract)."""
    assert a.vars == b.vars
    for v in a.vars:
        np.testing.assert_array_equal(np.asarray(a.columns[v]),
                                      np.asarray(b.columns[v]))
    if b.annotation is None:
        assert a.annotation is None
    else:
        np.testing.assert_array_equal(np.asarray(a.annotation),
                                      np.asarray(b.annotation))


# ------------------------------------------------------- plan-cache reuse
@pytest.mark.parametrize("backend", BACKENDS)
def test_rebind_zero_recompile_zero_retrace(backend):
    eng = make_engine(backend)
    pq = eng.prepare(PARAM_QUERIES[0][1])
    assert pq.n_params == 1
    pq.run(1)  # first execution: plans, emits, traces

    stats = eng.backend.stats
    before = dict(stats)
    traces_before = eng.backend.trace_count()
    for v in (2, 3, 5, 2):
        pq.run(v)
    # re-binding reuses every compile-side cache: zero plan searches,
    # zero logical/physical builds, zero new device traces
    def delta(key):
        return stats.get(key, 0) - before.get(key, 0)

    assert delta("compile.plan_searches") == 0
    assert delta("compile.logical_compiles") == 0
    assert delta("compile.physical_builds") == 0
    assert eng.backend.trace_count() == traces_before
    # and the hits prove the caches were consulted, not bypassed
    assert delta("compile.plan_cache_hits") >= 4
    assert delta("compile.physical_cache_hits") >= 4


def test_rebind_correctness_vs_inline_constant():
    eng = make_engine("numpy")
    pq = eng.prepare(PARAM_QUERIES[0][1])
    for v in (0, 1, 7):
        got = int(np.asarray(pq.run(v).scalar()))
        oracle = eng.query(
            f"O(;w:long) :- R({v},y),S(y,z),T({v},z); w=<<COUNT(*)>>.")
        assert got == int(np.asarray(oracle.scalar()))


def test_prepare_binds_distinct_constants_separately():
    eng = make_engine("numpy")
    pq = eng.prepare("P(y) :- R(0,y),S(1,y).")
    assert pq.n_params == 2  # two distinct literals -> two slots
    res = pq.run(2, 3)
    oracle = eng.query("O(y) :- R(2,y),S(3,y).")
    assert_same_result(res, oracle)
    # defaults re-run the source text's own constants
    assert_same_result(pq.run(), eng.query("O(y) :- R(0,y),S(1,y)."))


def test_bag_cache_is_binding_aware():
    """Binding A's cached bag rows must never answer binding B."""
    eng = make_engine("numpy")
    pq = eng.prepare(PARAM_QUERIES[0][1])
    a = int(np.asarray(pq.run(1).scalar()))
    b = int(np.asarray(pq.run(2).scalar()))
    a2 = int(np.asarray(pq.run(1).scalar()))
    oracle = eng.query("O(;w:long) :- R(2,y),S(y,z),T(2,z); w=<<COUNT(*)>>.")
    assert a == a2
    assert b == int(np.asarray(oracle.scalar()))


# ------------------------------------------------- batched vs sequential
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("qname,query", PARAM_QUERIES,
                         ids=[n for n, _ in PARAM_QUERIES])
def test_batched_exact_parity(backend, qname, query):
    eng = make_engine(backend)
    pq = eng.prepare(query)
    bindings = [0, 1, 2, 5, 1]
    batched = pq.run_batch(bindings)
    sequential = [pq.run(b) for b in bindings]
    assert len(batched) == len(bindings)
    for got, want in zip(batched, sequential):
        assert_same_result(got, want)


def test_batched_with_missing_vertex_parity():
    """A binding with no matching tuples degenerates out of the modal
    batch signature and must still return the right (empty) answer."""
    eng = make_engine("numpy")
    pq = eng.prepare(PARAM_QUERIES[0][1])
    bindings = [1, 10_000, 2]  # 10_000 is not a vertex
    batched = pq.run_batch(bindings)
    for got, want in zip(batched, [pq.run(b) for b in bindings]):
        assert_same_result(got, want)
    assert int(np.asarray(batched[1].scalar())) == 0


def test_batch_is_one_fused_launch_on_device():
    eng = make_engine("device")
    pq = eng.prepare(PARAM_QUERIES[0][1])
    pq.run(0)  # warm: plan + trace
    stats = eng.backend.stats
    if not (getattr(eng.backend, "pipeline_enabled", False)
            and getattr(eng.backend, "fuse_bags", False)):
        pytest.skip("device pipeline/fusion disabled by env")
    before = dict(stats)
    bindings = [0, 1, 2, 3]
    pq.run_batch(bindings)
    delta = {k: stats.get(k, 0) - before.get(k, 0) for k in stats}
    assert delta["pipeline.batched_launches"] == 1
    assert delta["pipeline.batched_queries"] == len(bindings)
    # one fused launch = one closing sync for the whole batch
    assert delta["extend.closing_syncs"] == 1


# -------------------------------------------------------- query server
@pytest.mark.parametrize("backend", BACKENDS)
def test_query_server_drain_parity(backend):
    srv = QueryServer(backend=backend)
    src, dst, _ = random_undirected_graph(24, 0.3, seed=1)
    srv.load_graph("acme", "R", src, dst)
    for al in ALIASES:
        srv.alias("acme", al, "R")
    q = PARAM_QUERIES[0][1]
    tickets = [srv.submit("acme", q, v) for v in (0, 1, 2, 3)]
    assert srv.pending() == 4
    srv.drain()
    assert srv.pending() == 0
    pq = srv.prepare("acme", q)
    for t, v in zip(tickets, (0, 1, 2, 3)):
        assert t.done
        assert_same_result(t.result, pq.run(v))
    assert srv.counters["tenant.acme.queries"] == 4
    assert srv.counters["tenant.acme.batches"] == 1
    assert srv.counters["queue.admitted"] == 4
    assert srv.counters["queue.drained"] == 4


def test_query_server_tenant_isolation():
    srv = QueryServer(backend="numpy")
    srv.load_graph("a", "R", np.array([0, 1]), np.array([1, 2]))
    srv.load_graph("b", "R", np.array([5, 6]), np.array([6, 7]))
    ra = srv.run("a", "P(x,y) :- R(x,y).")
    rb = srv.run("b", "P(x,y) :- R(x,y).")
    assert set(ra.columns["x"].tolist()) == {0, 1}
    assert set(rb.columns["x"].tolist()) == {5, 6}
    # one shared backend instance across tenants
    assert srv.engine("a").backend is srv.engine("b").backend


# ------------------------------------------------------------- eviction
def _force_resident(srv, tenant, name="R"):
    """Backend-agnostic device-cache fill: the upload function is
    identity-cached, so np.asarray stands in for jnp.asarray here."""
    t = srv.engine(tenant).catalog.get(name)
    for lv in t.levels:
        lv.device_values(np.asarray)
        lv.device_offsets(np.asarray)
    return t


def test_graph_store_lru_eviction_three_graphs_capacity_two():
    srv = QueryServer(backend="numpy", max_graphs=2)
    for tenant, seed in (("a", 0), ("b", 1), ("c", 2)):
        src, dst, _ = random_undirected_graph(16, 0.3, seed=seed)
        srv.load_graph(tenant, "R", src, dst)
        _force_resident(srv, tenant)
    # LRU order is load order: a coldest. Touch a so b becomes coldest.
    srv.run("a", "P(x,y) :- R(x,y).")
    srv._evict_over_budget()
    store = srv.store
    assert not store.resident("b")
    assert store.resident("a") and store.resident("c")
    assert srv.counters["store.evictions"] == 1
    assert srv.counters["tenant.b.evictions"] == 1
    # eviction drops device caches only — the evicted tenant still answers
    res = srv.run("b", "P(x,y) :- R(x,y).")
    assert res.num_rows == srv.engine("b").catalog.get("R").num_tuples


def test_graph_store_byte_budget_eviction():
    srv = QueryServer(backend="numpy", capacity_bytes=1)
    for tenant, seed in (("a", 0), ("b", 1)):
        src, dst, _ = random_undirected_graph(16, 0.3, seed=seed)
        srv.load_graph(tenant, "R", src, dst)
        _force_resident(srv, tenant)
    srv._evict_over_budget()
    # over a 1-byte budget only the warmest survives (never evicted)
    assert not srv.store.resident("a")
    assert srv.store.resident("b")


def test_graph_store_never_evicts_last_resident():
    srv = QueryServer(backend="numpy", capacity_bytes=1)
    src, dst, _ = random_undirected_graph(16, 0.3, seed=0)
    srv.load_graph("only", "R", src, dst)
    _force_resident(srv, "only")
    srv._evict_over_budget()
    assert srv.store.resident("only")
    assert srv.counters.get("store.evictions", 0) == 0


def test_trie_evict_device_counts_and_clears():
    srv = QueryServer(backend="numpy")
    src, dst, _ = random_undirected_graph(16, 0.3, seed=0)
    t = srv.load_graph("a", "R", src, dst)
    assert not t.device_resident
    _force_resident(srv, "a")
    assert t.device_resident
    dropped = t.evict_device()
    assert dropped == 2 * len(t.levels)
    assert not t.device_resident
    assert t.evict_device() == 0  # idempotent


# ------------------------------------------------------ verifier check
def test_plan_verifier_accepts_prepared_plan():
    eng = make_engine("numpy")
    pq = eng.prepare(PARAM_QUERIES[0][1])
    pq.run(1)
    pplan = eng.last_physical
    bad = [v for v in verify_physical_plan(pplan, eng.catalog)
           if v.code == "param-selection"]
    assert bad == []


def test_plan_verifier_flags_bad_param_slots():
    eng = make_engine("numpy")
    pq = eng.prepare(PARAM_QUERIES[0][1])
    pq.run(1)
    pplan = eng.last_physical
    scan = pplan.bag_ops[0].scan

    def with_slot(slot):
        accesses = []
        for acc in scan.accesses:
            if acc.selections:
                acc = dataclasses.replace(
                    acc, selections=tuple((p, Param(slot))
                                          for p, _ in acc.selections))
            accesses.append(acc)
        return accesses

    orig = scan.accesses
    try:
        scan.accesses = with_slot(-1)  # negative slot
        codes = [v.code for v in verify_physical_plan(pplan, eng.catalog)]
        assert "param-selection" in codes
        scan.accesses = with_slot(3)   # gap: slots {3} without 0..2
        codes = [v.code for v in verify_physical_plan(pplan, eng.catalog)]
        assert "param-selection" in codes
    finally:
        scan.accesses = orig
