"""Zero-host-sync Generic Join: the device-resident count-then-fill
pipeline (ROADMAP item 3).

Three layers of proof:

* **counter proofs** — ``extend.host_syncs`` is exactly zero for the
  paper queries on the DeviceBackend, with >= 1 ``extend.closing_syncs``
  (the single landing per join) — and stays zero under morsel spill and
  overflow retry;
* **differential oracles** — the pipelined path against both the
  NumpyBackend and the pinned per-extension-sync device path
  (``Engine(device_pipeline=False)`` / ``REPRO_DEVICE_PIPELINE=off``),
  exact listing parity included;
* **buffer-sizing guards** — ``frontier_capacity`` clamps the
  stats-informed AGM target to the true cross-product bound, rejects
  un-sizable estimates loudly, and a skewed high-fanout trie (the case
  mean-fanout statistics under-estimate) still answers exactly via the
  overflow retry.

``hypothesis`` is not available in this environment, so the property
test is a seeded-random sweep over small acyclic query shapes — same
oracle discipline, deterministic seeds.
"""
import numpy as np
import pytest

from conftest import random_undirected_graph
from repro.core import statistics as S
from repro.core import workload as W
from repro.core.engine import Engine
from repro.core.gj import GenericJoin
from repro.core.plan_ir import BagHints
from repro.core.semiring import COUNT
from repro.core.trie import Trie

ALIASES = W.ALIASES

PAPER_QUERIES = {
    "triangle_count": W.TRIANGLE_COUNT,
    "triangle_list": W.TRIANGLE_LIST,
    "4clique": W.FOUR_CLIQUE,
    "lollipop": W.LOLLIPOP,
    "barbell": W.BARBELL,
    "pagerank": W.pagerank_program(iters=5),
    "sssp": W.sssp_program("{s}"),
}


def make_engine(src, dst, backend, **kw):
    eng = Engine(backend=backend, **kw)
    eng.load_edges("Edge", src, dst)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


def assert_same_result(r1, r2):
    assert r1.vars == r2.vars
    for v in r1.vars:
        np.testing.assert_array_equal(np.asarray(r1.columns[v]),
                                      np.asarray(r2.columns[v]))
    if r1.annotation is None:
        assert r2.annotation is None
    else:
        np.testing.assert_allclose(np.asarray(r1.annotation, np.float64),
                                   np.asarray(r2.annotation, np.float64),
                                   rtol=1e-6, atol=1e-7)


def sync_delta(eng, q):
    before = dict(eng.backend.stats)
    res = eng.query(q)
    d = {k: eng.backend.stats.get(k, 0) - before.get(k, 0)
         for k in set(eng.backend.stats) | set(before)}
    return res, {k: v for k, v in d.items() if v}


# ------------------------------------------------------ counter proofs
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_paper_queries_zero_host_syncs_on_device(qname):
    """THE acceptance criterion: no per-extension host round-trips —
    statically impossible paths aside, the dynamic counter must be 0
    with at least one closing sync per executed join."""
    src, dst, _ = random_undirected_graph(30, 0.3, 7)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    eng = make_engine(src, dst, "device")
    assert eng.device_pipeline        # on by default
    _, d = sync_delta(eng, q)
    assert d.get("extend.host_syncs", 0) == 0, (qname, d)
    if d.get("extend.calls", 0):
        assert d.get("extend.closing_syncs", 0) >= 1, (qname, d)
        assert (d.get("extend.pipeline_extends", 0)
                == d.get("extend.calls", 0)), (qname, d)


def test_closing_syncs_bounded_by_joins():
    """One landing per GenericJoin attempt — never one per extension."""
    src, dst, _ = random_undirected_graph(30, 0.3, 7)
    eng = make_engine(src, dst, "device")
    _, d = sync_delta(eng, PAPER_QUERIES["4clique"])
    assert (d.get("extend.closing_syncs", 0)
            <= d.get("extend.pipeline_extends", 0)
            + d.get("pipeline.device_folds", 0)), d


def test_morsel_spill_keeps_parity_and_zero_syncs(monkeypatch):
    """A tiny REPRO_MORSEL_SIZE forces frontiers to spill across many
    fill chunks of the same device loop — more morsels than extensions,
    still exact, still zero host syncs."""
    monkeypatch.setenv("REPRO_MORSEL_SIZE", "8")
    src, dst, _ = random_undirected_graph(26, 0.35, 3)
    oracle = make_engine(src, dst, "numpy").query(
        PAPER_QUERIES["triangle_list"])
    eng = make_engine(src, dst, "device")
    res, d = sync_delta(eng, PAPER_QUERIES["triangle_list"])
    assert_same_result(oracle, res)
    assert d.get("extend.host_syncs", 0) == 0, d
    assert d.get("pipeline.morsels", 0) > d.get("extend.pipeline_extends",
                                                0), d


def test_env_escape_hatch(monkeypatch):
    """REPRO_DEVICE_PIPELINE=off pins the per-extension-sync oracle."""
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "off")
    src, dst, _ = random_undirected_graph(20, 0.3, 5)
    eng = make_engine(src, dst, "device")
    assert not eng.device_pipeline
    _, d = sync_delta(eng, PAPER_QUERIES["triangle_list"])
    assert d.get("extend.host_syncs", 0) == d.get("extend.calls", 0) > 0
    assert d.get("extend.closing_syncs", 0) == 0


# -------------------------------------------------- differential oracle
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_pipeline_matches_pinned_sync_path(qname):
    """Satellite 6: Engine(device_pipeline=False) is the differential
    oracle — exact parity on every paper query."""
    src, dst, _ = random_undirected_graph(28, 0.25, 11)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    r_off = make_engine(src, dst, "device",
                        device_pipeline=False).query(q)
    r_on = make_engine(src, dst, "device", device_pipeline=True).query(q)
    assert_same_result(r_off, r_on)


# ------------------------------------------------------ overflow retry
def test_overflow_retries_device_resident_with_exact_caps():
    """A hint that lies about the frontier size trips the overflow flag
    at landing; the join must retry device-resident with buffers sized
    from the aborted attempt's counting-pass totals — right answer,
    still zero host syncs.  Steps downstream of the first overflow
    counted over a truncated frontier, so their measurements firm up
    one retry at a time: with every cap lied about, convergence takes
    one retry per overflowing level, never the host path."""
    src, dst, _ = random_undirected_graph(24, 0.4, 9)
    cols = [np.asarray(src, np.int64), np.asarray(dst, np.int64)]
    ta = Trie.build("E0", ("x", "y"), cols)
    tb = Trie.build("E1", ("y", "z"), cols)
    tc = Trie.build("E2", ("x", "z"), cols)
    hints = BagHints(extend_caps={"x": 1.0, "y": 1.0, "z": 1.0}, morsel=8)

    def run(backend, h):
        gj = GenericJoin(
            [(ta, ("x", "y")), (tb, ("y", "z")), (tc, ("x", "z"))],
            ("x", "y", "z"), ("x", "y", "z"), backend=backend, hints=h)
        return gj.run()

    from repro.core.backend import DeviceBackend, NumpyBackend
    oracle = run(NumpyBackend(), None)
    dev = DeviceBackend()
    res = run(dev, hints)
    assert_same_result(oracle, res)
    st = dict(dev.stats)
    assert 1 <= st.get("pipeline.retries", 0) <= 2, st
    assert st.get("extend.host_syncs", 0) == 0, st
    # every attempt stayed device-resident (no host-path extends)
    assert st.get("extend.calls") == st.get("extend.pipeline_extends"), st
    # the converged measurements were recorded as engine-lifetime
    # feedback: re-running the SAME bag shape on the same backend sizes
    # its buffers right the first time — zero further retries
    assert dev.cap_feedback, dict(dev.stats)
    before = st.get("pipeline.retries", 0)
    res2 = run(dev, hints)
    assert_same_result(oracle, res2)
    assert dev.stats.get("pipeline.retries", 0) == before, dict(dev.stats)
    assert dev.stats.get("extend.host_syncs", 0) == 0, dict(dev.stats)


def test_skewed_high_fanout_trie_regression():
    """Satellite 2: a hub graph (one vertex adjacent to everything)
    makes mean-fanout statistics drastically under-estimate the
    expansion; the AGM-capped allocation must clamp to the true
    cross-product bound / retry rather than drop rows."""
    n = 40
    hub_src = np.concatenate([np.zeros(n - 1, np.int64),
                              np.arange(1, n, dtype=np.int64),
                              np.arange(1, n - 1, dtype=np.int64)])
    hub_dst = np.concatenate([np.arange(1, n, dtype=np.int64),
                              np.zeros(n - 1, np.int64),
                              np.arange(2, n, dtype=np.int64)])
    oracle = make_engine(hub_src, hub_dst, "numpy").query(
        PAPER_QUERIES["triangle_list"])
    eng = make_engine(hub_src, hub_dst, "device")
    res, d = sync_delta(eng, PAPER_QUERIES["triangle_list"])
    assert_same_result(oracle, res)
    assert d.get("extend.host_syncs", 0) == 0, d


# -------------------------------------------------- buffer-sizing guard
def test_frontier_capacity_clamps_to_cross_bound():
    # est far above the exact bound: the bound wins (plus bucketing)
    assert S.frontier_capacity(10**9, 100, 64) == 128
    # est below: est + morsel slack, bucketed to a power-of-two multiple
    cap = S.frontier_capacity(100, 10**9, 64)
    assert cap >= 100 and cap % 64 == 0 and (cap & (cap - 1)) == 0


def test_frontier_capacity_respects_max_buffer():
    assert S.frontier_capacity(10**12, 10**12, 256) \
        <= S.PIPELINE_MAX_BUFFER


def test_frontier_capacity_never_below_one_morsel():
    assert S.frontier_capacity(0, 10**6, 256) == 256
    # ... unless the exact bound itself is smaller
    assert S.frontier_capacity(0, 3, 256) >= 3


def test_frontier_capacity_rejects_unsizable_estimates():
    for bad in (None, float("nan"), float("inf"), -1.0):
        with pytest.raises(ValueError):
            S.frontier_capacity(bad, 1000, 256)
    with pytest.raises(ValueError):
        S.frontier_capacity(100, -5, 256)
    with pytest.raises(ValueError):
        S.frontier_capacity(100, 1000, 0)


def test_frontier_capacity_huge_inputs_no_overflow():
    # python-int arithmetic: must not wrap or raise on astronomical bounds
    cap = S.frontier_capacity(float(2**80), 2**90, 1024)
    assert 0 < cap <= S.PIPELINE_MAX_BUFFER


# --------------------------------------------- seeded property sweep
# hypothesis is not installed in this environment (and adding deps is
# off the table), so: deterministic seeds over random acyclic shapes.
_SHAPES = [
    # (head vars, body as (rel_vars, ...)) — all acyclic, <= 4 atoms
    (("x", "y"), (("x", "y"),)),
    (("x", "z"), (("x", "y"), ("y", "z"))),
    (("x", "y", "z"), (("x", "y"), ("y", "z"))),
    (("x", "w"), (("x", "y"), ("y", "z"), ("z", "w"))),
    (("x", "y", "z", "w"), (("x", "y"), ("x", "z"), ("z", "w"))),
    (("x", "y", "z", "w"), (("x", "y"), ("y", "z"), ("y", "w"))),
    (("y", "z", "w"), (("x", "y"), ("x", "z"), ("x", "w"))),
]


def _program(head, body, agg):
    rels = ["R", "S", "T", "U"]
    atoms = ", ".join(f"{rels[i]}({a},{b})"
                      for i, (a, b) in enumerate(body))
    if agg:
        return f"Q(;c:long) :- {atoms}; c=<<COUNT(*)>>."
    return f"Q({','.join(head)}) :- {atoms}."


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_acyclic_queries_match_numpy_oracle(seed):
    """Satellite 3: random small graphs x random acyclic query shapes,
    listing and COUNT flavors, against the NumpyBackend — on BOTH device
    paths, with the zero-sync counter proof on the pipelined one."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 26))
    m = int(rng.integers(n, 4 * n))
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]

    e_np = make_engine(src, dst, "numpy")
    e_on = make_engine(src, dst, "device")
    e_off = make_engine(src, dst, "device", device_pipeline=False)
    for i, (head, body) in enumerate(_SHAPES):
        agg = (seed + i) % 2 == 0
        q = _program(head, body, agg)
        oracle = e_np.query(q)
        res_on, d = sync_delta(e_on, q)
        res_off = e_off.query(q)
        assert_same_result(oracle, res_on)
        assert_same_result(oracle, res_off)
        assert d.get("extend.host_syncs", 0) == 0, (q, d)
