"""Zero-host-sync Generic Join: the device-resident count-then-fill
pipeline (ROADMAP item 3).

Three layers of proof:

* **counter proofs** — ``extend.host_syncs`` is exactly zero for the
  paper queries on the DeviceBackend, with >= 1 ``extend.closing_syncs``
  (the single landing per join) — and stays zero under morsel spill and
  overflow retry;
* **differential oracles** — the pipelined path against both the
  NumpyBackend and the pinned per-extension-sync device path
  (``Engine(device_pipeline=False)`` / ``REPRO_DEVICE_PIPELINE=off``),
  exact listing parity included;
* **buffer-sizing guards** — ``frontier_capacity`` clamps the
  stats-informed AGM target to the true cross-product bound, rejects
  un-sizable estimates loudly, and a skewed high-fanout trie (the case
  mean-fanout statistics under-estimate) still answers exactly via the
  overflow retry.

``hypothesis`` is not available in this environment, so the property
test is a seeded-random sweep over small acyclic query shapes — same
oracle discipline, deterministic seeds.
"""
import numpy as np
import pytest

from conftest import random_undirected_graph
from repro.core import statistics as S
from repro.core import workload as W
from repro.core.engine import Engine
from repro.core.gj import GenericJoin
from repro.core.plan_ir import BagHints
from repro.core.semiring import COUNT
from repro.core.trie import Trie

ALIASES = W.ALIASES

PAPER_QUERIES = {
    "triangle_count": W.TRIANGLE_COUNT,
    "triangle_list": W.TRIANGLE_LIST,
    "4clique": W.FOUR_CLIQUE,
    "lollipop": W.LOLLIPOP,
    "barbell": W.BARBELL,
    "pagerank": W.pagerank_program(iters=5),
    "sssp": W.sssp_program("{s}"),
}


def make_engine(src, dst, backend, **kw):
    eng = Engine(backend=backend, **kw)
    eng.load_edges("Edge", src, dst)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


def assert_same_result(r1, r2):
    assert r1.vars == r2.vars
    for v in r1.vars:
        np.testing.assert_array_equal(np.asarray(r1.columns[v]),
                                      np.asarray(r2.columns[v]))
    if r1.annotation is None:
        assert r2.annotation is None
    else:
        np.testing.assert_allclose(np.asarray(r1.annotation, np.float64),
                                   np.asarray(r2.annotation, np.float64),
                                   rtol=1e-6, atol=1e-7)


def sync_delta(eng, q):
    before = dict(eng.backend.stats)
    res = eng.query(q)
    d = {k: eng.backend.stats.get(k, 0) - before.get(k, 0)
         for k in set(eng.backend.stats) | set(before)}
    return res, {k: v for k, v in d.items() if v}


# ------------------------------------------------------ counter proofs
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_paper_queries_zero_host_syncs_on_device(qname):
    """THE acceptance criterion: no per-extension host round-trips —
    statically impossible paths aside, the dynamic counter must be 0
    with at least one closing sync per executed join."""
    src, dst, _ = random_undirected_graph(30, 0.3, 7)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    eng = make_engine(src, dst, "device")
    assert eng.device_pipeline        # on by default
    _, d = sync_delta(eng, q)
    assert d.get("extend.host_syncs", 0) == 0, (qname, d)
    if d.get("extend.calls", 0):
        assert d.get("extend.closing_syncs", 0) >= 1, (qname, d)
        assert (d.get("extend.pipeline_extends", 0)
                == d.get("extend.calls", 0)), (qname, d)


def test_closing_syncs_bounded_by_joins():
    """One landing per GenericJoin attempt — never one per extension."""
    src, dst, _ = random_undirected_graph(30, 0.3, 7)
    eng = make_engine(src, dst, "device")
    _, d = sync_delta(eng, PAPER_QUERIES["4clique"])
    assert (d.get("extend.closing_syncs", 0)
            <= d.get("extend.pipeline_extends", 0)
            + d.get("pipeline.device_folds", 0)), d


def test_morsel_spill_keeps_parity_and_zero_syncs(monkeypatch):
    """A tiny REPRO_MORSEL_SIZE forces frontiers to spill across many
    fill chunks of the same device loop — more morsels than extensions,
    still exact, still zero host syncs."""
    monkeypatch.setenv("REPRO_MORSEL_SIZE", "8")
    src, dst, _ = random_undirected_graph(26, 0.35, 3)
    oracle = make_engine(src, dst, "numpy").query(
        PAPER_QUERIES["triangle_list"])
    eng = make_engine(src, dst, "device")
    res, d = sync_delta(eng, PAPER_QUERIES["triangle_list"])
    assert_same_result(oracle, res)
    assert d.get("extend.host_syncs", 0) == 0, d
    assert d.get("pipeline.morsels", 0) > d.get("extend.pipeline_extends",
                                                0), d


def test_env_escape_hatch(monkeypatch):
    """REPRO_DEVICE_PIPELINE=off pins the per-extension-sync oracle."""
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "off")
    src, dst, _ = random_undirected_graph(20, 0.3, 5)
    eng = make_engine(src, dst, "device")
    assert not eng.device_pipeline
    _, d = sync_delta(eng, PAPER_QUERIES["triangle_list"])
    assert d.get("extend.host_syncs", 0) == d.get("extend.calls", 0) > 0
    assert d.get("extend.closing_syncs", 0) == 0


# -------------------------------------------------- differential oracle
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_pipeline_matches_pinned_sync_path(qname):
    """Satellite 6: Engine(device_pipeline=False) is the differential
    oracle — exact parity on every paper query."""
    src, dst, _ = random_undirected_graph(28, 0.25, 11)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    r_off = make_engine(src, dst, "device",
                        device_pipeline=False).query(q)
    r_on = make_engine(src, dst, "device", device_pipeline=True).query(q)
    assert_same_result(r_off, r_on)


# ------------------------------------------------------ overflow retry
def test_overflow_retries_device_resident_with_exact_caps():
    """A hint that lies about the frontier size trips the overflow flag
    at landing; the join must retry device-resident with buffers sized
    from the aborted attempt's counting-pass totals — right answer,
    still zero host syncs.  Steps downstream of the first overflow
    counted over a truncated frontier, so their measurements firm up
    one retry at a time: with every cap lied about, convergence takes
    one retry per overflowing level, never the host path."""
    src, dst, _ = random_undirected_graph(24, 0.4, 9)
    cols = [np.asarray(src, np.int64), np.asarray(dst, np.int64)]
    ta = Trie.build("E0", ("x", "y"), cols)
    tb = Trie.build("E1", ("y", "z"), cols)
    tc = Trie.build("E2", ("x", "z"), cols)
    hints = BagHints(extend_caps={"x": 1.0, "y": 1.0, "z": 1.0}, morsel=8)

    def run(backend, h):
        gj = GenericJoin(
            [(ta, ("x", "y")), (tb, ("y", "z")), (tc, ("x", "z"))],
            ("x", "y", "z"), ("x", "y", "z"), backend=backend, hints=h)
        return gj.run()

    from repro.core.backend import DeviceBackend, NumpyBackend
    oracle = run(NumpyBackend(), None)
    dev = DeviceBackend()
    res = run(dev, hints)
    assert_same_result(oracle, res)
    st = dict(dev.stats)
    assert 1 <= st.get("pipeline.retries", 0) <= 2, st
    assert st.get("extend.host_syncs", 0) == 0, st
    # every attempt stayed device-resident (no host-path extends)
    assert st.get("extend.calls") == st.get("extend.pipeline_extends"), st
    # the converged measurements were recorded as engine-lifetime
    # feedback: re-running the SAME bag shape on the same backend sizes
    # its buffers right the first time — zero further retries
    assert dev.cap_feedback, dict(dev.stats)
    before = st.get("pipeline.retries", 0)
    res2 = run(dev, hints)
    assert_same_result(oracle, res2)
    assert dev.stats.get("pipeline.retries", 0) == before, dict(dev.stats)
    assert dev.stats.get("extend.host_syncs", 0) == 0, dict(dev.stats)


def test_skewed_high_fanout_trie_regression():
    """Satellite 2: a hub graph (one vertex adjacent to everything)
    makes mean-fanout statistics drastically under-estimate the
    expansion; the AGM-capped allocation must clamp to the true
    cross-product bound / retry rather than drop rows."""
    n = 40
    hub_src = np.concatenate([np.zeros(n - 1, np.int64),
                              np.arange(1, n, dtype=np.int64),
                              np.arange(1, n - 1, dtype=np.int64)])
    hub_dst = np.concatenate([np.arange(1, n, dtype=np.int64),
                              np.zeros(n - 1, np.int64),
                              np.arange(2, n, dtype=np.int64)])
    oracle = make_engine(hub_src, hub_dst, "numpy").query(
        PAPER_QUERIES["triangle_list"])
    eng = make_engine(hub_src, hub_dst, "device")
    res, d = sync_delta(eng, PAPER_QUERIES["triangle_list"])
    assert_same_result(oracle, res)
    assert d.get("extend.host_syncs", 0) == 0, d


# -------------------------------------------------- buffer-sizing guard
def test_frontier_capacity_clamps_to_cross_bound():
    # est far above the exact bound: the bound wins (plus bucketing)
    assert S.frontier_capacity(10**9, 100, 64) == 128
    # est below: est + morsel slack, bucketed to a power-of-two multiple
    cap = S.frontier_capacity(100, 10**9, 64)
    assert cap >= 100 and cap % 64 == 0 and (cap & (cap - 1)) == 0


def test_frontier_capacity_respects_max_buffer():
    assert S.frontier_capacity(10**12, 10**12, 256) \
        <= S.PIPELINE_MAX_BUFFER


def test_frontier_capacity_tiny_estimates_stay_tiny():
    # regression: sizing slack off the MORSEL ballooned an est≈1
    # extension to a full morsel-sized buffer (256x over-allocation
    # zeroed and scattered every step); slack now scales with the
    # estimate and the bucket floor is PIPELINE_MIN_BUCKET
    assert S.frontier_capacity(0, 10**6, 256) == S.PIPELINE_MIN_BUCKET
    assert S.frontier_capacity(1, 10**6, 2048) == S.PIPELINE_MIN_BUCKET
    # capacity still covers the true bound when it is small
    assert S.frontier_capacity(0, 3, 256) >= 3
    # and keeps real estimate-scaled headroom for non-tiny frontiers
    assert S.frontier_capacity(1000, 10**9, 64) >= 1500


def test_frontier_capacity_rejects_unsizable_estimates():
    for bad in (None, float("nan"), float("inf"), -1.0):
        with pytest.raises(ValueError):
            S.frontier_capacity(bad, 1000, 256)
    with pytest.raises(ValueError):
        S.frontier_capacity(100, -5, 256)
    with pytest.raises(ValueError):
        S.frontier_capacity(100, 1000, 0)


def test_frontier_capacity_huge_inputs_no_overflow():
    # python-int arithmetic: must not wrap or raise on astronomical bounds
    cap = S.frontier_capacity(float(2**80), 2**90, 1024)
    assert 0 < cap <= S.PIPELINE_MAX_BUFFER


# --------------------------------------------- seeded property sweep
# hypothesis is not installed in this environment (and adding deps is
# off the table), so: deterministic seeds over random acyclic shapes.
_SHAPES = [
    # (head vars, body as (rel_vars, ...)) — all acyclic, <= 4 atoms
    (("x", "y"), (("x", "y"),)),
    (("x", "z"), (("x", "y"), ("y", "z"))),
    (("x", "y", "z"), (("x", "y"), ("y", "z"))),
    (("x", "w"), (("x", "y"), ("y", "z"), ("z", "w"))),
    (("x", "y", "z", "w"), (("x", "y"), ("x", "z"), ("z", "w"))),
    (("x", "y", "z", "w"), (("x", "y"), ("y", "z"), ("y", "w"))),
    (("y", "z", "w"), (("x", "y"), ("x", "z"), ("x", "w"))),
]


def _program(head, body, agg):
    rels = ["R", "S", "T", "U"]
    atoms = ", ".join(f"{rels[i]}({a},{b})"
                      for i, (a, b) in enumerate(body))
    if agg:
        return f"Q(;c:long) :- {atoms}; c=<<COUNT(*)>>."
    return f"Q({','.join(head)}) :- {atoms}."


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_acyclic_queries_match_numpy_oracle(seed):
    """Satellite 3: random small graphs x random acyclic query shapes,
    listing and COUNT flavors, against the NumpyBackend — on BOTH device
    paths, with the zero-sync counter proof on the pipelined one."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 26))
    m = int(rng.integers(n, 4 * n))
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]

    e_np = make_engine(src, dst, "numpy")
    e_on = make_engine(src, dst, "device")
    e_off = make_engine(src, dst, "device", device_pipeline=False)
    for i, (head, body) in enumerate(_SHAPES):
        agg = (seed + i) % 2 == 0
        q = _program(head, body, agg)
        oracle = e_np.query(q)
        res_on, d = sync_delta(e_on, q)
        res_off = e_off.query(q)
        assert_same_result(oracle, res_on)
        assert_same_result(oracle, res_off)
        assert d.get("extend.host_syncs", 0) == 0, (q, d)


# ------------------------------------------------- whole-bag fusion (PR 8)
def test_fused_bag_is_one_launch_per_join():
    """THE launch-budget criterion: with fusion on (the default), every
    executed bag is ONE jitted program — ``pipeline.launches`` equals
    ``extend.closing_syncs`` (one landing per join attempt), and is
    exactly 1 for the single-bag triangle queries."""
    src, dst, _ = random_undirected_graph(30, 0.3, 7)
    for qname in ("triangle_count", "triangle_list"):
        eng = make_engine(src, dst, "device")
        assert eng.fused_bags          # on by default
        _, d = sync_delta(eng, PAPER_QUERIES[qname])
        assert d.get("pipeline.launches", 0) == 1, (qname, d)
        assert d.get("extend.closing_syncs", 0) == 1, (qname, d)
        assert d.get("extend.host_syncs", 0) == 0, (qname, d)


@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_fused_launches_equal_closing_syncs(qname):
    """The invariant generalizes to every paper query, multi-bag and
    recursive ones included: launches == landings, never one launch per
    attribute step."""
    src, dst, _ = random_undirected_graph(30, 0.3, 7)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    eng = make_engine(src, dst, "device")
    _, d = sync_delta(eng, q)
    assert (d.get("pipeline.launches", 0)
            == d.get("extend.closing_syncs", 0)), (qname, d)


@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_fused_matches_per_step_pipeline(qname):
    """Satellite 3: Engine(fused_bags=False) pins the per-attribute-step
    pipeline as the differential oracle — exact parity on every paper
    query, with the unfused leg paying one launch per step."""
    src, dst, _ = random_undirected_graph(28, 0.25, 13)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    e_unf = make_engine(src, dst, "device", fused_bags=False)
    assert not e_unf.fused_bags
    r_unf, d_unf = sync_delta(e_unf, q)
    r_fus = make_engine(src, dst, "device", fused_bags=True).query(q)
    assert_same_result(r_unf, r_fus)
    # unfused: one launch per pipelined step, not per bag
    assert d_unf.get("pipeline.launches", 0) == (
        d_unf.get("extend.pipeline_extends", 0)
        + d_unf.get("pipeline.device_folds", 0)), (qname, d_unf)


@pytest.mark.parametrize("seed", [4, 5])
def test_random_acyclic_queries_fused_parity(seed):
    """The seeded-random sweep, fused leg: random graphs x the acyclic
    shapes, fused vs unfused vs the NumpyBackend — exact, zero host
    syncs, and never more launches fused than unfused."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 26))
    m = int(rng.integers(n, 4 * n))
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]

    e_np = make_engine(src, dst, "numpy")
    e_fus = make_engine(src, dst, "device", fused_bags=True)
    e_unf = make_engine(src, dst, "device", fused_bags=False)
    for i, (head, body) in enumerate(_SHAPES):
        agg = (seed + i) % 2 == 0
        q = _program(head, body, agg)
        oracle = e_np.query(q)
        r_fus, d_fus = sync_delta(e_fus, q)
        r_unf, d_unf = sync_delta(e_unf, q)
        assert_same_result(oracle, r_fus)
        assert_same_result(oracle, r_unf)
        assert d_fus.get("extend.host_syncs", 0) == 0, (q, d_fus)
        assert (d_fus.get("pipeline.launches", 0)
                <= d_unf.get("pipeline.launches", 0)), (q, d_fus, d_unf)


def test_fused_overflow_retry_one_launch_per_attempt():
    """Overflow retry under fusion: a lying cap hint trips the sticky
    overflow flag at landing and the bag re-traces with count-informed
    sizes — each ATTEMPT is still one launch (launches == landings),
    zero host syncs, exact answer."""
    src, dst, _ = random_undirected_graph(24, 0.4, 9)
    cols = [np.asarray(src, np.int64), np.asarray(dst, np.int64)]
    ta = Trie.build("E0", ("x", "y"), cols)
    tb = Trie.build("E1", ("y", "z"), cols)
    tc = Trie.build("E2", ("x", "z"), cols)
    hints = BagHints(extend_caps={"x": 1.0, "y": 1.0, "z": 1.0}, morsel=8)

    from repro.core.backend import DeviceBackend, NumpyBackend

    def run(backend, h):
        gj = GenericJoin(
            [(ta, ("x", "y")), (tb, ("y", "z")), (tc, ("x", "z"))],
            ("x", "y", "z"), ("x", "y", "z"), backend=backend, hints=h)
        return gj.run()

    oracle = run(NumpyBackend(), None)
    dev = DeviceBackend()
    assert dev.fuse_bags
    res = run(dev, hints)
    assert_same_result(oracle, res)
    st = dict(dev.stats)
    assert st.get("pipeline.retries", 0) >= 1, st
    assert st.get("extend.host_syncs", 0) == 0, st
    assert (st.get("pipeline.launches", 0)
            == st.get("extend.closing_syncs", 0)), st


def test_fused_env_escape_hatch(monkeypatch):
    """REPRO_FUSED_BAG=off pins the per-step pipeline — still exact,
    still zero host syncs, one launch per step."""
    monkeypatch.setenv("REPRO_FUSED_BAG", "off")
    src, dst, _ = random_undirected_graph(20, 0.3, 5)
    oracle = make_engine(src, dst, "numpy").query(
        PAPER_QUERIES["triangle_list"])
    eng = make_engine(src, dst, "device")
    assert not eng.fused_bags
    res, d = sync_delta(eng, PAPER_QUERIES["triangle_list"])
    assert_same_result(oracle, res)
    assert d.get("extend.host_syncs", 0) == 0, d
    assert d.get("pipeline.launches", 0) == (
        d.get("extend.pipeline_extends", 0)
        + d.get("pipeline.device_folds", 0)) > 1, d


def test_frontier_fill_jnp_mode_parity(monkeypatch):
    """REPRO_FRONTIER_FILL=jnp swaps the Pallas fill kernel for its jnp
    reference inside the same traced program — bit-identical results."""
    monkeypatch.setenv("REPRO_FRONTIER_FILL", "jnp")
    src, dst, _ = random_undirected_graph(22, 0.3, 3)
    oracle = make_engine(src, dst, "numpy").query(PAPER_QUERIES["4clique"])
    eng = make_engine(src, dst, "device")
    assert eng.backend.fill_mode == "jnp"
    res, d = sync_delta(eng, PAPER_QUERIES["4clique"])
    assert_same_result(oracle, res)
    assert d.get("extend.host_syncs", 0) == 0, d


def test_wall_split_compile_then_steady():
    """The dispatch-wall split: the first execution of a bag shape lands
    in the compile bucket, re-dispatching the SAME traced program lands
    in steady — both observable through ``wall_split()``."""
    from repro.core.executor import BagResultCache
    src, dst, _ = random_undirected_graph(20, 0.3, 5)
    eng = make_engine(src, dst, "device")
    eng.query(PAPER_QUERIES["triangle_count"])
    ws = eng.backend.wall_split()
    assert ws["pipeline.wall_compile_s"] > 0, ws
    steady0 = ws["pipeline.wall_steady_s"]
    # a fresh bag cache so the second run re-DISPATCHES (the engine-
    # lifetime cache would otherwise answer without launching anything)
    eng.bag_cache = BagResultCache()
    eng.query(PAPER_QUERIES["triangle_count"])
    ws2 = eng.backend.wall_split()
    assert ws2["pipeline.wall_steady_s"] > steady0, ws2
    # the wall split is timing, NOT part of the exact-gated counters
    assert "pipeline.wall_compile_s" not in eng.backend.stats


# ------------------------------------------- bitset sideways filtering
def _complete_graph(n):
    s, d = np.nonzero(~np.eye(n, dtype=bool))
    return s.astype(np.int64), d.astype(np.int64)


def test_sideways_bitset_fires_on_dense_graph(monkeypatch):
    """Tentpole leg 3: on a dense graph the planner annotates depth-1
    probes ``sideways='bitset'`` and the counting pass intersects
    Figure-6 block directories — counter-proven (``pipeline.sideways_
    extends`` + one bitset-directory upload), exact against both the
    numpy oracle and the REPRO_SIDEWAYS_BITSET=off leg."""
    src, dst = _complete_graph(14)
    oracle = make_engine(src, dst, "numpy").query(PAPER_QUERIES["4clique"])
    eng = make_engine(src, dst, "device")
    res, d = sync_delta(eng, PAPER_QUERIES["4clique"])
    assert_same_result(oracle, res)
    assert d.get("pipeline.sideways_extends", 0) >= 1, d
    assert d.get("upload.bitset_dirs", 0) >= 1, d
    assert d.get("extend.host_syncs", 0) == 0, d

    monkeypatch.setenv("REPRO_SIDEWAYS_BITSET", "off")
    eng2 = make_engine(src, dst, "device")
    res2, d2 = sync_delta(eng2, PAPER_QUERIES["4clique"])
    assert_same_result(oracle, res2)
    assert d2.get("pipeline.sideways_extends", 0) == 0, d2


def test_sideways_stays_off_on_sparse_graph():
    """The statistics density gate: adjacency sets whose neighbors are
    scattered across a wide ID range (inverse density above the
    Algorithm-3 threshold) fall in the sparse cohort, so the planner
    must not annotate sideways filtering.  NB small-universe graphs
    don't exercise this — a degree-1 set has span 1 and is trivially
    'dense' — hence the deliberately spread-out degree-2 graph."""
    rng = np.random.default_rng(17)
    n = 4000
    src = np.repeat(np.arange(n, dtype=np.int64), 2)
    dst = rng.integers(0, n, 2 * n).astype(np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    eng = make_engine(src, dst, "device")
    _, d = sync_delta(eng, PAPER_QUERIES["4clique"])
    assert d.get("pipeline.sideways_extends", 0) == 0, d
    from repro.core.plan_ir import Extend
    assert all(s.sideways is None
               for b in eng.last_physical.bag_ops
               for s in b.steps if isinstance(s, Extend))


def test_sideways_parity_unfused_and_listing(monkeypatch):
    """Sideways filtering composes with both execution modes: the dense
    graph's 4-clique LISTING answers identically with fusion off, and
    the annotation survives into the per-step pipeline too."""
    src, dst = _complete_graph(12)
    q = "Q(x,y,z,w) :- R(x,y),S(x,z),T(x,w),U(y,z),X(y,w),Y(z,w)."
    oracle = make_engine(src, dst, "numpy").query(q)
    r_fus = make_engine(src, dst, "device").query(q)
    e_unf = make_engine(src, dst, "device", fused_bags=False)
    r_unf, d_unf = sync_delta(e_unf, q)
    assert_same_result(oracle, r_fus)
    assert_same_result(oracle, r_unf)
    assert d_unf.get("pipeline.sideways_extends", 0) >= 1, d_unf
