"""Trace-level program auditor (repro.analysis.jaxpr_audit) tests.

Locks the tentpole invariants: injected host callbacks, launch-budget
drift, mis-sized frontier buffers, 64-bit dtype leaks and oversized
broadcasts are each rejected with their typed code, and the seven paper
queries (plus the batched serving probe) audit clean against the
committed ``jaxpr_baseline.json`` on every CI leg.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_audit as JA
from repro.analysis.jaxpr_audit import (JaxprAuditError, ProgramSpec,
                                        assert_clean, audit_closed_jaxpr)


def codes(violations):
    return [v.code for v in violations]


def _loop_over_buffer(cap_traced: int):
    """One chunked fill-style while loop carrying a (cap_traced,) buffer
    — the shape the audit must reconcile with the declared capacity."""

    def fn(buf):
        def cond(s):
            return s[0] < 2

        def body(s):
            c, b = s
            return c + 1, b.at[c].set(c)

        return jax.lax.while_loop(cond, body, (jnp.int32(0), buf))

    return jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((cap_traced,), np.int32))


# ------------------------------------------------------------- rejections
def test_injected_pure_callback_rejected():
    def fn(x):
        return jax.pure_callback(
            lambda a: np.asarray(a),
            jax.ShapeDtypeStruct((4,), np.int32), x)

    closed = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4,), np.int32))
    vs = audit_closed_jaxpr(closed, ProgramSpec("inj"))
    assert "host-callback" in codes(vs)
    with pytest.raises(JaxprAuditError, match="host-callback"):
        assert_clean(closed, ProgramSpec("inj"))


def test_extra_while_loop_breaks_launch_budget():
    closed = _loop_over_buffer(8)
    # the program declares ZERO fill loops -> the traced while is a
    # launch the budget never accounted for
    vs = audit_closed_jaxpr(closed, ProgramSpec("extra"))
    assert codes(vs) == ["launch-budget"]


def test_missing_while_loop_breaks_launch_budget():
    closed = jax.make_jaxpr(lambda x: x + 1)(
        jax.ShapeDtypeStruct((8,), np.int32))
    vs = audit_closed_jaxpr(
        closed, ProgramSpec("missing", loops=(("extend", "y", 8, 8),)))
    assert codes(vs) == ["launch-budget"]


def test_oversized_frontier_buffer_rejected():
    # the loop carries a 16-wide buffer but the plan lowered cap 8
    closed = _loop_over_buffer(16)
    vs = audit_closed_jaxpr(
        closed, ProgramSpec("wide", loops=(("extend", "y", 8, 8),)))
    assert "frontier-cap" in codes(vs)
    # matching capacity: clean
    ok = _loop_over_buffer(8)
    assert audit_closed_jaxpr(
        ok, ProgramSpec("ok", loops=(("extend", "y", 8, 8),))) == []


def test_non_pow2_declared_capacity_rejected():
    closed = _loop_over_buffer(12)
    vs = audit_closed_jaxpr(
        closed, ProgramSpec("bucket", loops=(("extend", "y", 12, 4),)))
    assert "frontier-bucket" in codes(vs)


def test_f64_leak_rejected_under_x64_trace():
    """A float64 compiled in under enable_x64 must be flagged when the
    program's own inputs never declared a 64-bit width."""
    from jax.experimental import enable_x64

    with enable_x64():
        closed = jax.make_jaxpr(
            lambda x: (x.astype(jnp.float64) * 2.0).sum())(
            jax.ShapeDtypeStruct((4,), np.float32))
    vs = audit_closed_jaxpr(closed,
                            ProgramSpec("leak", allow_64=False))
    assert "dtype-widening" in codes(vs)
    # declared 64-bit INPUTS are not leaks (catalog long annotations)
    with enable_x64():
        ok = jax.make_jaxpr(lambda x: x * 2)(
            jax.ShapeDtypeStruct((4,), np.int64))
    assert audit_closed_jaxpr(ok, ProgramSpec("ok", allow_64=False)) == []


def test_oversized_broadcast_rejected():
    from repro.core import statistics as S

    big = 2 * S.PIPELINE_MAX_BUFFER
    closed = jax.make_jaxpr(
        lambda x: jnp.zeros((big,), np.int32) + x)(
        jax.ShapeDtypeStruct((), np.int32))
    vs = audit_closed_jaxpr(closed, ProgramSpec("bcast"))
    assert "broadcast-materialize" in codes(vs)
    # under the ceiling: clean
    small = jax.make_jaxpr(
        lambda x: jnp.zeros((64,), np.int32) + x)(
        jax.ShapeDtypeStruct((), np.int32))
    assert audit_closed_jaxpr(small, ProgramSpec("s")) == []


# ------------------------------------------------- the real paper programs
@pytest.fixture(scope="module")
def paper_audit():
    return JA.audit_paper_queries(smoke=True)


def test_paper_queries_audit_clean(paper_audit):
    reports, violations = paper_audit
    assert violations == [], [str(v) for v in violations]
    # every program is callback-free — the zero-host-sync claim at the
    # trace level, not just the counter level
    assert all(r.host_callbacks == 0 for r in reports)
    # the inventory covers all seven paper queries + the serving batch
    names = {r.name.split("::")[0] for r in reports}
    assert {"triangle", "triangle_list", "4clique", "lollipop", "barbell",
            "pagerank", "sssp", "serve_batch"} <= names


def test_paper_queries_match_committed_baseline(paper_audit):
    reports, _ = paper_audit
    new, removed = JA.compare(reports, JA.load_baseline())
    assert new == [], f"programs/launches not in baseline: {new}"
    assert removed == [], (f"baselined programs disappeared — shrink "
                           f"jaxpr_baseline.json: {removed}")


def test_fixpoint_programs_have_expected_loops(paper_audit):
    reports, _ = paper_audit
    by_name = {r.name: r for r in reports}
    # seminaive SSSP carries exactly one device while-loop; the naive
    # fixed-iteration PageRank path unrolls through scan (zero whiles)
    assert by_name["sssp::seminaive2"].fill_loops == 1
    assert by_name["pagerank::naive2"].fill_loops == 0


def test_counters_surface_in_dispatch_summary():
    records, eng = JA.collect_paper_programs(smoke=True)
    JA.audit_records(records[:2], counters=eng.backend.stats)
    summary = eng.dispatch_summary()
    assert summary.get("analysis.jaxpr_programs", 0) >= 2
    assert summary.get("analysis.jaxpr_violations", 0) == 0


def test_batched_program_spec_carries_batch_dim():
    """The vmapped serving program audits with base_ndim=1: [B, cap]
    buffers are the declared capacity, not a violation."""
    records, _eng = JA.collect_paper_programs(smoke=True)
    batched = [r for r in records if r[0] == "bag_batch"]
    assert batched, "serving probe recorded no batched program"
    closed, spec = JA.trace_record(batched[0])
    assert spec.batch > 1
    assert audit_closed_jaxpr(closed, spec) == []
