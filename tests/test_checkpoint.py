"""Checkpoint manager: roundtrip, atomic commit, retention, auto-resume,
elastic re-shard (mesh A -> mesh B restore)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train import TrainState, make_train_step, train_loop


def small_state():
    cfg = tfm.TransformerConfig(
        "t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=50, d_head=8, dtype=jnp.float32, q_block=8, kv_block=8)
    p = tfm.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    return cfg, opt, TrainState.create(p, opt).tree()


def test_roundtrip(tmp_path):
    _, _, state = small_state()
    ck = CheckpointManager(str(tmp_path))
    ck.save(state, 3, note="hello")
    restored, step = ck.restore(state)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ck.manifest(3)["meta"]["note"] == "hello"


def test_retention_and_latest(tmp_path):
    _, _, state = small_state()
    ck = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(state, s)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_no_partial_checkpoint_visible(tmp_path):
    """A .tmp staging dir must never be listed as a checkpoint."""
    _, _, state = small_state()
    ck = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "step_9.tmp")
    assert ck.all_steps() == []
    assert ck.latest_step() is None


def test_restore_shape_mismatch_raises(tmp_path):
    _, _, state = small_state()
    ck = CheckpointManager(str(tmp_path))
    ck.save(state, 1)
    bad = jax.tree.map(
        lambda x: jnp.zeros(x.shape + (1,), x.dtype), state)
    with pytest.raises(AssertionError):
        ck.restore(bad)


def test_train_loop_auto_resume(tmp_path):
    cfg, opt, state = small_state()
    step = jax.jit(make_train_step(lambda p, b: tfm.loss_fn(p, b, cfg), opt))

    def batch_at(i):
        r = np.random.default_rng(i)
        t = r.integers(0, 50, (2, 8)).astype(np.int32)
        return {"tokens": jnp.asarray(t), "targets": jnp.asarray(t)}

    s1, _ = train_loop(step, state, batch_at, 4, ckpt_dir=str(tmp_path))
    assert int(s1["step"]) == 4
    # resume continues from 4 -> 6, starting from the saved state
    s2, _ = train_loop(step, state, batch_at, 6, ckpt_dir=str(tmp_path))
    assert int(s2["step"]) == 6


def test_elastic_reshard(tmp_path):
    """Save under mesh A (4x2), restore under mesh B (2x2x2) with
    different shardings — the 1000-node failure/rescale path."""
    import subprocess
    import sys
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import CheckpointManager

state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
          "step": jnp.asarray(5)}}
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
sh_a = {{"w": NamedSharding(mesh_a, P("data", "model")), "step": None}}
state_a = {{"w": jax.device_put(state["w"], sh_a["w"]), "step": state["step"]}}
ck = CheckpointManager(r"{tmp_path}")
ck.save(state_a, 5)

mesh_b = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
sh_b = {{"w": NamedSharding(mesh_b, P(("pod", "data"), "model")),
         "step": None}}
restored, step = ck.restore(state, shardings=sh_b)
assert step == 5
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.asarray(state["w"]))
assert restored["w"].sharding.is_equivalent_to(sh_b["w"], 2)
print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd="/root/repo")
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
