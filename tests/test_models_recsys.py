"""FM model tests: sum-square == pairwise, embedding-bag semantics,
retrieval == full-FM-score consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.recsys.fm as fm


@pytest.fixture
def cfg():
    return fm.FMConfig("fm", n_sparse=5, vocab_per_field=50, embed_dim=8)


def test_forward_backward(cfg, rng):
    p = fm.init(jax.random.PRNGKey(0), cfg)
    b = {"ids": jnp.asarray(rng.integers(0, 50, (16, 5))),
         "label": jnp.asarray(rng.integers(0, 2, 16), jnp.float32)}
    logits = fm.forward(p, b, cfg)
    assert logits.shape == (16,) and bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda p: fm.loss_fn(p, b, cfg)[0])(p)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_fm_equals_explicit_pairwise(cfg, rng):
    """logit == w0 + sum w_i + sum_{i<j} <v_i, v_j> computed by loops."""
    p = fm.init(jax.random.PRNGKey(1), cfg)
    ids = rng.integers(0, 50, (4, 5))
    got = np.asarray(fm.forward(p, {"ids": jnp.asarray(ids)}, cfg))
    emb = np.asarray(p["emb"])
    wl = np.asarray(p["w_lin"])
    w0 = float(p["w0"])
    for b in range(4):
        rows = [f * 50 + ids[b, f] for f in range(5)]
        lin = sum(wl[r] for r in rows)
        inter = 0.0
        for i in range(5):
            for j in range(i + 1, 5):
                inter += float(emb[rows[i]] @ emb[rows[j]])
        np.testing.assert_allclose(got[b], w0 + lin + inter, rtol=1e-4)


def test_embedding_bag_sum_and_mean(rng):
    table = jnp.asarray(rng.normal(size=(20, 4)), jnp.float32)
    bag_ids = jnp.asarray([0, 1, 2, 5, 5, 7])
    segs = jnp.asarray([0, 0, 0, 1, 1, 2])
    s = fm.embedding_bag(table, bag_ids, segs, 3, "sum")
    m = fm.embedding_bag(table, bag_ids, segs, 3, "mean")
    t = np.asarray(table)
    np.testing.assert_allclose(np.asarray(s[0]), t[[0, 1, 2]].sum(0),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m[1]), t[[5, 5]].mean(0),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s[2]), t[7], rtol=1e-6)


def test_retrieval_scores_rank_consistency(cfg, rng):
    """retrieval_scores must rank candidates identically to dot-product
    scoring computed by hand (batched dot, not a loop — but same math)."""
    p = fm.init(jax.random.PRNGKey(2), cfg)
    user_rows = jnp.asarray([3, 57, 101])
    cand = jnp.arange(200)
    got = np.asarray(fm.retrieval_scores(p, user_rows, cand, cfg))
    emb = np.asarray(p["emb"])
    u = emb[np.asarray(user_rows)].sum(0)
    want = emb[:200] @ u + np.asarray(p["w_lin"])[:200]
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_kernel_and_ref_paths_agree(rng):
    cfg_k = fm.FMConfig("fm", n_sparse=5, vocab_per_field=50, embed_dim=8,
                        use_kernel=True)
    cfg_r = fm.FMConfig("fm", n_sparse=5, vocab_per_field=50, embed_dim=8)
    key = jax.random.PRNGKey(3)
    p = fm.init(key, cfg_r)
    ids = jnp.asarray(rng.integers(0, 50, (8, 5)))
    a = np.asarray(fm.forward(p, {"ids": ids}, cfg_k))
    b = np.asarray(fm.forward(p, {"ids": ids}, cfg_r))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
