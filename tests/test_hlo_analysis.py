"""HLO structural analyzer: trip-count multipliers, dot flops, collective
bytes — validated against a controlled sharded-scan program."""
import os
import subprocess
import sys

import pytest


def run_probe(code: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_analyzer_exact_on_scan():
    """flops and collective bytes must multiply by the scan trip count."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.dist.act_sharding import use_mesh
from repro.launch.hlo_analysis import analyze

mesh = jax.make_mesh((4, 4), ("data", "model"))
def g(x, w):
    def body(c, _):
        return jnp.tanh((c @ w) @ w.T), None
    y, _ = jax.lax.scan(body, x, None, length=7)
    return y
with use_mesh(mesh):
    xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    comp = jax.jit(g, in_shardings=(NamedSharding(mesh, P("data", "model")),
                                    NamedSharding(mesh, P("model", None)))
                   ).lower(xs, ws).compile()
    st = analyze(comp.as_text())
    exp_flops = 2 * 2 * 64 * 256 * 512 / 16 * 7       # per-device
    exp_ar = 16 * 512 * 4 * 7                          # all-reduce bytes
    assert abs(st.flops - exp_flops) / exp_flops < 1e-6, st.flops
    assert abs(st.coll["all-reduce"] - exp_ar) / exp_ar < 1e-6
    assert 7 in st.while_trips.values()
    assert st.bytes_accessed > 0
    print("ANALYZER_OK")
"""
    assert "ANALYZER_OK" in run_probe(code)


def test_collective_parse_units():
    from repro.launch.hlo_analysis import _type_bytes
    assert _type_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _type_bytes("f32[]") == 4
    assert _type_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert _type_bytes("pred[16]") == 16


def test_roofline_terms():
    from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline
    r = Roofline(flops=197e12, bytes_accessed=819e9, coll_bytes=0,
                 coll_breakdown={}, chips=256, model_flops=197e12 * 256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")
    assert r.mfu == pytest.approx(1.0)
    assert r.useful_flop_frac == pytest.approx(1.0)
