"""GNN model tests: shapes/NaNs, equivariance properties (Wigner-D), the
GCN-vs-relational-engine differential (the paper's thesis made a test),
and DimeNet triplet correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.gnn.dimenet as dn
import repro.models.gnn.equivariant as eq
import repro.models.gnn.gcn as gcn
from repro.core.engine import Engine
from repro.models.gnn.irreps import (clebsch_gordan, random_rotation,
                                     sph_harm_real, tp_paths, wigner_d_real)


@pytest.fixture
def small_graph(rng):
    n, e = 24, 80
    snd = rng.integers(0, n, e).astype(np.int32)
    rcv = rng.integers(0, n, e).astype(np.int32)
    fix = snd == rcv
    snd[fix] = (rcv[fix] + 1) % n
    pos = rng.uniform(0, 4, (n, 3)).astype(np.float32)
    return n, snd, rcv, pos


# ---------------------------------------------------------------------- irreps
def test_sph_harm_rotation_property():
    rot = random_rotation(3)
    pts = np.random.default_rng(1).normal(size=(20, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    for l in range(3):
        d = wigner_d_real(l, rot)
        err = np.abs(sph_harm_real(l, pts @ rot.T)
                     - sph_harm_real(l, pts) @ d.T).max()
        assert err < 1e-8
        assert np.abs(d @ d.T - np.eye(2 * l + 1)).max() < 1e-8


def test_cg_equivariance_all_paths():
    rot = random_rotation(5)
    rng = np.random.default_rng(2)
    for (l1, l2, l3) in tp_paths(2):
        c = clebsch_gordan(l1, l2, l3)
        x = rng.normal(size=(2 * l1 + 1,))
        y = rng.normal(size=(2 * l2 + 1,))
        d1 = wigner_d_real(l1, rot)
        d2 = wigner_d_real(l2, rot)
        d3 = wigner_d_real(l3, rot)
        lhs = np.einsum("i,j,ijk->k", d1 @ x, d2 @ y, c)
        rhs = d3 @ np.einsum("i,j,ijk->k", x, y, c)
        assert np.abs(lhs - rhs).max() < 1e-8, (l1, l2, l3)


# ------------------------------------------------------------------------ GCN
def test_gcn_forward_backward(rng, small_graph):
    n, snd, rcv, _ = small_graph
    cfg = gcn.GCNConfig("g", d_feat=32, n_classes=5)
    snd2 = np.concatenate([snd, np.arange(n)])
    rcv2 = np.concatenate([rcv, np.arange(n)])
    batch = {"features": jnp.asarray(rng.normal(size=(n, 32)), jnp.float32),
             "senders": jnp.asarray(snd2), "receivers": jnp.asarray(rcv2),
             "labels": jnp.asarray(rng.integers(0, 5, n))}
    p = gcn.init(jax.random.PRNGKey(0), cfg)
    logits = gcn.forward(p, batch, cfg)
    assert logits.shape == (n, 5) and bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda p: gcn.loss_fn(p, batch, cfg)[0])(p)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_gcn_spmm_equals_relational_engine(rng, small_graph):
    """One GCN propagation (sum aggregator, no norm) == the EmptyHeaded
    engine's (+,*) join-aggregate over Edge annotated with message values.
    This is DESIGN.md §5's 'a GNN layer is a semiring join-aggregate'."""
    n, snd, rcv, _ = small_graph
    # engine uses set semantics: dedup edges first
    pairs = np.unique(np.stack([snd, rcv], 1), axis=0)
    snd, rcv = pairs[:, 0], pairs[:, 1]
    x = rng.normal(size=(n,)).astype(np.float64)  # 1-d features
    # engine: Out(y; s) :- Edge(x, y), Feat(x); s = SUM(x)
    eng = Engine()
    eng.load_edges("Edge", snd.astype(np.int64), rcv.astype(np.int64))
    eng.load_table("Feat", [np.arange(n)], annotation=x)
    res = eng.query("Out(y;s:float) :- Edge(x,y),Feat(x); s=<<SUM(x)>>.")
    got = np.zeros(n)
    d = res.as_dict()
    for k, v in d.items():
        got[k] = v
    # segment-sum substrate
    want = np.asarray(jax.ops.segment_sum(
        jnp.asarray(x)[jnp.asarray(snd)], jnp.asarray(rcv), num_segments=n))
    np.testing.assert_allclose(got, want, rtol=1e-9)


def test_gcn_edge_mask_equals_dropped_edges(rng, small_graph):
    n, snd, rcv, _ = small_graph
    cfg = gcn.GCNConfig("g", d_feat=8, n_classes=3)
    feats = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    p = gcn.init(jax.random.PRNGKey(1), cfg)
    keep = rng.random(len(snd)) < 0.6
    b_masked = {"features": feats, "senders": jnp.asarray(snd),
                "receivers": jnp.asarray(rcv),
                "edge_mask": jnp.asarray(keep.astype(np.float32))}
    b_dropped = {"features": feats, "senders": jnp.asarray(snd[keep]),
                 "receivers": jnp.asarray(rcv[keep])}
    np.testing.assert_allclose(np.asarray(gcn.forward(p, b_masked, cfg)),
                               np.asarray(gcn.forward(p, b_dropped, cfg)),
                               rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------------- DimeNet
def test_dimenet_triplets_exact():
    snd = np.array([0, 1, 2, 1], dtype=np.int32)   # edges: 0->1,1->2,2->0,1->0
    rcv = np.array([1, 2, 0, 0], dtype=np.int32)
    t1, t2, tm = dn.build_triplets(snd, rcv, 16)
    # wedges (e1: k->j, e2: j->i) with k != i:
    got = {(int(a), int(b)) for a, b, m in zip(t1, t2, tm) if m}
    # e2=0 (0->1): e1 ends at 0: e1=2 (2->0) k=2 != i=1 ok; e1=3 (1->0) k=1==i? i=1 -> excluded
    # e2=1 (1->2): e1 ends at 1: e1=0 (0->1), k=0 != 2 ok
    # e2=2 (2->0): e1 ends at 2: e1=1 (1->2), k=1 != 0 ok
    # e2=3 (1->0): e1 ends at 1: e1=0 (0->1), k=0 == i=0 -> excluded
    assert got == {(2, 0), (0, 1), (1, 2)}


def test_dimenet_forward_backward(rng, small_graph):
    n, snd, rcv, pos = small_graph
    cfg = dn.DimeNetConfig("d", n_blocks=2, d_hidden=16, n_bilinear=4)
    t1, t2, tm = dn.build_triplets(snd, rcv, 300)
    batch = {"species": jnp.asarray(rng.integers(0, 4, n)),
             "positions": jnp.asarray(pos),
             "senders": jnp.asarray(snd), "receivers": jnp.asarray(rcv),
             "edge_mask": jnp.ones(len(snd)),
             "t_e1": jnp.asarray(t1), "t_e2": jnp.asarray(t2),
             "t_mask": jnp.asarray(tm)}
    p = dn.init(jax.random.PRNGKey(0), cfg)
    e = dn.forward(p, batch, cfg)
    assert e.shape == (n,) and bool(jnp.isfinite(e).all())
    g = jax.grad(lambda p: dn.loss_fn(p, batch, cfg)[0])(p)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_dimenet_translation_invariance(rng, small_graph):
    n, snd, rcv, pos = small_graph
    cfg = dn.DimeNetConfig("d", n_blocks=2, d_hidden=16, n_bilinear=4)
    t1, t2, tm = dn.build_triplets(snd, rcv, 300)
    base = {"species": jnp.asarray(rng.integers(0, 4, n)),
            "senders": jnp.asarray(snd), "receivers": jnp.asarray(rcv),
            "edge_mask": jnp.ones(len(snd)),
            "t_e1": jnp.asarray(t1), "t_e2": jnp.asarray(t2),
            "t_mask": jnp.asarray(tm)}
    p = dn.init(jax.random.PRNGKey(0), cfg)
    e1 = dn.forward(p, dict(base, positions=jnp.asarray(pos)), cfg)
    e2 = dn.forward(p, dict(base, positions=jnp.asarray(pos + 7.5)), cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=2e-4, atol=2e-5)


# ------------------------------------------------------------- NequIP / MACE
@pytest.mark.parametrize("model", ["nequip", "mace"])
def test_equivariant_energy_invariance(model, rng, small_graph):
    n, snd, rcv, pos = small_graph
    batch = {"species": jnp.asarray(rng.integers(0, 4, n)),
             "positions": jnp.asarray(pos),
             "senders": jnp.asarray(snd), "receivers": jnp.asarray(rcv),
             "edge_mask": jnp.ones(len(snd))}
    rot = jnp.asarray(random_rotation(11), jnp.float32)
    shift = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
    b_rot = dict(batch, positions=batch["positions"] @ rot.T + shift)
    if model == "nequip":
        cfg = eq.NequIPConfig("n", n_layers=2, d_hidden=8)
        p = eq.init(jax.random.PRNGKey(0), cfg)
        e1, e2 = eq.forward(p, batch, cfg), eq.forward(p, b_rot, cfg)
    else:
        cfg = eq.MACEConfig("m", n_layers=2, d_hidden=8)
        p = eq.mace_init(jax.random.PRNGKey(0), cfg)
        e1, e2 = eq.mace_forward(p, batch, cfg), eq.mace_forward(p, b_rot, cfg)
    assert float(jnp.abs(e1 - e2).max()) < 1e-4


@pytest.mark.parametrize("model", ["nequip", "mace"])
def test_equivariant_backward(model, rng, small_graph):
    n, snd, rcv, pos = small_graph
    batch = {"species": jnp.asarray(rng.integers(0, 4, n)),
             "positions": jnp.asarray(pos),
             "senders": jnp.asarray(snd), "receivers": jnp.asarray(rcv),
             "edge_mask": jnp.ones(len(snd)),
             "graph_id": jnp.zeros(n, jnp.int32),
             "energy": jnp.zeros(1, jnp.float32)}
    if model == "nequip":
        cfg = eq.NequIPConfig("n", n_layers=2, d_hidden=8)
        p = eq.init(jax.random.PRNGKey(0), cfg)
        g = jax.grad(lambda p: eq.loss_fn(p, batch, cfg)[0])(p)
    else:
        cfg = eq.MACEConfig("m", n_layers=2, d_hidden=8)
        p = eq.mace_init(jax.random.PRNGKey(0), cfg)
        g = jax.grad(lambda p: eq.mace_loss_fn(p, batch, cfg)[0])(p)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_dimenet_wedge_join_equals_engine(rng):
    """Triplet count == 3-way self-join count in the relational engine:
    wedges (k->j->i, k != i) are Edge(k,j) |x| Edge(j,i) minus backtracks."""
    n, e = 15, 40
    snd = rng.integers(0, n, e).astype(np.int64)
    rcv = rng.integers(0, n, e).astype(np.int64)
    fix = snd == rcv
    snd[fix] = (rcv[fix] + 1) % n
    # dedup edges (engine uses set semantics)
    pairs = np.unique(np.stack([snd, rcv], 1), axis=0)
    snd, rcv = pairs[:, 0], pairs[:, 1]
    t1, t2, tm = dn.build_triplets(snd.astype(np.int32),
                                   rcv.astype(np.int32), 10_000)
    eng = Engine()
    eng.load_edges("E1", snd, rcv)
    eng.alias("E2", "E1")
    res = eng.query("W(k,j,i) :- E1(k,j),E2(j,i).")
    wedges = set(zip(res.columns["k"].tolist(), res.columns["j"].tolist(),
                     res.columns["i"].tolist()))
    wedges = {(k, j, i) for (k, j, i) in wedges if k != i}
    assert int(tm.sum()) == len(wedges)
