"""Fault layer: StepRunner retry semantics, checkpoint cadence, and
train_loop riding through transient failures + auto-resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.dist.fault import FaultPolicy, StepRunner, TransientError
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train import TrainState, make_train_step, train_loop


class FlakyStep:
    """step_fn that raises ``exc`` on the first ``n_failures`` calls."""

    def __init__(self, n_failures, exc=TransientError("preempted")):
        self.n_failures = n_failures
        self.exc = exc
        self.calls = 0

    def __call__(self, state, batch):
        self.calls += 1
        if self.calls <= self.n_failures:
            raise self.exc
        return {**state, "step": state["step"] + 1}, {"loss": 0.0}


def test_retries_transient_then_succeeds():
    flaky = FlakyStep(2)
    runner = StepRunner(flaky, policy=FaultPolicy(max_retries=3,
                                                  retry_wait_s=0.0))
    state, _ = runner.run({"step": 0}, {}, step=0)
    assert state["step"] == 1
    assert flaky.calls == 3
    assert runner.retries_total == 2


def test_retries_exhausted_reraises():
    flaky = FlakyStep(5)
    runner = StepRunner(flaky, policy=FaultPolicy(max_retries=2,
                                                  retry_wait_s=0.0))
    with pytest.raises(TransientError):
        runner.run({"step": 0}, {}, step=0)
    assert flaky.calls == 3  # 1 try + 2 retries


def test_non_transient_fails_fast():
    flaky = FlakyStep(1, exc=ValueError("NaN loss"))
    runner = StepRunner(flaky, policy=FaultPolicy(max_retries=3))
    with pytest.raises(ValueError):
        runner.run({"step": 0}, {}, step=0)
    assert flaky.calls == 1  # no retry for a model bug


def test_marker_classification():
    policy = FaultPolicy()
    assert policy.is_transient(RuntimeError("RESOURCE_EXHAUSTED: oom"))
    assert policy.is_transient(RuntimeError("worker preempted"))
    assert not policy.is_transient(ValueError("shape mismatch"))


def test_checkpoint_cadence(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep_last=10)
    runner = StepRunner(lambda s, b: (s, {}), ckpt,
                        FaultPolicy(checkpoint_every=2))
    saved = [step for step in range(1, 7)
             if runner.maybe_checkpoint({"w": jnp.zeros(())}, step)]
    assert saved == [2, 4, 6]
    assert ckpt.all_steps() == [2, 4, 6]
    # idempotent per step: a second call at the same step doesn't re-save
    assert not runner.maybe_checkpoint({"w": jnp.zeros(())}, 6)


def test_cadence_disabled():
    runner = StepRunner(lambda s, b: (s, {}), ckpt=None,
                        policy=FaultPolicy(checkpoint_every=0))
    assert not runner.maybe_checkpoint({}, 100)


def _small_lm():
    cfg = tfm.TransformerConfig(
        "t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=50, d_head=8, dtype=jnp.float32, q_block=8, kv_block=8)
    p = tfm.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    return cfg, opt, TrainState.create(p, opt).tree()


def _batch_at(i):
    r = np.random.default_rng(i)
    t = r.integers(0, 50, (2, 8)).astype(np.int32)
    return {"tokens": jnp.asarray(t), "targets": jnp.asarray(t)}


def test_train_loop_rides_through_transient_failure(tmp_path):
    cfg, opt, state = _small_lm()
    real_step = jax.jit(make_train_step(
        lambda p, b: tfm.loss_fn(p, b, cfg), opt))
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:  # one preemption mid-run
            raise TransientError("slice restart")
        return real_step(state, batch)

    policy = FaultPolicy(max_retries=2, retry_wait_s=0.0,
                         checkpoint_every=2)
    s, _ = train_loop(step, state, _batch_at, 5, ckpt_dir=str(tmp_path),
                      policy=policy)
    assert int(s["step"]) == 5
    assert calls["n"] == 6  # 5 successful + 1 retried
    ck = CheckpointManager(str(tmp_path))
    assert ck.all_steps() == [2, 4, 5]  # cadence saves + final save


def test_train_loop_resumes_from_cadence_checkpoint(tmp_path):
    """Crash mid-run after a cadence save -> rerun resumes from it."""
    cfg, opt, state = _small_lm()
    real_step = jax.jit(make_train_step(
        lambda p, b: tfm.loss_fn(p, b, cfg), opt))
    calls = {"n": 0}

    def crashy(state, batch):
        calls["n"] += 1
        if calls["n"] == 4:
            raise RuntimeError("hard fault")  # non-transient: crashes
        return real_step(state, batch)

    policy = FaultPolicy(max_retries=1, retry_wait_s=0.0,
                         checkpoint_every=2)
    with pytest.raises(RuntimeError):
        train_loop(crashy, state, _batch_at, 8, ckpt_dir=str(tmp_path),
                   policy=policy)
    ck = CheckpointManager(str(tmp_path))
    assert ck.latest_step() == 2  # saved before the crash at step 3

    s, _ = train_loop(real_step, state, _batch_at, 8,
                      ckpt_dir=str(tmp_path), policy=policy)
    assert int(s["step"]) == 8
