"""Static HBM footprint model (repro.analysis.memory_budget) tests.

Locks the tentpole contract: the model predicts the live device-cache
bytes EXACTLY (uploads go through known dtypes — any drift is a missed
component and raises), ``serve.GraphStore`` budgets eviction on model
device bytes rather than host ``nbytes()``, and the frontier/fixpoint
helpers size the transient buffers from the lowered program.
"""
import numpy as np
import pytest

from repro.analysis import memory_budget as MB
from repro.core.engine import Engine
from repro.core.workload import ALIASES, FOUR_CLIQUE, TRIANGLE_COUNT
from repro.data import powerlaw_graph
from repro.serve.query import QueryServer


@pytest.fixture(scope="module")
def device_engine():
    # 80/5 is dense enough that the counting pass routes through the
    # blocked bitset (its device directory is a modeled component)
    g = powerlaw_graph(80, 5, 2.0, seed=0)
    src = np.repeat(np.arange(g.n), g.degrees)
    eng = Engine(backend="device")
    trie = eng.load_edges("Edge", src, g.neighbors)
    for al in ALIASES:
        eng.alias(al, "Edge")
    records = []
    eng.backend.audit_log = records
    try:
        eng.query(TRIANGLE_COUNT)
        eng.query(FOUR_CLIQUE)    # routes a probe through the bitset
    finally:
        eng.backend.audit_log = None
    return eng, trie, records


# ---------------------------------------------------------- model vs live
def test_model_matches_live_exactly(device_engine):
    _eng, trie, _ = device_engine
    fp = MB.trie_footprint(trie)
    assert fp.components, "triangle query left no device caches"
    for c in fp.components:
        assert c.model_bytes == c.live_bytes, c
    assert fp.model_bytes == fp.live_bytes


def test_model_counts_components_host_nbytes_misses(device_engine):
    """Device bytes != host bytes: offsets narrow to int32 on upload
    (x64 off) and the bitset block directory exists only on device."""
    _eng, trie, _ = device_engine
    fp = MB.trie_footprint(trie)
    names = {c.name for c in fp.components}
    assert any(n.startswith("bitset_dir") for n in names)
    offsets = [c for c in fp.components if c.name.endswith(".offsets")]
    assert offsets, "no offsets resident — upload path changed?"
    import jax
    if not jax.config.jax_enable_x64:
        for i, lv in enumerate(trie.levels):
            for c in offsets:
                if c.name == f"level{i}.offsets":
                    # host holds int64, device holds int32: half
                    assert c.model_bytes * 2 == lv.offsets.nbytes
    assert fp.model_bytes != trie.nbytes()


def test_drift_raises_with_component_breakdown(device_engine):
    _eng, trie, _ = device_engine
    lv = next(lv for lv in trie.levels
              if lv.__dict__.get("_dev_values") is not None)
    real = lv.__dict__["_dev_values"]
    # fake an unaccounted 4 KiB device buffer behind the cache key
    lv.__dict__["_dev_values"] = (real[0], np.zeros(1024, np.int32))
    try:
        with pytest.raises(MB.MemoryBudgetError, match="drift"):
            MB.check_tries([trie])
    finally:
        lv.__dict__["_dev_values"] = real
    MB.check_tries([trie])   # restored: clean again


def test_check_counters_surface(device_engine):
    eng, trie, _ = device_engine
    before = eng.backend.stats.get("analysis.memory_checks", 0)
    MB.check_tries([trie], counters=eng.backend.stats)
    summary = eng.dispatch_summary()
    assert summary["analysis.memory_checks"] == before + 1
    assert summary["analysis.memory_model_bytes"] > 0


def test_full_upload_upper_bounds_resident(device_engine):
    _eng, trie, _ = device_engine
    assert MB.trie_full_upload_bytes(trie) \
        >= MB.trie_device_bytes(trie) > 0


# ------------------------------------------------------- transient buffers
def test_program_frontier_bytes_from_recorded_program(device_engine):
    _eng, _trie, records = device_engine
    prog = next(r[2] for r in records if r[0] == "bag")
    ext = [s for s in prog if s[0] == "extend"]
    assert ext
    got = MB.program_frontier_bytes(prog)
    idx = MB._idx_itemsize()
    want = sum(s[2] * (4 + idx * (2 + max(len(s[4]) - 1, 0)) + 1)
               for s in ext)
    assert got == want > 0
    # the batched path allocates per lane
    assert MB.program_frontier_bytes(prog, batch=4) == 4 * got


def test_fixpoint_state_bytes():
    # x64 off: float64 state narrows to 4 bytes + 1 frontier bool
    import jax
    per = 9 if jax.config.jax_enable_x64 else 5
    assert MB.fixpoint_state_bytes(100, np.float64) == 100 * per


# -------------------------------------------------- GraphStore integration
def test_graphstore_budgets_on_model_bytes():
    """``resident_bytes`` must agree with the model per registered trie
    — eviction decisions run off the static model, not host nbytes."""
    g = powerlaw_graph(40, 4, 2.0, seed=1)
    src = np.repeat(np.arange(g.n), g.degrees)
    srv = QueryServer(backend="device")
    trie = srv.load_graph("a", "Edge", src, g.neighbors)
    for al in ALIASES:
        srv.alias("a", al, "Edge")
    assert srv.store.resident_bytes() == 0    # nothing uploaded yet
    srv.run("a", TRIANGLE_COUNT)
    model = MB.trie_device_bytes(trie)
    assert srv.store.resident_bytes() == model > 0
    assert model != trie.nbytes()


def test_eviction_uses_model_budget():
    """A budget sized between one and two model footprints evicts the
    cold tenant and keeps the warm one."""
    g = powerlaw_graph(40, 4, 2.0, seed=1)
    src = np.repeat(np.arange(g.n), g.degrees)
    probe = QueryServer(backend="device")
    t0 = probe.load_graph("x", "Edge", src, g.neighbors)
    for al in ALIASES:
        probe.alias("x", al, "Edge")
    probe.run("x", TRIANGLE_COUNT)
    one = MB.trie_device_bytes(t0)

    srv = QueryServer(backend="device", capacity_bytes=int(1.5 * one))
    for tenant in ("a", "b"):
        srv.load_graph(tenant, "Edge", src, g.neighbors)
        for al in ALIASES:
            srv.alias(tenant, al, "Edge")
    srv.run("a", TRIANGLE_COUNT)
    assert srv.store.resident(a := "a")
    srv.run("b", TRIANGLE_COUNT)
    # both resident would cost ~2x the budget: the cold tenant dropped
    assert not srv.store.resident(a)
    assert srv.store.resident("b")
    assert srv.store.resident_bytes() <= int(1.5 * one)
    assert srv.counters.get("store.evictions", 0) >= 1
