"""Per-arch smoke tests (deliverable f): every assigned architecture as a
REDUCED config of the same family — one forward/train step on the host CPU,
asserting output shapes and no NaNs. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_arch
from repro.launch.train import reduced_lm_config
from repro.models import transformer as tfm
import repro.models.gnn.dimenet as dn
import repro.models.gnn.equivariant as eq
import repro.models.gnn.gcn as gcn
import repro.models.recsys.fm as fm


LM_ARCHS = [a for a in ASSIGNED if REGISTRY[a].family == "lm"]
GNN_ARCHS = [a for a in ASSIGNED if REGISTRY[a].family == "gnn"]


def test_registry_complete():
    assert len(ASSIGNED) == 10
    assert set(REGISTRY) - set(ASSIGNED) == {"emptyheaded"}
    # 40 assigned cells (incl. skipped long_500k entries)
    cells = [(a, s) for a in ASSIGNED for s in REGISTRY[a].shapes]
    assert len(cells) == 40


def test_exact_configs_match_assignment():
    c = get_arch("arctic-480b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.top_k, c.dense_residual) == \
        (35, 7168, 56, 8, 4864, 32000, 128, 2, True)
    c = get_arch("mixtral-8x7b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.attention, c.window) == \
        (32, 4096, 32, 8, 14336, 32000, 8, "swa", 4096)
    c = get_arch("granite-3-8b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 4096, 32, 8, 12800, 49155)
    c = get_arch("qwen2-72b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.qkv_bias) == (80, 8192, 64, 8, 29568, 152064, True)
    c = get_arch("minicpm3-4b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab,
            c.attention) == (62, 2560, 40, 6400, 73448, "mla")
    c = get_arch("dimenet").config
    assert (c.n_blocks, c.d_hidden, c.n_bilinear, c.n_spherical,
            c.n_radial) == (6, 128, 8, 7, 6)
    c = get_arch("gcn-cora").config
    assert (c.n_layers, c.d_hidden, c.aggregator, c.norm) == \
        (2, 16, "mean", "sym")
    c = get_arch("nequip").config
    assert (c.n_layers, c.d_hidden, c.l_max, c.n_rbf, c.cutoff) == \
        (5, 32, 2, 8, 5.0)
    c = get_arch("mace").config
    assert (c.n_layers, c.d_hidden, c.l_max, c.correlation_order,
            c.n_rbf) == (2, 128, 2, 3, 8)
    c = get_arch("fm").config
    assert (c.n_sparse, c.embed_dim, c.interaction) == (39, 10, "fm-2way")


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_lm_smoke(arch_name):
    """Reduced config keeps the structure (MoE-ness, attention kind,
    biases); one train step; shapes + finiteness."""
    arch = get_arch(arch_name)
    cfg = reduced_lm_config(arch.config)
    assert cfg.is_moe == arch.config.is_moe
    assert cfg.attention == arch.config.attention
    assert cfg.qkv_bias == arch.config.qkv_bias
    key = jax.random.PRNGKey(0)
    p = tfm.init(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    loss, metrics = tfm.loss_fn(p, batch, cfg)
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: tfm.loss_fn(p, batch, cfg)[0])(p)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    # serve path
    lg, cache = tfm.prefill(p, toks[:, :8], cfg, max_len=16)
    assert lg.shape == (2, cfg.vocab) and bool(jnp.isfinite(lg).all())
    step = tfm.decode_step_mla if cfg.attention == "mla" else tfm.decode_step
    lg2, cache = step(p, cache, toks[:, 8:9], cfg)
    assert lg2.shape == (2, cfg.vocab) and bool(jnp.isfinite(lg2).all())


def _tiny_graph(rng, n=20, e=60):
    snd = rng.integers(0, n, e).astype(np.int32)
    rcv = rng.integers(0, n, e).astype(np.int32)
    fix = snd == rcv
    snd[fix] = (rcv[fix] + 1) % n
    pos = rng.uniform(0, 4, (n, 3)).astype(np.float32)
    return n, snd, rcv, pos


@pytest.mark.parametrize("arch_name", GNN_ARCHS)
def test_gnn_smoke(arch_name, rng):
    arch = get_arch(arch_name)
    n, snd, rcv, pos = _tiny_graph(rng)
    if arch_name == "gcn-cora":
        cfg = dataclasses.replace(arch.config, d_feat=12, n_classes=4)
        batch = {"features": jnp.asarray(rng.normal(size=(n, 12)),
                                         jnp.float32),
                 "senders": jnp.asarray(snd), "receivers": jnp.asarray(rcv),
                 "labels": jnp.asarray(rng.integers(0, 4, n))}
        p = gcn.init(jax.random.PRNGKey(0), cfg)
        out = gcn.forward(p, batch, cfg)
        assert out.shape == (n, 4)
        g = jax.grad(lambda p: gcn.loss_fn(p, batch, cfg)[0])(p)
    else:
        batch = {"species": jnp.asarray(rng.integers(0, 4, n)),
                 "positions": jnp.asarray(pos),
                 "senders": jnp.asarray(snd), "receivers": jnp.asarray(rcv),
                 "edge_mask": jnp.ones(len(snd)),
                 "graph_id": jnp.zeros(n, jnp.int32),
                 "energy": jnp.zeros(1, jnp.float32)}
        if arch_name == "dimenet":
            cfg = dataclasses.replace(arch.config, n_blocks=2, d_hidden=16,
                                      n_bilinear=4)
            t1, t2, tm = dn.build_triplets(snd, rcv, 200)
            batch.update({"t_e1": jnp.asarray(t1), "t_e2": jnp.asarray(t2),
                          "t_mask": jnp.asarray(tm)})
            p = dn.init(jax.random.PRNGKey(0), cfg)
            out = dn.forward(p, batch, cfg)
            g = jax.grad(lambda p: dn.loss_fn(p, batch, cfg)[0])(p)
        elif arch_name == "nequip":
            cfg = dataclasses.replace(arch.config, n_layers=2, d_hidden=8)
            p = eq.init(jax.random.PRNGKey(0), cfg)
            out = eq.forward(p, batch, cfg)
            g = jax.grad(lambda p: eq.loss_fn(p, batch, cfg)[0])(p)
        else:
            cfg = dataclasses.replace(arch.config, n_layers=2, d_hidden=8)
            p = eq.mace_init(jax.random.PRNGKey(0), cfg)
            out = eq.mace_forward(p, batch, cfg)
            g = jax.grad(lambda p: eq.mace_loss_fn(p, batch, cfg)[0])(p)
        assert out.shape == (n,)
    assert bool(jnp.isfinite(out).all())
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_fm_smoke(rng):
    cfg = dataclasses.replace(get_arch("fm").config, vocab_per_field=100)
    p = fm.init(jax.random.PRNGKey(0), cfg)
    batch = {"ids": jnp.asarray(rng.integers(0, 100, (8, cfg.n_sparse))),
             "label": jnp.asarray(rng.integers(0, 2, 8), jnp.float32)}
    logits = fm.forward(p, batch, cfg)
    assert logits.shape == (8,) and bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda p: fm.loss_fn(p, batch, cfg)[0])(p)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
    scores = fm.retrieval_scores(p, jnp.arange(4), jnp.arange(500), cfg)
    assert scores.shape == (500,)


def test_long500k_skips_recorded():
    """Exactly the four pure-full-attention archs skip long_500k."""
    skipped = {a for a in LM_ARCHS
               if REGISTRY[a].shapes["long_500k"].skip}
    assert skipped == {"arctic-480b", "granite-3-8b", "qwen2-72b",
                       "minicpm3-4b"}
    assert REGISTRY["mixtral-8x7b"].shapes["long_500k"].skip is None
