"""Lock-discipline linter (repro.analysis.concurrency_lint) tests.

Locks the serving-layer concurrency contract: every registered shared
attribute is mutated only under ``self.*_lock`` (or inside a declared
``@guarded_by`` method), ``serve/`` is lock-clean with no baseline
escape hatch, and the accounted single-threaded-core findings exactly
match ``concurrency_baseline.json`` in both directions.
"""
import textwrap

from repro.analysis import concurrency_lint as CL

FILE = "serve/query.py"   # label with registered shared state


def lint(body: str):
    src = "class QueryServer:\n" + textwrap.indent(
        textwrap.dedent(body), "    ")
    return CL.lint_source(src, FILE)


def kinds(findings):
    return [f.kind for f in findings]


# ------------------------------------------------------------- snippets
def test_unguarded_rmw_flagged():
    fs = lint("""
        def _bump(self, key):
            self.counters[key] = self.counters.get(key, 0) + 1
    """)
    assert kinds(fs) == ["unguarded-rmw"]
    assert "counters" in fs[0].detail


def test_unguarded_append_flagged():
    fs = lint("""
        def submit(self, p):
            self._queue.append(p)
    """)
    assert kinds(fs) == ["unguarded-rmw"]


def test_unguarded_swap_in_tuple_unpack_flagged():
    """The drain idiom — ``queue, self._queue = self._queue, []`` — is
    a write hiding inside tuple unpacking."""
    fs = lint("""
        def drain(self):
            queue, self._queue = self._queue, []
            return queue
    """)
    assert kinds(fs) == ["unguarded-write"]


def test_with_lock_guards_mutation():
    fs = lint("""
        def submit(self, p):
            with self._lock:
                self._queue.append(p)
                self.counters["n"] = self.counters.get("n", 0) + 1
    """)
    assert fs == []


def test_non_lock_with_does_not_guard():
    fs = lint("""
        def submit(self, p):
            with self._file:
                self._queue.append(p)
    """)
    assert kinds(fs) == ["unguarded-rmw"]


def test_guarded_by_declares_lock_held():
    fs = lint("""
        @guarded_by("_lock")
        def _drop(self, k):
            self._engines.pop(k)
    """)
    assert fs == []


def test_unheld_call_to_guarded_method_flagged():
    fs = lint("""
        @guarded_by("_lock")
        def _drop(self, k):
            self._engines.pop(k)

        def evict(self, k):
            self._drop(k)
    """)
    assert kinds(fs) == ["unheld-guard-call"]
    assert "_lock" in fs[0].detail


def test_held_call_to_guarded_method_clean():
    fs = lint("""
        @guarded_by("_lock")
        def _drop(self, k):
            self._engines.pop(k)

        def evict(self, k):
            with self._lock:
                self._drop(k)
    """)
    assert fs == []


def test_nested_def_does_not_inherit_lock():
    """A closure runs later, possibly on another thread — holding the
    lock at definition time guards nothing."""
    fs = lint("""
        def submit(self, p):
            with self._lock:
                def later():
                    self._queue.append(p)
                return later
    """)
    assert kinds(fs) == ["unguarded-rmw"]


def test_init_writes_exempt():
    fs = lint("""
        def __init__(self):
            self._queue = []
            self.counters = {}
    """)
    assert fs == []


def test_unregistered_attrs_ignored():
    fs = lint("""
        def note(self):
            self._scratch.append(1)
            self.tmp = 2
    """)
    assert fs == []


def test_device_cache_store_flagged_anywhere():
    src = textwrap.dedent("""
        class DeviceBackend:
            def _dev_sideways(self, bs):
                bs._dev_sideways_cache = (bs.block_ids, ())
    """)
    fs = CL.lint_source(src, "core/backend.py")
    assert kinds(fs) == ["unguarded-write"]
    assert "_dev_sideways_cache" in fs[0].detail


# ----------------------------------------------------------- the real tree
def test_serve_is_lock_clean():
    strict = CL.strict_findings(CL.lint_tree())
    assert strict == [], [str(f) for f in strict]


def test_tree_matches_committed_baseline():
    findings = CL.lint_tree()
    new, removed = CL.compare(findings, CL.load_baseline())
    assert new == [], f"new unguarded shared-state mutations: {new}"
    assert removed == [], (f"findings removed but baseline not shrunk: "
                           f"{removed}")


def test_guarded_by_is_a_runtime_noop():
    @CL.guarded_by("_lock")
    def f(self):
        return 7

    assert f(None) == 7
    assert f.__guarded_by__ == "_lock"


def test_graphstore_helpers_declared():
    """The two budget helpers really carry the declaration the linter
    verifies call sites against."""
    from repro.serve.query import GraphStore
    assert GraphStore._resident_tenants.__guarded_by__ == "_lock"
    assert GraphStore._over_budget.__guarded_by__ == "_lock"
