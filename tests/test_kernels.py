"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle,
over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitset_intersect.ops import bitset_and_popcount
from repro.kernels.bitset_intersect.ref import bitset_and_popcount_ref
from repro.kernels.fm_interaction.ops import fm_interaction
from repro.kernels.fm_interaction.ref import (fm_interaction_pairwise_ref,
                                              fm_interaction_ref)
from repro.kernels.spmv_ell.ops import csr_to_ell, spmv_ell
from repro.kernels.spmv_ell.ref import spmv_ell_ref
from repro.kernels.triangle_mm.ops import densify_csr, triangle_count_dense
from repro.kernels.triangle_mm.ref import triangle_count_dense_ref
from repro.kernels.uint_intersect.ops import uint_intersect_count
from repro.kernels.uint_intersect.ref import uint_intersect_count_ref


@pytest.mark.parametrize("n_blocks,words,pairs", [
    (1, 1, 1), (10, 8, 64), (64, 8, 300), (50, 128, 1000), (7, 13, 77),
])
def test_bitset_and_popcount_sweep(rng, n_blocks, words, pairs):
    blocks = rng.integers(0, 2**32, size=(n_blocks, words), dtype=np.uint32)
    pa = rng.integers(0, n_blocks, pairs)
    pb = rng.integers(0, n_blocks, pairs)
    got = np.asarray(bitset_and_popcount(blocks, pa, pb, interpret=True))
    want = np.asarray(bitset_and_popcount_ref(jnp.asarray(blocks)[pa],
                                              jnp.asarray(blocks)[pb]))
    np.testing.assert_array_equal(got, want)


def test_bitset_and_popcount_empty():
    out = bitset_and_popcount(np.zeros((4, 8), np.uint32),
                              np.zeros(0, np.int64), np.zeros(0, np.int64),
                              interpret=True)
    assert out.shape == (0,)


@pytest.mark.parametrize("p,la,lb,hi", [
    (1, 5, 7, 50), (20, 37, 61, 200), (8, 128, 128, 1000),
    (33, 200, 90, 500),
])
def test_uint_intersect_sweep(rng, p, la, lb, hi):
    a = np.full((p, la), -1, np.int32)
    b = np.full((p, lb), -1, np.int32)
    for i in range(p):
        na = rng.integers(0, la + 1)
        nb = rng.integers(0, lb + 1)
        a[i, :na] = np.sort(rng.choice(hi, na, replace=False))
        b[i, :nb] = np.sort(rng.choice(hi, nb, replace=False))
    got = np.asarray(uint_intersect_count(a, b, interpret=True))
    want = np.asarray(uint_intersect_count_ref(jnp.asarray(a),
                                               jnp.asarray(b)))
    expect = [len(np.intersect1d(a[i][a[i] >= 0], b[i][b[i] >= 0]))
              for i in range(p)]
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, expect)


@pytest.mark.parametrize("n,dens", [(64, 0.1), (300, 0.05), (400, 0.2),
                                    (128, 0.0)])
def test_triangle_mm_sweep(rng, n, dens):
    a = (rng.random((n, n)) < dens).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    got = float(triangle_count_dense(a, symmetric=True, interpret=True))
    want = float(triangle_count_dense_ref(jnp.asarray(a))) / 6.0
    brute = int(np.trace(np.linalg.matrix_power(a.astype(np.int64), 3)) // 6)
    assert abs(got - want) < 1e-3
    assert abs(got - brute) < 1e-3


def test_triangle_mm_pruned_dag(rng):
    """On a src>dst pruned DAG the raw masked sum counts each triangle once
    ... for path DAGs; cross-check against the symmetric count."""
    n = 150
    a = (rng.random((n, n)) < 0.1).astype(np.float32)
    a = np.triu(a, 1) + np.triu(a, 1).T
    sym = float(triangle_count_dense(a, symmetric=True, interpret=True))
    lower = np.tril(a)  # src > dst pruning keeps lower triangle
    # wedges u>v>w with (u,w) edge: each triangle exactly once
    pruned = float(((lower @ lower) * lower).sum())
    assert abs(sym - pruned) < 1e-3


@pytest.mark.parametrize("n,max_deg", [(10, 3), (700, 8), (513, 1),
                                       (1000, 16)])
def test_spmv_ell_sweep(rng, n, max_deg):
    deg = rng.integers(0, max_deg + 1, n)
    offs = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=offs[1:])
    nbr = rng.integers(0, n, offs[-1]).astype(np.int32)
    w = rng.random(offs[-1]).astype(np.float32)
    cols, vals = csr_to_ell(offs, nbr, w)
    x = rng.random(n).astype(np.float32)
    got = np.asarray(spmv_ell(cols, vals, x, interpret=True))
    want = np.asarray(spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals),
                                   jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # dense oracle
    dense = np.zeros((n, n), np.float32)
    row = np.repeat(np.arange(n), deg)
    np.add.at(dense, (row, nbr), w)
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,f,d", [(1, 2, 4), (33, 39, 10), (128, 16, 32),
                                   (7, 8, 8)])
def test_fm_interaction_sweep(rng, b, f, d):
    emb = rng.normal(size=(b, f, d)).astype(np.float32)
    got = np.asarray(fm_interaction(emb, interpret=True))
    w1 = np.asarray(fm_interaction_ref(jnp.asarray(emb)))
    w2 = np.asarray(fm_interaction_pairwise_ref(jnp.asarray(emb)))
    np.testing.assert_allclose(got, w1, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(got, w2, rtol=3e-4, atol=3e-4)


def test_densify_roundtrip(rng):
    n = 50
    deg = rng.integers(0, 5, n)
    offs = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=offs[1:])
    nbr = rng.integers(0, n, offs[-1]).astype(np.int32)
    dense = densify_csr(offs, nbr, n)
    assert dense.sum() <= offs[-1]  # duplicates collapse
    for u in range(n):
        for v in nbr[offs[u]:offs[u + 1]]:
            assert dense[u, v] == 1.0
