"""End-to-end behaviour tests for the EmptyHeaded core (paper §2-§3):
datalog -> GHD -> worst-case-optimal join, against brute-force oracles.
Includes hypothesis property tests on random graphs."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import brute_triangle_count, random_undirected_graph
from repro.core.engine import Engine


def make_engine(src, dst, aliases=("R", "S", "T", "U", "X", "Y")):
    eng = Engine()
    eng.load_edges("Edge", src, dst)
    for a in aliases:
        eng.alias(a, "Edge")
    return eng


# ------------------------------------------------------------------ triangles
@pytest.mark.parametrize("n,p,seed", [(12, 0.3, 0), (30, 0.2, 1),
                                      (60, 0.1, 2), (25, 0.5, 3)])
def test_triangle_count_vs_brute(n, p, seed):
    src, dst, adj = random_undirected_graph(n, p, seed)
    eng = make_engine(src, dst)
    res = eng.query("T3(;w:long) :- R(x,y),S(y,z),T(x,z); w=<<COUNT(*)>>.")
    # directed listing counts each undirected triangle 6x
    assert int(res.scalar()) == 6 * brute_triangle_count(adj)


def test_triangle_listing_rows(rng):
    src, dst, adj = random_undirected_graph(20, 0.3, 7)
    eng = make_engine(src, dst)
    res = eng.query("Tri(x,y,z) :- R(x,y),S(y,z),T(x,z).")
    a = adj.astype(bool)
    want = {(x, y, z) for x in range(20) for y in range(20)
            for z in range(20) if a[x, y] and a[y, z] and a[x, z]}
    got = set(zip(res.columns["x"].tolist(), res.columns["y"].tolist(),
                  res.columns["z"].tolist()))
    assert got == want


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 24), p=st.floats(0.05, 0.6),
       seed=st.integers(0, 100))
def test_triangle_property(n, p, seed):
    src, dst, adj = random_undirected_graph(n, p, seed)
    if len(src) == 0:
        return
    eng = make_engine(src, dst)
    res = eng.query("T3(;w:long) :- R(x,y),S(y,z),T(x,z); w=<<COUNT(*)>>.")
    assert int(res.scalar()) == 6 * brute_triangle_count(adj)


# ----------------------------------------------------------- 4-clique/pattern
def brute_4clique(adj) -> int:
    n = adj.shape[0]
    a = adj.astype(bool)
    cnt = 0
    for x in range(n):
        for y in range(x + 1, n):
            if not a[x, y]:
                continue
            for z in range(y + 1, n):
                if not (a[x, z] and a[y, z]):
                    continue
                for w in range(z + 1, n):
                    if a[x, w] and a[y, w] and a[z, w]:
                        cnt += 1
    return cnt


@pytest.mark.parametrize("n,p,seed", [(14, 0.4, 0), (20, 0.3, 5)])
def test_4clique_vs_brute(n, p, seed):
    src, dst, adj = random_undirected_graph(n, p, seed)
    eng = make_engine(src, dst)
    res = eng.query(
        "K4(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,w),X(y,w),Y(z,w); "
        "w=<<COUNT(*)>>.")
    assert int(res.scalar()) == 24 * brute_4clique(adj)  # 4! orderings


def test_lollipop_vs_brute(rng):
    src, dst, adj = random_undirected_graph(16, 0.3, 11)
    eng = make_engine(src, dst)
    res = eng.query(
        "L(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,w); w=<<COUNT(*)>>.")
    a = adj.astype(np.int64)
    tri_at_x = (a @ a * a).sum(axis=1)           # per-x directed (y,z) pairs
    deg = a.sum(axis=1)
    assert int(res.scalar()) == int((tri_at_x * deg).sum())


def test_barbell_vs_brute(rng):
    src, dst, adj = random_undirected_graph(12, 0.35, 13)
    eng = make_engine(src, dst, aliases=("R", "S", "T", "U",
                                         "R2", "S2", "T2"))
    res = eng.query(
        "B(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),R2(a,b),S2(b,c),T2(a,c); "
        "w=<<COUNT(*)>>.")
    a = adj.astype(np.int64)
    tri_at = (a @ a * a).sum(axis=1)             # directed triangle pairs at v
    want = int(tri_at @ a @ tri_at)              # wedge of two triangles
    assert int(res.scalar()) == want


def test_ghd_vs_single_bag_same_answer(rng):
    """The GHD plan (early aggregation) and the single-bag WCOJ plan must
    agree on every query (paper §5.3.1 -GHD ablation)."""
    src, dst, adj = random_undirected_graph(14, 0.35, 17)
    q = ("B(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),R2(a,b),S2(b,c),T2(a,c);"
         " w=<<COUNT(*)>>.")
    aliases = ("R", "S", "T", "U", "R2", "S2", "T2")
    e1 = make_engine(src, dst, aliases=aliases)
    e2 = Engine(use_ghd=False)
    e2.load_edges("Edge", src, dst)
    for al in aliases:
        e2.alias(al, "Edge")
    r1 = e1.query(q)
    r2 = e2.query(q)
    assert int(r1.scalar()) == int(r2.scalar())


def test_codegen_vs_interpreter(rng):
    """Generated source and the plan interpreter are differential twins."""
    src, dst, adj = random_undirected_graph(18, 0.3, 19)
    q = "T3(;w:long) :- R(x,y),S(y,z),T(x,z); w=<<COUNT(*)>>."
    e1 = Engine(use_codegen=True)
    e2 = Engine(use_codegen=False)
    for e in (e1, e2):
        e.load_edges("Edge", src, dst)
        for al in ("R", "S", "T"):
            e.alias(al, "Edge")
    assert int(e1.query(q).scalar()) == int(e2.query(q).scalar())
    assert e1.generated_source() is not None


# ------------------------------------------------------------------ analytics
def test_pagerank_vs_numpy(rng):
    src, dst, adj = random_undirected_graph(20, 0.3, 23)
    # keep only nodes with degree > 0 consistent: engine operates on edges
    eng = make_engine(src, dst)
    res = eng.query(
        "N(;w:int) :- Edge(x,y); w=<<COUNT(x)>>.\n"
        "InvDeg(x;y:float) :- Edge(x,z); y=1.0/<<COUNT(z)>>.\n"
        "PageRank(x;y:float) :- Edge(x,z); y=1.0/N.\n"
        "PageRank(x;y:float)*[i=8] :- Edge(x,z),PageRank(z),InvDeg(z); "
        "y=0.15/N+0.85*<<SUM(z)>>.")
    pr = res.as_dict()
    # numpy reference (same semantics: nodes = those with out-edges)
    nodes = sorted(set(src.tolist()) | set(dst.tolist()))
    n = len(nodes)
    deg = {u: 0 for u in nodes}
    for u in src:
        deg[u] += 1
    r = {u: 1.0 / n for u in nodes}
    for _ in range(8):
        new = {}
        for x in nodes:
            s = sum(r[z] / deg[z] for z in adj[x].nonzero()[0])
            new[x] = 0.15 / n + 0.85 * s
        r = new
    for u in nodes:
        assert abs(pr[u] - r[u]) < 1e-6, (u, pr[u], r[u])


def test_sssp_vs_bfs(rng):
    src, dst, adj = random_undirected_graph(30, 0.15, 29)
    eng = make_engine(src, dst)
    start = int(src[0])
    res = eng.query(
        f"SSSP(x;y:int) :- Edge({start},x); y=1.\n"
        "SSSP(x;y:int)* :- Edge(w,x),SSSP(w); y=<<MIN(w)>>+1.")
    d = res.as_dict()
    # BFS reference
    from collections import deque
    dist = {start: 0}
    dq = deque([start])
    while dq:
        u = dq.popleft()
        for v in adj[u].nonzero()[0]:
            if v not in dist:
                dist[int(v)] = dist[u] + 1
                dq.append(int(v))
    for v, dv in dist.items():
        if v == start:
            continue
        assert int(d[v]) == dv, (v, d[v], dv)
    # exact reach: no identity-annotated (inf) tuples may leak out of the
    # seminaive evaluation (regression: empty-intersection terminal folds)
    assert set(d) <= set(dist), sorted(set(d) - set(dist))[:5]
    assert all(np.isfinite(list(d.values())))


# ------------------------------------------------------------------ selection
def test_selection_constant(rng):
    src, dst, adj = random_undirected_graph(15, 0.4, 31)
    eng = make_engine(src, dst)
    x0 = int(src[0])
    res = eng.query(f"Nbr(y) :- Edge({x0},y).")
    assert set(res.columns["y"].tolist()) == set(adj[x0].nonzero()[0].tolist())
