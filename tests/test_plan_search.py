"""Cost-based plan search (core.plan_search) tests.

Locks the PR's acceptance behaviour: (1) the search picks a
non-appearance-order plan on the lollipop query and stays parity-exact
with the ``REPRO_PLAN_SEARCH=off`` seed plan on both backends, (2) the
barbell query KEEPS the seed plan (its Appendix-A.1 shared-triangle
dedup makes the seed cheapest — a cost model that loses the sharing
would regress it), (3) on random small acyclic queries the bounded
search never returns a plan costlier than exhaustive enumeration's best,
and (4) the cohort-routed materializing intersections
(``HybridSetStore.intersect_materialize``) are exercised and counted.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import random_undirected_graph
from repro.core import ghd as ghd_mod
from repro.core import plan_ir, plan_search
from repro.core import workload as W
from repro.core.datalog import parse
from repro.core.engine import Engine
from repro.core.hypergraph import Hypergraph

ALIASES = W.ALIASES


def make_engine(src, dst, backend="numpy", **kw):
    eng = Engine(backend=backend, **kw)
    eng.load_edges("Edge", src, dst)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


def _scalar(res):
    return float(np.asarray(res.scalar()))


# ------------------------------------------------ plan-change regression
@pytest.mark.parametrize("backend", ["numpy", "device"])
def test_lollipop_search_changes_order_with_parity(backend):
    """Acceptance lock-in: cost-based search roots the lollipop GHD at
    the triangle bag (skipping the seed plan's per-x sort-projection),
    changing the global order away from the appearance-order tie-break —
    with exact result parity against REPRO_PLAN_SEARCH=off."""
    src, dst, _ = random_undirected_graph(40, 0.2, 5)
    on = make_engine(src, dst, backend, plan_search=True)
    off = make_engine(src, dst, backend, plan_search=False)
    r_on, r_off = on.query(W.LOLLIPOP), off.query(W.LOLLIPOP)
    assert _scalar(r_on) == _scalar(r_off)

    ps = on.plan_metadata()[0]["plan_search"]
    assert ps["enabled"] is True
    assert ps["order_changed"] is True
    assert ps["chosen_order"] != ps["baseline_order"]
    assert ps["chosen_cost"] < ps["baseline_cost"]
    assert ps["candidates"] > 1
    # the off-engine really ran the appearance-order plan
    off_ps = off.plan_metadata()[0]["plan_search"]
    assert off_ps == {"enabled": False}
    assert off.plan_metadata()[0]["order"] == ps["baseline_order"]
    # min-fhw is a hard constraint of the candidate space
    assert ps["chosen_fhw"] == pytest.approx(1.5)


@pytest.mark.parametrize("backend", ["numpy", "device"])
def test_barbell_search_keeps_seed_plan_and_dedup(backend):
    """The barbell seed plan computes its two alias-equivalent triangle
    bags ONCE (Appendix A.1); the cost model counts shared bags once, so
    re-rooting (which would break the sharing and double the triangle
    work) must lose."""
    src, dst, _ = random_undirected_graph(40, 0.2, 6)
    on = make_engine(src, dst, backend, plan_search=True)
    off = make_engine(src, dst, backend, plan_search=False)
    assert _scalar(on.query(W.BARBELL)) == _scalar(off.query(W.BARBELL))
    ps = on.plan_metadata()[0]["plan_search"]
    assert ps["order_changed"] is False
    assert ps["chosen_index"] == 0
    assert on.dispatch_summary()["bag_cache.hits"] >= 1


def test_symmetric_queries_keep_appearance_order():
    """On symmetric data every triangle/K4 order costs the same — strict
    argmin must keep the seed (candidate 0), bit-for-bit."""
    src, dst, _ = random_undirected_graph(30, 0.3, 7)
    eng = make_engine(src, dst, plan_search=True)
    eng.query(W.TRIANGLE_COUNT)
    assert eng.plan_metadata()[0]["plan_search"]["chosen_index"] == 0
    eng.query(W.FOUR_CLIQUE)
    assert eng.plan_metadata()[0]["plan_search"]["chosen_index"] == 0
    assert eng.plan_metadata()[0]["order"] == ["x", "y", "z", "a"]


# ------------------------------------------------------- search machinery
def test_candidate_orders_seed_first_and_invariants():
    rule = parse(W.BARBELL).rules[0]
    hg = Hypergraph.from_rule(rule)
    g = ghd_mod.decompose(hg)
    orders = ghd_mod.candidate_orders(g)
    assert orders[0] == ghd_mod.attribute_order(g)
    assert len(orders) == len(set(orders)) > 1
    for o in orders:
        assert sorted(o) == sorted(hg.vertices)


def test_decompose_candidates_seed_first_min_width_only():
    rule = parse(W.LOLLIPOP).rules[0]
    hg = Hypergraph.from_rule(rule)
    seed = ghd_mod.decompose(hg)
    cands = ghd_mod.decompose_candidates(hg)
    assert len(cands) > 1
    assert all(g.width == pytest.approx(seed.width) for g in cands)
    first = cands[0]
    assert sorted(first.root.edge_idxs) == sorted(seed.root.edge_idxs)
    assert first.num_bags() == seed.num_bags()


def test_escape_hatch_env_variable(monkeypatch):
    monkeypatch.setenv(plan_search.ENV_FLAG, "off")
    assert Engine().plan_search is False
    monkeypatch.setenv(plan_search.ENV_FLAG, "on")
    assert Engine().plan_search is True
    monkeypatch.delenv(plan_search.ENV_FLAG)
    assert Engine().plan_search is True
    assert Engine(plan_search=False).plan_search is False


def test_search_overhead_paid_once_per_rule():
    """Recursion bumps catalog versions every round; the search decision
    is pinned per rule so later rounds only re-annotate the chosen plan
    (the physical plan itself still rebuilds on fresh statistics)."""
    src, dst, _ = random_undirected_graph(24, 0.3, 8)
    eng = make_engine(src, dst, plan_search=True)
    eng.query(W.sssp_program(int(src[0])))
    assert len(eng._search_cache) >= 1
    n_decided = len(eng._search_cache)
    eng.query(W.sssp_program(int(src[0])))
    assert len(eng._search_cache) == n_decided


# --------------------------------------------- cost-model property test
def _random_acyclic_count_query(rng, n_atoms):
    """A random ≤4-atom ACYCLIC (tree-shaped) scalar COUNT query."""
    vars_ = ["v0"]
    atoms = []
    for i in range(n_atoms):
        parent = vars_[rng.randrange(len(vars_))]
        child = f"v{i + 1}"
        vars_.append(child)
        rel = ALIASES[i % len(ALIASES)]
        pair = (parent, child) if rng.random() < 0.5 else (child, parent)
        atoms.append(f"{rel}({pair[0]},{pair[1]})")
    return f"C(;w:long) :- {','.join(atoms)}; w=<<COUNT(*)>>."


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_atoms=st.integers(min_value=1, max_value=4))
def test_search_never_worse_than_exhaustive_best(seed, n_atoms):
    """Property: on random ≤4-atom acyclic queries the bounded search's
    chosen cost never exceeds the best over EXHAUSTIVE candidate
    enumeration (i.e. the beam/top-k bounds lose nothing at this query
    size), and the chosen plan's results match the seed plan's."""
    import random

    rng = random.Random(seed)
    q = _random_acyclic_count_query(rng, n_atoms)
    src, dst, _ = random_undirected_graph(24, 0.25, seed % 97)
    eng = make_engine(src, dst, plan_search=False)
    rule = parse(q).rules[0]
    plan = eng._compile(rule)

    sr = plan_search.search(plan, eng.stats_catalog, eng.catalog)
    exhaustive = plan_search.enumerate_candidates(
        plan, k_partitions=512, max_roots=16, max_orders=720,
        max_candidates=4096)
    memo = {}
    best = min(
        plan_ir.plan_cost(plan_ir.build_physical_plan(
            c, eng.stats_catalog, eng.catalog, agm_memo=memo))
        for c in exhaustive)
    assert sr.cost <= best * (1 + 1e-9) + 1e-9
    assert len(exhaustive) >= sr.candidates

    # and the chosen plan computes the same answer as the seed plan
    on = make_engine(src, dst, plan_search=True)
    assert _scalar(on.query(q)) == _scalar(eng.query(q))


# -------------------------------------- materializing intersection routing
@pytest.mark.parametrize("backend", ["numpy", "device"])
def test_materializing_intersections_cohort_routed(backend):
    """ROADMAP known issue closed: materializing binary self-join
    intersections route through the layout store by plan hint — dense
    pairs take the bitset extraction — instead of always falling back to
    the uint search; dispatch counters prove it.

    Pinned to the appearance-order seed plan: since PR 8 the cost-based
    search prefers an all-search order on dense graphs (the sideways
    bitset credit keeps the whole bag on the zero-sync fused pipeline,
    which a landing pair_store extend would break out of), so the
    pair-materialize capability is exercised on the seed plan where the
    lowering still routes it."""
    src, dst, adj = random_undirected_graph(30, 0.4, 9)
    eng = make_engine(src, dst, backend, plan_search=False)
    res = eng.query(W.TRIANGLE_LIST)
    st_ = eng.dispatch_summary()
    assert st_.get("extend.pair_materialize_calls", 0) >= 1, st_
    dense_pairs = (st_.get("intersect.materialize_bitset", 0)
                   + st_.get("intersect.materialize_kernel", 0))
    assert dense_pairs + st_.get("intersect.materialize_uint", 0) > 0, st_
    # dense graph, small id range -> the bitset cohort must have fired;
    # under the device backend it must be the Pallas materialize kernel,
    # on numpy the host extraction (the oracle)
    assert dense_pairs > 0, st_
    if backend == "device":
        assert st_.get("intersect.materialize_kernel", 0) > 0, st_
        assert st_.get("intersect.materialize_bitset", 0) == 0, st_
    else:
        assert st_.get("intersect.materialize_kernel", 0) == 0, st_
    got = set(zip(res.columns["x"].tolist(), res.columns["y"].tolist(),
                  res.columns["z"].tolist()))
    want = {(x, y, z)
            for x in range(adj.shape[0]) for y in range(adj.shape[0])
            for z in range(adj.shape[0])
            if adj[x, y] and adj[y, z] and adj[x, z]}
    assert got == want


def test_materialize_bitset_positions_align_with_annotations():
    """The recovered positions index the set-level value array — the same
    contract the search path meets — so annotation gathers stay correct:
    SUM over an annotated triangle listing matches the brute force."""
    src, dst, adj = random_undirected_graph(26, 0.4, 10)
    ann = (np.arange(len(src)) % 7 + 1).astype(np.float64)
    eng = Engine()
    eng.load_edges("Edge", src, dst, annotation=ann)
    for a in ALIASES:
        eng.alias(a, "Edge")
    res = eng.query("C(x,y;w:float) :- R(x,y),S(y,z),T(x,z); "
                    "w=<<SUM(z)>>.")
    st_ = eng.dispatch_summary()
    # per-(x,y) sum of T(x,z) annotations over completing z's
    t = eng.catalog.get("Edge")
    tuples, tann = t.materialize()
    emap = {(int(a_), int(b_)): float(w)
            for (a_, b_), w in zip(tuples, tann)}
    got = {(int(x), int(y)): float(w)
           for x, y, w in zip(res.columns["x"], res.columns["y"],
                              np.asarray(res.annotation))}
    want = {}
    n = adj.shape[0]
    for x in range(n):
        for y in range(n):
            if not adj[x, y]:
                continue
            s = sum(emap[(y, z)] * emap[(x, z)]
                    for z in range(n) if adj[y, z] and adj[x, z])
            if s:
                want[(x, y)] = emap[(x, y)] * s
    assert got == pytest.approx(want)


# ---------------------------------------------------- metadata contract
def test_plan_metadata_reports_search_and_estimation_error():
    src, dst, _ = random_undirected_graph(24, 0.3, 11)
    eng = make_engine(src, dst, plan_search=True)
    eng.query(W.LOLLIPOP)
    md = eng.plan_metadata()[0]
    import json
    json.dumps(md)  # stays artifact-serializable
    ps = md["plan_search"]
    for key in ("candidates", "chosen_cost", "baseline_cost",
                "chosen_order", "baseline_order", "order_changed"):
        assert key in ps
    assert md["est_error"]["n_bags"] >= 1
    assert md["est_error"]["geo_mean_q"] >= 1.0
    assert md["est_cost"] > 0
    for bag in md["bags"]:
        assert bag["cost"] >= 0
        for step in bag["steps"]:
            assert "cost" in step
