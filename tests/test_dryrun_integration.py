"""Integration: the multi-pod dry-run driver lowers + compiles real cells
on the 512-placeholder-device production meshes (subprocess — XLA_FLAGS
must be set before jax init). One small cell per family to keep CI time
bounded; the full 40-cell sweep is `python -m repro.launch.dryrun --all`.
"""
import json
import os
import subprocess
import sys

import pytest

# every case here lowers+compiles against 256-512 placeholder devices
# (~100 s each); CI's fast lane deselects them with -m "not slow"
pytestmark = pytest.mark.slow

CELLS = [
    ("gcn-cora", "full_graph_sm"),
    ("fm", "serve_p99"),
    ("emptyheaded", "triangle_lg"),
]


def run_dryrun(arch, shape, multi=False):
    env = dict(os.environ, PYTHONPATH="src")
    args = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
            "--shape", shape]
    if multi:
        args.append("--multi-pod")
    out = subprocess.run(args, capture_output=True, text=True, env=env,
                         cwd="/root/repo", timeout=900)
    return out


@pytest.mark.parametrize("arch,shape", CELLS)
def test_single_pod_cell(arch, shape):
    out = run_dryrun(arch, shape)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "[OK]" in out.stdout


def test_multi_pod_cell():
    out = run_dryrun("emptyheaded", "triangle_lg", multi=True)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "[OK]" in out.stdout


def test_skip_reason_surfaces():
    out = run_dryrun("qwen2-72b", "long_500k")
    assert out.returncode == 0
    assert "[SKIP]" in out.stdout and "sub-quadratic" in out.stdout


def test_sweep_artifacts_exist():
    """The full-sweep artifacts recorded in experiments/dryrun must cover
    every non-skipped (arch x shape x mesh) cell."""
    d = "experiments/dryrun"
    if not os.path.isdir(d):
        pytest.skip("full sweep not yet run")
    recs = []
    for name in os.listdir(d):
        with open(os.path.join(d, name)) as f:
            recs.append(json.load(f))
    ok = [r for r in recs if r["status"] == "ok"]
    assert len(ok) >= 70  # 37 cells x 2 meshes (40 - 4 skips + engine)
    for r in ok:
        roof = r["roofline"]
        assert roof["bottleneck"] in ("compute", "memory", "collective")
        assert roof["flops"] >= 0 and roof["bytes"] > 0
