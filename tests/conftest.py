"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
the 1 real CPU device; only launch/dryrun.py (a subprocess in tests) forces
512 placeholder devices."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_undirected_graph(n: int, p: float, seed: int = 0):
    """Symmetric edge list (both directions), no self loops."""
    r = np.random.default_rng(seed)
    a = r.random((n, n)) < p
    a = np.triu(a, 1)
    a = a | a.T
    src, dst = np.nonzero(a)
    return src.astype(np.int64), dst.astype(np.int64), a


def brute_triangle_count(adj: np.ndarray) -> int:
    """Count undirected triangles by trace(A^3)/6."""
    a = adj.astype(np.int64)
    return int(np.trace(a @ a @ a) // 6)
