"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
the 1 real CPU device; only launch/dryrun.py (a subprocess in tests) forces
512 placeholder devices."""
import os
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # container image has no hypothesis; use the stub
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute compile-heavy tests (dry-run integration); "
        "deselect with -m 'not slow'")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_undirected_graph(n: int, p: float, seed: int = 0):
    """Symmetric edge list (both directions), no self loops."""
    r = np.random.default_rng(seed)
    a = r.random((n, n)) < p
    a = np.triu(a, 1)
    a = a | a.T
    src, dst = np.nonzero(a)
    return src.astype(np.int64), dst.astype(np.int64), a


def brute_triangle_count(adj: np.ndarray) -> int:
    """Count undirected triangles by trace(A^3)/6."""
    a = adj.astype(np.int64)
    return int(np.trace(a @ a @ a) // 6)
