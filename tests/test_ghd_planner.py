"""GHD planner tests: attribute-order tie-break regressions, the -GHD
(single-bag) ablation parity over the full paper workload, and the
search-budget truncation flag on ``ghd.decompose``."""
import warnings

import numpy as np
import pytest

from conftest import random_undirected_graph
from repro.core import ghd as ghd_mod
from repro.core import workload as W
from repro.core.compile import compile_rule
from repro.core.datalog import parse
from repro.core.engine import Engine
from repro.core.hypergraph import Hypergraph

ALIASES = W.ALIASES


def make_engine(src, dst, backend="numpy", use_ghd=True):
    eng = Engine(backend=backend, use_ghd=use_ghd)
    eng.load_edges("Edge", src, dst)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


# ------------------------------------------------------ attribute ordering
def test_k4_appearance_order_tiebreak_regression():
    """The symmetric K4 query: the global order must follow QUERY-
    APPEARANCE order (x,y,z,a). The old alphabetical tie-break put the
    4th clique vertex 'a' first and cost 7x (Table 8 benchmark)."""
    rule = parse(W.FOUR_CLIQUE).rules[0]
    plan = compile_rule(rule)
    assert plan.order == ("x", "y", "z", "a")
    assert plan.order[0] != "a"


def test_attribute_order_shared_vars_lead_in_child_bags():
    """Within a bag, attributes shared with the parent come first (they
    are bound when the bag runs)."""
    rule = parse(W.BARBELL).rules[0]
    plan = compile_rule(rule)
    for bp in plan.bags_bottom_up():
        k = len(bp.bag.shared_with_parent)
        if k:
            assert set(bp.var_order[:k]) == set(bp.bag.shared_with_parent)


# ------------------------------------------------- -GHD ablation parity
def _digest(res):
    if not res.vars:
        return ("scalar", float(np.asarray(res.annotation)))
    cols = np.stack([np.asarray(res.columns[v]) for v in res.vars], axis=1)
    rows = {tuple(r) for r in cols.tolist()}
    if res.annotation is None:
        return ("rows", frozenset(rows))
    order = np.lexsort(tuple(reversed([np.asarray(res.columns[v])
                                       for v in res.vars])))
    ann = np.asarray(res.annotation, dtype=np.float64)[order]
    return ("annotated", frozenset(rows), tuple(np.round(ann, 5).tolist()))


@pytest.mark.parametrize("backend", ["numpy", "device"])
@pytest.mark.parametrize("qname,query", [
    ("triangle", W.TRIANGLE_COUNT),
    ("triangle_list", W.TRIANGLE_LIST),
    ("4clique", W.FOUR_CLIQUE),
    ("lollipop", W.LOLLIPOP),
    ("barbell", W.BARBELL),
    ("pagerank", W.pagerank_program(iters=3)),
    ("sssp", W.sssp_program("{s}")),
])
def test_single_bag_vs_ghd_parity(qname, query, backend):
    """The GHD plan (early aggregation across bags) and the single-bag
    WCOJ plan (-GHD ablation, the LogicBlox mode) must agree on every
    paper workload query on both backends (paper Section 5.3.1)."""
    src, dst, _ = random_undirected_graph(16, 0.3, 21)
    q = query.replace("{s}", str(int(src[0])))
    r1 = make_engine(src, dst, backend, use_ghd=True).query(q)
    r2 = make_engine(src, dst, backend, use_ghd=False).query(q)
    assert set(r1.vars) == set(r2.vars)
    assert _digest(r1) == _digest(r2)


# ------------------------------------------------- search-budget truncation
def _barbell_hypergraph() -> Hypergraph:
    return Hypergraph.from_rule(parse(W.BARBELL).rules[0])


def test_decompose_search_exhausted_flag_and_warning():
    hg = _barbell_hypergraph()  # 7 hyperedges: Bell(7)=877 partitions
    with pytest.warns(RuntimeWarning, match="GHD search truncated"):
        g = ghd_mod.decompose(hg, max_partitions=5)
    assert g.search_exhausted is True
    # the truncated result is still a valid (if possibly suboptimal) GHD
    assert g.num_bags() >= 1


def test_decompose_full_search_not_exhausted():
    hg = _barbell_hypergraph()
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        g = ghd_mod.decompose(hg)
    assert g.search_exhausted is False
    assert g.width == pytest.approx(1.5)


def test_search_exhausted_surfaces_in_plan_metadata():
    src, dst, _ = random_undirected_graph(12, 0.3, 23)
    eng = make_engine(src, dst)
    eng.query(W.TRIANGLE_COUNT)
    assert eng.plan_metadata()[0]["search_exhausted"] is False
