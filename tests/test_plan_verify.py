"""Plan-IR validator (repro.analysis.plan_verify) tests.

The acceptance gate of the static verification layer: every paper-query
plan and every plan-search candidate passes with ``verify_plans`` on by
default, and the validator REJECTS reconstructions of the shipped bug
classes — PR 3's dropped connector attributes and invalid routing/layout
annotations — as static errors before any tuple moves.
"""
import math

import pytest

from conftest import random_undirected_graph
from repro.analysis import (PlanVerificationError, assert_valid,
                            verify_physical_plan)
from repro.core import workload as W
from repro.core.engine import Engine, verify_plans_enabled
from repro.core.statistics import MAX_THRESHOLD_BITS

PAPER_QUERIES = {
    "triangle_count": W.TRIANGLE_COUNT,
    "triangle_list": W.TRIANGLE_LIST,
    "4clique": W.FOUR_CLIQUE,
    "lollipop": W.LOLLIPOP,
    "barbell": W.BARBELL,
    "pagerank": W.pagerank_program(iters=4),
    "sssp": W.sssp_program("{s}"),
}
SPAN_QUERY = "P(y,a) :- R(x,y),S(y,z),T(x,z),U(x,a)."


def make_engine(src, dst, **kw):
    eng = Engine(backend="numpy", **kw)
    eng.load_edges("Edge", src, dst)
    for a in W.ALIASES:
        eng.alias(a, "Edge")
    return eng


def span_plan(seed=3):
    """A two-bag listing plan (top-down join over a connector attr)."""
    src, dst, _ = random_undirected_graph(16, 0.3, seed)
    eng = make_engine(src, dst)
    eng.query(SPAN_QUERY)
    return eng, eng.last_physical


def triangle_plan(seed=1):
    src, dst, _ = random_undirected_graph(20, 0.3, seed)
    eng = make_engine(src, dst)
    eng.query(PAPER_QUERIES["triangle_count"])
    return eng, eng.last_physical


def codes(violations):
    return {v.code for v in violations}


# ------------------------------------------------------------ happy paths
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_paper_query_plans_validate(qname):
    src, dst, _ = random_undirected_graph(18, 0.3, 7)
    eng = make_engine(src, dst)
    eng.query(PAPER_QUERIES[qname].replace("{s}", str(int(src[0]))))
    if eng.last_physical is not None:
        assert verify_physical_plan(eng.last_physical, eng.catalog,
                                    eng.stats_catalog) == []


def test_verify_on_by_default_and_counted():
    eng, _ = triangle_plan()
    assert eng.verify_plans is True
    st = eng.dispatch_summary()
    assert st.get("analysis.plans_verified", 0) >= 1
    # plan search on by default: every candidate was validated too
    assert st.get("analysis.candidates_verified", 0) >= 1


def test_env_escape_hatch(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY_PLANS", "off")
    assert verify_plans_enabled() is False
    src, dst, _ = random_undirected_graph(12, 0.3, 5)
    eng = make_engine(src, dst)
    assert eng.verify_plans is False
    eng.query(PAPER_QUERIES["triangle_count"])
    assert eng.dispatch_summary().get("analysis.plans_verified", 0) == 0
    monkeypatch.delenv("REPRO_VERIFY_PLANS")
    assert verify_plans_enabled() is True


def test_structural_checks_run_without_catalog():
    """Hand-built plans (no catalog) still get the structural checks."""
    _, pp = triangle_plan()
    assert verify_physical_plan(pp, catalog=None, stats=None) == []


def test_reload_reannotates_and_revalidates():
    """Regression guard for the stale-annotation bug class the ISSUE
    names: a reload must re-plan against fresh statistics (new layout
    thresholds), and the re-annotated plan is re-validated."""
    src1, dst1, _ = random_undirected_graph(20, 0.3, 11)
    src2, dst2, _ = random_undirected_graph(40, 0.08, 5)
    eng = make_engine(src1, dst1)
    eng.query(PAPER_QUERIES["triangle_count"])
    verified1 = eng.dispatch_summary()["analysis.plans_verified"]
    thr1 = eng.last_physical.bag_ops[0].steps[-1].layout_threshold
    eng.load_edges("Edge", src2, dst2)
    eng.query(PAPER_QUERIES["triangle_count"])
    assert eng.dispatch_summary()["analysis.plans_verified"] > verified1
    thr2 = eng.last_physical.bag_ops[0].steps[-1].layout_threshold
    assert thr1 != thr2  # annotations are data-dependent, not pinned
    assert verify_physical_plan(eng.last_physical, eng.catalog,
                                eng.stats_catalog) == []


# --------------------------------------------------- rejected: connectors
def test_dropped_child_connector_rejected():
    """The PR 3 bug class, child side: a connector attribute projected
    out of the child's materialized output."""
    eng, pp = span_plan()
    child = pp.bag_ops[0]
    ci = pp.bag_ops[-1].scan.child_inputs[0]
    assert set(ci.vars) <= set(child.materialize.output_vars)
    child.materialize.output_vars = tuple(
        v for v in child.materialize.output_vars if v not in ci.vars)
    vs = verify_physical_plan(pp, eng.catalog)
    assert "dropped-connector" in codes(vs)
    with pytest.raises(PlanVerificationError, match="dropped-connector"):
        assert_valid(pp, eng.catalog)


def test_dropped_parent_connector_rejected():
    """The PR 3 bug class, parent side: a listing plan whose parent bag
    drops the attribute it shares with a child — the top-down join would
    degenerate into a cross product."""
    eng, pp = span_plan()
    parent = pp.bag_ops[-1]
    ci = parent.scan.child_inputs[0]
    assert pp.final is not None
    parent.materialize.output_vars = tuple(
        v for v in parent.materialize.output_vars if v not in ci.vars)
    assert "dropped-connector" in codes(verify_physical_plan(pp,
                                                             eng.catalog))


# ------------------------------------------------------ rejected: routing
def test_invalid_routing_cohort_rejected():
    eng, pp = triangle_plan()
    fold = pp.bag_ops[0].steps[-1]
    fold.routing = "simd_gather"   # not in plan_ir.FOLD_ROUTINGS
    vs = verify_physical_plan(pp, eng.catalog, eng.stats_catalog)
    assert "routing-invalid" in codes(vs)


def test_pair_routing_without_pair_structure_rejected():
    """'pair_kernel' on a fold that is NOT a binary self-join: the
    runtime would silently fall back, so the annotation is a lie."""
    eng, pp = span_plan()
    from repro.core.plan_ir import Extend, TerminalFold
    bops = pp.bag_ops[-1]
    step = bops.steps[0]
    assert isinstance(step, Extend)
    step.routing = "pair_store"
    vs = verify_physical_plan(pp, eng.catalog)
    assert "routing-invalid" in codes(vs)


def test_threshold_out_of_range_rejected():
    eng, pp = triangle_plan()
    fold = pp.bag_ops[0].steps[-1]
    assert fold.routing == "pair_kernel"
    fold.layout_threshold = 10.0  # below block_bits
    vs = verify_physical_plan(pp, eng.catalog, eng.stats_catalog)
    assert "threshold-range" in codes(vs)
    fold.layout_threshold = MAX_THRESHOLD_BITS * 2.0
    vs = verify_physical_plan(pp, eng.catalog, eng.stats_catalog)
    assert "threshold-range" in codes(vs)


def test_search_routing_with_threshold_rejected():
    eng, pp = triangle_plan()
    fold = pp.bag_ops[0].steps[-1]
    fold.routing = "search"
    assert fold.layout_threshold is not None   # now inconsistent
    vs = verify_physical_plan(pp, eng.catalog, eng.stats_catalog)
    assert "threshold-range" in codes(vs)


# ---------------------------------------------------- rejected: estimates
def test_nonfinite_estimate_rejected():
    eng, pp = triangle_plan()
    pp.bag_ops[0].steps[0].est_rows = float("nan")
    assert "est-invalid" in codes(verify_physical_plan(pp, eng.catalog))


def test_agm_exceeded_rejected():
    eng, pp = triangle_plan()
    m = eng.catalog.get("Edge").num_tuples
    pp.bag_ops[0].steps[-1].est_rows = float(m) ** 3  # >> m^1.5 AGM cap
    vs = verify_physical_plan(pp, eng.catalog)
    assert "agm-exceeded" in codes(vs)
    assert math.isfinite(m ** 1.5)


# ------------------------------------------------- rejected: shape/reuse
def test_wrong_n_constraining_rejected():
    eng, pp = triangle_plan()
    pp.bag_ops[0].steps[0].n_constraining += 1
    assert "step-shape" in codes(verify_physical_plan(pp, eng.catalog))


def test_unconstrained_variable_rejected():
    eng, pp = triangle_plan()
    scan = pp.bag_ops[0].scan
    scan.var_order = scan.var_order + ("phantom",)
    vs = verify_physical_plan(pp, eng.catalog)
    assert vs  # step-shape (count mismatch) at minimum
    assert codes(vs) & {"unconstrained-var", "step-shape"}


def test_incomplete_reuse_rels_rejected():
    """A bag-cache key that omits a relation the bag reads would survive
    reloads of that relation — stale-result hazard."""
    eng, pp = triangle_plan()
    mat = pp.bag_ops[0].materialize
    assert mat.reuse_rels == ("Edge",)
    mat.reuse_rels = ()
    assert "reuse-key" in codes(verify_physical_plan(pp, eng.catalog))


def test_malformed_reuse_struct_rejected():
    eng, pp = triangle_plan()
    pp.bag_ops[0].materialize.reuse_struct = ("not", "canonical")
    assert "reuse-key" in codes(verify_physical_plan(pp, eng.catalog))


# -------------------------------------------------------------- topdown
def test_final_join_input_coverage():
    eng, pp = span_plan()
    pp.final.inputs = pp.final.inputs[:1]  # drop one reduced bag
    vs = verify_physical_plan(pp, eng.catalog)
    assert "unconstrained-var" in codes(vs)


def test_search_candidates_all_validated():
    """plan_search with verify=True validates every candidate, counted
    on the backend stats counter."""
    import collections

    from repro.core import plan_search as ps
    src, dst, _ = random_undirected_graph(16, 0.3, 9)
    eng = make_engine(src, dst)
    plan = eng._compile(__import__("repro.core.datalog",
                                   fromlist=["parse"])
                        .parse(PAPER_QUERIES["4clique"]).rules[0])
    counter = collections.Counter()
    sr = ps.search(plan, eng.stats_catalog, eng.catalog,
                   bag_cache=eng.bag_cache, verify=True, counter=counter)
    assert counter["analysis.candidates_verified"] == sr.candidates
    assert verify_physical_plan(sr.physical, eng.catalog) == []


# ----------------------------------------------------- rejected: sideways
def test_sideways_annotation_invalid_rejected():
    """PR 8: sideways bitset filtering is a VALIDATED annotation — an
    unknown value, or 'bitset' on a step whose counting pass has no
    depth-1 arity-2 probe to intersect block directories for, is a
    static error before any tuple moves."""
    eng, pp = triangle_plan()
    from repro.core.plan_ir import Extend
    first = next(s for s in pp.bag_ops[0].steps if isinstance(s, Extend))
    assert first.sideways is None      # the root extension never has it
    first.sideways = "bloom"           # not in the legal vocabulary
    vs = verify_physical_plan(pp, eng.catalog, eng.stats_catalog)
    assert "sideways-invalid" in codes(vs)
    # 'bitset' on the ROOT extension: every probe is at trie depth 0,
    # so there is no second-level block directory to intersect
    first.sideways = "bitset"
    vs = verify_physical_plan(pp, eng.catalog, eng.stats_catalog)
    assert "sideways-invalid" in codes(vs)
    first.sideways = None
    assert "sideways-invalid" not in codes(
        verify_physical_plan(pp, eng.catalog, eng.stats_catalog))
