"""Runtime layer: optimizers, schedules, train step, grad accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.optim import (adamw, clip_by_global_norm, constant_schedule,
                         cosine_schedule, linear_warmup_cosine, sgd_momentum)
from repro.train import TrainState, make_train_step


@pytest.fixture
def cfg():
    return tfm.TransformerConfig(
        "t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=50, d_head=8, dtype=jnp.float32, q_block=8, kv_block=8)


def batch_at(i, vocab=50, b=4, s=16):
    r = np.random.default_rng(i)
    t = r.integers(0, vocab, (b, s)).astype(np.int32)
    return {"tokens": jnp.asarray(t),
            "targets": jnp.asarray(np.roll(t, -1, 1))}


def test_loss_decreases(cfg):
    p = tfm.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    state = TrainState.create(p, opt).tree()
    step = jax.jit(make_train_step(lambda p, b: tfm.loss_fn(p, b, cfg), opt))
    losses = []
    b = batch_at(0)
    for i in range(12):
        state, m = step(state, b)  # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_grad_accum_equivalence(cfg):
    p = tfm.init(jax.random.PRNGKey(1), cfg)
    opt = adamw(1e-3)
    state = TrainState.create(p, opt).tree()
    step1 = jax.jit(make_train_step(lambda p, b: tfm.loss_fn(p, b, cfg), opt))
    step2 = jax.jit(make_train_step(lambda p, b: tfm.loss_fn(p, b, cfg), opt,
                                    accum_steps=2))
    b = batch_at(7)
    b2 = jax.tree.map(lambda x: x.reshape(2, 2, *x.shape[1:]), b)
    s1, _ = step1(state, b)
    s2, _ = step2(state, b2)
    # AdamW's rsqrt(v)+eps amplifies fp32 summation-order noise at step 0;
    # 5e-3 relative is well inside the single-step update scale (lr=1e-3)
    for a, c in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=5e-3, atol=1e-5)


def test_sgd_momentum_runs(cfg):
    p = tfm.init(jax.random.PRNGKey(2), cfg)
    opt = sgd_momentum(1e-2, 0.9, clip_norm=1.0)
    state = TrainState.create(p, opt).tree()
    step = jax.jit(make_train_step(lambda p, b: tfm.loss_fn(p, b, cfg), opt))
    b = batch_at(3)
    l0 = None
    for i in range(8):
        state, m = step(state, b)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5
    assert abs(float(norm) - np.sqrt(700.0)) < 1e-3
    # below threshold -> unchanged
    unclipped, _ = clip_by_global_norm(g, 1e6)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), 10.0)


def test_schedules():
    c = constant_schedule(0.1)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.1)
    cos = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(cos(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1)
    w = linear_warmup_cosine(1.0, 10, 110, final_frac=0.0)
    assert float(w(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(w(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(w(jnp.asarray(5))) == pytest.approx(0.5)


def test_adamw_bf16_mu(cfg):
    """bf16 first moment halves optimizer bytes but still trains."""
    p = tfm.init(jax.random.PRNGKey(3), cfg)
    opt = adamw(1e-3, mu_dtype=jnp.bfloat16)
    state = TrainState.create(p, opt).tree()
    assert all(m.dtype == jnp.bfloat16
               for m in jax.tree.leaves(state["opt_state"]["mu"]))
    step = jax.jit(make_train_step(lambda p, b: tfm.loss_fn(p, b, cfg), opt))
    b = batch_at(9)
    l0 = None
    for i in range(10):
        state, m = step(state, b)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0


def test_weight_decay_shrinks_params():
    p = {"w": jnp.ones((8,))}
    opt = adamw(1e-1, weight_decay=1.0, clip_norm=None)
    st = opt.init(p)
    zero_g = {"w": jnp.zeros((8,))}
    upd, st, _ = opt.update(zero_g, st, p, jnp.asarray(0))
    new = p["w"] - upd["w"]
    assert float(new[0]) < 1.0
