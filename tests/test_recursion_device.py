"""Device-resident recursion (the PR-5 layer).

Locks the acceptance behaviour:

  * seminaive SSSP and naive PageRank through the DATALOG ENGINE run as
    one jitted device loop under ``DeviceBackend`` — zero host delta-trie
    rebuilds (counter-proven) — with exact result parity against the
    ``NumpyBackend`` host loop (the differential oracle);
  * randomized weighted graphs (self-loops, zero-weight edges,
    disconnected vertices, single-node graphs) keep that parity;
  * the Pallas materialize kernel matches the host bitset extraction
    bit-for-bit and is what the device backend dispatches;
  * plan-search candidate costing builds NO reordered indexes for
    discarded candidates (``reorder_cache.builds``);
  * ``recursion.fixpoint``'s tolerance path checks convergence in blocks
    (device-side diffs, one host sync per block) without changing the
    returned iterate;
  * ``sssp_np`` terminates on pathological inputs (tight Bellman–Ford
    bound + negative-cycle detection).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import random_undirected_graph
from repro.core import workload as W
from repro.core.engine import Engine

ALIASES = W.ALIASES


def make_engine(src, dst, backend, annotation=None, **kw):
    eng = Engine(backend=backend, **kw)
    eng.load_edges("Edge", src, dst, annotation=annotation)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


def assert_same_result(r1, r2, exact_ann=False):
    assert r1.vars == r2.vars
    for v in r1.vars:
        np.testing.assert_array_equal(r1.columns[v], r2.columns[v])
    if r1.annotation is None:
        assert r2.annotation is None
    elif exact_ann:
        np.testing.assert_array_equal(np.asarray(r1.annotation),
                                      np.asarray(r2.annotation))
    else:
        np.testing.assert_allclose(np.asarray(r1.annotation),
                                   np.asarray(r2.annotation),
                                   rtol=1e-6, atol=1e-7)


def random_weighted_digraph(seed: int, n: int):
    """Directed multigraph with the pathological features the device loop
    must survive: self-loops, zero-weight edges, duplicate edges,
    disconnected vertices (ids never drawn). Integer-valued float32
    weights keep min-plus arithmetic exact on both paths."""
    r = np.random.default_rng(seed)
    m = int(r.integers(0, 3 * n + 1))
    src = r.integers(0, n, m)
    dst = r.integers(0, n, m)
    w = r.integers(0, 4, m).astype(np.float32)
    return src, dst, w


# ------------------------------------------------------ engine-loop parity
def test_sssp_device_loop_parity_and_counters():
    src, dst, _ = random_undirected_graph(28, 0.25, 42)
    q = W.sssp_program(int(src[0]))
    e1 = make_engine(src, dst, "numpy")
    e2 = make_engine(src, dst, "device")
    assert_same_result(e1.query(q), e2.query(q), exact_ann=True)
    st1, st2 = e1.dispatch_summary(), e2.dispatch_summary()
    # the oracle rebuilt host tries every round ...
    assert st1["recursion.host_trie_rebuilds"] >= 2
    assert st1.get("recursion.device_rounds", 0) == 0
    # ... the device loop rebuilt NONE (not merely "none after round 1")
    assert st2.get("recursion.host_trie_rebuilds", 0) == 0, st2
    assert st2["recursion.device_fixpoints"] == 1
    assert st2["recursion.device_rounds"] >= 2
    assert st2["recursion.device_rounds"] == st1["recursion.host_rounds"]


def test_pagerank_device_loop_parity_and_counters():
    src, dst, _ = random_undirected_graph(24, 0.3, 23)
    q = W.pagerank_program(iters=8)
    e1 = make_engine(src, dst, "numpy")
    e2 = make_engine(src, dst, "device")
    assert_same_result(e1.query(q), e2.query(q))
    st2 = e2.dispatch_summary()
    assert st2.get("recursion.host_trie_rebuilds", 0) == 0, st2
    assert st2["recursion.device_rounds"] == 8
    md = [m for m in e2.plan_metadata() if "recursion" in m]
    assert md and md[0]["recursion"] == {
        "mode": "device", "strategy": "naive", "rounds": 8}


def test_pagerank_tolerance_device_convergence_on_device():
    """Float-differential convergence (c=eps) must agree round-for-round:
    the device loop checks the diff inside the while_loop, the host loop
    on host — same data, same rounds, same result."""
    src, dst, _ = random_undirected_graph(20, 0.3, 3)
    q = ("N(;w:int) :- Edge(x,y); w=<<COUNT(x)>>.\n"
         "InvDeg(x;y:float) :- Edge(x,z); y=1.0/<<COUNT(z)>>.\n"
         "PageRank(x;y:float) :- Edge(x,z); y=1.0/N.\n"
         "PageRank(x;y:float)*[c=0.0001] :- Edge(x,z),PageRank(z),"
         "InvDeg(z); y=0.15/N+0.85*<<SUM(z)>>.")
    e1 = make_engine(src, dst, "numpy")
    e2 = make_engine(src, dst, "device")
    assert_same_result(e1.query(q), e2.query(q))
    assert (e2.dispatch_summary()["recursion.device_rounds"]
            == e1.dispatch_summary()["recursion.host_rounds"])


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 24))
def test_seminaive_parity_on_random_weighted_graphs(seed, n):
    """Hypothesis sweep: weighted MIN-recursion (annotations ride the
    edge relation) over directed multigraphs with self-loops, zero
    weights, disconnected and single-node cases — device loop must equal
    the numpy host loop EXACTLY."""
    src, dst, w = random_weighted_digraph(seed, n)
    source = int(src[0]) if len(src) else 0
    q = (f"D(x;y:float) :- Edge({source},x); y=1.\n"
         "D(x;y:float)* :- Edge(u,x),D(u); y=<<MIN(u)>>.")
    r1 = make_engine(src, dst, "numpy", annotation=w).query(q)
    e2 = make_engine(src, dst, "device", annotation=w)
    r2 = e2.query(q)
    assert_same_result(r1, r2, exact_ann=True)
    assert e2.dispatch_summary().get("recursion.host_trie_rebuilds", 0) == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 20),
       iters=st.integers(1, 6))
def test_naive_parity_on_random_graphs(seed, n, iters):
    """Hypothesis sweep for the naive (SUM) loop: PageRank over random
    directed multigraphs, fixed iteration counts."""
    src, dst, _w = random_weighted_digraph(seed, n)
    if len(src) == 0:
        return  # PageRank base rules need at least one edge
    q = W.pagerank_program(iters=iters)
    r1 = make_engine(src, dst, "numpy").query(q)
    e2 = make_engine(src, dst, "device")
    r2 = e2.query(q)
    assert_same_result(r1, r2)
    assert e2.dispatch_summary().get("recursion.host_trie_rebuilds", 0) == 0


def test_single_node_self_loop_graph():
    src = np.array([0]); dst = np.array([0])
    q = W.sssp_program(0)
    r1 = make_engine(src, dst, "numpy").query(q)
    r2 = make_engine(src, dst, "device").query(q)
    assert_same_result(r1, r2, exact_ann=True)


# ------------------------------------------------- fallbacks & escape hatch
def test_escape_hatch_pins_host_loop(monkeypatch):
    src, dst, _ = random_undirected_graph(20, 0.25, 7)
    q = W.sssp_program(int(src[0]))
    ref = make_engine(src, dst, "numpy").query(q)
    # constructor flag
    e1 = make_engine(src, dst, "device", device_recursion=False)
    assert_same_result(ref, e1.query(q), exact_ann=True)
    assert e1.dispatch_summary()["recursion.host_trie_rebuilds"] > 0
    # environment variable
    monkeypatch.setenv("REPRO_DEVICE_RECURSION", "off")
    e2 = make_engine(src, dst, "device")
    assert_same_result(ref, e2.query(q), exact_ann=True)
    assert e2.dispatch_summary()["recursion.host_trie_rebuilds"] > 0
    monkeypatch.delenv("REPRO_DEVICE_RECURSION")
    e3 = make_engine(src, dst, "device")
    assert_same_result(ref, e3.query(q), exact_ann=True)
    assert e3.dispatch_summary().get("recursion.host_trie_rebuilds", 0) == 0


def test_non_spmv_shape_falls_back_to_host_loop():
    """A seminaive rule with a unary extra atom is outside the SpMV shape
    the device loop handles: the device engine must fall back to the host
    loop and stay parity-exact."""
    src, dst, _ = random_undirected_graph(18, 0.3, 11)
    allowed = np.unique(src)[::2].astype(np.int64)
    q = (f"SSSP(x;y:int) :- Edge({int(src[0])},x); y=1.\n"
         "SSSP(x;y:int)* :- Edge(w,x),SSSP(w),Allowed(w); y=<<MIN(w)>>+1.")
    engines = []
    for b in ("numpy", "device"):
        eng = make_engine(src, dst, b)
        eng.load_table("Allowed", [allowed])
        engines.append((eng, eng.query(q)))
    (e1, r1), (e2, r2) = engines
    assert_same_result(r1, r2, exact_ann=True)
    assert e2.dispatch_summary()["recursion.host_rounds"] > 0
    assert e2.dispatch_summary().get("recursion.device_fixpoints", 0) == 0


# ------------------------------------------------------ materialize kernel
def _dense_bitset(seed=11, n=60, p=0.3, block_bits=256):
    from repro.core import intersect as I
    from repro.core.layouts import decide_set_level
    from repro.core.trie import CSRGraph
    src, dst, _ = random_undirected_graph(n, p, seed)
    csr = CSRGraph.from_edges(src, dst)
    d = decide_set_level(csr, threshold=4096)
    assert len(d.dense_ids) >= 2
    bs = I.build_blocked_bitset(csr.offsets, csr.neighbors, d.dense_ids,
                                csr.n, block_bits)
    return csr, d, bs


def test_materialize_kernel_matches_host_extraction():
    from repro.core import intersect as I
    from repro.kernels.materialize.ops import bitset_pair_materialize
    csr, d, bs = _dense_bitset()
    rng = np.random.default_rng(3)
    u = d.dense_ids[rng.integers(0, len(d.dense_ids), 40)]
    v = d.dense_ids[rng.integers(0, len(d.dense_ids), 40)]
    want = I.bitset_intersect_materialize(bs, bs.slot_of[u], bs.slot_of[v])
    got = bitset_pair_materialize(bs, bs.slot_of[u], bs.slot_of[v],
                                  interpret=True)
    assert len(got[0]) > 0
    for w_, g_, nm in zip(want, got, ("pair_id", "vals", "rank_a", "rank_b")):
        np.testing.assert_array_equal(g_, w_, err_msg=nm)
    # empty input
    empty = bitset_pair_materialize(bs, bs.slot_of[u][:0], bs.slot_of[v][:0],
                                    interpret=True)
    assert all(len(x) == 0 for x in empty)


def test_materialize_kernel_matches_ref():
    import jax.numpy as jnp
    from repro.kernels.materialize.kernel import bitset_materialize_kernel
    from repro.kernels.materialize.ops import _tri
    from repro.kernels.materialize.ref import bitset_materialize_ref
    rng = np.random.default_rng(0)
    bits_a = jnp.asarray(rng.integers(0, 2, (256, 256)).astype(np.int32))
    bits_b = jnp.asarray(rng.integers(0, 2, (256, 256)).astype(np.int32))
    got = bitset_materialize_kernel(bits_a, bits_b, _tri(256),
                                    interpret=True)
    want = bitset_materialize_ref(bits_a, bits_b)
    for g, w_, nm in zip(got, want, ("band", "rank_a", "rank_b")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_),
                                      err_msg=nm)


def test_device_backend_dispatches_materialize_kernel():
    # plan_search=False: the PR 8 sideways credit steers the searched
    # plan onto the fully-pipelined all-search order on dense graphs;
    # the seed plan still routes the materializing pair_store extend
    src, dst, _ = random_undirected_graph(40, 0.3, 3)
    eng = make_engine(src, dst, "device", plan_search=False)
    eng.query(W.TRIANGLE_LIST)
    st_ = eng.dispatch_summary()
    assert st_.get("intersect.materialize_kernel", 0) > 0, st_
    assert st_.get("intersect.materialize_bitset", 0) == 0, st_


# ------------------------------------------------------ reorder-cache bugfix
def test_plan_search_losers_build_no_reorder_indexes():
    """ROADMAP open item closed: candidate costing profiles from the BASE
    trie, so discarded plans leave no reordered tries in the
    engine-lifetime reorder cache — on data where the old per-candidate
    ``catalog.reordered`` provably built one."""
    from repro.core import plan_ir, plan_search
    from repro.core.datalog import parse
    rng = np.random.default_rng(5)
    src = rng.integers(0, 50, 400)
    dst = rng.integers(0, 50, 400)
    keep = src != dst
    src, dst = src[keep], dst[keep]

    eng = make_engine(src, dst, "numpy", plan_search=True)
    rule = parse(W.LOLLIPOP).rules[0]
    plan = eng._compile(rule)
    sr = plan_search.search(plan, eng.stats_catalog, eng.catalog,
                            bag_cache=eng.bag_cache)
    assert sr.candidates > 1
    assert eng.catalog.reorder_builds == 0, \
        "candidate costing built reorder indexes"
    # teeth: FULL-mode lowering of every candidate does build indexes on
    # this (directed) data — the regression the profile mode prevents
    for cand in plan_search.enumerate_candidates(plan):
        plan_ir.build_physical_plan(cand, eng.stats_catalog, eng.catalog)
    assert eng.catalog.reorder_builds > 0


@pytest.mark.parametrize("backend", ["numpy", "device"])
def test_reorder_counter_in_dispatch_summary(backend):
    src, dst, _ = random_undirected_graph(20, 0.3, 9)
    eng = make_engine(src, dst, backend)
    eng.query(W.TRIANGLE_COUNT)
    st_ = eng.dispatch_summary()
    assert "reorder_cache.builds" in st_ and "reorder_cache.hits" in st_


# ------------------------------------------------------------ fixpoint syncs
def test_fixpoint_tolerance_batched_syncs_and_identical_result():
    import jax.numpy as jnp
    from repro.core.backend import DeviceBackend
    from repro.core.recursion import fixpoint
    b = DeviceBackend()
    c = jnp.array([1.0, 2.0, 3.0, 4.0])

    def step(x):
        return 0.5 * (x + c)

    got = fixpoint(step, jnp.zeros(4), tol=1e-5, backend=b)
    assert b.stats["fixpoint.host_syncs"] >= 1
    assert b.stats["fixpoint.host_syncs"] < b.stats["fixpoint.steps"]
    # per-iteration reference: identical returned iterate
    x = jnp.zeros(4)
    steps = 0
    for _ in range(10_000):
        nx = step(x)
        steps += 1
        if float(jnp.max(jnp.abs(nx - x))) <= 1e-5:
            x = nx
            break
        x = nx
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    assert b.stats["fixpoint.steps"] == steps


def test_fixpoint_fixed_iters_counts_steps():
    from repro.core.backend import NumpyBackend
    from repro.core.recursion import fixpoint
    b = NumpyBackend()
    out = fixpoint(lambda x: x + 1.0, np.float32(0.0), iters=5, backend=b)
    assert float(out) == 5.0
    assert b.stats["fixpoint.steps"] == 5
    assert b.stats.get("fixpoint.host_syncs", 0) == 0


# ------------------------------------------------------------ sssp_np oracle
def test_sssp_np_negative_cycle_raises():
    from repro.core.recursion import sssp_np
    from repro.core.trie import CSRGraph
    csr = CSRGraph.from_edges([0, 1, 2], [1, 2, 0], n=3)
    w = np.array([1.0, -2.0, 0.5], np.float32)
    with pytest.raises(ValueError, match="negative cycle"):
        sssp_np(csr, 0, w)


def test_sssp_np_line_graph_needs_n_minus_1_rounds():
    from repro.core.recursion import sssp_np
    from repro.core.trie import CSRGraph
    n = 12
    line = CSRGraph.from_edges(np.arange(n - 1), np.arange(1, n), n=n)
    np.testing.assert_array_equal(sssp_np(line, 0),
                                  np.arange(n, dtype=np.float32))


def test_sssp_np_negative_weights_without_cycle_ok():
    from repro.core.recursion import sssp_np
    from repro.core.trie import CSRGraph
    # DAG with a negative (non-cycle) edge; weights are CSR-ordered:
    # (0,1)=2, (0,2)=-1.5, (1,2)=1  ->  d(2) = min(-1.5, 2+1)
    csr = CSRGraph.from_edges([0, 1, 0], [1, 2, 2], n=3)
    w = np.array([2.0, -1.5, 1.0], np.float32)
    d = sssp_np(csr, 0, w)
    np.testing.assert_allclose(d, [0.0, 2.0, -1.5])


def test_sssp_np_still_matches_device_sssp():
    from repro.core.recursion import sssp, sssp_np
    from repro.core.trie import CSRGraph
    src, dst, _ = random_undirected_graph(30, 0.15, 29)
    csr = CSRGraph.from_edges(src, dst)
    np.testing.assert_array_equal(sssp_np(csr, int(src[0])),
                                  np.asarray(sssp(csr, int(src[0]))))
