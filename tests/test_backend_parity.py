"""Backend differential testing (the backend-layer invariant): every
paper query must produce identical results on the NumpyBackend (oracle)
and the DeviceBackend (device-resident set store + layout-cohort Pallas
kernels), and the dispatch counters must prove the device backend runs
its intersections through the kernels from inside the GJ loop with at
most one host sync per attribute extension."""
import numpy as np
import pytest

from conftest import brute_triangle_count, random_undirected_graph
from repro.core import workload as W
from repro.core.backend import DeviceBackend, NumpyBackend, make_backend
from repro.core.engine import Engine
from repro.core.layouts import set_engine_layout_mode

ALIASES = W.ALIASES

PAPER_QUERIES = {
    "triangle_count": W.TRIANGLE_COUNT,
    "triangle_list": W.TRIANGLE_LIST,
    "4clique": W.FOUR_CLIQUE,
    "lollipop": W.LOLLIPOP,
    "barbell": W.BARBELL,
    "pagerank": W.pagerank_program(iters=6),
    "sssp": W.sssp_program("{s}"),
}


def make_engine(src, dst, backend, annotation=None):
    eng = Engine(backend=backend)
    eng.load_edges("Edge", src, dst, annotation=annotation)
    for a in ALIASES:
        eng.alias(a, "Edge")
    return eng


def assert_same_result(r1, r2):
    assert r1.vars == r2.vars
    for v in r1.vars:
        np.testing.assert_array_equal(r1.columns[v], r2.columns[v])
    if r1.annotation is None:
        assert r2.annotation is None
    else:
        np.testing.assert_allclose(np.asarray(r1.annotation),
                                   np.asarray(r2.annotation),
                                   rtol=1e-6, atol=1e-7)


# -------------------------------------------------------------- paper queries
@pytest.mark.parametrize("qname", sorted(PAPER_QUERIES))
def test_paper_query_parity(qname):
    src, dst, adj = random_undirected_graph(28, 0.25, 42)
    q = PAPER_QUERIES[qname].replace("{s}", str(int(src[0])))
    r1 = make_engine(src, dst, "numpy").query(q)
    r2 = make_engine(src, dst, "device").query(q)
    assert_same_result(r1, r2)
    if qname == "triangle_count":
        assert int(r1.scalar()) == 6 * brute_triangle_count(adj)


@pytest.mark.parametrize("backend", ["numpy", "device"])
def test_interpreter_vs_codegen_on_backend(backend):
    """Both execution strategies agree on both backends."""
    src, dst, _ = random_undirected_graph(20, 0.3, 7)
    q = PAPER_QUERIES["triangle_count"]
    res = {}
    for use_codegen in (True, False):
        eng = Engine(use_codegen=use_codegen, backend=backend)
        eng.load_edges("Edge", src, dst)
        for a in ("R", "S", "T"):
            eng.alias(a, "Edge")
        res[use_codegen] = int(eng.query(q).scalar())
    assert res[True] == res[False]


# ----------------------------------------------------------------- edge cases
@pytest.mark.parametrize("backend", ["numpy", "device"])
def test_empty_join(backend):
    # a 6-cycle has edges but no triangles
    n = 6
    src = np.array([i for i in range(n)] + [(i + 1) % n for i in range(n)])
    dst = np.array([(i + 1) % n for i in range(n)] + [i for i in range(n)])
    eng = make_engine(src, dst, backend)
    cnt = eng.query(PAPER_QUERIES["triangle_count"])
    assert int(cnt.scalar()) == 0
    lst = eng.query(PAPER_QUERIES["triangle_list"])
    assert lst.num_rows == 0


def test_selection_prefix_parity():
    src, dst, adj = random_undirected_graph(18, 0.3, 5)
    x0 = int(src[0])
    r1 = make_engine(src, dst, "numpy").query(f"Nbr(y) :- Edge({x0},y).")
    r2 = make_engine(src, dst, "device").query(f"Nbr(y) :- Edge({x0},y).")
    assert_same_result(r1, r2)
    assert set(r1.columns["y"].tolist()) == set(adj[x0].nonzero()[0].tolist())
    # empty selection: constant not present in the relation
    for b in ("numpy", "device"):
        res = make_engine(src, dst, b).query("Nbr(y) :- Edge(999,y).")
        assert res.num_rows == 0


def test_annotated_semiring_parity():
    src, dst, _ = random_undirected_graph(16, 0.35, 9)
    w = (np.arange(len(src)) % 5).astype(np.float32) + 0.25
    q = "WS(x;s:float) :- Edge(x,y); s=<<SUM(y)>>."
    r1 = make_engine(src, dst, "numpy", annotation=w).query(q)
    r2 = make_engine(src, dst, "device", annotation=w).query(q)
    assert_same_result(r1, r2)
    # oracle: per-source sum of edge annotations
    want = {}
    for (u, _v), wi in zip(zip(src, dst), w):
        want[int(u)] = want.get(int(u), 0.0) + float(wi)
    got = r1.as_dict()
    assert set(got) == set(want)
    for k in want:
        assert abs(got[k] - want[k]) < 1e-4


# ------------------------------------------------------------- dispatch proof
def test_device_backend_uses_bitset_kernel_in_gj_loop():
    """Dense cohorts (Algorithm 3) must reach the Pallas AND+popcount
    kernel from inside the GJ terminal fold, with ZERO per-extension
    host syncs (the pipeline lands once, before the pair kernel)."""
    src, dst, _ = random_undirected_graph(40, 0.3, 3)  # dense -> bitset
    eng = make_engine(src, dst, "device")
    eng.query(PAPER_QUERIES["triangle_count"])
    st = eng.dispatch_summary()
    assert st.get("intersect.bitset_kernel", 0) > 0, st
    assert st.get("intersect.bitset_jnp", 0) == 0, st
    assert st.get("extend.host_syncs", 0) == 0, st
    assert st.get("extend.closing_syncs", 0) >= 1, st
    assert st["upload.levels"] > 0


def test_device_backend_uses_uint_kernel_in_gj_loop():
    """Relation-level uint mode (the -R ablation) must route the sparse
    cohort through the Pallas membership-test kernel."""
    src, dst, _ = random_undirected_graph(40, 0.3, 3)
    set_engine_layout_mode("uint")
    try:
        eng = make_engine(src, dst, "device")
        eng.query(PAPER_QUERIES["triangle_count"])
        st = eng.dispatch_summary()
    finally:
        set_engine_layout_mode("set")
    assert st.get("intersect.uint_kernel", 0) > 0, st


def test_numpy_backend_never_touches_pallas_kernels():
    """The oracle keeps the seed behaviour: jnp word kernel, search path."""
    src, dst, _ = random_undirected_graph(30, 0.3, 4)
    eng = make_engine(src, dst, "numpy")
    eng.query(PAPER_QUERIES["triangle_count"])
    st = eng.dispatch_summary()
    assert st.get("intersect.bitset_kernel", 0) == 0, st
    assert st.get("intersect.uint_kernel", 0) == 0, st
    # one search round-trip per probe atom >= one per extension
    assert st["extend.host_syncs"] >= st["extend.calls"]


def test_device_uploads_cached_across_queries():
    """Trie levels upload once; the second query reuses resident copies
    (what makes multi-rule/recursive programs stay on device)."""
    src, dst, _ = random_undirected_graph(24, 0.3, 8)
    eng = make_engine(src, dst, "device")
    eng.query(PAPER_QUERIES["triangle_count"])
    first = eng.dispatch_summary().get("upload.levels", 0)
    eng.query(PAPER_QUERIES["triangle_count"])
    second = eng.dispatch_summary().get("upload.levels", 0)
    assert first > 0 and second == first


def test_bitset_pair_count_entry_point_matches_oracle():
    """The batched bitset cohort entry point (kernels ops) agrees with
    the pure-numpy pairwise intersection oracle."""
    from repro.core import intersect as I
    from repro.core.layouts import decide_set_level
    from repro.core.trie import CSRGraph
    from repro.kernels.bitset_intersect.ops import bitset_pair_count

    src, dst, _ = random_undirected_graph(50, 0.3, 21)
    csr = CSRGraph.from_edges(src, dst)
    d = decide_set_level(csr, threshold=4096)  # force a dense cohort
    assert len(d.dense_ids) >= 2
    bs = I.build_blocked_bitset(csr.offsets, csr.neighbors, d.dense_ids,
                                csr.n, 256)
    rng_ = np.random.default_rng(2)
    u = d.dense_ids[rng_.integers(0, len(d.dense_ids), 30)]
    v = d.dense_ids[rng_.integers(0, len(d.dense_ids), 30)]
    got = bitset_pair_count(bs, bs.slot_of[u], bs.slot_of[v],
                            interpret=True)
    want = I.intersect_count_uint_np(csr.offsets, csr.neighbors, u, v)
    np.testing.assert_array_equal(got, want)


def test_pagerank_fixpoint_ell_kernel_under_device_backend():
    """The analytics fixpoint path picks the ELL Pallas kernel under the
    device backend and matches the numpy oracle."""
    from repro.core.recursion import pagerank, pagerank_np
    from repro.core.trie import CSRGraph

    src, dst, _ = random_undirected_graph(24, 0.3, 12)
    csr = CSRGraph.from_edges(src, dst)
    b = DeviceBackend()
    got = pagerank(csr, iters=4, backend=b)
    want = pagerank_np(csr, iters=4)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert b.stats["spmv.ell_kernel"] == 4


# --------------------------------------------------------------- construction
def test_make_backend_resolution(monkeypatch):
    assert isinstance(make_backend("numpy"), NumpyBackend)
    assert isinstance(make_backend("device"), DeviceBackend)
    b = DeviceBackend()
    assert make_backend(b) is b
    monkeypatch.setenv("REPRO_ENGINE_BACKEND", "device")
    assert isinstance(make_backend(None), DeviceBackend)
    monkeypatch.delenv("REPRO_ENGINE_BACKEND")
    assert isinstance(make_backend(None), NumpyBackend)
    with pytest.raises(ValueError):
        make_backend("tpu9000")
