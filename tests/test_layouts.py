"""Layout optimizer + set-intersection properties (paper §4), with
hypothesis property tests on the core invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import intersect as I
from repro.core.layouts import (HybridSetStore, decide_relation_level,
                                decide_set_level, set_ranges)
from repro.core.trie import CSRGraph
from repro.kernels.bitset_intersect.ops import as_word_kernel


def random_csr(n, mean_deg, seed):
    rng = np.random.default_rng(seed)
    deg = rng.poisson(mean_deg, n)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, len(src))
    keep = src != dst
    return CSRGraph.from_edges(src[keep], dst[keep], n=n)


# ------------------------------------------------------------- decision rule
def test_algorithm3_rule():
    """bitset iff range/|S| < SIMD width (paper Algorithm 3)."""
    # dense set: 0..99 complete -> inverse density 1
    src = np.zeros(100, np.int64)
    dst = np.arange(100)
    csr = CSRGraph.from_edges(src, dst, n=100)
    d = decide_set_level(csr, threshold=256)
    assert 0 in d.dense_ids
    # sparse set: two values 10^6 apart
    csr2 = CSRGraph.from_edges(np.zeros(2, np.int64),
                               np.array([0, 10**6]), n=10**6 + 1)
    d2 = decide_set_level(csr2, threshold=256)
    assert 0 in d2.sparse_ids


def test_set_ranges(rng):
    csr = random_csr(50, 4, 0)
    r = set_ranges(csr)
    for u in range(csr.n):
        nb = csr.neighbors_of(u)
        want = (nb.max() - nb.min() + 1) if len(nb) else 0
        assert r[u] == want


def test_relation_level_is_all_one_layout():
    csr = random_csr(40, 3, 1)
    d = decide_relation_level(csr, force="uint")
    assert len(d.dense_ids) == 0


# ----------------------------------------------------- intersection oracles
@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 120), mean=st.floats(1, 12),
       seed=st.integers(0, 10_000), threshold=st.sampled_from([64, 256, 4096]))
def test_hybrid_store_matches_numpy(n, mean, seed, threshold):
    """Routing through any layout combination preserves exact counts —
    the system invariant behind the paper's Table 4 study."""
    csr = random_csr(n, mean, seed)
    rng = np.random.default_rng(seed + 1)
    u = rng.integers(0, n, 50)
    v = rng.integers(0, n, 50)
    store = HybridSetStore.build(csr, threshold=threshold)
    got = store.intersect_count(u, v)
    want = I.intersect_count_uint_np(csr.offsets, csr.neighbors, u, v)
    np.testing.assert_array_equal(got, want)


def test_engine_layout_modes_agree():
    """The engine's terminal fold routed through set/uint/off layout modes
    must produce identical counts (the -R ablation's invariant)."""
    from repro.core.engine import Engine
    from repro.core.layouts import set_engine_layout_mode

    rng = np.random.default_rng(9)
    n = 60
    a = rng.random((n, n)) < 0.2
    a = np.triu(a, 1)
    a = a | a.T
    src, dst = np.nonzero(a)
    counts = {}
    try:
        for mode in ("set", "uint", "off"):
            set_engine_layout_mode(mode)
            eng = Engine()
            eng.load_edges("Edge", src, dst)
            for al in ("R", "S", "T"):
                eng.alias(al, "Edge")
            counts[mode] = int(eng.query(
                "T(;w:long) :- R(x,y),S(y,z),T(x,z); w=<<COUNT(*)>>.")
                .scalar())
    finally:
        set_engine_layout_mode("set")
    assert counts["set"] == counts["uint"] == counts["off"]


def test_hybrid_store_with_pallas_kernel():
    csr = random_csr(200, 8, 3)
    rng = np.random.default_rng(4)
    u = rng.integers(0, 200, 100)
    v = rng.integers(0, 200, 100)
    store = HybridSetStore.build(csr,
                                 word_kernel=as_word_kernel(interpret=True))
    got = store.intersect_count(u, v)
    want = I.intersect_count_uint_np(csr.offsets, csr.neighbors, u, v)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(sa=st.integers(0, 60), sb=st.integers(0, 60), hi=st.integers(64, 2000),
       seed=st.integers(0, 1000))
def test_segment_search_min_property_oracle(sa, sb, hi, seed):
    """The lockstep search intersection equals numpy for arbitrary pairs."""
    rng = np.random.default_rng(seed)
    a = np.sort(rng.choice(hi, min(sa, hi), replace=False)).astype(np.int32)
    b = np.sort(rng.choice(hi, min(sb, hi), replace=False)).astype(np.int32)
    values = np.concatenate([a, b])
    offsets = np.array([0, len(a), len(a) + len(b)], dtype=np.int64)
    got = I.intersect_count_uint(offsets, values, np.array([0]),
                                 np.array([1]))[0]
    assert got == len(np.intersect1d(a, b))


def test_blocked_bitset_roundtrip(rng):
    csr = random_csr(80, 6, 5)
    ids = np.flatnonzero(csr.degrees > 0)[:20]
    bs = I.build_blocked_bitset(csr.offsets, csr.neighbors, ids, csr.n, 256)
    # popcount of all blocks of set i == degree(i) (sets are deduped)
    card = I.popcount_u32_np(bs.words).sum(axis=1)
    for slot, nid in enumerate(ids):
        lo, hi = bs.offsets[slot], bs.offsets[slot + 1]
        assert card[lo:hi].sum() == len(np.unique(csr.neighbors_of(nid)))


def test_uint_bitset_cross_layout(rng):
    csr = random_csr(100, 10, 6)
    d = decide_set_level(csr, threshold=4096)  # force many dense
    if len(d.dense_ids) == 0 or len(d.sparse_ids) == 0:
        pytest.skip("degenerate split")
    bs = I.build_blocked_bitset(csr.offsets, csr.neighbors, d.dense_ids,
                                csr.n, 256)
    u = d.sparse_ids[:10]
    v = d.dense_ids[:10][:len(u)]
    u = u[:len(v)]
    got = I.uint_bitset_intersect_count(csr.offsets, csr.neighbors, u, bs,
                                        bs.slot_of[v])
    want = I.intersect_count_uint_np(csr.offsets, csr.neighbors, u, v)
    np.testing.assert_array_equal(got, want)
