"""Kernel contract checker + REPRO_SANITIZE dispatch-mode tests."""
import jax
import numpy as np
import pytest

from conftest import random_undirected_graph
from repro.analysis import kernel_check
from repro.analysis.kernel_check import (CapturedCall, KernelContractError,
                                         SanitizeError, check_captured,
                                         check_dispatch)
from repro.core import workload as W
from repro.core.engine import Engine, sanitize_enabled


def make_engine(src, dst, **kw):
    eng = Engine(backend="numpy", **kw)
    eng.load_edges("Edge", src, dst)
    for a in W.ALIASES:
        eng.alias(a, "Edge")
    return eng


# ------------------------------------------------------- static contracts
def test_all_kernel_contracts_pass():
    counts = kernel_check.check_all()
    assert set(counts) == {"uint_intersect", "bitset_intersect",
                           "materialize", "frontier_fill"}
    assert all(n >= 1 for n in counts.values())


def _spec(block, index_map):
    import jax.experimental.pallas as pl
    return pl.BlockSpec(block, index_map)


def _call(grid, in_specs, operands, out_specs, out_shape):
    return CapturedCall(kernel_name="fake", grid=grid, in_specs=in_specs,
                        out_specs=out_specs, out_shape=out_shape,
                        operands=operands)


def test_non_tiling_blockspec_rejected():
    rec = _call(
        grid=(2,),
        in_specs=[_spec((3, 8), lambda i: (i, 0))],   # 3 does not tile 8
        operands=[jax.ShapeDtypeStruct((8, 8), np.int32)],
        out_specs=[_spec((4, 8), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((8, 8), np.int32)])
    with pytest.raises(KernelContractError, match="does not tile"):
        check_captured("fake", rec)


def test_index_map_out_of_bounds_rejected():
    rec = _call(
        grid=(4,),                                    # 4 steps, 2 blocks
        in_specs=[_spec((4, 8), lambda i: (i, 0))],
        operands=[jax.ShapeDtypeStruct((8, 8), np.int32)],
        out_specs=[_spec((4, 8), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((8, 8), np.int32)])
    with pytest.raises(KernelContractError, match="out of bounds"):
        check_captured("fake", rec)


def test_uncovered_output_block_rejected():
    rec = _call(
        grid=(2,),
        in_specs=[_spec((4, 8), lambda i: (i, 0))],
        operands=[jax.ShapeDtypeStruct((8, 8), np.int32)],
        out_specs=[_spec((4, 8), lambda i: (0, 0))],  # never writes block 1
        out_shape=[jax.ShapeDtypeStruct((8, 8), np.int32)])
    with pytest.raises(KernelContractError, match="never writes"):
        check_captured("fake", rec)


def test_spec_operand_count_mismatch_rejected():
    rec = _call(
        grid=(1,),
        in_specs=[],
        operands=[jax.ShapeDtypeStruct((8,), np.int32)],
        out_specs=[_spec((8,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((8,), np.int32)])
    with pytest.raises(KernelContractError, match="in_specs"):
        check_captured("fake", rec)


def test_vmapped_frontier_fill_divergence_pinned():
    """Satellite contract: ``jax.vmap`` over the frontier-fill launch
    keeps per-lane values bit-exact BUT rewrites the launch geometry
    away from the declared contract (grid (1,) -> (B, 1), Mapped block
    dims, mixed-rank blocks).  This pins the exact divergence — it is
    why ``_bag_program_batch`` pins ``fill_mode="jnp"``.  If a jax
    upgrade makes this check pass, this test fails and the pin should
    be revisited."""
    from repro.analysis.kernel_check import KernelVmapDivergence
    from repro.kernels.frontier_fill import ops as ff

    with pytest.raises(KernelVmapDivergence) as ei:
        kernel_check.check_vmap_contract(ff.CONTRACT_VMAP)
    msg = str(ei.value)
    assert f"(1,) -> ({ff._CONTRACT_BATCH}, 1)" in msg
    assert "Mapped" in msg
    assert "values match the oracle" in msg


def test_vmapped_frontier_fill_parity_is_exact():
    """The parity half alone: batched launch output equals the per-lane
    oracle bit-for-bit (KernelContractError, not just the geometry
    divergence, would mean broken semantics)."""
    import numpy as onp

    from repro.kernels.frontier_fill import ops as ff

    inputs = ff.CONTRACT_VMAP["make_inputs"]()
    jax.clear_caches()
    out = ff.CONTRACT_VMAP["entry"](*inputs)
    ref = ff.CONTRACT_VMAP["ref"](*inputs)
    assert len(out) == len(ref)
    for g, r in zip(out, ref):
        assert onp.array_equal(onp.asarray(g), onp.asarray(r))
    # lanes are genuinely distinct — parity is not vacuous
    keep = onp.asarray(out[3])
    assert any(not onp.array_equal(keep[0], keep[b])
               for b in range(1, keep.shape[0]))


def test_contract_oracle_mismatch_rejected():
    """A contract whose entry disagrees with its oracle must fail."""
    from repro.kernels.uint_intersect import ops as uops
    bad = dict(uops.CONTRACT)
    # right shape/dtype, wrong values — the numeric cross-check must fire
    bad["ref"] = lambda a, b: jax.numpy.zeros((np.shape(a)[0],),
                                              jax.numpy.int32)
    with pytest.raises(KernelContractError, match="oracle"):
        kernel_check.check_contract(bad)


# --------------------------------------------------------- runtime checks
def test_sanitize_engine_run_both_routings():
    src, dst, _ = random_undirected_graph(20, 0.3, 3)
    eng = make_engine(src, dst, sanitize=True)
    eng.query(W.TRIANGLE_COUNT)          # pair_kernel fold
    eng.query("P(y,a) :- R(x,y),S(y,z),T(x,z),U(x,a).")  # listing + topdown
    st = eng.dispatch_summary()
    assert st.get("analysis.sanitize_checks", 0) >= 2


def test_sanitize_off_by_default():
    assert sanitize_enabled() is False
    src, dst, _ = random_undirected_graph(12, 0.3, 3)
    eng = make_engine(src, dst)
    assert eng.sanitize is False
    eng.query(W.TRIANGLE_COUNT)
    assert eng.dispatch_summary().get("analysis.sanitize_checks", 0) == 0


def test_sanitize_env_resolution(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled() is True
    src, dst, _ = random_undirected_graph(12, 0.3, 3)
    eng = make_engine(src, dst)
    assert eng.sanitize is True
    eng.query(W.TRIANGLE_COUNT)
    assert eng.dispatch_summary().get("analysis.sanitize_checks", 0) >= 1


def _path_plan():
    """A plan with NO pair routing anywhere (2-atom path join)."""
    src, dst, _ = random_undirected_graph(16, 0.3, 5)
    eng = make_engine(src, dst)
    eng.query("P(x,z) :- R(x,y),S(y,z).")
    pp = eng.last_physical
    from repro.core.plan_ir import Extend, TerminalFold
    assert not any(
        (isinstance(s, TerminalFold) and s.routing == "pair_kernel")
        or (isinstance(s, Extend) and s.routing == "pair_store")
        for b in pp.bag_ops for s in b.steps)
    return pp


def test_fabricated_pair_dispatch_raises():
    """The sanitizer's core assertion: pair-cohort kernels must not fire
    on a plan that never routed to them."""
    pp = _path_plan()
    with pytest.raises(SanitizeError, match="pair-cohort"):
        check_dispatch(pp, {"fold.pair_count_calls": 2}, {}, "numpy")
    with pytest.raises(SanitizeError, match="pair-store"):
        check_dispatch(pp, {"extend.pair_materialize_calls": 1}, {},
                       "numpy")


def test_sync_budget_violation_raises():
    pp = _path_plan()
    # device backend: at most ONE host sync per fused extension call
    with pytest.raises(SanitizeError, match="host syncs exceed"):
        check_dispatch(pp, {"extend.calls": 2, "extend.host_syncs": 3},
                       {}, "device")
    # within budget: fine
    check_dispatch(pp, {"extend.calls": 2, "extend.host_syncs": 2},
                   {}, "device")
    # numpy oracle: one per probe atom — budget scales with bag width
    check_dispatch(pp, {"extend.calls": 2, "extend.host_syncs": 2},
                   {}, "numpy")


def test_missing_fold_dispatch_raises():
    src, dst, _ = random_undirected_graph(16, 0.3, 5)
    eng = make_engine(src, dst)
    eng.query(W.TRIANGLE_COUNT)
    pp = eng.last_physical
    op_id = pp.bag_ops[0].materialize.op_id
    metrics = {op_id: {"actual_rows": 5, "level_actuals": []}}
    with pytest.raises(SanitizeError, match="fold.calls"):
        check_dispatch(pp, {"extend.calls": 2, "extend.host_syncs": 2},
                       metrics, "numpy")
    # cached bag (no level_actuals): no fold demanded
    check_dispatch(pp, {}, {op_id: {"actual_rows": 5}}, "numpy")
