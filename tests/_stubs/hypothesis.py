"""Minimal stand-in for the ``hypothesis`` API this suite uses, loaded by
conftest.py ONLY when the real package is absent (the container image does
not ship it and installing deps is off-limits).

Covers: ``@given`` with keyword strategies, ``@settings(max_examples,
deadline)``, and ``strategies.{integers, floats, lists, text,
sampled_from}``. Each decorated test runs ``max_examples`` times with
inputs drawn from a per-test deterministic PRNG (seeded from the test
name), so runs are reproducible. No shrinking, no database — a failing
example's kwargs are attached to the assertion via exception notes.
"""
from __future__ import annotations

import random
import string
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value=0, max_value=1 << 30):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(r):
        return [elements.draw(r) for _ in range(r.randint(min_size, hi))]

    return _Strategy(draw)


_ALPHABET = string.ascii_letters + string.digits + "_-. é√"


def text(min_size=0, max_size=10):
    def draw(r):
        return "".join(r.choice(_ALPHABET)
                       for _ in range(r.randint(min_size, max_size)))

    return _Strategy(draw)


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, lists=lists, text=text,
    sampled_from=sampled_from)


def settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        # NOTE: signature intentionally (*args, **kwargs) and no
        # __wrapped__, so pytest does not mistake drawn names for fixtures.
        def runner(*args, **kwargs):
            cfg = getattr(runner, "_stub_settings", None) \
                or getattr(fn, "_stub_settings", {})
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(cfg.get("max_examples", 10)):
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    if hasattr(e, "add_note"):  # py3.11+
                        e.add_note(f"falsifying example: {drawn}")
                    raise

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__qualname__ = fn.__qualname__
        runner.pytestmark = list(getattr(fn, "pytestmark", []))
        return runner

    return deco
