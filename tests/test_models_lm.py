"""LM transformer tests: every attention/FFN variant fwd+bwd, flash vs
naive attention equivalence, prefill/decode == full-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm


def tiny(name, **kw):
    base = dict(name=name, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=97, d_head=16, dtype=jnp.float32,
                q_block=8, kv_block=8)
    base.update(kw)
    return tfm.TransformerConfig(**base)


VARIANTS = {
    "dense": tiny("dense"),
    "qkv_bias": tiny("qkv_bias", qkv_bias=True),
    "swa": tiny("swa", attention="swa", window=6),
    "moe": tiny("moe", n_experts=4, top_k=2),
    "moe_dense_residual": tiny("moe_dense_residual", n_experts=4, top_k=2,
                               dense_residual=True),
    "mla": tiny("mla", attention="mla", n_kv_heads=4, q_lora_rank=32,
                kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                v_head_dim=16),
}


@pytest.mark.parametrize("variant", list(VARIANTS))
def test_forward_backward(variant):
    cfg = VARIANTS[variant]
    key = jax.random.PRNGKey(0)
    p = tfm.init(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    logits, aux = tfm.forward(p, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert jnp.isfinite(logits).all()
    batch = {"tokens": toks, "targets": toks}
    loss, _ = tfm.loss_fn(p, batch, cfg)
    grads = jax.grad(lambda p: tfm.loss_fn(p, batch, cfg)[0])(p)
    assert jnp.isfinite(loss)
    for g in jax.tree.leaves(grads):
        assert jnp.isfinite(g).all()


@pytest.mark.parametrize("variant", list(VARIANTS))
def test_param_axes_matches_params(variant):
    cfg = VARIANTS[variant]
    p = jax.eval_shape(lambda k: tfm.init(k, cfg), jax.random.PRNGKey(0))
    ax = tfm.param_axes(cfg)
    flat_p = jax.tree_util.tree_flatten_with_path(p)[0]
    flat_a = jax.tree_util.tree_flatten_with_path(
        ax, is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))[0]
    assert len(flat_p) == len(flat_a)
    paths_p = {tuple(str(x) for x in k) for k, _ in flat_p}
    paths_a = {tuple(str(x) for x in k) for k, _ in flat_a}
    assert paths_p == paths_a
    for (kp, leaf), (ka, axes) in zip(sorted(flat_p, key=lambda t: str(t[0])),
                                      sorted(flat_a, key=lambda t: str(t[0]))):
        assert len(axes) == leaf.ndim, (kp, axes, leaf.shape)


def naive_attention(q, k, v, causal, window, scale):
    b, s, h, hd = q.shape
    g = k.shape[2]
    kk = jnp.repeat(k, h // g, axis=2)
    vv = jnp.repeat(v, h // g, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * scale
    i, j = jnp.meshgrid(jnp.arange(s), jnp.arange(s), indexing="ij")
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i >= j
    if window is not None:
        mask &= i - j < window
    sc = jnp.where(mask[None, None], sc, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vv)


@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("qb,kb", [(8, 8), (4, 16), (24, 24), (7, 9)])
def test_flash_vs_naive(window, qb, kb):
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    B, S, H, G, hd = 2, 24, 4, 2, 16
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, G, hd))
    v = jax.random.normal(k3, (B, S, G, hd))
    got = tfm.flash_attention(q, k, v, causal=True, window=window,
                              q_offset=0, q_block=qb, kv_block=kb,
                              scale=0.25)
    want = naive_attention(q, k, v, True, window, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("variant", ["dense", "qkv_bias", "swa"])
def test_prefill_decode_match_forward(variant):
    cfg = VARIANTS[variant]
    key = jax.random.PRNGKey(2)
    p = tfm.init(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    full, _ = tfm.forward(p, toks, cfg)
    lg, cache = tfm.prefill(p, toks[:, :8], cfg, max_len=16)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 7]),
                               rtol=2e-3, atol=2e-3)
    for t in range(8, 12):
        lg, cache = tfm.decode_step(p, cache, toks[:, t:t + 1], cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=5e-3, atol=5e-3)


def test_mla_decode_matches_forward():
    cfg = VARIANTS["mla"]
    key = jax.random.PRNGKey(3)
    p = tfm.init(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    full, _ = tfm.forward(p, toks, cfg)
    lg, cache = tfm.prefill(p, toks[:, :8], cfg, max_len=16)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 7]),
                               rtol=5e-3, atol=5e-3)
    for t in range(8, 12):
        lg, cache = tfm.decode_step_mla(p, cache, toks[:, t:t + 1], cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=1e-2, atol=1e-2)


def test_mla_cache_is_compressed():
    """The MLA cache must store the latent, not full K/V — the memory win
    that motivates MLA."""
    cfg = VARIANTS["mla"]
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 2, 16))
    full_kv_floats = 2 * cfg.n_layers * 2 * 16 * cfg.n_heads \
        * (cfg.qk_nope_dim + cfg.qk_rope_dim)
    lat_floats = sum(int(np.prod(v.shape)) for k, v in cache.items()
                     if k != "len")
    assert lat_floats < full_kv_floats / 3


def test_swa_cache_is_windowed():
    cfg = VARIANTS["swa"]
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 2, 512))
    assert cache["k"].shape[2] == cfg.window  # rolling window, not 512


def test_moe_load_balance_aux_positive():
    cfg = VARIANTS["moe"]
    p = tfm.init(jax.random.PRNGKey(4), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
    _, aux = tfm.forward(p, toks, cfg)
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, = E at uniform


def test_param_count_matches_init():
    for cfg in VARIANTS.values():
        p = jax.eval_shape(lambda k: tfm.init(k, cfg), jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
        analytic = cfg.param_count()
        # analytic excludes norm gammas; allow 2% slack
        assert abs(actual - analytic) / actual < 0.02, cfg.name
