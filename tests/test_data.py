"""Data substrate tests: generators, orderings, pruning, sampler, pipelines."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (NeighborSampler, RecsysBatchGen, TokenPipeline,
                        kronecker_graph, molecule_batch, powerlaw_graph)
from repro.graph import (ORDERINGS, apply_ordering, density_skew,
                         graph_stats, order_nodes, prune_symmetric)
from repro.graph.dictionary import Dictionary, encode_edges


def test_powerlaw_graph_structure():
    g = powerlaw_graph(500, 8, 2.0, seed=1)
    assert g.n == 500
    # symmetric: every edge has its reverse
    src = np.repeat(np.arange(g.n), g.degrees)
    fwd = set(zip(src.tolist(), g.neighbors.tolist()))
    assert all((v, u) in fwd for (u, v) in list(fwd)[:200])
    # no self loops
    assert all(u != v for (u, v) in list(fwd)[:500])


def test_kronecker_graph():
    g = kronecker_graph(8, 8, seed=2)
    assert g.n == 256 and g.m > 0
    s = graph_stats(g)
    assert s["max_degree"] > s["mean_degree"]  # skewed


@pytest.mark.parametrize("method", sorted(ORDERINGS))
def test_ordering_is_permutation(method):
    g = powerlaw_graph(300, 6, 2.2, seed=3)
    perm = order_nodes(g, method, seed=0)
    assert sorted(perm.tolist()) == list(range(g.n))
    g2 = apply_ordering(g, perm)
    assert g2.m == g.m
    # degree multiset preserved
    assert sorted(g.degrees.tolist()) == sorted(g2.degrees.tolist())


def test_degree_ordering_sorts_by_degree():
    g = powerlaw_graph(200, 6, 2.0, seed=4)
    perm = order_nodes(g, "degree")
    g2 = apply_ordering(g, perm)
    d = g2.degrees
    assert (np.diff(d) <= 0).all() or (np.sort(d)[::-1] == d).all()


def test_prune_halves_symmetric_edges():
    g = powerlaw_graph(200, 6, 2.0, seed=5)
    p = prune_symmetric(g)
    assert p.m * 2 == g.m
    src = np.repeat(np.arange(p.n), p.degrees)
    assert (src > p.neighbors).all()


def test_density_skew_orders():
    low = powerlaw_graph(500, 8, 3.0, seed=6)   # flatter
    high = powerlaw_graph(500, 8, 1.7, seed=6)  # heavier tail
    assert density_skew(high) != density_skew(low)


def test_sampler_shapes_and_membership():
    g = powerlaw_graph(400, 8, 2.0, seed=7)
    s = NeighborSampler(g, (5, 3), seed=0)
    batch = s.sample(np.arange(32))
    assert batch.blocks[0].nodes.shape == (32 * 5,)
    assert batch.blocks[1].nodes.shape == (32 * 5 * 3,)
    # sampled hop-1 nodes are real neighbors (or self for deg-0)
    for i, seed_node in enumerate(batch.seeds[:8]):
        nbrs = set(g.neighbors_of(int(seed_node)).tolist()) | {int(seed_node)}
        got = set(batch.blocks[0].nodes[i * 5:(i + 1) * 5].tolist())
        assert got <= nbrs


def test_token_pipeline_deterministic():
    p = TokenPipeline(1000, 4, 16, seed=3)
    a = p.batch_at(7)
    b = p.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 1000
    # restore reproduces the stream (checkpoint/restart invariant)
    p2 = TokenPipeline.restore(p.state(7), 1000, 4, 16)
    np.testing.assert_array_equal(p2.batch_at(7)["tokens"], a["tokens"])


def test_recsys_batchgen():
    g = RecsysBatchGen(39, 10_000, 64, seed=1)
    b = g.batch_at(0)
    assert b["ids"].shape == (64, 39) and b["ids"].max() < 10_000
    assert set(np.unique(b["label"])) <= {0.0, 1.0}
    np.testing.assert_array_equal(b["ids"], g.batch_at(0)["ids"])


def test_molecule_batch_edges_within_cutoff():
    pos, sp, snd, rcv, mask = molecule_batch(3, cutoff=5.0, seed=2)
    for b in range(3):
        for e in range(snd.shape[1]):
            if mask[b, e]:
                d = np.linalg.norm(pos[b, snd[b, e]] - pos[b, rcv[b, e]])
                assert d < 5.0


@settings(max_examples=10, deadline=None)
@given(vals=st.lists(st.text(min_size=1, max_size=5), min_size=1,
                     max_size=50))
def test_dictionary_roundtrip(vals):
    d = Dictionary.build(vals)
    enc = d.encode(vals)
    assert d.decode(enc) == list(vals)
    assert enc.max() < d.size


def test_encode_edges():
    src = ["a", "b", "a"]
    dst = ["b", "c", "c"]
    s, t, d = encode_edges(src, dst)
    assert len(s) == 3 and d.size == 3
    assert d.decode(s) == src and d.decode(t) == dst
