"""LM token pipeline.

A deterministic, checkpointable synthetic token stream (the container has no
corpora): per-step batches are derived from (seed, step), so restoring a
checkpoint at step k reproduces the exact stream — the property the
fault-tolerance layer needs. Swap ``_synth`` for a real tokenizer-backed
reader in production; the interface (``batch(step)``) is unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int):
        """Returns dict(tokens [B, S] int32, targets [B, S] int32)."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        # Zipf-distributed ids resemble natural token frequencies.
        toks = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(toks, self.vocab_size - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": step}

    @staticmethod
    def restore(state: dict, vocab_size: int, batch: int, seq_len: int
                ) -> "TokenPipeline":
        return TokenPipeline(vocab_size, batch, seq_len, state["seed"])
