"""Synthetic graph generators.

The paper's datasets (LiveJournal, Twitter, ...) are not available offline;
benchmarks run on synthetic graphs matched to the paper's density-skew
regimes: power-law (configurable exponent, as in App. C.2.1's Snap
generator study) and Kronecker (RMAT-style, models real social-graph
structure). ``molecule_batch`` builds batched small radius graphs for the
molecular GNN archs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.trie import CSRGraph
from repro.graph.prune import symmetrize


def powerlaw_graph(n: int, mean_deg: float = 8.0, exponent: float = 2.0,
                   seed: int = 0) -> CSRGraph:
    """Chung-Lu style power-law graph: P(edge ij) ∝ w_i w_j with
    w_i ~ i^{-1/(exponent-1)} (undirected, deduped, no self-loops)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-1.0 / (exponent - 1.0))
    w *= (mean_deg * n / 2) / w.sum()
    p = w / w.sum()
    m = int(mean_deg * n / 2)
    src = rng.choice(n, size=m, p=p)
    dst = rng.choice(n, size=m, p=p)
    return symmetrize(src, dst, n=n)


def kronecker_graph(scale: int, edge_factor: int = 16,
                    a: float = 0.57, b: float = 0.19, c: float = 0.19,
                    seed: int = 0) -> CSRGraph:
    """RMAT/Kronecker generator (Graph500 parameters by default)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for lvl in range(scale):
        r = rng.random(m)
        # quadrant probabilities (a | b / c | d)
        go_right = r > (a + c)          # dst high bit
        r2 = rng.random(m)
        thresh = np.where(go_right, b / (a + b + 1e-12), a / (a + c + 1e-12))
        # recompute: P(src high | dst side)
        go_down = np.where(go_right, r2 < c / (b + (1 - a - b - c) + 1e-12),
                           r2 < c / (a + c + 1e-12))
        src |= go_down.astype(np.int64) << lvl
        dst |= go_right.astype(np.int64) << lvl
    return symmetrize(src, dst, n=n)


def random_features(n: int, d: int, seed: int = 0,
                    dtype=np.float32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(scale=1.0 / np.sqrt(d), size=(n, d)).astype(dtype)


def molecule_batch(batch: int, n_nodes: int = 30, n_edges: int = 64,
                   cutoff: float = 5.0, seed: int = 0):
    """Batched small molecular graphs with 3D positions (for DimeNet /
    NequIP / MACE shapes): returns (positions [B,N,3], species [B,N],
    senders [B,E], receivers [B,E], edge mask [B,E]).

    Edges are the ``n_edges`` nearest pairs within ``cutoff`` per molecule,
    padded with self-edges of mask 0.
    """
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, cutoff * 1.2, size=(batch, n_nodes, 3)).astype(np.float32)
    species = rng.integers(0, 4, size=(batch, n_nodes), dtype=np.int32)
    senders = np.zeros((batch, n_edges), dtype=np.int32)
    receivers = np.zeros((batch, n_edges), dtype=np.int32)
    mask = np.zeros((batch, n_edges), dtype=np.float32)
    for bi in range(batch):
        d = np.linalg.norm(pos[bi][:, None] - pos[bi][None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        ii, jj = np.nonzero(d < cutoff)
        order = np.argsort(d[ii, jj])[:n_edges]
        k = len(order)
        senders[bi, :k] = ii[order]
        receivers[bi, :k] = jj[order]
        mask[bi, :k] = 1.0
    return pos, species, senders, receivers, mask
