"""Fanout neighbor sampler (GraphSAGE-style) for ``minibatch_lg`` training.

Produces fixed-shape (padded) k-hop samples so the sampled subgraph feeds a
jit-compiled GNN step without retracing: each hop gathers up to ``fanout[h]``
neighbors per frontier node (with replacement when deg > 0, self-loop padding
when deg == 0), emitting flat (senders, receivers) edge lists whose receiver
side indexes the previous hop's frontier.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.trie import CSRGraph


@dataclasses.dataclass
class SampledBlock:
    """One hop: edges from sampled source nodes into the frontier."""

    senders: np.ndarray     # [F * fanout] indices into ``nodes`` (next hop)
    receivers: np.ndarray   # [F * fanout] indices into previous frontier
    nodes: np.ndarray       # [F * fanout] global node ids of this hop (w/ dup)


@dataclasses.dataclass
class SampledBatch:
    seeds: np.ndarray                 # [B] global seed node ids
    blocks: List[SampledBlock]        # one per hop, frontier-outward
    all_nodes: np.ndarray             # unique global ids touched


class NeighborSampler:
    """Fixed-fanout sampler over a CSR graph."""

    def __init__(self, csr: CSRGraph, fanouts: Sequence[int] = (15, 10),
                 seed: int = 0):
        self.csr = csr
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> SampledBatch:
        seeds = np.asarray(seeds, dtype=np.int64)
        frontier = seeds
        blocks: List[SampledBlock] = []
        touched = [seeds]
        for fanout in self.fanouts:
            f = len(frontier)
            deg = self.csr.degrees[frontier]
            # sample ``fanout`` slots per frontier node (with replacement);
            # zero-degree nodes self-loop.
            r = self.rng.integers(0, 1 << 62, size=(f, fanout))
            slot = np.where(deg[:, None] > 0, r % np.maximum(deg, 1)[:, None], 0)
            base = self.csr.offsets[frontier]
            idx = base[:, None] + slot
            nodes = np.where(deg[:, None] > 0,
                             self.csr.neighbors[idx.astype(np.int64)],
                             frontier[:, None]).astype(np.int64)
            senders = np.arange(f * fanout, dtype=np.int64)
            receivers = np.repeat(np.arange(f, dtype=np.int64), fanout)
            blocks.append(SampledBlock(senders, receivers, nodes.reshape(-1)))
            frontier = nodes.reshape(-1)
            touched.append(frontier)
        return SampledBatch(seeds, blocks, np.unique(np.concatenate(touched)))

    def batches(self, batch_nodes: int, epochs: int = 1):
        """Yield seed batches covering all nodes (shuffled) per epoch."""
        for _ in range(epochs):
            perm = self.rng.permutation(self.csr.n)
            for s in range(0, self.csr.n, batch_nodes):
                yield self.sample(perm[s:s + batch_nodes])
