"""Data pipeline substrate: synthetic graph generators (power-law /
Kronecker / molecule batches), a fanout neighbor sampler for minibatch GNN
training, an LM token pipeline, and recsys batch generation."""
from repro.data.graphs import (  # noqa: F401
    kronecker_graph, molecule_batch, powerlaw_graph, random_features,
)
from repro.data.sampler import NeighborSampler  # noqa: F401
from repro.data.lm import TokenPipeline  # noqa: F401
from repro.data.recsys import RecsysBatchGen  # noqa: F401
