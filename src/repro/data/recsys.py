"""Recsys batch generation: Criteo-like 39 sparse fields + CTR labels.

Deterministic per-(seed, step) like the LM pipeline. Field ids follow a
per-field Zipf so embedding-row access is realistically skewed (hot rows).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RecsysBatchGen:
    n_fields: int
    vocab_per_field: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int):
        """Returns dict(ids [B, F] int32, label [B] float32)."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        ids = rng.zipf(1.2, size=(self.batch, self.n_fields))
        ids = np.minimum(ids - 1, self.vocab_per_field - 1).astype(np.int32)
        logits = (ids.astype(np.float64) % 7 - 3).mean(axis=1)
        label = (rng.random(self.batch) < 1 / (1 + np.exp(-logits))) \
            .astype(np.float32)
        return {"ids": ids, "label": label}
