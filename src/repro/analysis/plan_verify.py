"""Plan-IR validator: structural proof obligations over a lowered DAG.

Every :class:`repro.core.plan_ir.PhysicalPlan` the engine is about to
execute (and every candidate the plan search costs) is checked against
the invariants both lowerings silently rely on.  Two of these invariants
have already been violated by shipped bugs — PR 3's dropped connector
attributes (listing queries spanning bags degenerated into cross
products) and stale routing annotations would be equally silent — so the
checker turns them into *static* errors raised before any tuple moves.

Checks, each with a stable violation ``code``:

  * ``op-registry`` / ``child-order`` — operator ids unique, registered,
    and referenced bottom-up (children strictly before parents).
  * ``access-order`` — per-atom access paths: ``perm`` is a permutation,
    selections occupy a leading prefix, and live variables appear in the
    bag's attribute order (the ``GenericJoin.__init__`` induced-order
    assert, now decided without building anything).
  * ``unconstrained-var`` / ``step-shape`` — the descent simulation:
    every attribute is advanced by at least one atom or child input at
    its turn, one step per attribute, terminal folds only at the end of
    aggregate bags, and ``Extend.n_constraining`` matches the structure.
  * ``dropped-connector`` — connector-attribute retention: every child
    input's join variables must survive in the child's materialized
    output, and (for listing plans with a final top-down join) in the
    parent's output too — the PR 3 bug class as a static error.
  * ``est-invalid`` / ``agm-exceeded`` — ``est_rows``/``cost`` finite and
    non-negative, and no estimate above the bag's AGM bound (paper Eq. 1
    with real relation sizes; recomputed here, memoizable).
  * ``routing-invalid`` / ``threshold-range`` — routing hints drawn from
    the legal vocabulary (``plan_ir.EXTEND_ROUTINGS`` /
    ``FOLD_ROUTINGS``), pair routing only where the binary-self-join
    structural condition actually holds, and Algorithm-3 layout
    thresholds inside ``[block_bits, MAX_THRESHOLD_BITS]`` — the cohort
    tables :mod:`repro.core.layouts` dispatches on.
  * ``sideways-invalid`` — sideways bitset filtering only annotated on
    search-routed extensions with >= 2 constraining atoms where some
    arity-2 atom actually probes its second trie level (the shape the
    counting pass's block-directory intersection requires).
  * ``reuse-key`` — engine-lifetime bag-cache keys: hashable
    canonicalized structure, alias-resolved relation names, and
    ``reuse_rels`` covering every relation the bag's subtree reads (an
    incomplete set would let a stale cached result survive a reload).
  * ``param-selection`` — bind-parameter selections (prepared queries):
    every ``datalog.Param`` appearing as a selection constant must carry
    a non-negative integer slot, and the slots used across the whole
    plan must be contiguous from 0 — the shape
    ``compile.parameterize`` emits and ``PreparedQuery._binding``
    indexes into.  A gap would make some positional argument silently
    unused; a bad slot would crash (or worse, mis-bind) at encode time.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import plan_ir
from repro.core import statistics
from repro.core.datalog import Param
from repro.core.plan_ir import (BagOps, BagScan, Extend, MaterializeShared,
                                PhysicalPlan, TerminalFold, TopDownJoin)
from repro.core.statistics import BASE_BLOCK_BITS, MAX_THRESHOLD_BITS

# 0.1% slack on the AGM comparison: the builder and the checker both go
# through exp(min(obj, 700)) so they agree bit-for-bit today, but the cap
# is a float bound, not an identity.
_AGM_TOLERANCE = 1.001


@dataclasses.dataclass(frozen=True)
class PlanViolation:
    code: str       # stable machine-readable class, e.g. "dropped-connector"
    where: str      # "bag#<op_id>", "final", or "plan"
    message: str

    def __str__(self) -> str:
        return f"[{self.code}] {self.where}: {self.message}"


class PlanVerificationError(AssertionError):
    """Raised by :func:`assert_valid` with every violation attached."""

    def __init__(self, violations: list[PlanViolation]):
        self.violations = list(violations)
        lines = "\n  ".join(str(v) for v in violations)
        super().__init__(f"physical plan failed verification "
                         f"({len(violations)} violation(s)):\n  {lines}")


def assert_valid(pplan: PhysicalPlan, catalog=None, stats=None,
                 agm_memo: dict | None = None) -> PhysicalPlan:
    """Raise :class:`PlanVerificationError` unless ``pplan`` is valid."""
    violations = verify_physical_plan(pplan, catalog, stats,
                                      agm_memo=agm_memo)
    if violations:
        raise PlanVerificationError(violations)
    return pplan


def verify_physical_plan(pplan: PhysicalPlan, catalog=None, stats=None,
                         agm_memo: dict | None = None) -> list[PlanViolation]:
    """All violations of ``pplan`` (empty list = valid).

    ``catalog`` (the executor's relation catalog) enables the checks that
    need data identity — alias resolution, atom arity, AGM bounds; without
    it the purely structural checks still run (hand-built plans in tests).
    ``stats`` (a ``StatisticsCatalog``) supplies ``block_bits`` for the
    threshold range; ``agm_memo`` shares fractional-cover LP solves with
    the plan search's candidate loop.
    """
    out: list[PlanViolation] = []
    add = out.append

    # ---------------------------------------------------- operator registry
    seen_ids: set[int] = set()

    def check_registered(op, where: str):
        if op.op_id in seen_ids:
            add(PlanViolation("op-registry", where,
                              f"duplicate op_id {op.op_id}"))
        seen_ids.add(op.op_id)
        if pplan.ops.get(op.op_id) is not op:
            add(PlanViolation("op-registry", where,
                              f"op_id {op.op_id} not registered in plan.ops"))

    if not pplan.bag_ops:
        add(PlanViolation("op-registry", "plan", "plan has no bags"))
        return out
    if pplan.bag_ops[-1] is not pplan.root:
        add(PlanViolation("child-order", "plan",
                          "bag_ops is not bottom-up (root must be last)"))

    materialized: dict[int, BagOps] = {}
    aggregate = pplan.logical.semiring is not None

    for bops in pplan.bag_ops:
        where = f"bag#{bops.materialize.op_id}"
        check_registered(bops.scan, where)
        for s in bops.steps:
            check_registered(s, where)
        check_registered(bops.materialize, where)
        if bops.materialize.source != bops.scan.op_id:
            add(PlanViolation("op-registry", where,
                              "materialize.source does not reference the "
                              "bag's own scan"))
        for ci in bops.scan.child_inputs:
            if ci.op_id not in materialized:
                add(PlanViolation("child-order", where,
                                  f"child input {ci.op_id} does not "
                                  f"reference an earlier bag's materialize"))
        _verify_bag(bops, materialized, aggregate, pplan, catalog, stats,
                    agm_memo, add)
        materialized[bops.materialize.op_id] = bops

    _verify_final(pplan, materialized, add)
    if pplan.final is not None:
        check_registered(pplan.final, "final")
    _verify_params(pplan, add)
    return out


def _verify_params(pplan: PhysicalPlan, add) -> None:
    """Bind-parameter selections: Param slots valid and contiguous.

    ``compile.parameterize`` assigns slots ``0..n-1`` in first-appearance
    order, and ``engine.PreparedQuery`` binds positionally against that
    range — so any Param with a non-int / negative slot, or a slot set
    with gaps, is a plan that cannot have come from the prepared-query
    path and would mis-bind at encode time.
    """
    slots: set[int] = set()
    for bops in pplan.bag_ops:
        where = f"bag#{bops.materialize.op_id}"
        for acc in bops.scan.accesses:
            for pos, value in acc.selections:
                if not isinstance(value, Param):
                    continue
                if not isinstance(value.slot, int) or value.slot < 0:
                    add(PlanViolation(
                        "param-selection", where,
                        f"{acc.rel}[{pos}]: Param slot {value.slot!r} is "
                        f"not a non-negative int"))
                else:
                    slots.add(value.slot)
    if slots and slots != set(range(max(slots) + 1)):
        missing = sorted(set(range(max(slots) + 1)) - slots)
        add(PlanViolation(
            "param-selection", "plan",
            f"bind-parameter slots {sorted(slots)} are not contiguous "
            f"from 0 (missing {missing}) — positional binding would "
            f"leave arguments unused"))


# --------------------------------------------------------------- per bag
def _verify_bag(bops: BagOps, materialized: dict[int, BagOps],
                aggregate: bool, pplan: PhysicalPlan, catalog, stats,
                agm_memo: dict | None, add) -> None:
    scan: BagScan = bops.scan
    mat: MaterializeShared = bops.materialize
    where = f"bag#{mat.op_id}"
    var_order = scan.var_order
    order_pos = {v: i for i, v in enumerate(var_order)}

    if len(set(var_order)) != len(var_order):
        add(PlanViolation("step-shape", where,
                          f"duplicate attribute in var_order {var_order}"))
        return

    # ------------------------------------------------------- access paths
    atom_keys: list[tuple | None] = []
    atom_arity: list[int | None] = []
    for acc in scan.accesses:
        n = len(acc.vars)
        if sorted(acc.perm) != list(range(n)):
            add(PlanViolation("access-order", where,
                              f"{acc.rel}: perm {acc.perm} is not a "
                              f"permutation of range({n})"))
        sel_pos = [p for p, _ in acc.selections]
        if sel_pos != list(range(len(sel_pos))):
            add(PlanViolation("access-order", where,
                              f"{acc.rel}: selections {sel_pos} are not a "
                              f"leading prefix of the index order"))
        live = acc.live_vars
        missing = [v for v in live if v not in order_pos]
        if missing:
            add(PlanViolation("access-order", where,
                              f"{acc.rel}: live vars {missing} not in bag "
                              f"var_order {var_order}"))
        else:
            pos = [order_pos[v] for v in live]
            if pos != sorted(pos):
                add(PlanViolation("access-order", where,
                                  f"{acc.rel}: live vars {live} are not in "
                                  f"the bag attribute order {var_order}"))
        arity = None
        key = None
        if catalog is not None:
            try:
                arity = catalog.get(acc.rel).arity
                key = (catalog.resolve(acc.rel), acc.perm)
            except KeyError:
                pass
        if arity is None:
            arity = len(acc.vars)
        atom_keys.append(key)
        atom_arity.append(arity)

    # ------------------------------------------------- child input schema
    for ci in scan.child_inputs:
        child = materialized.get(ci.op_id)
        pos = [order_pos[v] for v in ci.vars if v in order_pos]
        if len(pos) != len(ci.vars) or pos != sorted(pos):
            add(PlanViolation("access-order", where,
                              f"child#{ci.op_id} vars {ci.vars} not ordered "
                              f"by the parent var_order {var_order}"))
        if child is not None:
            dropped = [v for v in ci.vars
                       if v not in child.materialize.output_vars]
            if dropped:
                add(PlanViolation(
                    "dropped-connector", where,
                    f"connector attrs {dropped} joined from child"
                    f"#{ci.op_id} but absent from the child's "
                    f"materialized output "
                    f"{child.materialize.output_vars}"))

    # Listing plans spanning bags: the final top-down join reconnects bags
    # on shared attributes, so the PARENT must also retain everything it
    # shares with its children (the PR 3 bug class — projecting these away
    # degenerates the final join into a cross product).
    if pplan.final is not None:
        out_set = set(mat.output_vars)
        for ci in scan.child_inputs:
            dropped = [v for v in ci.vars if v not in out_set]
            if dropped:
                add(PlanViolation(
                    "dropped-connector", where,
                    f"connector attrs {dropped} shared with child"
                    f"#{ci.op_id} but dropped from this bag's output "
                    f"{mat.output_vars} (top-down join would cross-product)"))

    # ------------------------------------------------- output projection
    out_pos = [order_pos[v] for v in mat.output_vars if v in order_pos]
    if len(out_pos) != len(mat.output_vars) or out_pos != sorted(out_pos):
        add(PlanViolation("step-shape", where,
                          f"output_vars {mat.output_vars} is not an ordered "
                          f"subsequence of var_order {var_order}"))

    # ------------------------------------------------ descent simulation
    if len(bops.steps) != len(var_order):
        add(PlanViolation("step-shape", where,
                          f"{len(bops.steps)} steps for {len(var_order)} "
                          f"attributes"))
        return
    depth = [len(acc.selections) for acc in scan.accesses]
    cdepth = [0] * len(scan.child_inputs)
    out_set = set(mat.output_vars)
    for vi, (v, step) in enumerate(zip(var_order, bops.steps)):
        if step.var != v:
            add(PlanViolation("step-shape", where,
                              f"step {vi} extends {step.var!r}, var_order "
                              f"says {v!r}"))
            return
        advancing_atoms = []
        for i, acc in enumerate(scan.accesses):
            live = acc.live_vars
            d = depth[i] - len(acc.selections)
            if d < len(live) and live[d] == v:
                advancing_atoms.append(i)
        advancing_children = [
            i for i, ci in enumerate(scan.child_inputs)
            if cdepth[i] < len(ci.vars) and ci.vars[cdepth[i]] == v]
        n_cons = len(advancing_atoms) + len(advancing_children)
        if n_cons == 0:
            add(PlanViolation("unconstrained-var", where,
                              f"attribute {v!r} has no constraining atom or "
                              f"child input at its turn"))
        last = vi == len(var_order) - 1
        if isinstance(step, TerminalFold):
            if not (aggregate and last and v not in out_set):
                add(PlanViolation("step-shape", where,
                                  f"terminal fold on {v!r} is only legal as "
                                  f"the last, non-retained attribute of an "
                                  f"aggregate bag"))
            _verify_fold_routing(step, scan, advancing_atoms,
                                 advancing_children, atom_keys, atom_arity,
                                 depth, stats, where, add)
        elif isinstance(step, Extend):
            if step.n_constraining != n_cons:
                add(PlanViolation("step-shape", where,
                                  f"extend {v!r}: n_constraining="
                                  f"{step.n_constraining} but the plan "
                                  f"structure gives {n_cons}"))
            _verify_extend_routing(step, scan, advancing_atoms,
                                   advancing_children, atom_keys, atom_arity,
                                   depth, where, add)
            # device-pipeline buffer annotations: a cap the runtime
            # cannot size a static frontier buffer from is a plan bug
            if step.frontier_cap is not None and not (
                    math.isfinite(step.frontier_cap)
                    and 0 < step.frontier_cap
                    <= statistics.PIPELINE_MAX_BUFFER):
                add(PlanViolation("est-invalid", where,
                                  f"extend {v!r}: frontier_cap="
                                  f"{step.frontier_cap!r} is not a "
                                  f"positive finite buffer size within "
                                  f"PIPELINE_MAX_BUFFER"))
            if step.morsel is not None and not (
                    isinstance(step.morsel, int) and step.morsel > 0):
                add(PlanViolation("est-invalid", where,
                                  f"extend {v!r}: morsel="
                                  f"{step.morsel!r} is not a positive "
                                  f"integer"))
        else:
            add(PlanViolation("step-shape", where,
                              f"unknown step operator {type(step).__name__}"))
        for i in advancing_atoms:
            depth[i] += 1
        for i in advancing_children:
            cdepth[i] += 1

    # ---------------------------------------------------- est/cost sanity
    agm_cap = None
    if catalog is not None:
        agm_cap = plan_ir._bag_agm_bound(pplan.logical, bops.logical,
                                         catalog, agm_memo)
    for op in (scan, *bops.steps, mat):
        if not (math.isfinite(op.est_rows) and op.est_rows >= 0):
            add(PlanViolation("est-invalid", where,
                              f"op#{op.op_id} est_rows={op.est_rows!r}"))
        if not (math.isfinite(op.cost) and op.cost >= 0):
            add(PlanViolation("est-invalid", where,
                              f"op#{op.op_id} cost={op.cost!r}"))
    if agm_cap is not None:
        limit = agm_cap * _AGM_TOLERANCE
        for op in (*bops.steps, mat):
            if math.isfinite(op.est_rows) and op.est_rows > limit:
                add(PlanViolation("agm-exceeded", where,
                                  f"op#{op.op_id} est_rows={op.est_rows:.4g} "
                                  f"exceeds the bag AGM bound "
                                  f"{agm_cap:.4g}"))

    _verify_reuse_key(bops, materialized, catalog, where, add)


# ----------------------------------------------------------- routing checks
def _verify_extend_routing(step: Extend, scan, advancing_atoms,
                           advancing_children, atom_keys, atom_arity,
                           depth, where, add) -> None:
    if step.routing not in plan_ir.EXTEND_ROUTINGS:
        add(PlanViolation("routing-invalid", where,
                          f"extend {step.var!r}: unknown routing "
                          f"{step.routing!r} (legal: "
                          f"{sorted(plan_ir.EXTEND_ROUTINGS)})"))
        return
    decidable = all(atom_keys[i] is not None for i in advancing_atoms)
    if step.routing == "pair_store" and decidable and \
            not plan_ir._pair_self_join(
            scan.accesses, advancing_atoms, advancing_children,
            atom_keys, atom_arity, dict(enumerate(depth))):
        add(PlanViolation("routing-invalid", where,
                          f"extend {step.var!r} routed 'pair_store' but is "
                          f"not a binary self-join over one arity-2 index "
                          f"at depth 1"))
    if step.sideways is None:
        return
    if step.sideways != "bitset":
        add(PlanViolation("sideways-invalid", where,
                          f"extend {step.var!r}: unknown sideways "
                          f"{step.sideways!r} (legal: 'bitset')"))
    elif step.routing != "search" or step.n_constraining < 2:
        add(PlanViolation("sideways-invalid", where,
                          f"extend {step.var!r}: sideways filtering needs "
                          f">= 2 constraining atoms on the 'search' "
                          f"routing (got routing={step.routing!r}, "
                          f"n_constraining={step.n_constraining})"))
    elif not any(atom_arity[i] == 2 and depth[i] == 1
                 and not scan.accesses[i].selections
                 for i in advancing_atoms):
        add(PlanViolation("sideways-invalid", where,
                          f"extend {step.var!r}: sideways 'bitset' but no "
                          f"constraining arity-2 atom probes its second "
                          f"trie level"))


def _verify_fold_routing(step: TerminalFold, scan, advancing_atoms,
                         advancing_children, atom_keys, atom_arity,
                         depth, stats, where, add) -> None:
    if step.routing not in plan_ir.FOLD_ROUTINGS:
        add(PlanViolation("routing-invalid", where,
                          f"fold {step.var!r}: unknown routing "
                          f"{step.routing!r} (legal: "
                          f"{sorted(plan_ir.FOLD_ROUTINGS)})"))
        return
    if step.routing == "pair_kernel":
        # atom_keys are None without a catalog — the pair-structure
        # predicate is undecidable then, so only flag when decidable
        decidable = all(atom_keys[i] is not None for i in advancing_atoms)
        if decidable and not plan_ir._pair_self_join(
                scan.accesses, advancing_atoms, advancing_children,
                atom_keys, atom_arity, dict(enumerate(depth))):
            add(PlanViolation("routing-invalid", where,
                              f"fold {step.var!r} routed 'pair_kernel' but "
                              f"is not a binary self-join over one arity-2 "
                              f"index at depth 1"))
        thr = step.layout_threshold
        block_bits = stats.block_bits if stats is not None \
            else BASE_BLOCK_BITS
        if thr is None or not math.isfinite(thr) \
                or not block_bits <= thr <= MAX_THRESHOLD_BITS:
            add(PlanViolation("threshold-range", where,
                              f"fold {step.var!r}: layout_threshold {thr!r} "
                              f"outside [{block_bits}, "
                              f"{MAX_THRESHOLD_BITS}]"))
    elif step.layout_threshold is not None:
        add(PlanViolation("threshold-range", where,
                          f"fold {step.var!r}: search routing must not carry "
                          f"a layout threshold "
                          f"(got {step.layout_threshold!r})"))


# --------------------------------------------------------- reuse-key checks
def _well_formed_struct(key) -> bool:
    """``MaterializeShared.reuse_struct`` shape: ``(atom_keys, out_key,
    sr_key, child_keys)`` of hashable primitives, recursively."""
    if not (isinstance(key, tuple) and len(key) == 4):
        return False
    atom_keys, out_key, sr_key, child_keys = key
    if not isinstance(atom_keys, tuple) or not isinstance(out_key, tuple) \
            or not isinstance(child_keys, tuple):
        return False
    for ak in atom_keys:
        if not (isinstance(ak, tuple) and len(ak) == 2
                and isinstance(ak[0], str) and isinstance(ak[1], tuple)):
            return False
    if not all(isinstance(p, int) for p in out_key):
        return False
    if sr_key is not None and not isinstance(sr_key, str):
        return False
    return all(_well_formed_struct(c) for c in child_keys)


def _verify_reuse_key(bops: BagOps, materialized: dict[int, BagOps],
                      catalog, where, add) -> None:
    mat = bops.materialize
    key = mat.reuse_struct
    try:
        hash((key, mat.reuse_rels))
    except TypeError:
        add(PlanViolation("reuse-key", where,
                          "reuse_struct/reuse_rels are not hashable"))
        return
    if not _well_formed_struct(key):
        add(PlanViolation("reuse-key", where,
                          f"reuse_struct {key!r} is not a canonicalized "
                          f"(atom_keys, out_key, sr_key, child_keys) tuple"))
        return
    rels = mat.reuse_rels
    if list(rels) != sorted(set(rels)) \
            or not all(isinstance(r, str) for r in rels):
        add(PlanViolation("reuse-key", where,
                          f"reuse_rels {rels!r} must be sorted unique "
                          f"relation names"))
    if catalog is not None:
        unresolved = [r for r in rels if catalog.resolve(r) != r]
        if unresolved:
            add(PlanViolation("reuse-key", where,
                              f"reuse_rels entries {unresolved} are not "
                              f"alias-resolved"))
        rel_set = set(rels)
        missing = sorted({catalog.resolve(a.rel) for a in bops.scan.accesses}
                         - rel_set)
        if missing:
            add(PlanViolation("reuse-key", where,
                              f"relations {missing} are read by this bag but "
                              f"absent from reuse_rels — a reload would not "
                              f"invalidate the cached result"))
        for ci in bops.scan.child_inputs:
            child = materialized.get(ci.op_id)
            if child is None:
                continue
            leaked = sorted(set(child.materialize.reuse_rels) - rel_set)
            if leaked:
                add(PlanViolation("reuse-key", where,
                                  f"child#{ci.op_id} reads {leaked} but the "
                                  f"parent's reuse_rels omits them"))


# ------------------------------------------------------------------- final
def _verify_final(pplan: PhysicalPlan, materialized: dict[int, BagOps],
                  add) -> None:
    final: TopDownJoin | None = pplan.final
    if final is None:
        return
    where = "final"
    if pplan.logical.semiring is not None:
        add(PlanViolation("step-shape", where,
                          "aggregate plans must elide the top-down join"))
    if not final.inputs:
        add(PlanViolation("topdown-cover", where,
                          "top-down join with no inputs"))
        return
    covered: set[str] = set()
    for op_id in final.inputs:
        child = materialized.get(op_id)
        if child is None:
            add(PlanViolation("op-registry", where,
                              f"input {op_id} is not a materialized bag"))
            continue
        out_vars = child.materialize.output_vars
        if not out_vars:
            add(PlanViolation("topdown-cover", where,
                              f"input bag#{op_id} materializes no "
                              f"attributes"))
        pos = [final.var_order.index(v) for v in out_vars
               if v in final.var_order]
        if len(pos) != len(out_vars) or pos != sorted(pos):
            add(PlanViolation("access-order", where,
                              f"bag#{op_id} output {out_vars} inconsistent "
                              f"with the final order {final.var_order}"))
        covered |= set(out_vars)
    unconstrained = [v for v in final.var_order if v not in covered]
    if unconstrained:
        add(PlanViolation("unconstrained-var", where,
                          f"final-join attrs {unconstrained} constrained by "
                          f"no input bag"))
    not_covered = [v for v in final.output_vars
                   if v not in final.var_order]
    if not_covered:
        add(PlanViolation("topdown-cover", where,
                          f"output attrs {not_covered} missing from the "
                          f"final join order"))
    if not (math.isfinite(final.est_rows) and final.est_rows >= 0
            and math.isfinite(final.cost) and final.cost >= 0):
        add(PlanViolation("est-invalid", where,
                          f"est_rows={final.est_rows!r} cost={final.cost!r}"))
