"""Serving-layer concurrency lint: AST lock-discipline checker.

``serve.QueryServer`` is the one place the engine meets threads: an
admission queue appended from request handlers, per-tenant engines
created on first touch, LRU order mutated on every query, and dispatch
counters bumped from every path.  None of that is protected by types —
a missing ``with self._lock:`` is invisible until two drains interleave.
This pass makes the lock discipline a static property, the same way
``sync_lint`` does for host syncs:

  * **shared-state registry** — :data:`SHARED_STATE` names, per scanned
    file and class, the attributes that are mutated after construction
    and may be touched from multiple threads (the serve queue/LRU/
    counters, the engine plan caches, the backend dispatch counters);
    :data:`SHARED_OBJECT_ATTRS` additionally names the identity-keyed
    device-upload caches written onto trie/bitset instances from the
    backend.
  * **unguarded-write / unguarded-rmw** — an assignment (or an
    in-place read-modify-write: ``+=``, ``.append``, ``.setdefault``,
    ``.move_to_end``, ``.pop``, subscript stores …) to a registered
    attribute, outside ``__init__``, is a finding unless the statement
    sits under ``with self.<lock>:`` (any attribute ending ``_lock``)
    or the enclosing method is declared :func:`guarded_by` that lock.
  * **unheld-guard-call** — calling a ``@guarded_by``-declared method
    of the same class from a context that provably does not hold the
    declared lock.

``@guarded_by("_lock")`` is the written half of the convention (see
CONTRIBUTING.md): it marks a method whose CALLERS must hold the lock.
The decorator is a no-op at run time — it exists so the discipline is
declared next to the code and machine-checked here.

Scope and policy mirror ``sync_lint``: findings in ``serve/`` are
**never baselinable** — the serving layer is the threaded surface and
must stay lock-clean; findings in the single-threaded core (engine plan
caches, backend counters and upload caches — serialized per instance by
the server's lock, see the class docstrings) are *accounted* in the
committed ``concurrency_baseline.json`` and ratcheted in both
directions.  CLI::

    PYTHONPATH=src python -m repro.analysis.concurrency_lint
    PYTHONPATH=src python -m repro.analysis.concurrency_lint --write-baseline
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import sys

_REPRO_ROOT = pathlib.Path(__file__).resolve().parents[1]
DEFAULT_BASELINE = pathlib.Path(__file__).with_name(
    "concurrency_baseline.json")

# file (posix, relative to src/repro) -> class -> shared instance attrs.
# Only attrs mutated AFTER construction matter; __init__ is exempt.
SHARED_STATE: dict[str, dict[str, set]] = {
    "serve/query.py": {
        "GraphStore": {"_tries", "evictions"},
        "QueryServer": {"counters", "_queue", "_engines", "_prepared"},
    },
    "core/engine.py": {
        "Engine": {"_plan_cache", "_search_cache", "_physical_cache"},
    },
    "core/backend.py": {
        "DeviceBackend": {"stats"},
        "NumpyBackend": {"stats"},
    },
}

# Identity-keyed device-upload caches written onto OTHER objects (trie
# levels / bitsets) from the scanned files: benign-race idempotent
# writes today, accounted in the baseline so a new cache site shows up.
SHARED_OBJECT_ATTRS = {
    "_dev_values", "_dev_offsets", "_dev_annotation", "_dev_sideways_cache",
}

# Method calls that read-modify-write their receiver in place.
RMW_METHODS = {
    "append", "extend", "insert", "pop", "popitem", "remove", "clear",
    "setdefault", "update", "move_to_end", "add", "discard",
}

# serve/ findings are regressions by definition — never baselinable.
STRICT_PREFIXES = ("serve/",)

KINDS = ("unguarded-write", "unguarded-rmw", "unheld-guard-call")


def guarded_by(lock_attr: str):
    """Declare that callers of the decorated method must hold
    ``self.<lock_attr>``.  No-op at run time; enforced statically by
    this module's linter (kind ``unheld-guard-call``)."""

    def mark(fn):
        fn.__guarded_by__ = lock_attr
        return fn

    return mark


@dataclasses.dataclass(frozen=True)
class Finding:
    file: str
    qualname: str
    kind: str
    lineno: int
    detail: str

    @property
    def key(self) -> str:
        # line numbers excluded: unrelated edits must not churn the
        # baseline (same identity scheme as sync_lint)
        return f"{self.file}::{self.qualname}::{self.kind}"

    def __str__(self) -> str:
        return (f"{self.file}:{self.lineno} [{self.kind}] "
                f"{self.qualname}: {self.detail}")


# --------------------------------------------------------------- helpers
def _self_attr(node: ast.AST) -> str | None:
    """'x' for ``self.x``, else None."""
    if isinstance(node, ast.Attribute) \
            and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def _guard_decorator(fn: ast.AST) -> str | None:
    """The lock name a ``@guarded_by("...")`` decorator declares."""
    for dec in getattr(fn, "decorator_list", ()):
        if isinstance(dec, ast.Call):
            head = dec.func
            name = head.attr if isinstance(head, ast.Attribute) \
                else getattr(head, "id", None)
            if name == "guarded_by" and dec.args \
                    and isinstance(dec.args[0], ast.Constant):
                return str(dec.args[0].value)
    return None


def _lock_of_with_item(item: ast.withitem) -> str | None:
    """'x' when the with-item enters ``self.x`` and x looks like a lock."""
    attr = _self_attr(item.context_expr)
    if attr is not None and attr.endswith("_lock"):
        return attr
    return None


class _MethodScan:
    """Per-statement lock context for one method body."""

    def __init__(self, fn: ast.AST):
        self.fn = fn
        self.declared = _guard_decorator(fn)
        # node id -> set of self.<lock> names held at that node
        self.held: dict[int, set] = {}
        base = {self.declared} if self.declared else set()
        self._walk(fn, base)

    def _walk(self, node: ast.AST, held: set) -> None:
        for child in ast.iter_child_nodes(node):
            h = held
            if isinstance(child, ast.With):
                locks = {lk for it in child.items
                         if (lk := _lock_of_with_item(it))}
                if locks:
                    h = held | locks
            elif isinstance(child, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)) \
                    and child is not self.fn:
                # nested defs run later, under unknown locks
                h = set()
            self.held[id(child)] = h
            self._walk(child, h)

    def held_at(self, node: ast.AST) -> set:
        return self.held.get(id(node), set())


def _mutation_of(node: ast.AST):
    """(attr, kind, lineno) when ``node`` writes through ``self.<attr>``
    or read-modify-writes it; attr may also come back as
    ``('obj', name)`` for SHARED_OBJECT_ATTRS stores."""
    if isinstance(node, ast.Assign):
        targets = []
        for tgt in node.targets:
            if isinstance(tgt, (ast.Tuple, ast.List)):   # a, self.b = ...
                targets.extend(tgt.elts)
            else:
                targets.append(tgt)
        for tgt in targets:
            # self.attr = ... / self.attr[k] = ...
            base = tgt
            kind = "unguarded-write"
            if isinstance(base, ast.Subscript):
                base = base.value
                kind = "unguarded-rmw"   # store into a shared container
            attr = _self_attr(base)
            if attr is not None:
                yield attr, kind, node.lineno
            elif isinstance(base, ast.Attribute) \
                    and base.attr in SHARED_OBJECT_ATTRS:
                yield ("obj", base.attr), "unguarded-write", node.lineno
    elif isinstance(node, ast.AugAssign):
        base = node.target
        if isinstance(base, ast.Subscript):
            base = base.value
        attr = _self_attr(base)
        if attr is not None:
            yield attr, "unguarded-rmw", node.lineno
    elif isinstance(node, ast.Call) \
            and isinstance(node.func, ast.Attribute) \
            and node.func.attr in RMW_METHODS:
        attr = _self_attr(node.func.value)
        if attr is not None:
            yield attr, "unguarded-rmw", node.lineno


# --------------------------------------------------------------- the pass
def lint_source(source: str, file: str) -> list:
    tree = ast.parse(source, filename=file)
    shared_by_class = SHARED_STATE.get(file, {})
    findings: list[Finding] = []

    for cls in (n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)):
        shared = shared_by_class.get(cls.name, set())
        methods = [n for n in cls.body
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))]
        guarded = {m.name: _guard_decorator(m) for m in methods
                   if _guard_decorator(m)}
        for m in methods:
            scan = _MethodScan(m)
            qual = f"{cls.name}.{m.name}"
            skip_writes = m.name == "__init__"   # construction is
            for node in ast.walk(m):             # single-threaded
                held = scan.held_at(node)
                for attr, kind, lineno in _mutation_of(node):
                    if isinstance(attr, tuple):   # object-cache store
                        if not held:
                            findings.append(Finding(
                                file, qual, "unguarded-write", lineno,
                                f"unlocked store to shared device cache "
                                f".{attr[1]}"))
                        continue
                    if skip_writes or attr not in shared or held:
                        continue
                    findings.append(Finding(
                        file, qual, kind, lineno,
                        f"self.{attr} mutated without holding a lock "
                        f"(no enclosing `with self.*_lock:` and no "
                        f"@guarded_by on {qual})"))
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in guarded \
                        and _self_attr(node.func) is not None:
                    need = guarded[node.func.attr]
                    if need not in held:
                        findings.append(Finding(
                            file, qual, "unheld-guard-call", node.lineno,
                            f"calls {cls.name}.{node.func.attr} "
                            f"(@guarded_by('{need}')) without holding "
                            f"self.{need}"))
    return sorted(findings, key=lambda f: (f.file, f.lineno, f.kind))


def lint_tree(root: pathlib.Path = _REPRO_ROOT) -> list:
    findings: list[Finding] = []
    files = sorted(set(SHARED_STATE)
                   | {p.relative_to(root).as_posix()
                      for p in (root / "serve").rglob("*.py")})
    for rel in files:
        path = root / rel
        if path.exists():
            findings.extend(lint_source(path.read_text(), rel))
    return findings


# --------------------------------------------------------------- baseline
def baseline_counts(findings: list) -> dict:
    out: dict[str, int] = {}
    for f in findings:
        out[f.key] = out.get(f.key, 0) + 1
    return out


def load_baseline(path: pathlib.Path = DEFAULT_BASELINE) -> dict:
    return {str(k): int(v)
            for k, v in json.loads(path.read_text()).items()}


def write_baseline(findings: list,
                   path: pathlib.Path = DEFAULT_BASELINE) -> None:
    counts = baseline_counts(findings)
    path.write_text(json.dumps(dict(sorted(counts.items())), indent=2)
                    + "\n")


def compare(findings: list, baseline: dict) -> tuple:
    """(new, removed) vs baseline — either non-empty fails CI."""
    counts = baseline_counts(findings)
    new = sorted(f"{k} (x{v - baseline.get(k, 0)})"
                 for k, v in counts.items() if v > baseline.get(k, 0))
    removed = sorted(f"{k} (x{v - counts.get(k, 0)})"
                     for k, v in baseline.items() if counts.get(k, 0) < v)
    return new, removed


def strict_findings(findings: list) -> list:
    return [f for f in findings if f.file.startswith(STRICT_PREFIXES)]


def main(argv: list | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    write = "--write-baseline" in argv
    findings = lint_tree()
    strict = strict_findings(findings)
    if strict:
        print("serving-layer lock-discipline violations (never "
              "baselinable — serve/ is the threaded surface):")
        for f in strict:
            print(f"  {f}")
        return 1
    if write:
        write_baseline(findings)
        print(f"wrote {DEFAULT_BASELINE.name}: {len(findings)} accounted "
              f"single-threaded-core finding(s)")
        return 0
    try:
        baseline = load_baseline()
    except FileNotFoundError:
        print(f"missing {DEFAULT_BASELINE}; run with --write-baseline")
        return 1
    new, removed = compare(findings, baseline)
    for f in findings:
        print(f"known: {f}")
    if new:
        print("NEW unguarded shared-state mutations:")
        for k in new:
            print(f"  + {k}")
    if removed:
        print("findings removed — shrink the baseline with "
              "--write-baseline:")
        for k in removed:
            print(f"  - {k}")
    return 1 if (new or removed) else 0


if __name__ == "__main__":
    raise SystemExit(main())
