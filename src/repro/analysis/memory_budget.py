"""Static HBM footprint model, cross-checked against live allocations.

The serving layer budgets device residency (``serve.GraphStore``), but
host-side ``Trie.nbytes()`` is the WRONG number on device: trie level
offsets are int64 on the host and ``_IDX`` (int32 without x64) on
device, annotations narrow under the x64 regime, and the blocked-bitset
block directories (uploaded for the counting pass's sideways
intersection) are invisible to the host view entirely.  This module
computes a **model** of device bytes purely from host shapes + the
x64-canonical dtypes (``kernels.common.canonical_dtype``) and
cross-checks it against the **live** bytes of the identity-keyed device
caches — read via buffer inspection (``.nbytes`` on the cached arrays),
never ``device_get``, so the check itself is invisible to the host-sync
budget.

Three views:

* :func:`trie_footprint` — per-component ``(model, live)`` bytes of one
  trie's resident device caches (level values / offsets, annotation,
  bitset directories);
* :func:`trie_device_bytes` — the model total of the RESIDENT
  components.  ``serve.GraphStore.resident_bytes`` budgets eviction on
  this instead of ``Trie.nbytes()``;
* :func:`program_frontier_bytes` / :func:`plan_frontier_bytes` — the
  static peak frontier-buffer bytes one bag launch allocates (per
  extend step: ``cap × (values + row + seed-pos + per-probe pos + keep)``,
  times the vmapped batch dim), from the audited lowered program or the
  plan IR — the transient half of the per-plan HBM story;
* :func:`fixpoint_state_bytes` — the dense fixpoint state one device
  recursion round carries.

Drift between model and live beyond :data:`DEFAULT_TOLERANCE` is a
modeling bug we want loud: :func:`check_tries` raises through
:class:`MemoryBudgetError` and CI runs the CLI on both backend legs::

    PYTHONPATH=src python -m repro.analysis.memory_budget
"""
from __future__ import annotations

import dataclasses
import sys

import numpy as np

from repro.kernels.common import canonical_dtype

# |model - live| <= tol * max(model, 1): the model predicts exact array
# nbytes, so any real drift means a component we failed to account for.
DEFAULT_TOLERANCE = 0.05


class MemoryBudgetError(AssertionError):
    """Raised when the static model drifts from live device allocations."""


@dataclasses.dataclass(frozen=True)
class Component:
    """One device-cached array family of a trie."""

    name: str           # "level0.values" | "annotation" | "bitset_dir" ...
    model_bytes: int    # predicted from host shape + canonical dtype
    live_bytes: int     # actual .nbytes of the cached (device) arrays


@dataclasses.dataclass(frozen=True)
class TrieFootprint:
    trie: str
    components: tuple

    @property
    def model_bytes(self) -> int:
        return sum(c.model_bytes for c in self.components)

    @property
    def live_bytes(self) -> int:
        return sum(c.live_bytes for c in self.components)


def _idx_itemsize() -> int:
    from repro.core.backend import _IDX_NP
    return int(np.dtype(_IDX_NP).itemsize)


def _nbytes(x) -> int:
    return int(getattr(x, "nbytes", 0))


def _model_bytes(host_arr) -> int:
    return int(host_arr.size) * int(canonical_dtype(host_arr.dtype).itemsize)


def trie_footprint(trie) -> TrieFootprint:
    """Per-component model-vs-live device bytes of one trie's RESIDENT
    caches.  Components with no device cache contribute nothing — the
    footprint is what eviction would actually reclaim."""
    comps: list[Component] = []
    idx = _idx_itemsize()
    for i, lv in enumerate(trie.levels):
        cached = lv.__dict__.get("_dev_values")
        if cached is not None:
            comps.append(Component(
                f"level{i}.values", _model_bytes(lv.values),
                _nbytes(cached[1])))
        cached = lv.__dict__.get("_dev_offsets")
        if cached is not None:
            # offsets upload through backend._up_idx: always _IDX_NP
            comps.append(Component(
                f"level{i}.offsets", int(lv.offsets.size) * idx,
                _nbytes(cached[1])))
    cached = trie.__dict__.get("_dev_annotation")
    if cached is not None:
        comps.append(Component(
            "annotation", _model_bytes(trie.annotation),
            _nbytes(cached[1])))
    for key, store in sorted(
            (trie.__dict__.get("_hybrid_stores") or {}).items(),
            key=repr):
        bs = getattr(store, "bitset", None)
        sw = getattr(bs, "_dev_sideways_cache", None) if bs is not None \
            else None
        if sw is None or sw[0] is not bs.block_ids:
            continue
        model = (int(np.asarray(bs.slot_of).size) * 4
                 + int(np.asarray(bs.offsets).size) * idx
                 + int(np.asarray(bs.block_ids).size) * 4)
        live = sum(_nbytes(a) for a in sw[1])
        comps.append(Component(f"bitset_dir[{key[0]}:{key[1]}]",
                               model, live))
    return TrieFootprint(trie=trie.name, components=tuple(comps))


def trie_device_bytes(trie) -> int:
    """Model-side device bytes of the trie's resident caches — the number
    ``serve.GraphStore`` budgets eviction on (host ``nbytes()`` counts
    int64 offsets the device never holds)."""
    return trie_footprint(trie).model_bytes


def trie_full_upload_bytes(trie) -> int:
    """Model device bytes if every level, the annotation AND every
    already-built bitset directory were resident — capacity planning
    for admission, independent of current caches."""
    idx = _idx_itemsize()
    total = 0
    for lv in trie.levels:
        total += _model_bytes(lv.values) + int(lv.offsets.size) * idx
    if trie.annotation is not None:
        total += _model_bytes(trie.annotation)
    for store in (trie.__dict__.get("_hybrid_stores") or {}).values():
        bs = getattr(store, "bitset", None)
        if bs is not None:
            total += (int(np.asarray(bs.slot_of).size) * 4
                      + int(np.asarray(bs.offsets).size) * idx
                      + int(np.asarray(bs.block_ids).size) * 4)
    return total


# ------------------------------------------------------ transient buffers
def program_frontier_bytes(prog, *, batch: int = 1) -> int:
    """Peak static frontier-buffer bytes of one lowered bag program: per
    extend step the fill loop carries ``cap`` rows of values(int32) +
    source-row/seed-pos/per-probe positions(_IDX) + keep(bool), and the
    batched path allocates all of it ``batch`` times (leading vmap axis
    — ``statistics.max_batch`` sizes B against the same ceiling)."""
    idx = _idx_itemsize()
    total = 0
    for step in prog:
        if step[0] != "extend":
            continue
        _, _var, cap_out, _morsel, cons = step
        nprobes = max(len(cons) - 1, 0)
        per_row = 4 + idx * (2 + nprobes) + 1
        total += int(cap_out) * per_row
    return total * max(int(batch), 1)


def plan_frontier_bytes(pplan, *, batch: int = 1) -> int:
    """Same model from the plan IR (pre-lowering): each ``Extend`` step's
    ``frontier_cap`` estimate through ``statistics.frontier_capacity``
    with the morsel hint — the capacity the pipeline will declare unless
    the live cross-product bound clamps it further (this is therefore an
    upper-bound model)."""
    from repro.core import plan_ir as P
    from repro.core import statistics as S
    idx = _idx_itemsize()
    total = 0
    for bag in pplan.bag_ops:
        morsel = bag.hints().morsel or S.DEFAULT_MORSEL
        for s in bag.steps:
            if not isinstance(s, P.Extend) or s.frontier_cap is None:
                continue
            cap = S.frontier_capacity(float(s.frontier_cap),
                                      S.PIPELINE_MAX_BUFFER, int(morsel))
            nprobes = max(int(s.n_constraining) - 1, 0)
            total += cap * (4 + idx * (2 + nprobes) + 1)
    return total * max(int(batch), 1)


def fixpoint_state_bytes(n: int, dtype) -> int:
    """Dense device fixpoint state: annotation vector over [0, n) plus
    the boolean frontier mask (``recursion._seminaive_device``)."""
    return int(n) * (int(canonical_dtype(dtype).itemsize) + 1)


# ------------------------------------------------------------ cross-check
def check_tries(tries, *, tolerance: float = DEFAULT_TOLERANCE,
                counters=None) -> list[TrieFootprint]:
    """Cross-check model vs live for every trie; raise on drift.

    ``counters`` (e.g. ``backend.stats``) receives the
    ``analysis.memory_*`` tallies surfaced by ``dispatch_summary()``."""
    fps = []
    for t in tries:
        fp = trie_footprint(t)
        fps.append(fp)
        if counters is not None:
            counters["analysis.memory_checks"] += 1
            counters["analysis.memory_model_bytes"] += fp.model_bytes
        drift = abs(fp.model_bytes - fp.live_bytes)
        if drift > tolerance * max(fp.model_bytes, 1):
            comps = ", ".join(f"{c.name}: model={c.model_bytes} "
                              f"live={c.live_bytes}"
                              for c in fp.components)
            raise MemoryBudgetError(
                f"trie '{fp.trie}': static model {fp.model_bytes}B vs "
                f"live device {fp.live_bytes}B (drift {drift}B > "
                f"{tolerance:.0%}) — [{comps}]")
    return fps


def check_store(server, *, tolerance: float = DEFAULT_TOLERANCE
                ) -> dict[str, dict[str, int]]:
    """Per-tenant model-vs-live report over a ``QueryServer``'s store
    (the serve_bench artifact + gate).  Raises on drift."""
    out: dict[str, dict[str, int]] = {}
    for tenant in server.store.tenants():
        tries = [t for t in server.store._tries.get(tenant, ())
                 if t.device_resident]
        fps = check_tries(tries, tolerance=tolerance,
                          counters=server.backend.stats)
        model = sum(fp.model_bytes for fp in fps)
        live = sum(fp.live_bytes for fp in fps)
        out[tenant] = {"model_bytes": model, "live_bytes": live,
                       "delta_bytes": live - model}
    return out


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    from repro.core.engine import Engine
    from repro.core.workload import ALIASES, FOUR_CLIQUE, TRIANGLE_COUNT
    from repro.data import powerlaw_graph

    g = powerlaw_graph(80, 5, 2.0, seed=0)
    src = np.repeat(np.arange(g.n), g.degrees)
    eng = Engine(backend="device")
    trie = eng.load_edges("Edge", src, g.neighbors)
    for al in ALIASES:
        eng.alias(al, "Edge")
    # record the lowered bag programs on the FIRST run — identical
    # reruns are served from the engine-lifetime BagResultCache and
    # never reach the backend
    records: list = []
    eng.backend.audit_log = records
    try:
        eng.query(TRIANGLE_COUNT)
        eng.query(FOUR_CLIQUE)
    finally:
        eng.backend.audit_log = None

    status = 0
    try:
        fps = check_tries([trie], counters=eng.backend.stats)
    except MemoryBudgetError as e:
        print(f"FAIL: {e}")
        return 1
    for fp in fps:
        print(f"ok: trie '{fp.trie}' model={fp.model_bytes}B "
              f"live={fp.live_bytes}B "
              f"(host nbytes={eng.catalog.get('Edge').nbytes()}B)")
        for c in fp.components:
            print(f"    {c.name}: model={c.model_bytes}B "
                  f"live={c.live_bytes}B")
    for rec in records:
        if rec[0] not in ("bag", "bag_batch"):
            continue
        prog = rec[2]
        print(f"frontier[{rec[1]}]: {program_frontier_bytes(prog)}B peak "
              f"({sum(1 for s in prog if s[0] == 'extend')} extend(s))")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
