"""Trace-level program auditor: jaxpr invariants for the device engine.

``plan_verify`` checks the plan IR and ``sync_lint`` checks the Python
source, but the invariants the engine actually ships on live in the
**traced jaxprs** — the fused bag programs (``backend._bag_program``),
their vmapped batch counterparts, and the device fixpoints
(``recursion._seminaive_device`` / ``_naive_device``).  This module
retraces each recorded program to its ``ClosedJaxpr`` (abstractly — via
``jax.make_jaxpr`` over ``ShapeDtypeStruct`` avals, no device work) and
walks the equation graph, recursing into ``while`` / ``scan`` / ``cond``
/ ``pjit`` sub-jaxprs, to statically prove:

* **zero host callbacks** (``host-callback``) — no ``io_callback`` /
  ``pure_callback`` / ``debug_callback`` primitive anywhere in the
  program.  A callback inside a traced program is a hidden host
  round-trip that no runtime counter would attribute;
* **launch-budget consistency** (``launch-budget``) — the program
  contains exactly the fill loops its lowered ``prog`` implies (one
  ``lax.while_loop`` per extension, one per non-plain terminal fold,
  one per fixpoint) and nothing else loops.  This is the static half of
  the dynamic ``pipeline.launches == extend.closing_syncs`` budget: one
  traced program, one launch, one closing sync;
* **frontier buffer shapes** (``frontier-cap`` / ``frontier-bucket``) —
  every fill loop carries buffers of exactly the plan-lowered static
  capacity (trailing batch-free dim == ``cap_out``), and each declared
  capacity is a valid ``statistics.frontier_capacity`` bucket (power of
  two in ``[PIPELINE_MIN_BUCKET, PIPELINE_MAX_BUFFER]``, divisible by
  its pow2 morsel);
* **no dtype widening** (``dtype-widening``) — no f64 / i64 / c128 aval
  appears unless x64 is enabled or the width was declared by a program
  input (the catalog's annotation dtypes enter through the operand
  avals);
* **no broadcast materialization** (``broadcast-materialize``) — no
  ``broadcast_in_dim`` materializes more elements than the pipeline's
  buffer ceiling (``statistics.PIPELINE_MAX_BUFFER``).

Violations are typed (:class:`AuditViolation`) like ``plan_verify``'s
and are NEVER baselinable.  The committed ratchet baseline
(``jaxpr_baseline.json``) instead pins the audited program inventory —
``program-name -> fill-loop count`` over the seven paper queries plus a
batched serving probe — and the comparison fails in BOTH directions like
``sync_lint``: a new loop (you added a launch) and a vanished program
(coverage silently shrank) both fail CI.

CLI::

    PYTHONPATH=src python -m repro.analysis.jaxpr_audit
    PYTHONPATH=src python -m repro.analysis.jaxpr_audit --write-baseline
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys

import jax
import numpy as np

DEFAULT_BASELINE = pathlib.Path(__file__).with_name("jaxpr_baseline.json")

# Host-callback primitives: any of these inside a traced program is a
# hidden device->host round-trip (jax wraps them all over `callback`).
HOST_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
})

# Primitives whose params carry sub-jaxprs we must recurse into.  The
# walk is generic (any param holding a Jaxpr/ClosedJaxpr is followed),
# this set only documents the expected carriers.
SUBJAXPR_PRIMS = frozenset({
    "while", "scan", "cond", "pjit", "custom_jvp_call", "custom_vjp_call",
    "remat", "checkpoint", "pallas_call",
})


class JaxprAuditError(AssertionError):
    """Raised by :func:`assert_clean` with the violation list attached."""

    def __init__(self, violations: list["AuditViolation"]):
        self.violations = list(violations)
        lines = "\n  ".join(str(v) for v in violations)
        super().__init__(f"jaxpr audit failed "
                         f"({len(violations)} violation(s)):\n  {lines}")


@dataclasses.dataclass(frozen=True)
class AuditViolation:
    """One typed trace-level invariant violation (cf. ``PlanViolation``)."""

    code: str       # "host-callback" | "launch-budget" | ...
    where: str      # program name (+ eqn path)
    message: str

    def __str__(self) -> str:
        return f"[{self.code}] {self.where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """What the lowered program declares about its own trace.

    ``loops`` is one entry per expected fill loop, in program order:
    ``(kind, var, cap, morsel)`` — the loop's carried buffers must have
    trailing dim ``cap``.  ``batch`` > 0 means every buffer grows one
    leading batch axis (the vmapped serving path)."""

    name: str
    loops: tuple = ()
    batch: int = 0
    # None -> read jax.config at audit time (the CI legs differ only in
    # REPRO_ENGINE_BACKEND, not x64, but tests inject both states)
    allow_64: bool | None = None


@dataclasses.dataclass(frozen=True)
class ProgramReport:
    name: str
    n_eqns: int
    fill_loops: int
    host_callbacks: int


def _fold_has_loop(sr, cons) -> bool:
    """Mirror of ``backend._fold_body``'s statically-decided shortcut: a
    probe-free COUNT fold with no leaf annotations folds inside the
    counting pass and traces NO fill loop."""
    plain = len(cons) == 1 and all(c[3] < 0 for c in cons)
    return not (plain and getattr(sr, "name", None) == "count")


def spec_for_bag(name: str, prog: tuple, *, batch: int = 0,
                 allow_64: bool | None = None) -> ProgramSpec:
    """Derive the expected loop inventory from a lowered bag program
    (the hashable ``prog`` tuple ``DeviceBackend._lower_bag`` builds)."""
    loops = []
    cap = 1
    for step in prog:
        kind = step[0]
        if kind == "extend":
            _, var, cap_out, morsel, _cons = step
            loops.append(("extend", var, int(cap_out), int(morsel)))
            cap = int(cap_out)
        elif kind == "fold":
            _, var, morsel, sr, cons = step
            if _fold_has_loop(sr, cons):
                loops.append(("fold", var, cap, int(morsel)))
        # "annmul" steps are pure gathers: no loop
    return ProgramSpec(name=name, loops=tuple(loops), batch=batch,
                       allow_64=allow_64)


def spec_for_fixpoint(name: str, *, state_dim: int, batch: int = 0,
                      loops: int = 1,
                      allow_64: bool | None = None) -> ProgramSpec:
    """Fixpoint programs: one while_loop carrying the dense state vector
    (``loops=0`` for the fori/scan-shaped fixed-iteration naive path)."""
    entries = tuple(("fixpoint", name, int(state_dim), 0)
                    for _ in range(loops))
    return ProgramSpec(name=name, loops=entries, batch=batch,
                       allow_64=allow_64)


# ------------------------------------------------------------ jaxpr walk
def _sub_jaxprs(eqn) -> list:
    subs = []
    for v in eqn.params.values():
        for item in (v if isinstance(v, (list, tuple)) else (v,)):
            inner = getattr(item, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                subs.append(inner)          # ClosedJaxpr
            elif hasattr(item, "eqns"):
                subs.append(item)           # raw Jaxpr
    return subs


def iter_eqns(jaxpr, *, into_pallas: bool = True, _path: str = ""):
    """Yield ``(eqn, path, in_pallas)`` over the whole equation graph,
    recursing into every sub-jaxpr (``while``/``scan``/``cond``/``pjit``
    bodies, custom-derivative wrappers, and — when ``into_pallas`` —
    Pallas kernel bodies, whose loops are grid-local, not launches)."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        path = f"{_path}/{name}" if _path else name
        in_pallas = "pallas_call" in _path.split("/")
        yield eqn, path, in_pallas
        if name == "pallas_call" and not into_pallas:
            continue
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, into_pallas=into_pallas, _path=path)


def _avals_of(eqn):
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and getattr(aval, "dtype", None) is not None \
                and getattr(aval, "shape", None) is not None:
            yield aval


_WIDE = frozenset({"int64", "uint64", "float64", "complex128"})


def audit_closed_jaxpr(closed, spec: ProgramSpec,
                       *, broadcast_limit: int | None = None
                       ) -> list[AuditViolation]:
    """Run every trace-level check on one ClosedJaxpr; return violations."""
    from repro.core import statistics as S
    if broadcast_limit is None:
        broadcast_limit = S.PIPELINE_MAX_BUFFER
    allow_64 = (bool(jax.config.jax_enable_x64)
                if spec.allow_64 is None else spec.allow_64)
    out: list[AuditViolation] = []
    jaxpr = closed.jaxpr

    # widths the program's own inputs declare (catalog annotation dtypes
    # enter here) are never "widening"
    declared = {str(v.aval.dtype) for v in jaxpr.invars
                if getattr(v.aval, "dtype", None) is not None}
    declared |= {str(np.asarray(c).dtype) for c in closed.consts}

    # ---- static bucket validity of the DECLARED capacities
    for kind, var, cap, morsel in spec.loops:
        if kind != "extend":
            continue
        ok = (cap >= S.PIPELINE_MIN_BUCKET
              and cap <= S.PIPELINE_MAX_BUFFER
              and (cap & (cap - 1)) == 0
              and morsel > 0 and (morsel & (morsel - 1)) == 0
              and morsel <= cap and cap % morsel == 0)
        if not ok:
            out.append(AuditViolation(
                "frontier-bucket", f"{spec.name}::{var}",
                f"declared cap {cap} / morsel {morsel} is not a pow2 "
                f"frontier_capacity bucket in "
                f"[{S.PIPELINE_MIN_BUCKET}, {S.PIPELINE_MAX_BUFFER}]"))

    fill_loops = []     # (eqn, path) outside pallas kernels, in order
    callbacks = 0
    wide_seen = set()
    for eqn, path, in_pallas in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in HOST_CALLBACK_PRIMS:
            callbacks += 1
            out.append(AuditViolation(
                "host-callback", f"{spec.name}::{path}",
                f"host callback primitive `{name}` inside a traced "
                f"program — a hidden device->host round-trip"))
        if name == "while" and not in_pallas:
            fill_loops.append((eqn, path))
        if name == "broadcast_in_dim" and eqn.outvars:
            aval = eqn.outvars[0].aval
            size = int(np.prod(aval.shape)) if aval.shape else 1
            if size > broadcast_limit:
                out.append(AuditViolation(
                    "broadcast-materialize", f"{spec.name}::{path}",
                    f"broadcast materializes {size} elements "
                    f"(> {broadcast_limit} buffer ceiling) "
                    f"of {aval.dtype}"))
        if not allow_64:
            for aval in _avals_of(eqn):
                dt = str(aval.dtype)
                if dt in _WIDE and dt not in declared \
                        and (spec.name, dt) not in wide_seen:
                    wide_seen.add((spec.name, dt))
                    out.append(AuditViolation(
                        "dtype-widening", f"{spec.name}::{path}",
                        f"{dt} aval with x64 disabled and no {dt} "
                        f"program input — a silent width leak"))

    # ---- launch budget: exactly the declared fill loops, in order
    if len(fill_loops) != len(spec.loops):
        out.append(AuditViolation(
            "launch-budget", spec.name,
            f"traced {len(fill_loops)} while-loop(s), lowered program "
            f"declares {len(spec.loops)} fill loop(s) "
            f"({[(k, v) for k, v, _c, _m in spec.loops]})"))
    else:
        base_ndim = 1 if spec.batch else 0
        for (eqn, path), (kind, var, cap, _morsel) in zip(fill_loops,
                                                          spec.loops):
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                shape = getattr(aval, "shape", None)
                if shape is None or len(shape) <= base_ndim:
                    continue    # chunk counters (scalars / [B])
                if int(shape[-1]) != int(cap):
                    out.append(AuditViolation(
                        "frontier-cap", f"{spec.name}::{path}",
                        f"{kind} '{var}' fill loop carries a buffer of "
                        f"shape {tuple(shape)} but the plan-lowered "
                        f"capacity is {cap}"))
                    break
    return out


def assert_clean(closed, spec: ProgramSpec) -> None:
    violations = audit_closed_jaxpr(closed, spec)
    if violations:
        raise JaxprAuditError(violations)


# ----------------------------------------------- retracing recorded programs
def trace_record(rec: tuple):
    """Retrace one audit-log record (see ``DeviceBackend.audit_log`` and
    ``recursion.AUDIT_LOG``) to ``(ClosedJaxpr, ProgramSpec)`` — purely
    abstract: the record holds ShapeDtypeStructs, not arrays."""
    from repro.core import backend as backend_mod
    from repro.core import recursion as recursion_mod
    kind = rec[0]
    if kind == "bag":
        _, name, prog, arrays, cursors, ann, fill_mode, fill_interpret = rec

        def fn(arrays, cursors0, ann):
            return backend_mod._bag_program(
                arrays, cursors0, ann, prog=prog, fill_mode=fill_mode,
                fill_interpret=fill_interpret)

        closed = jax.make_jaxpr(fn)(arrays, cursors, ann)
        return closed, spec_for_bag(name, prog)
    if kind == "bag_batch":
        _, name, prog, arrays, cursors, ann, batch, fill_interpret = rec

        def fnb(arrays, cursors0, ann):
            return backend_mod._bag_program_batch(
                arrays, cursors0, ann, prog=prog,
                fill_interpret=fill_interpret)

        closed = jax.make_jaxpr(fnb)(arrays, cursors, ann)
        return closed, spec_for_bag(name, prog, batch=batch)
    if kind == "seminaive":
        _, name, sr, apply_expr, max_rounds, n, args = rec

        def fns(gather, scatter, edge_ann, state0, frontier0):
            return recursion_mod._seminaive_device(
                sr, apply_expr, max_rounds, n,
                gather, scatter, edge_ann, state0, frontier0)

        closed = jax.make_jaxpr(fns)(*args)
        return closed, spec_for_fixpoint(name, state_dim=n)
    if kind == "naive":
        (_, name, sr, apply_expr, iters, tol, max_rounds, k,
         factor_kinds, args) = rec

        def fnn(out_idx, rec_idx, factor_anns, ann0):
            return recursion_mod._naive_device(
                sr, apply_expr, iters, tol, max_rounds, k,
                factor_kinds, out_idx, rec_idx, factor_anns, ann0)

        closed = jax.make_jaxpr(fnn)(*args)
        loops = 0 if iters is not None else 1
        return closed, spec_for_fixpoint(name, state_dim=k, loops=loops)
    raise ValueError(f"unknown audit record kind {kind!r}")


def audit_records(records, *, counters=None
                  ) -> tuple[list[ProgramReport], list[AuditViolation]]:
    """Retrace + audit every recorded program.  ``counters`` (a
    Counter-like mapping, e.g. ``backend.stats``) receives the
    ``analysis.jaxpr_*`` tallies ``dispatch_summary()`` surfaces."""
    reports: list[ProgramReport] = []
    violations: list[AuditViolation] = []
    for rec in records:
        closed, spec = trace_record(rec)
        vs = audit_closed_jaxpr(closed, spec)
        violations.extend(vs)
        n_eqns = sum(1 for _ in iter_eqns(closed.jaxpr))
        fills = sum(1 for eqn, _p, in_p in iter_eqns(closed.jaxpr)
                    if eqn.primitive.name == "while" and not in_p)
        cbs = sum(1 for eqn, _p, _ip in iter_eqns(closed.jaxpr)
                  if eqn.primitive.name in HOST_CALLBACK_PRIMS)
        reports.append(ProgramReport(name=spec.name, n_eqns=n_eqns,
                                     fill_loops=fills,
                                     host_callbacks=cbs))
        if counters is not None:
            counters["analysis.jaxpr_programs"] += 1
            counters["analysis.jaxpr_violations"] += len(vs)
    return reports, violations


# ----------------------------------------------- the paper-query inventory
def collect_paper_programs(*, smoke: bool = True):
    """Run the seven paper queries (Table 2 patterns + triangle listing +
    the SSSP/PageRank fixpoints) plus one batched serving probe on a
    DeviceBackend with audit recording on; return ``(records, engine)``.

    The device backend runs on whatever jax platform is present (CPU in
    CI) — the traced programs are identical, which is why this audit is
    meaningful on both CI legs."""
    from repro.core import recursion as recursion_mod
    from repro.core.engine import Engine
    from repro.core.workload import ALIASES, TRIANGLE_LIST, paper_query_set
    from repro.data import powerlaw_graph

    n, deg = (60, 4) if smoke else (600, 8)
    g = powerlaw_graph(n, deg, 2.0, seed=0)
    src = np.repeat(np.arange(g.n), g.degrees)
    hub = int(np.argmax(g.degrees))

    eng = Engine(backend="device")
    eng.load_edges("Edge", src, g.neighbors)
    for al in ALIASES:
        eng.alias(al, "Edge")

    records: list[tuple] = []
    eng.backend.audit_log = records
    recursion_mod.AUDIT_LOG = records
    try:
        queries = list(paper_query_set(source=hub))
        queries.insert(1, ("triangle_list", TRIANGLE_LIST))
        for qname, q in queries:
            before = len(records)
            eng.query(q)
            # label this query's records (run_bag appends unnamed)
            for i in range(before, len(records)):
                rec = records[i]
                records[i] = (rec[0], f"{qname}::{rec[0]}{i - before}",
                              *rec[2:])
        # the batched serving path: one vmapped program over B probes
        pq = eng.prepare(
            "C(;w:long) :- R(0,y),S(y,z),T(0,z); w=<<COUNT(*)>>.")
        before = len(records)
        pq.run_batch([hub, 0, 1, 2])
        for i in range(before, len(records)):
            rec = records[i]
            records[i] = (rec[0], f"serve_batch::{rec[0]}{i - before}",
                          *rec[2:])
    finally:
        eng.backend.audit_log = None
        recursion_mod.AUDIT_LOG = None
    return records, eng


def audit_paper_queries(*, smoke: bool = True):
    records, eng = collect_paper_programs(smoke=smoke)
    reports, violations = audit_records(records,
                                        counters=eng.backend.stats)
    return reports, violations


# --------------------------------------------------------------- baseline
def baseline_counts(reports: list[ProgramReport]) -> dict[str, int]:
    return {r.name: r.fill_loops for r in
            sorted(reports, key=lambda r: r.name)}


def load_baseline(path: pathlib.Path = DEFAULT_BASELINE) -> dict[str, int]:
    return {str(k): int(v)
            for k, v in json.loads(path.read_text()).items()}


def write_baseline(reports: list[ProgramReport],
                   path: pathlib.Path = DEFAULT_BASELINE) -> None:
    path.write_text(json.dumps(baseline_counts(reports), indent=2) + "\n")


def compare(reports: list[ProgramReport],
            baseline: dict[str, int]) -> tuple[list[str], list[str]]:
    """(new, removed) program/loop drift — both directions fail CI."""
    counts = baseline_counts(reports)
    new = sorted(f"{k} ({v} loop(s), baseline {baseline.get(k, 'absent')})"
                 for k, v in counts.items() if baseline.get(k) != v)
    removed = sorted(f"{k} ({v} loop(s))"
                     for k, v in baseline.items() if k not in counts)
    return new, removed


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    write = "--write-baseline" in argv
    smoke = "--full" not in argv
    reports, violations = audit_paper_queries(smoke=smoke)
    for r in reports:
        print(f"ok: {r.name} ({r.fill_loops} fill loop(s), "
              f"{r.n_eqns} eqn(s), {r.host_callbacks} host callback(s))")
    if violations:
        print(f"{len(violations)} trace-level violation(s) "
              f"(never baselinable):")
        for v in violations:
            print(f"  {v}")
        return 1
    if write:
        write_baseline(reports)
        print(f"wrote {DEFAULT_BASELINE.name}: "
              f"{len(reports)} audited program(s)")
        return 0
    try:
        baseline = load_baseline()
    except FileNotFoundError:
        print(f"missing {DEFAULT_BASELINE}; run with --write-baseline")
        return 1
    new, removed = compare(reports, baseline)
    if new:
        print("program/loop drift vs baseline (a new launch or a changed "
              "loop structure):")
        for k in new:
            print(f"  + {k}")
    if removed:
        print("baselined programs no longer audited (coverage shrank) — "
              "refresh with --write-baseline if intended:")
        for k in removed:
            print(f"  - {k}")
    return 1 if (new or removed) else 0


if __name__ == "__main__":
    raise SystemExit(main())
