"""Pallas kernel contract checker + the ``REPRO_SANITIZE`` dispatch mode.

Static pass (:func:`check_all` / :func:`check_contract`): each kernel
package under ``repro.kernels`` that participates in engine dispatch
(``uint_intersect``, ``bitset_intersect``, ``materialize``) publishes a
``CONTRACT`` in its ``ops.py`` — representative inputs, the dispatch
entry point, and the package's pure-jnp ``ref.py`` oracle.  The checker
clears the jit cache and runs the entry with ``pl.pallas_call``
instrumented, so every launch is captured at trace time with its
declared geometry, then cross-checks:

  * grid is a tuple of positive ints and every ``BlockSpec`` block shape
    tiles its operand exactly (the kernels pad to tile geometry in
    ``ops.py`` — a partial block reaching ``pallas_call`` is a bug);
  * every index map stays in bounds over the WHOLE grid, and the output
    index maps jointly cover every output block (an uncovered block is
    silently-uninitialized memory);
  * the entry's output pytree matches ``jax.eval_shape`` of the oracle —
    same structure, shapes, dtypes — and the interpret-mode values match
    the oracle numerically.

Runtime pass (:func:`check_dispatch`, wired into ``Engine._execute``
behind ``REPRO_SANITIZE=1``): after each rule executes, assert the
backend's dispatch-counter DELTA matches what the validated physical
plan predicted — pair-cohort kernels only fire when some bag routed to
them, and the host-sync budget (ROADMAP item 3: at most one
``device_get`` per fused extension on the device backend, one per probe
atom on the numpy oracle) holds.  Violations raise
:class:`SanitizeError` — a counter mismatch means the plan annotations
and the runtime disagreed about what actually ran.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math

import numpy as np

from repro.core.plan_ir import Extend, PhysicalPlan, TerminalFold


class KernelContractError(AssertionError):
    """A Pallas kernel's declared geometry contradicts its oracle."""


class SanitizeError(AssertionError):
    """Runtime dispatch counters contradict the validated plan."""


class KernelVmapDivergence(KernelContractError):
    """``jax.vmap``'s batching rule rewrote a kernel's launch geometry
    away from its declared per-launch contract (extra grid dim, Mapped
    block dims, mixed-rank blocks).  Values may still be bit-exact —
    the divergence is that the static tiling contract no longer
    describes the lowered launch."""


# ------------------------------------------------------- pallas capture
@dataclasses.dataclass
class CapturedCall:
    kernel_name: str
    grid: tuple
    in_specs: list
    out_specs: list
    out_shape: list          # ShapeDtypeStructs
    operands: list           # ShapeDtypeStructs of the actual inputs


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    if isinstance(x, (list, tuple)):
        return tuple(x)
    return (x,)


def _kernel_label(kernel) -> str:
    return getattr(kernel, "__name__",
                   getattr(getattr(kernel, "func", None), "__name__",
                           repr(kernel)))


@contextlib.contextmanager
def capture_pallas_calls():
    """Instrument ``pl.pallas_call`` so every launch records its declared
    geometry and actual operand avals; yields the record list."""
    import jax.experimental.pallas as pl
    real = pl.pallas_call
    records: list[CapturedCall] = []

    def wrapper(kernel, **kw):
        inner = real(kernel, **kw)

        def call(*operands):
            records.append(CapturedCall(
                kernel_name=_kernel_label(kernel),
                grid=_as_tuple(kw.get("grid")),
                in_specs=list(_as_tuple(kw.get("in_specs"))),
                out_specs=list(_as_tuple(kw.get("out_specs"))),
                out_shape=list(_as_tuple(kw.get("out_shape"))),
                operands=[_aval(o) for o in operands]))
            return inner(*operands)

        return call

    pl.pallas_call = wrapper
    try:
        yield records
    finally:
        pl.pallas_call = real


def _aval(x):
    import jax
    return jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype
                                if not hasattr(x, "dtype") else x.dtype)


# -------------------------------------------------------- geometry checks
_MAX_ENUM_GRID = 4096


def _check_spec(name: str, what: str, spec, aval, grid: tuple,
                covered: set | None = None) -> None:
    block = getattr(spec, "block_shape", None)
    index_map = getattr(spec, "index_map", None)
    if block is None or index_map is None:
        raise KernelContractError(
            f"{name}: {what} BlockSpec exposes no block_shape/index_map")
    shape = tuple(aval.shape)
    if len(block) != len(shape):
        raise KernelContractError(
            f"{name}: {what} block {block} rank-mismatches operand "
            f"{shape}")
    for d, (b, s) in enumerate(zip(block, shape)):
        if not (isinstance(b, int) and b >= 1):
            raise KernelContractError(
                f"{name}: {what} block dim {d} is {b!r}")
        if s % b != 0:
            raise KernelContractError(
                f"{name}: {what} block {block} does not tile operand "
                f"{shape} (ops.py must pad to tile geometry)")
    nblocks = tuple(s // b for b, s in zip(block, shape))
    if math.prod(grid) > _MAX_ENUM_GRID:
        # too large to enumerate — check the extreme corners only
        points = itertools.product(*[(0, g - 1) for g in grid])
    else:
        points = itertools.product(*[range(g) for g in grid])
    for pt in points:
        idx = index_map(*pt)
        idx = idx if isinstance(idx, tuple) else (idx,)
        if len(idx) != len(shape):
            raise KernelContractError(
                f"{name}: {what} index map returned rank-{len(idx)} index "
                f"for rank-{len(shape)} operand")
        for d, (i, nb) in enumerate(zip(idx, nblocks)):
            i = int(i)
            if not 0 <= i < nb:
                raise KernelContractError(
                    f"{name}: {what} index map out of bounds at grid {pt}: "
                    f"dim {d} block index {i} not in [0, {nb})")
        if covered is not None:
            covered.add(tuple(int(i) for i in idx))
    if covered is not None and math.prod(grid) <= _MAX_ENUM_GRID:
        want = set(itertools.product(*[range(n) for n in nblocks]))
        missing = want - covered
        if missing:
            raise KernelContractError(
                f"{name}: {what} index map never writes block(s) "
                f"{sorted(missing)[:4]} — uninitialized output")


def check_captured(name: str, rec: CapturedCall) -> None:
    if not rec.grid or not all(isinstance(g, int) and g >= 1
                               for g in rec.grid):
        raise KernelContractError(f"{name}: bad grid {rec.grid!r}")
    if len(rec.in_specs) != len(rec.operands):
        raise KernelContractError(
            f"{name}: {len(rec.in_specs)} in_specs for "
            f"{len(rec.operands)} operands")
    for i, (spec, aval) in enumerate(zip(rec.in_specs, rec.operands)):
        _check_spec(name, f"in_specs[{i}] ({rec.kernel_name})", spec, aval,
                    rec.grid)
    if len(rec.out_specs) != len(rec.out_shape):
        raise KernelContractError(
            f"{name}: {len(rec.out_specs)} out_specs for "
            f"{len(rec.out_shape)} outputs")
    for i, (spec, aval) in enumerate(zip(rec.out_specs, rec.out_shape)):
        _check_spec(name, f"out_specs[{i}] ({rec.kernel_name})", spec,
                    aval, rec.grid, covered=set())


# --------------------------------------------------------- contract check
def contracts() -> list:
    """The dispatch-participating kernel packages' CONTRACT records."""
    from repro.kernels.bitset_intersect import ops as bitset_ops
    from repro.kernels.frontier_fill import ops as frontier_fill_ops
    from repro.kernels.materialize import ops as materialize_ops
    from repro.kernels.uint_intersect import ops as uint_ops
    return [uint_ops.CONTRACT, bitset_ops.CONTRACT,
            materialize_ops.CONTRACT, frontier_fill_ops.CONTRACT]


def check_contract(contract: dict) -> int:
    """Verify one kernel package; returns the number of captured
    launches (>= 1, or the entry silently skipped the kernel)."""
    import jax

    name = contract["name"]
    inputs = contract["make_inputs"]()
    # force a fresh trace: the entries are jitted, and a cache hit would
    # skip the Python body (and with it the pallas_call capture).
    # NB ``jax.disable_jit()`` is NOT an option — pallas_call's eager impl
    # re-binds through jit and recurses forever when jit is a no-op.
    jax.clear_caches()
    with capture_pallas_calls() as records:
        out = contract["entry"](*inputs)
    if not records:
        raise KernelContractError(
            f"{name}: entry launched no Pallas kernel on the contract "
            f"inputs — the capture saw nothing to check")
    for rec in records:
        check_captured(name, rec)
    # oracle signature: same pytree structure, shapes, dtypes
    expect = jax.eval_shape(contract["ref"], *inputs)
    got_flat = _as_tuple(out if isinstance(out, (list, tuple)) else (out,))
    exp_flat = _as_tuple(expect if isinstance(expect, (list, tuple))
                         else (expect,))
    if len(got_flat) != len(exp_flat):
        raise KernelContractError(
            f"{name}: entry returns {len(got_flat)} arrays, oracle "
            f"{len(exp_flat)}")
    for i, (g, e) in enumerate(zip(got_flat, exp_flat)):
        if tuple(np.shape(g)) != tuple(e.shape) or \
                np.asarray(g).dtype != np.dtype(e.dtype):
            raise KernelContractError(
                f"{name}: output[{i}] is {np.shape(g)}/{np.asarray(g).dtype}"
                f", oracle says {tuple(e.shape)}/{np.dtype(e.dtype)}")
    # and interpret-mode values match the oracle numerically (these are
    # exact integer kernels — no tolerance)
    ref_out = contract["ref"](*inputs)
    ref_flat = _as_tuple(ref_out if isinstance(ref_out, (list, tuple))
                         else (ref_out,))
    for i, (g, r) in enumerate(zip(got_flat, ref_flat)):
        if not np.array_equal(np.asarray(g), np.asarray(r)):
            raise KernelContractError(
                f"{name}: output[{i}] differs from the ref.py oracle")
    return len(records)


def check_all() -> dict:
    """Run every registered kernel contract; returns name -> #launches."""
    return {c["name"]: check_contract(c) for c in contracts()}


# ----------------------------------------------------- vmap contract check
def vmap_contracts() -> list:
    from repro.kernels.frontier_fill import ops as frontier_fill_ops
    return [frontier_fill_ops.CONTRACT_VMAP]


def check_vmap_contract(contract: dict) -> None:
    """Vet one kernel under ``jax.vmap``: per-lane values must match the
    sequential oracle bit-exactly, AND the lowered batched launch must
    still satisfy the declared per-launch geometry contract.  Today the
    first half holds and the second does not — pallas_call's batching
    rule rewrites grid ``(1,)`` to ``(B, 1)`` and marks batched
    operands' leading block dim ``Mapped`` — so this raises
    :class:`KernelVmapDivergence` with the exact rewrite.  That
    divergence is WHY ``core.backend._bag_program_batch`` pins the fill
    stage to the jnp reference path; if a jax upgrade makes this pass,
    the pin can be revisited."""
    import jax

    name = contract["name"]
    inputs = contract["make_inputs"]()
    jax.clear_caches()
    out = contract["entry"](*inputs)
    ref = contract["ref"](*inputs)
    for i, (g, r) in enumerate(zip(out, ref)):
        if not np.array_equal(np.asarray(g), np.asarray(r)):
            raise KernelContractError(
                f"{name}: batched output[{i}] diverges from the per-lane "
                f"oracle — the batching rule broke kernel semantics")
    # geometry half: find the lowered pallas_call and compare its grid /
    # block shapes against the declared single-launch contract
    from repro.analysis.jaxpr_audit import iter_eqns
    closed = jax.make_jaxpr(contract["entry"])(*inputs)
    declared = tuple(contract["declared_grid"])
    for eqn, path, _ in iter_eqns(closed.jaxpr, into_pallas=False):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        grid = tuple(gm.grid)
        mapped = sum(1 for bm in gm.block_mappings
                     if any(not isinstance(d, (int, np.integer))
                            for d in bm.block_shape))
        ranks = {len(bm.block_shape) for bm in gm.block_mappings}
        if grid != declared or mapped or len(ranks) > 1:
            raise KernelVmapDivergence(
                f"{name}: vmap rewrote the launch at {path or '<top>'}: "
                f"grid {declared} -> {grid}, {mapped} block mapping(s) "
                f"gained a Mapped (non-integer) dim, block ranks {sorted(ranks)}"
                f" — per-lane values match the oracle, but the declared "
                f"per-launch tiling contract no longer describes the "
                f"lowered launch")
        return
    raise KernelContractError(
        f"{name}: no pallas_call found in the vmapped trace")


# ------------------------------------------------------- runtime sanitize
def check_dispatch(pplan: PhysicalPlan, delta: dict, metrics: dict,
                   backend_name: str) -> None:
    """Assert the dispatch-counter ``delta`` of one rule execution is
    consistent with the validated plan's routing annotations.

    Only SOUND assertions — ones no legitimate execution can trip:

      * no bag routes a fold to ``pair_kernel``  ⇒  zero
        ``fold.pair_count_calls`` (the binary-cohort kernels must not
        fire on plans that never routed to them);
      * additionally no ``pair_store`` extension  ⇒  zero
        ``extend.pair_materialize_calls``;
      * host-sync budget: the device backend syncs at most once per
        fused extension call; the numpy oracle at most once per probe
        atom per call (``TerminalFold``'s general path and the final
        top-down join also call ``extend`` internally, so the budget is
        per observed ``extend.calls``, not per planned step);
      * an executed bag (per-bag ``metrics`` carries ``level_actuals``
        only for bags actually run, not cache hits) that produced rows
        through a terminal fold must have registered >= 1 ``fold.calls``.
    """
    def fail(msg: str):
        raise SanitizeError(
            f"dispatch sanitizer: {msg}\n  plan routing: "
            f"{_routing_summary(pplan)}\n  delta: "
            f"{ {k: v for k, v in sorted(delta.items())} }")

    any_pair_fold = any(
        isinstance(s, TerminalFold) and s.routing == "pair_kernel"
        for b in pplan.bag_ops for s in b.steps)
    any_pair_extend = any(
        isinstance(s, Extend) and s.routing == "pair_store"
        for b in pplan.bag_ops for s in b.steps)
    if not any_pair_fold and delta.get("fold.pair_count_calls", 0):
        fail("pair-cohort fold kernel fired but no bag routed a fold to "
             "'pair_kernel'")
    if not any_pair_fold and not any_pair_extend \
            and delta.get("extend.pair_materialize_calls", 0):
        fail("pair-store materialize fired but no step routed to the "
             "layout store")

    ec = delta.get("extend.calls", 0)
    hs = delta.get("extend.host_syncs", 0)
    if backend_name == "device":
        # pipelined extensions NEVER sync per-extension (the frontier
        # lands once per join, counted as extend.closing_syncs); only
        # extensions served by the legacy per-extension path may sync
        budget = ec - delta.get("extend.pipeline_extends", 0)
        if (delta.get("extend.closing_syncs", 0)
                > delta.get("extend.pipeline_extends", 0)
                + delta.get("pipeline.device_folds", 0) + 1):
            fail("more closing syncs than pipelined steps + 1 — the "
                 "pipeline is landing more than once per join")
    else:
        # one sync per PROBE atom: every extension has at most
        # (constraining inputs - 1) probes; bound by the widest bag
        widest = max((len(b.scan.accesses) + len(b.scan.child_inputs)
                      for b in pplan.bag_ops), default=1)
        if pplan.final is not None:
            widest = max(widest, len(pplan.final.inputs))
        budget = ec * max(1, widest - 1)
    if hs > budget:
        fail(f"{hs} host syncs exceed the budget of {budget} for {ec} "
             f"extension calls on the {backend_name} backend (<=1 per "
             f"{'fused extension' if backend_name == 'device' else 'probe atom'})")

    executed = {op_id for op_id, m in metrics.items()
                if m and "level_actuals" in m}
    ran_fold_rows = any(
        b.materialize.op_id in executed
        and metrics[b.materialize.op_id].get("actual_rows", 0) > 0
        and any(isinstance(s, TerminalFold) for s in b.steps)
        for b in pplan.bag_ops)
    if ran_fold_rows and not delta.get("fold.calls", 0):
        fail("a terminal-fold bag executed and produced rows but no "
             "fold.calls were recorded")


def _routing_summary(pplan: PhysicalPlan) -> dict:
    out = {}
    for b in pplan.bag_ops:
        for s in b.steps:
            if isinstance(s, TerminalFold):
                out[f"bag#{b.materialize.op_id}.fold.{s.var}"] = s.routing
            elif isinstance(s, Extend) and s.routing != "search":
                out[f"bag#{b.materialize.op_id}.extend.{s.var}"] = s.routing
    return out


def main(argv: list | None = None) -> int:
    try:
        counts = check_all()
    except KernelContractError as e:
        print(f"FAIL: {e}")
        return 1
    for name, n in counts.items():
        print(f"ok: {name} ({n} captured launch(es))")
    for c in vmap_contracts():
        try:
            check_vmap_contract(c)
            print(f"ok: {c['name']} (batched lowering satisfies the "
                  f"declared contract — the fill_mode pin can be "
                  f"revisited)")
        except KernelVmapDivergence as e:
            # the known, typed divergence — parity holds, geometry does
            # not; tests/test_kernels.py pins the exact message
            print(f"pinned: {e}")
        except KernelContractError as e:
            print(f"FAIL: {e}")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
