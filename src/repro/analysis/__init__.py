"""Static verification layer (six passes, run before/around execution).

EmptyHeaded's bet is that a high-level query compiles into provably
correct low-level plans; this package makes the "provably" part checkable
instead of vibes:

  * :mod:`repro.analysis.plan_verify` — structural validator over every
    lowered :mod:`repro.core.plan_ir` DAG (schema/attribute-order
    consistency, connector retention, AGM-capped estimates, routing and
    bag-cache key well-formedness).  Wired into ``Engine`` behind
    ``verify_plans`` (default ON; ``REPRO_VERIFY_PLANS=off`` escape
    hatch) and into ``plan_search`` so every candidate is validated.
  * :mod:`repro.analysis.sync_lint` — AST pass over
    ``src/repro/{core,kernels}`` flagging host-transfer hazards inside
    jit/Pallas-traced code, gated against the committed baseline
    ``sync_baseline.json`` so ROADMAP item 3 ("kill the last host
    syncs") progress is monotone.
  * :mod:`repro.analysis.kernel_check` — per-Pallas-kernel contract
    checker (BlockSpec/grid/out_shape/dtype vs the ``ref.py`` oracle,
    index-map bounds), plus the ``REPRO_SANITIZE=1`` runtime dispatch
    assertions consumed by ``Engine``.
  * :mod:`repro.analysis.jaxpr_audit` — trace-level auditor: retraces
    every recorded bag program / batched program / device fixpoint to
    its jaxpr and proves zero host-callback primitives, a while-loop
    count matching the launch budget, frontier buffers exactly at the
    plan-declared pow2 capacities, no 64-bit dtype widening and no
    oversized broadcast materialization; ratcheted against
    ``jaxpr_baseline.json``.
  * :mod:`repro.analysis.memory_budget` — static HBM footprint model
    (trie level uploads + bitset block directories + frontier buffers ×
    batch + fixpoint state) cross-checked against the live device
    caches without a single transfer; ``serve.GraphStore`` budgets
    eviction on its model bytes.
  * :mod:`repro.analysis.concurrency_lint` — AST lock-discipline
    checker over the serving layer and the engine/backend shared state;
    defines the ``@guarded_by`` convention and keeps ``serve/``
    lock-clean (core findings accounted in
    ``concurrency_baseline.json``).
"""
from __future__ import annotations

from repro.analysis.concurrency_lint import guarded_by
from repro.analysis.plan_verify import (PlanVerificationError, PlanViolation,
                                        assert_valid, verify_physical_plan)

__all__ = [
    "PlanVerificationError",
    "PlanViolation",
    "assert_valid",
    "guarded_by",
    "verify_physical_plan",
]
