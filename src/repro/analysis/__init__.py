"""Static verification layer (three passes, run before/around execution).

EmptyHeaded's bet is that a high-level query compiles into provably
correct low-level plans; this package makes the "provably" part checkable
instead of vibes:

  * :mod:`repro.analysis.plan_verify` — structural validator over every
    lowered :mod:`repro.core.plan_ir` DAG (schema/attribute-order
    consistency, connector retention, AGM-capped estimates, routing and
    bag-cache key well-formedness).  Wired into ``Engine`` behind
    ``verify_plans`` (default ON; ``REPRO_VERIFY_PLANS=off`` escape
    hatch) and into ``plan_search`` so every candidate is validated.
  * :mod:`repro.analysis.sync_lint` — AST pass over
    ``src/repro/{core,kernels}`` flagging host-transfer hazards inside
    jit/Pallas-traced code, gated against the committed baseline
    ``sync_baseline.json`` so ROADMAP item 3 ("kill the last host
    syncs") progress is monotone.
  * :mod:`repro.analysis.kernel_check` — per-Pallas-kernel contract
    checker (BlockSpec/grid/out_shape/dtype vs the ``ref.py`` oracle,
    index-map bounds), plus the ``REPRO_SANITIZE=1`` runtime dispatch
    assertions consumed by ``Engine``.
"""
from __future__ import annotations

from repro.analysis.plan_verify import (PlanVerificationError, PlanViolation,
                                        assert_valid, verify_physical_plan)

__all__ = [
    "PlanVerificationError",
    "PlanViolation",
    "assert_valid",
    "verify_physical_plan",
]
