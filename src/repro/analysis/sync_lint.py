"""Host-sync linter: AST pass enforcing the ROADMAP's sync discipline.

The performance contract of the device backend (ROADMAP item 3) is a
*sync budget*: at most one host round-trip per Generic-Join attribute
extension, one ragged extraction in the materialize path, and one
closing transfer per recursion fixpoint.  Nothing in the runtime can
*prevent* a new ``.item()`` or ``np.*`` call from sneaking into a jitted
trace — it would silently force a device→host transfer per call and only
show up as a latency regression.  This linter makes the budget a static,
monotone property:

  * **traced-context hazards** — inside any function that jax traces
    (``@jax.jit`` in its spellings, or a kernel passed to
    ``pl.pallas_call``, including ``functools.partial``-wrapped ones),
    flag ``.item()`` calls, ``int()/float()/bool()`` coercions of
    non-literal values, any ``np.*`` call (host numpy inside a trace
    forces materialization), and ``if``/``while`` tests over ``jnp``
    expressions (implicit ``__bool__`` on a tracer).  Scanned across ALL
    of ``src/repro/{core,kernels}``; the codebase is clean today and must
    stay clean — these findings never enter the baseline legitimately.
  * **transfer points** — explicit host syncs (``jax.device_get``,
    ``.block_until_ready()``, ``np.nonzero``) in the modules that
    orchestrate device execution (``core/backend.py``,
    ``core/recursion.py``, ``kernels/**``).  These are *accounted*, not
    banned: the committed ``sync_baseline.json`` enumerates exactly
    today's known syncs.

``compare()`` fails in BOTH directions against the baseline: a new
finding is a regression (CI fails), and a finding that disappears means
a sync was actually removed — CI fails too, demanding the baseline file
shrink with it (run ``python -m repro.analysis.sync_lint
--write-baseline``), so ROADMAP progress is recorded monotonically.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import sys

_SRC_ROOT = pathlib.Path(__file__).resolve().parents[2]   # .../src
_REPRO_ROOT = _SRC_ROOT / "repro"
DEFAULT_BASELINE = pathlib.Path(__file__).with_name("sync_baseline.json")

# Packages the traced-context pass covers.
SCAN_PACKAGES = ("core", "kernels")

# Modules whose explicit transfer points are budgeted in the baseline:
# the device-orchestration layer. Host-side oracles (intersect.py's numpy
# reference paths, data generators, engine head materialization) transfer
# nothing from a device and stay out of the budget.
DEVICE_PATH_MODULES = ("core/backend.py", "core/recursion.py", "kernels/")

# Finding kinds. The first group only ever appears as a regression; the
# second group is the accounted budget.
TRACED_KINDS = ("item", "coerce", "np_call", "implicit_bool")
TRANSFER_KINDS = ("device_get", "block_until_ready", "np_nonzero")


@dataclasses.dataclass(frozen=True)
class Finding:
    file: str        # path relative to src/repro, posix separators
    qualname: str    # dotted enclosing def/class chain ("<module>" if none)
    kind: str
    lineno: int
    detail: str

    @property
    def key(self) -> str:
        """Baseline identity — line numbers excluded so unrelated edits
        above a known sync don't churn the baseline file."""
        return f"{self.file}::{self.qualname}::{self.kind}"

    def __str__(self) -> str:
        return (f"{self.file}:{self.lineno} [{self.kind}] "
                f"{self.qualname}: {self.detail}")


# --------------------------------------------------------------- AST pass
def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node: ast.AST) -> bool:
    """jax.jit / jit, possibly wrapped in (functools.)partial(jax.jit, …)
    or called as jax.jit(...)."""
    d = _dotted(node)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        head = _dotted(node.func)
        if head in ("jax.jit", "jit"):
            return True
        if head in ("functools.partial", "partial") and node.args:
            return _is_jit_expr(node.args[0])
    return False


def _kernel_name_of(node: ast.AST) -> str | None:
    """The function name a ``pl.pallas_call`` first argument refers to —
    a bare name or (functools.)partial(<name>, …) as in triangle_mm."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        head = _dotted(node.func)
        if head in ("functools.partial", "partial") and node.args:
            return _kernel_name_of(node.args[0])
    return None


class _ModuleScan(ast.NodeVisitor):
    """One pass collecting (a) jit-decorated defs, (b) names passed as
    pallas_call kernels, (c) every def node with its qualname."""

    def __init__(self):
        self.defs: dict[str, list[tuple[str, ast.AST]]] = {}  # name -> defs
        self.jit_defs: list[ast.AST] = []
        self.kernel_names: set[str] = set()
        self._stack: list[str] = []
        self.qualname: dict[ast.AST, str] = {}

    def _visit_def(self, node):
        q = ".".join(self._stack + [node.name])
        self.qualname[node] = q
        self.defs.setdefault(node.name, []).append((q, node))
        if any(_is_jit_expr(d) for d in node.decorator_list):
            self.jit_defs.append(node)
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def

    def visit_ClassDef(self, node):
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    def visit_Call(self, node):
        head = _dotted(node.func)
        if head is not None and head.split(".")[-1] == "pallas_call" \
                and node.args:
            name = _kernel_name_of(node.args[0])
            if name is not None:
                self.kernel_names.add(name)
        elif head is not None and (head in ("jax.jit", "jit")) and node.args:
            # jax.jit(fn) call form
            name = _kernel_name_of(node.args[0])
            if name is not None:
                self.kernel_names.add(name)
        self.generic_visit(node)


def _contains_jnp(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id == "jnp":
            return True
    return False


def _traced_hazards(fn: ast.AST, qualname: str, file: str) -> list[Finding]:
    out = []

    def add(kind, lineno, detail):
        out.append(Finding(file, qualname, kind, lineno, detail))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            head = _dotted(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                add("item", node.lineno, ".item() forces a host transfer "
                    "inside a traced function")
            elif head is not None and head.split(".")[0] in ("np", "numpy"):
                add("np_call", node.lineno,
                    f"host numpy call {head}() inside a traced function")
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("int", "float", "bool") \
                    and node.args \
                    and not isinstance(node.args[0], ast.Constant):
                add("coerce", node.lineno,
                    f"{node.func.id}() coercion of a traced value")
        elif isinstance(node, (ast.If, ast.While)) \
                and _contains_jnp(node.test):
            add("implicit_bool", node.lineno,
                "branch test over a jnp expression (implicit __bool__ on "
                "a tracer)")
    return out


def _transfer_points(tree: ast.Module, scan: _ModuleScan,
                     file: str) -> list[Finding]:
    out = []
    # map every node to its enclosing def qualname via a second walk
    owner: dict[ast.AST, str] = {}

    def paint(node, q):
        for child in ast.iter_child_nodes(node):
            q2 = scan.qualname.get(child, q)
            owner[child] = q2
            paint(child, q2)

    paint(tree, "<module>")
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        head = _dotted(node.func)
        q = owner.get(node, "<module>")
        if head in ("jax.device_get", "device_get"):
            out.append(Finding(file, q, "device_get", node.lineno,
                               "explicit device→host transfer"))
        elif head in ("np.nonzero", "numpy.nonzero"):
            out.append(Finding(file, q, "np_nonzero", node.lineno,
                               "ragged host extraction (np.nonzero)"))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "block_until_ready":
            out.append(Finding(file, q, "block_until_ready", node.lineno,
                               "explicit device sync"))
    return out


def lint_source(source: str, file: str) -> list[Finding]:
    """Lint one module's source. ``file`` is the repo-relative label
    (posix, relative to ``src/repro``) used for finding identity and for
    deciding whether transfer points are in the budgeted scope."""
    tree = ast.parse(source, filename=file)
    scan = _ModuleScan()
    scan.visit(tree)
    traced = list(scan.jit_defs)
    for name in scan.kernel_names:
        traced.extend(d for _, d in scan.defs.get(name, []))
    # nested defs inside a traced function are traced too
    traced_set = []
    seen = set()
    for fn in traced:
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(node) not in seen:
                seen.add(id(node))
                traced_set.append(node)
    findings = []
    for fn in traced_set:
        findings.extend(_traced_hazards(fn, scan.qualname[fn], file))
    if file.startswith(DEVICE_PATH_MODULES):
        transfers = _transfer_points(tree, scan, file)
        # a transfer inside a traced fn is already a traced hazard; don't
        # double-report the same (qualname, line)
        reported = {(f.qualname, f.lineno) for f in findings}
        findings.extend(t for t in transfers
                        if (t.qualname, t.lineno) not in reported)
    return sorted(findings, key=lambda f: (f.file, f.lineno, f.kind))


def lint_tree(root: pathlib.Path = _REPRO_ROOT) -> list[Finding]:
    findings: list[Finding] = []
    for pkg in SCAN_PACKAGES:
        for path in sorted((root / pkg).rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            findings.extend(lint_source(path.read_text(), rel))
    return findings


# --------------------------------------------------------------- baseline
def baseline_counts(findings: list[Finding]) -> dict[str, int]:
    out: dict[str, int] = {}
    for f in findings:
        out[f.key] = out.get(f.key, 0) + 1
    return out


def load_baseline(path: pathlib.Path = DEFAULT_BASELINE) -> dict[str, int]:
    return {str(k): int(v)
            for k, v in json.loads(path.read_text()).items()}


def write_baseline(findings: list[Finding],
                   path: pathlib.Path = DEFAULT_BASELINE) -> None:
    counts = baseline_counts(findings)
    path.write_text(json.dumps(dict(sorted(counts.items())), indent=2)
                    + "\n")


def compare(findings: list[Finding],
            baseline: dict[str, int]) -> tuple[list[str], list[str]]:
    """(new, removed) vs the baseline — both non-empty lists fail CI."""
    counts = baseline_counts(findings)
    new = sorted(f"{k} (x{v - baseline.get(k, 0)})"
                 for k, v in counts.items() if v > baseline.get(k, 0))
    removed = sorted(f"{k} (x{v - counts.get(k, 0)})"
                     for k, v in baseline.items() if counts.get(k, 0) < v)
    return new, removed


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    write = "--write-baseline" in argv
    findings = lint_tree()
    traced = [f for f in findings if f.kind in TRACED_KINDS]
    if write:
        if traced:
            print("refusing to baseline traced-context hazards:")
            for f in traced:
                print(f"  {f}")
            return 1
        write_baseline(findings)
        print(f"wrote {DEFAULT_BASELINE.name}: {len(findings)} known "
              f"sync(s)")
        return 0
    try:
        baseline = load_baseline()
    except FileNotFoundError:
        print(f"missing {DEFAULT_BASELINE}; run with --write-baseline")
        return 1
    new, removed = compare(findings, baseline)
    for f in findings:
        print(f"known: {f}")
    if new:
        print("NEW host-sync hazards (fix them — the sync budget is "
              "monotone):")
        for k in new:
            print(f"  + {k}")
    if removed:
        print("syncs removed (congratulations) — shrink the baseline with "
              "--write-baseline:")
        for k in removed:
            print(f"  - {k}")
    return 1 if (new or removed) else 0


if __name__ == "__main__":
    raise SystemExit(main())
