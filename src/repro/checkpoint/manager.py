"""Sharding-aware checkpointing with atomic commit and elastic restore.

Layout:  <dir>/step_<k>/
             arrays.npz            flat {path: array} of the state pytree
             manifest.json         step, tree structure, shapes/dtypes, meta
         <dir>/step_<k>.tmp/       staging (renamed atomically on commit)
         <dir>/LATEST              text file with the last committed step

Restore takes a target pytree of shardings (or None for host arrays): the
saved arrays are re-sharded on load via ``jax.device_put``, so a checkpoint
written under mesh A restores under mesh B (elastic re-scale; tested in
tests/test_checkpoint.py). On a multi-host cluster each host writes its
addressable shards (process-indexed npz) — single-host here writes the full
arrays; the manifest format carries shard metadata either way.

Retention: ``keep_last`` committed checkpoints are retained; older ones are
deleted only after a newer commit succeeds (never delete-then-write).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, state, step: int, **meta):
        flat = _flatten_with_paths(state)
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "meta": meta,
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):        # overwrite: remove then commit
            shutil.rmtree(final)
        os.replace(tmp, final)           # atomic commit
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{s}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template``. ``shardings`` is an
        optional matching pytree of jax.sharding.Sharding — pass the NEW
        mesh's shardings to re-shard elastically."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_t = _flatten_with_paths(template)
        flat_s = _flatten_with_paths(shardings) if shardings is not None \
            else {k: None for k in flat_t}
        out = {}
        for k in flat_t:
            arr = data[k]
            expect = flat_t[k]
            assert tuple(arr.shape) == tuple(expect.shape), \
                (k, arr.shape, expect.shape)
            out[k] = jax.device_put(arr, flat_s.get(k)) \
                if flat_s.get(k) is not None else arr
        # rebuild tree
        leaves_paths = jax.tree_util.tree_flatten_with_path(template)
        keys_in_order = ["/".join(str(getattr(p, "key",
                                               getattr(p, "idx", p)))
                                  for p in path_)
                         for path_, _ in leaves_paths[0]]
        treedef = leaves_paths[1]
        return jax.tree_util.tree_unflatten(
            treedef, [out[k] for k in keys_in_order]), step

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}",
                               "manifest.json")) as f:
            return json.load(f)
