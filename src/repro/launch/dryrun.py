import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, builds the step function with
production shardings, ``.lower().compile()``s it against the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh, and records
memory_analysis / cost_analysis / parsed collective bytes. No arrays are
ever allocated — inputs are ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
  python -m repro.launch.dryrun --all --both-meshes --out experiments/dryrun
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import REGISTRY, get_arch
from repro.dist.act_sharding import use_mesh
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl


def sharded_bytes_per_chip(args, shardings) -> int:
    """Exact per-chip bytes of the step inputs under their shardings
    (params + optimizer state + caches + batch). This is the reliable
    'does the state fit' number; the CPU backend's temp_size has no
    TPU-grade buffer reuse and is reported only as an upper bound."""
    total = 0
    flat_a = jax.tree.leaves(args)
    flat_s = jax.tree.leaves(shardings,
                             is_leaf=lambda x: hasattr(x, "spec"))
    for sds, sh in zip(flat_a, flat_s):
        n = int(np.prod(sds.shape)) * sds.dtype.itemsize if sds.shape \
            else sds.dtype.itemsize
        shards = 1
        if hasattr(sh, "spec") and sh.spec is not None:
            mesh_shape = sh.mesh.shape
            for entry in sh.spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    shards *= mesh_shape[a]
        total += n // shards
    return total


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, fsdp: bool = True,
             serve_fsdp: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    arch = get_arch(arch_name)
    shape = arch.shape(shape_name)
    tag = f"{arch_name}/{shape_name}/{'multi' if multi_pod else 'single'}"
    if shape.skip:
        if verbose:
            print(f"[SKIP] {tag}: {shape.skip}")
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": shape.skip}

    t0 = time.monotonic()
    cell = build_cell(arch_name, shape_name, mesh, fsdp=fsdp,
                      serve_fsdp=serve_fsdp)
    with use_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        roof = rl.derive(compiled, chips, cell.model_flops, hlo_text=hlo)
    dt = time.monotonic() - t0

    mem_d = {}
    if mem is not None:
        # all memory_analysis fields are PER-DEVICE (verified empirically;
        # see EXPERIMENTS.md §Dry-run methodology)
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[k] = int(getattr(mem, k, 0) or 0)
    mem_d["state_bytes_per_chip"] = sharded_bytes_per_chip(
        cell.args, cell.in_shardings)

    # cost_analysis cross-check (undercounts while-loop bodies — the
    # roofline uses the structural analyzer instead)
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        cost_d = {"flops": float(cost.get("flops", 0.0)),
                  "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    except Exception:
        cost_d = {}

    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips, "status": "ok", "kind": cell.kind,
        "description": cell.description,
        "model_params": cell.model_params,
        "memory": mem_d,
        "cost_analysis_raw": cost_d,
        "roofline": roof.to_dict(),
        "compile_seconds": dt,
    }
    if verbose:
        r = rec["roofline"]
        print(f"[OK]   {tag}  compile={dt:.1f}s  "
              f"state/chip={mem_d['state_bytes_per_chip']/2**30:.2f}GiB  "
              f"compute={r['t_compute_s']*1e3:.2f}ms "
              f"memory={r['t_memory_s']*1e3:.2f}ms "
              f"collective={r['t_collective_s']*1e3:.2f}ms "
              f"-> {r['bottleneck']}  mfu@roof={r['mfu_at_roofline']:.2%}")
        sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="disable weight FSDP (TP-only baseline)")
    ap.add_argument("--no-serve-fsdp", action="store_true",
                    help="serve cells: TP-only weights (no 2D sharding)")
    ap.add_argument("--out", default=None, help="JSON output directory")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for arch in REGISTRY.values():
            for shape in arch.shapes.values():
                cells.append((arch.name, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results, failures = [], []
    for multi in meshes:
        for (a, s) in cells:
            try:
                rec = run_cell(a, s, multi, fsdp=not args.no_fsdp,
                               serve_fsdp=not args.no_serve_fsdp)
            except Exception as e:
                print(f"[FAIL] {a}/{s}/{'multi' if multi else 'single'}: {e}")
                traceback.print_exc()
                rec = {"arch": a, "shape": s,
                       "mesh": "multi" if multi else "single",
                       "status": "fail", "error": str(e)}
                failures.append(rec)
            results.append(rec)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
                with open(os.path.join(args.out, name), "w") as f:
                    json.dump(rec, f, indent=1)

    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skip")
    print(f"\n=== dry-run: {ok} ok, {sk} skip, {len(failures)} fail, "
          f"{len(results)} total ===")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
