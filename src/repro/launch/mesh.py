"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run driver must be able to
set XLA_FLAGS before any jax initialization.

  single pod : (data=16, model=16)            = 256 chips (v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips

The 'pod' axis extends data parallelism across the ICI/DCN boundary
(gradient all-reduce hierarchy); 'model' carries TP/EP/SP intra-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke tests on the container CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def total_batch_shards(mesh) -> int:
    out = 1
    for a in batch_axes_of(mesh):
        out *= mesh.shape[a]
    return out
