"""Cell builders: one (arch x shape x mesh) -> (fn, input ShapeDtypeStructs,
in/out shardings, analytic-FLOP metadata).

This is the module the dry-run, the roofline analysis, and the launchers
share. Inputs are ShapeDtypeStructs throughout — nothing allocates until a
launcher feeds real arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchDef, ShapeDef
from repro.dist.act_sharding import with_batch_axes
from repro.dist.sharding import (GNN_RULES, LM_DENSE_FSDP_RULES, LM_RULES,
                                 RECSYS_RULES, resolve_batch_specs,
                                 resolve_param_specs, zero1_specs)
from repro.launch.mesh import batch_axes_of
from repro.models import transformer as tfm
from repro.models.gnn import dimenet as dn
from repro.models.gnn import equivariant as eq
from repro.models.gnn import gcn as gcn_mod
from repro.models.recsys import fm as fm_mod
from repro.optim import adamw, linear_warmup_cosine
from repro.train import make_train_step

SDS = jax.ShapeDtypeStruct
ENGINE_PAD = np.int32(2**31 - 1)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable                 # positional args match ``args``
    args: Tuple[Any, ...]        # pytrees of ShapeDtypeStruct
    in_shardings: Tuple[Any, ...]
    out_shardings: Any           # pytree (None = compiler-chosen)
    model_flops: float           # analytic "useful" FLOPs per step
    model_params: int
    description: str = ""
    donate: Tuple[int, ...] = ()


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def build_cell(arch_name: str, shape_name: str, mesh: Mesh,
               fsdp: bool = True, serve_fsdp: bool = True,
               accum_steps: int = 1) -> Cell:
    arch = get_arch(arch_name)
    shape = arch.shape(shape_name)
    if shape.skip:
        raise ValueError(f"cell {arch_name}/{shape_name} is N/A: {shape.skip}")
    if arch.family == "lm":
        return _lm_cell(arch, shape, mesh, fsdp, serve_fsdp, accum_steps)
    if arch.family == "gnn":
        return _gnn_cell(arch, shape, mesh)
    if arch.family == "recsys":
        return _recsys_cell(arch, shape, mesh)
    if arch.family == "engine":
        return _engine_cell(arch, shape, mesh)
    raise ValueError(arch.family)


# ------------------------------------------------------------------ LM cells
def _lm_state(cfg, mesh, rules, fsdp):
    params_sds = jax.eval_shape(lambda k: tfm.init(k, cfg),
                                jax.random.PRNGKey(0))
    axes = tfm.param_axes(cfg)
    pspecs = resolve_param_specs(axes, params_sds, mesh, rules, fsdp=fsdp)
    return params_sds, pspecs


def _batch_spec(b: int, mesh, axes: Tuple[str, ...]) -> P:
    """Longest divisible prefix of the composed batch axes."""
    axes = tuple(a for a in axes if a in mesh.shape)
    while axes:
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if b % total == 0:
            return P(axes)
        axes = axes[:-1]
    return P(None)


def _lm_cell(arch: ArchDef, shape: ShapeDef, mesh, fsdp, serve_fsdp,
             accum_steps) -> Cell:
    cfg = arch.config
    b = shape.params["global_batch"]
    s = shape.params["seq_len"]
    baxes = batch_axes_of(mesh)
    tokens_spec = _batch_spec(b, mesh, baxes)

    if shape.kind == "train":
        # dense archs train with the 2D-FSDP mapping (no TP — see
        # LM_DENSE_FSDP_RULES); MoE archs keep EP over 'model'
        rules = LM_RULES if cfg.is_moe else LM_DENSE_FSDP_RULES
        act_batch = ("pod", "data") if cfg.is_moe \
            else ("pod", "data", "model")
        tokens_spec = _batch_spec(b, mesh, ("pod",) + rules.batch_axes)
        params_sds, pspecs = _lm_state(cfg, mesh, rules, fsdp)
        opt = adamw(linear_warmup_cosine(3e-4, 100, 10_000),
                    mu_dtype=jnp.bfloat16, weight_decay=0.1)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = {k: zero1_specs(pspecs, params_sds, mesh, rules)
                  for k in ("mu", "nu")}
        state_sds = {"params": params_sds, "opt_state": opt_sds,
                     "step": SDS((), jnp.int32)}
        state_specs = {"params": pspecs, "opt_state": ospecs, "step": P()}
        batch_sds = {"tokens": SDS((b, s), jnp.int32),
                     "targets": SDS((b, s), jnp.int32)}
        batch_specs = {"tokens": tokens_spec, "targets": tokens_spec}
        step = with_batch_axes(make_train_step(
            lambda p, bt: tfm.loss_fn(p, bt, cfg), opt,
            accum_steps=accum_steps), act_batch)
        flops = 6.0 * cfg.active_param_count() * b * s \
            + 12.0 * cfg.n_layers * b * s * s * cfg.n_heads * cfg.d_head \
            * (0.5 if cfg.attention != "swa" else min(1.0, cfg.window / s))
        return Cell(arch.name, shape.name, "train", step,
                    (state_sds, batch_sds),
                    (_named(mesh, state_specs), _named(mesh, batch_specs)),
                    (_named(mesh, state_specs), None),
                    flops, cfg.param_count(),
                    f"{arch.name} train {b}x{s}")

    params_sds, pspecs = _lm_state(cfg, mesh, LM_RULES, serve_fsdp)
    cache_sds = jax.eval_shape(lambda: tfm.init_cache(cfg, b, s))
    cache_specs = resolve_batch_specs(
        tfm.cache_axes(cfg), cache_sds, mesh, LM_RULES)

    if shape.kind == "prefill":
        def fn(params, tokens):
            return tfm.prefill(params, tokens, cfg, max_len=s)
        flops = 2.0 * cfg.active_param_count() * b * s \
            + 4.0 * cfg.n_layers * b * s * s * cfg.n_heads * cfg.d_head \
            * (0.5 if cfg.attention != "swa" else min(1.0, cfg.window / s))
        logits_spec = P(baxes if b % np.prod(
            [mesh.shape[a] for a in baxes]) == 0 else None, "model") \
            if cfg.vocab % mesh.shape["model"] == 0 else P(None)
        return Cell(arch.name, shape.name, "prefill", fn,
                    (params_sds, SDS((b, s), jnp.int32)),
                    (_named(mesh, pspecs), NamedSharding(mesh, tokens_spec)),
                    (NamedSharding(mesh, logits_spec),
                     _named(mesh, cache_specs)),
                    flops, cfg.param_count(),
                    f"{arch.name} prefill {b}x{s}")

    assert shape.kind == "decode"
    step = tfm.decode_step_mla if cfg.attention == "mla" else tfm.decode_step

    def fn(params, cache, tokens):
        return step(params, cache, tokens, cfg)

    cache_tokens = min(s, cfg.window) if cfg.attention == "swa" else s
    if cfg.attention == "mla":
        cache_bytes_per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
    else:
        cache_bytes_per_tok = 2 * cfg.n_kv_heads * cfg.d_head
    flops = 2.0 * cfg.active_param_count() * b \
        + 2.0 * cfg.n_layers * b * cache_tokens * cache_bytes_per_tok
    return Cell(arch.name, shape.name, "decode", fn,
                (params_sds, cache_sds, SDS((b, 1), jnp.int32)),
                (_named(mesh, pspecs), _named(mesh, cache_specs),
                 NamedSharding(mesh, tokens_spec)),
                (None, _named(mesh, cache_specs)),
                flops, cfg.param_count(),
                f"{arch.name} decode b={b} cache={cache_tokens}",
                donate=(1,))


# ----------------------------------------------------------------- GNN cells
def _gnn_batch_sds(arch: ArchDef, shape: ShapeDef, mesh):
    """Build the batch ShapeDtypeStructs + specs for one GNN shape."""
    p = shape.params
    all_axes = tuple(mesh.shape.keys())
    n_shards = int(np.prod(list(mesh.shape.values())))

    if shape.name == "minibatch_lg":
        # sampled subgraph: seeds + fanout hops (see data.sampler)
        fanout = p["fanout"]
        sizes = [p["batch_nodes"]]
        for f in fanout:
            sizes.append(sizes[-1] * f)
        n = sum(sizes)
        e = sum(sizes[i] * fanout[i] for i in range(len(fanout)))
    elif shape.name == "molecule":
        n = p["batch"] * p["n_nodes"]
        e = p["batch"] * p["n_edges"]
    else:
        n = p["n_nodes"]
        e = p["n_edges"]
    epad = _round_up(e, n_shards)

    espec = P(all_axes)
    batch = {
        "senders": SDS((epad,), jnp.int32),
        "receivers": SDS((epad,), jnp.int32),
        "edge_mask": SDS((epad,), jnp.float32),
    }
    specs = {"senders": espec, "receivers": espec, "edge_mask": espec}

    name = arch.name
    if name == "gcn-cora":
        d_feat = p.get("d_feat", 16)
        n_classes = p.get("n_classes", 8)
        batch.update({"features": SDS((n, d_feat), jnp.float32),
                      "labels": SDS((n,), jnp.int32),
                      "mask": SDS((n,), jnp.float32)})
        specs.update({"features": P(None), "labels": P(None),
                      "mask": P(None)})
    else:
        batch.update({"species": SDS((n,), jnp.int32),
                      "positions": SDS((n, 3), jnp.float32)})
        specs.update({"species": P(None), "positions": P(None)})
        n_graphs = p.get("batch", 1)
        batch.update({"graph_id": SDS((n,), jnp.int32),
                      "energy": SDS((n_graphs,), jnp.float32)})
        specs.update({"graph_id": P(None), "energy": P(None)})
        if name == "dimenet":
            from repro.configs.dimenet import TRIPLET_FACTOR
            t = _round_up(e * TRIPLET_FACTOR[shape.name], n_shards)
            batch.update({"t_e1": SDS((t,), jnp.int32),
                          "t_e2": SDS((t,), jnp.int32),
                          "t_mask": SDS((t,), jnp.float32)})
            specs.update({"t_e1": espec, "t_e2": espec, "t_mask": espec})
    return batch, specs, n, epad


def _gnn_model(arch: ArchDef, shape: ShapeDef):
    """(init, loss_fn, param_axes, cfg) for the arch, with per-shape
    d_feat/n_classes overrides for GCN (each shape is its own dataset)."""
    if arch.name == "gcn-cora":
        # per-shape dataset dims (molecule has none -> small defaults,
        # matching _gnn_batch_sds)
        cfg = dataclasses.replace(
            arch.config,
            d_feat=shape.params.get("d_feat", 16),
            n_classes=shape.params.get("n_classes", 8))
        return gcn_mod.init, gcn_mod.loss_fn, gcn_mod.param_axes, cfg
    if arch.name == "dimenet":
        return dn.init, dn.loss_fn, dn.param_axes, arch.config
    if arch.name == "nequip":
        return eq.init, eq.loss_fn, eq.param_axes, arch.config
    if arch.name == "mace":
        return eq.mace_init, eq.mace_loss_fn, eq.mace_param_axes, arch.config
    raise ValueError(arch.name)


def _gnn_flops(arch: ArchDef, shape: ShapeDef, n: int, e: int) -> float:
    cfg = arch.config
    if arch.name == "gcn-cora":
        d_feat = shape.params.get("d_feat", 16)
        dims = [d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) \
            + [shape.params.get("n_classes", 8)]
        fwd = sum(2.0 * n * dims[i] * dims[i + 1] + 2.0 * e * dims[i + 1]
                  for i in range(cfg.n_layers))
        return 3.0 * fwd
    if arch.name == "dimenet":
        from repro.configs.dimenet import TRIPLET_FACTOR
        t = e * TRIPLET_FACTOR[shape.name]
        d, nb = cfg.d_hidden, cfg.n_bilinear
        per_block = (2.0 * e * d * d * 4
                     + 2.0 * t * (cfg.n_spherical * cfg.n_radial * nb
                                  + nb * d * d / 64))   # bilinear: see model
        # the bilinear einsum is t * nb * d * d
        per_block = 2.0 * e * d * d * 4 + 2.0 * t * nb * d * d
        return 3.0 * cfg.n_blocks * per_block
    # nequip / mace: per-path depthwise TP + channel mixes
    c = cfg.d_hidden
    n_paths = len(cfg.paths)
    tp = sum(2.0 * e * c * (2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
             for (l1, l2, l3) in cfg.paths)
    mix = 2.0 * n * c * c * (cfg.l_max + 1) ** 2
    radial = 2.0 * e * (cfg.n_rbf * cfg.radial_hidden
                        + cfg.radial_hidden * n_paths * c)
    per_layer = tp + 4.0 * mix + radial
    if arch.name == "mace":
        per_layer += 3.0 * sum(
            2.0 * n * c * (2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
            for (l1, l2, l3) in cfg.paths)
    return 3.0 * cfg.n_layers * per_layer


def _gnn_cell(arch: ArchDef, shape: ShapeDef, mesh) -> Cell:
    init, loss_fn, param_axes, cfg = _gnn_model(arch, shape)
    batch_sds, batch_specs, n, epad = _gnn_batch_sds(arch, shape, mesh)
    params_sds = jax.eval_shape(lambda k: init(k, cfg),
                                jax.random.PRNGKey(0))
    pspecs = resolve_param_specs(param_axes(cfg), params_sds, mesh,
                                 GNN_RULES, fsdp=False)
    opt = adamw(1e-3)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    ospecs = {k: pspecs for k in ("mu", "nu")}
    state_sds = {"params": params_sds, "opt_state": opt_sds,
                 "step": SDS((), jnp.int32)}
    state_specs = {"params": pspecs, "opt_state": ospecs, "step": P()}
    step = make_train_step(lambda p, bt: loss_fn(p, bt, cfg), opt)
    return Cell(arch.name, shape.name, "train", step,
                (state_sds, batch_sds),
                (_named(mesh, state_specs), _named(mesh, batch_specs)),
                (_named(mesh, state_specs), None),
                _gnn_flops(arch, shape, n, epad), cfg.param_count(),
                f"{arch.name} {shape.name} N={n} E={epad}")


# -------------------------------------------------------------- recsys cells
def _recsys_cell(arch: ArchDef, shape: ShapeDef, mesh) -> Cell:
    cfg = arch.config
    baxes = batch_axes_of(mesh)
    n_batch_shards = int(np.prod([mesh.shape[a] for a in baxes]))
    params_sds = jax.eval_shape(lambda k: fm_mod.init(k, cfg),
                                jax.random.PRNGKey(0))
    pspecs = resolve_param_specs(fm_mod.param_axes(cfg), params_sds, mesh,
                                 RECSYS_RULES, fsdp=False)
    b = shape.params["batch"]
    ids_spec = P(baxes if b % n_batch_shards == 0 else None, None)
    lbl_spec = P(baxes if b % n_batch_shards == 0 else None)

    if shape.kind == "train":
        opt = adamw(1e-3)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = {k: pspecs for k in ("mu", "nu")}
        state_sds = {"params": params_sds, "opt_state": opt_sds,
                     "step": SDS((), jnp.int32)}
        state_specs = {"params": pspecs, "opt_state": ospecs, "step": P()}
        batch_sds = {"ids": SDS((b, cfg.n_sparse), jnp.int32),
                     "label": SDS((b,), jnp.float32)}
        batch_specs = {"ids": ids_spec, "label": lbl_spec}
        step = make_train_step(lambda p, bt: fm_mod.loss_fn(p, bt, cfg), opt)
        flops = 3.0 * (2.0 * b * cfg.n_sparse * cfg.embed_dim * 2)
        return Cell(arch.name, shape.name, "train", step,
                    (state_sds, batch_sds),
                    (_named(mesh, state_specs), _named(mesh, batch_specs)),
                    (_named(mesh, state_specs), None),
                    flops, cfg.param_count(),
                    f"fm train b={b}")

    if shape.kind == "score":
        def fn(params, ids):
            return fm_mod.forward(params, {"ids": ids}, cfg)
        flops = 2.0 * b * cfg.n_sparse * cfg.embed_dim * 2
        return Cell(arch.name, shape.name, "score", fn,
                    (params_sds, SDS((b, cfg.n_sparse), jnp.int32)),
                    (_named(mesh, pspecs), NamedSharding(mesh, ids_spec)),
                    None, flops, cfg.param_count(), f"fm score b={b}")

    assert shape.kind == "retrieval"
    nc = shape.params["n_candidates"]
    cand_spec = P(baxes) if nc % n_batch_shards == 0 else P(None)

    def fn(params, user_ids, cand_ids):
        return fm_mod.retrieval_scores(params, user_ids, cand_ids, cfg)

    flops = 2.0 * nc * cfg.embed_dim
    return Cell(arch.name, shape.name, "retrieval", fn,
                (params_sds, SDS((16,), jnp.int32), SDS((nc,), jnp.int32)),
                (_named(mesh, pspecs), NamedSharding(mesh, P(None)),
                 NamedSharding(mesh, cand_spec)),
                None, flops, cfg.param_count(),
                f"fm retrieval 1x{nc}")


# -------------------------------------------------------------- engine cells
def engine_triangle_count_search(adj, edges):
    """Edge-parallel WCOJ triangle count, lockstep binary search variant
    (min property — the SIMDGalloping side of Algorithm 2). BASELINE in
    §Perf: the log2(K) search loop re-reads the gathered rows every
    iteration (7x HBM traffic on the padded-ELL layout)."""
    u, v = edges[:, 0], edges[:, 1]
    nu = adj[u]                          # [E, K]
    nv = adj[v]                          # [E, K]
    k = adj.shape[1]
    pos = jax.vmap(jnp.searchsorted)(nv, nu)
    pos = jnp.clip(pos, 0, k - 1)
    found = (jnp.take_along_axis(nv, pos, axis=1) == nu) & (nu != ENGINE_PAD)
    return found.sum(dtype=jnp.int64)


def engine_triangle_count(adj, edges, kv_blk: int = 16):
    """Edge-parallel WCOJ triangle count, blocked membership-test variant
    (the SIMDShuffling side of Algorithm 2, which fits the similar-
    cardinality padded-ELL rows; TPU-adapted as tile-vs-tile compares —
    the formulation of kernels/uint_intersect). One HBM pass over the
    gathered rows; the K x kv_blk compare cube stays in registers/VMEM.
    10.5x lower memory roofline term than the search variant
    (EXPERIMENTS.md §Perf). Edges shard over the whole mesh; the scalar
    partial sums all-reduce at the end (the paper's 48-thread
    parallelism at 512-chip scale)."""
    nu = adj[edges[:, 0]]                # [E, K]
    nv = adj[edges[:, 1]]
    k = adj.shape[1]

    def blk(carry, j):
        sl = jax.lax.dynamic_slice_in_dim(nv, j * kv_blk, kv_blk, 1)
        hit = (nu[:, :, None] == sl[:, None, :]).any(axis=2)
        return carry | hit, None

    hit0 = jnp.zeros(nu.shape, bool)
    hit, _ = jax.lax.scan(blk, hit0, jnp.arange(k // kv_blk))
    return (hit & (nu != ENGINE_PAD)).sum(dtype=jnp.int64)


def _engine_cell(arch: ArchDef, shape: ShapeDef, mesh) -> Cell:
    p = shape.params
    n, e, k = p["n_nodes"], p["n_edges"], p["ell_width"]
    all_axes = tuple(mesh.shape.keys())
    n_shards = int(np.prod(list(mesh.shape.values())))
    epad = _round_up(e, n_shards)
    args = (SDS((n, k), jnp.int32), SDS((epad, 2), jnp.int32))
    shardings = (NamedSharding(mesh, P(None, None)),
                 NamedSharding(mesh, P(all_axes, None)))
    # per edge: K searches x log2(K) steps, 2 flops each + K compares
    flops = epad * (k * np.log2(k) * 2 + k)
    return Cell(arch.name, shape.name, "engine", engine_triangle_count,
                args, shardings, None, float(flops), 0,
                f"emptyheaded triangle count E={epad} K={k}")
