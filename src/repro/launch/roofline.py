"""Roofline-term derivation from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs            / (chips * 197e12  bf16 FLOP/s)
  memory     = HLO_bytes            / (chips * 819e9   B/s HBM)
  collective = collective_bytes     / (chips * 50e9    B/s per ICI link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are parsed from the optimized HLO text (cost_analysis does not report
them): the summed output-operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO result shape:  bf16[8,128]{1,0}  /  f32[]  /  (tuple, ...)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind over the optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-defining lines look like: %name = TYPE[shape] op-name(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # -done pairs with -start; count once
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(
            rhs.split("(")[0]))
        out[kind] += total
        out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    """All inputs are PER-CHIP (optimized HLO is the per-device SPMD
    program); ``model_flops`` is the GLOBAL analytic step cost."""

    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    chips: int
    model_flops: float

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global vs per-chip x chips): > 1 means
        the compiled program does LESS than the analytic count (e.g.
        causal-block skipping); < 1 flags remat/redundant compute."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def step_time(self) -> float:
        """Roofline step-time estimate = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline estimate."""
        t = self.step_time
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / t if t else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "chips": self.chips, "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flop_frac": self.useful_flop_frac,
            "roofline_step_s": self.step_time, "mfu_at_roofline": self.mfu,
        }


def derive(compiled, chips: int, model_flops: float,
           hlo_text: Optional[str] = None) -> Roofline:
    """Preferred path: the structural HLO analyzer (correct while-loop
    trip-count multipliers). ``compiled.cost_analysis()`` is kept as a
    cross-check in the dry-run JSON (it undercounts scan bodies)."""
    from repro.launch import hlo_analysis

    text = hlo_text if hlo_text is not None else compiled.as_text()
    st = hlo_analysis.analyze(text)
    coll = {k: int(v) for k, v in st.coll.items()}
    coll["count"] = st.coll_count
    return Roofline(st.flops, st.bytes_accessed, st.coll_bytes, coll,
                    chips, model_flops)
