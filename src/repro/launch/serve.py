"""Serving launcher: batched greedy decoding on a reduced config.

``python -m repro.launch.serve --arch minicpm3-4b --requests 8``
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.train import reduced_lm_config
from repro.models import transformer as tfm
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    assert arch.family == "lm"
    cfg = reduced_lm_config(arch.config)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=args.batch_slots,
                      max_len=args.prompt_len + args.new_tokens + 1)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32), max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    outs = eng.run(reqs)
    for i, o in enumerate(outs):
        print(f"req{i}: {o}")


if __name__ == "__main__":
    main()
