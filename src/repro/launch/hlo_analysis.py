"""Structural analyzer for optimized (post-SPMD, post-fusion) HLO text.

Why: ``compiled.cost_analysis()`` counts each instruction ONCE, so anything
inside a ``while`` body (every ``lax.scan`` — our layer stack, flash-
attention blocks, grad accumulation) is undercounted by its trip count.
This module re-derives the roofline inputs with correct multipliers:

  * per-computation call graph with while-loop trip counts (parsed from the
    loop condition's ``compare(iv, constant(N))``),
  * dot FLOPs (2 * prod(output dims) * prod(contracting dims)) from each
    computation's local symbol table,
  * fusion-aware HBM bytes: one read per fusion operand + one write per
    fusion output (that is what fusion means); non-fused compute ops count
    operands+outputs; bookkeeping ops (parameter/tuple/gte/bitcast/copy
    /constant) are free,
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), -start/-done deduplicated.

All shapes in optimized HLO are PER-DEVICE (SPMD partitioned), so every
number this module returns is per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "add-dependency", "opt-barrier", "iota"}

# a (possibly tuple) HLO type, e.g. bf16[8,128]{1,0} or (f32[2], s32[])
_SHAPE_ATOM = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-~]+)\s*\(.*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_ATOM.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _type_elems(type_str: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_ATOM.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_fusion: bool = False

    def table(self) -> Dict[str, str]:
        return {i.name: i.type_str for i in self.instrs}


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    """Returns (computations, entry_name). Fusion-called computations are
    marked after the parse (any ``calls=%X`` target of a fusion op)."""
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and " -> " in s:
                cur = Computation(m.group(1), [])
                if s.startswith("ENTRY"):
                    entry = cur.name
            continue
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(s)
        if dm:
            cur.instrs.append(Instr(dm.group(1), dm.group(2), dm.group(3),
                                    s))
    # mark fusion targets
    for c in comps.values():
        for i in c.instrs:
            if i.op == "fusion":
                fm = re.search(r"calls=%?([\w.\-~]+)", i.line)
                if fm and fm.group(1) in comps:
                    comps[fm.group(1)].is_fusion = True
    return comps, (entry or (next(iter(comps)) if comps else ""))


def _dot_flops(instr: Instr, table: Dict[str, str]) -> float:
    """2 * prod(out dims) * prod(lhs contracting dims)."""
    out_elems = _type_elems(instr.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if not m:
        return 2.0 * out_elems  # unusual dot; minimal count
    cdims = [int(x) for x in m.group(1).split(",") if x != ""]
    ops = _OPERAND_RE.findall(instr.line.split("(", 1)[1])
    lhs_type = table.get(ops[0]) if ops else None
    k = 1
    if lhs_type:
        atom = _SHAPE_ATOM.search(lhs_type)
        if atom and atom.group(2):
            dims = [int(d) for d in atom.group(2).split(",")]
            for c in cdims:
                if c < len(dims):
                    k *= dims[c]
    return 2.0 * out_elems * k


def _while_trip_count(cond: Computation,
                      comps: Dict[str, Computation]) -> int:
    """Find compare(iv, constant(N)) in the loop condition (searching
    through fused compare computations too)."""
    closure = [cond]
    for i in cond.instrs:
        fm = re.search(r"calls=%?([\w.\-~]+)", i.line)
        if fm and fm.group(1) in comps:
            closure.append(comps[fm.group(1)])
    # The bound constant may sit in the condition computation while the
    # compare lives inside a fused compare computation (operands are then
    # fusion parameters) — so: if the closure contains a compare at all,
    # the trip count is the largest positive integer constant in scope.
    has_compare = any(i.op == "compare" for c in closure for i in c.instrs)
    best = 0
    for c in closure:
        for i in c.instrs:
            if i.op != "constant":
                continue
            m = re.search(r"constant\((\d+)\)", i.line)
            if m:
                best = max(best, int(m.group(1)))
    return best if (has_compare and best > 0) else 1


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_count: int = 0
    while_trips: Dict[str, int] = dataclasses.field(default_factory=dict)
    # attribution: (computation, op name, kind, per-visit bytes, multiplier)
    coll_sites: List[Tuple[str, str, str, int, float]] = \
        dataclasses.field(default_factory=list)
    byte_sites: List[Tuple[str, str, str, int, float]] = \
        dataclasses.field(default_factory=list)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def top_collectives(self, n: int = 15):
        return sorted(self.coll_sites, key=lambda s: -s[3] * s[4])[:n]

    def top_bytes(self, n: int = 15):
        return sorted(self.byte_sites, key=lambda s: -s[3] * s[4])[:n]


def _fusion_bytes(instr: Instr, comps: Dict[str, Computation]):
    """Slice-aware HBM traffic of a fusion op.

    A fusion whose interior slices/updates big buffers only touches the
    sliced regions — counting its full operand list (the naive model)
    overstates traffic by orders of magnitude for scan-carry update
    fusions. Returns None for fusions without slicing interior ops (the
    caller then applies the plain operands+output model).
    """
    fm = re.search(r"calls=%?([\w.\-~]+)", instr.line)
    if not fm or fm.group(1) not in comps:
        return None
    inner = comps[fm.group(1)]
    has_slicing = any(i.op in ("dynamic-slice", "dynamic-update-slice",
                               "gather", "slice", "scatter")
                      for i in inner.instrs)
    if not has_slicing:
        return None
    table = inner.table()
    total = 0
    root_is_dus = False
    for i in inner.instrs:
        if i.op in ("dynamic-slice", "gather", "slice"):
            total += 2 * _type_bytes(i.type_str)
        elif i.op in ("dynamic-update-slice", "scatter"):
            ops = _OPERAND_RE.findall(i.line.split("(", 1)[1])
            upd = ops[1] if len(ops) > 1 else None
            total += 2 * _type_bytes(table.get(upd, "")) if upd else \
                2 * _type_bytes(i.type_str)
            if i.line.lstrip().startswith("ROOT"):
                root_is_dus = True
    if not root_is_dus:
        total += _type_bytes(instr.type_str)
    return total


def analyze(hlo: str) -> HloStats:
    comps, entry_name = parse_computations(hlo)
    entry = comps.get(entry_name)

    stats = HloStats()

    def visit(comp: Computation, mult: float):
        table = comp.table()
        for instr in comp.instrs:
            if instr.op == "while":
                m = re.search(r"body=%?([\w.\-~]+)", instr.line)
                c = re.search(r"condition=%?([\w.\-~]+)", instr.line)
                # authoritative: XLA records known_trip_count in the
                # backend_config; fall back to the condition-constant scan
                bt = re.search(r"known_trip_count\\?\":\{\\?\"n\\?\":\\?\""
                               r"(\d+)", instr.line)
                if bt:
                    trips = int(bt.group(1))
                elif c and c.group(1) in comps:
                    trips = _while_trip_count(comps[c.group(1)], comps)
                else:
                    trips = 1
                stats.while_trips[instr.name] = trips
                if m and m.group(1) in comps:
                    visit(comps[m.group(1)], mult * trips)
                continue
            if instr.op in ("call", "conditional"):
                for cm in re.finditer(
                        r"(?:to_apply|calls|branch_computations=\{|"
                        r"called_computations=\{)%?([\w.\-~]+)", instr.line):
                    cn = cm.group(1)
                    if cn in comps and not comps[cn].is_fusion:
                        visit(comps[cn], mult)
            if instr.op == "fusion":
                # dots inside the fused computation still count as flops
                fm = re.search(r"calls=%?([\w.\-~]+)", instr.line)
                if fm and fm.group(1) in comps:
                    fcomp = comps[fm.group(1)]
                    ftable = fcomp.table()
                    for fi in fcomp.instrs:
                        if fi.op == "dot":
                            stats.flops += mult * _dot_flops(fi, ftable)
                        elif fi.op == "convolution":
                            stats.flops += mult * 2.0 \
                                * _type_elems(fi.type_str)
            # ---- collectives
            kind = None
            for k in _COLLECTIVES:
                if instr.op in (k, f"{k}-start"):
                    kind = k
                    break
            if kind is not None:
                b = _type_bytes(instr.type_str)
                stats.coll[kind] += mult * b
                stats.coll_count += int(mult)
                stats.coll_sites.append((comp.name, instr.name, kind, b,
                                         mult))
            # ---- flops (top-level ops)
            if instr.op == "dot":
                stats.flops += mult * _dot_flops(instr, table)
            elif instr.op == "convolution":
                stats.flops += mult * 2.0 * _type_elems(instr.type_str)
            # ---- bytes (fusion-aware: the fusion op's operands/output are
            # the HBM traffic; ops inside fused computations are free)
            if instr.op in _FREE_OPS:
                continue
            out_b = _type_bytes(instr.type_str)
            if instr.op == "fusion":
                fb = _fusion_bytes(instr, comps)
                if fb is not None:
                    stats.bytes_accessed += mult * fb
                    stats.byte_sites.append((comp.name, instr.name,
                                             "fusion(slice-aware)", fb,
                                             mult))
                    continue
            if instr.op in ("dynamic-slice", "gather", "slice"):
                # reads only the sliced region, not the full operand
                stats.bytes_accessed += mult * 2 * out_b
                stats.byte_sites.append((comp.name, instr.name, instr.op,
                                         2 * out_b, mult))
                continue
            args = instr.line.split("(", 1)[1]
            operands = _OPERAND_RE.findall(args)
            if instr.op in ("dynamic-update-slice", "scatter"):
                # traffic = update region read+write (+ indices, small);
                # the pass-through operand aliases in place
                upd = operands[1] if len(operands) > 1 else None
                upd_b = _type_bytes(table.get(upd, "")) if upd else out_b
                stats.bytes_accessed += mult * 2 * upd_b
                stats.byte_sites.append((comp.name, instr.name, instr.op,
                                         2 * upd_b, mult))
                continue
            in_b = 0
            for o in operands[:8]:
                if o in table:
                    in_b += _type_bytes(table[o])
            stats.bytes_accessed += mult * (out_b + in_b)
            if out_b + in_b > (1 << 20):
                stats.byte_sites.append((comp.name, instr.name, instr.op,
                                         out_b + in_b, mult))

    if entry is not None:
        visit(entry, 1.0)
    return stats
