"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On the container this runs REDUCED configs on the host CPU (1-device mesh);
on a real cluster the same entrypoint builds the production mesh and the
cell shardings from ``repro.launch.cells`` — the model/step code is
identical (logical-axis sharding; DESIGN.md §4).

Compute/comm overlap notes (real-TPU deployment):
  * scan-over-layers + the XLA latency-hiding scheduler overlap each
    layer's gradient all-reduce/reduce-scatter with the next layer's
    matmuls; enable with
    ``--xla_tpu_enable_async_collective_fusion=true``
    ``--xla_tpu_overlap_compute_collective_tc=true`` (flags documented
    here so the launcher is the single source of deployment truth).
  * grad accumulation (--accum) additionally pipelines DCN all-reduces
    across microbatches for multi-pod meshes.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.lm import TokenPipeline
from repro.dist.fault import FaultPolicy
from repro.models import transformer as tfm
from repro.optim import adamw, linear_warmup_cosine
from repro.train import TrainState, make_train_step, train_loop

logging.basicConfig(level=logging.INFO,
                    format="%(asctime)s %(name)s %(message)s")


def reduced_lm_config(cfg: tfm.TransformerConfig) -> tfm.TransformerConfig:
    """Shrink an assigned LM config to smoke scale, keeping its structure
    (attention kind, MoE-ness, biases)."""
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=min(4, cfg.n_kv_heads), d_head=16,
        d_ff=128, vocab=256,
        n_experts=min(4, cfg.n_experts) if cfg.is_moe else 0,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, window=min(64, cfg.window),
        dtype=jnp.float32, q_block=64, kv_block=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-scale", action="store_true",
                    help="use the assigned config as-is (cluster only)")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    assert arch.family == "lm", "this launcher trains LM archs; see examples/"
    cfg = arch.config if args.full_scale else reduced_lm_config(arch.config)

    key = jax.random.PRNGKey(0)
    params = tfm.init(key, cfg)
    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps),
                weight_decay=0.1)
    state = TrainState.create(params, opt).tree()
    step = jax.jit(make_train_step(
        lambda p, b: tfm.loss_fn(p, b, cfg), opt, accum_steps=args.accum))

    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq)

    def batch_at(i):
        b = pipe.batch_at(i)
        if args.accum > 1:
            b = jax.tree.map(
                lambda x: x.reshape(args.accum, -1, *x.shape[1:]), b)
        return jax.tree.map(jnp.asarray, b)

    policy = FaultPolicy(checkpoint_every=args.ckpt_every)
    state, metrics = train_loop(step, state, batch_at, args.steps,
                                ckpt_dir=args.ckpt_dir, policy=policy)
    if "loss" in metrics:
        print(f"final loss: {float(metrics['loss']):.4f}")
    else:
        print(f"no steps to run (state at step {int(state['step'])})")


if __name__ == "__main__":
    main()
