"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from the sweep JSONs.

``PYTHONPATH=src python -m repro.launch.report experiments/dryrun``
prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import json
import os
import sys


def load(d: str):
    recs = []
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                recs.append(json.load(f))
    return recs


def fmt_bytes(b) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(recs) -> str:
    out = ["| arch | shape | mesh | kind | state GiB/chip | HLO flops/chip "
           "| bytes/chip | coll bytes/chip | collective mix |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                       f"— | — | — | — | SKIP: {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                       f"— | — | — | — | FAIL |")
            continue
        ro = r["roofline"]
        mix = ro["coll_breakdown"]
        mix_s = " ".join(f"{k.split('-')[-1][:3]}:{v/2**30:.0f}G"
                         for k, v in mix.items()
                         if k != "count" and v > (1 << 28))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{fmt_bytes(r['memory']['state_bytes_per_chip'])} | "
            f"{ro['flops']:.2e} | {ro['bytes']:.2e} | "
            f"{ro['coll_bytes']:.2e} | {mix_s or '-'} |")
    return "\n".join(out)


def roofline_table(recs) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL/HLO flops | MFU@roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']*1e3:.1f} ms"
            f" | {ro['t_memory_s']*1e3:.1f} ms |"
            f" {ro['t_collective_s']*1e3:.1f} ms | **{ro['bottleneck']}** |"
            f" {ro['useful_flop_frac']:.2f} |"
            f" {ro['mfu_at_roofline']*100:.2f}% |")
    return "\n".join(out)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    ok = [r for r in recs if r["status"] == "ok"]
    sk = [r for r in recs if r["status"] == "skip"]
    print(f"## Dry-run ({len(ok)} compiled, {len(sk)} skipped-with-reason)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16 = 256 chips)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
