from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, clip_by_global_norm, sgd_momentum,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule, cosine_schedule, linear_warmup_cosine,
)
