"""Optimizers as pure pytree transforms (no optax dependency).

``Optimizer`` is (init, update): init(params) -> state;
update(grads, state, params, step) -> (updates, state). Updates are
*subtracted* from params by the train step.

Memory note (v5e, 16 GB HBM): for the 480B-class MoE configs the optimizer
state dominates; ``adamw(mu_dtype=bf16)`` keeps the first moment in bf16
(half the bytes, standard large-run practice) while the second moment stays
fp32. Both moments inherit the params' sharding plus ZeRO-1 'data'-axis
sharding (see repro.dist.sharding.zero1_axes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          mu_dtype=jnp.float32, clip_norm: Optional[float] = 1.0
          ) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, mu_dtype), params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
        }

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            _, gnorm = clip_by_global_norm(grads, jnp.inf)
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g.astype(jnp.float32)).astype(mu_dtype),
            state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2)
            * jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_fn(step)

        def upd(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu}, {"grad_norm": gnorm}

    return Optimizer(init, update)


def sgd_momentum(lr: Callable | float, momentum: float = 0.9,
                 clip_norm: Optional[float] = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p), params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            _, gnorm = clip_by_global_norm(grads, jnp.inf)
        mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], grads)
        lr_t = lr_fn(step)
        updates = jax.tree.map(lambda m: (lr_t * m).astype(m.dtype), mom)
        return updates, {"mom": mom}, {"grad_norm": gnorm}

    return Optimizer(init, update)
