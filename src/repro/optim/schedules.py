"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(1, total_steps - warmup_steps), final_frac)

    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(1, warmup_steps)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return fn
