"""Shared model-building blocks: initializers, norms, logical-axis pytrees."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Fan-in scaled normal init (works under eval_shape).

    The scale is a weak-typed Python float so the requested dtype is
    preserved (a numpy scalar would promote bf16 -> f32)."""
    fan_in = shape[in_axis] if shape else 1
    return jax.random.normal(key, shape, dtype) / float(np.sqrt(max(1, fan_in)))


def embed_init(key, shape, scale: float = 1.0, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * scale


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jax.nn.silu(x @ w_gate)
    u = x @ w_up
    return (g * u) @ w_down


def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))


def tree_axes(template: Dict[str, Any]) -> Dict[str, Any]:
    """Identity helper to make axis pytrees read clearly at call sites."""
    return template


def split_keys(key, n: int):
    return jax.random.split(key, n)
