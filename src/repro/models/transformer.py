"""LM transformer family: dense / GQA / SWA / MLA attention + optional MoE.

One parameterized architecture covers the five assigned LM configs:

  arctic-480b   MoE 128e top-2 + dense residual FFN, GQA kv=8
  mixtral-8x7b  MoE 8e top-2, GQA kv=8, sliding-window attention (4096)
  granite-3-8b  dense, GQA kv=8
  qwen2-72b     dense, GQA kv=8, QKV bias
  minicpm3-4b   dense, MLA (latent-compressed KV)

Functional style: ``init`` builds a params pytree with per-layer weights
stacked on a leading [L] axis so the forward pass is one ``lax.scan`` over
layers (HLO size O(1) in depth; 80-layer qwen2 compiles as one scanned
block). Attention is flash-style: nested scans over query/key blocks with
an online-softmax accumulator, so peak memory is O(q_blk * kv_blk), never
O(S^2) — required for the 32k prefill shapes.

Logical weight axes (resolved to mesh axes by ``repro.dist.sharding``):
  "vocab" "embed" "heads" "kv_heads" "head_dim" "mlp" "expert" "qk_lora".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.act_sharding import constrain, model_axis_size
from repro.models.common import dense_init, embed_init, rms_norm


# --------------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    # attention
    attention: str = "full"          # full | swa | mla
    window: int = 4096               # swa window
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    # MLA dims (minicpm3-style)
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    # MoE
    n_experts: int = 0               # 0 => dense FFN
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    # numerics / exec
    dtype: Any = jnp.bfloat16
    remat: str = "dots"              # none | dots | full
    q_block: int = 512
    kv_block: int = 1024

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic total parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.attention == "mla":
            qk = self.qk_nope_dim + self.qk_rope_dim
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads * qk
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = (d * self.n_heads * self.d_head
                    + 2 * d * self.n_kv_heads * self.d_head
                    + self.n_heads * self.d_head * d)
        ffn = 3 * d * f
        per_layer = attn + (self.n_experts or 1) * ffn
        if self.dense_residual:
            per_layer += ffn
        if self.is_moe:
            per_layer += d * self.n_experts  # router
        return self.n_layers * per_layer + 2 * v * d  # embed + unembed

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        ffn = 3 * d * f
        inactive = self.n_layers * (self.n_experts - self.top_k) * ffn
        return self.param_count() - inactive


# --------------------------------------------------------------------- params
def init(key, cfg: TransformerConfig):
    """Params pytree; per-layer tensors stacked on leading [L]."""
    keys = jax.random.split(key, 16)
    L, d, dt = cfg.n_layers, cfg.d_model, cfg.dtype
    p: Dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab, d), 0.02, dt),
        "unembed": dense_init(keys[1], (d, cfg.vocab), 0, dt),
        "final_norm": jnp.ones((d,), dt),
    }
    blk: Dict[str, Any] = {
        "attn_norm": jnp.ones((L, d), dt),
        "ffn_norm": jnp.ones((L, d), dt),
    }
    if cfg.attention == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        blk.update({
            "wq_a": dense_init(keys[2], (L, d, cfg.q_lora_rank), 1, dt),
            "wq_b": dense_init(keys[3], (L, cfg.q_lora_rank, cfg.n_heads, qk), 1, dt),
            "wkv_a": dense_init(keys[4], (L, d, cfg.kv_lora_rank + cfg.qk_rope_dim), 1, dt),
            "wkv_b": dense_init(keys[5], (L, cfg.kv_lora_rank, cfg.n_heads,
                                          cfg.qk_nope_dim + cfg.v_head_dim), 1, dt),
            "wo": dense_init(keys[6], (L, cfg.n_heads, cfg.v_head_dim, d), 1, dt),
        })
    else:
        blk.update({
            "wq": dense_init(keys[2], (L, d, cfg.n_heads, cfg.d_head), 1, dt),
            "wk": dense_init(keys[3], (L, d, cfg.n_kv_heads, cfg.d_head), 1, dt),
            "wv": dense_init(keys[4], (L, d, cfg.n_kv_heads, cfg.d_head), 1, dt),
            "wo": dense_init(keys[5], (L, cfg.n_heads, cfg.d_head, d), 1, dt),
        })
        if cfg.qkv_bias:
            blk.update({
                "bq": jnp.zeros((L, cfg.n_heads, cfg.d_head), dt),
                "bk": jnp.zeros((L, cfg.n_kv_heads, cfg.d_head), dt),
                "bv": jnp.zeros((L, cfg.n_kv_heads, cfg.d_head), dt),
            })
    if cfg.is_moe:
        blk.update({
            "router": dense_init(keys[7], (L, d, cfg.n_experts), 1, jnp.float32),
            "moe_gate": dense_init(keys[8], (L, cfg.n_experts, d, cfg.d_ff), 1, dt),
            "moe_up": dense_init(keys[9], (L, cfg.n_experts, d, cfg.d_ff), 1, dt),
            "moe_down": dense_init(keys[10], (L, cfg.n_experts, cfg.d_ff, d), 1, dt),
        })
    if (not cfg.is_moe) or cfg.dense_residual:
        blk.update({
            "w_gate": dense_init(keys[11], (L, d, cfg.d_ff), 1, dt),
            "w_up": dense_init(keys[12], (L, d, cfg.d_ff), 1, dt),
            "w_down": dense_init(keys[13], (L, cfg.d_ff, d), 1, dt),
        })
    p["blocks"] = blk
    return p


def param_axes(cfg: TransformerConfig):
    """Logical-axis names per param tensor (leading layer axis = 'layer')."""
    ax: Dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "unembed": ("embed", "vocab"),
        "final_norm": ("embed",),
    }
    blk: Dict[str, Any] = {
        "attn_norm": ("layer", "embed"),
        "ffn_norm": ("layer", "embed"),
    }
    if cfg.attention == "mla":
        blk.update({
            "wq_a": ("layer", "embed", "qk_lora"),
            "wq_b": ("layer", "qk_lora", "heads", "head_dim"),
            "wkv_a": ("layer", "embed", "qk_lora"),
            "wkv_b": ("layer", "qk_lora", "heads", "head_dim"),
            "wo": ("layer", "heads", "head_dim", "embed"),
        })
    else:
        blk.update({
            "wq": ("layer", "embed", "heads", "head_dim"),
            "wk": ("layer", "embed", "kv_heads", "head_dim"),
            "wv": ("layer", "embed", "kv_heads", "head_dim"),
            "wo": ("layer", "heads", "head_dim", "embed"),
        })
        if cfg.qkv_bias:
            blk.update({
                "bq": ("layer", "heads", "head_dim"),
                "bk": ("layer", "kv_heads", "head_dim"),
                "bv": ("layer", "kv_heads", "head_dim"),
            })
    if cfg.is_moe:
        blk.update({
            "router": ("layer", "embed", "expert_dim"),
            "moe_gate": ("layer", "expert", "embed", "mlp"),
            "moe_up": ("layer", "expert", "embed", "mlp"),
            "moe_down": ("layer", "expert", "mlp", "embed"),
        })
    if (not cfg.is_moe) or cfg.dense_residual:
        blk.update({
            "w_gate": ("layer", "embed", "mlp"),
            "w_up": ("layer", "embed", "mlp"),
            "w_down": ("layer", "mlp", "embed"),
        })
    return {"embed": ax["embed"], "unembed": ax["unembed"],
            "final_norm": ax["final_norm"], "blocks": blk}


# ----------------------------------------------------------------------- rope
def rope(x, positions, theta: float):
    """Rotary embedding over the last dim of x [..., S, H, hd]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ flash attention
def _online_softmax_block(q, k, v, mask, state, scale):
    """One kv-block step of online softmax.

    q [B,qc,H,hd]; k/v [B,kc,H,hd] — already broadcast to the full head
    dim so 'model' shards H cleanly (a grouped (g, rep) layout defeats
    GSPMD when g < mesh model size; the repeat costs kc*H*hd per block,
    negligible next to the score tensor)."""
    m_prev, l_prev, acc = state
    s = jnp.einsum("bqhd,bkhd->bqhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(mask[:, :, None, :], s, -1e30)
    s = constrain(s, "batch", None, "model", None)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l_prev * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bqhk,bkhd->bqhd", p, v.astype(jnp.float32))
    acc = acc * alpha[..., None] + pv
    return m_new, l_new, acc


def flash_attention(q, k, v, *, causal: bool, window: Optional[int],
                    q_offset, q_block: int, kv_block: int, scale: float):
    """Blockwise attention. q [B,Sq,H,hd]; k,v [B,Skv,G,hd].

    ``q_offset`` is the global position of q[0] relative to k[0]
    (prefill: 0; chunked decode would pass cache_len).
    Memory: O(q_block * kv_block) per step — never materializes S^2.
    """
    b, sq, h, hd = q.shape
    skv, g = k.shape[1], k.shape[2]
    hd_v = v.shape[3]                      # MLA: v head dim != qk head dim
    rep = h // g
    if rep > 1:                            # GQA: broadcast KV to all heads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # Pad heads to a model-axis multiple (arctic 56, minicpm3 40 are not
    # divisible by model=16): the padded heads cost <= 20% extra attention
    # FLOPs but let every score/accumulator tensor shard 16x over 'model'
    # — the §Perf fix for the worst-fraction cells. Padded heads are
    # sliced away before the output projection.
    h_orig = h
    m = model_axis_size()
    if m > 1 and h % m != 0:
        h_pad = -(-h // m) * m
        q = jnp.pad(q, ((0, 0), (0, 0), (0, h_pad - h), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, h_pad - h), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, h_pad - h), (0, 0)))
        h = h_pad
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    # pad to block multiples
    sq_p = -(-sq // qb) * qb
    skv_p = -(-skv // kb) * kb
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    nq, nk = sq_p // qb, skv_p // kb

    # Batch and heads stay pinned through the block loops — XLA's
    # propagation loses them through nested scan carries otherwise (caught
    # by the dry-run roofline: unsharded score tensors + TB-scale
    # all-reduces; EXPERIMENTS.md §Perf).
    bspec = (None, "batch", None, "model", None)
    q_blocks = constrain(q.reshape(b, nq, qb, h, hd)
                         .transpose(1, 0, 2, 3, 4), *bspec)
    k_blocks = constrain(k.reshape(b, nk, kb, h, hd)
                         .transpose(1, 0, 2, 3, 4), *bspec)
    v_blocks = constrain(v.reshape(b, nk, kb, h, hd_v)
                         .transpose(1, 0, 2, 3, 4), *bspec)

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk
        qblk = constrain(qblk, "batch", None, "model", None)
        qpos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(state, ki_kv):
            ki, kblk, vblk = ki_kv
            kblk = constrain(kblk, "batch", None, "model", None)
            vblk = constrain(vblk, "batch", None, "model", None)
            kpos = ki * kb + jnp.arange(kb)
            mask = kpos[None, :] < skv  # padding mask
            mask = jnp.broadcast_to(mask, (qb, kb))
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask = jnp.broadcast_to(mask[None], (b, qb, kb))
            m_n, l_n, acc_n = _online_softmax_block(qblk, kblk, vblk, mask,
                                                    state, scale)
            m_n = constrain(m_n, "batch", None, "model")
            l_n = constrain(l_n, "batch", None, "model")
            acc_n = constrain(acc_n, "batch", None, "model", None)
            return (m_n, l_n, acc_n), None

        init = (constrain(jnp.full((b, qb, h), -1e30, jnp.float32),
                          "batch", None, "model"),
                constrain(jnp.zeros((b, qb, h), jnp.float32),
                          "batch", None, "model"),
                constrain(jnp.zeros((b, qb, h, hd_v), jnp.float32),
                          "batch", None, "model", None))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), k_blocks, v_blocks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, constrain(out, "batch", None, "model", None)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), q_blocks))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq_p, h, hd_v)
    out = out[:, :sq, :h_orig]             # drop seq + head padding
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, scale: float):
    """Single-position attention against a (possibly sharded) KV cache.

    q [B,1,H,hd]; caches [B,S,G,hd]. The softmax reductions over S become
    all-reduces when S is sharded over the mesh (context parallelism).
    """
    b, _, h, hd = q.shape
    s, g = k_cache.shape[1], k_cache.shape[2]
    rep = h // g
    qg = q.reshape(b, g, rep, hd)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, None, None, :] < cache_len[:, None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ------------------------------------------------------------------------ moe
def _moe_groups(t: int) -> int:
    """Number of dispatch groups = data-parallel shards of the token dim.

    Routing/sort/scatter run WITHIN groups (GShard-style): a global sort
    over data-sharded tokens cannot be partitioned — XLA materializes
    unsharded [T*k, D] buffers and TB-scale all-reduces (caught by the
    dry-run roofline; EXPERIMENTS.md §Perf). Group count comes from the
    tracing mesh; 1 on a host CPU (identical math)."""
    from repro.dist.act_sharding import _current_mesh

    mesh = _current_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            g *= mesh.shape[a]
    while g > 1 and t % g != 0:
        g //= 2
    return max(1, g)


def moe_ffn(x, router_w, w_gate, w_up, w_down, cfg: TransformerConfig):
    """Top-k token-choice MoE, sort-based capacity dispatch within
    data-sharded groups. x [T, D] -> ([T, D], aux loss)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_groups = _moe_groups(t)
    tg = t // n_groups
    cap = int(np.ceil(tg * k / e * cfg.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)

    xg = constrain(x.reshape(n_groups, tg, d), "batch", None, None)
    logits = xg.astype(jnp.float32) @ router_w              # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                    # [G, Tg, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    def group_dispatch(xg_g, idx_g, gate_g):
        """One group: tokens [Tg, D] -> expert buffer [E, C, D] and back."""
        flat_expert = idx_g.reshape(-1)                     # [Tg*k]
        flat_gate = gate_g.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(tg), k)
        order = jnp.argsort(flat_expert)
        sorted_expert = flat_expert[order]
        sorted_token = flat_token[order]
        sorted_gate = flat_gate[order]
        seg_start = jnp.searchsorted(sorted_expert, jnp.arange(e),
                                     side="left")
        pos = jnp.arange(tg * k) - seg_start[sorted_expert]
        keep = pos < cap
        safe_pos = jnp.where(keep, pos, cap - 1)
        buf = jnp.zeros((e, cap, d), xg_g.dtype)
        tok_vecs = xg_g[sorted_token] * keep[:, None].astype(xg_g.dtype)
        buf = buf.at[sorted_expert, safe_pos].add(tok_vecs)
        return buf, (sorted_expert, sorted_token, sorted_gate, keep,
                     safe_pos)

    buf, meta = jax.vmap(group_dispatch)(xg, idx, gates)    # [G, E, C, D]
    buf = constrain(buf, "batch", "expert", None, None)     # EP all-to-all

    g_act = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w_gate))
    u = jnp.einsum("gecd,edf->gecf", buf, w_up)
    y = jnp.einsum("gecf,efd->gecd", g_act * u, w_down)     # [G, E, C, D]
    y = constrain(y, "batch", "expert", None, None)

    def group_combine(y_g, meta_g):
        sorted_expert, sorted_token, sorted_gate, keep, safe_pos = meta_g
        out_vecs = y_g[sorted_expert, safe_pos] \
            * (sorted_gate * keep)[:, None].astype(y_g.dtype)
        return jnp.zeros((tg, d), y_g.dtype).at[sorted_token].add(out_vecs)

    out = jax.vmap(group_combine)(y, meta)                  # [G, Tg, D]
    out = constrain(out, "batch", None, None).reshape(t, d)
    # auxiliary load-balance loss (Switch): E * sum_e f_e * p_e
    me = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32).mean((0, 1))
    pe = probs.mean((0, 1))
    aux = e * jnp.sum(me * pe)
    return out, aux


# --------------------------------------------------------------------- blocks
def _attention_block(x, w, cfg: TransformerConfig, positions):
    b, s, d = x.shape
    if cfg.attention == "mla":
        return _mla_block(x, w, cfg, positions)
    q = jnp.einsum("bsd,dhk->bshk", x, w["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", x, w["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, w["wv"])
    if cfg.qkv_bias:
        q = q + w["bq"]
        k = k + w["bk"]
        v = v + w["bv"]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    window = cfg.window if cfg.attention == "swa" else None
    o = flash_attention(q, k, v, causal=True, window=window, q_offset=0,
                        q_block=cfg.q_block, kv_block=cfg.kv_block,
                        scale=1.0 / np.sqrt(cfg.d_head))
    return jnp.einsum("bshk,hkd->bsd", o, w["wo"])


def _mla_block(x, w, cfg: TransformerConfig, positions):
    """Multi-head latent attention (training/prefill path, up-projected)."""
    b, s, d = x.shape
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    cq = x @ w["wq_a"]                                     # [B,S,rq]
    q = jnp.einsum("bsr,rhk->bshk", cq, w["wq_b"])          # [B,S,H,qk]
    ckv_full = x @ w["wkv_a"]                              # [B,S,rkv+rope]
    ckv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    kv = jnp.einsum("bsr,rhk->bshk", ckv, w["wkv_b"])
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, (b, s, cfg.n_heads, cfg.qk_rope_dim))
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, k_rope], axis=-1)
    o = flash_attention(qf, kf, v, causal=True, window=None, q_offset=0,
                        q_block=cfg.q_block, kv_block=cfg.kv_block,
                        scale=1.0 / np.sqrt(qk))
    return jnp.einsum("bshk,hkd->bsd", o, w["wo"])


def _ffn_block(x, w, cfg: TransformerConfig):
    b, s, d = x.shape
    aux = jnp.zeros((), jnp.float32)
    out = jnp.zeros_like(x)
    if cfg.is_moe:
        moe_out, aux = moe_ffn(x.reshape(b * s, d), w["router"],
                               w["moe_gate"], w["moe_up"], w["moe_down"], cfg)
        out = out + moe_out.reshape(b, s, d)
    if (not cfg.is_moe) or cfg.dense_residual:
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", x, w["w_up"])
        out = out + jnp.einsum("bsf,fd->bsd", g * u, w["w_down"])
    return out, aux


def _layer(x, layer_w, cfg: TransformerConfig, positions):
    h = _attention_block(rms_norm(x, layer_w["attn_norm"]), layer_w, cfg,
                         positions)
    x = x + h
    f, aux = _ffn_block(rms_norm(x, layer_w["ffn_norm"]), layer_w, cfg)
    return x + f, aux


def _remat(fn, cfg: TransformerConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


# -------------------------------------------------------------------- forward
def forward(params, tokens, cfg: TransformerConfig):
    """tokens [B, S] -> logits [B, S, V] (+ aux losses)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(s)[None, :]

    layer_fn = _remat(functools.partial(_layer, cfg=cfg, positions=positions),
                      cfg)

    def scan_body(x, layer_w):
        x = constrain(x, "batch", None, None)
        x, aux = layer_fn(x, layer_w)
        return constrain(x, "batch", None, None), aux

    x, auxes = jax.lax.scan(scan_body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return logits, auxes.sum()


def loss_fn(params, batch, cfg: TransformerConfig,
            aux_weight: float = 0.01):
    """Causal-LM cross entropy; stays sharded over (batch, vocab)."""
    logits, aux = forward(params, batch["tokens"], cfg)
    logits = logits.astype(jnp.float32)
    tgt = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# -------------------------------------------------------------------- serving
def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Decode KV cache pytree. MLA caches the compressed latent (+ rope key)
    — the memory win that motivates MLA; SWA caches only the window."""
    L = cfg.n_layers
    if cfg.attention == "mla":
        return {
            "ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), cfg.dtype),
            "k_rope": jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), cfg.dtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    s = min(max_len, cfg.window) if cfg.attention == "swa" else max_len
    return {
        "k": jnp.zeros((L, batch, s, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
        "v": jnp.zeros((L, batch, s, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg: TransformerConfig):
    if cfg.attention == "mla":
        return {"ckv": ("layer", "batch", "cache_seq", "qk_lora"),
                "k_rope": ("layer", "batch", "cache_seq", "head_dim"),
                "len": ("batch",)}
    return {"k": ("layer", "batch", "cache_seq", "kv_heads", "head_dim"),
            "v": ("layer", "batch", "cache_seq", "kv_heads", "head_dim"),
            "len": ("batch",)}


def decode_step(params, cache, tokens, cfg: TransformerConfig):
    """One-token decode: tokens [B, 1] + cache -> (logits [B, V], cache).

    Each layer appends its new K/V (at position cache["len"]) and attends
    over the full cache; with the cache's seq axis sharded over 'model',
    the softmax reductions become all-reduces (context parallelism).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)         # [B, 1, D]
    pos = cache["len"][:, None]                            # [B, 1]

    new_cache = dict(cache)
    L = cfg.n_layers

    def body(i, carry):
        x, cache_k, cache_v = carry
        w = jax.tree.map(lambda p: p[i], params["blocks"])
        xn = rms_norm(x, w["attn_norm"])
        if cfg.attention == "mla":
            raise NotImplementedError  # handled in decode_step_mla
        q = jnp.einsum("bsd,dhk->bshk", xn, w["wq"])
        k = jnp.einsum("bsd,dgk->bsgk", xn, w["wk"])
        v = jnp.einsum("bsd,dgk->bsgk", xn, w["wv"])
        if cfg.qkv_bias:
            q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        s_cache = cache_k.shape[2]
        if cfg.attention == "swa":
            slot = cache["len"] % s_cache                 # rolling window
        else:
            slot = cache["len"]
        bidx = jnp.arange(b)
        cache_k = cache_k.at[i, bidx, slot].set(k[:, 0])
        cache_v = cache_v.at[i, bidx, slot].set(v[:, 0])
        eff_len = jnp.minimum(cache["len"] + 1, s_cache) \
            if cfg.attention == "swa" else cache["len"] + 1
        o = decode_attention(q, cache_k[i], cache_v[i], eff_len,
                             1.0 / np.sqrt(cfg.d_head))
        x = x + jnp.einsum("bshk,hkd->bsd", o, w["wo"])
        f, _ = _ffn_block(rms_norm(x, w["ffn_norm"]), w, cfg)
        return x + f, cache_k, cache_v

    x, ck, cv = jax.lax.fori_loop(0, L, body,
                                  (x, cache["k"], cache["v"]))
    new_cache["k"], new_cache["v"] = ck, cv
    new_cache["len"] = cache["len"] + 1
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])[:, 0]
    return logits, new_cache


def decode_step_mla(params, cache, tokens, cfg: TransformerConfig):
    """MLA decode with the latent cache: caches ckv [B,S,r] + k_rope."""
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)
    pos = cache["len"][:, None]
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    bidx = jnp.arange(b)

    def body(i, carry):
        x, c_ckv, c_rope = carry
        w = jax.tree.map(lambda p: p[i], params["blocks"])
        xn = rms_norm(x, w["attn_norm"])
        cq = xn @ w["wq_a"]
        q = jnp.einsum("bsr,rhk->bshk", cq, w["wq_b"])
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
        q_rope = rope(q_rope, pos, cfg.rope_theta)
        ckv_full = xn @ w["wkv_a"]
        ckv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
        k_rope = rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
        c_ckv = c_ckv.at[i, bidx, cache["len"]].set(ckv[:, 0])
        c_rope = c_rope.at[i, bidx, cache["len"]].set(k_rope[:, 0])
        # absorbed attention: score = q_nope^T (W_uk c) + q_rope^T k_rope
        w_uk, w_uv = jnp.split(w["wkv_b"], [cfg.qk_nope_dim], axis=-1)
        # fold q_nope through W_uk: [B,H,r]
        q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], w_uk)
        s_cache = c_ckv.shape[2]
        scores = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                             c_ckv[i].astype(jnp.float32))
                  + jnp.einsum("bhk,bsk->bhs",
                               q_rope[:, 0].astype(jnp.float32),
                               c_rope[i].astype(jnp.float32)))
        scores = scores / np.sqrt(qk)
        valid = jnp.arange(s_cache)[None, None, :] <= cache["len"][:, None, None]
        scores = jnp.where(valid, scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        # value: o_h = sum_s p (W_uv c_s) = (sum_s p c_s) W_uv
        ctx = jnp.einsum("bhs,bsr->bhr", p, c_ckv[i].astype(jnp.float32))
        o = jnp.einsum("bhr,rhk->bhk", ctx, w_uv.astype(jnp.float32))
        x = x + jnp.einsum("bhk,hkd->bd", o.astype(cfg.dtype),
                           w["wo"])[:, None, :]
        f, _ = _ffn_block(rms_norm(x, w["ffn_norm"]), w, cfg)
        return x + f, c_ckv, c_rope

    x, ckv, krope = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, cache["ckv"], cache["k_rope"]))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])[:, 0]
    return logits, {"ckv": ckv, "k_rope": krope, "len": cache["len"] + 1}


def prefill(params, tokens, cfg: TransformerConfig, max_len: int):
    """Run the full prompt, returning (logits of last position, cache)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.arange(s)[None, :]

    if cfg.attention == "mla":
        def body(i, carry):
            x, c_ckv, c_rope = carry
            w = jax.tree.map(lambda p: p[i], params["blocks"])
            xn = rms_norm(x, w["attn_norm"])
            ckv_full = xn @ w["wkv_a"]
            ckv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
            k_rope_r = rope(k_rope[:, :, None, :], positions,
                            cfg.rope_theta)[:, :, 0]
            c_ckv = c_ckv.at[i, :, :s].set(ckv)
            c_rope = c_rope.at[i, :, :s].set(k_rope_r)
            h = _mla_block(xn, w, cfg, positions)
            x = x + h
            f, _ = _ffn_block(rms_norm(x, w["ffn_norm"]), w, cfg)
            return x + f, c_ckv, c_rope

        x, ckv, krope = jax.lax.fori_loop(
            0, cfg.n_layers, body, (x, cache["ckv"], cache["k_rope"]))
        cache = {"ckv": ckv, "k_rope": krope,
                 "len": jnp.full((b,), s, jnp.int32)}
    else:
        s_cache = cache["k"].shape[2]

        def body(i, carry):
            x, ck, cv = carry
            w = jax.tree.map(lambda p: p[i], params["blocks"])
            xn = rms_norm(x, w["attn_norm"])
            q = jnp.einsum("bsd,dhk->bshk", xn, w["wq"])
            k = jnp.einsum("bsd,dgk->bsgk", xn, w["wk"])
            v = jnp.einsum("bsd,dgk->bsgk", xn, w["wv"])
            if cfg.qkv_bias:
                q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            if s_cache < s:
                # rolling window: position p lives at slot p % s_cache so
                # decode_step's (len % s_cache) writes land consistently;
                # slots of the kept tail form a rotation — permute the tail
                # into slot order and write contiguously
                tail_pos = np.arange(s - s_cache, s)
                inv = np.argsort(tail_pos % s_cache)
                ck = ck.at[i, :, :s_cache].set(k[:, -s_cache:][:, inv])
                cv = cv.at[i, :, :s_cache].set(v[:, -s_cache:][:, inv])
            else:
                ck = ck.at[i, :, :s].set(k)
                cv = cv.at[i, :, :s].set(v)
            window = cfg.window if cfg.attention == "swa" else None
            o = flash_attention(q, k, v, causal=True, window=window,
                                q_offset=0, q_block=cfg.q_block,
                                kv_block=cfg.kv_block,
                                scale=1.0 / np.sqrt(cfg.d_head))
            x = x + jnp.einsum("bshk,hkd->bsd", o, w["wo"])
            f, _ = _ffn_block(rms_norm(x, w["ffn_norm"]), w, cfg)
            return x + f, ck, cv

        x, ck, cv = jax.lax.fori_loop(0, cfg.n_layers, body,
                                      (x, cache["k"], cache["v"]))
        cache = {"k": ck, "v": cv, "len": jnp.full((b,), s, jnp.int32)}

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"])
    return logits, cache
