"""Model zoo: LM transformers (dense / GQA / MLA / SWA / MoE), GNNs
(GCN, DimeNet, NequIP, MACE), and recsys (FM).

Every model follows the same functional contract:

  init(key, cfg)          -> params pytree (works under jax.eval_shape)
  apply / loss_fn         -> pure functions of (params, batch)
  param_axes(cfg)         -> pytree of logical-axis tuples (for pjit)

Logical axes are resolved to mesh axes by ``repro.dist.sharding``.
"""
