"""Factorization Machine (Rendle, ICDM'10).

logit(x) = w0 + sum_i w_i x_i + sum_{i<j} <v_i, v_j> x_i x_j, with the
second-order term computed by the O(nk) sum-square trick — fused in the
Pallas kernel ``repro.kernels.fm_interaction`` (ref path available for
differential tests).

Embedding lookup: JAX has no EmbeddingBag; it is built here from
``jnp.take`` + ``jax.ops.segment_sum`` (multi-hot bags), as the system-spec
requires. For the single-hot Criteo-style shapes, the bag degenerates to a
plain gather.

Tables are one [F * V, D] matrix row-sharded over 'model' (the classic
model-parallel embedding); field f's row v lives at f * V + v.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.fm_interaction.ref import fm_interaction_ref


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str
    n_sparse: int = 39           # number of categorical fields
    vocab_per_field: int = 100_000
    embed_dim: int = 10
    interaction: str = "fm-2way"
    dtype: Any = jnp.float32
    use_kernel: bool = False     # route interaction through the Pallas op

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.vocab_per_field

    def param_count(self) -> int:
        return self.total_rows * (self.embed_dim + 1) + 1


def init(key, cfg: FMConfig):
    k1, k2 = jax.random.split(key)
    return {
        "emb": jax.random.normal(k1, (cfg.total_rows, cfg.embed_dim),
                                 cfg.dtype) * 0.01,
        "w_lin": jax.random.normal(k2, (cfg.total_rows,), cfg.dtype) * 0.01,
        "w0": jnp.zeros((), cfg.dtype),
    }


def param_axes(cfg: FMConfig):
    return {"emb": ("table_rows", "embed"), "w_lin": ("table_rows",),
            "w0": ()}


def _global_ids(ids, cfg: FMConfig):
    """Per-field ids [B, F] -> rows in the fused table."""
    field_base = jnp.arange(cfg.n_sparse, dtype=ids.dtype) * cfg.vocab_per_field
    return ids + field_base[None, :]


def embedding_bag(table, bag_ids, bag_segments, num_bags: int,
                  combiner: str = "sum"):
    """EmbeddingBag: rows = table[bag_ids]; reduce rows per bag.

    bag_ids [M] row indices, bag_segments [M] bag index per id (sorted).
    """
    rows = jnp.take(table, bag_ids, axis=0)
    out = jax.ops.segment_sum(rows, bag_segments, num_segments=num_bags)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(bag_ids, table.dtype),
                                  bag_segments, num_segments=num_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def forward(params, batch, cfg: FMConfig):
    """batch["ids"]: [B, F] single-hot field ids -> logits [B]."""
    ids = _global_ids(batch["ids"], cfg)
    emb = jnp.take(params["emb"], ids, axis=0)          # [B, F, D]
    lin = jnp.take(params["w_lin"], ids, axis=0).sum(axis=1)
    if cfg.use_kernel:
        from repro.kernels.fm_interaction.ops import fm_interaction
        inter = fm_interaction(emb)
    else:
        inter = fm_interaction_ref(emb.astype(jnp.float32))
    return params["w0"] + lin + inter.astype(cfg.dtype)


def loss_fn(params, batch, cfg: FMConfig):
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"]
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"bce": loss}


def retrieval_scores(params, user_ids, cand_ids, cfg: FMConfig):
    """Score one multi-hot user query against N candidates: FM reduces to
    dot(user_vec_sum, cand_emb) + linear terms (batched dot, not a loop).

    user_ids [Fu] global rows; cand_ids [N] global rows.
    """
    u = jnp.take(params["emb"], user_ids, axis=0).sum(axis=0)     # [D]
    c = jnp.take(params["emb"], cand_ids, axis=0)                 # [N, D]
    lin = jnp.take(params["w_lin"], cand_ids, axis=0)
    return c @ u + lin
