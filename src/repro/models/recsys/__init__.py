from repro.models.recsys.fm import FMConfig  # noqa: F401
