"""DimeNet (Gasteiger et al., arXiv:2003.03123) — directional message
passing with a triplet gather.

Kernel regime (taxonomy §GNN): *triplet gather* — messages live on directed
edges (j->i) and are updated from all wedges (k->j->i), which is a 3-way
self-join of the Edge relation (the paper's WCOJ machinery applies:
DESIGN.md §5). Triplet index lists are precomputed host-side
(``build_triplets``) and padded to a static T for jit.

Bases: radial Bessel RBF sin(n pi d / c) / d (exact, paper eq. 6) and a
cos(l * angle) x radial product angular basis (compact stand-in for the
paper's spherical Bessel j_l; same [n_spherical x n_radial] shape —
deviation recorded in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 16
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        d, nb = self.d_hidden, self.n_bilinear
        per_block = (2 * d * d                        # edge MLPs
                     + self.n_spherical * self.n_radial * nb  # sbf proj
                     + nb * d * d                     # bilinear
                     + 2 * d * d)                     # update MLP
        out = self.n_blocks * per_block
        out += self.n_species * d + self.n_radial * d + 3 * d * d  # embed
        out += self.n_blocks * (d * d + d)            # output blocks
        return out


# ----------------------------------------------------------------- host prep
def build_triplets(senders: np.ndarray, receivers: np.ndarray,
                   max_triplets: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Wedge list: pairs of edges (e1: k->j, e2: j->i) with k != i.

    Returns (t_e1 [T], t_e2 [T], t_mask [T]) padded/truncated to
    ``max_triplets`` (truncation count is reported by the data pipeline —
    no silent caps)."""
    senders = np.asarray(senders)
    receivers = np.asarray(receivers)
    e = len(senders)
    t1, t2 = [], []
    # e1 must END at j (receiver == j); bucket edges by receiver.
    by_receiver: Dict[int, list] = {}
    for idx in range(e):
        by_receiver.setdefault(int(receivers[idx]), []).append(idx)
    for e2 in range(e):
        j = int(senders[e2])
        i = int(receivers[e2])
        for e1 in by_receiver.get(j, []):
            if int(senders[e1]) != i:       # exclude backtracking k == i
                t1.append(e1)
                t2.append(e2)
    t = len(t1)
    keep = min(t, max_triplets)
    t_e1 = np.zeros(max_triplets, np.int32)
    t_e2 = np.zeros(max_triplets, np.int32)
    t_mask = np.zeros(max_triplets, np.float32)
    t_e1[:keep] = t1[:keep]
    t_e2[:keep] = t2[:keep]
    t_mask[:keep] = 1.0
    return t_e1, t_e2, t_mask


# -------------------------------------------------------------------- bases
def bessel_rbf(d, n_radial: int, cutoff: float):
    """Radial Bessel basis sqrt(2/c) sin(n pi d / c) / d (paper eq. 6)."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d_safe = jnp.maximum(d, 1e-6)[:, None]
    env = jnp.where(d[:, None] < cutoff, 1.0, 0.0)
    return np.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * d_safe / cutoff) \
        / d_safe * env


def angular_basis(cos_angle, d, n_spherical: int, n_radial: int,
                  cutoff: float):
    """[T, n_spherical * n_radial] product basis cos(l*theta) x RBF(d_kj)."""
    theta = jnp.arccos(jnp.clip(cos_angle, -1.0, 1.0))
    ls = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(ls[None, :] * theta[:, None])           # [T, S]
    rad = bessel_rbf(d, n_radial, cutoff)                 # [T, R]
    return (ang[:, :, None] * rad[:, None, :]).reshape(d.shape[0], -1)


# -------------------------------------------------------------------- params
def init(key, cfg: DimeNetConfig):
    d, nb = cfg.d_hidden, cfg.n_bilinear
    sr = cfg.n_spherical * cfg.n_radial
    keys = jax.random.split(key, 4 + cfg.n_blocks)
    p = {
        "species_embed": jax.random.normal(keys[0], (cfg.n_species, d),
                                           cfg.dtype) * 0.1,
        "rbf_proj": dense_init(keys[1], (cfg.n_radial, d), 0, cfg.dtype),
        "edge_embed": dense_init(keys[2], (3 * d, d), 0, cfg.dtype),
        "out_proj": dense_init(keys[3], (d, 1), 0, cfg.dtype),
    }
    blocks = []
    for i in range(cfg.n_blocks):
        bk = jax.random.split(keys[4 + i], 6)
        blocks.append({
            "w_src": dense_init(bk[0], (d, d), 0, cfg.dtype),
            "sbf_proj": dense_init(bk[1], (sr, nb), 0, cfg.dtype),
            "bilinear": dense_init(bk[2], (nb, d, d), 0, cfg.dtype) * 0.1,
            "w_upd1": dense_init(bk[3], (d, d), 0, cfg.dtype),
            "w_upd2": dense_init(bk[4], (d, d), 0, cfg.dtype),
            "w_out": dense_init(bk[5], (d, d), 0, cfg.dtype),
        })
    # stack blocks for scan
    p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return p


def param_axes(cfg: DimeNetConfig):
    return {
        "species_embed": ("vocab", "feat"),
        "rbf_proj": ("basis", "feat"),
        "edge_embed": ("feat_in", "feat"),
        "out_proj": ("feat", None),
        "blocks": {
            "w_src": ("layer", "feat_in", "feat"),
            "sbf_proj": ("layer", "basis", "bilinear"),
            "bilinear": ("layer", "bilinear", "feat_in", "feat"),
            "w_upd1": ("layer", "feat_in", "feat"),
            "w_upd2": ("layer", "feat_in", "feat"),
            "w_out": ("layer", "feat_in", "feat"),
        },
    }


# ------------------------------------------------------------------- forward
def forward(params, batch, cfg: DimeNetConfig):
    """Flat-graph form. batch keys:
       species [N], positions [N,3], senders [E], receivers [E],
       edge_mask [E], t_e1 [T], t_e2 [T], t_mask [T].
    Returns per-node energies [N]."""
    pos = batch["positions"].astype(cfg.dtype)
    snd, rcv = batch["senders"], batch["receivers"]
    emask = batch["edge_mask"].astype(cfg.dtype)
    n = pos.shape[0]

    vec = pos[rcv] - pos[snd]                      # edge vector j->i
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = bessel_rbf(dist, cfg.n_radial, cfg.cutoff).astype(cfg.dtype)

    h = params["species_embed"][batch["species"]]
    m = jnp.concatenate([h[snd], h[rcv], rbf @ params["rbf_proj"]], axis=-1)
    m = jax.nn.silu(m @ params["edge_embed"]) * emask[:, None]

    # triplet geometry: angle at j between (k->j) and (j->i)
    t1, t2, tmask = batch["t_e1"], batch["t_e2"], batch["t_mask"]
    v1 = -vec[t1]                                  # j->k direction
    v2 = vec[t2]                                   # j->i direction
    cos_a = (v1 * v2).sum(-1) / (
        jnp.linalg.norm(v1 + 1e-12, axis=-1)
        * jnp.linalg.norm(v2 + 1e-12, axis=-1))
    sbf = angular_basis(cos_a, dist[t1], cfg.n_spherical, cfg.n_radial,
                        cfg.cutoff).astype(cfg.dtype)

    def block(m, w):
        src = jax.nn.silu(m @ w["w_src"])          # [E, d]
        a = sbf @ w["sbf_proj"]                    # [T, nb]
        b = src[t1]                                # [T, d] message k->j
        tm = jnp.einsum("tb,bde,te->td", a, w["bilinear"], b)
        tm = tm * tmask[:, None].astype(cfg.dtype)
        agg = jax.ops.segment_sum(tm, t2, num_segments=m.shape[0])
        upd = jax.nn.silu(agg @ w["w_upd1"])
        m = m + jax.nn.silu(upd @ w["w_upd2"]) * emask[:, None]
        out = jax.nn.silu(m @ w["w_out"])
        return m, out

    m, outs = jax.lax.scan(block, m, params["blocks"])
    edge_out = outs.sum(axis=0) * emask[:, None]   # [E, d]
    node = jax.ops.segment_sum(edge_out, rcv, num_segments=n)
    return (node @ params["out_proj"])[:, 0]


def loss_fn(params, batch, cfg: DimeNetConfig):
    """Energy regression: sum node energies per graph vs target."""
    e_node = forward(params, batch, cfg)
    seg = batch.get("graph_id", jnp.zeros_like(batch["species"]))
    target = batch.get("energy")
    if target is None:
        target = jnp.zeros((1,), jnp.float32)
    e_graph = jax.ops.segment_sum(e_node, seg,
                                  num_segments=target.shape[0])
    loss = jnp.mean((e_graph - target) ** 2)
    return loss, {"mse": loss}
