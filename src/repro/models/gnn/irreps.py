"""Minimal real-spherical-harmonic irrep algebra for NequIP/MACE.

No e3nn dependency: real SH (orthonormal, l <= 2 explicit formulas), exact
real-basis Clebsch-Gordan tensors (sympy CG + complex->real unitary,
computed once and cached), and numerically-recovered Wigner-D matrices for
the equivariance property tests.

Feature convention: an irrep feature of degree l is an array
[..., channels, 2l+1]; a full feature is a dict {l: array}.
"""
from __future__ import annotations

import functools
from typing import Dict

import numpy as np

_SQRT_PI = np.sqrt(np.pi)


# ------------------------------------------------------- spherical harmonics
def sph_harm_real(l: int, vec):
    """Real orthonormal SH evaluated at unit vectors vec [..., 3].

    Returns [..., 2l+1] ordered m = -l..l. Supports l in {0, 1, 2}.
    Works on numpy or jax arrays.
    """
    xp = np
    try:  # allow jax arrays transparently
        import jax.numpy as jnp
        if not isinstance(vec, np.ndarray):
            xp = jnp
    except ImportError:
        pass
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    if l == 0:
        return xp.full(vec.shape[:-1] + (1,), 0.5 / _SQRT_PI, vec.dtype) \
            if xp is np else xp.full(vec.shape[:-1] + (1,), 0.5 / _SQRT_PI,
                                     dtype=vec.dtype)
    if l == 1:
        c = np.sqrt(3 / (4 * np.pi))
        return xp.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c1 = 0.5 * np.sqrt(15 / np.pi)
        c2 = 0.25 * np.sqrt(5 / np.pi)
        c3 = 0.25 * np.sqrt(15 / np.pi)
        return xp.stack([
            c1 * x * y,
            c1 * y * z,
            c2 * (3 * z * z - 1.0),
            c1 * x * z,
            c3 * (x * x - y * y) * 2.0 * 0.5,
        ], axis=-1)
    raise NotImplementedError(f"l={l}")


# --------------------------------------------------------- real CG tensors
def _u_real(l: int) -> np.ndarray:
    """Unitary mapping complex SH -> real SH: Y_real = U @ Y_complex.

    Rows indexed by real m = -l..l, cols by complex m' = -l..l.
    """
    dim = 2 * l + 1
    u = np.zeros((dim, dim), dtype=np.complex128)
    for m in range(-l, l + 1):
        i = m + l
        if m == 0:
            u[i, l] = 1.0
        elif m > 0:
            u[i, m + l] = (-1) ** m / np.sqrt(2)
            u[i, -m + l] = 1 / np.sqrt(2)
        else:  # m < 0
            am = -m
            u[i, am + l] = -1j * (-1) ** am / np.sqrt(2)
            u[i, -am + l] = 1j / np.sqrt(2)
    return u


@functools.lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG tensor C [2l1+1, 2l2+1, 2l3+1]:
    (x ⊗ y)^{l3}_{m3} = sum_{m1 m2} C[m1, m2, m3] x_{m1} y_{m2}
    is equivariant when x, y transform as real-SH irreps l1, l2.
    """
    from sympy.physics.quantum.cg import CG
    from sympy import S

    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    cc = np.zeros((d1, d2, d3), dtype=np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) <= l3:
                cc[m1 + l1, m2 + l2, m3 + l3] = float(
                    CG(S(l1), S(m1), S(l2), S(m2), S(l3), S(m3)).doit())
    u1, u2, u3 = _u_real(l1), _u_real(l2), _u_real(l3)
    creal = np.einsum("ia,jb,abc,kc->ijk", u1, u2, cc, u3.conj())
    re, im = np.real(creal), np.imag(creal)
    if np.abs(re).max() >= np.abs(im).max():
        out = re
        assert np.abs(im).max() < 1e-10, (l1, l2, l3, np.abs(im).max())
    else:
        out = im
        assert np.abs(re).max() < 1e-10, (l1, l2, l3, np.abs(re).max())
    return np.ascontiguousarray(out)


def tp_paths(l_max: int):
    """All (l1, l2, l3) with l1,l2,l3 <= l_max and |l1-l2| <= l3 <= l1+l2."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                paths.append((l1, l2, l3))
    return paths


# ----------------------------------------------------------- test utilities
def wigner_d_real(l: int, rot: np.ndarray) -> np.ndarray:
    """Representation matrix D_l(R) for real SH, recovered numerically:
    Y_l(R r) = D_l(R) Y_l(r). Exact to lstsq precision — used by tests."""
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(max(64, 8 * (2 * l + 1)), 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    a = sph_harm_real(l, pts)                         # [P, 2l+1]
    b = sph_harm_real(l, pts @ rot.T)                 # [P, 2l+1]
    d, *_ = np.linalg.lstsq(a, b, rcond=None)
    return d.T                                        # Y(Rr) = D @ Y(r)


def random_rotation(seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def rotate_feature(feat: Dict[int, np.ndarray], rot: np.ndarray):
    """Apply D_l(R) to every irrep component of a feature dict."""
    out = {}
    for l, x in feat.items():
        d = wigner_d_real(l, rot)
        out[l] = np.einsum("...ci,ji->...cj", np.asarray(x), d.T)
    return out
