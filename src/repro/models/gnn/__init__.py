"""GNN model zoo.

All message passing is built on ``jax.ops.segment_sum`` over edge-index
scatter — JAX has no native sparse message passing (BCOO only), so this
substrate IS part of the system (see kernel_taxonomy §GNN). The relational
view: a GNN layer is a semiring join-aggregate over the Edge relation,
which is the paper's "graph processing = relational algebra" thesis
(DESIGN.md §5).
"""
from repro.models.gnn.gcn import GCNConfig  # noqa: F401
from repro.models.gnn.dimenet import DimeNetConfig  # noqa: F401
from repro.models.gnn.equivariant import MACEConfig, NequIPConfig  # noqa: F401
