"""E(3)-equivariant GNNs: NequIP (arXiv:2101.03164) and MACE
(arXiv:2206.07697), built on the real-CG irrep algebra in ``irreps.py``.

Feature convention: dict {l: [N, C, 2l+1]}. Messages are channel-wise
(depthwise) tensor products h_j^{l1} (x) Y^{l2}(r_ij) -> l3 with per-edge
radial weights, aggregated by segment_sum (the join-aggregate substrate).
Nonlinearities are invariant-gated (scalars: silu; l>0: sigmoid gate from
the scalar channels) so every layer is exactly equivariant — property-tested
against numerically-recovered Wigner-D matrices in tests/.

MACE's higher-order ACE contraction (correlation order 3) is realised as
iterated CG products of the density A with itself: B2 = (A (x) A),
B3 = (B2 (x) A) — the "tensor-product equiv" kernel regime of the taxonomy,
adapted from the paper's symmetrized contraction (deviation in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init
from repro.models.gnn.dimenet import bessel_rbf
from repro.models.gnn.irreps import clebsch_gordan, sph_harm_real, tp_paths


# --------------------------------------------------------------------- common
def _cg_const(l1, l2, l3, dtype):
    return jnp.asarray(clebsch_gordan(l1, l2, l3), dtype)


def depthwise_tp(x, y, l1: int, l2: int, l3: int, dtype):
    """Channel-wise CG product: x [E,C,2l1+1] (x) y [E,2l2+1] -> [E,C,2l3+1]."""
    cg = _cg_const(l1, l2, l3, dtype)
    return jnp.einsum("eci,ej,ijk->eck", x, y, cg)


def feature_tp(x, y, l1: int, l2: int, l3: int, dtype):
    """CG product of two channel features [.,C,2l1+1] x [.,C,2l2+1]."""
    cg = _cg_const(l1, l2, l3, dtype)
    return jnp.einsum("eci,ecj,ijk->eck", x, y, cg)


def gate(feat: Dict[int, jnp.ndarray], gate_w, l_max: int):
    """Invariant gating: scalars -> silu; l>0 -> sigmoid(linear(scalars))."""
    scalars = feat[0][..., 0]                       # [N, C]
    out = {0: jax.nn.silu(feat[0])}
    for l in range(1, l_max + 1):
        g = jax.nn.sigmoid(scalars @ gate_w[l - 1])  # [N, C]
        out[l] = feat[l] * g[..., None]
    return out


# --------------------------------------------------------------------- NequIP
@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    radial_hidden: int = 64
    dtype: Any = jnp.float32

    @property
    def paths(self) -> List[Tuple[int, int, int]]:
        return tp_paths(self.l_max)

    def param_count(self) -> int:
        c = self.d_hidden
        n_paths = len(self.paths)
        per_layer = (self.n_rbf * self.radial_hidden
                     + self.radial_hidden * n_paths * c       # radial MLP
                     + (self.l_max + 1) * 2 * c * c           # self/msg mix
                     + self.l_max * c * c)                    # gates
        return (self.n_species * c + self.n_layers * per_layer + c)


def _nequip_layer_params(key, cfg: "NequIPConfig"):
    c = cfg.d_hidden
    n_paths = len(cfg.paths)
    ks = jax.random.split(key, 5)
    return {
        "radial1": dense_init(ks[0], (cfg.n_rbf, cfg.radial_hidden), 0,
                              cfg.dtype),
        "radial2": dense_init(ks[1], (cfg.radial_hidden, n_paths * c), 0,
                              cfg.dtype),
        "w_self": dense_init(ks[2], (cfg.l_max + 1, c, c), 1, cfg.dtype),
        "w_msg": dense_init(ks[3], (cfg.l_max + 1, c, c), 1, cfg.dtype),
        "w_gate": dense_init(ks[4], (cfg.l_max, c, c), 1, cfg.dtype),
    }


def init(key, cfg: NequIPConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    return {
        "species_embed": jax.random.normal(
            keys[0], (cfg.n_species, cfg.d_hidden), cfg.dtype) * 0.5,
        "layers": [_nequip_layer_params(keys[1 + i], cfg)
                   for i in range(cfg.n_layers)],
        "readout": dense_init(keys[-1], (cfg.d_hidden, 1), 0, cfg.dtype),
    }


def param_axes(cfg: NequIPConfig):
    layer = {"radial1": ("basis", "feat"), "radial2": ("feat", "feat_out"),
             "w_self": (None, "feat_in", "feat_out"),
             "w_msg": (None, "feat_in", "feat_out"),
             "w_gate": (None, "feat_in", "feat_out")}
    return {"species_embed": ("vocab", "feat"),
            "layers": [layer for _ in range(cfg.n_layers)],
            "readout": ("feat", None)}


def _message_pass(feat, edges, cfg):
    """Shared NequIP/MACE message step: returns aggregated density A."""
    snd, rcv, sh, radial, emask, n = edges
    c = cfg.d_hidden
    agg = {l: jnp.zeros((n, c, 2 * l + 1), cfg.dtype)
           for l in range(cfg.l_max + 1)}
    for p, (l1, l2, l3) in enumerate(cfg.paths):
        w = radial[:, p, :]                               # [E, C]
        hj = feat[l1][snd]
        m = depthwise_tp(hj, sh[l2], l1, l2, l3, cfg.dtype)
        m = m * (w * emask[:, None])[..., None]
        agg[l3] = agg[l3] + jax.ops.segment_sum(m, rcv, num_segments=n)
    return agg


def _edge_geometry(batch, cfg):
    pos = batch["positions"].astype(cfg.dtype)
    snd, rcv = batch["senders"], batch["receivers"]
    emask = batch["edge_mask"].astype(cfg.dtype)
    vec = pos[rcv] - pos[snd]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    unit = vec / jnp.maximum(dist, 1e-6)[:, None]
    sh = {l: sph_harm_real(l, unit).astype(cfg.dtype)
          for l in range(cfg.l_max + 1)}
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    return snd, rcv, emask, sh, rbf, pos.shape[0]


def forward(params, batch, cfg: NequIPConfig):
    """batch: species [N], positions [N,3], senders [E], receivers [E],
    edge_mask [E]. Returns per-node energies [N]."""
    snd, rcv, emask, sh, rbf, n = _edge_geometry(batch, cfg)
    c = cfg.d_hidden

    feat = {0: params["species_embed"][batch["species"]][..., None]}
    for l in range(1, cfg.l_max + 1):
        feat[l] = jnp.zeros((n, c, 2 * l + 1), cfg.dtype)

    for lw in params["layers"]:
        radial = jax.nn.silu(rbf @ lw["radial1"]) @ lw["radial2"]
        radial = radial.reshape(-1, len(cfg.paths), c)
        edges = (snd, rcv, sh, radial, emask, n)
        agg = _message_pass(feat, edges, cfg)
        new = {}
        for l in range(cfg.l_max + 1):
            new[l] = (jnp.einsum("ncj,cd->ndj", feat[l], lw["w_self"][l])
                      + jnp.einsum("ncj,cd->ndj", agg[l], lw["w_msg"][l]))
        feat = gate(new, lw["w_gate"], cfg.l_max)

    return (feat[0][..., 0] @ params["readout"])[:, 0]


def _energy_loss(e_node, batch):
    seg = batch.get("graph_id", jnp.zeros_like(batch["species"]))
    target = batch.get("energy")
    if target is None:
        target = jnp.zeros((1,), jnp.float32)
    n_graphs = target.shape[0]          # static (from the input spec)
    e_graph = jax.ops.segment_sum(e_node, seg, num_segments=n_graphs)
    loss = jnp.mean((e_graph - target) ** 2)
    return loss, {"mse": loss}


def loss_fn(params, batch, cfg: NequIPConfig):
    return _energy_loss(forward(params, batch, cfg), batch)


# ----------------------------------------------------------------------- MACE
@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    radial_hidden: int = 64
    dtype: Any = jnp.float32

    @property
    def paths(self) -> List[Tuple[int, int, int]]:
        return tp_paths(self.l_max)

    def param_count(self) -> int:
        c = self.d_hidden
        n_paths = len(self.paths)
        per_layer = (self.n_rbf * self.radial_hidden
                     + self.radial_hidden * n_paths * c
                     + (self.l_max + 1) * 4 * c * c
                     + self.l_max * c * c)
        return self.n_species * c + self.n_layers * per_layer + 2 * c


def _mace_layer_params(key, cfg: MACEConfig):
    c = cfg.d_hidden
    ks = jax.random.split(key, 7)
    return {
        "radial1": dense_init(ks[0], (cfg.n_rbf, cfg.radial_hidden), 0,
                              cfg.dtype),
        "radial2": dense_init(ks[1], (cfg.radial_hidden,
                                      len(cfg.paths) * c), 0, cfg.dtype),
        "w_a": dense_init(ks[2], (cfg.l_max + 1, c, c), 1, cfg.dtype),
        "w_b2": dense_init(ks[3], (cfg.l_max + 1, c, c), 1, cfg.dtype),
        "w_b3": dense_init(ks[4], (cfg.l_max + 1, c, c), 1, cfg.dtype),
        "w_self": dense_init(ks[5], (cfg.l_max + 1, c, c), 1, cfg.dtype),
        "w_gate": dense_init(ks[6], (cfg.l_max, c, c), 1, cfg.dtype),
    }


def mace_init(key, cfg: MACEConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    return {
        "species_embed": jax.random.normal(
            keys[0], (cfg.n_species, cfg.d_hidden), cfg.dtype) * 0.5,
        "layers": [_mace_layer_params(keys[1 + i], cfg)
                   for i in range(cfg.n_layers)],
        "readout": dense_init(keys[-1], (cfg.d_hidden, 1), 0, cfg.dtype),
    }


def mace_param_axes(cfg: MACEConfig):
    layer = {"radial1": ("basis", "feat"), "radial2": ("feat", "feat_out"),
             "w_a": (None, "feat_in", "feat_out"),
             "w_b2": (None, "feat_in", "feat_out"),
             "w_b3": (None, "feat_in", "feat_out"),
             "w_self": (None, "feat_in", "feat_out"),
             "w_gate": (None, "feat_in", "feat_out")}
    return {"species_embed": ("vocab", "feat"),
            "layers": [layer for _ in range(cfg.n_layers)],
            "readout": ("feat", None)}


def _product_basis(a: Dict[int, jnp.ndarray], cfg: MACEConfig):
    """Iterated-CG higher-order basis: B2 = A(x)A, B3 = B2(x)A."""
    def one_order(x):
        out = {l: jnp.zeros_like(a[l]) for l in a}
        for (l1, l2, l3) in cfg.paths:
            out[l3] = out[l3] + feature_tp(x[l1], a[l2], l1, l2, l3,
                                           cfg.dtype)
        return out

    b2 = one_order(a)
    b3 = one_order(b2) if cfg.correlation_order >= 3 else None
    return b2, b3


def mace_forward(params, batch, cfg: MACEConfig):
    snd, rcv, emask, sh, rbf, n = _edge_geometry(batch, cfg)
    c = cfg.d_hidden

    feat = {0: params["species_embed"][batch["species"]][..., None]}
    for l in range(1, cfg.l_max + 1):
        feat[l] = jnp.zeros((n, c, 2 * l + 1), cfg.dtype)

    for lw in params["layers"]:
        radial = jax.nn.silu(rbf @ lw["radial1"]) @ lw["radial2"]
        radial = radial.reshape(-1, len(cfg.paths), c)
        edges = (snd, rcv, sh, radial, emask, n)
        a = _message_pass(feat, edges, cfg)
        # normalize the density before taking products (numerics)
        a = {l: x / np.sqrt(max(1.0, cfg.d_hidden)) for l, x in a.items()}
        b2, b3 = _product_basis(a, cfg)
        new = {}
        for l in range(cfg.l_max + 1):
            upd = (jnp.einsum("ncj,cd->ndj", a[l], lw["w_a"][l])
                   + jnp.einsum("ncj,cd->ndj", b2[l], lw["w_b2"][l]))
            if b3 is not None:
                upd = upd + jnp.einsum("ncj,cd->ndj", b3[l], lw["w_b3"][l])
            new[l] = upd + jnp.einsum("ncj,cd->ndj", feat[l],
                                      lw["w_self"][l])
        feat = gate(new, lw["w_gate"], cfg.l_max)

    return (feat[0][..., 0] @ params["readout"])[:, 0]


def mace_loss_fn(params, batch, cfg: MACEConfig):
    return _energy_loss(mace_forward(params, batch, cfg), batch)
