"""GCN (Kipf & Welling, arXiv:1609.02907) on the segment-sum substrate.

H' = sigma( D^-1/2 (A + I) D^-1/2 H W ). The normalized SpMM is a
(+, *)-semiring join-aggregate over Edge(src, dst) with edge annotation
1/sqrt(d_src d_dst) — executable by the EmptyHeaded engine OR by the
vectorized segment_sum path here (differentially tested in tests/).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 16
    d_feat: int = 1433
    n_classes: int = 7
    aggregator: str = "mean"     # mean == sym-normalized sum here
    norm: str = "sym"
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        dims = [self.d_feat] + [self.d_hidden] * (self.n_layers - 1) \
            + [self.n_classes]
        return sum(dims[i] * dims[i + 1] + dims[i + 1]
                   for i in range(self.n_layers))


def init(key, cfg: GCNConfig):
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, cfg.n_layers)
    return {
        f"layer{i}": {
            "w": dense_init(keys[i], (dims[i], dims[i + 1]), 0, cfg.dtype),
            "b": jnp.zeros((dims[i + 1],), cfg.dtype),
        }
        for i in range(cfg.n_layers)
    }


def param_axes(cfg: GCNConfig):
    return {f"layer{i}": {"w": ("feat_in", "feat_out"), "b": ("feat_out",)}
            for i in range(cfg.n_layers)}


def sym_norm_coeff(senders, receivers, n_nodes: int, edge_mask=None):
    """1/sqrt(d_i d_j) per edge, with self-loops added by the caller.
    ``edge_mask`` zeroes padding edges (and their degree contribution)."""
    ones = jnp.ones_like(senders, jnp.float32)
    w = ones if edge_mask is None else edge_mask.astype(jnp.float32)
    deg = jax.ops.segment_sum(w, receivers, num_segments=n_nodes)
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
    return inv_sqrt[senders] * inv_sqrt[receivers] * w


def forward(params, batch, cfg: GCNConfig):
    """batch: features [N, F], senders [E], receivers [E] (self-loops
    included; optional edge_mask zeroes padding), n_nodes static.
    Returns logits [N, C]."""
    x = batch["features"].astype(cfg.dtype)
    snd, rcv = batch["senders"], batch["receivers"]
    emask = batch.get("edge_mask")
    n = x.shape[0]
    coeff = sym_norm_coeff(snd, rcv, n, emask) if cfg.norm == "sym" else \
        (jnp.ones_like(snd, jnp.float32) if emask is None
         else emask.astype(jnp.float32))
    for i in range(cfg.n_layers):
        w = params[f"layer{i}"]
        x = x @ w["w"] + w["b"]
        msgs = x[snd] * coeff[:, None].astype(cfg.dtype)
        x = jax.ops.segment_sum(msgs, rcv, num_segments=n)
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params, batch, cfg: GCNConfig):
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"ce": loss}
