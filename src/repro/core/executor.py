"""Physical plan execution: Yannakakis over GHD bags (paper Section 3.3).

Two phases, exactly as the paper describes:

  * **Within a node** — each bag runs the generic worst-case optimal join
    (``core.gj.GenericJoin``) over its relations, with early aggregation
    folding away attributes not retained above the bag.
  * **Across nodes** — a bottom-up pass (reverse level order): each bag
    passes its result projected onto the attributes shared with its parent
    ("Between nodes (t0, t1) we pass the relations projected onto the
    shared attributes chi(t0) cap chi(t1)"). For aggregate queries whose
    outputs live in the root, the annotation rides along and the top-down
    pass is elided (Appendix A.1). For listing queries, the final result
    is assembled by joining the reduced bag results (the "top-down walk"
    as one acyclic worst-case-optimal join).

Appendix A.1 "Eliminating Redundant Work" is implemented via
``BagPlan.dedup_key``: structurally equivalent bags (same relations, same
canonicalized pattern, same aggregations, same subtrees) are computed once
— this is the 2x saving on the Barbell query the paper reports.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.compile import BagPlan, PlanAtom, QueryPlan
from repro.core.datalog import eval_expr
from repro.core.gj import GenericJoin, GJResult
from repro.core.semiring import Semiring
from repro.core.trie import Trie


@dataclasses.dataclass
class ExecStats:
    bags_run: int = 0
    bags_deduped: int = 0
    intersect_rows: int = 0


class Catalog:
    """Relation storage: base tries + reorder cache + aliases."""

    def __init__(self):
        self.tries: Dict[str, Trie] = {}
        self.aliases: Dict[str, str] = {}
        self._reordered: Dict[Tuple[str, Tuple[int, ...]], Trie] = {}
        self.scalars: Dict[str, object] = {}

    def add(self, name: str, trie: Trie):
        self.tries[name] = trie
        self._reordered = {k: v for k, v in self._reordered.items()
                           if k[0] != name}

    def alias(self, name: str, target: str):
        self.aliases[name] = target

    def resolve(self, name: str) -> str:
        seen = set()
        while name in self.aliases:
            assert name not in seen, f"alias cycle at {name}"
            seen.add(name)
            name = self.aliases[name]
        return name

    def get(self, name: str) -> Trie:
        return self.tries[self.resolve(name)]

    def __contains__(self, name: str) -> bool:
        return self.resolve(name) in self.tries

    def reordered(self, name: str, perm: Tuple[int, ...]) -> Trie:
        """Trie for ``name`` with columns permuted by ``perm`` (an index
        order; paper Section 2.2 "Column (Index) Order")."""
        base_name = self.resolve(name)
        key = (base_name, perm)
        if key not in self._reordered:
            base = self.tries[base_name]
            attrs = [base.attrs[p] for p in perm]
            self._reordered[key] = base.reorder(attrs)
        return self._reordered[key]


class Executor:
    def __init__(self, catalog: Catalog,
                 encode: Optional[Callable[[object], int]] = None,
                 backend=None):
        self.catalog = catalog
        self.encode = encode or (lambda v: int(v))
        self.backend = backend  # None -> GenericJoin resolves the default
        self.stats = ExecStats()

    # ------------------------------------------------------------------ api
    def run(self, plan: QueryPlan) -> GJResult:
        self.stats = ExecStats()
        dedup_cache: Dict[Tuple, GJResult] = {}
        aggregate = plan.semiring is not None
        if aggregate and plan.needs_top_down:
            raise ValueError(
                "aggregate outputs must live in the root bag; recompile "
                "with use_ghd=False (engine does this automatically)")

        bag_results: Dict[int, GJResult] = {}

        def eval_bag(bp: BagPlan) -> GJResult:
            child_res = [eval_bag(c) for c in bp.children]
            key = bp.dedup_key
            if key in dedup_cache:
                self.stats.bags_deduped += 1
                res = dedup_cache[key]
            else:
                res = self._run_bag(bp, child_res, aggregate, plan)
                dedup_cache[key] = res
                self.stats.bags_run += 1
            bag_results[id(bp)] = res
            return res

        root_res = eval_bag(plan.root)

        if len(plan.root.children) == 0 or aggregate:
            final = root_res
        else:
            # Listing query across bags: join the reduced bag results (the
            # paper's top-down walk, evaluated as one acyclic WCO join).
            final = self._final_join(plan, bag_results)

        return self._apply_expr(plan, final)

    # ------------------------------------------------------------ internals
    def _run_bag(self, bp: BagPlan, child_res: List[GJResult],
                 aggregate: bool, plan: QueryPlan) -> GJResult:
        gj_atoms: List[Tuple[Trie, Tuple[str, ...]]] = []
        selections: Dict[int, Dict[int, int]] = {}
        for a in bp.atoms:
            trie, vars_, sel = self._atom_trie(a, bp.var_order)
            if sel:
                selections[len(gj_atoms)] = sel
            gj_atoms.append((trie, vars_))

        for c, res in zip(bp.children, child_res):
            shared = tuple(v for v in c.bag.shared_with_parent)
            # order shared vars by this bag's var_order
            shared = tuple(v for v in bp.var_order if v in set(shared))
            t = _result_to_trie(res, shared,
                                keep_annotation=aggregate)
            gj_atoms.append((t, shared))

        semiring = plan.semiring if aggregate else None
        gj = GenericJoin(gj_atoms, bp.var_order, bp.output_vars,
                         semiring=semiring, selections=selections,
                         backend=self.backend)
        res = gj.run()
        self.stats.intersect_rows += res.num_rows
        return res

    def _atom_trie(self, a: PlanAtom, var_order: Tuple[str, ...]):
        """Reorder the atom's trie: selected positions first, live vars by
        the bag attribute order. Returns (trie, vars, selections)."""
        order_pos = {v: i for i, v in enumerate(var_order)}
        sel_positions = sorted(a.selections.keys())
        live_positions = [p for p in range(len(a.vars))
                          if p not in a.selections]
        live_positions.sort(key=lambda p: order_pos[a.vars[p]])
        perm = tuple(sel_positions + live_positions)
        trie = self.catalog.reordered(a.rel, perm)
        vars_ = tuple(a.vars[p] for p in perm)
        sels = {i: self.encode(a.selections[p])
                for i, p in enumerate(sel_positions)}
        return trie, vars_, sels

    def _final_join(self, plan: QueryPlan,
                    bag_results: Dict[int, GJResult]) -> GJResult:
        atoms: List[Tuple[Trie, Tuple[str, ...]]] = []
        all_bags = plan.bags_bottom_up()
        for bp in all_bags:
            res = bag_results[id(bp)]
            if not res.vars:
                continue
            t = _result_to_trie(res, res.vars, keep_annotation=False)
            atoms.append((t, res.vars))
        var_order = tuple(v for v in plan.order
                          if any(v in vs for _, vs in atoms))
        gj = GenericJoin(atoms, var_order, plan.output_vars, semiring=None,
                         backend=self.backend)
        return gj.run()

    def _apply_expr(self, plan: QueryPlan, res: GJResult) -> GJResult:
        return apply_expr(plan, res, self.catalog.scalars)


def apply_expr(plan: QueryPlan, res: GJResult, scalars: Dict) -> GJResult:
    """Evaluate the rule's annotation expression around the folded
    aggregate (e.g. ``y = 0.15 + 0.85*<<SUM(z)>>`` or ``y = 1/N``)."""
    expr = plan.rule.agg_expr
    if expr is None:
        return res
    agg_value = res.annotation
    if plan.semiring is None:
        # pure expression (no aggregation): one value per output tuple
        n = res.num_rows
        value = eval_expr(expr, None, scalars)
        ann = np.full((n,), value, dtype=np.float32) if res.vars else \
            np.asarray(value, dtype=np.float32)
        return GJResult(res.vars, res.columns, ann)
    value = eval_expr(expr, np.asarray(agg_value), scalars)
    return GJResult(res.vars, res.columns, np.asarray(value))


def _result_to_trie(res: GJResult, vars_: Tuple[str, ...],
                    keep_annotation: bool) -> Trie:
    """Materialize a bag result as a trie over ``vars_`` (a subsequence of
    ``res.vars``), folding the annotation by summation is NOT done here —
    annotations are already folded by the bag's own projection."""
    assert set(vars_) <= set(res.vars), (vars_, res.vars)
    cols = [np.asarray(res.columns[v]) for v in vars_]
    ann = np.asarray(res.annotation) if (keep_annotation and
                                         res.annotation is not None) else None
    if vars_ != res.vars and ann is not None:
        # project with fold happens in the bag itself; reaching here with a
        # strict subset + annotation would double-count.
        raise AssertionError("annotated pass-up must use the bag's own "
                             "output projection")
    if not vars_:
        return Trie.build("@res", ("_",), [np.zeros(0, np.int32)])
    return Trie.build("@res", vars_, cols, annotation=ann)
