"""Physical plan execution: Yannakakis over GHD bags (paper Section 3.3).

The executor is the *interpreter lowering* of the physical plan IR
(``core.plan_ir``) — the differential-testing oracle for the code
generator (``core.codegen``), which lowers the SAME IR to straight-line
source.  Neither lowering re-derives a physical decision: access paths,
layout thresholds, routing hints, and reuse keys are read off the IR.

Two phases, exactly as the paper describes:

  * **Within a node** — each bag runs the generic worst-case optimal join
    (``core.gj.GenericJoin``) over its relations, with early aggregation
    folding away attributes not retained above the bag.
  * **Across nodes** — a bottom-up pass (reverse level order): each bag
    passes its result projected onto the attributes shared with its parent
    ("Between nodes (t0, t1) we pass the relations projected onto the
    shared attributes chi(t0) cap chi(t1)"). For aggregate queries whose
    outputs live in the root, the annotation rides along and the top-down
    pass is elided (Appendix A.1). For listing queries, the final result
    is assembled by joining the reduced bag results (the "top-down walk"
    as one acyclic worst-case-optimal join) — the IR's ``TopDownJoin``
    operator, whose inputs reference every reduced bag structurally.

Appendix A.1 "Eliminating Redundant Work" operates at two scopes:

  * per-query: structurally equivalent bags (``BagPlan.dedup_key``) are
    computed once — the 2x saving on the Barbell query;
  * engine-lifetime: :class:`BagResultCache` keys a bag's result on its
    canonicalized structure PLUS the catalog versions of every relation
    its subtree reads (``MaterializeShared.reuse_struct/reuse_rels``), so
    shared sub-bags recur across *rules and iterations* without being
    recomputed, and are invalidated the moment an input relation is
    reloaded.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.compile import QueryPlan
from repro.core.datalog import eval_expr
from repro.core.gj import GenericJoin, GJResult
from repro.core.trie import Trie

# backend dispatch counters snapshotted around each bag run; "syncs" in
# per-bag metrics is the delta of these (the zero-host-sync invariant —
# ROADMAP item 3 — is stated and gated per query, not per process)
_SYNC_KEYS = ("extend.calls", "extend.host_syncs", "extend.closing_syncs",
              "extend.pipeline_extends", "pipeline.device_folds",
              "pipeline.retries", "pipeline.morsels")


@dataclasses.dataclass
class ExecStats:
    bags_run: int = 0
    bags_deduped: int = 0          # per-query structural dedup (Appendix A.1)
    bags_cached: int = 0           # engine-lifetime BagResultCache hits
    intersect_rows: int = 0


class Catalog:
    """Relation storage: base tries + reorder cache + aliases.

    Every ``add`` bumps the relation's version counter; engine-lifetime
    bag-result reuse keys include these versions, so reloading a relation
    (or a recursion round rebuilding its delta) invalidates exactly the
    cached results that read it.
    """

    def __init__(self):
        self.tries: Dict[str, Trie] = {}
        self.aliases: Dict[str, str] = {}
        self._reordered: Dict[Tuple[str, Tuple[int, ...]], Trie] = {}
        self.scalars: Dict[str, object] = {}
        self.versions: Dict[str, int] = {}
        # reorder-cache instrumentation: ``reorder_builds`` counts REAL
        # index builds (a non-identity permutation materialized+rebuilt).
        # The plan search costs candidates from base-trie profiles, so
        # discarded candidates must build nothing — the engine surfaces
        # these as ``reorder_cache.*`` in ``dispatch_summary()`` and the
        # tests assert on them.
        self.reorder_builds = 0
        self.reorder_hits = 0

    def add(self, name: str, trie: Trie):
        self.tries[name] = trie
        self.versions[name] = self.versions.get(name, 0) + 1
        self._reordered = {k: v for k, v in self._reordered.items()
                           if k[0] != name}

    def alias(self, name: str, target: str):
        self.aliases[name] = target

    def resolve(self, name: str) -> str:
        seen = set()
        while name in self.aliases:
            assert name not in seen, f"alias cycle at {name}"
            seen.add(name)
            name = self.aliases[name]
        return name

    def get(self, name: str) -> Trie:
        return self.tries[self.resolve(name)]

    def __contains__(self, name: str) -> bool:
        return self.resolve(name) in self.tries

    def version(self, name: str) -> int:
        return self.versions.get(self.resolve(name), 0)

    def version_key(self, names: Tuple[str, ...]) -> Tuple:
        """(resolved name, version) per relation — the data-identity half
        of an engine-lifetime bag reuse key (generated code calls this at
        run time so stale emitted keys are impossible)."""
        return tuple((self.resolve(n), self.version(n)) for n in names)

    def reordered(self, name: str, perm: Tuple[int, ...]) -> Trie:
        """Trie for ``name`` with columns permuted by ``perm`` (an index
        order; paper Section 2.2 "Column (Index) Order")."""
        base_name = self.resolve(name)
        key = (base_name, perm)
        if key not in self._reordered:
            base = self.tries[base_name]
            attrs = [base.attrs[p] for p in perm]
            built = base.reorder(attrs)
            if built is not base:
                self.reorder_builds += 1
            self._reordered[key] = built
        else:
            self.reorder_hits += 1
        return self._reordered[key]


class BagResultCache:
    """Engine-lifetime Appendix-A.1 cache: bag reuse key -> GJResult.

    Bounded FIFO (recursion bumps relation versions every round, so stale
    keys age out instead of accumulating). Results are treated as
    immutable by every consumer.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._data: Dict[Tuple, GJResult] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple) -> Optional[GJResult]:
        res = self._data.get(key)
        if res is None:
            self.misses += 1
        else:
            self.hits += 1
        return res

    def contains(self, key: Tuple) -> bool:
        """Peek WITHOUT touching the hit/miss counters — the plan search
        costs cached bags at zero but must not distort the instrumentation
        the benchmarks assert on."""
        return key in self._data

    def put(self, key: Tuple, res: GJResult):
        if len(self._data) >= self.maxsize:
            self._data.pop(next(iter(self._data)))
        self._data[key] = res

    def __len__(self) -> int:
        return len(self._data)


class Executor:
    """Interpreter lowering of the physical plan IR."""

    def __init__(self, catalog: Catalog,
                 encode: Optional[Callable[[object], int]] = None,
                 backend=None,
                 bag_cache: Optional[BagResultCache] = None,
                 stats_catalog=None):
        self.catalog = catalog
        self.encode = encode or (lambda v: int(v))
        self.backend = backend  # None -> GenericJoin resolves the default
        self.bag_cache = bag_cache
        self.stats_catalog = stats_catalog
        self.stats = ExecStats()
        # per-run optimizer scorecard: materialize op id -> {est, actual}
        self.metrics: Dict[int, dict] = {}

    # ------------------------------------------------------------------ api
    def run(self, plan) -> GJResult:
        """Execute a PhysicalPlan (or a QueryPlan, annotated on the fly)."""
        from repro.core import plan_ir
        from repro.core.statistics import StatisticsCatalog
        if isinstance(plan, QueryPlan):
            pplan = plan_ir.build_physical_plan(
                plan, self.stats_catalog or StatisticsCatalog(),
                self.catalog)
        else:
            pplan = plan
        lplan = pplan.logical

        self.stats = ExecStats()
        self.metrics = {}
        dedup_cache: Dict[Tuple, GJResult] = {}
        aggregate = lplan.semiring is not None
        if aggregate and lplan.needs_top_down:
            raise ValueError(
                "aggregate outputs must live in the root bag; recompile "
                "with use_ghd=False (engine does this automatically)")

        results: Dict[int, GJResult] = {}   # materialize op id -> result
        for bops in pplan.bag_ops:          # bottom-up: children first
            out_vars = bops.materialize.output_vars
            key = bops.logical.dedup_key
            if key in dedup_cache:
                self.stats.bags_deduped += 1
                res = rename_result(dedup_cache[key], out_vars)
            else:
                ck = self._reuse_key(bops.materialize)
                res = self.bag_cache.get(ck) if self.bag_cache else None
                if res is not None:
                    self.stats.bags_cached += 1
                    res = rename_result(res, out_vars)
                else:
                    res, level_actuals, syncs = self._run_bag(
                        bops, results, aggregate, lplan)
                    self.stats.bags_run += 1
                    if self.bag_cache is not None:
                        self.bag_cache.put(ck, res)
                    self.metrics[bops.materialize.op_id] = {
                        "est_rows": float(bops.materialize.est_rows),
                        "actual_rows": int(res.num_rows),
                        "level_actuals": level_actuals,
                        "syncs": syncs,
                    }
                dedup_cache[key] = res
            results[bops.materialize.op_id] = res
            self.metrics.setdefault(bops.materialize.op_id, {
                "est_rows": float(bops.materialize.est_rows),
                "actual_rows": int(res.num_rows),
            })

        root_res = results[pplan.root.materialize.op_id]
        if pplan.final is None:
            final = root_res
        else:
            final = self._final_join(pplan, results)
        return self._apply_expr(lplan, final)

    # ------------------------------------------------------------ internals
    def _reuse_key(self, mat) -> Tuple:
        # Parameterized rules (Engine.prepare) share one reuse_struct
        # across bindings — Param slots, not values, live in the dedup
        # key — so the binding itself must join the runtime key or
        # binding A's cached rows would answer binding B. Unparameterized
        # encodes carry no binding_key and contribute ().
        return (mat.reuse_struct,
                getattr(self.encode, "binding_key", ()),
                self.catalog.version_key(mat.reuse_rels))

    def _run_bag(self, bops, results: Dict[int, GJResult],
                 aggregate: bool, lplan: QueryPlan) -> GJResult:
        gj_atoms: List[Tuple[Trie, Tuple[str, ...]]] = []
        selections: Dict[int, Dict[int, int]] = {}
        for acc in bops.scan.accesses:
            sel = acc.selection_map(self.encode)
            if sel:
                selections[len(gj_atoms)] = sel
            gj_atoms.append((self.catalog.reordered(acc.rel, acc.perm),
                             acc.vars))
        for ci in bops.scan.child_inputs:
            t = _result_to_trie(results[ci.op_id], ci.vars,
                                keep_annotation=aggregate)
            gj_atoms.append((t, ci.vars))

        semiring = lplan.semiring if aggregate else None
        gj = GenericJoin(gj_atoms, bops.scan.var_order,
                         bops.materialize.output_vars,
                         semiring=semiring, selections=selections,
                         backend=self.backend, hints=bops.hints())
        # per-bag host-sync accounting: the zero-sync invariant is
        # per-query, so the bench artifact needs the delta, not the
        # backend's process-cumulative counters
        snap = {k: gj.backend.stats.get(k, 0) for k in _SYNC_KEYS}
        res = gj.run()
        syncs = {k: gj.backend.stats.get(k, 0) - snap[k]
                 for k in _SYNC_KEYS}
        self.stats.intersect_rows += res.num_rows
        return res, gj.level_actuals, syncs

    def _final_join(self, pplan, results: Dict[int, GJResult]) -> GJResult:
        """The IR's TopDownJoin: one acyclic WCO join over the reduced bag
        results, inputs referenced structurally by operator id."""
        td = pplan.final
        atoms: List[Tuple[Trie, Tuple[str, ...]]] = []
        for op_id in td.inputs:
            res = results[op_id]
            t = _result_to_trie(res, res.vars, keep_annotation=False)
            atoms.append((t, res.vars))
        gj = GenericJoin(atoms, td.var_order, td.output_vars, semiring=None,
                         backend=self.backend)
        return gj.run()

    def _apply_expr(self, plan: QueryPlan, res: GJResult) -> GJResult:
        return apply_expr(plan, res, self.catalog.scalars)


def apply_expr(plan: QueryPlan, res: GJResult, scalars: Dict) -> GJResult:
    """Evaluate the rule's annotation expression around the folded
    aggregate (e.g. ``y = 0.15 + 0.85*<<SUM(z)>>`` or ``y = 1/N``)."""
    expr = plan.rule.agg_expr
    if expr is None:
        return res
    agg_value = res.annotation
    if plan.semiring is None:
        # pure expression (no aggregation): one value per output tuple
        n = res.num_rows
        value = eval_expr(expr, None, scalars)
        ann = np.full((n,), value, dtype=np.float32) if res.vars else \
            np.asarray(value, dtype=np.float32)
        return GJResult(res.vars, res.columns, ann)
    value = eval_expr(expr, np.asarray(agg_value), scalars)
    return GJResult(res.vars, res.columns, np.asarray(value))


def rename_result(res: GJResult, vars_: Tuple[str, ...]) -> GJResult:
    """Re-label a reused bag result with this occurrence's variable names.

    Equivalent bags match on a variable-CANONICALIZED key, so a hit may
    carry the other occurrence's names (Barbell: the (x,y,z) triangle's
    result reused for (a,b,c)); the canonical output key guarantees
    positional correspondence. Columns are shared, never copied."""
    if res.vars == tuple(vars_):
        return res
    assert len(res.vars) == len(vars_), (res.vars, vars_)
    cols = {t: res.columns[s] for s, t in zip(res.vars, vars_)}
    return GJResult(tuple(vars_), cols, res.annotation)


def _result_to_trie(res: GJResult, vars_: Tuple[str, ...],
                    keep_annotation: bool) -> Trie:
    """Materialize a bag result as a trie over ``vars_`` (a subsequence of
    ``res.vars``), folding the annotation by summation is NOT done here —
    annotations are already folded by the bag's own projection."""
    assert set(vars_) <= set(res.vars), (vars_, res.vars)
    cols = [np.asarray(res.columns[v]) for v in vars_]
    ann = np.asarray(res.annotation) if (keep_annotation and
                                         res.annotation is not None) else None
    if vars_ != res.vars and ann is not None:
        # project with fold happens in the bag itself; reaching here with a
        # strict subset + annotation would double-count.
        raise AssertionError("annotated pass-up must use the bag's own "
                             "output projection")
    if not vars_:
        return Trie.build("@res", ("_",), [np.zeros(0, np.int32)])
    return Trie.build("@res", vars_, cols, annotation=ann)
