"""EmptyHeaded core: datalog -> GHD plans -> worst-case-optimal joins.

Public surface:
  * :class:`repro.core.engine.Engine` — load relations, run datalog.
  * :mod:`repro.core.ghd` — GHD search (the paper's logical plans).
  * :mod:`repro.core.gj` — vectorized Generic-Join (NPRR) executor.
  * :mod:`repro.core.layouts` — the uint/bitset set-layout optimizer.
  * :mod:`repro.core.semiring` — aggregation algebra (Green et al.).
"""
from repro.core.engine import Engine, QueryResult  # noqa: F401
from repro.core.trie import CSRGraph, Trie  # noqa: F401
