"""Cost-based plan search: GHD + attribute-order selection (paper §4).

EmptyHeaded's compiler picks the GHD and the global attribute order to
minimize work; until now this reproduction broke every tie by
query-appearance order even though the plan IR carries statistics-driven
cardinality estimates per operator.  This module closes the loop, in the
classic Selinger shape (PAPERS.md: "Access Path Selection in a
Relational DBMS" — enumerate bounded candidates, cost each with the
statistics, pick the cheapest):

  1. **enumerate** — top-k minimum-fhw edge-partition GHDs
     (``ghd.decompose_candidates``; width stays a hard constraint, per
     the paper) x alternate rootings x per-bag attribute-order group
     permutations (``ghd.candidate_orders``).  The FIRST candidate is
     exactly the seed appearance-order plan, and every candidate is
     compiled through the ordinary ``compile.compile_rule``, so a
     candidate IS a real plan.
  2. **cost** — lower each candidate to the physical IR
     (``plan_ir.build_physical_plan``; per-bag fractional-cover LPs
     memoized across candidates) and take ``plan_ir.plan_cost``: the sum
     of per-operator modelled work (AGM-capped independence-model rows,
     ``statistics`` cost-model weights with layout-cohort terms so
     bitset-cohort folds cost less than search-path folds), counting
     Appendix-A.1-equivalent bags once and engine-lifetime-cached bags
     (``BagResultCache``) at zero.
  3. **choose** — strict argmin; ties keep the earliest candidate, so
     symmetric queries reproduce the seed plan bit-for-bit.

Escape hatch: ``REPRO_PLAN_SEARCH=off`` (or ``Engine(plan_search=False)``)
pins the seed appearance-order plan — the differential-testing oracle the
regression tests compare against.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple

from repro.core import ghd as ghd_mod
from repro.core import plan_ir
from repro.core.compile import QueryPlan, compile_rule
from repro.core.statistics import StatisticsCatalog

ENV_FLAG = "REPRO_PLAN_SEARCH"

# Search bounds (the "beam"): top-k min-width partitions, alternate roots
# per partition, per-group order permutations, and a global candidate cap.
K_PARTITIONS = 4
MAX_ROOTS = 4
MAX_GROUP_PERM = 4
MAX_ORDERS_PER_GHD = 24
MAX_CANDIDATES = 96


def enabled_by_env(default: bool = True) -> bool:
    """Resolve the ``REPRO_PLAN_SEARCH`` escape hatch (default on)."""
    val = os.environ.get(ENV_FLAG)
    if val is None:
        return default
    return val.strip().lower() not in ("off", "0", "false", "no")


@dataclasses.dataclass
class SearchResult:
    chosen: QueryPlan
    physical: plan_ir.PhysicalPlan
    cost: float
    baseline_cost: float
    candidates: int
    chosen_index: int            # 0 == the seed appearance-order plan
    baseline_order: Tuple[str, ...]

    @property
    def order_changed(self) -> bool:
        return self.chosen.order != self.baseline_order

    def metadata(self) -> dict:
        """JSON-serializable optimizer-search record for
        ``Engine.plan_metadata()`` / the benchmark artifact."""
        return {
            "enabled": True,
            "candidates": int(self.candidates),
            "chosen_index": int(self.chosen_index),
            "chosen_cost": float(self.cost),
            "baseline_cost": float(self.baseline_cost),
            "chosen_order": list(self.chosen.order),
            "baseline_order": list(self.baseline_order),
            "order_changed": bool(self.order_changed),
            "chosen_fhw": float(self.chosen.ghd.width),
        }


def enumerate_candidates(base_plan: QueryPlan,
                         use_ghd: bool = True,
                         k_partitions: int = K_PARTITIONS,
                         max_roots: int = MAX_ROOTS,
                         max_group: int = MAX_GROUP_PERM,
                         max_orders: int = MAX_ORDERS_PER_GHD,
                         max_candidates: int = MAX_CANDIDATES,
                         ) -> List[QueryPlan]:
    """All candidate query plans, the seed ``base_plan`` FIRST.

    Every candidate is compiled via ``compile_rule`` with an injected
    (GHD, order) pair; candidates that an aggregate query cannot execute
    (outputs spanning bags — the executor requires aggregate outputs in
    the root) are filtered out.  Deduplication is on the global order
    plus the GHD's bag/rooting structure, so the seed plan never appears
    twice.
    """
    rule = base_plan.rule
    aggregate = base_plan.semiring is not None
    out_vars = base_plan.output_vars

    def signature(plan: QueryPlan):
        bags = tuple(sorted(
            (tuple(sorted(b.bag.edge_idxs)),
             tuple(sorted(b.bag.shared_with_parent)))
            for b in plan.bags_bottom_up()))
        root = tuple(sorted(plan.root.bag.edge_idxs))
        return (bags, root, plan.order)

    cands: List[QueryPlan] = [base_plan]
    seen = {signature(base_plan)}

    def ghd_sig(g: ghd_mod.GHD):
        bags = tuple(sorted(
            (tuple(sorted(b.edge_idxs)),
             tuple(sorted(b.parent.edge_idxs)) if b.parent else None)
            for b in g.root.walk()))
        return (bags, tuple(sorted(g.root.edge_idxs)))

    # decompose_candidates()[0] is exactly the seed GHD base_plan was
    # compiled with (unless the engine fell back to a single bag), so
    # dedup at GHD level too — otherwise every order of the seed GHD
    # would be compiled twice and dropped only after compilation.
    ghds: List[ghd_mod.GHD] = [base_plan.ghd]
    ghd_seen = {ghd_sig(base_plan.ghd)}
    if use_ghd:
        for g in ghd_mod.decompose_candidates(
                base_plan.hg, out_vars, k=k_partitions,
                max_roots=max_roots):
            gs = ghd_sig(g)
            if gs not in ghd_seen:
                ghd_seen.add(gs)
                ghds.append(g)

    for g in ghds:
        if len(cands) >= max_candidates:
            break
        if aggregate and not set(out_vars) <= set(g.root.attrs):
            continue  # executor requires aggregate outputs in the root
        for order in ghd_mod.candidate_orders(g, out_vars,
                                              max_group=max_group,
                                              limit=max_orders):
            if len(cands) >= max_candidates:
                break
            plan = compile_rule(rule, ghd=g, order=order)
            sig = signature(plan)
            if sig in seen:
                continue
            seen.add(sig)
            cands.append(plan)
    return cands


def search(base_plan: QueryPlan,
           stats: StatisticsCatalog,
           catalog,
           bag_cache=None,
           use_ghd: bool = True,
           verify: bool = False,
           counter=None,
           **bounds) -> SearchResult:
    """Cost every candidate against the CURRENT catalog statistics and
    return the cheapest (strict argmin — ties keep the seed plan).

    Candidates are lowered in PROFILE mode (``profile_tries=False``):
    every atom is costed from its base trie's statistics, so losing
    candidates leave NO reordered tries in the engine-lifetime reorder
    cache (a K-candidate search used to build up to K×atoms indexes; wide
    relations paid real materialize+sort work for plans that were then
    discarded).  Only the WINNER is re-lowered in full mode — building
    exactly the indexes execution is about to use anyway — which is also
    the plan whose routing annotations the runtime consumes.

    ``verify=True`` runs the static plan validator
    (:mod:`repro.analysis.plan_verify`) over EVERY candidate lowering —
    not just the winner — sharing the candidate loop's ``agm_memo`` for
    the AGM-cap checks; an invalid candidate is a planner bug and raises
    immediately.  ``counter`` (the backend's stats Counter) records how
    many candidates were verified under ``analysis.candidates_verified``.
    """
    if verify:
        from repro.analysis import assert_valid
    cands = enumerate_candidates(base_plan, use_ghd=use_ghd, **bounds)
    agm_memo: dict = {}
    best = None
    best_cost = None
    best_idx = 0
    baseline_cost = None
    for i, plan in enumerate(cands):
        pplan = plan_ir.build_physical_plan(plan, stats, catalog,
                                            agm_memo=agm_memo,
                                            profile_tries=False)
        if verify:
            assert_valid(pplan, catalog, stats, agm_memo=agm_memo)
            if counter is not None:
                counter["analysis.candidates_verified"] += 1
        cost = plan_ir.plan_cost(pplan, bag_cache, catalog)
        if i == 0:
            baseline_cost = cost
        if best_cost is None or cost < best_cost:
            best, best_cost, best_idx = plan, cost, i
    chosen = best
    physical = plan_ir.build_physical_plan(chosen, stats, catalog,
                                           agm_memo=agm_memo)
    if verify:
        assert_valid(physical, catalog, stats, agm_memo=agm_memo)
    return SearchResult(chosen=chosen, physical=physical,
                        cost=float(best_cost),
                        baseline_cost=float(baseline_cost),
                        candidates=len(cands), chosen_index=best_idx,
                        baseline_order=base_plan.order)
