"""Execution backends: where sets live and who intersects them.

EmptyHeaded's algorithm layer (Generic-Join over GHD bags, ``core.gj``)
is decoupled from the data-placement/intersection layer following the
GraphIt algorithm/backend split (Zhang et al. 2018):

  * :class:`NumpyBackend` — the seed behaviour: trie levels stay host
    numpy, each probe atom's lockstep binary search is a separate jitted
    call with its own host round-trip. Kept as the differential-testing
    oracle.
  * :class:`DeviceBackend` — trie levels are uploaded to device once
    (cached on the :class:`~repro.core.trie.TrieLevel`, so multi-rule and
    seminaive programs reuse the upload across iterations), every
    attribute extension runs all probe atoms in ONE fused jitted call
    with at most one host sync, and terminal-fold intersections are
    partitioned into bitset/uint cohorts via the Algorithm-3
    :class:`~repro.core.layouts.LayoutDecision` and dispatched to the
    Pallas kernels (uint×uint membership test, bitset×bitset
    AND+popcount, uint×bitset probe).

Backend selection: ``Engine(backend=...)`` accepts a backend instance or
the names ``"numpy"`` / ``"device"``; when unset, the
``REPRO_ENGINE_BACKEND`` environment variable decides (default numpy).

Every backend carries ``stats``, a flat counter recording which kernel
handled each intersection (``intersect.*`` keys count pairs) and the
host-sync discipline of the extension loop (``extend.calls`` vs
``extend.host_syncs``).  The static verification layer
(:mod:`repro.analysis`) rides the same counter: ``analysis.plans_verified``
/ ``analysis.candidates_verified`` count validator runs and
``analysis.sanitize_checks`` counts passed ``REPRO_SANITIZE`` dispatch
assertions, so the benchmark artifact's dispatch gate also proves
verification stayed on.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import intersect as I
from repro.core.layouts import engine_store_for
from repro.core.semiring import Semiring
from repro.kernels.bitset_intersect.ops import as_word_kernel
from repro.kernels.common import host_get
from repro.kernels.materialize.ops import as_materialize_kernel
from repro.kernels.uint_intersect.ops import intersect_count_csr_batched

# Pairs whose larger set exceeds this stay on the lockstep binary search
# (the SIMDGalloping analogue); shorter pairs take the membership-test
# kernel (the SIMDShuffling analogue) — Algorithm 2's regime split.
UINT_KERNEL_MAX_LEN = 256

# Index dtype of the device-resident pipeline (positions, counts,
# offsets) — mirrors intersect.segment_searchsorted's choice.
_IDX = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
_IDX_NP = np.int64 if jax.config.jax_enable_x64 else np.int32
# Without x64, on-device counts are int32: the pipeline only engages when
# the exact cross-product bound of the extension stays below this, so the
# counting pass cannot wrap around.
_COUNT_LIMIT = (1 << 62) if jax.config.jax_enable_x64 else (1 << 31) - 1

_FALSEY = frozenset({"0", "off", "false", "no"})


def _env_on(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() not in _FALSEY


class PipelineOverflow(RuntimeError):
    """A pipelined frontier buffer was undersized (the stats-informed
    capacity under-estimated the true expansion).  Raised at the single
    closing sync, BEFORE any join state was mutated.  ``needed`` carries
    the counting pass's exact per-variable output totals fetched with
    that same sync — the caller retries device-resident with buffers
    sized from the measured truth (falling back to the per-extension-
    sync host path only if that second attempt overflows too, which can
    happen when an upstream overflow truncated the rows the later
    counts were taken over)."""

    def __init__(self, msg: str, needed: Optional[Dict[str, int]] = None):
        super().__init__(msg)
        self.needed = needed or {}


class ExecBackend:
    """Protocol for the Generic-Join execution backend.

    ``extend(infos, F)`` receives the per-atom candidate descriptors of
    one attribute extension — ``infos`` is a list of
    ``(atom, values, lo, hi, mass)`` tuples sorted by total candidate
    mass (the min-property seed first) — and returns
    ``(row_id, vals, pos)`` exactly like the seed-expand-probe loop:
    ``pos`` maps ``id(atom)`` to absolute positions into that atom's
    current trie level.

    ``pair_count(trie, u, v)`` is the binary terminal-fold fast path:
    layout-routed ``|N(u_i) ∩ N(v_i)|`` counts, or ``None`` when the
    store is bypassed (layout mode "off"). ``has_pair_store(trie)`` lets
    the caller skip the frontier gathers entirely in the bypassed case.
    """

    name = "abstract"

    def __init__(self):
        self.stats: collections.Counter = collections.Counter()
        self._dtype_cache: Dict[str, np.dtype] = {}

    # jnp is resolved once at module import (not per GJ call); the per-
    # semiring canonical numpy dtype is cached per backend instance.
    def dtype_of(self, sr: Semiring) -> np.dtype:
        dt = self._dtype_cache.get(sr.name)
        if dt is None:
            dt = np.dtype(jnp.zeros((), sr.dtype).dtype)
            self._dtype_cache[sr.name] = dt
        return dt

    def extend(self, infos: Sequence[Tuple], F: int):
        raise NotImplementedError

    @staticmethod
    def _expand_seed(lo0: np.ndarray, hi0: np.ndarray, F: int):
        """Min-property seed expansion shared by both backends: flatten
        every frontier row's seed segment, returning (row_id, p0) with
        ``p0`` absolute positions into the seed level's values."""
        cnt = (hi0 - lo0).astype(np.int64)
        row_id = np.repeat(np.arange(F, dtype=np.int64), cnt)
        seg_start = np.repeat(np.concatenate([[0], np.cumsum(cnt)])[:-1], cnt)
        flat = np.arange(len(row_id), dtype=np.int64)
        p0 = np.repeat(lo0, cnt) + (flat - seg_start)
        return row_id, p0

    def _pair_store(self, trie, threshold: Optional[float] = None):
        raise NotImplementedError

    def has_pair_store(self, trie,
                       threshold: Optional[float] = None) -> bool:
        return self._pair_store(trie, threshold) is not None

    def pair_count(self, trie, u: np.ndarray, v: np.ndarray,
                   threshold: Optional[float] = None):
        """Binary terminal-fold fast path. ``threshold`` is the plan IR's
        statistics-driven Algorithm-3 density threshold (None lets the
        layout store profile the trie itself)."""
        store = self._pair_store(trie, threshold)
        if store is None:
            return None
        self.stats["fold.pair_count_calls"] += 1
        return store.intersect_count(u, v)

    def pair_materialize(self, trie, u: np.ndarray, v: np.ndarray,
                         threshold: Optional[float] = None):
        """Binary MATERIALIZING extension fast path (the plan IR's
        ``Extend.routing == "pair_store"``): cohort-routed
        ``N(u_i) ∩ N(v_i)`` with positions for trie descent, or ``None``
        when the layout store is bypassed."""
        store = self._pair_store(trie, threshold)
        if store is None:
            return None
        self.stats["extend.pair_materialize_calls"] += 1
        return store.intersect_materialize(u, v)

    def dispatch_summary(self) -> Dict[str, int]:
        return dict(self.stats)


class NumpyBackend(ExecBackend):
    """Seed behaviour: host-side expansion, one search (and one host
    round-trip) per probe atom, layout store only on the binary terminal
    fold with the plain-jnp word kernel."""

    name = "numpy"

    def extend(self, infos, F: int):
        a0, v0, lo0, hi0, _ = infos[0]
        row_id, p0 = self._expand_seed(lo0, hi0, F)
        vals = v0[p0]
        pos = {id(a0): p0}
        self.stats["extend.calls"] += 1
        for a, values, lo, hi, _m in infos[1:]:
            p, found = I.segment_searchsorted(values, lo[row_id], hi[row_id],
                                              vals)
            p = np.asarray(p); found = np.asarray(found)
            self.stats["extend.host_syncs"] += 1
            keep = found
            row_id = row_id[keep]
            vals = vals[keep]
            for k in pos:
                pos[k] = pos[k][keep]
            pos[id(a)] = p[keep]
        return row_id, vals, pos

    def _pair_store(self, trie, threshold=None):
        return engine_store_for(trie, counter=self.stats, cache_tag="host",
                                threshold=threshold)


class DeviceBackend(ExecBackend):
    """Device-resident set store: upload trie levels once, fuse every
    extension's probes into one jitted call (one host sync per attribute
    extension), and route terminal-fold intersections to the
    layout-cohort Pallas kernels."""

    name = "device"

    def __init__(self, interpret: Optional[bool] = None,
                 uint_max_len: int = UINT_KERNEL_MAX_LEN,
                 pipeline: Optional[bool] = None):
        super().__init__()
        self._interpret = interpret
        self._word_kernel = as_word_kernel(interpret=interpret)
        self._materialize_kernel = as_materialize_kernel(interpret=interpret)
        self._uint_max_len = uint_max_len
        # Zero-sync extension pipeline (count-then-fill): on by default,
        # REPRO_DEVICE_PIPELINE=off pins the per-extension-sync path as
        # the differential oracle (Engine(device_pipeline=...) overrides).
        self.pipeline_enabled = (_env_on("REPRO_DEVICE_PIPELINE", True)
                                 if pipeline is None else bool(pipeline))
        # engine-lifetime pipeline-cap feedback: bag shape -> the
        # counting pass's measured per-variable totals from an
        # overflow-retried execution, so repeated queries size their
        # frontier buffers right the first time (see GenericJoin.run)
        self.cap_feedback: Dict[Tuple, Dict[str, int]] = {}

        def uint_kernel(offsets, neighbors, u, v):
            return intersect_count_csr_batched(
                offsets, neighbors, u, v, interpret=interpret,
                max_len=uint_max_len)

        self._uint_kernel = uint_kernel

    # ------------------------------------------------------------- uploads
    def _dev_values(self, atom) -> jnp.ndarray:
        lv = atom.trie.levels[atom.depth]
        return lv.device_values(jnp.asarray, on_upload=self._count_upload)

    def _count_upload(self):
        self.stats["upload.levels"] += 1

    def _up_idx(self, arr) -> jnp.ndarray:
        return jnp.asarray(np.asarray(arr, dtype=_IDX_NP))

    # ------------------------------------------------------------- extend
    def extend(self, infos, F: int):
        self.stats["extend.calls"] += 1
        a0, v0, lo0, hi0, _ = infos[0]
        row_id, p0 = self._expand_seed(lo0, hi0, F)
        if len(row_id) == 0:
            z = np.zeros(0, np.int64)
            return z, np.zeros(0, np.int32), {id(a): z for a, *_ in infos}
        if len(infos) == 1:
            # unary extension: no probes, so the host copy already has the
            # answer — zero device traffic
            return row_id, v0[p0], {id(a0): p0}
        vals_dev = self._dev_values(a0)[p0]

        values_t = tuple(self._dev_values(a) for a, *_ in infos[1:])
        lo_t = tuple(info[2][row_id] for info in infos[1:])
        hi_t = tuple(info[3][row_id] for info in infos[1:])
        pos_t, found = _fused_probe(values_t, lo_t, hi_t, vals_dev)
        # the ONLY host round-trip of this extension: every probe atom's
        # positions + the combined membership mask come back together.
        pos_h, found_h, vals_h = host_get((pos_t, found, vals_dev))
        self.stats["extend.host_syncs"] += 1
        keep = np.asarray(found_h)
        out_row = row_id[keep]
        out_vals = np.asarray(vals_h)[keep]
        pos = {id(a0): p0[keep]}
        for (a, *_), p in zip(infos[1:], pos_h):
            pos[id(a)] = np.asarray(p)[keep]
        return out_row, out_vals, pos

    # ------------------------------------------------------ terminal folds
    def _pair_store(self, trie, threshold=None):
        return engine_store_for(trie, word_kernel=self._word_kernel,
                                 uint_kernel=self._uint_kernel,
                                 materialize_kernel=self._materialize_kernel,
                                 uint_max_len=self._uint_max_len,
                                 counter=self.stats, cache_tag="device",
                                 threshold=threshold)

    # ---------------------------------------------- zero-sync pipeline
    # The frontier stays device-resident between attribute extensions:
    # each step is ONE jitted count-then-fill program (counting probe →
    # exclusive scan → morsel-chunked fill → compaction) into a
    # static-shaped buffer, with NO host round-trip.  The join "lands"
    # once per query (``pipeline_land``'s host_get) when it reaches the
    # first host-needing step — so ``extend.host_syncs`` is zero and
    # ``extend.closing_syncs`` is one for non-materializing queries.

    def pipeline_begin(self, cursors0: Dict[int, np.ndarray],
                       ann0: Optional[np.ndarray]) -> "DeviceFrontier":
        cursors = {k: self._up_idx(c) for k, c in cursors0.items()}
        ann = jnp.asarray(ann0) if ann0 is not None else None
        return DeviceFrontier(
            cap=1, count=jnp.asarray(1, _IDX),
            overflow=jnp.asarray(False),
            morsels=jnp.asarray(0, _IDX),
            cols={}, cursors=cursors, ann=ann, level_counts=[],
            needed=[])

    def pipeline_extend(self, state: "DeviceFrontier", var: str,
                        cons: Sequence[Tuple], cap_out: int,
                        morsel: int) -> "DeviceFrontier":
        """One pipelined attribute extension.  ``cons`` lists
        ``(cursor_key, trie_level, depth0)`` per constraining atom, the
        estimated-min-property seed first.  Returns the successor state;
        nothing touches the host."""
        self.stats["extend.calls"] += 1
        self.stats["extend.pipeline_extends"] += 1
        if len(cons) > 1:
            self.stats["pipeline.sip_extends"] += 1

        def triple(key, lv, d0):
            vals = lv.device_values(jnp.asarray,
                                    on_upload=self._count_upload)
            if d0:
                return (vals, None, None)
            offs = lv.device_offsets(self._up_idx,
                                     on_upload=self._count_upload)
            return (vals, offs, state.cursors[key])

        seed = triple(*cons[0])
        probes = tuple(triple(*c) for c in cons[1:])
        probe_d0 = tuple(bool(c[2]) for c in cons[1:])
        cons_keys = {c[0] for c in cons}
        col_keys = list(state.cols)
        cur_keys = [k for k in state.cursors if k not in cons_keys]
        carry = (tuple(state.cols[k] for k in col_keys)
                 + tuple(state.cursors[k] for k in cur_keys)
                 + ((state.ann,) if state.ann is not None else ()))

        (count, overflow, chunks, total, vals_c, p0_c, pos_c,
         carry_c) = _pipeline_step(
            state.count, state.overflow, seed, probes, carry,
            cap_in=state.cap, cap_out=int(cap_out), morsel=int(morsel),
            seed_d0=bool(cons[0][2]), probe_d0=probe_d0)

        it = iter(carry_c)
        cols = {k: next(it) for k in col_keys}
        cursors = {k: next(it) for k in cur_keys}
        ann = next(it) if state.ann is not None else None
        cols[var] = vals_c
        cursors[cons[0][0]] = p0_c
        for (k, _lv, _d0), p in zip(cons[1:], pos_c):
            cursors[k] = p
        return DeviceFrontier(
            cap=int(cap_out), count=count, overflow=overflow,
            morsels=state.morsels + chunks, cols=cols, cursors=cursors,
            ann=ann, level_counts=state.level_counts + [(var, count)],
            needed=state.needed + [(var, total)])

    def pipeline_terminal_fold(self, state: "DeviceFrontier", var: str,
                               cons: Sequence[Tuple], sr: Semiring,
                               morsel: int) -> "DeviceFrontier":
        """Device-resident early aggregation of the last attribute: the
        expansion is folded per source row (no materialization, so no
        output buffer and no overflow) and rows whose candidate
        intersection is empty are compacted away — mirroring the host
        loop's ``_terminal_fold`` support semantics without a sync.

        ``cons`` lists ``(cursor_key, trie_level, depth0, leaf_ann)``
        per constraining atom, estimated-min-property seed first;
        ``leaf_ann`` is the atom's annotation vector (or None) —
        terminal atoms always exhaust their attrs here, so it is
        multiplied into each candidate's contribution at its position.
        """
        self.stats["fold.calls"] += 1
        self.stats["pipeline.device_folds"] += 1

        def triple(key, lv, d0, _ann):
            vals = lv.device_values(jnp.asarray,
                                    on_upload=self._count_upload)
            if d0:
                return (vals, None, None)
            offs = lv.device_offsets(self._up_idx,
                                     on_upload=self._count_upload)
            return (vals, offs, state.cursors[key])

        def leaf(c):
            if c[3] is None:
                return None
            return c[3].device_annotation(jnp.asarray,
                                          on_upload=self._count_upload)

        seed = triple(*cons[0])
        probes = tuple(triple(*c) for c in cons[1:])
        probe_d0 = tuple(bool(c[2]) for c in cons[1:])
        leaf_anns = tuple(leaf(c) for c in cons)
        col_keys = list(state.cols)
        cur_keys = list(state.cursors)
        carry = (tuple(state.cols[k] for k in col_keys)
                 + tuple(state.cursors[k] for k in cur_keys))

        count, chunks, ann_c, carry_c = _pipeline_fold(
            state.count, seed, probes, state.ann, leaf_anns, carry,
            cap_in=state.cap, morsel=int(morsel),
            seed_d0=bool(cons[0][2]), probe_d0=probe_d0, sr=sr)

        it = iter(carry_c)
        cols = {k: next(it) for k in col_keys}
        cursors = {k: next(it) for k in cur_keys}
        return DeviceFrontier(
            cap=state.cap, count=count, overflow=state.overflow,
            morsels=state.morsels + chunks, cols=cols, cursors=cursors,
            ann=ann_c, level_counts=state.level_counts + [(var, count)],
            needed=state.needed)

    def pipeline_ann_mul(self, state: "DeviceFrontier", sr: Semiring,
                         trie, cursor_key: int) -> None:
        """Multiply an exhausted atom's annotation into the device-
        resident frontier annotation (eager jnp ops: async dispatch, no
        sync).  Mirrors the host loop's leaf-annotation multiply."""
        ann_dev = trie.device_annotation(jnp.asarray,
                                         on_upload=self._count_upload)
        cur = state.cursors[cursor_key]
        n = ann_dev.shape[0]
        leaf = ann_dev[jnp.clip(cur, 0, max(n - 1, 0))]
        state.ann = sr.mul(state.ann, leaf.astype(state.ann.dtype))

    def pipeline_land(self, state: "DeviceFrontier"):
        """THE closing sync: fetch the compacted frontier (columns,
        cursors, annotation), the per-level counts and the overflow flag
        in one transfer.  Counted as ``extend.closing_syncs``."""
        # pack the payload into three leaves (scalars / int vectors /
        # annotation) before the transfer: per-array materialization
        # overhead would otherwise dominate the single sync on small
        # frontiers.  Every live vector shares the final capacity, so
        # one stacked matrix carries them all.
        scal = jnp.stack(
            [state.count.astype(_IDX), state.overflow.astype(_IDX),
             state.morsels.astype(_IDX)]
            + [c.astype(_IDX) for _v, c in state.level_counts]
            + [t.astype(_IDX) for _v, t in state.needed])
        col_keys = list(state.cols)
        cur_keys = list(state.cursors)
        vecs = ([state.cols[k].astype(_IDX) for k in col_keys]
                + [state.cursors[k] for k in cur_keys])
        packed = jnp.stack(vecs) if vecs else None
        scal_h, packed_h, ann = host_get((scal, packed, state.ann))
        self.stats["extend.closing_syncs"] += 1
        nl = len(state.level_counts)
        count, overflow, morsels = (int(scal_h[0]), bool(scal_h[1]),
                                    int(scal_h[2]))
        self.stats["pipeline.morsels"] += morsels
        levels = [(v, int(c)) for (v, _), c in
                  zip(state.level_counts, scal_h[3:3 + nl])]
        needed = {v: int(t) for (v, _), t in
                  zip(state.needed, scal_h[3 + nl:])}
        cols = {k: packed_h[i] for i, k in enumerate(col_keys)}
        cursors = {k: packed_h[len(col_keys) + i]
                   for i, k in enumerate(cur_keys)}
        return (count, overflow, cols, cursors, ann, levels, needed)


@dataclasses.dataclass
class DeviceFrontier:
    """Device-resident Generic-Join frontier between pipelined
    extensions.  All buffers are static-shaped ``[cap]``; ``count`` (a
    device scalar) marks the live prefix and slots past it hold garbage
    that every consumer masks.  ``overflow`` is sticky: set when a
    counting pass found more rows than the buffer holds, read exactly
    once at the closing sync."""

    cap: int                            # static buffer capacity
    count: jnp.ndarray                  # [] live rows
    overflow: jnp.ndarray               # [] bool, sticky
    morsels: jnp.ndarray                # [] fill chunks actually run
    cols: Dict[str, jnp.ndarray]        # var -> int32 [cap]
    cursors: Dict[int, jnp.ndarray]     # id(atom) -> positions [cap]
    ann: Optional[jnp.ndarray]          # semiring annotation [cap]
    level_counts: List                  # [(var, count snapshot)]
    needed: List                        # [(var, counting-pass total)]


def _bounds(values, offsets, cursor, cap_in, valid):
    """Per-row candidate bounds [cap_in] of one atom, on device: the
    whole level at depth 0 (no cursor), else the cursor's CSR segment.
    Dead rows get an empty segment."""
    n = values.shape[0]
    if cursor is None:
        lo = jnp.zeros(cap_in, _IDX)
        hi = jnp.full(cap_in, n, _IDX)
    else:
        c = jnp.clip(cursor, 0, offsets.shape[0] - 2)
        lo = offsets[c]
        hi = offsets[c + 1]
    lo = jnp.where(valid, lo, 0)
    hi = jnp.where(valid, hi, 0)
    return lo, hi


@partial(jax.jit, static_argnames=("cap_in", "cap_out", "morsel",
                                   "seed_d0", "probe_d0"))
def _pipeline_step(count, overflow, seed, probes, carry, *,
                   cap_in: int, cap_out: int, morsel: int,
                   seed_d0: bool, probe_d0: Tuple[bool, ...]):
    """One zero-sync attribute extension: count-then-fill in one program.

    1. counting probe: per-row seed-segment sizes, narrowed by sideways
       min/max information from every later (probe) atom;
    2. exclusive scan -> per-row output offsets + total (the overflow
       check against the static capacity);
    3. fill: ``morsel``-sized chunks invert the offsets (searchsorted)
       to seed positions, gather values and probe every other atom with
       the branch-free lockstep search — oversized frontiers just spill
       to the next chunk of the same loop instead of a host round-trip;
    4. compaction: scatter surviving rows to a dense prefix and gather
       the previous frontier's columns/cursors/annotation through them.

    Output ordering (frontier-row-major, values ascending within a row)
    is identical to the host path's, so results match exactly.
    """
    seed_values, seed_offsets, seed_cursor = seed
    n0 = seed_values.shape[0]
    valid = jnp.arange(cap_in, dtype=_IDX) < count
    lo0, hi0 = _bounds(seed_values, seed_offsets, seed_cursor, cap_in,
                       valid)

    # ---- sideways information passing: clip the seed segment to the
    # [max(mins), min(maxs)] envelope of the probe atoms' candidate
    # ranges — rows outside it would fail every probe anyway, so the
    # result set (and ordering) is unchanged while the expansion shrinks.
    bounds = []
    alive = valid
    gmin = gmax = None
    for (vals_k, offs_k, cur_k), d0 in zip(probes, probe_d0):
        nk = vals_k.shape[0]
        lo_k, hi_k = _bounds(vals_k, offs_k, cur_k, cap_in, valid)
        alive = alive & (lo_k < hi_k)
        mn = vals_k[jnp.clip(lo_k, 0, nk - 1)]
        mx = vals_k[jnp.clip(hi_k - 1, 0, nk - 1)]
        gmin = mn if gmin is None else jnp.maximum(gmin, mn)
        gmax = mx if gmax is None else jnp.minimum(gmax, mx)
        bounds.append((vals_k, lo_k, hi_k))
    if probes:
        p_lo, _ = I.segment_searchsorted(seed_values, lo0, hi0, gmin)
        p_hi, f_hi = I.segment_searchsorted(seed_values, lo0, hi0, gmax)
        lo0 = p_lo.astype(_IDX)
        hi0 = (p_hi + f_hi).astype(_IDX)

    # ---- counting pass + exclusive scan
    cnt = jnp.where(alive, jnp.maximum(hi0 - lo0, 0), 0).astype(_IDX)
    offs = jnp.cumsum(cnt) - cnt
    total = offs[-1] + cnt[-1]
    overflow = overflow | (total > cap_out)
    total_c = jnp.minimum(total, cap_out)

    # ---- fill: morsel-chunked expand-and-probe into static buffers
    nchunks = cap_out // morsel
    bufs = (jnp.zeros(cap_out, jnp.int32),              # values
            jnp.zeros(cap_out, _IDX),                   # source row
            jnp.zeros(cap_out, _IDX),                   # seed positions
            tuple(jnp.zeros(cap_out, _IDX) for _ in probes),
            jnp.zeros(cap_out, jnp.bool_))              # keep mask

    def cond(st):
        c = st[0]
        return (c < nchunks) & (c * morsel < total_c)

    def body(st):
        c, vals_b, row_b, p0_b, pos_bs, keep_b = st
        j = c * morsel + jnp.arange(morsel, dtype=_IDX)
        row = jnp.clip(jnp.searchsorted(offs, j, side="right") - 1,
                       0, cap_in - 1).astype(_IDX)
        p0 = lo0[row] + (j - offs[row])
        live = j < total_c
        vals = seed_values[jnp.clip(p0, 0, max(n0 - 1, 0))]
        keep = live
        poss = []
        for vals_k, lo_k, hi_k in bounds:
            pk, fk = I.segment_searchsorted(vals_k, lo_k[row], hi_k[row],
                                            vals)
            poss.append(pk.astype(_IDX))
            keep = keep & fk
        at = (c * morsel,)
        vals_b = lax.dynamic_update_slice(vals_b, vals, at)
        row_b = lax.dynamic_update_slice(row_b, row, at)
        p0_b = lax.dynamic_update_slice(p0_b, p0, at)
        pos_bs = tuple(lax.dynamic_update_slice(b, p, at)
                       for b, p in zip(pos_bs, poss))
        keep_b = lax.dynamic_update_slice(keep_b, keep, at)
        return (c + 1, vals_b, row_b, p0_b, pos_bs, keep_b)

    st = lax.while_loop(cond, body, (jnp.asarray(0, _IDX),) + bufs)
    chunks, vals_b, row_b, p0_b, pos_bs, keep = st

    # ---- compaction: dense prefix of surviving rows (order-preserving)
    widx = jnp.cumsum(keep.astype(_IDX)) - 1
    new_count = (widx[-1] + 1).astype(_IDX)
    scat = jnp.where(keep, widx, cap_out)

    def compact(x):
        return jnp.zeros((cap_out,), x.dtype).at[scat].set(x, mode="drop")

    vals_c = compact(vals_b)
    row_c = compact(row_b)
    p0_c = compact(p0_b)
    pos_c = tuple(compact(p) for p in pos_bs)
    rowg = jnp.clip(row_c, 0, cap_in - 1)
    carry_c = tuple(g[rowg] for g in carry)
    # ``total`` is the UNCAPPED counting-pass truth: landed with the
    # closing sync so an overflow retry can size this buffer exactly
    return new_count, overflow, chunks, total, vals_c, p0_c, pos_c, carry_c


@partial(jax.jit, static_argnames=("cap_in", "morsel", "seed_d0",
                                   "probe_d0", "sr"))
def _pipeline_fold(count, seed, probes, ann, leaf_anns, carry, *,
                   cap_in: int, morsel: int, seed_d0: bool,
                   probe_d0: Tuple[bool, ...], sr: Semiring):
    """Terminal-fold companion of ``_pipeline_step``: identical counting
    pass and morsel-chunked expand-and-probe, but each surviving
    candidate's semiring contribution is segment-reduced straight onto
    its source row — nothing is materialized, so no output capacity and
    no overflow.  Returns the support-compacted frontier (rows with an
    empty candidate intersection are NOT derived — same rule as the host
    fold, which Table 7's SSSP catches when violated)."""
    seed_values, seed_offsets, seed_cursor = seed
    n0 = seed_values.shape[0]
    valid = jnp.arange(cap_in, dtype=_IDX) < count
    lo0, hi0 = _bounds(seed_values, seed_offsets, seed_cursor, cap_in,
                       valid)

    bounds = []
    alive = valid
    gmin = gmax = None
    for (vals_k, offs_k, cur_k), d0 in zip(probes, probe_d0):
        nk = vals_k.shape[0]
        lo_k, hi_k = _bounds(vals_k, offs_k, cur_k, cap_in, valid)
        alive = alive & (lo_k < hi_k)
        mn = vals_k[jnp.clip(lo_k, 0, nk - 1)]
        mx = vals_k[jnp.clip(hi_k - 1, 0, nk - 1)]
        gmin = mn if gmin is None else jnp.maximum(gmin, mn)
        gmax = mx if gmax is None else jnp.minimum(gmax, mx)
        bounds.append((vals_k, lo_k, hi_k))
    if probes:
        p_lo, _ = I.segment_searchsorted(seed_values, lo0, hi0, gmin)
        p_hi, f_hi = I.segment_searchsorted(seed_values, lo0, hi0, gmax)
        lo0 = p_lo.astype(_IDX)
        hi0 = (p_hi + f_hi).astype(_IDX)

    cnt = jnp.where(alive, jnp.maximum(hi0 - lo0, 0), 0).astype(_IDX)
    offs = jnp.cumsum(cnt) - cnt
    total = offs[-1] + cnt[-1]

    plain = not probes and all(la is None for la in leaf_anns)
    if plain and sr.name == "count":
        # counting a bare segment needs no expansion at all: the
        # counting pass IS the fold (e.g. lollipop's pendant edge)
        folded = cnt.astype(sr.dtype)
        supp = cnt
        chunks = jnp.asarray(0, _IDX)
    else:
        zero = jnp.asarray(sr.zero, dtype=sr.dtype)

        def cond(st):
            c = st[0]
            return c * morsel < total

        def body(st):
            c, folded_b, supp_b = st
            j = c * morsel + jnp.arange(morsel, dtype=_IDX)
            row = jnp.clip(jnp.searchsorted(offs, j, side="right") - 1,
                           0, cap_in - 1).astype(_IDX)
            p0 = lo0[row] + (j - offs[row])
            live = j < total
            vals = seed_values[jnp.clip(p0, 0, max(n0 - 1, 0))]
            keep = live
            poss = [p0]
            for vals_k, lo_k, hi_k in bounds:
                pk, fk = I.segment_searchsorted(vals_k, lo_k[row],
                                                hi_k[row], vals)
                poss.append(pk.astype(_IDX))
                keep = keep & fk
            contrib = sr.lift(morsel)
            for la, pos in zip(leaf_anns, poss):
                if la is None:
                    continue
                nl = la.shape[0]
                at = la[jnp.clip(pos, 0, max(nl - 1, 0))]
                contrib = sr.mul(contrib, at.astype(sr.dtype))
            contrib = jnp.where(keep, contrib, zero)
            seg = row.astype(jnp.int32)
            folded_b = sr.add(folded_b,
                              sr.segment_reduce(contrib, seg, cap_in))
            supp_b = supp_b + jax.ops.segment_sum(
                keep.astype(_IDX), seg, num_segments=cap_in)
            return (c + 1, folded_b, supp_b)

        st = lax.while_loop(
            cond, body,
            (jnp.asarray(0, _IDX),
             jnp.full((cap_in,), sr.zero, dtype=sr.dtype),
             jnp.zeros(cap_in, _IDX)))
        chunks, folded, supp = st

    ann_new = sr.mul(ann, folded.astype(ann.dtype))
    support = supp > 0

    # ---- support compaction (order-preserving dense prefix)
    widx = jnp.cumsum(support.astype(_IDX)) - 1
    new_count = jnp.where(support.any(), widx[-1] + 1, 0).astype(_IDX)
    scat = jnp.where(support, widx, cap_in)

    def compact(x):
        return jnp.zeros((cap_in,), x.dtype).at[scat].set(x, mode="drop")

    ann_c = compact(ann_new)
    carry_c = tuple(compact(g) for g in carry)
    return new_count, chunks, ann_c, carry_c


@jax.jit
def _fused_probe(values_t, lo_t, hi_t, queries):
    """Probe ``queries`` into every atom's candidate segment in one jitted
    program. Each atom's search is independent of the others' outcomes
    (positions don't depend on which rows survive), so computing all
    searches then AND-ing the membership masks is equivalent to the
    sequential filter — but costs one device round-trip instead of one
    per atom."""
    poss = []
    found_all = None
    for values, lo, hi in zip(values_t, lo_t, hi_t):
        pos, found = I.segment_searchsorted(values, lo, hi, queries)
        poss.append(pos)
        found_all = found if found_all is None else (found_all & found)
    return tuple(poss), found_all


# -------------------------------------------------------------- selection
_BY_NAME = {"numpy": NumpyBackend, "host": NumpyBackend,
            "device": DeviceBackend}
_DEFAULT: Optional[ExecBackend] = None


def make_backend(spec=None) -> ExecBackend:
    """Resolve ``spec`` (instance | name | None) to a fresh backend.
    ``None`` defers to ``REPRO_ENGINE_BACKEND`` (default "numpy")."""
    if isinstance(spec, ExecBackend):
        return spec
    if spec is None:
        spec = os.environ.get("REPRO_ENGINE_BACKEND", "numpy")
    spec = str(spec).lower()
    if spec not in _BY_NAME:
        raise ValueError(f"unknown backend {spec!r}; "
                         f"expected one of {sorted(_BY_NAME)}")
    return _BY_NAME[spec]()


def default_backend() -> ExecBackend:
    """Process-wide backend for GenericJoin instances constructed without
    an explicit backend (honours REPRO_ENGINE_BACKEND at first use)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make_backend(None)
    return _DEFAULT
