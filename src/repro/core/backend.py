"""Execution backends: where sets live and who intersects them.

EmptyHeaded's algorithm layer (Generic-Join over GHD bags, ``core.gj``)
is decoupled from the data-placement/intersection layer following the
GraphIt algorithm/backend split (Zhang et al. 2018):

  * :class:`NumpyBackend` — the seed behaviour: trie levels stay host
    numpy, each probe atom's lockstep binary search is a separate jitted
    call with its own host round-trip. Kept as the differential-testing
    oracle.
  * :class:`DeviceBackend` — trie levels are uploaded to device once
    (cached on the :class:`~repro.core.trie.TrieLevel`, so multi-rule and
    seminaive programs reuse the upload across iterations), every
    attribute extension runs all probe atoms in ONE fused jitted call
    with at most one host sync, and terminal-fold intersections are
    partitioned into bitset/uint cohorts via the Algorithm-3
    :class:`~repro.core.layouts.LayoutDecision` and dispatched to the
    Pallas kernels (uint×uint membership test, bitset×bitset
    AND+popcount, uint×bitset probe).

Backend selection: ``Engine(backend=...)`` accepts a backend instance or
the names ``"numpy"`` / ``"device"``; when unset, the
``REPRO_ENGINE_BACKEND`` environment variable decides (default numpy).

Every backend carries ``stats``, a flat counter recording which kernel
handled each intersection (``intersect.*`` keys count pairs) and the
host-sync discipline of the extension loop (``extend.calls`` vs
``extend.host_syncs``).  The static verification layer
(:mod:`repro.analysis`) rides the same counter: ``analysis.plans_verified``
/ ``analysis.candidates_verified`` count validator runs and
``analysis.sanitize_checks`` counts passed ``REPRO_SANITIZE`` dispatch
assertions, so the benchmark artifact's dispatch gate also proves
verification stayed on.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import intersect as I
from repro.core.layouts import engine_store_for
from repro.core.semiring import Semiring
from repro.kernels.bitset_intersect.ops import as_word_kernel
from repro.kernels.common import audit_avals, host_get, interpret_default
from repro.kernels.frontier_fill import ops as ff_ops
from repro.kernels.frontier_fill import ref as ff_ref
from repro.kernels.materialize.ops import as_materialize_kernel
from repro.kernels.uint_intersect.ops import intersect_count_csr_batched

# Pairs whose larger set exceeds this stay on the lockstep binary search
# (the SIMDGalloping analogue); shorter pairs take the membership-test
# kernel (the SIMDShuffling analogue) — Algorithm 2's regime split.
UINT_KERNEL_MAX_LEN = 256

# Index dtype of the device-resident pipeline (positions, counts,
# offsets) — mirrors intersect.segment_searchsorted's choice.
_IDX = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
_IDX_NP = np.int64 if jax.config.jax_enable_x64 else np.int32
# Without x64, on-device counts are int32: the pipeline only engages when
# the exact cross-product bound of the extension stays below this, so the
# counting pass cannot wrap around.
_COUNT_LIMIT = (1 << 62) if jax.config.jax_enable_x64 else (1 << 31) - 1

_FALSEY = frozenset({"0", "off", "false", "no"})


def _env_on(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() not in _FALSEY


class PipelineOverflow(RuntimeError):
    """A pipelined frontier buffer was undersized (the stats-informed
    capacity under-estimated the true expansion).  Raised at the single
    closing sync, BEFORE any join state was mutated.  ``needed`` carries
    the counting pass's exact per-variable output totals fetched with
    that same sync — the caller retries device-resident with buffers
    sized from the measured truth (falling back to the per-extension-
    sync host path only if that second attempt overflows too, which can
    happen when an upstream overflow truncated the rows the later
    counts were taken over)."""

    def __init__(self, msg: str, needed: Optional[Dict[str, int]] = None):
        super().__init__(msg)
        self.needed = needed or {}


class ExecBackend:
    """Protocol for the Generic-Join execution backend.

    ``extend(infos, F)`` receives the per-atom candidate descriptors of
    one attribute extension — ``infos`` is a list of
    ``(atom, values, lo, hi, mass)`` tuples sorted by total candidate
    mass (the min-property seed first) — and returns
    ``(row_id, vals, pos)`` exactly like the seed-expand-probe loop:
    ``pos`` maps ``id(atom)`` to absolute positions into that atom's
    current trie level.

    ``pair_count(trie, u, v)`` is the binary terminal-fold fast path:
    layout-routed ``|N(u_i) ∩ N(v_i)|`` counts, or ``None`` when the
    store is bypassed (layout mode "off"). ``has_pair_store(trie)`` lets
    the caller skip the frontier gathers entirely in the bypassed case.
    """

    name = "abstract"

    def __init__(self):
        self.stats: collections.Counter = collections.Counter()
        self._dtype_cache: Dict[str, np.dtype] = {}

    # jnp is resolved once at module import (not per GJ call); the per-
    # semiring canonical numpy dtype is cached per backend instance.
    def dtype_of(self, sr: Semiring) -> np.dtype:
        dt = self._dtype_cache.get(sr.name)
        if dt is None:
            dt = np.dtype(jnp.zeros((), sr.dtype).dtype)
            self._dtype_cache[sr.name] = dt
        return dt

    def extend(self, infos: Sequence[Tuple], F: int):
        raise NotImplementedError

    @staticmethod
    def _expand_seed(lo0: np.ndarray, hi0: np.ndarray, F: int):
        """Min-property seed expansion shared by both backends: flatten
        every frontier row's seed segment, returning (row_id, p0) with
        ``p0`` absolute positions into the seed level's values."""
        cnt = (hi0 - lo0).astype(np.int64)
        row_id = np.repeat(np.arange(F, dtype=np.int64), cnt)
        seg_start = np.repeat(np.concatenate([[0], np.cumsum(cnt)])[:-1], cnt)
        flat = np.arange(len(row_id), dtype=np.int64)
        p0 = np.repeat(lo0, cnt) + (flat - seg_start)
        return row_id, p0

    def _pair_store(self, trie, threshold: Optional[float] = None):
        raise NotImplementedError

    def has_pair_store(self, trie,
                       threshold: Optional[float] = None) -> bool:
        return self._pair_store(trie, threshold) is not None

    def pair_count(self, trie, u: np.ndarray, v: np.ndarray,
                   threshold: Optional[float] = None):
        """Binary terminal-fold fast path. ``threshold`` is the plan IR's
        statistics-driven Algorithm-3 density threshold (None lets the
        layout store profile the trie itself)."""
        store = self._pair_store(trie, threshold)
        if store is None:
            return None
        self.stats["fold.pair_count_calls"] += 1
        return store.intersect_count(u, v)

    def pair_materialize(self, trie, u: np.ndarray, v: np.ndarray,
                         threshold: Optional[float] = None):
        """Binary MATERIALIZING extension fast path (the plan IR's
        ``Extend.routing == "pair_store"``): cohort-routed
        ``N(u_i) ∩ N(v_i)`` with positions for trie descent, or ``None``
        when the layout store is bypassed."""
        store = self._pair_store(trie, threshold)
        if store is None:
            return None
        self.stats["extend.pair_materialize_calls"] += 1
        return store.intersect_materialize(u, v)

    def dispatch_summary(self) -> Dict[str, int]:
        return dict(self.stats)

    def trace_count(self) -> int:
        """Number of distinct traced program shapes this backend has
        compiled — the retrace-proof counter for the serving layer's
        no-recompile-on-rebind invariant. Host backends trace nothing."""
        return 0


class NumpyBackend(ExecBackend):
    """Seed behaviour: host-side expansion, one search (and one host
    round-trip) per probe atom, layout store only on the binary terminal
    fold with the plain-jnp word kernel."""

    name = "numpy"

    def extend(self, infos, F: int):
        a0, v0, lo0, hi0, _ = infos[0]
        row_id, p0 = self._expand_seed(lo0, hi0, F)
        vals = v0[p0]
        pos = {id(a0): p0}
        self.stats["extend.calls"] += 1
        for a, values, lo, hi, _m in infos[1:]:
            p, found = I.segment_searchsorted(values, lo[row_id], hi[row_id],
                                              vals)
            p = np.asarray(p); found = np.asarray(found)
            self.stats["extend.host_syncs"] += 1
            keep = found
            row_id = row_id[keep]
            vals = vals[keep]
            for k in pos:
                pos[k] = pos[k][keep]
            pos[id(a)] = p[keep]
        return row_id, vals, pos

    def _pair_store(self, trie, threshold=None):
        return engine_store_for(trie, counter=self.stats, cache_tag="host",
                                threshold=threshold)


class DeviceBackend(ExecBackend):
    """Device-resident set store: upload trie levels once, fuse every
    extension's probes into one jitted call (one host sync per attribute
    extension), and route terminal-fold intersections to the
    layout-cohort Pallas kernels."""

    name = "device"

    def __init__(self, interpret: Optional[bool] = None,
                 uint_max_len: int = UINT_KERNEL_MAX_LEN,
                 pipeline: Optional[bool] = None):
        super().__init__()
        self._interpret = interpret
        self._word_kernel = as_word_kernel(interpret=interpret)
        self._materialize_kernel = as_materialize_kernel(interpret=interpret)
        self._uint_max_len = uint_max_len
        # Zero-sync extension pipeline (count-then-fill): on by default,
        # REPRO_DEVICE_PIPELINE=off pins the per-extension-sync path as
        # the differential oracle (Engine(device_pipeline=...) overrides).
        self.pipeline_enabled = (_env_on("REPRO_DEVICE_PIPELINE", True)
                                 if pipeline is None else bool(pipeline))
        # Whole-bag fusion: record each bag's pipelined extension chain
        # and trace it as ONE jitted composite (``run_bag``), so XLA
        # fuses step k's compaction with step k+1's counting pass and a
        # bag costs a single launch.  REPRO_FUSED_BAG=off falls back to
        # one launch per attribute step (Engine(fused_bags=...)
        # overrides).
        self.fuse_bags = _env_on("REPRO_FUSED_BAG", True)
        # Fill-stage kernel: "pallas" runs the frontier-fill kernel
        # package per morsel chunk; REPRO_FRONTIER_FILL=jnp (or any
        # falsey value) pins the plain-jnp reference path as the
        # differential oracle.
        fm = os.environ.get("REPRO_FRONTIER_FILL", "pallas")
        fm = fm.strip().lower()
        self.fill_mode = "jnp" if (fm in _FALSEY or fm == "jnp") \
            else "pallas"
        self._fill_interpret = (bool(interpret) if interpret is not None
                                else interpret_default())
        # compile-vs-steady wall split: trace keys seen once are charged
        # to compile wall, repeats to steady wall (informational only —
        # kept OUT of ``stats`` so the exact dispatch gates stay exact).
        self._traced: set = set()
        self.wall_compile_s = 0.0
        self.wall_steady_s = 0.0
        # engine-lifetime pipeline-cap feedback: bag shape -> the
        # counting pass's measured per-variable totals from an
        # overflow-retried execution, so repeated queries size their
        # frontier buffers right the first time (see GenericJoin.run)
        self.cap_feedback: Dict[Tuple, Dict[str, int]] = {}
        # trace-level audit hook (repro.analysis.jaxpr_audit): when a
        # list, run_bag/run_bag_batched append an abstract record of
        # every dispatched program — (kind, name, prog, operand avals,
        # cursor avals, ann aval, ...) — so the auditor can retrace the
        # exact jaxprs the engine ran without holding device buffers.
        self.audit_log: Optional[List[tuple]] = None

        def uint_kernel(offsets, neighbors, u, v):
            return intersect_count_csr_batched(
                offsets, neighbors, u, v, interpret=interpret,
                max_len=uint_max_len)

        self._uint_kernel = uint_kernel

    # ------------------------------------------------------------- uploads
    def _dev_values(self, atom) -> jnp.ndarray:
        lv = atom.trie.levels[atom.depth]
        return lv.device_values(jnp.asarray, on_upload=self._count_upload)

    def _count_upload(self):
        self.stats["upload.levels"] += 1

    def _up_idx(self, arr) -> jnp.ndarray:
        return jnp.asarray(np.asarray(arr, dtype=_IDX_NP))

    def _dev_sideways(self, bs):
        """Device copies of a blocked bitset's DIRECTORY (slot router,
        block CSR, block ids) for the counting pass's sideways block
        intersection — the words themselves stay host-side.  Cached on
        the bitset instance, invalidated if the bitset was rebuilt."""
        cached = getattr(bs, "_dev_sideways_cache", None)
        if cached is not None and cached[0] is bs.block_ids:
            return cached[1]
        dev = (jnp.asarray(np.asarray(bs.slot_of, np.int32)),
               self._up_idx(bs.offsets),
               jnp.asarray(np.asarray(bs.block_ids, np.int32)))
        bs._dev_sideways_cache = (bs.block_ids, dev)
        self.stats["upload.bitset_dirs"] += 1
        return dev

    def _timed(self, key, fn, *args, **kw):
        """Dispatch ``fn`` and charge its wall time to the compile or
        steady bucket by whether this trace ``key`` was seen before.
        Measures dispatch/trace wall only (no blocking sync — that
        would be a transfer, and the whole point is not to have one)."""
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        if key in self._traced:
            self.wall_steady_s += dt
        else:
            self._traced.add(key)
            self.wall_compile_s += dt
        return out

    def wall_split(self) -> Dict[str, float]:
        return {"pipeline.wall_compile_s": round(self.wall_compile_s, 6),
                "pipeline.wall_steady_s": round(self.wall_steady_s, 6)}

    def trace_count(self) -> int:
        return len(self._traced)

    def _sideways_dev(self, cons):
        """Per-probe device sideways tuples + static block_bits for an
        extension's constraining atoms (seed excluded)."""
        sw_t, bits_t = [], []
        for c in cons[1:]:
            sw = c[3]
            if sw is None:
                sw_t.append(None)
                bits_t.append(None)
            else:
                l0, bs = sw
                l0v = l0.device_values(jnp.asarray,
                                       on_upload=self._count_upload)
                slot_d, boffs_d, bids_d = self._dev_sideways(bs)
                sw_t.append((l0v, slot_d, boffs_d, bids_d))
                bits_t.append(int(bs.block_bits))
        return tuple(sw_t), tuple(bits_t)

    # ------------------------------------------------------------- extend
    def extend(self, infos, F: int):
        self.stats["extend.calls"] += 1
        a0, v0, lo0, hi0, _ = infos[0]
        row_id, p0 = self._expand_seed(lo0, hi0, F)
        if len(row_id) == 0:
            z = np.zeros(0, np.int64)
            return z, np.zeros(0, np.int32), {id(a): z for a, *_ in infos}
        if len(infos) == 1:
            # unary extension: no probes, so the host copy already has the
            # answer — zero device traffic
            return row_id, v0[p0], {id(a0): p0}
        vals_dev = self._dev_values(a0)[p0]

        values_t = tuple(self._dev_values(a) for a, *_ in infos[1:])
        lo_t = tuple(info[2][row_id] for info in infos[1:])
        hi_t = tuple(info[3][row_id] for info in infos[1:])
        pos_t, found = _fused_probe(values_t, lo_t, hi_t, vals_dev)
        # the ONLY host round-trip of this extension: every probe atom's
        # positions + the combined membership mask come back together.
        pos_h, found_h, vals_h = host_get((pos_t, found, vals_dev))
        self.stats["extend.host_syncs"] += 1
        keep = np.asarray(found_h)
        out_row = row_id[keep]
        out_vals = np.asarray(vals_h)[keep]
        pos = {id(a0): p0[keep]}
        for (a, *_), p in zip(infos[1:], pos_h):
            pos[id(a)] = np.asarray(p)[keep]
        return out_row, out_vals, pos

    # ------------------------------------------------------ terminal folds
    def _pair_store(self, trie, threshold=None):
        return engine_store_for(trie, word_kernel=self._word_kernel,
                                 uint_kernel=self._uint_kernel,
                                 materialize_kernel=self._materialize_kernel,
                                 uint_max_len=self._uint_max_len,
                                 counter=self.stats, cache_tag="device",
                                 threshold=threshold)

    # ---------------------------------------------- zero-sync pipeline
    # The frontier stays device-resident between attribute extensions:
    # each step is ONE jitted count-then-fill program (counting probe →
    # exclusive scan → morsel-chunked fill → compaction) into a
    # static-shaped buffer, with NO host round-trip.  The join "lands"
    # once per query (``pipeline_land``'s host_get) when it reaches the
    # first host-needing step — so ``extend.host_syncs`` is zero and
    # ``extend.closing_syncs`` is one for non-materializing queries.

    def pipeline_begin(self, cursors0: Dict[int, np.ndarray],
                       ann0: Optional[np.ndarray]) -> "DeviceFrontier":
        cursors = {k: self._up_idx(c) for k, c in cursors0.items()}
        ann = jnp.asarray(ann0) if ann0 is not None else None
        return DeviceFrontier(
            cap=1, count=jnp.asarray(1, _IDX),
            overflow=jnp.asarray(False),
            morsels=jnp.asarray(0, _IDX),
            cols={}, cursors=cursors, ann=ann, level_counts=[],
            needed=[])

    def pipeline_extend(self, state: "DeviceFrontier", var: str,
                        cons: Sequence[Tuple], cap_out: int,
                        morsel: int) -> "DeviceFrontier":
        """One pipelined attribute extension.  ``cons`` lists
        ``(cursor_key, trie_level, depth0, sideways)`` per constraining
        atom, the estimated-min-property seed first; ``sideways`` is
        ``(level0, blocked_bitset)`` when the counting pass should also
        intersect that probe atom's bitset blocks (dense cohorts —
        prunes before expansion, not just clips), else None.  Returns
        the successor state; nothing touches the host."""
        self.stats["extend.calls"] += 1
        self.stats["extend.pipeline_extends"] += 1
        self.stats["pipeline.launches"] += 1
        if len(cons) > 1:
            self.stats["pipeline.sip_extends"] += 1
        if any(c[3] is not None for c in cons[1:]):
            self.stats["pipeline.sideways_extends"] += 1

        def triple(key, lv, d0, _sw):
            vals = lv.device_values(jnp.asarray,
                                    on_upload=self._count_upload)
            if d0:
                return (vals, None, None)
            offs = lv.device_offsets(self._up_idx,
                                     on_upload=self._count_upload)
            return (vals, offs, state.cursors[key])

        seed = triple(*cons[0])
        probes = tuple(triple(*c) for c in cons[1:])
        probe_d0 = tuple(bool(c[2]) for c in cons[1:])
        sideways, sideways_bits = self._sideways_dev(cons)
        cons_keys = {c[0] for c in cons}
        col_keys = list(state.cols)
        cur_keys = [k for k in state.cursors if k not in cons_keys]
        carry = (tuple(state.cols[k] for k in col_keys)
                 + tuple(state.cursors[k] for k in cur_keys)
                 + ((state.ann,) if state.ann is not None else ()))

        (count, overflow, chunks, total, vals_c, p0_c, pos_c,
         carry_c) = self._timed(
            ("step", state.cap, int(cap_out), int(morsel), probe_d0,
             sideways_bits, len(carry)),
            _pipeline_step,
            state.count, state.overflow, seed, probes, sideways, carry,
            cap_in=state.cap, cap_out=int(cap_out), morsel=int(morsel),
            seed_d0=bool(cons[0][2]), probe_d0=probe_d0,
            sideways_bits=sideways_bits, fill_mode=self.fill_mode,
            fill_interpret=self._fill_interpret)

        it = iter(carry_c)
        cols = {k: next(it) for k in col_keys}
        cursors = {k: next(it) for k in cur_keys}
        ann = next(it) if state.ann is not None else None
        cols[var] = vals_c
        cursors[cons[0][0]] = p0_c
        for (k, _lv, _d0, _sw), p in zip(cons[1:], pos_c):
            cursors[k] = p
        return DeviceFrontier(
            cap=int(cap_out), count=count, overflow=overflow,
            morsels=state.morsels + chunks, cols=cols, cursors=cursors,
            ann=ann, level_counts=state.level_counts + [(var, count)],
            needed=state.needed + [(var, total)])

    def pipeline_terminal_fold(self, state: "DeviceFrontier", var: str,
                               cons: Sequence[Tuple], sr: Semiring,
                               morsel: int) -> "DeviceFrontier":
        """Device-resident early aggregation of the last attribute: the
        expansion is folded per source row (no materialization, so no
        output buffer and no overflow) and rows whose candidate
        intersection is empty are compacted away — mirroring the host
        loop's ``_terminal_fold`` support semantics without a sync.

        ``cons`` lists ``(cursor_key, trie_level, depth0, leaf_ann)``
        per constraining atom, estimated-min-property seed first;
        ``leaf_ann`` is the atom's annotation vector (or None) —
        terminal atoms always exhaust their attrs here, so it is
        multiplied into each candidate's contribution at its position.
        """
        self.stats["fold.calls"] += 1
        self.stats["pipeline.device_folds"] += 1
        self.stats["pipeline.launches"] += 1

        def triple(key, lv, d0, _ann):
            vals = lv.device_values(jnp.asarray,
                                    on_upload=self._count_upload)
            if d0:
                return (vals, None, None)
            offs = lv.device_offsets(self._up_idx,
                                     on_upload=self._count_upload)
            return (vals, offs, state.cursors[key])

        def leaf(c):
            if c[3] is None:
                return None
            return c[3].device_annotation(jnp.asarray,
                                          on_upload=self._count_upload)

        seed = triple(*cons[0])
        probes = tuple(triple(*c) for c in cons[1:])
        probe_d0 = tuple(bool(c[2]) for c in cons[1:])
        leaf_anns = tuple(leaf(c) for c in cons)
        col_keys = list(state.cols)
        cur_keys = list(state.cursors)
        carry = (tuple(state.cols[k] for k in col_keys)
                 + tuple(state.cursors[k] for k in cur_keys))

        count, chunks, ann_c, carry_c = self._timed(
            ("fold", state.cap, int(morsel), probe_d0, sr.name,
             len(carry)),
            _pipeline_fold,
            state.count, seed, probes, state.ann, leaf_anns, carry,
            cap_in=state.cap, morsel=int(morsel),
            seed_d0=bool(cons[0][2]), probe_d0=probe_d0, sr=sr)

        it = iter(carry_c)
        cols = {k: next(it) for k in col_keys}
        cursors = {k: next(it) for k in cur_keys}
        return DeviceFrontier(
            cap=state.cap, count=count, overflow=state.overflow,
            morsels=state.morsels + chunks, cols=cols, cursors=cursors,
            ann=ann_c, level_counts=state.level_counts + [(var, count)],
            needed=state.needed)

    def pipeline_ann_mul(self, state: "DeviceFrontier", sr: Semiring,
                         trie, cursor_key: int) -> None:
        """Multiply an exhausted atom's annotation into the device-
        resident frontier annotation (eager jnp ops: async dispatch, no
        sync).  Mirrors the host loop's leaf-annotation multiply."""
        ann_dev = trie.device_annotation(jnp.asarray,
                                         on_upload=self._count_upload)
        cur = state.cursors[cursor_key]
        n = ann_dev.shape[0]
        leaf = ann_dev[jnp.clip(cur, 0, max(n - 1, 0))]
        state.ann = sr.mul(state.ann, leaf.astype(state.ann.dtype))

    def run_bag(self, cursors0: Dict[int, np.ndarray],
                ann0: Optional[np.ndarray],
                steps: Sequence[Tuple]) -> "DeviceFrontier":
        """Execute ONE bag's whole recorded extension chain as a single
        jitted composite — the fused counterpart of calling
        ``pipeline_begin`` + per-attribute ``pipeline_extend`` /
        ``pipeline_terminal_fold`` / ``pipeline_ann_mul``.

        ``steps`` is the host-recorded plan (one tuple per attribute:
        ``("extend", var, cons, cap_out, morsel)``,
        ``("fold", var, cons, sr, morsel)`` or
        ``("annmul", cursor_key, trie, sr)`` with the same ``cons``
        descriptors the per-step methods take).  The chain is lowered to
        a pure hashable program over a flat deduplicated operand list,
        so ``_bag_program`` retraces only when the bag SHAPE changes —
        and XLA sees step k's compaction and step k+1's counting pass in
        one module, fusing across the attribute boundary.  One launch
        per bag; the closing ``pipeline_land`` stays the only transfer.
        """
        self.stats["pipeline.launches"] += 1
        prog_t, arrays, canon, cap = self._lower_bag(steps, cursors0)
        cur_canon = {canon[k]: self._up_idx(c)
                     for k, c in cursors0.items()}
        ann = jnp.asarray(ann0) if ann0 is not None else None
        if self.audit_log is not None:
            self.audit_log.append(
                ("bag", "bag", prog_t, audit_avals(tuple(arrays)),
                 audit_avals(cur_canon), audit_avals(ann),
                 self.fill_mode, self._fill_interpret))
        (count, overflow, morsels, lcounts, needs, cols, cursors,
         ann_o) = self._timed(
            ("bag", prog_t, self.fill_mode),
            _bag_program, tuple(arrays), cur_canon, ann,
            prog=prog_t, fill_mode=self.fill_mode,
            fill_interpret=self._fill_interpret)
        id_of = {v: k for k, v in canon.items()}
        lvars = [s[1] for s in prog_t if s[0] in ("extend", "fold")]
        evars = [s[1] for s in prog_t if s[0] == "extend"]
        return DeviceFrontier(
            cap=cap, count=count, overflow=overflow, morsels=morsels,
            cols=dict(cols),
            cursors={id_of[c]: cur for c, cur in cursors.items()},
            ann=ann_o, level_counts=list(zip(lvars, lcounts)),
            needed=list(zip(evars, needs)))

    def _lower_bag(self, steps: Sequence[Tuple],
                   cursors0: Dict[int, np.ndarray]):
        """Lower a host-recorded bag chain to the pure hashable program
        ``_bag_program`` consumes: ``(prog, arrays, canon, final cap)``.
        Shared by the single-query ``run_bag`` and the vmapped
        ``run_bag_batched`` — the program is identical; only the cursor
        rank differs.  Dispatch counters for the chain's steps are
        charged here, once per lowered chain."""
        canon: Dict[int, int] = {}

        def ckey(k):
            if k not in canon:
                canon[k] = len(canon)
            return canon[k]

        for k in cursors0:
            ckey(k)
        arrays: List = []
        seen: Dict[int, int] = {}

        def aref(x):
            if x is None:
                return -1
            i = seen.get(id(x))
            if i is None:
                i = len(arrays)
                arrays.append(x)
                seen[id(x)] = i
            return i

        def upload(lv, d0):
            vals_i = aref(lv.device_values(jnp.asarray,
                                           on_upload=self._count_upload))
            offs_i = -1 if d0 else aref(lv.device_offsets(
                self._up_idx, on_upload=self._count_upload))
            return vals_i, offs_i

        prog = []
        cap = 1
        for step in steps:
            kind = step[0]
            if kind == "extend":
                _, var, cons, cap_out, morsel = step
                self.stats["extend.calls"] += 1
                self.stats["extend.pipeline_extends"] += 1
                if len(cons) > 1:
                    self.stats["pipeline.sip_extends"] += 1
                if any(c[3] is not None for c in cons[1:]):
                    self.stats["pipeline.sideways_extends"] += 1
                cdescs = []
                for i, (key, lv, d0, sw) in enumerate(cons):
                    vals_i, offs_i = upload(lv, d0)
                    swt = None
                    if sw is not None and i > 0:
                        l0, bs = sw
                        l0v = l0.device_values(
                            jnp.asarray, on_upload=self._count_upload)
                        slot_d, boffs_d, bids_d = self._dev_sideways(bs)
                        swt = (aref(l0v), aref(slot_d), aref(boffs_d),
                               aref(bids_d), int(bs.block_bits))
                    cdescs.append((ckey(key), vals_i, offs_i, swt))
                prog.append(("extend", var, int(cap_out), int(morsel),
                             tuple(cdescs)))
                cap = int(cap_out)
            elif kind == "fold":
                _, var, cons, sr, morsel = step
                self.stats["fold.calls"] += 1
                self.stats["pipeline.device_folds"] += 1
                cdescs = []
                for key, lv, d0, ann_trie in cons:
                    vals_i, offs_i = upload(lv, d0)
                    ann_i = -1
                    if ann_trie is not None:
                        ann_i = aref(ann_trie.device_annotation(
                            jnp.asarray, on_upload=self._count_upload))
                    cdescs.append((ckey(key), vals_i, offs_i, ann_i))
                prog.append(("fold", var, int(morsel), sr,
                             tuple(cdescs)))
            elif kind == "annmul":
                _, key, trie, sr = step
                ann_i = aref(trie.device_annotation(
                    jnp.asarray, on_upload=self._count_upload))
                prog.append(("annmul", ckey(key), ann_i, sr))
            else:
                raise ValueError(f"unknown bag step {kind!r}")
        return tuple(prog), arrays, canon, cap

    def run_bag_batched(self, cursors0: Dict[int, np.ndarray],
                        ann0: Optional[np.ndarray],
                        steps: Sequence[Tuple]) -> "BatchedFrontier":
        """Execute B same-shape bag instances as ONE fused device launch.

        ``cursors0`` maps each pre-bound atom to a ``[B, 1]`` cursor
        stack — one row per query.  The chain is lowered through the
        SAME ``_lower_bag`` as the single-query path, then dispatched
        through ``_bag_program_batch``: ``jax.vmap`` maps the leading
        batch dimension over the cursors while the operand arrays (trie
        levels — shared by every query) stay unbatched.  One launch
        (``pipeline.launches`` += 1, ``pipeline.batched_launches`` += 1)
        serves all B probes; ``pipeline_land_batched`` is the single
        closing transfer.

        The fill stage is pinned to the plain-jnp reference path:
        ``lax.while_loop`` under vmap is fine (the cond becomes
        any-active), but the frontier-fill Pallas kernel is not vetted
        under a batching rule — and the reference is bit-identical by
        the kernel contract, so batched-vs-sequential parity stays
        EXACT.
        """
        b = next(iter(cursors0.values())).shape[0]
        self.stats["pipeline.launches"] += 1
        self.stats["pipeline.batched_launches"] += 1
        self.stats["pipeline.batched_queries"] += int(b)
        prog_t, arrays, canon, cap = self._lower_bag(steps, cursors0)
        cur_canon = {canon[k]: self._up_idx(c)
                     for k, c in cursors0.items()}
        ann = jnp.asarray(ann0) if ann0 is not None else None
        if self.audit_log is not None:
            self.audit_log.append(
                ("bag_batch", "bag_batch", prog_t,
                 audit_avals(tuple(arrays)), audit_avals(cur_canon),
                 audit_avals(ann), int(b), self._fill_interpret))
        (count, overflow, morsels, lcounts, needs, cols, cursors,
         ann_o) = self._timed(
            ("bag_batch", prog_t, int(b)),
            _bag_program_batch, tuple(arrays), cur_canon, ann,
            prog=prog_t, fill_interpret=self._fill_interpret)
        id_of = {v: k for k, v in canon.items()}
        lvars = [s[1] for s in prog_t if s[0] in ("extend", "fold")]
        evars = [s[1] for s in prog_t if s[0] == "extend"]
        return BatchedFrontier(
            batch=int(b), cap=cap, count=count, overflow=overflow,
            morsels=morsels, cols=dict(cols),
            cursors={id_of[c]: cur for c, cur in cursors.items()},
            ann=ann_o, level_counts=list(zip(lvars, lcounts)),
            needed=list(zip(evars, needs)))

    def pipeline_land_batched(self, state: "BatchedFrontier"):
        """THE closing sync of a batched bag run: every query's compacted
        frontier, per-level counts and overflow flag in ONE transfer
        (``extend.closing_syncs`` += 1 for the whole batch)."""
        scal = jnp.stack(
            [state.count.astype(_IDX), state.overflow.astype(_IDX),
             state.morsels.astype(_IDX)]
            + [c.astype(_IDX) for _v, c in state.level_counts]
            + [t.astype(_IDX) for _v, t in state.needed])   # [3+nl+nn, B]
        col_keys = list(state.cols)
        cur_keys = list(state.cursors)
        vecs = ([state.cols[k].astype(_IDX) for k in col_keys]
                + [state.cursors[k] for k in cur_keys])
        packed = jnp.stack(vecs) if vecs else None          # [nv, B, cap]
        scal_h, packed_h, ann = host_get((scal, packed, state.ann))
        self.stats["extend.closing_syncs"] += 1
        nl = len(state.level_counts)
        counts = np.asarray(scal_h[0], dtype=np.int64)
        overflows = np.asarray(scal_h[1]).astype(bool)
        self.stats["pipeline.morsels"] += int(np.asarray(scal_h[2]).sum())
        # worst case over the batch per variable: the retry loop sizes
        # ONE shared buffer shape for every query in the batch
        needed = {v: int(np.asarray(t).max(initial=0)) for (v, _), t in
                  zip(state.needed, scal_h[3 + nl:])}
        cols = {k: np.asarray(packed_h[i]) for i, k in enumerate(col_keys)}
        cursors = {k: np.asarray(packed_h[len(col_keys) + i])
                   for i, k in enumerate(cur_keys)}
        ann = np.asarray(ann) if ann is not None else None
        return (counts, overflows, cols, cursors, ann, needed)

    def pipeline_land(self, state: "DeviceFrontier"):
        """THE closing sync: fetch the compacted frontier (columns,
        cursors, annotation), the per-level counts and the overflow flag
        in one transfer.  Counted as ``extend.closing_syncs``."""
        # pack the payload into three leaves (scalars / int vectors /
        # annotation) before the transfer: per-array materialization
        # overhead would otherwise dominate the single sync on small
        # frontiers.  Every live vector shares the final capacity, so
        # one stacked matrix carries them all.
        scal = jnp.stack(
            [state.count.astype(_IDX), state.overflow.astype(_IDX),
             state.morsels.astype(_IDX)]
            + [c.astype(_IDX) for _v, c in state.level_counts]
            + [t.astype(_IDX) for _v, t in state.needed])
        col_keys = list(state.cols)
        cur_keys = list(state.cursors)
        vecs = ([state.cols[k].astype(_IDX) for k in col_keys]
                + [state.cursors[k] for k in cur_keys])
        packed = jnp.stack(vecs) if vecs else None
        scal_h, packed_h, ann = host_get((scal, packed, state.ann))
        self.stats["extend.closing_syncs"] += 1
        nl = len(state.level_counts)
        count, overflow, morsels = (int(scal_h[0]), bool(scal_h[1]),
                                    int(scal_h[2]))
        self.stats["pipeline.morsels"] += morsels
        levels = [(v, int(c)) for (v, _), c in
                  zip(state.level_counts, scal_h[3:3 + nl])]
        needed = {v: int(t) for (v, _), t in
                  zip(state.needed, scal_h[3 + nl:])}
        cols = {k: packed_h[i] for i, k in enumerate(col_keys)}
        cursors = {k: packed_h[len(col_keys) + i]
                   for i, k in enumerate(cur_keys)}
        return (count, overflow, cols, cursors, ann, levels, needed)


@dataclasses.dataclass
class DeviceFrontier:
    """Device-resident Generic-Join frontier between pipelined
    extensions.  All buffers are static-shaped ``[cap]``; ``count`` (a
    device scalar) marks the live prefix and slots past it hold garbage
    that every consumer masks.  ``overflow`` is sticky: set when a
    counting pass found more rows than the buffer holds, read exactly
    once at the closing sync."""

    cap: int                            # static buffer capacity
    count: jnp.ndarray                  # [] live rows
    overflow: jnp.ndarray               # [] bool, sticky
    morsels: jnp.ndarray                # [] fill chunks actually run
    cols: Dict[str, jnp.ndarray]        # var -> int32 [cap]
    cursors: Dict[int, jnp.ndarray]     # id(atom) -> positions [cap]
    ann: Optional[jnp.ndarray]          # semiring annotation [cap]
    level_counts: List                  # [(var, count snapshot)]
    needed: List                        # [(var, counting-pass total)]


@dataclasses.dataclass
class BatchedFrontier:
    """``DeviceFrontier`` with a leading batch dimension: B same-shape
    bag instances executed by one vmapped program.  Every per-query
    field gains axis 0 of extent ``batch``; ``cap`` stays the (shared)
    static buffer capacity."""

    batch: int                          # B
    cap: int                            # static buffer capacity (shared)
    count: jnp.ndarray                  # [B] live rows per query
    overflow: jnp.ndarray               # [B] bool, sticky per query
    morsels: jnp.ndarray                # [B] fill chunks per query
    cols: Dict[str, jnp.ndarray]        # var -> int32 [B, cap]
    cursors: Dict[int, jnp.ndarray]     # id(atom) -> positions [B, cap]
    ann: Optional[jnp.ndarray]          # semiring annotation [B, cap]
    level_counts: List                  # [(var, [B] counts)]
    needed: List                        # [(var, [B] counting totals)]


@partial(jax.jit, static_argnames=("prog", "fill_interpret"))
def _bag_program_batch(arrays, cursors0, ann, *, prog: Tuple,
                       fill_interpret: bool):
    """B same-shape bag instances as ONE traced program: ``jax.vmap``
    over the leading cursor axis of ``_bag_program``'s body.  The
    operand ``arrays`` (trie levels, annotations, bitset directories)
    are closed over un-batched — every query reads the same resident
    relations — so XLA sees one module whose only batched inputs are the
    ``[B, 1]`` pre-bound cursors.  The trace key is (bag shape, B): a
    re-bound batch of the same size relaunches without retracing."""
    def one(cur):
        return _bag_program(arrays, cur, ann, prog=prog,
                            fill_mode="jnp",
                            fill_interpret=fill_interpret)
    return jax.vmap(one)(cursors0)


def _bounds(values, offsets, cursor, cap_in, valid):
    """Per-row candidate bounds [cap_in] of one atom, on device: the
    whole level at depth 0 (no cursor), else the cursor's CSR segment.
    Dead rows get an empty segment."""
    n = values.shape[0]
    if cursor is None:
        lo = jnp.zeros(cap_in, _IDX)
        hi = jnp.full(cap_in, n, _IDX)
    else:
        c = jnp.clip(cursor, 0, offsets.shape[0] - 2)
        lo = offsets[c]
        hi = offsets[c + 1]
    lo = jnp.where(valid, lo, 0)
    hi = jnp.where(valid, hi, 0)
    return lo, hi


@partial(jax.jit, static_argnames=("cap_in", "cap_out", "morsel",
                                   "seed_d0", "probe_d0",
                                   "sideways_bits", "fill_mode",
                                   "fill_interpret"))
def _pipeline_step(count, overflow, seed, probes, sideways, carry, *,
                   cap_in: int, cap_out: int, morsel: int,
                   seed_d0: bool, probe_d0: Tuple[bool, ...],
                   sideways_bits: Tuple = (), fill_mode: str = "jnp",
                   fill_interpret: bool = True):
    """Per-step (unfused) jitted wrapper around ``_extend_body`` — one
    launch per attribute extension.  Whole-bag fusion calls the body
    directly from ``_bag_program`` instead."""
    return _extend_body(count, overflow, seed, probes, sideways, carry,
                        cap_in=cap_in, cap_out=cap_out, morsel=morsel,
                        sideways_bits=sideways_bits, fill_mode=fill_mode,
                        fill_interpret=fill_interpret)


def _extend_body(count, overflow, seed, probes, sideways, carry, *,
                 cap_in: int, cap_out: int, morsel: int,
                 sideways_bits: Tuple, fill_mode: str,
                 fill_interpret: bool):
    """One zero-sync attribute extension: count-then-fill in one program.

    1. counting probe: per-row seed-segment sizes, narrowed by sideways
       min/max information from every later (probe) atom — and, for
       probe atoms with a ``sideways`` bitset directory, by intersecting
       the probe row's POPULATED bitset blocks with the envelope (dense
       cohorts prune before expansion, not just clip);
    2. exclusive scan -> per-row output offsets + total (the overflow
       check against the static capacity);
    3. fill: ``morsel``-sized chunks invert the offsets (searchsorted)
       to seed positions, gather values and probe every other atom with
       the branch-free lockstep search — one ``frontier_fill`` Pallas
       launch per chunk (``fill_mode="jnp"`` pins the bit-identical
       plain-jnp reference), and oversized frontiers just spill to the
       next chunk of the same loop instead of a host round-trip;
    4. compaction: scatter surviving rows to a dense prefix and gather
       the previous frontier's columns/cursors/annotation through them.

    Output ordering (frontier-row-major, values ascending within a row)
    is identical to the host path's, so results match exactly.
    """
    seed_values, seed_offsets, seed_cursor = seed
    n0 = seed_values.shape[0]
    valid = jnp.arange(cap_in, dtype=_IDX) < count
    lo0, hi0 = _bounds(seed_values, seed_offsets, seed_cursor, cap_in,
                       valid)

    # ---- sideways information passing: clip the seed segment to the
    # [max(mins), min(maxs)] envelope of the probe atoms' candidate
    # ranges — rows outside it would fail every probe anyway, so the
    # result set (and ordering) is unchanged while the expansion shrinks.
    bounds = []
    alive = valid
    gmin = gmax = None
    cur_ks = []
    for vals_k, offs_k, cur_k in probes:
        nk = vals_k.shape[0]
        lo_k, hi_k = _bounds(vals_k, offs_k, cur_k, cap_in, valid)
        alive = alive & (lo_k < hi_k)
        mn = vals_k[jnp.clip(lo_k, 0, nk - 1)]
        mx = vals_k[jnp.clip(hi_k - 1, 0, nk - 1)]
        gmin = mn if gmin is None else jnp.maximum(gmin, mn)
        gmax = mx if gmax is None else jnp.minimum(gmax, mx)
        bounds.append((vals_k, lo_k, hi_k))
        cur_ks.append(cur_k)

    # ---- bitset sideways pass: a dense-cohort probe atom's candidate
    # set is exactly the union of its POPULATED bitset blocks, so the
    # envelope can only contain matches inside blocks the directory
    # lists.  Search the row's block-id segment for the envelope's
    # block range: rows with no populated block in range die here
    # (their expansion would fail that probe for every candidate), and
    # the envelope snaps inward to the first/last populated block.
    # Rows routed to the sparse cohort (slot_of < 0) pass through
    # untouched — pure narrowing, results unchanged.
    for sw, bbits, cur_k in zip(sideways, sideways_bits, cur_ks):
        if sw is None or cur_k is None:
            continue
        l0v, slot_of, boffs, bids = sw
        nl0 = l0v.shape[0]
        nid = slot_of.shape[0]
        ns = boffs.shape[0] - 1
        nb = bids.shape[0]
        ids = l0v[jnp.clip(cur_k, 0, max(nl0 - 1, 0))]
        slot = slot_of[jnp.clip(ids, 0, max(nid - 1, 0))]
        in_bs = alive & (ids >= 0) & (ids < nid) & (slot >= 0)
        s = jnp.clip(slot, 0, max(ns - 1, 0)).astype(_IDX)
        blo = jnp.where(in_bs, boffs[s], 0)
        bhi = jnp.where(in_bs, boffs[s + 1], 0)
        qlo = (gmin // bbits).astype(bids.dtype)
        qhi = (gmax // bbits).astype(bids.dtype)
        p_lo, _ = I.segment_searchsorted(bids, blo, bhi, qlo)
        p_hi, f_hi = I.segment_searchsorted(bids, blo, bhi, qhi)
        last = p_hi + f_hi - 1
        has = in_bs & (p_lo <= last)
        alive = alive & (~in_bs | has)
        fb = bids[jnp.clip(p_lo, 0, max(nb - 1, 0))]
        lb = bids[jnp.clip(last, 0, max(nb - 1, 0))]
        gmin = jnp.where(has, jnp.maximum(gmin, fb * bbits), gmin)
        gmax = jnp.where(has, jnp.minimum(gmax, (lb + 1) * bbits - 1),
                         gmax)

    if probes:
        p_lo, _ = I.segment_searchsorted(seed_values, lo0, hi0, gmin)
        p_hi, f_hi = I.segment_searchsorted(seed_values, lo0, hi0, gmax)
        lo0 = p_lo.astype(_IDX)
        hi0 = (p_hi + f_hi).astype(_IDX)

    # ---- counting pass + exclusive scan
    cnt = jnp.where(alive, jnp.maximum(hi0 - lo0, 0), 0).astype(_IDX)
    offs = jnp.cumsum(cnt) - cnt
    total = offs[-1] + cnt[-1]
    overflow = overflow | (total > cap_out)
    total_c = jnp.minimum(total, cap_out)

    # ---- fill: morsel-chunked expand-and-probe into static buffers
    nchunks = cap_out // morsel
    bufs = (jnp.zeros(cap_out, jnp.int32),              # values
            jnp.zeros(cap_out, _IDX),                   # source row
            jnp.zeros(cap_out, _IDX),                   # seed positions
            tuple(jnp.zeros(cap_out, _IDX) for _ in probes),
            jnp.zeros(cap_out, jnp.bool_))              # keep mask

    def cond(st):
        c = st[0]
        return (c < nchunks) & (c * morsel < total_c)

    def body(st):
        c, vals_b, row_b, p0_b, pos_bs, keep_b = st
        if fill_mode == "pallas":
            vals, row, p0, keep, poss = ff_ops.fill_chunk(
                c, total_c, offs, lo0, seed_values, tuple(bounds),
                morsel=morsel, interpret=fill_interpret)
        else:
            vals, row, p0, keep, poss = ff_ref.fill_chunk_ref(
                c, total_c, offs, lo0, seed_values, tuple(bounds),
                morsel=morsel)
        row = row.astype(_IDX)
        p0 = p0.astype(_IDX)
        poss = tuple(p.astype(_IDX) for p in poss)
        at = (c * morsel,)
        vals_b = lax.dynamic_update_slice(vals_b, vals, at)
        row_b = lax.dynamic_update_slice(row_b, row, at)
        p0_b = lax.dynamic_update_slice(p0_b, p0, at)
        pos_bs = tuple(lax.dynamic_update_slice(b, p, at)
                       for b, p in zip(pos_bs, poss))
        keep_b = lax.dynamic_update_slice(keep_b, keep, at)
        return (c + 1, vals_b, row_b, p0_b, pos_bs, keep_b)

    st = lax.while_loop(cond, body, (jnp.asarray(0, _IDX),) + bufs)
    chunks, vals_b, row_b, p0_b, pos_bs, keep = st

    # ---- compaction: dense prefix of surviving rows (order-preserving)
    widx = jnp.cumsum(keep.astype(_IDX)) - 1
    new_count = (widx[-1] + 1).astype(_IDX)
    scat = jnp.where(keep, widx, cap_out)

    def compact(x):
        return jnp.zeros((cap_out,), x.dtype).at[scat].set(x, mode="drop")

    vals_c = compact(vals_b)
    row_c = compact(row_b)
    p0_c = compact(p0_b)
    pos_c = tuple(compact(p) for p in pos_bs)
    rowg = jnp.clip(row_c, 0, cap_in - 1)
    carry_c = tuple(g[rowg] for g in carry)
    # ``total`` is the UNCAPPED counting-pass truth: landed with the
    # closing sync so an overflow retry can size this buffer exactly
    return new_count, overflow, chunks, total, vals_c, p0_c, pos_c, carry_c


@partial(jax.jit, static_argnames=("cap_in", "morsel", "seed_d0",
                                   "probe_d0", "sr"))
def _pipeline_fold(count, seed, probes, ann, leaf_anns, carry, *,
                   cap_in: int, morsel: int, seed_d0: bool,
                   probe_d0: Tuple[bool, ...], sr: Semiring):
    """Per-step (unfused) jitted wrapper around ``_fold_body`` — see
    ``_pipeline_step``."""
    return _fold_body(count, seed, probes, ann, leaf_anns, carry,
                      cap_in=cap_in, morsel=morsel, sr=sr)


def _fold_body(count, seed, probes, ann, leaf_anns, carry, *,
               cap_in: int, morsel: int, sr: Semiring):
    """Terminal-fold companion of ``_extend_body``: identical counting
    pass and morsel-chunked expand-and-probe, but each surviving
    candidate's semiring contribution is segment-reduced straight onto
    its source row — nothing is materialized, so no output capacity and
    no overflow.  Returns the support-compacted frontier (rows with an
    empty candidate intersection are NOT derived — same rule as the host
    fold, which Table 7's SSSP catches when violated)."""
    seed_values, seed_offsets, seed_cursor = seed
    n0 = seed_values.shape[0]
    valid = jnp.arange(cap_in, dtype=_IDX) < count
    lo0, hi0 = _bounds(seed_values, seed_offsets, seed_cursor, cap_in,
                       valid)

    bounds = []
    alive = valid
    gmin = gmax = None
    for vals_k, offs_k, cur_k in probes:
        nk = vals_k.shape[0]
        lo_k, hi_k = _bounds(vals_k, offs_k, cur_k, cap_in, valid)
        alive = alive & (lo_k < hi_k)
        mn = vals_k[jnp.clip(lo_k, 0, nk - 1)]
        mx = vals_k[jnp.clip(hi_k - 1, 0, nk - 1)]
        gmin = mn if gmin is None else jnp.maximum(gmin, mn)
        gmax = mx if gmax is None else jnp.minimum(gmax, mx)
        bounds.append((vals_k, lo_k, hi_k))
    if probes:
        p_lo, _ = I.segment_searchsorted(seed_values, lo0, hi0, gmin)
        p_hi, f_hi = I.segment_searchsorted(seed_values, lo0, hi0, gmax)
        lo0 = p_lo.astype(_IDX)
        hi0 = (p_hi + f_hi).astype(_IDX)

    cnt = jnp.where(alive, jnp.maximum(hi0 - lo0, 0), 0).astype(_IDX)
    offs = jnp.cumsum(cnt) - cnt
    total = offs[-1] + cnt[-1]

    plain = not probes and all(la is None for la in leaf_anns)
    if plain and sr.name == "count":
        # counting a bare segment needs no expansion at all: the
        # counting pass IS the fold (e.g. lollipop's pendant edge)
        folded = cnt.astype(sr.dtype)
        supp = cnt
        chunks = jnp.asarray(0, _IDX)
    else:
        zero = jnp.asarray(sr.zero, dtype=sr.dtype)

        def cond(st):
            c = st[0]
            return c * morsel < total

        def body(st):
            c, folded_b, supp_b = st
            j = c * morsel + jnp.arange(morsel, dtype=_IDX)
            row = jnp.clip(jnp.searchsorted(offs, j, side="right") - 1,
                           0, cap_in - 1).astype(_IDX)
            p0 = lo0[row] + (j - offs[row])
            live = j < total
            vals = seed_values[jnp.clip(p0, 0, max(n0 - 1, 0))]
            keep = live
            poss = [p0]
            for vals_k, lo_k, hi_k in bounds:
                pk, fk = I.segment_searchsorted(vals_k, lo_k[row],
                                                hi_k[row], vals)
                poss.append(pk.astype(_IDX))
                keep = keep & fk
            contrib = sr.lift(morsel)
            for la, pos in zip(leaf_anns, poss):
                if la is None:
                    continue
                nl = la.shape[0]
                at = la[jnp.clip(pos, 0, max(nl - 1, 0))]
                contrib = sr.mul(contrib, at.astype(sr.dtype))
            contrib = jnp.where(keep, contrib, zero)
            seg = row.astype(jnp.int32)
            folded_b = sr.add(folded_b,
                              sr.segment_reduce(contrib, seg, cap_in))
            supp_b = supp_b + jax.ops.segment_sum(
                keep.astype(_IDX), seg, num_segments=cap_in)
            return (c + 1, folded_b, supp_b)

        st = lax.while_loop(
            cond, body,
            (jnp.asarray(0, _IDX),
             jnp.full((cap_in,), sr.zero, dtype=sr.dtype),
             jnp.zeros(cap_in, _IDX)))
        chunks, folded, supp = st

    ann_new = sr.mul(ann, folded.astype(ann.dtype))
    support = supp > 0

    # ---- support compaction (order-preserving dense prefix)
    widx = jnp.cumsum(support.astype(_IDX)) - 1
    new_count = jnp.where(support.any(), widx[-1] + 1, 0).astype(_IDX)
    scat = jnp.where(support, widx, cap_in)

    def compact(x):
        return jnp.zeros((cap_in,), x.dtype).at[scat].set(x, mode="drop")

    ann_c = compact(ann_new)
    carry_c = tuple(compact(g) for g in carry)
    return new_count, chunks, ann_c, carry_c


@partial(jax.jit, static_argnames=("prog", "fill_mode",
                                   "fill_interpret"))
def _bag_program(arrays, cursors0, ann, *, prog: Tuple,
                 fill_mode: str, fill_interpret: bool):
    """ONE bag's whole extension chain as a single traced program.

    ``prog`` is the pure hashable lowering built by ``run_bag``: per
    step the constraining atoms reference operands by index into the
    flat deduplicated ``arrays`` tuple and cursors by canonical ordinal,
    so the trace key is exactly the bag SHAPE (chain of capacities,
    morsels, atom structure, sideways directories, semirings) — two
    executions of the same query shape hit the cache regardless of
    which relation instances flow through.  The Python loop below runs
    at trace time; at run time the whole chain is one XLA module, one
    launch, zero transfers.
    """
    count = jnp.asarray(1, _IDX)
    overflow = jnp.asarray(False)
    morsels = jnp.asarray(0, _IDX)
    cap = 1
    cols: Dict[str, jnp.ndarray] = {}
    cursors = dict(cursors0)
    lcounts = []
    needs = []
    for step in prog:
        kind = step[0]
        if kind == "extend":
            _, var, cap_out, morsel, cons = step

            def trip(c):
                key, vi, oi = c[0], c[1], c[2]
                if oi < 0:
                    return (arrays[vi], None, None)
                return (arrays[vi], arrays[oi], cursors[key])

            seed = trip(cons[0])
            probes = tuple(trip(c) for c in cons[1:])
            sideways = tuple(
                None if c[3] is None else
                (arrays[c[3][0]], arrays[c[3][1]], arrays[c[3][2]],
                 arrays[c[3][3]])
                for c in cons[1:])
            # c[3][4] is already a Python int (run_bag lowered it), so
            # no coercion happens inside this traced program
            sideways_bits = tuple(
                None if c[3] is None else c[3][4]
                for c in cons[1:])
            cons_keys = {c[0] for c in cons}
            col_keys = list(cols)
            cur_keys = [k for k in cursors if k not in cons_keys]
            carry = (tuple(cols[k] for k in col_keys)
                     + tuple(cursors[k] for k in cur_keys)
                     + ((ann,) if ann is not None else ()))
            (count, overflow, chunks, total, vals_c, p0_c, pos_c,
             carry_c) = _extend_body(
                count, overflow, seed, probes, sideways, carry,
                cap_in=cap, cap_out=cap_out, morsel=morsel,
                sideways_bits=sideways_bits, fill_mode=fill_mode,
                fill_interpret=fill_interpret)
            it = iter(carry_c)
            cols = {k: next(it) for k in col_keys}
            cursors = {k: next(it) for k in cur_keys}
            if ann is not None:
                ann = next(it)
            cols[var] = vals_c
            cursors[cons[0][0]] = p0_c
            for c, p in zip(cons[1:], pos_c):
                cursors[c[0]] = p
            cap = cap_out
            morsels = morsels + chunks
            lcounts.append(count)
            needs.append(total)
        elif kind == "fold":
            _, var, morsel, sr, cons = step

            def tripf(c):
                key, vi, oi = c[0], c[1], c[2]
                if oi < 0:
                    return (arrays[vi], None, None)
                return (arrays[vi], arrays[oi], cursors[key])

            seed = tripf(cons[0])
            probes = tuple(tripf(c) for c in cons[1:])
            leaf_anns = tuple(None if c[3] < 0 else arrays[c[3]]
                              for c in cons)
            col_keys = list(cols)
            cur_keys = list(cursors)
            carry = (tuple(cols[k] for k in col_keys)
                     + tuple(cursors[k] for k in cur_keys))
            count, chunks, ann, carry_c = _fold_body(
                count, seed, probes, ann, leaf_anns, carry,
                cap_in=cap, morsel=morsel, sr=sr)
            it = iter(carry_c)
            cols = {k: next(it) for k in col_keys}
            cursors = {k: next(it) for k in cur_keys}
            morsels = morsels + chunks
            lcounts.append(count)
        else:  # annmul
            _, key, ai, sr = step
            la = arrays[ai]
            n = la.shape[0]
            leaf = la[jnp.clip(cursors[key], 0, max(n - 1, 0))]
            ann = sr.mul(ann, leaf.astype(ann.dtype))
    return (count, overflow, morsels, tuple(lcounts), tuple(needs),
            cols, cursors, ann)


@jax.jit
def _fused_probe(values_t, lo_t, hi_t, queries):
    """Probe ``queries`` into every atom's candidate segment in one jitted
    program. Each atom's search is independent of the others' outcomes
    (positions don't depend on which rows survive), so computing all
    searches then AND-ing the membership masks is equivalent to the
    sequential filter — but costs one device round-trip instead of one
    per atom."""
    poss = []
    found_all = None
    for values, lo, hi in zip(values_t, lo_t, hi_t):
        pos, found = I.segment_searchsorted(values, lo, hi, queries)
        poss.append(pos)
        found_all = found if found_all is None else (found_all & found)
    return tuple(poss), found_all


# -------------------------------------------------------------- selection
_BY_NAME = {"numpy": NumpyBackend, "host": NumpyBackend,
            "device": DeviceBackend}
_DEFAULT: Optional[ExecBackend] = None


def make_backend(spec=None) -> ExecBackend:
    """Resolve ``spec`` (instance | name | None) to a fresh backend.
    ``None`` defers to ``REPRO_ENGINE_BACKEND`` (default "numpy")."""
    if isinstance(spec, ExecBackend):
        return spec
    if spec is None:
        spec = os.environ.get("REPRO_ENGINE_BACKEND", "numpy")
    spec = str(spec).lower()
    if spec not in _BY_NAME:
        raise ValueError(f"unknown backend {spec!r}; "
                         f"expected one of {sorted(_BY_NAME)}")
    return _BY_NAME[spec]()


def default_backend() -> ExecBackend:
    """Process-wide backend for GenericJoin instances constructed without
    an explicit backend (honours REPRO_ENGINE_BACKEND at first use)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make_backend(None)
    return _DEFAULT
