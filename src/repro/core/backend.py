"""Execution backends: where sets live and who intersects them.

EmptyHeaded's algorithm layer (Generic-Join over GHD bags, ``core.gj``)
is decoupled from the data-placement/intersection layer following the
GraphIt algorithm/backend split (Zhang et al. 2018):

  * :class:`NumpyBackend` — the seed behaviour: trie levels stay host
    numpy, each probe atom's lockstep binary search is a separate jitted
    call with its own host round-trip. Kept as the differential-testing
    oracle.
  * :class:`DeviceBackend` — trie levels are uploaded to device once
    (cached on the :class:`~repro.core.trie.TrieLevel`, so multi-rule and
    seminaive programs reuse the upload across iterations), every
    attribute extension runs all probe atoms in ONE fused jitted call
    with at most one host sync, and terminal-fold intersections are
    partitioned into bitset/uint cohorts via the Algorithm-3
    :class:`~repro.core.layouts.LayoutDecision` and dispatched to the
    Pallas kernels (uint×uint membership test, bitset×bitset
    AND+popcount, uint×bitset probe).

Backend selection: ``Engine(backend=...)`` accepts a backend instance or
the names ``"numpy"`` / ``"device"``; when unset, the
``REPRO_ENGINE_BACKEND`` environment variable decides (default numpy).

Every backend carries ``stats``, a flat counter recording which kernel
handled each intersection (``intersect.*`` keys count pairs) and the
host-sync discipline of the extension loop (``extend.calls`` vs
``extend.host_syncs``).  The static verification layer
(:mod:`repro.analysis`) rides the same counter: ``analysis.plans_verified``
/ ``analysis.candidates_verified`` count validator runs and
``analysis.sanitize_checks`` counts passed ``REPRO_SANITIZE`` dispatch
assertions, so the benchmark artifact's dispatch gate also proves
verification stayed on.
"""
from __future__ import annotations

import collections
import os
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intersect as I
from repro.core.layouts import engine_store_for
from repro.core.semiring import Semiring
from repro.kernels.bitset_intersect.ops import as_word_kernel
from repro.kernels.materialize.ops import as_materialize_kernel
from repro.kernels.uint_intersect.ops import intersect_count_csr_batched

# Pairs whose larger set exceeds this stay on the lockstep binary search
# (the SIMDGalloping analogue); shorter pairs take the membership-test
# kernel (the SIMDShuffling analogue) — Algorithm 2's regime split.
UINT_KERNEL_MAX_LEN = 256


class ExecBackend:
    """Protocol for the Generic-Join execution backend.

    ``extend(infos, F)`` receives the per-atom candidate descriptors of
    one attribute extension — ``infos`` is a list of
    ``(atom, values, lo, hi, mass)`` tuples sorted by total candidate
    mass (the min-property seed first) — and returns
    ``(row_id, vals, pos)`` exactly like the seed-expand-probe loop:
    ``pos`` maps ``id(atom)`` to absolute positions into that atom's
    current trie level.

    ``pair_count(trie, u, v)`` is the binary terminal-fold fast path:
    layout-routed ``|N(u_i) ∩ N(v_i)|`` counts, or ``None`` when the
    store is bypassed (layout mode "off"). ``has_pair_store(trie)`` lets
    the caller skip the frontier gathers entirely in the bypassed case.
    """

    name = "abstract"

    def __init__(self):
        self.stats: collections.Counter = collections.Counter()
        self._dtype_cache: Dict[str, np.dtype] = {}

    # jnp is resolved once at module import (not per GJ call); the per-
    # semiring canonical numpy dtype is cached per backend instance.
    def dtype_of(self, sr: Semiring) -> np.dtype:
        dt = self._dtype_cache.get(sr.name)
        if dt is None:
            dt = np.dtype(jnp.zeros((), sr.dtype).dtype)
            self._dtype_cache[sr.name] = dt
        return dt

    def extend(self, infos: Sequence[Tuple], F: int):
        raise NotImplementedError

    @staticmethod
    def _expand_seed(lo0: np.ndarray, hi0: np.ndarray, F: int):
        """Min-property seed expansion shared by both backends: flatten
        every frontier row's seed segment, returning (row_id, p0) with
        ``p0`` absolute positions into the seed level's values."""
        cnt = (hi0 - lo0).astype(np.int64)
        row_id = np.repeat(np.arange(F, dtype=np.int64), cnt)
        seg_start = np.repeat(np.concatenate([[0], np.cumsum(cnt)])[:-1], cnt)
        flat = np.arange(len(row_id), dtype=np.int64)
        p0 = np.repeat(lo0, cnt) + (flat - seg_start)
        return row_id, p0

    def _pair_store(self, trie, threshold: Optional[float] = None):
        raise NotImplementedError

    def has_pair_store(self, trie,
                       threshold: Optional[float] = None) -> bool:
        return self._pair_store(trie, threshold) is not None

    def pair_count(self, trie, u: np.ndarray, v: np.ndarray,
                   threshold: Optional[float] = None):
        """Binary terminal-fold fast path. ``threshold`` is the plan IR's
        statistics-driven Algorithm-3 density threshold (None lets the
        layout store profile the trie itself)."""
        store = self._pair_store(trie, threshold)
        if store is None:
            return None
        self.stats["fold.pair_count_calls"] += 1
        return store.intersect_count(u, v)

    def pair_materialize(self, trie, u: np.ndarray, v: np.ndarray,
                         threshold: Optional[float] = None):
        """Binary MATERIALIZING extension fast path (the plan IR's
        ``Extend.routing == "pair_store"``): cohort-routed
        ``N(u_i) ∩ N(v_i)`` with positions for trie descent, or ``None``
        when the layout store is bypassed."""
        store = self._pair_store(trie, threshold)
        if store is None:
            return None
        self.stats["extend.pair_materialize_calls"] += 1
        return store.intersect_materialize(u, v)

    def dispatch_summary(self) -> Dict[str, int]:
        return dict(self.stats)


class NumpyBackend(ExecBackend):
    """Seed behaviour: host-side expansion, one search (and one host
    round-trip) per probe atom, layout store only on the binary terminal
    fold with the plain-jnp word kernel."""

    name = "numpy"

    def extend(self, infos, F: int):
        a0, v0, lo0, hi0, _ = infos[0]
        row_id, p0 = self._expand_seed(lo0, hi0, F)
        vals = v0[p0]
        pos = {id(a0): p0}
        self.stats["extend.calls"] += 1
        for a, values, lo, hi, _m in infos[1:]:
            p, found = I.segment_searchsorted(values, lo[row_id], hi[row_id],
                                              vals)
            p = np.asarray(p); found = np.asarray(found)
            self.stats["extend.host_syncs"] += 1
            keep = found
            row_id = row_id[keep]
            vals = vals[keep]
            for k in pos:
                pos[k] = pos[k][keep]
            pos[id(a)] = p[keep]
        return row_id, vals, pos

    def _pair_store(self, trie, threshold=None):
        return engine_store_for(trie, counter=self.stats, cache_tag="host",
                                threshold=threshold)


class DeviceBackend(ExecBackend):
    """Device-resident set store: upload trie levels once, fuse every
    extension's probes into one jitted call (one host sync per attribute
    extension), and route terminal-fold intersections to the
    layout-cohort Pallas kernels."""

    name = "device"

    def __init__(self, interpret: Optional[bool] = None,
                 uint_max_len: int = UINT_KERNEL_MAX_LEN):
        super().__init__()
        self._interpret = interpret
        self._word_kernel = as_word_kernel(interpret=interpret)
        self._materialize_kernel = as_materialize_kernel(interpret=interpret)
        self._uint_max_len = uint_max_len

        def uint_kernel(offsets, neighbors, u, v):
            return intersect_count_csr_batched(
                offsets, neighbors, u, v, interpret=interpret,
                max_len=uint_max_len)

        self._uint_kernel = uint_kernel

    # ------------------------------------------------------------- uploads
    def _dev_values(self, atom) -> jnp.ndarray:
        lv = atom.trie.levels[atom.depth]
        return lv.device_values(jnp.asarray, on_upload=self._count_upload)

    def _count_upload(self):
        self.stats["upload.levels"] += 1

    # ------------------------------------------------------------- extend
    def extend(self, infos, F: int):
        self.stats["extend.calls"] += 1
        a0, v0, lo0, hi0, _ = infos[0]
        row_id, p0 = self._expand_seed(lo0, hi0, F)
        if len(row_id) == 0:
            z = np.zeros(0, np.int64)
            return z, np.zeros(0, np.int32), {id(a): z for a, *_ in infos}
        if len(infos) == 1:
            # unary extension: no probes, so the host copy already has the
            # answer — zero device traffic
            return row_id, v0[p0], {id(a0): p0}
        vals_dev = self._dev_values(a0)[p0]

        values_t = tuple(self._dev_values(a) for a, *_ in infos[1:])
        lo_t = tuple(info[2][row_id] for info in infos[1:])
        hi_t = tuple(info[3][row_id] for info in infos[1:])
        pos_t, found = _fused_probe(values_t, lo_t, hi_t, vals_dev)
        # the ONLY host round-trip of this extension: every probe atom's
        # positions + the combined membership mask come back together.
        pos_h, found_h, vals_h = jax.device_get((pos_t, found, vals_dev))
        self.stats["extend.host_syncs"] += 1
        keep = np.asarray(found_h)
        out_row = row_id[keep]
        out_vals = np.asarray(vals_h)[keep]
        pos = {id(a0): p0[keep]}
        for (a, *_), p in zip(infos[1:], pos_h):
            pos[id(a)] = np.asarray(p)[keep]
        return out_row, out_vals, pos

    # ------------------------------------------------------ terminal folds
    def _pair_store(self, trie, threshold=None):
        return engine_store_for(trie, word_kernel=self._word_kernel,
                                 uint_kernel=self._uint_kernel,
                                 materialize_kernel=self._materialize_kernel,
                                 uint_max_len=self._uint_max_len,
                                 counter=self.stats, cache_tag="device",
                                 threshold=threshold)


@jax.jit
def _fused_probe(values_t, lo_t, hi_t, queries):
    """Probe ``queries`` into every atom's candidate segment in one jitted
    program. Each atom's search is independent of the others' outcomes
    (positions don't depend on which rows survive), so computing all
    searches then AND-ing the membership masks is equivalent to the
    sequential filter — but costs one device round-trip instead of one
    per atom."""
    poss = []
    found_all = None
    for values, lo, hi in zip(values_t, lo_t, hi_t):
        pos, found = I.segment_searchsorted(values, lo, hi, queries)
        poss.append(pos)
        found_all = found if found_all is None else (found_all & found)
    return tuple(poss), found_all


# -------------------------------------------------------------- selection
_BY_NAME = {"numpy": NumpyBackend, "host": NumpyBackend,
            "device": DeviceBackend}
_DEFAULT: Optional[ExecBackend] = None


def make_backend(spec=None) -> ExecBackend:
    """Resolve ``spec`` (instance | name | None) to a fresh backend.
    ``None`` defers to ``REPRO_ENGINE_BACKEND`` (default "numpy")."""
    if isinstance(spec, ExecBackend):
        return spec
    if spec is None:
        spec = os.environ.get("REPRO_ENGINE_BACKEND", "numpy")
    spec = str(spec).lower()
    if spec not in _BY_NAME:
        raise ValueError(f"unknown backend {spec!r}; "
                         f"expected one of {sorted(_BY_NAME)}")
    return _BY_NAME[spec]()


def default_backend() -> ExecBackend:
    """Process-wide backend for GenericJoin instances constructed without
    an explicit backend (honours REPRO_ENGINE_BACKEND at first use)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make_backend(None)
    return _DEFAULT
