"""Semiring annotations (Green et al., provenance semirings).

EmptyHeaded annotates trie values with elements of a commutative semiring
``(K, add, mul, zero, one)`` and folds annotations during projection — this is
what makes early aggregation (Section 3.2 of the paper) a *logical* plan
property rather than an executor special case.

The same structures double as the message-passing aggregators of the GNN
substrate (a GCN layer is a (+,*) join-aggregate; SSSP is (min,+)): the
paper's thesis that "graph processing is relational algebra" is realized by
sharing this module between ``repro.core`` and ``repro.models.gnn``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A commutative semiring with a vectorized segment reduction.

    Attributes:
      name: human-readable id.
      dtype: canonical dtype for annotation arrays.
      zero: additive identity (scalar).
      one: multiplicative identity (scalar).
      add: elementwise ``a (+) b``.
      mul: elementwise ``a (*) b``.
      segment_reduce: ``(data, segment_ids, num_segments) -> reduced`` — the
        vectorized fold of ``add`` by key; maps onto jax.ops.segment_*.
    """

    name: str
    dtype: Any
    zero: Any
    one: Any
    add: Callable[[Array, Array], Array]
    mul: Callable[[Array, Array], Array]
    segment_reduce: Callable[[Array, Array, int], Array]

    def lift(self, n: int, value: Any = None) -> Array:
        """An annotation vector of length ``n`` filled with ``one`` (or value)."""
        fill = self.one if value is None else value
        return jnp.full((n,), fill, dtype=self.dtype)

    def total(self, data: Array) -> Array:
        """Fold a whole annotation vector with ``add``."""
        zeros = jnp.zeros((data.shape[0],), dtype=jnp.int32)
        return self.segment_reduce(data, zeros, 1)[0]


def _seg_sum(data, seg, n):
    return jax.ops.segment_sum(data, seg, num_segments=n)


def _seg_min(data, seg, n):
    return jax.ops.segment_min(data, seg, num_segments=n)


def _seg_max(data, seg, n):
    return jax.ops.segment_max(data, seg, num_segments=n)


def _seg_or(data, seg, n):
    return jax.ops.segment_max(data.astype(jnp.int32), seg, num_segments=n).astype(jnp.bool_)


COUNT = Semiring(
    name="count",
    dtype=jnp.int64 if jax.config.jax_enable_x64 else jnp.int32,
    zero=0,
    one=1,
    add=lambda a, b: a + b,
    mul=lambda a, b: a * b,
    segment_reduce=_seg_sum,
)

SUM_F32 = Semiring(
    name="sum_f32",
    dtype=jnp.float32,
    zero=0.0,
    one=1.0,
    add=lambda a, b: a + b,
    mul=lambda a, b: a * b,
    segment_reduce=_seg_sum,
)

SUM_F64 = Semiring(
    name="sum_f64",
    dtype=jnp.float64,
    zero=0.0,
    one=1.0,
    add=lambda a, b: a + b,
    mul=lambda a, b: a * b,
    segment_reduce=_seg_sum,
)

# Tropical / shortest-path semiring: add = min, mul = +.
MIN_PLUS = Semiring(
    name="min_plus",
    dtype=jnp.float32,
    zero=np.float32(np.inf),
    one=0.0,
    add=jnp.minimum,
    mul=lambda a, b: a + b,
    segment_reduce=_seg_min,
)

# Bottleneck semiring: add = max, mul = min.
MAX_MIN = Semiring(
    name="max_min",
    dtype=jnp.float32,
    zero=np.float32(-np.inf),
    one=np.float32(np.inf),
    add=jnp.maximum,
    mul=jnp.minimum,
    segment_reduce=_seg_max,
)

BOOLEAN = Semiring(
    name="boolean",
    dtype=jnp.bool_,
    zero=False,
    one=True,
    add=jnp.logical_or,
    mul=jnp.logical_and,
    segment_reduce=_seg_or,
)

BY_NAME = {s.name: s for s in (COUNT, SUM_F32, SUM_F64, MIN_PLUS, MAX_MIN, BOOLEAN)}

# Aggregation-syntax name (<<SUM(x)>> etc.) -> semiring used to fold it.
AGG_TO_SEMIRING = {
    "count": COUNT,
    "sum": SUM_F32,
    "min": MIN_PLUS,
    "max": MAX_MIN,
    "or": BOOLEAN,
}
