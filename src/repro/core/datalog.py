"""Datalog-like query language (paper Section 3.1, Table 2).

Grammar (recursive descent; the paper's surface syntax):

    rule      := head star? ":-" body (";" aggdef)? "."
    head      := NAME "(" keyvars (";" annvar ":" type)? ")"
    star      := "*" ("[" ("i"|"c") "=" NUMBER "]")?
    body      := atom ("," atom)*
    atom      := NAME "(" term ("," term)* ")"
    term      := VAR | NUMBER | STRING
    aggdef    := VAR "=" expr          # expr may contain <<AGG(arg)>>
    expr      := arithmetic over numbers, scalar-relation names, and one
                 "<<OP(arg)>>" aggregation placeholder

Examples accepted verbatim from Table 2: Triangle, 4-Clique, Lollipop,
Barbell, CountTriangle, PageRank (3 rules), SSSP (2 rules).
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Tuple, Union


# --------------------------------------------------------------------- AST
@dataclasses.dataclass(frozen=True)
class Var:
    name: str

    def __repr__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class Const:
    value: Union[int, str, float, "Param"]

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True)
class Param:
    """A bind-parameter slot standing in for a selection constant.

    ``Engine.prepare`` rewrites every body ``Const(v)`` to
    ``Const(Param(slot))`` (one slot per distinct constant value, in
    first-appearance order), so the rule's ``repr`` — and with it every
    compile/plan/trace cache key — is stable across bindings. The actual
    value is supplied at run time through the binding-aware ``encode``
    closure; it never reaches a compile key.

    ``repr`` is eval-able on purpose: parameterized selections survive
    the codegen round-trip (`emit_source` embeds ``encode({v!r})``).
    """

    slot: int

    def __repr__(self):
        return f"Param({self.slot})"


@dataclasses.dataclass(frozen=True)
class Atom:
    rel: str
    terms: Tuple[Union[Var, Const], ...]

    @property
    def vars(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.terms if isinstance(t, Var))

    def __repr__(self):
        return f"{self.rel}({','.join(map(repr, self.terms))})"


# Expression nodes for the aggregation definition -------------------------
@dataclasses.dataclass(frozen=True)
class Num:
    value: float


@dataclasses.dataclass(frozen=True)
class ScalarRef:
    """Reference to a scalar (arity-0, annotated) relation, e.g. 1/N."""
    name: str


@dataclasses.dataclass(frozen=True)
class AggRef:
    """The <<OP(arg)>> placeholder."""
    op: str     # count|sum|min|max
    arg: str    # variable name or "*"


@dataclasses.dataclass(frozen=True)
class BinOp:
    op: str  # + - * /
    lhs: "Expr"
    rhs: "Expr"


Expr = Union[Num, ScalarRef, AggRef, BinOp]


def expr_agg(e: Optional[Expr]) -> Optional[AggRef]:
    """Find the (single) aggregation placeholder in an expression."""
    if e is None or isinstance(e, (Num, ScalarRef)):
        return None
    if isinstance(e, AggRef):
        return e
    l, r = expr_agg(e.lhs), expr_agg(e.rhs)
    assert not (l and r), "at most one aggregation per rule"
    return l or r


def eval_expr(e: Expr, agg_value, scalars: dict):
    """Evaluate with the aggregation placeholder bound to ``agg_value``
    (a scalar or vector); scalar relation names resolved via ``scalars``."""
    if isinstance(e, Num):
        return e.value
    if isinstance(e, ScalarRef):
        if e.name not in scalars:
            raise KeyError(f"scalar relation {e.name} not materialized")
        return scalars[e.name]
    if isinstance(e, AggRef):
        assert agg_value is not None, "aggregation placeholder with no value"
        return agg_value
    l = eval_expr(e.lhs, agg_value, scalars)
    r = eval_expr(e.rhs, agg_value, scalars)
    return {"+": lambda: l + r, "-": lambda: l - r,
            "*": lambda: l * r, "/": lambda: l / r}[e.op]()


@dataclasses.dataclass(frozen=True)
class Head:
    rel: str
    keyvars: Tuple[str, ...]
    ann_var: Optional[str] = None
    ann_type: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Recursion:
    kind: str                 # "iterations" | "tolerance" | "fixpoint"
    value: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Rule:
    head: Head
    body: Tuple[Atom, ...]
    agg_expr: Optional[Expr] = None
    recursion: Optional[Recursion] = None

    @property
    def agg(self) -> Optional[AggRef]:
        return expr_agg(self.agg_expr)

    @property
    def body_vars(self) -> Tuple[str, ...]:
        seen, out = set(), []
        for a in self.body:
            for v in a.vars:
                if v not in seen:
                    seen.add(v)
                    out.append(v)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class Program:
    rules: Tuple[Rule, ...]


# ---------------------------------------------------------------- tokenizer
_TOKEN_RE = re.compile(r"""
      (?P<WS>\s+)
    | (?P<LAGG><<)
    | (?P<RAGG>>>)
    | (?P<IMPL>:-)
    | (?P<NAME>\d*[A-Za-z_][A-Za-z0-9_']*)
    | (?P<NUM>\d+\.\d+|\.\d+|\d+)
    | (?P<STR>"[^"]*")
    | (?P<PUNCT>[(),;.*\[\]=+\-/:])
""", re.VERBOSE)


def tokenize(text: str) -> List[Tuple[str, str]]:
    toks, i = [], 0
    while i < len(text):
        m = _TOKEN_RE.match(text, i)
        if not m:
            raise SyntaxError(f"bad character at {text[i:i+20]!r}")
        i = m.end()
        kind = m.lastgroup
        if kind == "WS":
            continue
        toks.append((kind, m.group()))
    toks.append(("EOF", ""))
    return toks


class Parser:
    def __init__(self, text: str):
        self.toks = tokenize(text)
        self.i = 0

    # -- primitives
    def peek(self, k=0):
        return self.toks[min(self.i + k, len(self.toks) - 1)]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, val=None, kind=None):
        k, v = self.next()
        if val is not None and v != val:
            raise SyntaxError(f"expected {val!r}, got {v!r}")
        if kind is not None and k != kind:
            raise SyntaxError(f"expected {kind}, got {k}:{v!r}")
        return v

    def accept(self, val) -> bool:
        if self.peek()[1] == val:
            self.i += 1
            return True
        return False

    # -- grammar
    def parse_program(self) -> Program:
        rules = []
        while self.peek()[0] != "EOF":
            rules.append(self.parse_rule())
        return Program(tuple(rules))

    def parse_rule(self) -> Rule:
        head = self.parse_head()
        recursion = None
        if self.accept("*"):
            if self.accept("["):
                key = self.expect(kind="NAME")
                self.expect("=")
                num = float(self.expect(kind="NUM"))
                self.expect("]")
                recursion = Recursion("iterations" if key == "i" else "tolerance",
                                      num)
            else:
                recursion = Recursion("fixpoint")
        self.expect(":-")
        body = [self.parse_atom()]
        while self.accept(","):
            body.append(self.parse_atom())
        agg_expr = None
        if self.accept(";"):
            # "y = expr"
            self.expect(kind="NAME")
            self.expect("=")
            agg_expr = self.parse_expr()
        self.expect(".")
        return Rule(head, tuple(body), agg_expr, recursion)

    def parse_head(self) -> Head:
        name = self.expect(kind="NAME")
        self.expect("(")
        keyvars: List[str] = []
        ann_var = ann_type = None
        if not self.accept(")"):
            # keyvars until ';' or ')'
            while self.peek()[1] not in (";", ")"):
                keyvars.append(self.expect(kind="NAME"))
                if not self.accept(","):
                    break
            if self.accept(";"):
                ann_var = self.expect(kind="NAME")
                self.expect(":")
                ann_type = self.expect(kind="NAME")
            self.expect(")")
        return Head(name, tuple(keyvars), ann_var, ann_type)

    def parse_atom(self) -> Atom:
        name = self.expect(kind="NAME")
        self.expect("(")
        terms: List[Union[Var, Const]] = []
        if not self.accept(")"):
            while True:
                k, v = self.next()
                if k == "NAME":
                    terms.append(Var(v))
                elif k == "NUM":
                    terms.append(Const(int(float(v)) if "." not in v else float(v)))
                elif k == "STR":
                    terms.append(Const(v.strip('"')))
                else:
                    raise SyntaxError(f"bad term {v!r}")
                if not self.accept(","):
                    break
            self.expect(")")
        return Atom(name, tuple(terms))

    # expression grammar: term (("+"|"-") term)*; term: factor (("*"|"/") factor)*
    def parse_expr(self) -> Expr:
        e = self.parse_term()
        while self.peek()[1] in ("+", "-"):
            op = self.next()[1]
            e = BinOp(op, e, self.parse_term())
        return e

    def parse_term(self) -> Expr:
        e = self.parse_factor()
        while self.peek()[1] in ("*", "/"):
            op = self.next()[1]
            e = BinOp(op, e, self.parse_factor())
        return e

    def parse_factor(self) -> Expr:
        k, v = self.peek()
        if k == "NUM":
            self.next()
            return Num(float(v))
        if k == "LAGG":
            self.next()
            op = self.expect(kind="NAME").lower()
            self.expect("(")
            arg = self.next()[1]  # var name or '*'
            self.expect(")")
            self.expect(kind="RAGG")
            return AggRef(op, arg)
        if k == "NAME":
            self.next()
            return ScalarRef(v)
        if v == "(":
            self.next()
            e = self.parse_expr()
            self.expect(")")
            return e
        raise SyntaxError(f"bad expression factor {v!r}")


def parse(text: str) -> Program:
    return Parser(text).parse_program()


def parse_rule(text: str) -> Rule:
    prog = parse(text)
    assert len(prog.rules) == 1
    return prog.rules[0]
