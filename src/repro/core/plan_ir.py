"""Typed physical plan IR between ``compile.QueryPlan`` and execution.

The logical plan (rule -> hypergraph -> GHD, ``core.compile``) says *what*
to join; this module decides *how*, once, in one place.  A
:class:`PhysicalPlan` is an explicit operator DAG —

  * :class:`BagScan` — the physical access paths of one GHD bag: per-atom
    trie reorder permutation + leading equality selections, plus
    structural references to the child bags' materialized results,
  * :class:`Extend` — one Generic-Join attribute extension, annotated with
    the estimated fanout and cumulative cardinality,
  * :class:`TerminalFold` — the early-aggregation fold of the last
    non-retained attribute, annotated with the backend routing hint and
    the statistics-driven Algorithm-3 layout threshold,
  * :class:`MaterializeShared` — the bag's output projection passed up the
    GHD, carrying the engine-lifetime reuse key (Appendix A.1 dedup,
    generalized from per-query to cross-rule/cross-iteration),
  * :class:`TopDownJoin` — the final acyclic join of the reduced bag
    results for listing queries spanning bags, referencing its inputs
    *structurally* by operator id (this is what deleted the old
    ``codegen._bag_names`` source-text scraping).

Both lowerings — the interpreter (``core.executor``, the oracle) and the
code generator (``core.codegen``) — walk this DAG; neither re-derives a
physical decision.  ``GenericJoin`` and the backends consume the
annotations via :class:`BagHints`.  Estimated cardinalities come from the
:class:`~repro.core.statistics.StatisticsCatalog` under an independence
model capped by the bag's AGM bound (``core.agm`` with real relation
sizes), and are written next to the *actual* cardinalities into the
benchmark artifact so optimizer mispredictions are visible per run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core import agm
from repro.core.compile import BagPlan, PlanAtom, QueryPlan
from repro.core.statistics import StatisticsCatalog, TrieStats


# ------------------------------------------------------------ access paths
@dataclasses.dataclass(frozen=True)
class AtomAccess:
    """Physical access path for one atom: which trie index order to use
    (selected positions lead, live vars follow the bag attribute order)
    and the leading equality selections. This logic previously lived
    twice, in ``executor._atom_trie`` and inline in ``codegen``."""

    rel: str
    perm: Tuple[int, ...]                       # column permutation
    vars: Tuple[str, ...]                       # post-perm variable names
    selections: Tuple[Tuple[int, object], ...]  # (post-perm pos, raw const)

    @staticmethod
    def from_plan_atom(a: PlanAtom, var_order: Tuple[str, ...]) -> "AtomAccess":
        order_pos = {v: i for i, v in enumerate(var_order)}
        sel_positions = sorted(a.selections.keys())
        live_positions = [p for p in range(len(a.vars))
                          if p not in a.selections]
        live_positions.sort(key=lambda p: order_pos[a.vars[p]])
        perm = tuple(sel_positions + live_positions)
        vars_ = tuple(a.vars[p] for p in perm)
        sels = tuple((i, a.selections[p]) for i, p in enumerate(sel_positions))
        return AtomAccess(a.rel, perm, vars_, sels)

    @property
    def live_vars(self) -> Tuple[str, ...]:
        return self.vars[len(self.selections):]

    def selection_map(self, encode) -> Dict[int, int]:
        return {i: encode(v) for i, v in self.selections}


@dataclasses.dataclass(frozen=True)
class ChildInput:
    """Structural reference to a child bag's materialized result."""

    op_id: int                  # the child's MaterializeShared op id
    vars: Tuple[str, ...]       # shared attrs, ordered by the parent order


# ------------------------------------------------------------ operator DAG
@dataclasses.dataclass
class PlanOp:
    op_id: int
    est_rows: float             # estimated cardinality after this operator
    cost: float                 # modelled work of this operator (the
    #                             plan-search objective; statistics.py
    #                             cost-model weights, summed by plan_cost)


@dataclasses.dataclass
class BagScan(PlanOp):
    accesses: Tuple[AtomAccess, ...]
    child_inputs: Tuple[ChildInput, ...]
    var_order: Tuple[str, ...]


# Legal routing vocabularies — the cohort dispatch tables in
# ``core.layouts`` / ``core.gj`` only understand these values, and the
# plan validator (``repro.analysis.plan_verify``) rejects anything else.
EXTEND_ROUTINGS = frozenset({"search", "pair_store"})
FOLD_ROUTINGS = frozenset({"search", "pair_kernel"})


@dataclasses.dataclass
class Extend(PlanOp):
    var: str
    n_constraining: int
    est_fanout: float
    # "pair_store" when this materializing extension is a binary self-join
    # the HybridSetStore can serve cohort-routed (bitset extraction for
    # dense pairs); "search" keeps the generic expand-and-probe path.
    routing: str = "search"
    # Zero-sync pipeline annotations (core.backend.DeviceBackend): the
    # stats-informed frontier-buffer allocation target (AGM-capped
    # est_rows with statistics.CAP_HEADROOM slack — the runtime clamps it
    # further to the exact cross-product bound of the live tries) and the
    # stats-chosen morsel (fill-chunk) size.  None = statistics were
    # unavailable; the pipeline then refuses to size a buffer from it.
    frontier_cap: Optional[float] = None
    morsel: Optional[int] = None
    # "bitset" when the pipelined counting pass should also intersect a
    # probe atom's bitset BLOCK directory with the candidate envelope
    # (sideways filtering: prune before expansion, not just clip) —
    # annotated only where the statistics density gate expects the probe
    # level's Algorithm-3 dense cohort to dominate.  None = envelope
    # clipping only.
    sideways: Optional[str] = None


@dataclasses.dataclass
class TerminalFold(PlanOp):
    var: str
    semiring: str
    routing: str                        # "pair_kernel" | "search"
    layout_threshold: Optional[float]   # Algorithm-3 threshold (stats-driven)


@dataclasses.dataclass
class MaterializeShared(PlanOp):
    source: int                          # BagScan op id
    output_vars: Tuple[str, ...]
    keep_annotation: bool
    reuse_struct: Tuple                  # canonicalized structural key
    reuse_rels: Tuple[str, ...]          # relations whose versions gate reuse


@dataclasses.dataclass
class TopDownJoin(PlanOp):
    inputs: Tuple[int, ...]              # MaterializeShared op ids
    var_order: Tuple[str, ...]
    output_vars: Tuple[str, ...]


@dataclasses.dataclass
class BagHints:
    """The IR annotations GenericJoin / the backend consume at run time."""

    layout_threshold: Optional[float] = None
    terminal_routing: Optional[str] = None
    est_rows: Optional[float] = None
    # var -> "pair_store" for materializing extensions routed through the
    # layout store (None/missing var = generic search path)
    extend_routing: Optional[Dict[str, str]] = None
    # var -> stats-informed frontier-buffer allocation target for the
    # zero-sync extension pipeline (Extend.frontier_cap); missing var or
    # None hints disengage the pipeline for that step.
    extend_caps: Optional[Dict[str, float]] = None
    # stats-chosen morsel size for the pipelined fill loop
    # (REPRO_MORSEL_SIZE overrides at run time)
    morsel: Optional[int] = None
    # var -> "bitset" where the pipelined counting pass should apply
    # sideways bitset-block filtering (Extend.sideways)
    extend_sideways: Optional[Dict[str, str]] = None


@dataclasses.dataclass
class BagOps:
    """One GHD bag's operator pipeline."""

    logical: BagPlan
    scan: BagScan
    steps: Tuple[PlanOp, ...]            # Extend | TerminalFold per attr
    materialize: MaterializeShared

    def hints(self) -> BagHints:
        thr = None
        routing = None
        ext_routing = {}
        ext_caps = {}
        ext_sideways = {}
        morsel = None
        for s in self.steps:
            if isinstance(s, TerminalFold):
                thr = s.layout_threshold
                routing = s.routing
            elif isinstance(s, Extend):
                if s.routing != "search":
                    ext_routing[s.var] = s.routing
                if s.frontier_cap is not None:
                    ext_caps[s.var] = s.frontier_cap
                if s.sideways is not None:
                    ext_sideways[s.var] = s.sideways
                if s.morsel is not None:
                    morsel = s.morsel
        return BagHints(layout_threshold=thr, terminal_routing=routing,
                        est_rows=self.materialize.est_rows,
                        extend_routing=ext_routing or None,
                        extend_caps=ext_caps or None,
                        morsel=morsel,
                        extend_sideways=ext_sideways or None)


@dataclasses.dataclass
class PhysicalPlan:
    logical: QueryPlan
    bag_ops: List[BagOps]                # bottom-up (children first)
    final: Optional[TopDownJoin]         # listing queries spanning bags
    ops: Dict[int, PlanOp]

    @property
    def root(self) -> BagOps:
        return self.bag_ops[-1]

    def pretty(self) -> str:
        lines = [f"physical plan: order={self.logical.order} "
                 f"out={self.logical.output_vars} "
                 f"fhw={self.logical.ghd.width:.3g}"]
        for b in self.bag_ops:
            atoms = ", ".join(f"{a.rel}({','.join(a.vars)})"
                              for a in b.scan.accesses)
            lines.append(f"  bag#{b.scan.op_id} [{atoms}] "
                         f"est_rows={b.materialize.est_rows:.3g}")
            for s in b.steps:
                if isinstance(s, Extend):
                    lines.append(f"    extend {s.var} "
                                 f"fanout~{s.est_fanout:.3g} "
                                 f"rows~{s.est_rows:.3g}")
                else:
                    lines.append(f"    fold {s.var} [{s.semiring}] "
                                 f"route={s.routing} "
                                 f"thr={s.layout_threshold}")
        if self.final is not None:
            lines.append(f"  top-down join over bags "
                         f"{list(self.final.inputs)}")
        return "\n".join(lines)

    def metadata(self) -> dict:
        """JSON-serializable optimizer-choice record (benchmark artifact)."""
        plan = self.logical
        bags = []
        for b in self.bag_ops:
            steps = []
            for s in b.steps:
                if isinstance(s, Extend):
                    steps.append({"op": "extend", "var": s.var,
                                  "est_fanout": float(s.est_fanout),
                                  "est_rows": float(s.est_rows),
                                  "routing": s.routing,
                                  "frontier_cap":
                                      float(s.frontier_cap)
                                      if s.frontier_cap is not None
                                      else None,
                                  "morsel": s.morsel,
                                  "sideways": s.sideways,
                                  "cost": float(s.cost)})
                else:
                    steps.append({"op": "terminal_fold", "var": s.var,
                                  "semiring": s.semiring,
                                  "routing": s.routing,
                                  "cost": float(s.cost),
                                  "layout_threshold":
                                      float(s.layout_threshold)
                                      if s.layout_threshold is not None
                                      else None})
            bags.append({
                "op_id": int(b.materialize.op_id),
                "atoms": [f"{a.rel}({','.join(a.vars)})"
                          for a in b.scan.accesses],
                "var_order": list(b.scan.var_order),
                "output_vars": list(b.materialize.output_vars),
                "est_rows": float(b.materialize.est_rows),
                "cost": float(b.scan.cost + sum(s.cost for s in b.steps)
                              + b.materialize.cost),
                "steps": steps,
            })
        return {
            "head": plan.rule.head.rel,
            "fhw": float(plan.ghd.width),
            "order": list(plan.order),
            "output_vars": list(plan.output_vars),
            "needs_top_down": bool(plan.needs_top_down),
            "search_exhausted": bool(getattr(plan.ghd, "search_exhausted",
                                             False)),
            "num_bags": len(self.bag_ops),
            "est_cost": float(plan_cost(self)),
            "top_down_inputs": (list(map(int, self.final.inputs))
                                if self.final is not None else []),
            "bags": bags,
        }


# ----------------------------------------------------------------- builder
def build_physical_plan(plan: QueryPlan, stats: StatisticsCatalog,
                        catalog, agm_memo: Optional[Dict] = None,
                        profile_tries: bool = True) -> PhysicalPlan:
    """Annotate the logical GHD plan into the physical operator DAG.

    ``catalog`` is the executor's relation catalog — the builder resolves
    each atom's reordered trie through it (the same identity-cached trie
    the lowering will run on) to profile real data.  ``agm_memo`` (an
    optional dict) memoizes the per-bag fractional-cover LPs across
    candidate lowerings of the SAME rule — the plan search lowers dozens
    of candidates whose bags repeat.

    ``profile_tries=False`` profiles each atom from its BASE trie instead
    of resolving ``catalog.reordered`` — candidate COSTING mode for the
    plan search, so discarded candidates never build reordered indexes
    in the engine-lifetime reorder cache (the base profile is the proxy
    for every index order; exact for symmetric relations, an
    approximation otherwise).  Routing hints are decided from
    ``(resolved relation, permutation)`` keys in both modes, which is
    exactly the reorder cache's identity.
    """
    from repro.core import statistics as S
    aggregate = plan.semiring is not None
    counter = [0]
    ops: Dict[int, PlanOp] = {}
    bag_ops: List[BagOps] = []

    def new_id() -> int:
        counter[0] += 1
        return counter[0]

    def reg(op: PlanOp) -> PlanOp:
        ops[op.op_id] = op
        return op

    def build_bag(bp: BagPlan) -> BagOps:
        children = [build_bag(c) for c in bp.children]
        accesses = tuple(AtomAccess.from_plan_atom(a, bp.var_order)
                         for a in bp.atoms)
        atom_keys: List[Optional[Tuple]] = []
        atom_arity: List[Optional[int]] = []
        atom_stats: List[Optional[TrieStats]] = []
        for acc in accesses:
            try:
                base = catalog.get(acc.rel)
            except KeyError:
                atom_keys.append(None)
                atom_arity.append(None)
                atom_stats.append(None)
                continue
            atom_keys.append((catalog.resolve(acc.rel), acc.perm))
            atom_arity.append(base.arity)
            profiled = (catalog.reordered(acc.rel, acc.perm)
                        if profile_tries else base)
            atom_stats.append(stats.stats_for(profiled))

        child_inputs = []
        for cb in children:
            shared = tuple(v for v in bp.var_order
                           if v in set(cb.logical.bag.shared_with_parent))
            child_inputs.append(ChildInput(cb.materialize.op_id, shared))
        child_inputs = tuple(child_inputs)

        scan = reg(BagScan(new_id(), 1.0, 0.0, accesses, child_inputs,
                           bp.var_order))

        agm_cap = _bag_agm_bound(plan, bp, catalog, agm_memo)
        steps: List[PlanOp] = []
        frontier = 1.0
        rows_into_last = 1.0      # frontier entering the final step
        out_domain = 1.0          # product of output-var value universes
        out_domain_known = True
        # live descent state mirrored from GenericJoin: per-input depth
        depth = {i: len(acc.selections) for i, acc in enumerate(accesses)}
        cdepth = {i: 0 for i in range(len(child_inputs))}
        out_set = set(bp.output_vars)
        for vi, v in enumerate(bp.var_order):
            cons: List[Tuple] = []
            advancing_atoms, advancing_children = [], []
            for i, acc in enumerate(accesses):
                live = acc.live_vars
                d = depth[i] - len(acc.selections)
                if d < len(live) and live[d] == v:
                    cons.append((atom_stats[i], depth[i], 0.0))
                    advancing_atoms.append(i)
            for i, ci in enumerate(child_inputs):
                if cdepth[i] < len(ci.vars) and ci.vars[cdepth[i]] == v:
                    child_est = ops[ci.op_id].est_rows
                    cons.append((None, cdepth[i], child_est, len(ci.vars)))
                    advancing_children.append(i)
            fanout, min_cand, max_cand, universe = \
                stats.extension_profile(cons)
            if v in out_set:
                if any(c[0] is not None for c in cons):
                    out_domain *= universe
                else:
                    out_domain_known = False
            rows_into_last = frontier
            frontier = max(frontier * fanout, 1e-9)
            if agm_cap is not None:
                frontier = min(frontier, agm_cap)
            last = vi == len(bp.var_order) - 1
            terminal = aggregate and v not in out_set and last
            if terminal:
                routing, thr = _terminal_routing(
                    accesses, advancing_atoms, advancing_children,
                    atom_keys, atom_arity, atom_stats, depth, stats)
                set_stats = None
                if advancing_atoms:
                    st = atom_stats[advancing_atoms[0]]
                    if st is not None and st.levels:
                        set_stats = st.levels[-1]
                cost = S.fold_cost(rows_into_last, min_cand, max_cand,
                                   len(cons), routing, set_stats, thr,
                                   stats.block_bits)
                steps.append(reg(TerminalFold(
                    new_id(), frontier, cost, v, plan.semiring.name,
                    routing, thr)))
            else:
                ext_routing = _extend_routing(
                    accesses, advancing_atoms, advancing_children,
                    atom_keys, atom_arity, depth)
                # stats-informed allocation target for the zero-sync
                # pipeline's static frontier buffer (AGM-capped estimate
                # with headroom; the runtime clamps to the exact
                # cross-product bound of the live tries).  The buffer is
                # zeroed/scattered whole, so its size is costed — the
                # plan search prefers orders with tighter intermediates.
                cap = min(frontier * S.CAP_HEADROOM,
                          float(S.PIPELINE_MAX_BUFFER))
                sideways = None
                if ext_routing == "search":
                    sideways = _extend_sideways(
                        accesses, advancing_atoms, atom_arity,
                        atom_stats, depth, stats, len(cons))
                cost = (S.extension_cost(rows_into_last, min_cand,
                                         max_cand, len(cons))
                        * (S.SIDEWAYS_COST_CREDIT if sideways else 1.0)
                        + S.buffer_cost(cap))
                steps.append(reg(Extend(new_id(), frontier, cost, v,
                                        len(cons), fanout, ext_routing,
                                        frontier_cap=cap,
                                        sideways=sideways)))
            for i in advancing_atoms:
                depth[i] += 1
            for i in advancing_children:
                cdepth[i] += 1
        # stats-chosen morsel: one bag-wide chunk size scaled to the peak
        # estimated frontier (all of a bag's extension buffers share it)
        ext_steps = [s for s in steps if isinstance(s, Extend)]
        if ext_steps:
            morsel = S.default_morsel(max(s.est_rows for s in ext_steps))
            for s in ext_steps:
                s.morsel = morsel

        # a terminal fold never expands the frontier (it folds the
        # expansion away; support can only shrink rows), so the bag's
        # output estimate is the frontier ENTERING the fold — using the
        # post-fanout value inflated est_rows by the folded attribute's
        # fanout, which the plan search would propagate into the parent
        # bag's candidate model
        est_out = (rows_into_last
                   if steps and isinstance(steps[-1], TerminalFold)
                   else frontier)
        if agm_cap is not None:
            est_out = min(est_out, agm_cap)
        # a bag's output cannot exceed the product of its retained
        # attributes' value universes (distinct-value cap — without it,
        # AGM-inflated intermediate estimates leak into the parent bag's
        # candidate model and distort the plan search)
        if bp.output_vars and out_domain_known:
            est_out = min(est_out, out_domain)
        # projection shape at the bag's end: the frontier holds every
        # extended (non-folded) attribute; extras force a sort-based
        # group-by, scalar aggregates a segment reduce.
        extended = [s.var for s in steps if isinstance(s, Extend)]
        proj_rows = (rows_into_last
                     if steps and isinstance(steps[-1], TerminalFold)
                     else frontier)
        has_extra = bool(bp.output_vars) and bool(
            set(extended) - set(bp.output_vars))
        scalar_out = aggregate and not bp.output_vars
        proj_cost = S.projection_cost(proj_rows, has_extra, scalar_out)
        mat = reg(MaterializeShared(
            new_id(), est_out, proj_cost, scan.op_id, bp.output_vars,
            keep_annotation=aggregate,
            reuse_struct=_resolved_struct(bp.dedup_key, catalog.resolve),
            reuse_rels=tuple(sorted({catalog.resolve(r)
                                     for r in bp.subtree_rels()}))))
        bops = BagOps(bp, scan, tuple(steps), mat)
        # children appended themselves (and their subtrees) already, so the
        # list order is bottom-up: every child precedes its parent.
        bag_ops.append(bops)
        return bops

    root_ops = build_bag(plan.root)

    final = None
    if plan.root.children and not aggregate:
        inputs = tuple(b.materialize.op_id for b in bag_ops
                       if b.materialize.output_vars)
        in_vars = set()
        for b in bag_ops:
            if b.materialize.output_vars:
                in_vars |= set(b.materialize.output_vars)
        var_order = tuple(v for v in plan.order if v in in_vars)
        est = max((ops[i].est_rows for i in inputs), default=1.0)
        td_cost = sum(ops[i].est_rows for i in inputs) * len(inputs)
        final = TopDownJoin(counter[0] + 1, est, td_cost, inputs, var_order,
                            plan.output_vars)
        counter[0] += 1
        ops[final.op_id] = final

    assert bag_ops[-1] is root_ops
    return PhysicalPlan(plan, bag_ops, final, ops)


def plan_cost(pplan: "PhysicalPlan", bag_cache=None, catalog=None) -> float:
    """Total modelled cost of the plan — the plan-search objective.

    Structurally equivalent bags (Appendix A.1 dedup) are counted ONCE,
    and a bag whose engine-lifetime reuse key is already resident in
    ``bag_cache`` costs nothing (memoized bag costing: a candidate that
    reuses work other rules/iterations already paid for is preferred).
    """
    total = 0.0
    seen = set()
    for b in pplan.bag_ops:
        # alias-RESOLVED structural key: the same key the runtime bag cache
        # uses, so Barbell's R,S,T vs R2,S2,T2 triangles (all = Edge) are
        # costed once, exactly as they execute once
        key = b.materialize.reuse_struct
        if key in seen:
            continue
        seen.add(key)
        if (bag_cache is not None and catalog is not None
                and bag_cache.contains(
                    (b.materialize.reuse_struct,
                     catalog.version_key(b.materialize.reuse_rels)))):
            continue
        total += b.scan.cost + sum(s.cost for s in b.steps) \
            + b.materialize.cost
    if pplan.final is not None:
        total += pplan.final.cost
    return total


def _resolved_struct(dedup_key: Tuple, resolve) -> Tuple:
    """``BagPlan.dedup_key`` with relation names resolved through the
    catalog's alias table — so structurally equivalent bags over ALIASES
    of the same relation (Barbell's R,S,T vs R2,S2,T2, all = Edge) share
    one engine-lifetime cache entry."""
    atom_keys, out_key, sr_key, child_keys = dedup_key
    # key=repr: column keys mix canonical ints with ("$", const) selection
    # markers, which Python refuses to order when two atoms tie on the
    # resolved relation name — repr gives a deterministic total order
    atom_keys = tuple(sorted(((resolve(rel), cols)
                              for rel, cols in atom_keys), key=repr))
    child_keys = tuple(sorted((_resolved_struct(c, resolve)
                               for c in child_keys), key=repr))
    return (atom_keys, out_key, sr_key, child_keys)


def _bag_agm_bound(plan: QueryPlan, bp: BagPlan, catalog,
                   memo: Optional[Dict] = None) -> Optional[float]:
    """AGM bound of the bag sub-query with real relation sizes
    (``min prod |R_e|^{x_e}``, paper Eq. 1) — the cap on every estimate.
    ``memo`` (keyed on the variable-canonicalized bag structure) shares
    the LP solves across the plan search's candidate lowerings."""
    key = None
    if memo is not None:
        canon: Dict[str, int] = {}

        def cv(v: str) -> int:
            if v not in canon:
                canon[v] = len(canon)
            return canon[v]

        key = tuple(sorted(
            (catalog.resolve(plan.hg.edges[ei].rel),
             tuple(cv(v) for v in plan.hg.edges[ei].vars))
            for ei in bp.bag.edge_idxs))
        if key in memo:
            return memo[key]
    try:
        log_sizes = {}
        for ei in bp.bag.edge_idxs:
            rel = plan.hg.edges[ei].rel
            log_sizes[ei] = math.log(max(2, catalog.get(rel).num_tuples))
        obj, _x = agm.fractional_cover(plan.hg, list(bp.bag.edge_idxs),
                                       log_sizes)
        out = float(math.exp(min(obj, 700.0)))
    except Exception:
        out = None
    if memo is not None:
        memo[key] = out
    return out


def _pair_self_join(accesses, advancing_atoms, advancing_children,
                    atom_keys, atom_arity, depth) -> bool:
    """True when the advancing atoms are a binary self-join over the SAME
    reordered arity-2 index at depth 1 — ``(resolved relation, perm)``
    equality IS the reorder cache's identity, so this matches the trie
    identity the runtime (``gj._fold_count`` / ``_extend_pair_store``)
    checks, without requiring the index to be built."""
    if advancing_children or len(advancing_atoms) != 2:
        return False
    i, j = advancing_atoms
    a, b = accesses[i], accesses[j]
    return not (atom_keys[i] is None or atom_keys[i] != atom_keys[j]
                or atom_arity[i] != 2
                or a.selections or b.selections
                or depth[i] != 1 or depth[j] != 1)


def _extend_sideways(accesses, advancing_atoms, atom_arity, atom_stats,
                     depth, stats: StatisticsCatalog,
                     n_cons: int) -> Optional[str]:
    """"bitset" when the pipelined counting pass should sideways-filter
    through a probe atom's bitset block directory: some constraining
    arity-2 atom probes its SECOND trie level (depth 1, no selections)
    and the statistics density gate expects its set level to be
    dominated by the Algorithm-3 dense cohort
    (``dense_fraction >= SIDEWAYS_DENSITY_MIN``) — sparse-dominated
    levels would route most rows past the directory, paying the block
    searches for nothing.  Needs >= 2 constraining atoms (the seed
    alone has no probe to filter through)."""
    if n_cons < 2:
        return None
    from repro.core.statistics import (SIDEWAYS_DENSITY_MIN,
                                       dense_fraction, layout_threshold)
    for i in advancing_atoms:
        if (atom_arity[i] != 2 or accesses[i].selections
                or depth[i] != 1):
            continue
        st = atom_stats[i]
        if st is None or len(st.levels) < 2:
            continue
        thr = layout_threshold(st, stats.block_bits)
        if dense_fraction(st.levels[1], thr) >= SIDEWAYS_DENSITY_MIN:
            return "bitset"
    return None


def _extend_routing(accesses, advancing_atoms, advancing_children,
                    atom_keys, atom_arity, depth) -> str:
    """Routing hint for a MATERIALIZING extension: "pair_store" when it is
    a binary self-join over the same reordered arity-2 trie at depth 1 —
    the condition under which ``HybridSetStore.intersect_materialize``
    can serve the expansion cohort-routed (bitset extraction for dense
    pairs) instead of the generic expand-and-probe search."""
    if _pair_self_join(accesses, advancing_atoms, advancing_children,
                       atom_keys, atom_arity, depth):
        return "pair_store"
    return "search"


def _terminal_routing(accesses, advancing_atoms, advancing_children,
                      atom_keys, atom_arity, atom_stats, depth,
                      stats: StatisticsCatalog):
    """Routing hint + statistics-driven layout threshold for the terminal
    fold.  The binary self-join pair-store path (Algorithm-3 cohorts,
    ``HybridSetStore``) applies when exactly two physical atoms resolve to
    the SAME reordered trie (aliases collapse through the catalog) with
    arity 2, no selections, folding at depth 1 — the condition
    ``gj._fold_count`` checks at run time, decided here once from the
    plan."""
    if not _pair_self_join(accesses, advancing_atoms, advancing_children,
                           atom_keys, atom_arity, depth):
        return "search", None
    from repro.core.statistics import layout_threshold
    i = advancing_atoms[0]
    return "pair_kernel", layout_threshold(atom_stats[i], stats.block_bits)
