"""The paper's Table 2 workload as datalog programs.

One definition shared by the cross-backend benchmark suite
(``benchmarks/run.py``) and the backend-parity tests, so both exercise
the same programs. All pattern queries expect the edge relation loaded
as ``Edge`` with the aliases in :data:`ALIASES` pointing at it.
"""
from __future__ import annotations

from typing import List, Tuple

ALIASES = ("R", "S", "T", "U", "X", "Y", "R2", "S2", "T2")

TRIANGLE_COUNT = "C(;w:long) :- R(x,y),S(y,z),T(x,z); w=<<COUNT(*)>>."
TRIANGLE_LIST = "Tri(x,y,z) :- R(x,y),S(y,z),T(x,z)."
FOUR_CLIQUE = ("C(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),X(y,a),Y(z,a); "
               "w=<<COUNT(*)>>.")
LOLLIPOP = "C(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a); w=<<COUNT(*)>>."
BARBELL = ("C(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),R2(a,b),S2(b,c),"
           "T2(a,c); w=<<COUNT(*)>>.")


def pagerank_program(iters: int = 5) -> str:
    return (
        "N(;w:int) :- Edge(x,y); w=<<COUNT(x)>>.\n"
        "InvDeg(x;y:float) :- Edge(x,z); y=1.0/<<COUNT(z)>>.\n"
        "PageRank(x;y:float) :- Edge(x,z); y=1.0/N.\n"
        f"PageRank(x;y:float)*[i={iters}] :- Edge(x,z),PageRank(z),"
        "InvDeg(z); y=0.15/N+0.85*<<SUM(z)>>.")


def sssp_program(source) -> str:
    return (f"SSSP(x;y:int) :- Edge({source},x); y=1.\n"
            "SSSP(x;y:int)* :- Edge(w,x),SSSP(w); y=<<MIN(w)>>+1.")


def paper_query_set(source=0, pr_iters: int = 5) -> List[Tuple[str, str]]:
    """(name, program) pairs for the full Table 2 workload."""
    return [
        ("triangle", TRIANGLE_COUNT),
        ("4clique", FOUR_CLIQUE),
        ("lollipop", LOLLIPOP),
        ("barbell", BARBELL),
        ("pagerank", pagerank_program(pr_iters)),
        ("sssp", sssp_program(source)),
    ]
