"""The EmptyHeaded engine facade (paper Figure 1).

``Engine`` wires the three phases together:

  1. query compiler — datalog text -> GHD logical plan (``core.compile``),
  2. code generation — GHD -> executable joins (``core.codegen`` emits
     Python source; the plan interpreter in ``core.executor`` is the
     differential-testing twin),
  3. execution engine — vectorized worst-case-optimal joins with
     layout/algorithm decisions made from data characteristics.

Multi-rule programs evaluate in order; Kleene-star rules run **naive**
recursion (fixed iterations / float tolerance — PageRank) or **seminaive**
recursion, selected automatically "if the aggregation is monotonically
increasing or decreasing with a MIN or MAX operator" (paper Section 3.3 —
SSSP), in which case only the delta relation is re-joined each round.

**Backend selection**: the execution engine runs on a pluggable backend
(``core.backend``). ``Engine(backend="numpy")`` is the host-side oracle;
``Engine(backend="device")`` keeps trie levels device-resident, fuses
each attribute extension into one device call, and dispatches
terminal-fold intersections to the layout-cohort Pallas kernels. With no
argument the ``REPRO_ENGINE_BACKEND`` environment variable decides
(default numpy). One backend instance lives per Engine, so multi-rule and
recursive programs reuse its device-resident uploads across rules and
iterations; ``Engine.dispatch_summary()`` reports which kernel handled
each intersection.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import codegen as codegen_mod
from repro.core import plan_ir
from repro.core import plan_search as plan_search_mod
from repro.core import recursion as recursion_mod
from repro.core.backend import ExecBackend, make_backend
from repro.core.compile import QueryPlan, compile_rule, parameterize
from repro.core.datalog import (AggRef, Num, Param, Rule, ScalarRef, Var,
                                eval_expr, parse)
from repro.core.executor import (BagResultCache, Catalog, Executor,
                                 apply_expr)
from repro.core.gj import GenericJoin, GJResult, run_batched
from repro.core.semiring import AGG_TO_SEMIRING, MAX_MIN, MIN_PLUS, SUM_F32
from repro.core.statistics import StatisticsCatalog
from repro.core.trie import Trie

# Escape hatch for the device-resident recursion loops (default on under
# the device backend): "off"/"0"/"false" pins the per-round host loop —
# the differential-testing oracle the parity tests compare against.
DEVICE_RECURSION_ENV = "REPRO_DEVICE_RECURSION"

# Escape hatch for the zero-sync extension pipeline (default on under the
# device backend): "off" pins the per-extension-sync expand-and-probe
# path as the differential oracle.  Resolved by the backend at
# construction (core.backend); Engine(device_pipeline=...) overrides.
DEVICE_PIPELINE_ENV = "REPRO_DEVICE_PIPELINE"

# Static plan verification (repro.analysis.plan_verify) over every lowered
# physical plan, default ON: the validator is cheap (pure structural walk)
# relative to planning itself. "REPRO_VERIFY_PLANS=off" is the escape
# hatch for debugging the validator itself.
VERIFY_PLANS_ENV = "REPRO_VERIFY_PLANS"
# Runtime dispatch sanitizer (repro.analysis.kernel_check.check_dispatch),
# default OFF: after every rule execution, assert the backend's dispatch
# counters match what the validated plan predicted. "REPRO_SANITIZE=1"
# turns it on (tests and the benchmark harness do).
SANITIZE_ENV = "REPRO_SANITIZE"


def _env_flag(name: str, default: bool) -> bool:
    val = os.environ.get(name)
    if val is None:
        return default
    return val.strip().lower() not in ("off", "0", "false", "no")


def device_recursion_enabled(default: bool = True) -> bool:
    return _env_flag(DEVICE_RECURSION_ENV, default)


def device_pipeline_enabled(default: bool = True) -> bool:
    return _env_flag(DEVICE_PIPELINE_ENV, default)


def verify_plans_enabled(default: bool = True) -> bool:
    return _env_flag(VERIFY_PLANS_ENV, default)


def sanitize_enabled(default: bool = False) -> bool:
    return _env_flag(SANITIZE_ENV, default)


@dataclasses.dataclass
class QueryResult:
    vars: Tuple[str, ...]
    columns: Dict[str, np.ndarray]
    annotation: Optional[np.ndarray]

    @staticmethod
    def from_gj(res: GJResult) -> "QueryResult":
        return QueryResult(res.vars,
                           {k: np.asarray(v) for k, v in res.columns.items()},
                           np.asarray(res.annotation)
                           if res.annotation is not None else None)

    @property
    def num_rows(self) -> int:
        if self.vars:
            return len(self.columns[self.vars[0]])
        return 1

    def scalar(self):
        assert not self.vars, f"not a scalar result: vars={self.vars}"
        return self.annotation

    def as_dict(self) -> Dict[int, object]:
        assert len(self.vars) == 1
        keys = self.columns[self.vars[0]]
        return dict(zip(keys.tolist(), self.annotation.tolist()))


@dataclasses.dataclass
class PreparedQuery:
    """A single rule compiled once, selection constants as bind slots.

    ``rule`` carries ``Const(Param(slot))`` placeholders (one slot per
    distinct constant in the source text, first-appearance order) and
    ``defaults`` the constants they replaced.  Because ``repr(rule)`` is
    binding-independent, every compile-side cache — logical plan, plan
    search decision, physical plan + emitted source, and the backend's
    traced bag programs — is shared across bindings: re-binding performs
    zero plan searches and zero retraces (``compile.*`` counters and
    ``backend.trace_count()`` prove it).

    ``run(*params)`` executes one binding; ``run_batch(bindings)``
    executes many, as ONE fused vmapped device launch per
    ``statistics.max_batch`` chunk where the plan shape allows, falling
    back to the sequential per-binding loop (the exact-parity oracle)
    otherwise.  Neither materializes the head relation.
    """

    engine: "Engine"
    rule: Rule
    defaults: Tuple[object, ...]

    @property
    def n_params(self) -> int:
        return len(self.defaults)

    def _binding(self, params: Tuple) -> Tuple:
        if not params:
            return tuple(self.defaults)
        if len(params) != len(self.defaults):
            raise ValueError(
                f"expected {len(self.defaults)} parameters "
                f"(defaults {self.defaults}), got {len(params)}")
        return tuple(params)

    def run(self, *params) -> QueryResult:
        binding = self._binding(params)
        enc = self.engine._binding_encode(binding)
        return self.engine._eval_rule(self.rule, materialize=False,
                                      encode=enc)

    __call__ = run

    def run_batch(self, bindings) -> List[QueryResult]:
        """Execute many bindings; results in submission order.  Each
        entry is a parameter tuple (a bare scalar binds a 1-slot rule)."""
        norm = [self._binding(tuple(b) if isinstance(b, (tuple, list))
                              else (b,)) for b in bindings]
        out = self.engine._execute_batch(self.rule, norm)
        if out is None:
            out = [self.run(*b) for b in norm]
        return out


class Engine:
    """Public API: load relations, run datalog programs."""

    def __init__(self, use_ghd: bool = True, use_codegen: bool = True,
                 backend=None, plan_search: Optional[bool] = None,
                 device_recursion: Optional[bool] = None,
                 device_pipeline: Optional[bool] = None,
                 fused_bags: Optional[bool] = None,
                 verify_plans: Optional[bool] = None,
                 sanitize: Optional[bool] = None):
        self.catalog = Catalog()
        self.use_ghd = use_ghd
        self.use_codegen = use_codegen
        # backend: ExecBackend | "numpy" | "device" | None (env-resolved)
        self.backend: ExecBackend = make_backend(backend)
        # zero-sync extension pipeline (count-then-fill, core.backend):
        # None keeps the backend's own REPRO_DEVICE_PIPELINE resolution;
        # an explicit bool overrides it (device_pipeline=False pins the
        # per-extension-sync path as the differential oracle)
        if device_pipeline is not None and hasattr(self.backend,
                                                   "pipeline_enabled"):
            self.backend.pipeline_enabled = bool(device_pipeline)
        self.device_pipeline = bool(getattr(self.backend,
                                            "pipeline_enabled", False))
        # whole-bag fusion (one traced composite per bag, backend.run_bag):
        # None keeps the backend's REPRO_FUSED_BAG resolution; an explicit
        # bool overrides it (fused_bags=False pins one launch per
        # attribute step as the A/B leg)
        if fused_bags is not None and hasattr(self.backend, "fuse_bags"):
            self.backend.fuse_bags = bool(fused_bags)
        self.fused_bags = bool(getattr(self.backend, "fuse_bags", False))
        # cost-based GHD + attribute-order search (core.plan_search); None
        # defers to REPRO_PLAN_SEARCH (default on, "off" = the seed
        # appearance-order plan, kept as the differential-testing oracle)
        self.plan_search = (plan_search_mod.enabled_by_env()
                            if plan_search is None else bool(plan_search))
        # static plan verification (repro.analysis.plan_verify) over every
        # lowered plan AND every plan-search candidate; None defers to
        # REPRO_VERIFY_PLANS (default on)
        self.verify_plans = (verify_plans_enabled()
                             if verify_plans is None else bool(verify_plans))
        # runtime dispatch sanitizer: after each rule execution, assert the
        # backend counters match the validated plan's predictions; None
        # defers to REPRO_SANITIZE (default off — it forces a stats
        # snapshot per rule)
        self.sanitize = (sanitize_enabled()
                         if sanitize is None else bool(sanitize))
        # device-resident recursion (seminaive/naive fixpoints as one
        # jitted loop, core.recursion): only meaningful under the device
        # backend; None defers to REPRO_DEVICE_RECURSION (default on)
        self.device_recursion = (device_recursion_enabled()
                                 if device_recursion is None
                                 else bool(device_recursion))
        self.dictionary: Dict[object, int] = {}
        self.last_plan: Optional[QueryPlan] = None
        self.last_physical: Optional[plan_ir.PhysicalPlan] = None
        self.last_source: Optional[str] = None
        # plan cache: the GHD search is brute-force (NP-hard in #attrs) and
        # the paper excludes compilation from query timing — repeated
        # queries reuse the compiled plan
        self._plan_cache: Dict[Tuple[str, bool], QueryPlan] = {}
        # plan-SEARCH decision cache: the chosen (GHD, order) per rule.
        # The choice is made once per engine from the statistics at first
        # execution; later rounds (recursion bumps catalog versions every
        # iteration) re-annotate the SAME chosen plan against fresh
        # statistics instead of re-running the whole candidate search.
        self._search_cache: Dict[Tuple, Tuple] = {}
        # physical-plan (+ emitted codegen) cache, keyed additionally on
        # catalog versions: re-plans when the data a rule reads changes
        self._physical_cache: Dict[Tuple, Tuple] = {}
        # statistics catalog: sampled per-trie profiles driving the plan
        # IR's cardinality estimates and Algorithm-3 layout thresholds
        self.stats_catalog = StatisticsCatalog()
        # engine-lifetime Appendix-A.1 bag cache: sub-bags shared across
        # rules / recursion rounds are computed once (version-invalidated)
        self.bag_cache = BagResultCache()
        # per-query() optimizer scorecard: one metadata dict per rule run
        self._program_metadata: List[dict] = []

    # ----------------------------------------------------------------- load
    def load_edges(self, name: str, src, dst, annotation=None):
        src = np.asarray(src)
        dst = np.asarray(dst)
        t = Trie.build(name, ("c0", "c1"), [src, dst], annotation=annotation)
        self.catalog.add(name, t)
        return t

    def load_table(self, name: str, columns: Sequence[np.ndarray],
                   annotation=None):
        attrs = tuple(f"c{i}" for i in range(len(columns)))
        t = Trie.build(name, attrs, list(columns), annotation=annotation)
        self.catalog.add(name, t)
        return t

    def alias(self, name: str, target: str):
        self.catalog.alias(name, target)

    def set_dictionary(self, mapping: Dict[object, int]):
        self.dictionary = dict(mapping)

    def encode(self, value) -> int:
        if isinstance(value, (int, np.integer)):
            return int(value)
        return int(self.dictionary[value])

    def _binding_encode(self, binding: Tuple):
        """Encode closure resolving ``Param`` slots against ``binding``.

        Carries ``binding_key`` so runtime result-reuse keys (the
        engine-lifetime bag cache) distinguish bindings even though the
        parameterized rule's STRUCTURAL keys are binding-invariant."""
        base = self.encode

        def enc(value):
            if isinstance(value, Param):
                return base(binding[value.slot])
            return base(value)

        enc.binding_key = tuple(binding)
        return enc

    # ---------------------------------------------------------------- query
    def query(self, text: str) -> QueryResult:
        """Run a datalog program; returns the result of the LAST head."""
        prog = parse(text)
        self._program_metadata = []
        result: Optional[QueryResult] = None
        for i, rule in enumerate(prog.rules):
            is_star_base = (rule.recursion is None and
                            any(r.recursion is not None and
                                r.head.rel == rule.head.rel
                                for r in prog.rules[i + 1:]))
            if rule.recursion is not None:
                result = self._eval_recursive(rule)
            else:
                result = self._eval_rule(rule, materialize=True or is_star_base)
        assert result is not None, "empty program"
        return result

    def prepare(self, text: str) -> PreparedQuery:
        """Compile ONE non-recursive rule with its selection constants
        rewritten into bind parameters (``compile.parameterize``); the
        returned :class:`PreparedQuery` re-binds without recompiling.
        The logical plan is warmed here; physical planning stays lazy
        because it keys on the catalog versions at first execution."""
        prog = parse(text)
        if len(prog.rules) != 1:
            raise ValueError("prepare() takes exactly one rule")
        rule = prog.rules[0]
        if rule.recursion is not None:
            raise ValueError("prepare() does not support recursive rules")
        rule_p, defaults = parameterize(rule)
        self._compile(rule_p)
        return PreparedQuery(self, rule_p, defaults)

    def explain(self, text: str) -> str:
        prog = parse(text)
        out = []
        for rule in prog.rules:
            plan = self._compile(rule)
            out.append(plan.pretty())
        return "\n".join(out)

    def generated_source(self) -> Optional[str]:
        return self.last_source

    def dispatch_summary(self) -> Dict[str, int]:
        """Instrumentation counters: which kernel handled each intersection
        (``intersect.*`` count pairs), extension-loop host-sync discipline
        (``extend.calls`` vs ``extend.host_syncs``), device uploads,
        statistics-driven layout routing (``layout.stats_driven`` /
        ``layout.threshold_bits``), engine-lifetime bag-cache traffic
        (``bag_cache.hits`` / ``bag_cache.misses``), reorder-index builds
        (``reorder_cache.builds`` — plan-search losers must build none),
        and the recursion sync discipline (``recursion.device_rounds`` /
        ``recursion.device_fixpoints`` vs ``recursion.host_rounds`` /
        ``recursion.host_trie_rebuilds``)."""
        out = self.backend.dispatch_summary()
        out["bag_cache.hits"] = self.bag_cache.hits
        out["bag_cache.misses"] = self.bag_cache.misses
        out["reorder_cache.builds"] = self.catalog.reorder_builds
        out["reorder_cache.hits"] = self.catalog.reorder_hits
        return out

    def plan_metadata(self) -> List[dict]:
        """Optimizer choices of the last ``query()`` call: one record per
        executed rule — fhw, attribute order, per-operator estimated vs
        actual cardinalities (plus the geometric-mean q-error scorecard in
        ``est_error``), terminal-fold routing and layout thresholds, and
        the cost-based search verdict in ``plan_search`` (candidates
        considered, chosen vs baseline cost/order). Written into the
        benchmark artifact by ``benchmarks/run.py``."""
        return list(self._program_metadata)

    # ------------------------------------------------------------ internals
    def _compile(self, rule: Rule) -> QueryPlan:
        key = (repr(rule), self.use_ghd)
        plan = self._plan_cache.get(key)
        if plan is None:
            self.backend.stats["compile.logical_compiles"] += 1
            plan = compile_rule(rule, use_ghd=self.use_ghd)
            if plan.semiring is not None and plan.needs_top_down:
                plan = compile_rule(rule, use_ghd=False)
            self._plan_cache[key] = plan
        else:
            self.backend.stats["compile.plan_cache_hits"] += 1
        self.last_plan = plan
        return plan

    def _physical(self, plan: QueryPlan):
        """Physical plan (+ emitted source) for ``plan`` against the
        CURRENT catalog contents. Cached on (rule, use_ghd, plan_search,
        catalog versions of the body relations): statistics, cardinality
        estimates, and layout thresholds are pure functions of the data
        versions, so repeated executions — the paper's repeated-query
        protocol — skip the planner and the codegen exec entirely, while
        any reload (or a recursion round rebuilding its delta)
        re-plans against fresh statistics.

        With the cost-based plan search on, the first execution of a rule
        runs the full candidate search (``core.plan_search``); the chosen
        logical plan is pinned in ``_search_cache`` so later rounds only
        re-annotate it."""
        rels = tuple(sorted({a.rel for a in plan.rule.body}))
        key = (repr(plan.rule), self.use_ghd, self.use_codegen,
               self.plan_search, self.catalog.version_key(rels))
        hit = self._physical_cache.get(key)
        if hit is None:
            self.backend.stats["compile.physical_builds"] += 1
            search_md = None
            if self.plan_search:
                dkey = (repr(plan.rule), self.use_ghd)
                decided = self._search_cache.get(dkey)
                if decided is None:
                    self.backend.stats["compile.plan_searches"] += 1
                    sr = plan_search_mod.search(
                        plan, self.stats_catalog, self.catalog,
                        bag_cache=self.bag_cache, use_ghd=self.use_ghd,
                        verify=self.verify_plans,
                        counter=self.backend.stats)
                    decided = (sr.chosen, sr.metadata())
                    if len(self._search_cache) >= 256:
                        self._search_cache.pop(
                            next(iter(self._search_cache)))
                    self._search_cache[dkey] = decided
                    pplan = sr.physical
                else:
                    pplan = plan_ir.build_physical_plan(
                        decided[0], self.stats_catalog, self.catalog)
                search_md = decided[1]
            else:
                pplan = plan_ir.build_physical_plan(plan, self.stats_catalog,
                                                    self.catalog)
            if self.verify_plans:
                # static proof obligations on the plan execution is about
                # to consume — the search path verified candidates too;
                # this re-checks the final (re-annotated) lowering
                from repro.analysis import assert_valid
                assert_valid(pplan, self.catalog, self.stats_catalog)
                self.backend.stats["analysis.plans_verified"] += 1
            fn = src = None
            if self.use_codegen:
                fn, src = codegen_mod.emit(pplan)
            if len(self._physical_cache) >= 256:
                self._physical_cache.pop(next(iter(self._physical_cache)))
            hit = self._physical_cache[key] = (pplan, fn, src, search_md)
        else:
            self.backend.stats["compile.physical_cache_hits"] += 1
        return hit

    def _execute(self, plan: QueryPlan, encode=None) -> GJResult:
        pplan, fn, src, search_md = self._physical(plan)
        self.last_physical = pplan
        enc = encode if encode is not None else self.encode
        # sanitize: snapshot AFTER planning (verification counters are not
        # execution dispatch) so the delta is exactly this rule's dispatch
        stats_before = dict(self.backend.stats) if self.sanitize else None
        metrics: Dict[int, dict] = {}
        if self.use_codegen:
            self.last_source = src
            res = fn(self.catalog, enc, self.backend,
                     bag_cache=self.bag_cache, metrics=metrics)
        else:
            ex = Executor(self.catalog, enc, backend=self.backend,
                          bag_cache=self.bag_cache,
                          stats_catalog=self.stats_catalog)
            res = ex.run(pplan)
            metrics = ex.metrics
        if self.sanitize:
            from repro.analysis.kernel_check import check_dispatch
            delta = {k: v - stats_before.get(k, 0)
                     for k, v in self.backend.stats.items()
                     if v != stats_before.get(k, 0)}
            check_dispatch(pplan, delta, metrics, self.backend.name)
            self.backend.stats["analysis.sanitize_checks"] += 1
        md = pplan.metadata()
        for bag in md["bags"]:
            m = metrics.get(bag["op_id"])
            if m is None:
                continue
            bag["actual_rows"] = int(m["actual_rows"])
            # per-extension estimated-vs-actual frontier sizes
            actuals = dict(m.get("level_actuals") or [])
            for step in bag["steps"]:
                if step["var"] in actuals:
                    step["actual_rows"] = int(actuals[step["var"]])
        md["plan_search"] = (search_md if search_md is not None
                             else {"enabled": False})
        md["est_error"] = _est_error(md["bags"])
        self._program_metadata.append(md)
        return res

    def _execute_batch(self, rule: Rule,
                       bindings: List[Tuple]) -> Optional[List[QueryResult]]:
        """Batched lowering of a prepared rule: one GenericJoin per
        binding over the SAME physical plan, handed to ``gj.run_batched``
        for fused vmapped execution.  Returns None when the shape is
        outside the batchable envelope — multi-bag plans, top-down joins,
        count-distinct rewrites, host backends — and the caller falls
        back to the sequential per-binding loop, the exact-parity oracle.

        The engine-lifetime bag cache and the dispatch sanitizer are
        bypassed on purpose: per-binding probe results are cheaper to
        recompute than to cache, and the sanitizer's per-rule dispatch
        model does not describe a batched launch.
        """
        agg = rule.agg
        if agg is not None and agg.op == "count" and agg.arg != "*":
            return None
        plan = self._compile(rule)
        pplan, _fn, _src, _md = self._physical(plan)
        if len(pplan.bag_ops) != 1 or pplan.final is not None:
            return None
        bops = pplan.bag_ops[0]
        if bops.scan.child_inputs:
            return None
        lplan = pplan.logical
        joins: List[GenericJoin] = []
        for binding in bindings:
            enc = self._binding_encode(binding)
            gj_atoms = []
            selections: Dict[int, Dict[int, int]] = {}
            for acc in bops.scan.accesses:
                sel = acc.selection_map(enc)
                if sel:
                    selections[len(gj_atoms)] = sel
                gj_atoms.append((self.catalog.reordered(acc.rel, acc.perm),
                                 acc.vars))
            joins.append(GenericJoin(
                gj_atoms, bops.scan.var_order,
                bops.materialize.output_vars, semiring=lplan.semiring,
                selections=selections, backend=self.backend,
                hints=bops.hints()))
        results = run_batched(joins)
        if results is None:
            return None
        return [QueryResult.from_gj(
            apply_expr(lplan, res, self.catalog.scalars))
            for res in results]

    def _eval_rule(self, rule: Rule, materialize: bool,
                   encode=None) -> QueryResult:
        agg = rule.agg
        if agg is not None and agg.op == "count" and agg.arg != "*":
            res = self._eval_count_distinct(rule, agg, encode=encode)
        else:
            plan = self._compile(rule)
            res = QueryResult.from_gj(self._execute(plan, encode=encode))
        if materialize:
            self._materialize_head(rule, res)
        return res

    def _eval_count_distinct(self, rule: Rule, agg: AggRef,
                             encode=None) -> QueryResult:
        """COUNT(v) = number of DISTINCT v per output group: evaluate the
        body with output keyvars+{v} under set semantics, then group-count."""
        ext_out = tuple(rule.head.keyvars) + ((agg.arg,)
                                              if agg.arg not in rule.head.keyvars else ())
        sub = dataclasses.replace(
            rule,
            head=dataclasses.replace(rule.head, keyvars=ext_out),
            agg_expr=None)
        plan = self._compile(sub)
        res = self._execute(plan, encode=encode)
        keyvars = tuple(rule.head.keyvars)
        if not keyvars:
            count = np.asarray(res.num_rows, dtype=np.int64)
            value = eval_expr(rule.agg_expr, count, self.catalog.scalars)
            return QueryResult((), {}, np.asarray(value))
        keys = np.stack([np.asarray(res.columns[v]) for v in keyvars], axis=1)
        uniq, inv = np.unique(keys, axis=0, return_inverse=True)
        counts = np.bincount(inv, minlength=len(uniq))
        value = eval_expr(rule.agg_expr, counts, self.catalog.scalars)
        cols = {v: uniq[:, i].astype(np.int32) for i, v in enumerate(keyvars)}
        return QueryResult(keyvars, cols, np.asarray(value))

    def _materialize_head(self, rule: Rule, res: QueryResult):
        name = rule.head.rel
        if not rule.head.keyvars:
            if res.annotation is not None:
                self.catalog.scalars[name] = np.asarray(res.annotation).item() \
                    if np.asarray(res.annotation).ndim == 0 else res.annotation
            return
        cols = [res.columns[v] for v in rule.head.keyvars]
        t = Trie.build(name, tuple(rule.head.keyvars), cols,
                       annotation=res.annotation)
        self.catalog.add(name, t)

    # ------------------------------------------------------------ recursion
    def _eval_recursive(self, rule: Rule) -> QueryResult:
        agg = rule.agg
        sr = AGG_TO_SEMIRING[agg.op] if agg is not None else None
        seminaive = sr in (MIN_PLUS, MAX_MIN)
        if seminaive:
            return self._seminaive(rule, sr)
        return self._naive(rule)

    # ----------------------------------------- device-resident fast path
    def _spmv_shape(self, rule: Rule):
        """Recognize the semiring-SpMV recursion shape the device loops
        execute: head ``Rec(h)``, body = ONE binary non-recursive atom
        over {h, r} + the recursive atom ``Rec(r)`` + optional unary
        non-recursive atoms ``A_i(r)``, aggregating over ``r``.  Returns
        ``(edge_atom, unary_atoms, h, r)`` or None (host loop)."""
        if len(rule.head.keyvars) != 1:
            return None
        h = rule.head.keyvars[0]
        agg = rule.agg
        if agg is None or agg.op == "count" or agg.arg in ("*", h):
            return None
        r = agg.arg
        name = self.catalog.resolve(rule.head.rel)
        rec_atoms = [a for a in rule.body
                     if self.catalog.resolve(a.rel) == name]
        if len(rec_atoms) != 1 or rec_atoms[0].terms != (Var(r),):
            return None
        others = [a for a in rule.body if a is not rec_atoms[0]]
        if any(not isinstance(t, Var) for a in others for t in a.terms):
            return None
        binary = [a for a in others if len(a.terms) == 2]
        unary = [a for a in others if len(a.terms) == 1]
        if len(binary) != 1 or len(binary) + len(unary) != len(others):
            return None
        e = binary[0]
        if set(e.vars) != {h, r} or e.rel not in self.catalog \
                or self.catalog.get(e.rel).arity != 2:
            return None
        for a in unary:
            if a.vars != (r,) or a.rel not in self.catalog \
                    or self.catalog.get(a.rel).arity != 1:
                return None
        return e, unary, h, r

    def _recursion_expr_fn(self, rule: Rule):
        """Jit-stable annotation-expression applier, or None when the
        expression references something the device loop cannot bake in
        (e.g. a non-scalar "scalar" relation)."""
        names = _expr_scalar_names(rule.agg_expr)
        scalars = {}
        for nm in names:
            v = self.catalog.scalars.get(nm)
            if v is None or np.ndim(v) != 0:
                return None
            scalars[nm] = float(v)
        return recursion_mod.ExprFn(rule.agg_expr, scalars)

    def _device_recursion_allowed(self) -> bool:
        return self.backend.name == "device" and self.device_recursion

    def _record_device_recursion(self, rule: Rule, strategy: str,
                                 rounds: int):
        self.backend.stats["recursion.device_fixpoints"] += 1
        self.backend.stats["recursion.device_rounds"] += int(rounds)
        self._program_metadata.append({
            "head": rule.head.rel,
            "recursion": {"mode": "device", "strategy": strategy,
                          "rounds": int(rounds)},
            "bags": [],
            "plan_search": {"enabled": False},
            "est_error": {"n_bags": 0, "geo_mean_q": None},
        })

    def _seminaive_device(self, rule: Rule, sr) -> Optional[QueryResult]:
        """Seminaive recursion as ONE jitted device loop (fixed-shape
        masked delta over the vertex domain, mirroring ``recursion.sssp``)
        instead of a host delta-trie rebuild per round.  Returns None when
        the rule/data fall outside the SpMV shape — the host loop is the
        fallback and the differential oracle."""
        if not self._device_recursion_allowed():
            return None
        shape = self._spmv_shape(rule)
        if shape is None or shape[1]:   # unary extras: host loop
            return None
        e, _unary, h, r = shape
        apply_expr = self._recursion_expr_fn(rule)
        if apply_expr is None:
            return None
        name = rule.head.rel
        base = self.catalog.get(name)
        keys0 = base.levels[0].values.astype(np.int64)
        if base.annotation is None or len(keys0) == 0:
            return None
        ann0 = np.asarray(base.annotation, dtype=np.float64)
        zero = float(np.asarray(sr.zero))
        if not np.all(ann0 != zero):
            # a base tuple annotated with the semiring zero would be
            # indistinguishable from "underived" in the masked state
            return None
        src, dst, eann = self.catalog.get(e.rel).edge_view()
        gather_v, scatter_v = (src, dst) if e.vars == (r, h) else (dst, src)
        n = int(max(keys0.max(initial=0),
                    gather_v.max(initial=0), scatter_v.max(initial=0))) + 1
        max_rounds = (int(rule.recursion.value)
                      if rule.recursion.kind == "iterations" else 1 << 30)
        keys, ann, rounds = recursion_mod.seminaive_device_fixpoint(
            sr, apply_expr, gather_v, scatter_v, eann, n, keys0, ann0,
            max_rounds)
        self._record_device_recursion(rule, "seminaive", rounds)
        keyvars = tuple(rule.head.keyvars)
        keys32 = keys.astype(np.int32)
        self.catalog.add(name, Trie.build(name, keyvars, [keys32],
                                          annotation=ann))
        return QueryResult(keyvars, {keyvars[0]: keys32}, ann)

    def _naive_device(self, rule: Rule, prev_keys: np.ndarray,
                      iters: Optional[int], tol: Optional[float],
                      max_iters: int) -> Optional[QueryResult]:
        """Naive recursion (every annotation rewritten every round) as ONE
        jitted device loop over the FIXED head key set: memberships and
        non-recursive annotation factors are resolved once on host, then
        every round is a gather → ⊗-chain → segment-⨁ → expression
        rewrite with zero per-round host syncs (tolerance checked on
        device inside the while-loop)."""
        if not self._device_recursion_allowed():
            return None
        agg = rule.agg
        if agg is None or AGG_TO_SEMIRING.get(agg.op) is not SUM_F32:
            return None
        shape = self._spmv_shape(rule)
        if shape is None:
            return None
        e, unary, h, r = shape
        sr = SUM_F32
        apply_expr = self._recursion_expr_fn(rule)
        if apply_expr is None:
            return None
        name = rule.head.rel
        base = self.catalog.get(name)
        if base.annotation is None or len(prev_keys) == 0:
            return None
        keys = np.asarray(prev_keys, dtype=np.int64)
        ann0 = np.asarray(base.annotation, dtype=np.float64)
        src, dst, eann = self.catalog.get(e.rel).edge_view()
        gather_v, scatter_v = (src, dst) if e.vars == (r, h) else (dst, src)

        def positions(sorted_keys, queries):
            if len(sorted_keys) == 0:
                return (np.zeros(len(queries), np.int64),
                        np.zeros(len(queries), bool))
            pos = np.searchsorted(sorted_keys, queries)
            pos = np.clip(pos, 0, len(sorted_keys) - 1)
            return pos, sorted_keys[pos] == queries

        out_idx, valid = positions(keys, scatter_v)
        rec_idx, ok = positions(keys, gather_v)
        valid = valid & ok
        # ⊗-factors in body-atom order (exactly the fold's mul order)
        factor_kinds: List[str] = []
        gathers: List[np.ndarray] = []
        for a in rule.body:
            if self.catalog.resolve(a.rel) == self.catalog.resolve(name):
                factor_kinds.append("rec")
            elif len(a.terms) == 2:
                if eann is not None:
                    factor_kinds.append("static")
                    gathers.append(np.asarray(eann))
            else:
                t = self.catalog.get(a.rel)
                upos, ok = positions(
                    t.levels[0].values.astype(np.int64), gather_v)
                valid = valid & ok
                if t.annotation is not None:
                    factor_kinds.append("static")
                    gathers.append(np.asarray(t.annotation)[upos])
        out_idx = out_idx[valid]
        rec_idx = rec_idx[valid]
        factor_anns = [g[valid] for g in gathers]
        if iters is None and tol is None:
            iters = max_iters   # bare-star naive: fixed round budget
        ann, rounds = recursion_mod.naive_device_fixpoint(
            sr, apply_expr, out_idx, rec_idx, tuple(factor_kinds),
            factor_anns, len(keys), ann0, iters, tol, max_iters)
        self._record_device_recursion(rule, "naive", rounds)
        keyvars = tuple(rule.head.keyvars)
        keys32 = keys.astype(np.int32)
        self.catalog.add(name, Trie.build(name, keyvars, [keys32],
                                          annotation=ann))
        return QueryResult(keyvars, {keyvars[0]: keys32}, ann)

    def _naive(self, rule: Rule) -> QueryResult:
        """Naive recursion: re-evaluate the body against the full current
        relation each round (paper: used for PageRank)."""
        rec = rule.recursion
        iters = int(rec.value) if rec.kind == "iterations" else None
        tol = float(rec.value) if rec.kind == "tolerance" else None
        max_iters = iters if iters is not None else 10_000
        name = rule.head.rel
        keyvars = tuple(rule.head.keyvars)
        prev = self.catalog.get(name)
        prev_keys = prev.levels[0].values.copy()
        prev_ann = (prev.annotation.copy() if prev.annotation is not None
                    else None)
        assert len(keyvars) == 1, "naive recursion implemented for unary heads"

        fast = self._naive_device(rule, prev_keys, iters, tol, max_iters)
        if fast is not None:
            return fast

        default = None
        res = None
        for it in range(max_iters):
            self.backend.stats["recursion.host_rounds"] += 1
            res = self._eval_rule(rule_without_star(rule), materialize=False)
            if default is None:
                default = float(eval_expr(rule.agg_expr, np.zeros(1),
                                          self.catalog.scalars)[0]) \
                    if rule.agg_expr is not None else 0.0
            # keys persist across iterations (head keys = initialized keys);
            # missing keys fall back to expr(aggregate == zero).
            new_ann = np.full(len(prev_keys), default, dtype=np.float64)
            if res.num_rows and res.vars:
                lookup = np.searchsorted(prev_keys, res.columns[keyvars[0]])
                lookup = np.clip(lookup, 0, len(prev_keys) - 1)
                hit = prev_keys[lookup] == res.columns[keyvars[0]]
                new_ann[lookup[hit]] = np.asarray(res.annotation)[hit]
            if tol is not None and prev_ann is not None:
                if float(np.max(np.abs(new_ann - prev_ann))) <= tol:
                    prev_ann = new_ann
                    break
            prev_ann = new_ann
            self.backend.stats["recursion.host_trie_rebuilds"] += 1
            t = Trie.build(name, keyvars, [prev_keys], annotation=new_ann)
            self.catalog.add(name, t)
        t = Trie.build(name, keyvars, [prev_keys], annotation=prev_ann)
        self.catalog.add(name, t)
        return QueryResult(keyvars, {keyvars[0]: prev_keys}, prev_ann)

    def _seminaive(self, rule: Rule, sr) -> QueryResult:
        """Seminaive recursion: only the delta (tuples whose annotation
        improved last round) re-joins (paper: used for SSSP)."""
        name = rule.head.rel
        keyvars = tuple(rule.head.keyvars)
        assert len(keyvars) == 1, "seminaive implemented for unary heads"
        fast = self._seminaive_device(rule, sr)
        if fast is not None:
            return fast
        base = self.catalog.get(name)
        keys = base.levels[0].values.copy().astype(np.int64)
        ann = np.asarray(base.annotation, dtype=np.float64).copy()

        rec_atoms = [a for a in rule.body if a.rel == name]
        assert len(rec_atoms) == 1, "exactly one recursive atom supported"
        delta_name = f"@delta_{name}"
        sub = rewrite_atom(rule_without_star(rule), name, delta_name)

        delta_keys, delta_ann = keys, ann
        zero = float(np.asarray(sr.zero))
        add = {"min_plus": np.minimum, "max_min": np.maximum}[sr.name]
        max_rounds = int(rule.recursion.value) if \
            rule.recursion.kind == "iterations" else 1 << 30

        rounds = 0
        while len(delta_keys) and rounds < max_rounds:
            rounds += 1
            self.backend.stats["recursion.host_rounds"] += 1
            self.backend.stats["recursion.host_trie_rebuilds"] += 1
            self.catalog.add(delta_name, Trie.build(
                delta_name, keyvars, [delta_keys.astype(np.int32)],
                annotation=delta_ann))
            res = self._eval_rule(sub, materialize=False)
            if not res.num_rows or not res.vars:
                break
            cand_keys = np.asarray(res.columns[sub.head.keyvars[0]],
                                   dtype=np.int64)
            cand_ann = np.asarray(res.annotation, dtype=np.float64)
            # merge candidates into (keys, ann)
            all_keys = np.concatenate([keys, cand_keys])
            all_ann = np.concatenate([ann, cand_ann])
            uniq, inv = np.unique(all_keys, return_inverse=True)
            merged = np.full(len(uniq), zero, dtype=np.float64)
            if sr.name == "min_plus":
                np.minimum.at(merged, inv, all_ann)
            else:
                np.maximum.at(merged, inv, all_ann)
            old = np.full(len(uniq), zero, dtype=np.float64)
            pos = np.searchsorted(uniq, keys)
            old[pos] = ann
            improved = merged != old
            delta_keys = uniq[improved]
            delta_ann = merged[improved]
            keys, ann = uniq, merged
            self.backend.stats["recursion.host_trie_rebuilds"] += 1
            t = Trie.build(name, keyvars, [keys.astype(np.int32)],
                           annotation=ann)
            self.catalog.add(name, t)
        if delta_name in self.catalog.tries:
            del self.catalog.tries[delta_name]
        return QueryResult(keyvars, {keyvars[0]: keys.astype(np.int32)}, ann)


def _est_error(bags: List[dict]) -> dict:
    """Optimizer scorecard: geometric-mean q-error (max(est,act)/min, >=1)
    of the per-bag cardinality estimates against the recorded actuals."""
    qs = []
    for bag in bags:
        actual = bag.get("actual_rows")
        if actual is None:
            continue
        est = max(float(bag["est_rows"]), 1.0)
        act = max(float(actual), 1.0)
        qs.append(max(est, act) / min(est, act))
    if not qs:
        return {"n_bags": 0, "geo_mean_q": None}
    return {"n_bags": len(qs),
            "geo_mean_q": float(np.exp(np.mean(np.log(qs))))}


def _expr_scalar_names(e) -> set:
    """Scalar-relation names referenced by an annotation expression."""
    if e is None or isinstance(e, (Num, AggRef)):
        return set()
    if isinstance(e, ScalarRef):
        return {e.name}
    return _expr_scalar_names(e.lhs) | _expr_scalar_names(e.rhs)


def rule_without_star(rule: Rule) -> Rule:
    return dataclasses.replace(rule, recursion=None)


def rewrite_atom(rule: Rule, old: str, new: str) -> Rule:
    body = tuple(dataclasses.replace(a, rel=new) if a.rel == old else a
                 for a in rule.body)
    return dataclasses.replace(rule, body=body)
