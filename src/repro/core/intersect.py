"""Set-intersection operators (paper Section 4.2, Appendix B.2).

EmptyHeaded's profiling showed >95% of WCOJ runtime is set intersection, so
this module is the execution engine's hot path. Three intersection kinds are
implemented, mirroring the paper:

  * ``uint \\cap uint``   — vectorized binary-search intersection. On CPU-SIMD
    the paper switches SIMDShuffling <-> SIMDGalloping at a 32:1 cardinality
    ratio (Algorithm 2). The TPU VPU has no cross-lane shuffle, so the
    galloping side is adapted as a *lockstep branch-free binary search* of the
    smaller set into the larger (cost ∝ |smaller| * log|larger| — satisfies
    the **min property** of Section 2.1, preserving worst-case optimality).
  * ``bitset \\cap bitset`` — intersect block offsets (as uint sets), then AND
    the matched 2^k-bit blocks and popcount. The AND+popcount inner loop is
    the Pallas kernel in ``repro.kernels.bitset_intersect``.
  * ``uint \\cap bitset``  — probe each uint element into the bitset blocks;
    result is stored as uint ("at most as dense as the sparser set").

Pure-numpy twins (`*_np`) serve as oracles for tests and for the Pallas
kernels' ``ref.py`` modules.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Ratio at which Algorithm 2 switches to the min-property search algorithm.
GALLOP_RATIO = 32


# ----------------------------------------------------------------- popcount
def popcount_u32(x):
    """Branch-free popcount over uint32 lanes (TPU has no popcnt op)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def popcount_u32_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(np.int32)


# ------------------------------------------------- branch-free segment search
@partial(jax.jit, static_argnames=("iters",))
def segment_searchsorted(values, lo, hi, queries, iters: int = 34):
    """For each i: insertion index of queries[i] in sorted values[lo[i]:hi[i]].

    Branch-free lockstep binary search: all lanes run the same log-step loop
    (the TPU adaptation of SIMDGalloping). Returns (pos, found) where ``pos``
    is the insertion point (absolute index into ``values``) and ``found`` says
    values[pos] == query (within the segment).
    """
    values = jnp.asarray(values)
    size = values.shape[0]
    idx_dtype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    lo = jnp.asarray(lo).astype(idx_dtype)
    hi0 = jnp.asarray(hi).astype(idx_dtype)
    q = jnp.asarray(queries)

    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) >> 1
        v = values[jnp.clip(mid, 0, size - 1)]
        open_ = lo_ < hi_
        right = v < q
        new_lo = jnp.where(open_ & right, mid + 1, lo_)
        new_hi = jnp.where(open_ & (~right), mid, hi_)
        return new_lo, new_hi

    lo_f, _ = jax.lax.fori_loop(0, iters, body, (lo, hi0))
    in_range = lo_f < hi0
    at = jnp.clip(lo_f, 0, size - 1)
    found = in_range & (values[at] == q)
    return lo_f, found


def segment_searchsorted_np(values, lo, hi, queries):
    """Numpy oracle for segment_searchsorted (loop over queries)."""
    pos = np.empty(len(queries), dtype=np.int64)
    found = np.zeros(len(queries), dtype=bool)
    for i, (l, h, q) in enumerate(zip(lo, hi, queries)):
        p = l + np.searchsorted(values[l:h], q)
        pos[i] = p
        found[i] = p < h and values[p] == q
    return pos, found


# --------------------------------------------------------- uint ∩ uint pairs
def _expand_smaller(offsets: np.ndarray, neighbors: np.ndarray,
                    u: np.ndarray, v: np.ndarray):
    """Expansion step: for each pair (u_i, v_i) pick the smaller endpoint set
    (min property) and flatten its elements, remembering the pair id and the
    search segment of the larger set."""
    deg = np.diff(offsets)
    du, dv = deg[u], deg[v]
    swap = du > dv
    small = np.where(swap, v, u)
    large = np.where(swap, u, v)
    cnt = deg[small]
    pair_id = np.repeat(np.arange(len(u), dtype=np.int64), cnt)
    # element indices within each small set
    starts = offsets[small]
    base = np.repeat(starts, cnt)
    local = np.arange(len(pair_id), dtype=np.int64)
    seg_start = np.repeat(np.concatenate([[0], np.cumsum(cnt)])[:-1], cnt)
    elem_idx = base + (local - seg_start)
    q = neighbors[elem_idx]
    lo = offsets[large][pair_id]
    hi = offsets[large + 1][pair_id]
    return pair_id, elem_idx, q, lo, hi


def intersect_count_uint(offsets: np.ndarray, neighbors: np.ndarray,
                         u: np.ndarray, v: np.ndarray,
                         chunk: int = 1 << 22) -> np.ndarray:
    """|N(u_i) ∩ N(v_i)| for each pair, CSR inputs; hybrid search algorithm.

    Host-side expansion (data-dependent sizes) + device lockstep search.
    Processes in chunks to bound memory (sum of min-degrees can be large).
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    out = np.zeros(len(u), dtype=np.int64)
    if len(u) == 0:
        return out
    values_dev = jnp.asarray(neighbors)
    pair_id, _, q, lo, hi = _expand_smaller(offsets, neighbors, u, v)
    for s in range(0, len(pair_id), chunk):
        e = min(s + chunk, len(pair_id))
        _, found = segment_searchsorted(values_dev, lo[s:e], hi[s:e], q[s:e])
        found = np.asarray(found)
        np.add.at(out, pair_id[s:e], found.astype(np.int64))
    return out


def intersect_pairs_uint(offsets: np.ndarray, neighbors: np.ndarray,
                         u: np.ndarray, v: np.ndarray):
    """Materializing variant: returns (pair_id, value, pos_u, pos_v) for every
    element of N(u_i) ∩ N(v_i). Positions are absolute indices into
    ``neighbors`` for descent into deeper trie levels."""
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    if len(u) == 0:
        z = np.zeros(0, np.int64)
        return z, np.zeros(0, np.int32), z, z
    deg = np.diff(offsets)
    swap = deg[u] > deg[v]
    pair_id, elem_idx, q, lo, hi = _expand_smaller(offsets, neighbors, u, v)
    pos, found = segment_searchsorted(jnp.asarray(neighbors), lo, hi, q)
    found = np.asarray(found)
    pos = np.asarray(pos)
    keep = found
    pair_id = pair_id[keep]
    vals = q[keep]
    small_pos = elem_idx[keep]
    large_pos = pos[keep]
    sw = swap[pair_id]
    pos_u = np.where(sw, large_pos, small_pos)
    pos_v = np.where(sw, small_pos, large_pos)
    return pair_id, vals, pos_u, pos_v


def intersect_count_uint_np(offsets, neighbors, u, v):
    """Numpy oracle (np.intersect1d per pair)."""
    out = np.zeros(len(u), dtype=np.int64)
    for i, (a, b) in enumerate(zip(u, v)):
        na = neighbors[offsets[a]:offsets[a + 1]]
        nb = neighbors[offsets[b]:offsets[b + 1]]
        out[i] = len(np.intersect1d(na, nb, assume_unique=True))
    return out


# -------------------------------------------------------------- blocked bitset
@dataclasses.dataclass
class BlockedBitset:
    """Paper Figure 6: a set is (offsets, bitvector-blocks, indices).

    ``block_ids`` play the role of the paper's offsets o_1..o_n (stored as a
    uint set, intersected with the uint algorithm); ``words`` are the
    bitvector blocks b_1..b_n; ``index`` mirrors the paper's i_1..i_n
    (cumulative cardinality before each block — used to address associated
    values / next-trie-level pointers).
    """

    block_bits: int
    set_ids: np.ndarray     # [S] original ids in this cohort, sorted
    offsets: np.ndarray     # [S+1] CSR over blocks
    block_ids: np.ndarray   # [B] int32 block numbers, sorted per set
    words: np.ndarray       # [B, block_bits//32] uint32
    index: np.ndarray       # [B] int64 cumulative cardinality before block
    slot_of: np.ndarray     # [n_ids] int32 -> slot in set_ids, or -1

    @property
    def words_per_block(self) -> int:
        return self.block_bits // 32

    def nbytes(self) -> int:
        return (self.block_ids.nbytes + self.words.nbytes + self.index.nbytes
                + self.offsets.nbytes + self.set_ids.nbytes)


def build_blocked_bitset(offsets: np.ndarray, neighbors: np.ndarray,
                         ids: np.ndarray, n_total: int,
                         block_bits: int = 256) -> BlockedBitset:
    """Render the neighbor sets of ``ids`` into the blocked-bitset layout."""
    ids = np.asarray(ids, dtype=np.int64)
    wpb = block_bits // 32
    deg = np.diff(offsets)
    cnt = deg[ids] if len(ids) else np.zeros(0, np.int64)
    set_idx = np.repeat(np.arange(len(ids), dtype=np.int64), cnt)
    starts = offsets[ids] if len(ids) else np.zeros(0, np.int64)
    base = np.repeat(starts, cnt)
    local = np.arange(len(set_idx), dtype=np.int64)
    seg_start = np.repeat(np.concatenate([[0], np.cumsum(cnt)])[:-1], cnt)
    elems = neighbors[base + (local - seg_start)].astype(np.int64)

    blk = elems // block_bits
    bit = elems % block_bits
    key = set_idx * ((n_total // block_bits) + 2) + blk
    uniq_key, block_of_elem = np.unique(key, return_inverse=True)
    n_blocks = len(uniq_key)
    words = np.zeros((n_blocks, wpb), dtype=np.uint32)
    w_idx = bit // 32
    mask = (np.uint32(1) << (bit % 32).astype(np.uint32)).astype(np.uint32)
    np.bitwise_or.at(words, (block_of_elem, w_idx), mask)

    blk_set = (uniq_key // ((n_total // block_bits) + 2)).astype(np.int64)
    blk_id = (uniq_key % ((n_total // block_bits) + 2)).astype(np.int32)
    counts = np.bincount(blk_set, minlength=len(ids))
    off = np.zeros(len(ids) + 1, dtype=np.int64)
    np.cumsum(counts, out=off[1:])
    # cumulative cardinality per block within each set
    card = popcount_u32_np(words).sum(axis=1).astype(np.int64)
    cum = np.cumsum(card) - card
    seg_base = np.repeat(cum[off[:-1]], counts) if n_blocks else cum
    index = cum - seg_base

    slot_of = np.full(n_total, -1, dtype=np.int32)
    slot_of[ids] = np.arange(len(ids), dtype=np.int32)
    return BlockedBitset(block_bits, ids, off, blk_id, words, index, slot_of)


def bitset_intersect_count(bs: BlockedBitset, a_slots: np.ndarray,
                           b_slots: np.ndarray,
                           word_and_popcount=None) -> np.ndarray:
    """|S_a ∩ S_b| for slot pairs, both sets in the bitset cohort.

    Step 1 intersects the block-id lists with the uint machinery (the paper:
    "we pack the offsets contiguously, which allows us to regard the offsets
    as a uint layout"). Step 2 ANDs matched blocks and popcounts — that inner
    op is pluggable so the Pallas kernel can be injected.
    """
    pair_id, _, pos_a, pos_b = intersect_pairs_uint(
        bs.offsets, bs.block_ids, np.asarray(a_slots, np.int64),
        np.asarray(b_slots, np.int64))
    if word_and_popcount is None:
        word_and_popcount = _word_and_popcount_jnp
    if len(pair_id) == 0:
        return np.zeros(len(a_slots), dtype=np.int64)
    per_block = np.asarray(word_and_popcount(bs.words, pos_a, pos_b))
    out = np.zeros(len(a_slots), dtype=np.int64)
    np.add.at(out, pair_id, per_block.astype(np.int64))
    return out


@jax.jit
def _word_and_popcount_jnp(words, pos_a, pos_b):
    wa = words[pos_a]
    wb = words[pos_b]
    return popcount_u32(wa & wb).sum(axis=1)


def bitset_intersect_materialize(bs: BlockedBitset, a_slots: np.ndarray,
                                 b_slots: np.ndarray):
    """Materializing bitset∩bitset: every element of S_a ∩ S_b plus its
    RANK (position) within each endpoint's sorted set.

    Step 1 intersects the block-id lists with the uint machinery (as in
    :func:`bitset_intersect_count`); step 2 ANDs the matched blocks and
    extracts the set bits; ranks come from the paper's per-block ``index``
    (Figure 6 i_k: cumulative cardinality before the block) plus a
    popcount of the endpoint's own word bits below the element — which is
    exactly what the index field exists for ("used to address associated
    values / next-trie-level pointers").

    Returns ``(pair_id, values, rank_a, rank_b)``, pair-major with values
    ascending within each pair (the canonical expansion order of the
    search path).
    """
    a_slots = np.asarray(a_slots, np.int64)
    b_slots = np.asarray(b_slots, np.int64)
    pair_id, _blk, pos_a, pos_b = intersect_pairs_uint(
        bs.offsets, bs.block_ids, a_slots, b_slots)
    z = np.zeros(0, np.int64)
    if len(pair_id) == 0:
        return z, np.zeros(0, np.int32), z, z
    wa = bs.words[pos_a]                      # [B', wpb] uint32
    wb = bs.words[pos_b]
    wand = wa & wb
    # extract set bits of each AND-ed block: little-endian unpack keeps
    # (block, bit-position) row-major, so matches come out
    # block-ascending then value-ascending; uint8 unpack avoids the 32x
    # uint32 broadcast blow-up on large dense frontiers
    flat = np.unpackbits(wand.view(np.uint8), axis=1, bitorder="little")
    blk_row, bitpos = np.nonzero(flat)
    word_idx = bitpos >> 5
    bit_idx = bitpos & 31
    vals = (bs.block_ids[pos_a[blk_row]].astype(np.int64) * bs.block_bits
            + bitpos)
    below = (np.uint32(1) << bit_idx.astype(np.uint32)) - np.uint32(1)

    def rank(words, pos):
        per_word = popcount_u32_np(words)             # [B', wpb]
        cum = np.cumsum(per_word, axis=1) - per_word  # exclusive per word
        return (bs.index[pos[blk_row]]
                + cum[blk_row, word_idx]
                + popcount_u32_np(words[blk_row, word_idx] & below))

    return (pair_id[blk_row], vals.astype(np.int32),
            rank(wa, pos_a).astype(np.int64),
            rank(wb, pos_b).astype(np.int64))


def uint_bitset_intersect_count(offsets, neighbors, u: np.ndarray,
                                bs: BlockedBitset, b_slots: np.ndarray) -> np.ndarray:
    """uint ∩ bitset (Section 4.2): probe each uint element into the bitset.

    Masks the low bits of each element to get its block id, searches the
    block-id (uint) list, then tests the bit. Min property holds with a
    constant set by the block size."""
    u = np.asarray(u, dtype=np.int64)
    b_slots = np.asarray(b_slots, dtype=np.int64)
    deg = np.diff(offsets)
    cnt = deg[u]
    pair_id = np.repeat(np.arange(len(u), dtype=np.int64), cnt)
    starts = offsets[u]
    base = np.repeat(starts, cnt)
    local = np.arange(len(pair_id), dtype=np.int64)
    seg_start = np.repeat(np.concatenate([[0], np.cumsum(cnt)])[:-1], cnt)
    elems = neighbors[base + (local - seg_start)].astype(np.int64)

    blk = (elems // bs.block_bits).astype(np.int32)
    lo = bs.offsets[b_slots][pair_id]
    hi = bs.offsets[b_slots + 1][pair_id]
    pos, found = segment_searchsorted(jnp.asarray(bs.block_ids), lo, hi, blk)
    pos = np.asarray(pos); found = np.asarray(found)
    bit = elems % bs.block_bits
    w = bs.words[np.clip(pos, 0, len(bs.block_ids) - 1), bit // 32]
    hit = found & (((w >> (bit % 32).astype(np.uint32)) & 1).astype(bool))
    out = np.zeros(len(u), dtype=np.int64)
    np.add.at(out, pair_id, hit.astype(np.int64))
    return out
