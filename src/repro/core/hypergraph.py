"""Query hypergraphs (paper Section 2.1).

"There is a direct correspondence between a query and its hypergraph: a
vertex for each attribute and a hyperedge for each relation."
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.datalog import Atom, Rule, Var


@dataclasses.dataclass(frozen=True)
class HyperEdge:
    idx: int                 # position of the atom in the rule body
    rel: str                 # relation name
    vars: FrozenSet[str]

    def __repr__(self):
        return f"{self.rel}[{self.idx}]({','.join(sorted(self.vars))})"


@dataclasses.dataclass
class Hypergraph:
    vertices: Tuple[str, ...]
    edges: Tuple[HyperEdge, ...]

    @staticmethod
    def from_rule(rule: Rule) -> "Hypergraph":
        verts: List[str] = []
        edges: List[HyperEdge] = []
        for i, atom in enumerate(rule.body):
            vs = frozenset(atom.vars)
            for v in atom.vars:
                if v not in verts:
                    verts.append(v)
            edges.append(HyperEdge(i, atom.rel, vs))
        return Hypergraph(tuple(verts), tuple(edges))

    def edge_vars(self, edge_idxs: Sequence[int]) -> FrozenSet[str]:
        out: set = set()
        for i in edge_idxs:
            out |= self.edges[i].vars
        return frozenset(out)

    def connected_components(self, edge_idxs: FrozenSet[int],
                             separator: FrozenSet[str]) -> List[FrozenSet[int]]:
        """Components of the sub-hypergraph on ``edge_idxs`` where two edges
        are adjacent iff they share a variable NOT in ``separator``."""
        remaining = set(edge_idxs)
        comps: List[FrozenSet[int]] = []
        while remaining:
            seed = remaining.pop()
            comp = {seed}
            frontier_vars = set(self.edges[seed].vars) - set(separator)
            changed = True
            while changed:
                changed = False
                for e in list(remaining):
                    if set(self.edges[e].vars) & frontier_vars:
                        comp.add(e)
                        remaining.discard(e)
                        frontier_vars |= set(self.edges[e].vars) - set(separator)
                        changed = True
            comps.append(frozenset(comp))
        return comps

    def is_connected(self) -> bool:
        if not self.edges:
            return True
        comps = self.connected_components(
            frozenset(range(len(self.edges))), frozenset())
        return len(comps) == 1
