"""Statistics catalog: sampled data profiles that drive the physical planner.

EmptyHeaded's claim is that the *compiler* closes the gap to hand-tuned
engines — but the seed planner made every physical choice from static
heuristics: a fixed ``SIMD_REGISTER_BITS`` density threshold for the
Algorithm-3 layout decision and no cardinality model at all.  This module
collects cheap, sampled statistics per trie level —

  * cardinality (level size, number of parent segments),
  * fanout (mean/max children per parent, i.e. degree for level 1),
  * skew (max/mean fanout ratio),
  * density (sampled per-segment ``range / |S|``, Algorithm 3's quantity),

and derives from them

  * per-level **extension fanout estimates** feeding the plan IR's
    ``est_rows`` annotations (``core.plan_ir``), and
  * a **data-driven Algorithm-3 threshold**: the bitset layout is chosen
    when ``range/|S| < threshold`` where the threshold sits at the
    estimated break-even between blocked AND+popcount (cost ``range /
    block_bits`` word ops) and per-element probing (cost ``|S| * log2(d)``
    comparisons), instead of the paper's fixed 256-bit register width.

Statistics are cached on the trie object itself (the codebase idiom for
derived per-trie indexes, cf. ``Trie._hybrid_stores``), so repeated
queries and recursion rounds over the same relation pay the profiling
cost once.  Index/statistics build time is excluded from query timing,
as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

# Baseline block width of the blocked-bitset layout (the paper's AVX
# register width); the data-driven threshold scales it by the estimated
# per-element probe cost. Mirrors layouts.SIMD_REGISTER_BITS without
# importing layouts (which imports this module).
BASE_BLOCK_BITS = 256
MAX_THRESHOLD_BITS = 4096  # one TPU VREG row of int32 lanes
SAMPLE_SEGMENTS = 512


@dataclasses.dataclass(frozen=True)
class LevelStats:
    """Profile of one trie level (a CSR of values grouped by parent)."""

    size: int                 # number of values at this level
    n_parents: int            # number of parent segments
    mean_fanout: float        # mean values per parent segment
    max_fanout: int           # largest segment
    skew: float               # max_fanout / mean_fanout (>= 1)
    mean_inv_density: float   # sampled mean of range/|S| per segment
    value_range: int          # max - min + 1 over the whole level


@dataclasses.dataclass(frozen=True)
class TrieStats:
    """Per-level profiles of one trie."""

    name: str
    arity: int
    num_tuples: int
    levels: Tuple[LevelStats, ...]

    def candidates_at(self, depth: int) -> float:
        """Expected candidate-set size when an atom extends at ``depth``:
        the whole first level at depth 0, one parent segment after."""
        if depth >= len(self.levels):
            return 1.0
        if depth == 0:
            return float(max(1, self.levels[0].size))
        return max(self.levels[depth].mean_fanout, 1e-6)

    def universe_at(self, depth: int) -> float:
        """Domain size estimate for selectivity at ``depth`` (the value
        range of the level — dictionary-encoded ids are dense-ish)."""
        if depth >= len(self.levels):
            return 1.0
        return float(max(1, self.levels[depth].value_range))


def _level_stats(values: np.ndarray, offsets: np.ndarray,
                 sample: int = SAMPLE_SEGMENTS) -> LevelStats:
    size = int(len(values))
    n_parents = int(len(offsets) - 1)
    deg = np.diff(offsets)
    if size == 0 or n_parents == 0:
        return LevelStats(size, n_parents, 0.0, 0, 1.0, float("inf"), 0)
    mean_fanout = float(deg.mean())
    max_fanout = int(deg.max())
    skew = float(max_fanout / mean_fanout) if mean_fanout > 0 else 1.0
    # Sampled per-segment inverse density (Algorithm 3's range/|S|):
    # evenly-spaced non-empty segments, min/max read straight off the
    # sorted values.
    nz = np.flatnonzero(deg > 0)
    if len(nz) > sample:
        nz = nz[np.linspace(0, len(nz) - 1, sample).astype(np.int64)]
    lo = values[offsets[nz]]
    hi = values[offsets[nz + 1] - 1]
    inv = (hi.astype(np.int64) - lo.astype(np.int64) + 1) / deg[nz]
    mean_inv_density = float(inv.mean()) if len(inv) else float("inf")
    value_range = int(values.max()) - int(values.min()) + 1
    return LevelStats(size, n_parents, mean_fanout, max_fanout, skew,
                      mean_inv_density, value_range)


def collect_trie_stats(trie, sample: int = SAMPLE_SEGMENTS) -> TrieStats:
    """Profile every level of ``trie``; cached on the trie object."""
    token = tuple(id(lv.values) for lv in trie.levels)
    cached = getattr(trie, "_trie_stats", None)
    if cached is not None and cached[0] == token:
        return cached[1]
    levels = tuple(_level_stats(lv.values, lv.offsets, sample)
                   for lv in trie.levels)
    stats = TrieStats(trie.name, trie.arity, trie.num_tuples, levels)
    trie._trie_stats = (token, stats)
    return stats


def layout_threshold(stats: TrieStats,
                     block_bits: int = BASE_BLOCK_BITS) -> float:
    """Data-driven Algorithm-3 threshold for the trie's set level.

    Break-even model: for a set S with range r and the probe side holding
    d elements, the bitset path costs ~``r / block_bits`` blocked word
    ops while the uint path costs ~``d * log2(d_max)`` branch-free
    searches — so bitset wins when ``r/|S| < block_bits * log2(d_max)``.
    The skew term widens the window further on skewed degree
    distributions, where the search cost is dominated by probes into hub
    sets.  Clamped to [block_bits, MAX_THRESHOLD_BITS] so the decision
    never regresses below the paper's constant.
    """
    ls = stats.levels[-1]
    if ls.size == 0:
        return float(block_bits)
    probe_cost = math.log2(2.0 + ls.mean_fanout)
    skew_bonus = 1.0 + math.log2(1.0 + ls.skew) / 8.0
    thr = block_bits * probe_cost * skew_bonus
    return float(min(max(thr, block_bits), MAX_THRESHOLD_BITS))


def layout_threshold_for(trie, block_bits: int = BASE_BLOCK_BITS) -> float:
    """Convenience entry point used by ``layouts.engine_store_for`` when
    no plan-IR annotation supplies a threshold."""
    return layout_threshold(collect_trie_stats(trie), block_bits)


class StatisticsCatalog:
    """Engine-lifetime facade over the per-trie profiles.

    One instance lives per :class:`~repro.core.engine.Engine`; the plan-IR
    builder pulls all cardinality/fanout/layout inputs through it so every
    physical decision is attributable to a recorded statistic.
    """

    def __init__(self, sample: int = SAMPLE_SEGMENTS,
                 block_bits: int = BASE_BLOCK_BITS):
        self.sample = sample
        self.block_bits = block_bits

    def stats_for(self, trie) -> TrieStats:
        return collect_trie_stats(trie, self.sample)

    def threshold_for(self, trie) -> float:
        return layout_threshold(self.stats_for(trie), self.block_bits)

    # ------------------------------------------------------- estimation
    def extension_estimate(self, cons: list, universe_hint: Optional[float]
                           = None) -> float:
        """Estimated per-frontier-row fanout of one attribute extension.

        ``cons`` lists ``(TrieStats | None, depth, est_rows)`` for every
        constraining input — physical atoms carry their profiled stats,
        child-bag inputs carry ``None`` stats plus the child's estimated
        rows (treated as a uniform relation).  Independence model: the
        smallest candidate set seeds (the min property), every other
        input keeps a candidate with probability ``|C_other| / U``.
        """
        cands = []
        universes = [universe_hint] if universe_hint else []
        for stats, depth, est_rows in cons:
            if stats is not None:
                cands.append(stats.candidates_at(depth))
                universes.append(stats.universe_at(depth))
            else:
                # child-bag pseudo relation: uniform per-level fanout
                cands.append(max(1.0, float(est_rows)) ** 0.5)
        if not cands:
            return 1.0
        universe = max(u for u in universes) if universes else max(cands)
        universe = max(universe, 1.0)
        cands.sort()
        est = cands[0]
        for c in cands[1:]:
            est *= min(1.0, c / universe)
        return max(est, 1e-9)
