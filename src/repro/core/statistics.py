"""Statistics catalog: sampled data profiles that drive the physical planner.

EmptyHeaded's claim is that the *compiler* closes the gap to hand-tuned
engines — but the seed planner made every physical choice from static
heuristics: a fixed ``SIMD_REGISTER_BITS`` density threshold for the
Algorithm-3 layout decision and no cardinality model at all.  This module
collects cheap, sampled statistics per trie level —

  * cardinality (level size, number of parent segments),
  * fanout (mean/max children per parent, i.e. degree for level 1),
  * skew (max/mean fanout ratio),
  * density (sampled per-segment ``range / |S|``, Algorithm 3's quantity),

and derives from them

  * per-level **extension fanout estimates** feeding the plan IR's
    ``est_rows`` annotations (``core.plan_ir``), and
  * a **data-driven Algorithm-3 threshold**: the bitset layout is chosen
    when ``range/|S| < threshold`` where the threshold sits at the
    estimated break-even between blocked AND+popcount (cost ``range /
    block_bits`` word ops) and per-element probing (cost ``|S| * log2(d)``
    comparisons), instead of the paper's fixed 256-bit register width.

Statistics are cached on the trie object itself (the codebase idiom for
derived per-trie indexes, cf. ``Trie._hybrid_stores``), so repeated
queries and recursion rounds over the same relation pay the profiling
cost once.  Index/statistics build time is excluded from query timing,
as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

# Baseline block width of the blocked-bitset layout (the paper's AVX
# register width); the data-driven threshold scales it by the estimated
# per-element probe cost. Mirrors layouts.SIMD_REGISTER_BITS without
# importing layouts (which imports this module).
BASE_BLOCK_BITS = 256
MAX_THRESHOLD_BITS = 4096  # one TPU VREG row of int32 lanes
SAMPLE_SEGMENTS = 512


@dataclasses.dataclass(frozen=True)
class LevelStats:
    """Profile of one trie level (a CSR of values grouped by parent)."""

    size: int                 # number of values at this level
    n_parents: int            # number of parent segments
    mean_fanout: float        # mean values per parent segment
    max_fanout: int           # largest segment
    skew: float               # max_fanout / mean_fanout (>= 1)
    mean_inv_density: float   # sampled mean of range/|S| per segment
    value_range: int          # max - min + 1 over the whole level
    # Evenly-spaced subsample of the per-segment range/|S| values (<= 64
    # entries) — lets the cost model estimate the Algorithm-3 dense-cohort
    # fraction at ANY threshold, not just the mean.
    inv_density_sample: Tuple[float, ...] = ()


@dataclasses.dataclass(frozen=True)
class TrieStats:
    """Per-level profiles of one trie."""

    name: str
    arity: int
    num_tuples: int
    levels: Tuple[LevelStats, ...]

    def candidates_at(self, depth: int) -> float:
        """Expected candidate-set size when an atom extends at ``depth``:
        the whole first level at depth 0, one parent segment after."""
        if depth >= len(self.levels):
            return 1.0
        if depth == 0:
            return float(max(1, self.levels[0].size))
        return max(self.levels[depth].mean_fanout, 1e-6)

    def universe_at(self, depth: int) -> float:
        """Domain size estimate for selectivity at ``depth`` (the value
        range of the level — dictionary-encoded ids are dense-ish)."""
        if depth >= len(self.levels):
            return 1.0
        return float(max(1, self.levels[depth].value_range))


def _level_stats(values: np.ndarray, offsets: np.ndarray,
                 sample: int = SAMPLE_SEGMENTS) -> LevelStats:
    size = int(len(values))
    n_parents = int(len(offsets) - 1)
    deg = np.diff(offsets)
    if size == 0 or n_parents == 0:
        return LevelStats(size, n_parents, 0.0, 0, 1.0, float("inf"), 0)
    mean_fanout = float(deg.mean())
    max_fanout = int(deg.max())
    skew = float(max_fanout / mean_fanout) if mean_fanout > 0 else 1.0
    # Sampled per-segment inverse density (Algorithm 3's range/|S|):
    # evenly-spaced non-empty segments, min/max read straight off the
    # sorted values.
    nz = np.flatnonzero(deg > 0)
    if len(nz) > sample:
        nz = nz[np.linspace(0, len(nz) - 1, sample).astype(np.int64)]
    lo = values[offsets[nz]]
    hi = values[offsets[nz + 1] - 1]
    inv = (hi.astype(np.int64) - lo.astype(np.int64) + 1) / deg[nz]
    mean_inv_density = float(inv.mean()) if len(inv) else float("inf")
    if len(inv) > 64:
        inv_sample = inv[np.linspace(0, len(inv) - 1, 64).astype(np.int64)]
    else:
        inv_sample = inv
    value_range = int(values.max()) - int(values.min()) + 1
    return LevelStats(size, n_parents, mean_fanout, max_fanout, skew,
                      mean_inv_density, value_range,
                      tuple(np.round(inv_sample, 4).tolist()))


def collect_trie_stats(trie, sample: int = SAMPLE_SEGMENTS) -> TrieStats:
    """Profile every level of ``trie``; cached on the trie object."""
    token = tuple(id(lv.values) for lv in trie.levels)
    cached = getattr(trie, "_trie_stats", None)
    if cached is not None and cached[0] == token:
        return cached[1]
    levels = tuple(_level_stats(lv.values, lv.offsets, sample)
                   for lv in trie.levels)
    stats = TrieStats(trie.name, trie.arity, trie.num_tuples, levels)
    trie._trie_stats = (token, stats)
    return stats


def layout_threshold(stats: TrieStats,
                     block_bits: int = BASE_BLOCK_BITS) -> float:
    """Data-driven Algorithm-3 threshold for the trie's set level.

    Break-even model: for a set S with range r and the probe side holding
    d elements, the bitset path costs ~``r / block_bits`` blocked word
    ops while the uint path costs ~``d * log2(d_max)`` branch-free
    searches — so bitset wins when ``r/|S| < block_bits * log2(d_max)``.
    The skew term widens the window further on skewed degree
    distributions, where the search cost is dominated by probes into hub
    sets.  Clamped to [block_bits, MAX_THRESHOLD_BITS] so the decision
    never regresses below the paper's constant.
    """
    ls = stats.levels[-1]
    if ls.size == 0:
        return float(block_bits)
    probe_cost = math.log2(2.0 + ls.mean_fanout)
    skew_bonus = 1.0 + math.log2(1.0 + ls.skew) / 8.0
    thr = block_bits * probe_cost * skew_bonus
    return float(min(max(thr, block_bits), MAX_THRESHOLD_BITS))


def layout_threshold_for(trie, block_bits: int = BASE_BLOCK_BITS) -> float:
    """Convenience entry point used by ``layouts.engine_store_for`` when
    no plan-IR annotation supplies a threshold."""
    return layout_threshold(collect_trie_stats(trie), block_bits)


class StatisticsCatalog:
    """Engine-lifetime facade over the per-trie profiles.

    One instance lives per :class:`~repro.core.engine.Engine`; the plan-IR
    builder pulls all cardinality/fanout/layout inputs through it so every
    physical decision is attributable to a recorded statistic.
    """

    def __init__(self, sample: int = SAMPLE_SEGMENTS,
                 block_bits: int = BASE_BLOCK_BITS):
        self.sample = sample
        self.block_bits = block_bits

    def stats_for(self, trie) -> TrieStats:
        return collect_trie_stats(trie, self.sample)

    def threshold_for(self, trie) -> float:
        return layout_threshold(self.stats_for(trie), self.block_bits)

    # ------------------------------------------------------- estimation
    def extension_profile(self, cons: list,
                          universe_hint: Optional[float] = None):
        """Candidate-set profile of one attribute extension:
        ``(fanout, min_cand, max_cand, universe)``.

        ``cons`` lists ``(TrieStats | None, depth, est_rows)`` — or
        4-tuples ``(..., arity)`` for child-bag inputs — for every
        constraining input: physical atoms carry their profiled stats;
        child-bag pseudo relations are modelled as ``est_rows`` uniform
        tuples over the co-constraining atoms' value universe ``U``
        (level-0 distinct values ``min(rows, U)``, deeper fanout
        ``rows / U^depth``; without a universe, ``rows^(1/arity)`` per
        level).  Independence model: the smallest candidate set seeds
        (the min property), every other input keeps a candidate with
        probability ``|C_other| / U``.
        """
        atom_cands = []
        child_cons = []
        universes = [universe_hint] if universe_hint else []
        for con in cons:
            stats, depth, est_rows = con[0], con[1], con[2]
            arity = con[3] if len(con) > 3 else 2
            if stats is not None:
                atom_cands.append(stats.candidates_at(depth))
                universes.append(stats.universe_at(depth))
            else:
                child_cons.append((depth, max(1.0, float(est_rows)),
                                   max(1, int(arity))))
        universe = max(universes) if universes else None
        cands = list(atom_cands)
        for depth, rows, arity in child_cons:
            if universe is None:
                cands.append(rows ** (1.0 / arity))
            elif depth == 0:
                cands.append(min(rows, universe))
            else:
                cands.append(max(1.0, rows / universe ** depth))
        if not cands:
            return 1.0, 1.0, 1.0, 1.0
        if universe is None:
            universe = max(cands)
        universe = max(universe, 1.0)
        cands.sort()
        est = cands[0]
        for c in cands[1:]:
            est *= min(1.0, c / universe)
        return max(est, 1e-9), cands[0], cands[-1], universe

    def extension_estimate(self, cons: list, universe_hint: Optional[float]
                           = None) -> float:
        """Estimated per-frontier-row fanout of one attribute extension
        (the fanout component of :meth:`extension_profile`)."""
        return self.extension_profile(cons, universe_hint)[0]


# ------------------------------------------------------------- cost model
# Relative per-element op weights of the plan-search cost model
# (``plan_ir`` sums these into per-operator ``cost`` fields). The unit is
# "one vectorized element touch"; what matters for plan choice is the
# RATIO between layout cohorts — the blocked-bitset AND+popcount fold
# touches words (many set elements per op), the uint kernel touches
# elements, and the lockstep binary search pays a log factor per probe.
COST_PROBE = 0.25        # one branch-free binary-search probe, per log step
COST_BITSET_WORD = 0.04  # blocked AND+popcount, per 32-bit word
COST_UINT_PROBE = 0.5    # uint-kernel membership test, per element
COST_SORT = 1.0          # sort-based group-by (np.unique), per element-log
COST_REDUCE = 0.25       # segment reduce, per element
COST_COUNT_ONLY = 0.05   # single-atom fold: (hi - lo), per frontier row
COST_ALLOC = 0.002       # static frontier-buffer slot (zero/scatter traffic)

# ------------------------------------------------- pipeline buffer sizing
# The zero-sync extension pipeline (core.backend.DeviceBackend) fills a
# STATIC-shaped frontier buffer per extension; its capacity is decided
# here, once, from the statistics the plan IR already computed.
CAP_HEADROOM = 2.0           # slack over est_rows before the cross clamp
PIPELINE_MAX_BUFFER = 1 << 22  # rows; beyond this the pipeline disengages
PIPELINE_MIN_BUCKET = 8      # smallest frontier-buffer capacity bucket
DEFAULT_MORSEL = 256
MORSEL_CHUNK_SHIFT = 5       # fill loops run at most 2**5 = 32 chunks/buffer

# ---------------------------------------------- sideways bitset filtering
# The counting pass intersects a probe atom's bitset BLOCK directory with
# the candidate envelope when the plan IR expects its set level to be
# dominated by the Algorithm-3 dense cohort: pruning before expansion
# only pays where most probed rows actually own a bitset.
SIDEWAYS_DENSITY_MIN = 0.5
# plan-search credit for a sideways-annotated extension: the counting
# pass prunes rows (and snaps the envelope to populated blocks) before
# the expansion is sized, so the modelled expansion work shrinks.
SIDEWAYS_COST_CREDIT = 0.85


def default_morsel(est_peak_rows: float) -> int:
    """Stats-chosen morsel (fill-chunk) size: scale with the estimated
    peak frontier so tiny queries don't pay 4k-row chunks while large
    frontiers amortize the per-chunk loop overhead.  Power of two in
    [64, 2048]; ``REPRO_MORSEL_SIZE`` overrides at run time."""
    if not math.isfinite(est_peak_rows) or est_peak_rows <= 0:
        return DEFAULT_MORSEL
    target = max(64.0, min(2048.0, est_peak_rows / 8.0))
    return 1 << max(6, math.ceil(math.log2(target)))


def frontier_capacity(est_cap: Optional[float], cross_bound: int,
                      morsel: int,
                      max_buffer: int = PIPELINE_MAX_BUFFER) -> int:
    """Static buffer capacity for one pipelined extension.

    ``est_cap`` is the plan IR's stats-informed allocation target (the
    AGM-capped ``est_rows`` with :data:`CAP_HEADROOM` slack); it is
    clamped to ``cross_bound`` — the TRUE cross-product bound of the
    extension, computed exactly from the live tries — so a wildly
    inflated estimate can never oversize the buffer beyond what the data
    could produce, and to ``max_buffer``.  Degenerate estimates (missing,
    NaN, infinite or negative — i.e. statistics were absent when the plan
    was built) raise instead of silently sizing a wrong buffer: an
    undersized buffer would be caught by the overflow flag, but a
    garbage-sized one is a planner bug we want loud.

    The result is bucketed to a power of two (floor
    :data:`PIPELINE_MIN_BUCKET`) so the jitted step retraces on a small
    set of bucketed shapes.  The slack over the estimate scales WITH the
    estimate (half of it, at least 4 rows): sizing slack off the morsel
    made an est≈1 extension balloon to a full morsel-sized buffer, a
    256x over-allocation that the fill loop then zeroed and scattered
    every step (the fill morsel is clamped to the capacity downstream,
    so a small bucket never starves the chunk loop).  All arithmetic is
    Python-int: a pathological ``cross_bound`` (e.g. a dense trie
    squared) cannot overflow into a negative numpy capacity.
    """
    if morsel <= 0:
        raise ValueError(f"morsel size must be positive, got {morsel}")
    if cross_bound < 0:
        raise ValueError(f"negative cross-product bound {cross_bound}")
    if est_cap is None or not math.isfinite(est_cap) or est_cap < 0:
        raise ValueError(
            "frontier-buffer sizing needs a finite statistics-informed "
            f"estimate; got {est_cap!r} (statistics missing or degenerate "
            "when the physical plan was built)")
    cross = min(int(cross_bound), 1 << 62)
    slack = max(4, int(est_cap) >> 1)
    cap = min(int(est_cap) + slack, cross, int(max_buffer))
    cap = max(cap, 1)
    # bucket: power of two, so repeated queries over similar
    # cardinalities reuse the compiled step
    bucket = PIPELINE_MIN_BUCKET
    while bucket < cap:
        bucket <<= 1
    return bucket


def max_batch(cap_rows: int,
              max_buffer: int = PIPELINE_MAX_BUFFER) -> int:
    """How many same-shape query instances one vmapped bag launch may
    carry: the batched pipeline allocates every frontier buffer B times
    (leading batch axis), so B is sized to keep the LARGEST per-query
    buffer within the same total-row budget the single-query pipeline
    enforces.  Bigger batches split into consecutive launches."""
    return max(1, int(max_buffer) // max(int(cap_rows), 1))


def buffer_cost(cap: float) -> float:
    """Modelled cost of one extension's static frontier buffer: every
    slot is zeroed/scattered whether or not a row lands in it, so the
    plan search (``core.plan_search``) sees over-allocation — attribute
    orders with tighter intermediate estimates win ties."""
    return max(cap, 0.0) * COST_ALLOC


def _log2(x: float) -> float:
    return math.log2(2.0 + max(0.0, x))


def dense_fraction(ls: LevelStats, threshold: float) -> float:
    """Estimated fraction of the level's sets in the Algorithm-3 dense
    (bitset) cohort at ``threshold``, from the sampled inverse densities."""
    if ls.inv_density_sample:
        below = sum(1 for d in ls.inv_density_sample if d < threshold)
        return below / len(ls.inv_density_sample)
    if ls.size == 0:
        return 0.0
    return 1.0 if ls.mean_inv_density < threshold else 0.0


def extension_cost(frontier: float, min_cand: float, max_cand: float,
                   n_cons: int) -> float:
    """Modelled work of one materializing attribute extension: expand the
    min-property seed, then probe every other input with the lockstep
    binary search."""
    expanded = max(frontier, 1.0) * max(min_cand, 1.0)
    return expanded * (1.0 + COST_PROBE * max(0, n_cons - 1)
                       * _log2(max_cand))


def fold_cost(frontier: float, min_cand: float, max_cand: float,
              n_cons: int, routing: str,
              set_stats: Optional[LevelStats],
              threshold: Optional[float],
              block_bits: int = BASE_BLOCK_BITS) -> float:
    """Modelled work of the early-aggregation terminal fold.

    ``pair_kernel`` routes cost through the layout cohorts: bitset-cohort
    pairs pay word ops (``range / 32`` per pair), uint-cohort pairs pay
    per-element probes — so on dense data the SAME fold is modelled
    cheaper than the generic search path, which is the lever that lets
    the plan search prefer orders whose folds land on kernel-friendly
    cohorts."""
    F = max(frontier, 1.0)
    if n_cons <= 1:
        return F * COST_COUNT_ONLY
    if routing == "pair_kernel" and set_stats is not None:
        thr = threshold if threshold is not None else float(block_bits)
        df = dense_fraction(set_stats, thr)
        d = max(set_stats.mean_fanout, 1.0)
        per_bitset = max(1.0, d * min(set_stats.mean_inv_density, thr)
                         / 32.0) * COST_BITSET_WORD
        per_uint = d * COST_UINT_PROBE
        per_search = d * COST_PROBE * _log2(d)
        # mixed (uint x bitset) pairs probe element-wise; weight the three
        # cohort combinations by the dense fraction.
        per_pair = (df * df * per_bitset
                    + 2.0 * df * (1.0 - df) * per_uint
                    + (1.0 - df) * (1.0 - df) * min(per_uint, per_search))
        return F * per_pair
    # generic fold: materialize the expansion locally, then segment-reduce
    expanded = F * max(min_cand, 1.0)
    return (extension_cost(frontier, min_cand, max_cand, n_cons)
            + expanded * COST_REDUCE)


def projection_cost(rows: float, has_extra_vars: bool,
                    scalar_output: bool) -> float:
    """Modelled cost of a bag's final projection: sort-based group-by when
    non-output attributes survive in the frontier, a segment reduce for
    scalar aggregates, free when the frontier already matches the
    output."""
    R = max(rows, 1.0)
    if has_extra_vars:
        return R * _log2(R) * COST_SORT
    if scalar_output:
        return R * COST_REDUCE
    return 0.0
