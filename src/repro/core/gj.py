"""Level-at-a-time (breadth-first) Generic-Join (paper Algorithm 1, adapted).

The paper's Generic-Join is a tuple-at-a-time recursion — control-flow bound
and unmappable to a TPU. The equivalent breadth-first formulation keeps the
*frontier* of partial bindings as a struct-of-arrays and performs each
attribute extension as ONE vectorized intersect-and-expand over the whole
frontier:

    for each attribute v in the global order:
        for every frontier row, intersect the candidate sets contributed by
        all relations whose next un-bound attribute is v  (min property:
        the smallest candidate set seeds the chain, the others are probed
        with branch-free binary search)
        expand the frontier by the intersection results

Early aggregation (the GHD payoff, Section 3.2): when the remaining
attributes are all aggregated away, the engine switches to a *terminal fold*
that never materializes the expansion — e.g. triangle counting folds
|N(x) ∩ N(y)| per frontier row directly.

Annotations follow Green et al. provenance semirings (`core.semiring`).

Where sets live and who intersects them is the *execution backend*'s
business (``core.backend``): this module owns the join logic only and
delegates every attribute extension and terminal-fold intersection to the
backend — ``NumpyBackend`` reproduces the host-side seed behaviour,
``DeviceBackend`` keeps trie levels device-resident and routes
intersections to the layout-cohort Pallas kernels. Construct
``GenericJoin(..., backend=...)`` to pick one explicitly; the default is
resolved from ``REPRO_ENGINE_BACKEND``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import backend as backend_mod
from repro.core import statistics as stats_mod
from repro.core.semiring import COUNT, Semiring
from repro.core.trie import Trie


@dataclasses.dataclass(eq=False)
class BoundAtom:
    """One relation occurrence in a bag, with live trie-descent state.

    ``eq=False``: atoms are identity-keyed live state. The generated
    dataclass equality deep-compared tries (numpy arrays -> ambiguous
    truth value) the moment one bag held two structurally identical
    child-bag inputs — e.g. ``R(v1,v0),S(v2,v0)`` decomposed into two
    single-atom bags both passing up ``(v0,)``."""

    trie: Trie
    vars: Tuple[str, ...]       # variables per attribute (post-selection)
    depth: int = 0              # how many attributes already bound
    # cursor: absolute positions into levels[depth-1].values per frontier row
    cursor: Optional[np.ndarray] = None

    def next_var(self) -> Optional[str]:
        return self.vars[self.depth] if self.depth < len(self.vars) else None

    def candidate_bounds(self, frontier_len: int):
        """Per-row (lo, hi) bounds of this relation's candidate set."""
        lv = self.trie.levels[self.depth]
        if self.depth == 0:
            lo = np.zeros(frontier_len, dtype=np.int64)
            hi = np.full(frontier_len, len(lv.values), dtype=np.int64)
        else:
            lo = lv.offsets[self.cursor]
            hi = lv.offsets[self.cursor + 1]
        return lv.values, lo, hi

    def annotation_at_leaf(self) -> Optional[np.ndarray]:
        return self.trie.annotation


@dataclasses.dataclass
class GJResult:
    vars: Tuple[str, ...]
    columns: Dict[str, np.ndarray]
    annotation: Optional[np.ndarray]  # semiring elements, None if no agg

    @property
    def num_rows(self) -> int:
        if not self.vars:
            return 1 if self.annotation is not None and self.annotation.ndim == 0 else (
                len(self.annotation) if self.annotation is not None else 1)
        return len(next(iter(self.columns.values())))

    def scalar(self):
        assert not self.vars
        return self.annotation


class _PipelineDriver:
    """Drives the backend's zero-sync extension pipeline for one run.

    The frontier lives on device between extensions; BoundAtom state is
    NOT mutated until the single closing sync succeeds — atom depths are
    shadowed here, so an overflow (undersized buffer) can abort with the
    join untouched and the caller re-runs on the host path.  ``finish``
    lands the device state back into the host loop's representation:
    frontier columns, atom cursors/depths, annotation, level actuals.
    """

    def __init__(self, gj: "GenericJoin", exact_caps: bool = False,
                 needed: Optional[Dict[str, int]] = None):
        self.gj = gj
        self.backend = gj.backend
        self.depth = {id(a): a.depth for a in gj.atoms}
        self.state = None
        # whole-bag fusion: eligible steps are RECORDED here and executed
        # as one traced composite (backend.run_bag) at finish() — atom
        # state is shadowed, so nothing observable happens until then.
        # REPRO_FUSED_BAG=off (or a backend without run_bag) falls back
        # to one jitted launch per attribute step.
        self.fused = bool(getattr(self.backend, "fuse_bags", False)
                          and hasattr(self.backend, "run_bag"))
        self.plans: List[Tuple] = []
        # bitset sideways filtering in the counting pass (plan-IR gated
        # per variable via BagHints.extend_sideways); the env knob pins
        # the envelope-only counting pass as the differential oracle
        self.sideways_on = backend_mod._env_on("REPRO_SIDEWAYS_BITSET",
                                               True)
        # overflow-retry mode: ignore the stats-informed targets and size
        # each buffer from the aborted attempt's counting-pass totals
        # (``needed``), or at the exact cross-product bound when no
        # measurement exists for the variable (steps whose bound exceeds
        # PIPELINE_MAX_BUFFER land instead)
        self.exact_caps = exact_caps
        self.needed = needed or {}
        # sound host-side bound on the live row count (min of the running
        # cross-product bound and each buffer capacity) — sizes the next
        # step's cross clamp and the int32 counting-overflow guard
        self.bound = 1
        h = gj.hints
        raw = os.environ.get("REPRO_MORSEL_SIZE")
        # env-pinned morsels stay exact (test/debug knob); otherwise the
        # per-step effective morsel scales with the buffer so the
        # sequential fill loop stays a bounded number of chunks
        self.morsel_pinned = bool(raw)
        if raw:
            self.morsel = max(8, int(raw))
        elif h is not None and h.morsel:
            self.morsel = int(h.morsel)
        else:
            self.morsel = stats_mod.DEFAULT_MORSEL

    def _effective_morsel(self, cap: int) -> int:
        # doubled from the base morsel (so jit specializations bucket)
        # until the chunk loop is at most 2^MORSEL_CHUNK_SHIFT long
        m = self.morsel
        if not self.morsel_pinned:
            target = cap >> stats_mod.MORSEL_CHUNK_SHIFT
            while m < target:
                m <<= 1
        # never exceed the buffer: capacities bucket to powers of two
        # with a small floor (PIPELINE_MIN_BUCKET), so a morsel larger
        # than cap would make the fill loop's chunk count round to ZERO
        # and silently drop rows.  The pow2 floor keeps cap % morsel == 0.
        m = min(m, cap)
        return 1 << (max(m, 1).bit_length() - 1)

    def _next_var(self, a: BoundAtom) -> Optional[str]:
        d = self.depth[id(a)]
        return a.vars[d] if d < len(a.vars) else None

    def _sideways(self, v: str, a: BoundAtom, d: int):
        """Bitset sideways spec ``(level0, blocked_bitset)`` for a probe
        atom of variable ``v``, or None.  Gated by the plan IR
        (``BagHints.extend_sideways`` — the statistics density gate
        decided dense cohorts dominate), and only where the runtime
        shape matches: a binary atom probing its SECOND level, whose
        layout store actually built a bitset cohort."""
        h = self.gj.hints
        if (not self.sideways_on or h is None
                or d != 1 or a.trie.arity != 2
                or (getattr(h, "extend_sideways", None) or {})
                .get(v) != "bitset"):
            return None
        store = self.backend._pair_store(a.trie,
                                         threshold=h.layout_threshold)
        if store is None or store.bitset is None:
            return None
        return (a.trie.levels[0], store.bitset)

    def try_step(self, v: str, terminal: bool) -> bool:
        """Run one attribute extension (or terminal fold) on device if
        eligible; False means the caller must land and continue on the
        host path (pair-kernel-routed steps, or un-sizable buffers)."""
        gj = self.gj
        cons = [a for a in gj.atoms if self._next_var(a) == v]
        assert cons, f"variable {v} unconstrained at its turn"
        if terminal:
            return self._terminal_step(v, cons)
        h = gj.hints
        if h is not None and len(cons) == 2:
            # mirror _extend_pair_store's runtime guards against the SHADOW
            # depths: the layout store serves binary self-join expansions
            # from host cursors, so land first when this step would route
            # there.  (terminal_routing == "pair_kernel" only matters at
            # the terminal fold, which lands unconditionally above.)
            a, b = cons
            if ((h.extend_routing or {}).get(v) == "pair_store"
                    and a.trie is b.trie and a.trie.arity == 2
                    and self.depth[id(a)] == 1 and self.depth[id(b)] == 1
                    and self.backend.has_pair_store(
                        a.trie, threshold=h.layout_threshold)):
                return False
        # ---- exact cross-product bound from the live tries (sound: the
        # expansion of one row cannot exceed the smallest constraining
        # atom's worst-case segment)
        branch = None
        infos = []
        for a in cons:
            d = self.depth[id(a)]
            lv = a.trie.levels[d]
            if lv.size == 0:
                return False        # empty candidates: host path handles
            ts = stats_mod.collect_trie_stats(a.trie).levels[d]
            b = lv.size if d == 0 else int(ts.max_fanout)
            mass = float(lv.size) if d == 0 else float(ts.mean_fanout)
            branch = b if branch is None else min(branch, b)
            infos.append((a, d, mass))
        cross = self.bound * max(branch, 0)
        if cross > backend_mod._COUNT_LIMIT:
            return False            # int32 counting pass could wrap
        # the counting pass's measured output size — from this query's
        # aborted attempt or a previous execution of the same bag shape
        # (engine-lifetime feedback) — beats any stats estimate
        est = self.needed.get(v)
        if est is None and not self.exact_caps \
                and h is not None and h.extend_caps:
            est = h.extend_caps.get(v)
        if est is None:
            # no stats-informed target (direct construction, top-down
            # join): the exact cross bound is itself a sound capacity
            if cross > stats_mod.PIPELINE_MAX_BUFFER:
                return False
            est = float(cross)
        cap_out = stats_mod.frontier_capacity(est, cross, self.morsel)
        # ---- engage: estimated min-property seed first
        infos.sort(key=lambda t: t[2])
        cons_desc = [(id(a), a.trie.levels[d], d == 0,
                      None if i == 0 else self._sideways(v, a, d))
                     for i, (a, d, _m) in enumerate(infos)]
        morsel = self._effective_morsel(cap_out)
        if self.fused:
            self.plans.append(("extend", v, cons_desc, cap_out, morsel))
        else:
            if self.state is None:
                self._begin()
            self.state = self.backend.pipeline_extend(
                self.state, v, cons_desc, cap_out, morsel)
        self.bound = min(cross, cap_out)
        sr = gj.semiring
        for a, d, _m in infos:
            self.depth[id(a)] = d + 1
            if (sr is not None and d + 1 == len(a.trie.attrs)
                    and a.trie.annotation is not None):
                if self.fused:
                    self.plans.append(("annmul", id(a), a.trie, sr))
                else:
                    self.backend.pipeline_ann_mul(self.state, sr,
                                                  a.trie, id(a))
        return True

    def _terminal_step(self, v: str, cons: List[BoundAtom]) -> bool:
        """Early-aggregate the terminal attribute on device when the
        host fold would otherwise materialize the expansion through a
        per-extension sync.  Lands (False) only for the pair-kernel
        routes — the Pallas AND+popcount / cohort-materialize paths need
        host cursors and are themselves extension-sync-free."""
        gj = self.gj
        sr = gj.semiring
        h = gj.hints
        if sr is None or not hasattr(self.backend,
                                     "pipeline_terminal_fold"):
            return False
        has_ann = any(a.trie.annotation is not None for a in cons)
        if len(cons) == 2:
            a, b = cons
            pair_shape = (a.trie is b.trie and a.trie.arity == 2
                          and self.depth[id(a)] == 1
                          and self.depth[id(b)] == 1
                          and self.backend.has_pair_store(
                              a.trie,
                              threshold=(h.layout_threshold
                                         if h else None)))
            routed_off = h is not None and h.terminal_routing == "search"
            if pair_shape and sr is COUNT and not has_ann \
                    and not routed_off:
                return False        # host pair_count kernel (no sync)
            if pair_shape and h is not None \
                    and h.terminal_routing == "pair_kernel":
                return False        # host pair materialize route
        branch = None
        infos = []
        for a in cons:
            d = self.depth[id(a)]
            lv = a.trie.levels[d]
            if lv.size == 0:
                return False
            ts = stats_mod.collect_trie_stats(a.trie).levels[d]
            b = lv.size if d == 0 else int(ts.max_fanout)
            mass = float(lv.size) if d == 0 else float(ts.mean_fanout)
            branch = b if branch is None else min(branch, b)
            infos.append((a, d, mass))
        cross = self.bound * max(branch, 0)
        if cross > backend_mod._COUNT_LIMIT:
            return False            # int32 counting pass could wrap
        infos.sort(key=lambda t: t[2])
        cons_desc = [
            (id(a), a.trie.levels[d], d == 0,
             a.trie if a.trie.annotation is not None else None)
            for a, d, _m in infos]
        # the fold never allocates an output buffer, so its morsel is
        # sized off the candidate-total bound to keep the sequential
        # chunk loop short.  Float semirings are chunk-order-sensitive:
        # partial sums across fill chunks re-associate the reduction and
        # shift the last ulp vs the host fold's single segment reduce —
        # those fold in ONE chunk (bitwise-identical order) or land when
        # the candidate bound exceeds the buffer ceiling.
        if np.issubdtype(np.dtype(sr.dtype), np.floating):
            if cross > stats_mod.PIPELINE_MAX_BUFFER:
                return False
            morsel = 1 << max(3, (int(cross) - 1).bit_length())
        else:
            morsel = self._effective_morsel(
                min(cross, stats_mod.PIPELINE_MAX_BUFFER))
        if self.fused:
            self.plans.append(("fold", v, cons_desc, sr, morsel))
        else:
            if self.state is None:
                self._begin()
            self.state = self.backend.pipeline_terminal_fold(
                self.state, v, cons_desc, sr, morsel)
        return True

    def _begin(self) -> None:
        gj = self.gj
        cursors0 = {id(a): a.cursor for a in gj.atoms
                    if a.cursor is not None}
        ann0 = (np.asarray(gj.semiring.lift(1))
                if gj.semiring is not None else None)
        self.state = self.backend.pipeline_begin(cursors0, ann0)

    def finish(self):
        """Land: one closing sync, then write the fetched state back into
        the host representation.  Raises PipelineOverflow (before any
        mutation) when a buffer was undersized."""
        gj = self.gj
        if self.fused and self.plans:
            # execute the recorded chain now, as ONE traced composite;
            # atoms were never mutated (depths are shadowed), so their
            # cursors still describe the pre-bag frontier
            cursors0 = {id(a): a.cursor for a in gj.atoms
                        if a.cursor is not None}
            ann0 = (np.asarray(gj.semiring.lift(1))
                    if gj.semiring is not None else None)
            self.state = self.backend.run_bag(cursors0, ann0,
                                              self.plans)
            self.plans = []
        if self.state is None:
            ann = (np.asarray(gj.semiring.lift(1))
                   if gj.semiring is not None else None)
            return {}, ann, 1
        (count, overflow, cols, cursors, ann,
         levels, needed) = self.backend.pipeline_land(self.state)
        if overflow:
            raise backend_mod.PipelineOverflow(
                f"frontier buffer overflow landing {gj.var_order}",
                needed=needed)
        n = count
        frontier = {k: np.asarray(c)[:n] for k, c in cols.items()}
        for a in gj.atoms:
            k = id(a)
            if k in cursors:
                a.cursor = np.asarray(cursors[k])[:n].astype(np.int64)
            a.depth = self.depth[k]
        gj.level_actuals.extend(levels)
        ann = np.asarray(ann)[:n] if ann is not None else None
        return frontier, ann, n


class GenericJoin:
    """Vectorized worst-case-optimal join over one GHD bag."""

    def __init__(self, atoms: Sequence[Tuple[Trie, Sequence[str]]],
                 var_order: Sequence[str],
                 output_vars: Sequence[str],
                 semiring: Optional[Semiring] = None,
                 selections: Optional[Dict[int, Dict[int, int]]] = None,
                 backend=None,
                 hints=None):
        """
        atoms: (trie, vars) pairs; trie attr order must equal the global order
          restricted to its vars (callers re-index via Trie.reorder).
        var_order: bag-local global attribute order.
        output_vars: χ(t) — retained attributes (prefix of var_order is NOT
          required; non-retained attrs are folded with the semiring or
          deduped away).
        semiring: fold algebra for projected-away attributes; None = set
          semantics (dedup).
        selections: atom_idx -> {attr_pos: constant} equality selections.
        backend: ExecBackend carrying out extensions/intersections; None
          resolves the process default (REPRO_ENGINE_BACKEND).
        hints: plan_ir.BagHints — physical annotations decided by the plan
          IR (statistics-driven Algorithm-3 layout threshold, terminal-fold
          routing). None keeps the backend defaults.
        """
        self.backend = (backend if backend is not None
                        else backend_mod.default_backend())
        self.var_order = tuple(var_order)
        self.output_vars = tuple(output_vars)
        self.semiring = semiring
        self.hints = hints
        # per-extension actual frontier sizes [(var, rows)], written by
        # run(); both lowerings forward these through their metrics dicts
        # into Engine.plan_metadata()'s per-step actual_rows
        self.level_actuals: List[Tuple[str, int]] = []
        self.atoms: List[BoundAtom] = []
        selections = selections or {}
        for i, (trie, vars_) in enumerate(atoms):
            sel = selections.get(i, {})
            self.atoms.append(self._prebind(trie, tuple(vars_), sel))
        for a in self.atoms:
            # check induced-order consistency on live (unselected) variables
            pos = [self.var_order.index(v) for v in a.vars[a.depth:]]
            assert pos == sorted(pos), (
                f"trie order {a.vars} inconsistent with global {self.var_order}")

    @staticmethod
    def _prebind(trie: Trie, vars_: Tuple[str, ...], sel: Dict[int, int]) -> BoundAtom:
        """Apply equality selections by descending the trie at constants.

        Constants must be a prefix of the attribute order (the compiler
        reorders tries so selections lead). Produces an atom whose cursor is
        pinned at the selected subtree (or an empty relation)."""
        if not sel:
            return BoundAtom(trie, vars_)
        assert sorted(sel.keys()) == list(range(len(sel))), \
            "selections must be on a prefix of the trie order"
        depth = 0
        cursor = None  # scalar position during prebind
        for pos in range(len(sel)):
            lv = trie.levels[pos]
            if pos == 0:
                lo, hi = 0, len(lv.values)
            else:
                lo, hi = int(lv.offsets[cursor]), int(lv.offsets[cursor + 1])
            c = sel[pos]
            p = lo + int(np.searchsorted(lv.values[lo:hi], c))
            if p >= hi or lv.values[p] != c:
                # empty selection: an empty trie over the live suffix, so the
                # first live variable's extension yields an empty frontier.
                live = vars_[len(sel):]
                k = max(1, len(live))
                empty = Trie.build(trie.name, trie.attrs[len(sel):] or ("_",),
                                   [np.zeros(0, np.int32)] * k)
                return BoundAtom(empty, live or ("_",), depth=0, cursor=None)
            cursor = p
            depth += 1
        # vars_ keeps one name per trie attribute; selected positions carry
        # "$sel<i>" placeholders injected by the compiler, never in var_order.
        return BoundAtom(trie, vars_, depth=depth,
                         cursor=np.array([cursor], dtype=np.int64))

    # ------------------------------------------------------------------ run
    def run(self) -> GJResult:
        """Execute the join.  On the DeviceBackend with the zero-sync
        pipeline enabled, attribute extensions run device-resident with
        ONE closing sync; an undersized buffer (stats under-estimate)
        aborts before any state mutation and retries device-resident
        with count-informed capacities, using the per-extension-sync
        host path only as the last resort."""
        if (getattr(self.backend, "pipeline_enabled", False)
                and hasattr(self.backend, "pipeline_extend")):
            # overflow-retry loop: an aborted attempt's closing sync
            # carries the counting pass's exact per-variable totals, so
            # the retry re-sizes each buffer from measured truth instead
            # of the (often wildly loose) cross-product bound.  A step
            # AFTER an overflowed one counted over a truncated frontier,
            # so its total is only a lower bound — but every retry's
            # counts are taken over fuller frontiers, so the measurements
            # grow monotonically and the loop converges device-resident
            # in at most one attempt per variable.
            # engine-lifetime cap feedback: a previous execution of this
            # same bag shape that overflowed recorded its measured
            # totals on the backend — seed from them so repeated queries
            # size their buffers right the FIRST time.  Stale entries
            # (relation reloaded under the same name) self-correct:
            # under-sized measurements re-overflow into this same loop,
            # over-sized ones are clamped by the live cross bound.
            fb_key = (self.var_order,
                      tuple((a.trie.name, tuple(a.vars))
                            for a in self.atoms))
            feedback = getattr(self.backend, "cap_feedback", None)
            needed: Dict[str, int] = {}
            if feedback is not None:
                needed.update(feedback.get(fb_key, {}))
            measured = False
            for attempt in range(len(self.var_order) + 1):
                try:
                    res = self._run(pipelined=True,
                                    exact_caps=attempt > 0,
                                    needed=needed or None)
                    if measured and feedback is not None:
                        feedback[fb_key] = dict(needed)
                    return res
                except backend_mod.PipelineOverflow as ovf:
                    self.backend.stats["pipeline.retries"] += 1
                    self.level_actuals = []
                    grew = False
                    for v, t in ovf.needed.items():
                        if t > needed.get(v, 0):
                            needed[v] = t
                            grew = True
                            measured = True
                    if not grew:  # pragma: no cover — measurement stuck
                        break
        return self._run(pipelined=False)

    def _run(self, pipelined: bool = False, exact_caps: bool = False,
             needed: Optional[Dict[str, int]] = None) -> GJResult:
        sr = self.semiring
        F = 1
        frontier: Dict[str, np.ndarray] = {}
        ann = sr.lift(1) if sr is not None else None
        ann = np.asarray(ann) if ann is not None else None
        atoms = self.atoms
        # broadcast pre-bound cursors to frontier length 1
        for a in atoms:
            if a.cursor is not None and len(a.cursor) != F:
                a.cursor = np.broadcast_to(a.cursor, (F,)).copy()

        pipe = (_PipelineDriver(self, exact_caps=exact_caps,
                                needed=needed)
                if pipelined else None)
        out_set = set(self.output_vars)
        for vi, v in enumerate(self.var_order):
            remaining = self.var_order[vi + 1:]
            # Early-aggregation fast path: the last attribute, not retained,
            # folds without materializing (e.g. |N(x) ∩ N(y)| for triangles).
            terminal = sr is not None and v not in out_set and not remaining
            if pipe is not None:
                if pipe.try_step(v, terminal):
                    continue
                # first host-needing step: land the device frontier (the
                # query's single closing sync) and continue below
                frontier, ann, F = pipe.finish()
                pipe = None
            cons = [a for a in atoms if a.next_var() == v]
            assert cons, f"variable {v} unconstrained at its turn"
            if terminal:
                fold, support = self._terminal_fold(cons, F)
                ann = sr.mul(ann, fold) if ann is not None else fold
                ann = np.asarray(ann)
                # rows with an EMPTY candidate intersection are NOT derived
                # (folding them to the semiring identity would leak e.g.
                # dist=inf tuples out of SSSP — caught by Table 7)
                if not support.all():
                    keep = np.flatnonzero(support)
                    frontier = {k: col[keep] for k, col in frontier.items()}
                    for a in atoms:
                        if a.cursor is not None and a not in cons:
                            a.cursor = a.cursor[keep]
                    ann = ann[keep]
                    F = len(keep)
                # frontier unchanged otherwise; v folded away
                self.level_actuals.append((v, int(F)))
                continue
            row_id, vals, pos = self._extend(cons, F)
            # rebuild frontier
            frontier = {k: col[row_id] for k, col in frontier.items()}
            frontier[v] = vals
            for a in atoms:
                if a in cons:
                    a.cursor = pos[id(a)]
                    a.depth += 1
                elif a.cursor is not None:
                    a.cursor = a.cursor[row_id]
            if ann is not None:
                ann = ann[row_id]
            # multiply in annotations of atoms that just exhausted their attrs
            if sr is not None:
                for a in cons:
                    if a.depth == len(a.trie.attrs) and a.trie.annotation is not None:
                        ann = sr.mul(ann, a.trie.annotation[a.cursor])
            F = len(vals)
            self.level_actuals.append((v, int(F)))
            if F == 0:
                # empty join: emit an empty result with all output columns
                empty_cols = {k: np.zeros(0, np.int32) for k in self.output_vars}
                empty_ann = None
                if sr is not None:
                    dt = self.backend.dtype_of(sr)
                    if self.output_vars:
                        empty_ann = np.zeros(0, dt)
                    else:
                        empty_ann = np.asarray(sr.zero, dtype=dt)
                return GJResult(self.output_vars, empty_cols, empty_ann)

        if pipe is not None:
            # every attribute extended on device: land once, project below
            frontier, ann, F = pipe.finish()
            pipe = None

        return self._finalize(frontier, ann, F)

    def _finalize(self, frontier: Dict[str, np.ndarray], ann,
                  F: int) -> GJResult:
        """Project a landed frontier to the output variables (group-by
        with the semiring fold, or dedup, where non-retained columns
        survived).  Touches no atom state, so ``run_batched`` reuses the
        template join's instance for every batch element."""
        out_set = set(self.output_vars)
        cols = {k: frontier[k] for k in self.output_vars if k in frontier}
        extra = [k for k in frontier if k not in out_set]
        if not extra and len(cols) == len(self.output_vars):
            return GJResult(self.output_vars, cols,
                            np.asarray(ann) if ann is not None else None)
        # group-by output vars, folding ann (or dedup)
        return self._project(cols, ann, F)

    # ------------------------------------------------------------ internals
    def _extend(self, cons: List[BoundAtom], F: int):
        """Intersect candidates of ``cons`` per frontier row; materialize.

        When the plan IR routed this extension to the layout store
        (``BagHints.extend_routing``), the binary self-join expansion is
        served cohort-routed by ``HybridSetStore.intersect_materialize``
        (bitset extraction for dense pairs). Otherwise: gathers each
        atom's per-row candidate bounds, orders by total candidate mass
        (the min-property seed first) and hands the whole extension to
        the backend — which expands the seed and probes every other atom
        (NumpyBackend: one search per atom; DeviceBackend: one fused
        device call for all atoms)."""
        routed = self._extend_pair_store(cons, F)
        if routed is not None:
            return routed
        infos = []
        for a in cons:
            values, lo, hi = a.candidate_bounds(F)
            infos.append((a, values, lo, hi, int((hi - lo).sum())))
        infos.sort(key=lambda t: t[4])
        return self.backend.extend(infos, F)

    def _extend_pair_store(self, cons: List[BoundAtom], F: int):
        """Layout-store fast path for a materializing binary self-join
        extension — applies only where the plan IR said so (hint), with
        the same runtime guards as the terminal-fold pair path.  Two
        hints route here: ``extend_routing`` for retained attributes, and
        ``terminal_routing == "pair_kernel"`` for the materialize inside
        an ANNOTATED terminal fold (which cannot take the count kernels
        but still profits from the cohort-routed expansion)."""
        h = self.hints
        if h is None or len(cons) != 2:
            return None
        a, b = cons
        routed = ((h.extend_routing or {}).get(a.next_var()) == "pair_store"
                  or h.terminal_routing == "pair_kernel")
        if not routed:
            return None
        thr = h.layout_threshold
        if (a.trie is not b.trie or a.trie.arity != 2
                or a.depth != 1 or b.depth != 1
                or a.cursor is None or b.cursor is None
                or not self.backend.has_pair_store(a.trie, threshold=thr)):
            return None
        u = a.trie.levels[0].values[a.cursor].astype(np.int64)
        v = b.trie.levels[0].values[b.cursor].astype(np.int64)
        out = self.backend.pair_materialize(a.trie, u, v, threshold=thr)
        if out is None:
            return None
        row_id, vals, pos_u, pos_v = out
        return row_id, np.asarray(vals, dtype=np.int32), \
            {id(a): pos_u, id(b): pos_v}

    def _terminal_fold(self, cons: List[BoundAtom], F: int):
        """Fold the last attribute without materializing the expansion.

        COUNT with no annotations on 2 relations is the common case
        (triangle counting): per-row intersection count. General case:
        materialize the per-row intersection *locally*, gather annotations,
        segment-reduce back to rows.

        Returns (folded [F], support [F] bool) — support marks rows whose
        candidate intersection was non-empty (only those are derived).
        """
        sr = self.semiring
        assert sr is not None
        self.backend.stats["fold.calls"] += 1
        has_ann = any(a.trie.annotation is not None for a in cons)
        if sr is COUNT and not has_ann:
            counts = self._fold_count(cons, F)
            return counts, counts > 0
        row_id, vals, pos = self._extend(cons, F)
        contrib = sr.lift(len(vals))
        contrib = np.asarray(contrib)
        for a in cons:
            if a.trie.annotation is not None and a.depth + 1 == len(a.trie.attrs):
                contrib = np.asarray(sr.mul(contrib, a.trie.annotation[pos[id(a)]]))
        folded = np.asarray(sr.segment_reduce(contrib, row_id.astype(np.int32), F))
        support = np.bincount(row_id, minlength=F) > 0
        return folded, support

    def _fold_count(self, cons: List[BoundAtom], F: int) -> np.ndarray:
        if len(cons) == 1:
            a, (values, lo, hi) = cons[0], cons[0].candidate_bounds(F)
            return (hi - lo).astype(np.int64)
        if len(cons) == 2:
            a, b = cons
            # Binary self-join terminal (the triangle hot path): route
            # through the backend's set-level layout store — bitset cohort
            # pairs take the AND+popcount kernel, sparse pairs the uint
            # kernel or lockstep search (paper Section 4). The plan IR's
            # TerminalFold annotation decides the route and the
            # statistics-driven Algorithm-3 threshold; without hints the
            # store falls back to its own statistics profile.
            thr = self.hints.layout_threshold if self.hints else None
            routed_off = (self.hints is not None
                          and self.hints.terminal_routing == "search")
            if (not routed_off
                    and a.trie is b.trie and a.trie.arity == 2
                    and a.depth == 1 and b.depth == 1
                    and a.cursor is not None and b.cursor is not None
                    and self.backend.has_pair_store(a.trie, threshold=thr)):
                u = a.trie.levels[0].values[a.cursor].astype(np.int64)
                v = b.trie.levels[0].values[b.cursor].astype(np.int64)
                out = self.backend.pair_count(a.trie, u, v, threshold=thr)
                if out is not None:
                    return out
        # chain: materialize smallest two's intersection per row, count others
        row_id, vals, _pos = self._extend(cons, F)
        return np.bincount(row_id, minlength=F).astype(np.int64)

    def _project(self, cols: Dict[str, np.ndarray], ann, F: int) -> GJResult:
        sr = self.semiring
        if not self.output_vars:
            if sr is None:
                return GJResult((), {}, None)
            total = np.asarray(sr.segment_reduce(
                np.asarray(ann), np.zeros(F, np.int32), 1))[0]
            return GJResult((), {}, np.asarray(total))
        key_cols = [cols[k] for k in self.output_vars]
        stacked = np.stack(key_cols, axis=1)
        uniq, inv = np.unique(stacked, axis=0, return_inverse=True)
        out_cols = {k: uniq[:, i].astype(np.int32)
                    for i, k in enumerate(self.output_vars)}
        if sr is None:
            return GJResult(self.output_vars, out_cols, None)
        folded = np.asarray(sr.segment_reduce(np.asarray(ann),
                                              inv.astype(np.int32), len(uniq)))
        return GJResult(self.output_vars, out_cols, folded)


# ------------------------------------------------------------- batched entry
def batch_signature(j: GenericJoin) -> Tuple:
    """Shape key deciding which GenericJoin instances may share one
    vmapped launch: same tries (by identity), same variable layout, same
    descent depths and cursor presence per atom.  Joins built from one
    prepared plan over one catalog differ only in their pre-bound cursor
    VALUES — except degenerate bindings (constant absent from the
    relation), whose ``_prebind`` substituted a fresh empty trie
    (distinct id) and which therefore fall out of the modal group."""
    return tuple((id(a.trie), a.vars, a.depth, a.cursor is not None)
                 for a in j.atoms)


def run_batched(joins: Sequence[GenericJoin]) -> Optional[List[GJResult]]:
    """Execute B same-shape GenericJoin instances as fused *batched*
    device launches — one vmapped ``run_bag_batched`` per
    ``statistics.max_batch`` chunk, i.e. ONE launch for any batch whose
    buffers fit the device budget — returning results in submission
    order.

    Returns None when batching is ineligible (host backend, pipeline or
    fusion disabled, a step that must land on the host, or no bound
    cursor to carry the batch axis); the caller falls back to the
    sequential per-query loop.  Safe to fall back at any point: no atom
    state is mutated before the closing sync, and ``_finalize`` touches
    none after it.

    Joins outside the modal shape group (degenerate bindings) run
    through their own sequential ``run()`` — they are the rare case and
    already produce the canonical empty result.
    """
    joins = list(joins)
    if not joins:
        return []
    be = joins[0].backend
    if not (getattr(be, "pipeline_enabled", False)
            and getattr(be, "fuse_bags", False)
            and hasattr(be, "run_bag_batched")):
        return None
    if any(j.backend is not be for j in joins[1:]):
        return None
    sigs = [batch_signature(j) for j in joins]
    tally: Dict[Tuple, int] = {}
    for s in sigs:
        tally[s] = tally.get(s, 0) + 1
    modal = max(tally, key=tally.get)
    group = [i for i, s in enumerate(sigs) if s == modal]
    rest = [i for i, s in enumerate(sigs) if s != modal]
    template = joins[group[0]]
    sr = template.semiring
    out_set = set(template.output_vars)
    cursor_atoms = [j for j, a in enumerate(template.atoms)
                    if a.cursor is not None]
    if not cursor_atoms:
        # nothing binds a batch axis: B identical unparameterized queries
        # are better served by the bag cache than by a vmapped launch
        return None

    def record(exact_caps: bool, needed: Dict[str, int]):
        """Re-run the driver's recording pass (binding-independent: caps
        come from trie statistics and plan hints, never cursor values) to
        produce the fused step chain at the given capacities."""
        pipe = _PipelineDriver(template, exact_caps=exact_caps,
                               needed=needed or None)
        if not pipe.fused:
            return None
        for vi, v in enumerate(template.var_order):
            remaining = template.var_order[vi + 1:]
            terminal = (sr is not None and v not in out_set
                        and not remaining)
            if not pipe.try_step(v, terminal):
                return None
        return pipe

    fb_key = (template.var_order,
              tuple((a.trie.name, tuple(a.vars)) for a in template.atoms))
    feedback = getattr(be, "cap_feedback", None)
    needed: Dict[str, int] = {}
    if feedback is not None:
        needed.update(feedback.get(fb_key, {}))
    pipe = record(False, needed)
    if pipe is None or not pipe.plans:
        return None
    results: List[Optional[GJResult]] = [None] * len(joins)
    measured = False
    peak_cap = max((op[3] for op in pipe.plans if op[0] == "extend"),
                   default=1)
    chunk = stats_mod.max_batch(peak_cap)
    for start in range(0, len(group), chunk):
        idxs = group[start:start + chunk]
        counts = overflows = cols = ann_b = None
        for _attempt in range(len(template.var_order) + 1):
            cursors0 = {
                id(template.atoms[j]): np.stack(
                    [joins[i].atoms[j].cursor for i in idxs])
                for j in cursor_atoms}
            ann0 = np.asarray(sr.lift(1)) if sr is not None else None
            state = be.run_bag_batched(cursors0, ann0, list(pipe.plans))
            (counts, overflows, cols, _cursors, ann_b,
             step_needed) = be.pipeline_land_batched(state)
            if not overflows.any():
                break
            be.stats["pipeline.retries"] += 1
            grew = False
            for v, t in step_needed.items():
                if t > needed.get(v, 0):
                    needed[v] = t
                    grew = True
                    measured = True
            if not grew:  # pragma: no cover — measurement stuck
                return None
            pipe = record(True, needed)
            if pipe is None:
                return None
        else:  # pragma: no cover — retries exhausted
            return None
        for bi, i in enumerate(idxs):
            f = int(counts[bi])
            frontier = {k: np.asarray(c[bi])[:f] for k, c in cols.items()}
            ann_i = np.asarray(ann_b[bi])[:f] if ann_b is not None else None
            results[i] = template._finalize(frontier, ann_i, f)
    if measured and feedback is not None:
        feedback[fb_key] = dict(needed)
    for i in rest:
        results[i] = joins[i].run()
    return results
