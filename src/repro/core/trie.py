"""Trie-structured relations (paper Section 2.2).

A relation with attribute order (a_1, ..., a_k) is stored as k levels.
Level i holds the sorted, de-duplicated values of attribute a_i grouped by
their parent tuple in level i-1 — i.e. nested CSR ("tries are multi-level
data structures common in column stores and graph engines").

Values are 32-bit dictionary-encoded ids (paper: "tries currently support
sets containing 32-bit values"); the encoding itself lives in
``repro.graph.dictionary``. Annotations (Section 2.2, "Trie Annotations")
are a 1-1 mapped value array on the last level and carry semiring elements.

Storage is host-side numpy (the trie is built once per query/dataset at load
time, like EmptyHeaded's loader); the execution engine moves the flat arrays
to device as needed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class TrieLevel:
    """One trie level: CSR of values grouped by parent index."""

    values: np.ndarray   # [n_i] int32, sorted within each parent segment
    offsets: np.ndarray  # [n_{i-1} + 1] int64 — segment bounds per parent

    def __post_init__(self):
        assert self.offsets[0] == 0 and self.offsets[-1] == len(self.values)

    @property
    def size(self) -> int:
        return int(len(self.values))

    def segment(self, parent_pos: int) -> np.ndarray:
        return self.values[self.offsets[parent_pos]:self.offsets[parent_pos + 1]]

    def device_values(self, to_device, on_upload=None):
        """Device-resident copy of ``values``, uploaded once and cached.

        ``to_device`` is the backend's upload function (``jnp.asarray``),
        injected so trie storage itself stays numpy-pure. The cache keys
        on array identity, so a rebuilt level re-uploads while repeated
        queries / recursion rounds over the same relation reuse the
        resident copy. ``on_upload`` (if given) is called exactly when an
        actual upload happens — the backend's instrumentation hook.
        """
        cached = self.__dict__.get("_dev_values")
        if cached is None or cached[0] is not self.values:
            cached = (self.values, to_device(self.values))
            self._dev_values = cached
            if on_upload is not None:
                on_upload()
        return cached[1]

    def device_offsets(self, to_device, on_upload=None):
        """Device-resident copy of ``offsets`` (same identity-keyed cache
        discipline as :meth:`device_values`).  The zero-sync extension
        pipeline derives per-row candidate bounds on device
        (``lo = offsets[cursor]``), so segment offsets must be resident
        alongside the values they index."""
        cached = self.__dict__.get("_dev_offsets")
        if cached is None or cached[0] is not self.offsets:
            cached = (self.offsets, to_device(self.offsets))
            self._dev_offsets = cached
            if on_upload is not None:
                on_upload()
        return cached[1]


@dataclasses.dataclass
class Trie:
    """A k-level trie over ``attrs`` with an optional annotation column."""

    name: str
    attrs: Tuple[str, ...]
    levels: list  # list[TrieLevel]
    annotation: Optional[np.ndarray] = None  # aligned with levels[-1].values

    @property
    def arity(self) -> int:
        return len(self.attrs)

    @property
    def num_tuples(self) -> int:
        return self.levels[-1].size if self.levels else 0

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(
        name: str,
        attrs: Sequence[str],
        columns: Sequence[np.ndarray],
        annotation: Optional[np.ndarray] = None,
        dedup: bool = True,
    ) -> "Trie":
        """Build a trie from column arrays (one per attribute, equal length).

        Tuples are lexicographically sorted by (columns[0], ..., columns[-1]);
        duplicate tuples are removed (annotations of duplicates are summed is
        NOT done here — callers pre-aggregate; we keep the first).
        """
        attrs = tuple(attrs)
        k = len(attrs)
        assert k >= 1 and len(columns) == k
        n = len(columns[0])
        cols = [np.asarray(c, dtype=np.int32) for c in columns]
        for c in cols:
            assert len(c) == n

        if n == 0:
            levels = [TrieLevel(np.zeros(0, np.int32), np.zeros(1, np.int64))]
            for _ in range(k - 1):
                levels.append(TrieLevel(np.zeros(0, np.int32), np.zeros(1, np.int64)))
            return Trie(name, attrs, levels, annotation)

        # np.lexsort sorts by the LAST key first.
        order = np.lexsort(tuple(reversed(cols)))
        cols = [c[order] for c in cols]
        ann = annotation[order] if annotation is not None else None

        if dedup:
            keep = np.ones(n, dtype=bool)
            same = np.ones(n - 1, dtype=bool)
            for c in cols:
                same &= c[1:] == c[:-1]
            keep[1:] = ~same
            cols = [c[keep] for c in cols]
            if ann is not None:
                ann = ann[keep]
            n = len(cols[0])

        levels = []
        # parent_ids: for each tuple, the index of its parent node in level i-1.
        parent_ids = np.zeros(n, dtype=np.int64)
        n_parents = 1
        for i in range(k):
            # A new node at level i starts where (parent_id, value) changes.
            v = cols[i]
            if i == 0:
                newnode = np.ones(n, dtype=bool)
                newnode[1:] = v[1:] != v[:-1]
            else:
                newnode = np.ones(n, dtype=bool)
                newnode[1:] = (v[1:] != v[:-1]) | (parent_ids[1:] != parent_ids[:-1])
            node_id = np.cumsum(newnode) - 1  # id of each tuple's level-i node
            n_nodes = int(node_id[-1]) + 1
            first = np.flatnonzero(newnode)
            values = v[first].astype(np.int32)
            # offsets: count of level-i nodes per parent.
            counts = np.bincount(parent_ids[first], minlength=n_parents)
            offsets = np.zeros(n_parents + 1, dtype=np.int64)
            np.cumsum(counts, out=offsets[1:])
            levels.append(TrieLevel(values, offsets))
            parent_ids = node_id
            n_parents = n_nodes

        if ann is not None:
            ann = np.asarray(ann)
        return Trie(name, attrs, levels, ann)

    @staticmethod
    def from_edges(
        name: str,
        src: np.ndarray,
        dst: np.ndarray,
        attrs: Tuple[str, str] = ("x", "y"),
        annotation: Optional[np.ndarray] = None,
    ) -> "Trie":
        return Trie.build(name, attrs, [src, dst], annotation)

    # ------------------------------------------------------------ navigation
    def level0_values(self) -> np.ndarray:
        return self.levels[0].values

    def child_bounds(self, depth: int, parent_pos: np.ndarray):
        """Vectorized segment bounds at ``depth`` for parent positions.

        depth: level index >= 1; parent_pos indexes levels[depth-1].values.
        Returns (lo, hi) int64 arrays.
        """
        off = self.levels[depth].offsets
        return off[parent_pos], off[parent_pos + 1]

    def edge_view(self):
        """Flat ``(src, dst, annotation)`` column view of a binary trie.

        This is the fixed-shape edge stream the device-resident recursion
        loops (``core.recursion``) consume instead of rebuilding a delta
        trie per round: uploaded once, it stays valid for every round
        because seminaive/naive deltas are annotation VECTORS over the
        vertex domain, not new tries.  Cached on the trie (identity-keyed
        like :meth:`TrieLevel.device_values`), so repeated recursive
        queries over the same relation pay the expansion once.
        """
        assert self.arity == 2, "edge_view is the binary fast path"
        token = (id(self.levels[0].values), id(self.levels[1].values))
        cached = self.__dict__.get("_edge_view")
        if cached is not None and cached[0] == token:
            return cached[1]
        counts = np.diff(self.levels[1].offsets)
        src = np.repeat(self.levels[0].values.astype(np.int64), counts)
        view = (src, self.levels[1].values.astype(np.int64), self.annotation)
        self._edge_view = (token, view)
        return view

    def device_annotation(self, to_device, on_upload=None):
        """Device-resident copy of the annotation column (identity-keyed
        like :meth:`TrieLevel.device_values`); ``None`` when the trie is
        unannotated.  The extension pipeline multiplies exhausted atoms'
        annotations into the device-resident frontier annotation."""
        if self.annotation is None:
            return None
        cached = self.__dict__.get("_dev_annotation")
        if cached is None or cached[0] is not self.annotation:
            cached = (self.annotation, to_device(self.annotation))
            self._dev_annotation = cached
            if on_upload is not None:
                on_upload()
        return cached[1]

    def reorder(self, attrs: Sequence[str]) -> "Trie":
        """Re-index this trie under a different attribute order.

        Materializes tuples and rebuilds — EmptyHeaded builds one trie per
        (relation, required index order); this is the "column (index) order"
        selection of Section 2.2.
        """
        attrs = tuple(attrs)
        if attrs == self.attrs:
            return self
        assert sorted(attrs) == sorted(self.attrs), (attrs, self.attrs)
        tuples, ann = self.materialize()
        perm = [self.attrs.index(a) for a in attrs]
        cols = [tuples[:, j] for j in perm]
        return Trie.build(self.name, attrs, cols, ann)

    def materialize(self):
        """Expand back to a dense tuple matrix [N, k] (+ annotation)."""
        k = self.arity
        n = self.num_tuples
        out = np.zeros((n, k), dtype=np.int32)
        # Walk levels from the bottom: each level-(k-1) value corresponds to a
        # tuple; propagate parents upward.
        idx = np.arange(n)
        out[:, k - 1] = self.levels[k - 1].values
        parent = _parent_of(self.levels[k - 1].offsets, idx)
        for i in range(k - 2, -1, -1):
            out[:, i] = self.levels[i].values[parent]
            if i > 0:
                parent = _parent_of(self.levels[i].offsets, parent)
        return out, (self.annotation.copy() if self.annotation is not None else None)

    def nbytes(self) -> int:
        total = 0
        for lv in self.levels:
            total += lv.values.nbytes + lv.offsets.nbytes
        if self.annotation is not None:
            total += self.annotation.nbytes
        return total

    # ------------------------------------------------------- device residency
    @property
    def device_resident(self) -> bool:
        """True if ANY level array (or the annotation) currently holds a
        device-resident cached copy — the multi-tenant graph store's
        eviction accounting reads this."""
        if self.__dict__.get("_dev_annotation") is not None:
            return True
        return any(lv.__dict__.get("_dev_values") is not None
                   or lv.__dict__.get("_dev_offsets") is not None
                   for lv in self.levels)

    def evict_device(self) -> int:
        """Drop every device-resident cached copy this trie holds.

        Host arrays are untouched; the next query touching the trie
        re-uploads on demand through the identity-keyed caches
        (``upload.levels`` counts it).  Returns the number of cache
        entries dropped — the serve layer's LRU eviction
        (``serve.query.GraphStore``) calls this on the coldest tenant
        when the resident-byte budget is exceeded.
        """
        dropped = 0
        for lv in self.levels:
            for key in ("_dev_values", "_dev_offsets"):
                if lv.__dict__.pop(key, None) is not None:
                    dropped += 1
        if self.__dict__.pop("_dev_annotation", None) is not None:
            dropped += 1
        # the blocked-bitset DIRECTORY uploads (the counting pass's
        # sideways block intersection) hang off the layout stores this
        # trie caches — byte-accurate eviction must drop those too, or
        # an "evicted" tenant would keep device memory pinned
        for store in (self.__dict__.get("_hybrid_stores") or {}).values():
            bs = getattr(store, "bitset", None)
            if bs is not None and bs.__dict__.pop(
                    "_dev_sideways_cache", None) is not None:
                dropped += 1
        return dropped


def _parent_of(offsets: np.ndarray, child_idx: np.ndarray) -> np.ndarray:
    """For CSR ``offsets``, the parent id of each child index."""
    return np.searchsorted(offsets, child_idx, side="right") - 1


# --------------------------------------------------------------------- graph
@dataclasses.dataclass
class CSRGraph:
    """Binary-relation fast path: an Edge(x, y) trie flattened over the full
    dictionary-encoded node-id space [0, n) (empty rows allowed).

    ``offsets[u]:offsets[u+1]`` bounds the sorted neighbor set N(u). This is
    the layout the execution engine's vectorized operators consume.
    """

    n: int
    offsets: np.ndarray  # [n+1] int64
    neighbors: np.ndarray  # [m] int32
    annotation: Optional[np.ndarray] = None  # [m] edge annotations

    @property
    def m(self) -> int:
        return int(len(self.neighbors))

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets).astype(np.int64)

    @staticmethod
    def from_trie(t: Trie, n: Optional[int] = None) -> "CSRGraph":
        assert t.arity == 2, "CSRGraph is the binary fast path"
        srcs = t.levels[0].values
        seg = t.levels[1].offsets  # [len(srcs)+1]
        if n is None:
            hi = 0
            if len(srcs):
                hi = int(srcs.max()) + 1
            if len(t.levels[1].values):
                hi = max(hi, int(t.levels[1].values.max()) + 1)
            n = hi
        offsets = np.zeros(n + 1, dtype=np.int64)
        counts = np.zeros(n, dtype=np.int64)
        counts[srcs] = np.diff(seg)
        np.cumsum(counts, out=offsets[1:])
        return CSRGraph(n, offsets, t.levels[1].values.copy(),
                        t.annotation.copy() if t.annotation is not None else None)

    @staticmethod
    def from_edges(src, dst, n=None, annotation=None) -> "CSRGraph":
        t = Trie.from_edges("E", np.asarray(src), np.asarray(dst), annotation=annotation)
        return CSRGraph.from_trie(t, n)

    def neighbors_of(self, u: int) -> np.ndarray:
        return self.neighbors[self.offsets[u]:self.offsets[u + 1]]

    def to_trie(self, name: str = "E", attrs=("x", "y")) -> Trie:
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return Trie.build(name, attrs, [src, self.neighbors], self.annotation)
