"""Logical query compilation: rule -> hypergraph -> GHD -> physical plan.

This is the paper's query compiler (Section 3): GHDs replace relational
algebra as the plan representation; the planner decides

  * which GHD (minimum fractional hypertree width, `ghd.decompose`),
  * the global attribute order (pre-order over the GHD, Section 3.2),
  * per-bag output attributes = (shared with parent) + (query outputs in
    the bag) — everything else is folded early with the semiring
    ("Aggregations over GHDs", Section 3.2),
  * whether the top-down Yannakakis pass can be elided (Appendix A.1:
    "if all the attributes appearing in the result also appear in the
    root node"),
  * equivalent-bag sharing keys (Appendix A.1 "Eliminating Redundant
    Work": identical join pattern + identical aggregations/selections +
    identical subtrees).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import ghd as ghd_mod
from repro.core.datalog import Atom, Const, Param, Rule, Var, expr_agg
from repro.core.ghd import GHD, Bag
from repro.core.hypergraph import Hypergraph
from repro.core.semiring import AGG_TO_SEMIRING, COUNT, Semiring


@dataclasses.dataclass
class PlanAtom:
    """One body atom, normalized for execution."""

    idx: int                      # body position
    rel: str                      # relation name (pre-alias)
    vars: Tuple[str, ...]         # variable per position; "$selK" for consts
    selections: Dict[int, object]  # position -> constant (undecoded)

    @staticmethod
    def from_atom(idx: int, atom: Atom) -> "PlanAtom":
        vars_: List[str] = []
        sels: Dict[int, object] = {}
        for pos, t in enumerate(atom.terms):
            if isinstance(t, Var):
                vars_.append(t.name)
            else:
                vars_.append(f"$sel{idx}_{pos}")
                sels[pos] = t.value
        live = [v for v in vars_ if not v.startswith("$sel")]
        assert len(set(live)) == len(live), \
            f"repeated variable in one atom unsupported: {atom}"
        return PlanAtom(idx, atom.rel, tuple(vars_), sels)

    @property
    def live_vars(self) -> Tuple[str, ...]:
        return tuple(v for v in self.vars if not v.startswith("$sel"))


@dataclasses.dataclass
class BagPlan:
    """Physical plan for one GHD bag."""

    bag: Bag
    atoms: List[PlanAtom]          # relations in lambda(t)
    var_order: Tuple[str, ...]     # global order restricted to the bag
    output_vars: Tuple[str, ...]   # retained: shared-with-parent + query out
    children: List["BagPlan"]
    dedup_key: Tuple = ()          # Appendix A.1 equivalence key

    def describe(self) -> str:
        rels = ", ".join(f"{a.rel}({','.join(a.vars)})" for a in self.atoms)
        return (f"bag[{rels}] order={self.var_order} "
                f"out={self.output_vars} w={self.bag.width:.3g}")

    def subtree_rels(self) -> Tuple[str, ...]:
        """Every relation read anywhere in this bag's subtree — the set
        whose catalog versions gate engine-lifetime result reuse
        (``plan_ir.MaterializeShared.reuse_rels``)."""
        rels = {a.rel for a in self.atoms}
        for c in self.children:
            rels.update(c.subtree_rels())
        return tuple(sorted(rels))


@dataclasses.dataclass
class QueryPlan:
    rule: Rule
    hg: Hypergraph
    ghd: GHD
    order: Tuple[str, ...]         # global attribute order
    root: BagPlan
    semiring: Optional[Semiring]
    agg_arg: Optional[str]         # <<OP(arg)>> argument var ("*" = all)
    output_vars: Tuple[str, ...]
    needs_top_down: bool

    def bags_bottom_up(self) -> List[BagPlan]:
        out: List[BagPlan] = []

        def rec(b: BagPlan):
            for c in b.children:
                rec(c)
            out.append(b)

        rec(self.root)
        return out

    def pretty(self) -> str:
        lines = [f"order={self.order} out={self.output_vars} "
                 f"fhw={self.ghd.width:.3g} top_down={self.needs_top_down}"]

        def rec(b: BagPlan, d: int):
            lines.append("  " * (d + 1) + b.describe())
            for c in b.children:
                rec(c, d + 1)

        rec(self.root, 0)
        return "\n".join(lines)


def compile_rule(rule: Rule, use_ghd: bool = True,
                 ghd: Optional[GHD] = None,
                 order: Optional[Sequence[str]] = None) -> QueryPlan:
    """Compile one (non-recursive) rule body into a GHD query plan.

    ``ghd`` / ``order`` inject a candidate decomposition / global
    attribute order instead of the defaults (min-fhw ``ghd.decompose`` /
    appearance-order ``ghd.attribute_order``) — the entry point of the
    cost-based plan search (``core.plan_search``), which compiles each
    (GHD, order) candidate through this same function so candidates are
    real plans, not approximations of one.
    """
    atoms = [PlanAtom.from_atom(i, a) for i, a in enumerate(rule.body)]
    hg = ghd.hypergraph if ghd is not None else Hypergraph.from_rule(rule)
    output_vars = tuple(rule.head.keyvars)

    agg = rule.agg
    semiring = AGG_TO_SEMIRING[agg.op] if agg is not None else None
    agg_arg = agg.arg if agg is not None else None

    if ghd is not None:
        g = ghd
    elif use_ghd:
        g = ghd_mod.decompose(hg, output_vars)
    else:
        g = ghd_mod.single_bag(hg)
    if order is not None:
        order = tuple(order)
        assert set(order) == set(hg.vertices), (order, hg.vertices)
    else:
        order = ghd_mod.attribute_order(g, output_vars)

    out_set = set(output_vars)
    by_edge = {a.idx: a for a in atoms}

    def build(bag: Bag) -> BagPlan:
        children = [build(c) for c in bag.children]
        bag_atoms = [by_edge[i] for i in bag.edge_idxs]
        retained = set(bag.shared_with_parent) | (set(bag.attrs) & out_set)
        var_order = tuple(v for v in order if v in set(bag.attrs))
        bp = BagPlan(
            bag=bag,
            atoms=bag_atoms,
            var_order=var_order,
            output_vars=tuple(v for v in var_order if v in retained),
            children=children,
        )
        return bp

    root = build(g.root)
    root_attrs = set(g.root.attrs)
    needs_top_down = not out_set <= root_attrs
    if needs_top_down and semiring is None:
        # Listing query whose outputs span bags: the final acyclic join of
        # the reduced bag results (plan_ir.TopDownJoin) connects bags on
        # their shared attributes, so every bag must RETAIN the attrs it
        # shares with its children — projecting them away (the seed
        # behaviour) degenerated the final join into a cross product.
        _retain_connectors(root)
    # Dedup keys include output_vars, so assign them only after the
    # connector-retention pass above.
    def assign_keys(bp: BagPlan):
        for c in bp.children:
            assign_keys(c)
        bp.dedup_key = _dedup_key(bp, semiring)

    assign_keys(root)
    return QueryPlan(rule, hg, g, order, root, semiring, agg_arg,
                     output_vars, needs_top_down)


def parameterize(rule: Rule) -> Tuple[Rule, Tuple[object, ...]]:
    """Rewrite body selection constants into ``Param`` bind-slots.

    Returns ``(rule_p, defaults)`` where ``rule_p`` has every body
    ``Const(v)`` replaced by ``Const(Param(slot))`` and ``defaults[slot]``
    is the constant the slot replaced. Slots are assigned one per
    DISTINCT constant value in first-appearance order, so a query like
    "triangles through vertex v" — the same literal in two atoms — binds
    both occurrences with one argument. (Corollary: two occurrences of
    the same literal cannot be re-bound independently; write distinct
    literals in the template if you need distinct slots.)

    ``repr(rule_p)`` is binding-independent, which is the whole point:
    the engine's logical/search/physical caches and the backend's traced
    bag programs key on it, so re-binding reuses all of them.
    """
    slots: Dict[object, int] = {}
    body: List[Atom] = []
    for atom in rule.body:
        terms: List[object] = []
        for t in atom.terms:
            if isinstance(t, Const) and not isinstance(t.value, Param):
                if t.value not in slots:
                    slots[t.value] = len(slots)
                terms.append(Const(Param(slots[t.value])))
            else:
                terms.append(t)
        body.append(Atom(atom.rel, tuple(terms)))
    rule_p = dataclasses.replace(rule, body=tuple(body))
    defaults = tuple(sorted(slots, key=slots.get))
    return rule_p, defaults


def _retain_connectors(bp: BagPlan):
    for c in bp.children:
        _retain_connectors(c)
    connectors = set()
    for c in bp.children:
        connectors |= set(c.bag.shared_with_parent)
    if connectors - set(bp.output_vars):
        retained = set(bp.output_vars) | connectors
        bp.output_vars = tuple(v for v in bp.var_order if v in retained)


def _dedup_key(bp: BagPlan, semiring) -> Tuple:
    """Appendix A.1: two bags produce equivalent bottom-up results iff
    (1) identical join patterns on the same input relations, (2) identical
    aggregations/selections/projections, (3) identical subtrees — all
    checked on a variable-canonicalized structural key."""
    canon: Dict[str, int] = {}

    def cv(v: str) -> int:
        if v not in canon:
            canon[v] = len(canon)
        return canon[v]

    # Canonicalize in var_order so positional roles match across renamings.
    for v in bp.var_order:
        cv(v)
    # key=repr: column keys mix canonical ints with ("$", const) selection
    # markers, which Python refuses to order when two atoms share a
    # relation name — repr gives a deterministic total order
    atom_keys = tuple(sorted(
        ((a.rel,
          tuple(cv(v) if not v.startswith("$sel") else ("$", a.selections[p])
                for p, v in enumerate(a.vars)))
         for a in bp.atoms), key=repr))
    out_key = tuple(cv(v) for v in bp.output_vars)
    child_keys = tuple(sorted((c.dedup_key for c in bp.children), key=repr))
    sr_key = semiring.name if semiring is not None else None
    return (atom_keys, out_key, sr_key, child_keys)
