"""Generalized hypertree decompositions (paper Section 3.2, Definition 1).

A GHD of a query hypergraph H is a tree whose nodes ("bags") carry
``chi(t)`` (attributes) and ``lambda(t)`` (hyperedges) such that

  1. every hyperedge is contained in some bag's chi,
  2. every attribute's bag-set is connected in the tree (running
     intersection property, RIP),
  3. chi(t) is covered by lambda(t).

The *width* of a bag is the fractional edge-cover number of its
sub-hypergraph (AGM exponent); the GHD's width is the max over bags; the
optimizer picks a minimum-width GHD ("it is key that the optimizer selects
a GHD with the smallest value of w", Section 3.2) and then, as in the
paper, applies early aggregation over it.

Search strategy: queries are tiny (<= ~8 atoms), so we enumerate *set
partitions of the hyperedges* into bags (Bell(8) = 4140) and, per
partition, test whether the bags admit a join tree via the classical
maximum-spanning-tree characterization: a tree over the bags satisfies RIP
iff the max-weight spanning tree (weights = |chi_i cap chi_j|) attains
``sum_v (#bags containing v) - 1`` total weight (Tarjan & Yannakakis'
acyclicity test applied to the bag hypergraph). This enumerates exactly
the edge-partitioned GHDs, which include the minimum-fhw plans for every
query in the paper (Triangle, 4-Clique, Lollipop, Barbell, ...).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import warnings
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.agm import fractional_cover_number
from repro.core.hypergraph import Hypergraph


@dataclasses.dataclass
class Bag:
    """One GHD node: lambda(t) = edge_idxs, chi(t) = attrs."""

    edge_idxs: Tuple[int, ...]
    attrs: Tuple[str, ...]          # chi(t), ordered by the global order later
    width: float                     # AGM exponent of the bag sub-query
    children: List["Bag"] = dataclasses.field(default_factory=list)
    parent: Optional["Bag"] = None

    # Filled by the planner --------------------------------------------------
    shared_with_parent: Tuple[str, ...] = ()

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def __repr__(self):
        return (f"Bag(edges={list(self.edge_idxs)}, chi={list(self.attrs)}, "
                f"w={self.width:.3g}, kids={len(self.children)})")


@dataclasses.dataclass
class GHD:
    root: Bag
    width: float                    # fractional hypertree width of this plan
    hypergraph: Hypergraph
    # True when decompose() hit its partition budget before exhausting the
    # search space — the returned GHD is best-so-far, not proven minimal.
    search_exhausted: bool = False

    def bags(self) -> List[Bag]:
        return list(self.root.walk())

    def num_bags(self) -> int:
        return len(self.bags())

    def pretty(self, bag: Optional[Bag] = None, depth: int = 0) -> str:
        bag = bag or self.root
        rels = ",".join(f"{self.hypergraph.edges[i].rel}" for i in bag.edge_idxs)
        line = "  " * depth + f"[{rels}] chi={{{','.join(bag.attrs)}}} w={bag.width:.3g}"
        return "\n".join([line] + [self.pretty(c, depth + 1) for c in bag.children])


# --------------------------------------------------------------- partitions
def _set_partitions(items: Sequence[int]):
    """All partitions of ``items`` into non-empty groups (restricted growth)."""
    items = list(items)
    n = len(items)
    if n == 0:
        yield []
        return
    codes = [0] * n

    def rec(i: int, maxc: int):
        if i == n:
            groups: Dict[int, List[int]] = {}
            for it, c in zip(items, codes):
                groups.setdefault(c, []).append(it)
            yield [tuple(g) for g in groups.values()]
            return
        for c in range(maxc + 2):
            codes[i] = c
            yield from rec(i + 1, max(maxc, c))

    yield from rec(1, 0) if n > 0 else iter([[]])


def _mst_rip_tree(chis: List[FrozenSet[str]]):
    """Max-weight spanning tree over bags; returns (parent[], ok) where ok
    says the tree satisfies the running intersection property."""
    k = len(chis)
    if k == 1:
        return [-1], True
    in_tree = [False] * k
    parent = [-1] * k
    best = [-1] * k
    in_tree[0] = True
    best_w = [len(chis[0] & chis[j]) for j in range(k)]
    for j in range(k):
        best[j] = 0
    total = 0
    for _ in range(k - 1):
        cand, cw = -1, -1
        for j in range(k):
            if not in_tree[j] and best_w[j] > cw:
                cand, cw = j, best_w[j]
        in_tree[cand] = True
        parent[cand] = best[cand]
        total += cw
        for j in range(k):
            if not in_tree[j]:
                w = len(chis[cand] & chis[j])
                if w > best_w[j]:
                    best_w[j], best[j] = w, cand
    # RIP iff total == sum_v (count(v) - 1)
    counts: Dict[str, int] = {}
    for chi in chis:
        for v in chi:
            counts[v] = counts.get(v, 0) + 1
    target = sum(c - 1 for c in counts.values())
    return parent, total == target


# ------------------------------------------------------------------- search
def _iter_valid_partitions(hg: Hypergraph, max_partitions: int,
                           state: Dict[str, bool]):
    """Stream RIP-valid edge partitions as ``(partition, chis, parent,
    widths)``; sets ``state["truncated"]`` when the budget runs out. The
    budget counts EVERY partition visited (valid or not), exactly as the
    original best-so-far loop did. Streaming keeps ``decompose()`` at
    O(1) memory — only ``decompose_candidates`` materializes the list."""
    E = len(hg.edges)
    assert E >= 1
    width_cache: Dict[Tuple[int, ...], float] = {}

    def bag_width(group: Tuple[int, ...]) -> float:
        key = tuple(sorted(group))
        if key not in width_cache:
            width_cache[key] = fractional_cover_number(hg, key)
        return width_cache[key]

    n_seen = 0
    for partition in _set_partitions(range(E)):
        n_seen += 1
        if n_seen > max_partitions:
            state["truncated"] = True
            return
        chis = [frozenset(hg.edge_vars(g)) for g in partition]
        parent, ok = _mst_rip_tree(chis)
        if not ok:
            continue
        widths = [bag_width(g) for g in partition]
        yield partition, chis, parent, widths


def _seed_root(chis, widths, out_set):
    """The decompose() root tie-break: a bag covering the output vars
    (elides the top-down pass, Appendix A.1); among covering bags prefer
    the *narrowest* — this tends to center the tree on connector bags
    (e.g. U in Barbell), making symmetric sub-queries siblings so the
    equivalent-bag elimination of Appendix A.1 can fire."""
    cands = [(widths[i], i) for i, chi in enumerate(chis) if out_set <= chi]
    if cands:
        return min(cands)[1], True
    return 0, False


def _partition_key(partition, chis, widths, out_set):
    """Tie-breaking (paper Section 3.2 + Example 3.1 behaviour):
      1. smallest width  (the theoretical guarantee),
      2. smallest sum of bag widths (prefer splitting a wide query into
         cheap bags -> early aggregation does more work),
      3. fewest bags (cheaper Yannakakis passes),
      4. root covers the output attributes if possible (lets the planner
         elide the top-down pass, Appendix A.1).
    """
    _root, covers_out = _seed_root(chis, widths, out_set)
    return (round(max(widths), 9), round(sum(widths), 9), len(partition),
            0 if covers_out else 1)


def decompose(hg: Hypergraph,
              output_vars: Sequence[str] = (),
              max_partitions: int = 200_000) -> GHD:
    """Enumerate edge-partition GHDs; return one of minimum width
    (tie-break: `_partition_key`, root: `_seed_root`)."""
    out_set = frozenset(output_vars)
    state = {"truncated": False}
    best_key, best = None, None
    for partition, chis, parent, widths in \
            _iter_valid_partitions(hg, max_partitions, state):
        key = _partition_key(partition, chis, widths, out_set)
        if best_key is None or key < best_key:
            best_key = key
            best = (partition, chis, parent, widths,
                    _seed_root(chis, widths, out_set)[0])

    assert best is not None, "no GHD found (disconnected RIP failure?)"
    truncated = state["truncated"]
    if truncated:
        # Best-so-far is returned, but silently truncating hid plan
        # quality regressions: record it on the GHD and warn.
        warnings.warn(
            f"GHD search truncated at max_partitions={max_partitions} "
            f"({len(hg.edges)} hyperedges): returning the best "
            f"decomposition seen so far (width {best_key[0]:.3g}); plan "
            f"may be suboptimal",
            RuntimeWarning, stacklevel=2)
    partition, chis, parent, widths, root_idx = best
    g = _build_tree(hg, partition, chis, parent, widths, root_idx)
    g.search_exhausted = truncated
    return g


def decompose_candidates(hg: Hypergraph,
                         output_vars: Sequence[str] = (),
                         k: int = 4,
                         max_roots: int = 4,
                         max_partitions: int = 200_000) -> List[GHD]:
    """Candidate GHDs for the cost-based plan search.

    Emits only MINIMUM-width partitions (the paper's hard constraint:
    "it is key that the optimizer selects a GHD with the smallest value
    of w", Section 3.2) — the top ``k`` of them by the ``decompose()``
    tie-break key — and, per partition, up to ``max_roots`` rootings
    (the seed root first, then other output-covering bags by width; for
    listing queries whose outputs no bag covers, any bag may root the
    tree and the top-down pass reassembles the result).

    The FIRST returned GHD is exactly ``decompose()``'s choice, so a
    cost model that breaks ties toward earlier candidates reproduces the
    seed plan when costs tie.
    """
    out_set = frozenset(output_vars)
    state = {"truncated": False}
    valid = list(_iter_valid_partitions(hg, max_partitions, state))
    truncated = state["truncated"]
    assert valid, "no GHD found (disconnected RIP failure?)"
    keyed = sorted(
        ((_partition_key(p, chis, widths, out_set), p, chis, parent, widths)
         for p, chis, parent, widths in valid),
        key=lambda t: t[0])
    min_width = keyed[0][0][0]
    keyed = [t for t in keyed if t[0][0] == min_width][:max(1, k)]

    ghds: List[GHD] = []
    for _key, partition, chis, parent, widths in keyed:
        seed_root, covers = _seed_root(chis, widths, out_set)
        roots = [seed_root]
        if covers:
            alt = sorted((widths[i], i) for i, chi in enumerate(chis)
                         if out_set <= chi)
        else:
            alt = sorted((widths[i], i) for i in range(len(chis)))
        for _w, i in alt:
            if i not in roots and len(roots) < max(1, max_roots):
                roots.append(i)
        for r in roots:
            g = _build_tree(hg, partition, chis, parent, widths, r)
            g.search_exhausted = truncated
            ghds.append(g)
    return ghds


def _build_tree(hg, partition, chis, parent, widths, root_idx) -> GHD:
    k = len(partition)
    # Re-root the MST at root_idx.
    adj: Dict[int, List[int]] = {i: [] for i in range(k)}
    for i, p in enumerate(parent):
        if p >= 0:
            adj[i].append(p)
            adj[p].append(i)
    bags = [Bag(tuple(partition[i]),
                tuple(sorted(chis[i])),
                widths[i]) for i in range(k)]
    seen = {root_idx}
    order = [root_idx]
    head = 0
    par = {root_idx: None}
    while head < len(order):
        u = order[head]
        head += 1
        for v in adj[u]:
            if v not in seen:
                seen.add(v)
                par[v] = u
                order.append(v)
    for v in order[1:]:
        p = par[v]
        bags[v].parent = bags[p]
        bags[p].children.append(bags[v])
        bags[v].shared_with_parent = tuple(
            sorted(chis[v] & chis[p]))
    return GHD(bags[root_idx], max(widths), hg)


# ----------------------------------------------------- global attribute order
def attribute_order(ghd: GHD, output_vars: Sequence[str] = ()) -> Tuple[str, ...]:
    """Pre-order traversal over the GHD, queueing each bag's attributes
    (paper Section 3.2 "Global Attribute Ordering").

    Within a bag, attributes shared with the parent come first (they are
    already bound when the bag runs), then output attributes, then the rest
    — this keeps retained attributes early, so aggregated attributes sit at
    the deepest loop levels where the terminal fold applies.

    Ties within each group break by QUERY-APPEARANCE order (the order the
    user wrote the variables), not alphabetically: on the symmetric K4
    query the alphabetical tie-break put the 4th clique vertex 'a' first
    and cost 7x vs the appearance order (caught by the Table 8 benchmark).
    """
    out_set = set(output_vars)
    appear = {v: i for i, v in enumerate(ghd.hypergraph.vertices)}
    order: List[str] = []
    seen = set()

    def visit(bag: Bag):
        def by_appearance(vs):
            return sorted(vs, key=lambda v: appear.get(v, 1 << 30))

        shared = [v for v in bag.shared_with_parent]
        outs = by_appearance(v for v in bag.attrs
                             if v in out_set and v not in shared)
        rest = by_appearance(v for v in bag.attrs
                             if v not in out_set and v not in shared)
        for v in shared + outs + rest:
            if v not in seen:
                seen.add(v)
                order.append(v)
        for c in bag.children:
            visit(c)

    visit(ghd.root)
    return tuple(order)


def candidate_orders(ghd: GHD, output_vars: Sequence[str] = (),
                     max_group: int = 4, limit: int = 64) -> List[Tuple[str, ...]]:
    """Candidate global attribute orders compatible with ``ghd``.

    Every candidate keeps the structural invariants of
    :func:`attribute_order` — pre-order over bags, shared-with-parent
    attributes inherited from the ancestor that introduced them, output
    attributes before aggregated-away attributes within a bag (so
    terminal folds stay terminal) — but permutes WITHIN each bag's
    output group and rest group, which is exactly the degree of freedom
    the appearance-order tie-break fixes arbitrarily.

    The FIRST order returned is exactly ``attribute_order(ghd,
    output_vars)``; groups larger than ``max_group`` attributes keep
    only their appearance order (bounding the product), and at most
    ``limit`` orders are emitted overall.
    """
    out_set = set(output_vars)
    appear = {v: i for i, v in enumerate(ghd.hypergraph.vertices)}

    def by_appearance(vs):
        return sorted(vs, key=lambda v: appear.get(v, 1 << 30))

    per_bag: List[List[Tuple[str, ...]]] = []
    for bag in ghd.root.walk():
        shared = set(bag.shared_with_parent)
        outs = by_appearance(v for v in bag.attrs
                             if v in out_set and v not in shared)
        rest = by_appearance(v for v in bag.attrs
                             if v not in out_set and v not in shared)
        outs_opts = ([tuple(outs)] if not 1 < len(outs) <= max_group
                     else [tuple(p) for p in itertools.permutations(outs)])
        rest_opts = ([tuple(rest)] if not 1 < len(rest) <= max_group
                     else [tuple(p) for p in itertools.permutations(rest)])
        per_bag.append([o + r for o in outs_opts for r in rest_opts])

    orders: List[Tuple[str, ...]] = []
    for combo in itertools.islice(itertools.product(*per_bag), max(1, limit)):
        order: List[str] = []
        seen = set()
        for seq in combo:
            for v in seq:
                if v not in seen:
                    seen.add(v)
                    order.append(v)
        order = tuple(order)
        if order not in orders:
            orders.append(order)
    return orders


def single_bag(hg: Hypergraph) -> GHD:
    """The no-GHD baseline (``-GHD`` ablation): one bag with every edge —
    exactly the generic worst-case optimal algorithm with no early
    aggregation across bags (what the paper says LogicBlox ships)."""
    g = tuple(range(len(hg.edges)))
    w = fractional_cover_number(hg, g)
    bag = Bag(g, tuple(sorted(hg.edge_vars(g))), w)
    return GHD(bag, w, hg)
