"""Recursive query evaluation (paper Sections 3.1/3.3).

EmptyHeaded supports Kleene-star rules with two evaluation strategies:

  * **naive** — re-apply the rule body to the full relation each iteration
    (used when every iteration rewrites every annotation, e.g. PageRank);
    convergence = fixed iteration count or float differential.
  * **seminaive** — only propagate from tuples whose annotation changed in
    the previous iteration; selected automatically when the aggregation is
    monotone MIN/MAX (e.g. SSSP).

The shared primitive is the semiring SpMV ``y[u] = ⨁_v A(u,v) ⊗ x[v]`` — a
one-step join-aggregate `Out(x) :- Edge(x,z), X(z)`. Its jitted form is used
by the GNN substrate too; the PageRank inner loop can route through the
ELL-blocked Pallas kernel (``repro.kernels.spmv_ell``).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import MIN_PLUS, SUM_F32, Semiring
from repro.core.trie import CSRGraph


# ------------------------------------------------------------------- spmv
def csr_row_ids(csr: CSRGraph) -> np.ndarray:
    return np.repeat(np.arange(csr.n, dtype=np.int32), csr.degrees)


@partial(jax.jit, static_argnames=("sr", "n"))
def semiring_spmv(sr: Semiring, n: int, row: jnp.ndarray, col: jnp.ndarray,
                  ann: Optional[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """y[u] = ⨁_{(u,v) in E} ann(u,v) ⊗ x[v] over any semiring."""
    contrib = x[col]
    if ann is not None:
        contrib = sr.mul(ann, contrib)
    return sr.segment_reduce(contrib, row, n)


# ---------------------------------------------------------------- pagerank
def pagerank(csr: CSRGraph, iters: int = 5, damping: float = 0.85,
             spmv_fn: Optional[Callable] = None,
             backend=None) -> np.ndarray:
    """Paper Table 2 PageRank: naive recursion, fixed iteration count.

        N(;w)        :- Edge(x,y); w=<<COUNT(x)>>
        PageRank(x;y):- Edge(x,z); y=1/N.
        PageRank(x;y)*[i=5] :- Edge(x,z),PageRank(z),InvDeg(z);
                               y=0.15+0.85*<<SUM(z)>>.

    The body is a (+,*) join-aggregate = SpMV with InvDeg folded into the
    propagated value. ``spmv_fn`` lets benchmarks inject the Pallas ELL
    kernel; default is the jitted segment-sum SpMV — except when this
    fixpoint API is handed the device execution backend
    (``core.backend.DeviceBackend``), in which case the ELL kernel is
    selected automatically and the whole fixpoint stays on device inside
    one ``fori_loop``. (The datalog engine's PageRank program evaluates
    through general naive recursion and does not route here.)
    """
    n = csr.n
    row = jnp.asarray(csr_row_ids(csr))
    col = jnp.asarray(csr.neighbors)
    out_deg = np.maximum(csr.degrees, 1).astype(np.float32)
    inv_deg = jnp.asarray(1.0 / out_deg)

    x = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    base = (1.0 - damping) / n

    if spmv_fn is None and getattr(backend, "name", None) == "device":
        from repro.kernels.spmv_ell.ops import csr_to_ell, spmv_ell
        cols, vals = csr_to_ell(csr.offsets, csr.neighbors)
        cols_d, vals_d = jnp.asarray(cols), jnp.asarray(vals)
        backend.stats["spmv.ell_kernel"] += iters

        def spmv_fn(x_scaled):
            return spmv_ell(cols_d, vals_d, x_scaled)

    if spmv_fn is None:
        def spmv_fn(x_scaled):
            return semiring_spmv(SUM_F32, n, row, col, None, x_scaled)

    def body(_, x):
        return base + damping * spmv_fn(x * inv_deg)

    x = jax.lax.fori_loop(0, iters, body, x)
    return np.asarray(x)


def pagerank_np(csr: CSRGraph, iters: int = 5, damping: float = 0.85) -> np.ndarray:
    """Numpy oracle."""
    n = csr.n
    row = csr_row_ids(csr)
    col = csr.neighbors
    inv_deg = 1.0 / np.maximum(csr.degrees, 1)
    x = np.full(n, 1.0 / n, dtype=np.float64)
    for _ in range(iters):
        y = np.zeros(n, dtype=np.float64)
        np.add.at(y, row, x[col] * inv_deg[col])
        x = (1 - damping) / n + damping * y
    return x.astype(np.float32)


# -------------------------------------------------------------------- sssp
def sssp(csr: CSRGraph, source: int, weights: Optional[np.ndarray] = None,
         max_iters: Optional[int] = None) -> np.ndarray:
    """Paper Table 2 SSSP: seminaive evaluation of the (min,+) recursion.

        SSSP(x;y) :- Edge("start",x); y=1.
        SSSP(x;y)* :- Edge(w,x),SSSP(w); y=<<MIN(w)>>+1.

    Monotone MIN aggregation triggers seminaive mode: each round relaxes only
    edges out of the frontier (vertices whose distance improved last round).
    The TPU-vectorized form masks non-frontier contributions to +inf inside a
    ``lax.while_loop`` — semantically seminaive (no stale work propagates)
    while keeping fixed shapes for the device.
    """
    n = csr.n
    row = jnp.asarray(csr_row_ids(csr))  # edge source u of (u -> v)
    col = jnp.asarray(csr.neighbors)
    w = jnp.asarray(weights.astype(np.float32)) if weights is not None \
        else jnp.ones((csr.m,), jnp.float32)
    if max_iters is None:
        max_iters = n

    inf = jnp.float32(jnp.inf)
    dist0 = jnp.full((n,), inf).at[source].set(0.0)
    frontier0 = jnp.zeros((n,), jnp.bool_).at[source].set(True)

    def cond(state):
        _, frontier, it = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        dist, frontier, it = state
        # seminaive: only edges whose source is in the frontier contribute
        src_d = jnp.where(frontier[row], dist[row], inf)
        cand = MIN_PLUS.segment_reduce(src_d + w, col, n)
        new = jnp.minimum(dist, cand)
        return new, new < dist, it + 1

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, frontier0, jnp.int32(0)))
    return np.asarray(dist)


def sssp_np(csr: CSRGraph, source: int, weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Numpy seminaive oracle with true work elimination (frontier gathers)."""
    n = csr.n
    w = weights if weights is not None else np.ones(csr.m, np.float32)
    dist = np.full(n, np.inf, dtype=np.float64)
    dist[source] = 0.0
    frontier = np.array([source])
    it = 0
    while len(frontier) and it <= n:
        # gather out-edges of the frontier only (the seminaive delta)
        segs = [(csr.offsets[u], csr.offsets[u + 1]) for u in frontier]
        idx = np.concatenate([np.arange(a, b) for a, b in segs]) if segs else np.zeros(0, np.int64)
        if len(idx) == 0:
            break
        srcs = np.repeat(frontier, [b - a for a, b in segs])
        dsts = csr.neighbors[idx]
        cand = dist[srcs] + w[idx]
        order = np.argsort(dsts, kind="stable")
        dsts_s, cand_s = dsts[order], cand[order]
        first = np.ones(len(dsts_s), bool)
        first[1:] = dsts_s[1:] != dsts_s[:-1]
        seg_id = np.cumsum(first) - 1
        best = np.full(seg_id[-1] + 1 if len(seg_id) else 0, np.inf)
        np.minimum.at(best, seg_id, cand_s)
        uniq = dsts_s[first]
        improved = best < dist[uniq]
        dist[uniq[improved]] = best[improved]
        frontier = uniq[improved]
        it += 1
    return dist.astype(np.float32)


# ----------------------------------------------------- generic fixpoint API
def fixpoint(step: Callable, x0, *, iters: Optional[int] = None,
             tol: Optional[float] = None, max_iters: int = 10_000):
    """Driver matching the paper's convergence criteria: a fixed number of
    iterations (i=K) or a float differential (c=eps)."""
    if iters is not None:
        x = x0
        for _ in range(iters):
            x = step(x)
        return x
    assert tol is not None
    x = x0
    for _ in range(max_iters):
        nx = step(x)
        if float(jnp.max(jnp.abs(nx - x))) <= tol:
            return nx
        x = nx
    return x
