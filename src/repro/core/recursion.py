"""Recursive query evaluation (paper Sections 3.1/3.3).

EmptyHeaded supports Kleene-star rules with two evaluation strategies:

  * **naive** — re-apply the rule body to the full relation each iteration
    (used when every iteration rewrites every annotation, e.g. PageRank);
    convergence = fixed iteration count or float differential.
  * **seminaive** — only propagate from tuples whose annotation changed in
    the previous iteration; selected automatically when the aggregation is
    monotone MIN/MAX (e.g. SSSP).

The shared primitive is the semiring SpMV ``y[u] = ⨁_v A(u,v) ⊗ x[v]`` — a
one-step join-aggregate `Out(x) :- Edge(x,z), X(z)`. Its jitted form is used
by the GNN substrate too; the PageRank inner loop can route through the
ELL-blocked Pallas kernel (``repro.kernels.spmv_ell``).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import MIN_PLUS, SUM_F32, Semiring
from repro.core.trie import CSRGraph
from repro.kernels.common import audit_avals, host_get

# trace-level audit hook (repro.analysis.jaxpr_audit): when a list, the
# device fixpoint entry points append an abstract record — (kind, name,
# static params, operand avals) — before dispatching, so the auditor can
# retrace the exact fixpoint jaxprs without re-running queries.
AUDIT_LOG: Optional[list] = None


# ------------------------------------------------------------------- spmv
def csr_row_ids(csr: CSRGraph) -> np.ndarray:
    return np.repeat(np.arange(csr.n, dtype=np.int32), csr.degrees)


@partial(jax.jit, static_argnames=("sr", "n"))
def semiring_spmv(sr: Semiring, n: int, row: jnp.ndarray, col: jnp.ndarray,
                  ann: Optional[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """y[u] = ⨁_{(u,v) in E} ann(u,v) ⊗ x[v] over any semiring."""
    contrib = x[col]
    if ann is not None:
        contrib = sr.mul(ann, contrib)
    return sr.segment_reduce(contrib, row, n)


# ---------------------------------------------------------------- pagerank
def pagerank(csr: CSRGraph, iters: int = 5, damping: float = 0.85,
             spmv_fn: Optional[Callable] = None,
             backend=None) -> np.ndarray:
    """Paper Table 2 PageRank: naive recursion, fixed iteration count.

        N(;w)        :- Edge(x,y); w=<<COUNT(x)>>
        PageRank(x;y):- Edge(x,z); y=1/N.
        PageRank(x;y)*[i=5] :- Edge(x,z),PageRank(z),InvDeg(z);
                               y=0.15+0.85*<<SUM(z)>>.

    The body is a (+,*) join-aggregate = SpMV with InvDeg folded into the
    propagated value. ``spmv_fn`` lets benchmarks inject the Pallas ELL
    kernel; default is the jitted segment-sum SpMV — except when this
    fixpoint API is handed the device execution backend
    (``core.backend.DeviceBackend``), in which case the ELL kernel is
    selected automatically and the whole fixpoint stays on device inside
    one ``fori_loop``. (The datalog engine's PageRank program evaluates
    through general naive recursion and does not route here.)
    """
    n = csr.n
    row = jnp.asarray(csr_row_ids(csr))
    col = jnp.asarray(csr.neighbors)
    out_deg = np.maximum(csr.degrees, 1).astype(np.float32)
    inv_deg = jnp.asarray(1.0 / out_deg)

    x = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    base = (1.0 - damping) / n

    if spmv_fn is None and getattr(backend, "name", None) == "device":
        from repro.kernels.spmv_ell.ops import csr_to_ell, spmv_ell
        cols, vals = csr_to_ell(csr.offsets, csr.neighbors)
        cols_d, vals_d = jnp.asarray(cols), jnp.asarray(vals)
        backend.stats["spmv.ell_kernel"] += iters

        def spmv_fn(x_scaled):
            return spmv_ell(cols_d, vals_d, x_scaled)

    if spmv_fn is None:
        def spmv_fn(x_scaled):
            return semiring_spmv(SUM_F32, n, row, col, None, x_scaled)

    def body(_, x):
        return base + damping * spmv_fn(x * inv_deg)

    x = jax.lax.fori_loop(0, iters, body, x)
    return np.asarray(x)


def pagerank_np(csr: CSRGraph, iters: int = 5, damping: float = 0.85) -> np.ndarray:
    """Numpy oracle."""
    n = csr.n
    row = csr_row_ids(csr)
    col = csr.neighbors
    inv_deg = 1.0 / np.maximum(csr.degrees, 1)
    x = np.full(n, 1.0 / n, dtype=np.float64)
    for _ in range(iters):
        y = np.zeros(n, dtype=np.float64)
        np.add.at(y, row, x[col] * inv_deg[col])
        x = (1 - damping) / n + damping * y
    return x.astype(np.float32)


# -------------------------------------------------------------------- sssp
def sssp(csr: CSRGraph, source: int, weights: Optional[np.ndarray] = None,
         max_iters: Optional[int] = None) -> np.ndarray:
    """Paper Table 2 SSSP: seminaive evaluation of the (min,+) recursion.

        SSSP(x;y) :- Edge("start",x); y=1.
        SSSP(x;y)* :- Edge(w,x),SSSP(w); y=<<MIN(w)>>+1.

    Monotone MIN aggregation triggers seminaive mode: each round relaxes only
    edges out of the frontier (vertices whose distance improved last round).
    The TPU-vectorized form masks non-frontier contributions to +inf inside a
    ``lax.while_loop`` — semantically seminaive (no stale work propagates)
    while keeping fixed shapes for the device.
    """
    n = csr.n
    row = jnp.asarray(csr_row_ids(csr))  # edge source u of (u -> v)
    col = jnp.asarray(csr.neighbors)
    w = jnp.asarray(weights.astype(np.float32)) if weights is not None \
        else jnp.ones((csr.m,), jnp.float32)
    if max_iters is None:
        max_iters = n

    inf = jnp.float32(jnp.inf)
    dist0 = jnp.full((n,), inf).at[source].set(0.0)
    frontier0 = jnp.zeros((n,), jnp.bool_).at[source].set(True)

    def cond(state):
        _, frontier, it = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        dist, frontier, it = state
        # seminaive: only edges whose source is in the frontier contribute
        src_d = jnp.where(frontier[row], dist[row], inf)
        cand = MIN_PLUS.segment_reduce(src_d + w, col, n)
        new = jnp.minimum(dist, cand)
        return new, new < dist, it + 1

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, frontier0, jnp.int32(0)))
    return np.asarray(dist)


def sssp_np(csr: CSRGraph, source: int, weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Numpy seminaive oracle with true work elimination (frontier gathers).

    Termination: Bellman–Ford shortest paths use at most ``n - 1`` edges,
    so improvements can only occur in rounds 1..n-1 (round k finds paths
    of exactly k edges). One extra round is allowed as the detection
    pass: any improvement there implies a negative cycle reachable from
    the source, and the oracle raises instead of relaxing forever.
    """
    n = csr.n
    w = weights if weights is not None else np.ones(csr.m, np.float32)
    dist = np.full(n, np.inf, dtype=np.float64)
    dist[source] = 0.0
    frontier = np.array([source])
    it = 0
    while len(frontier):
        if it >= n:
            # the frontier is non-empty after the round-n detection pass:
            # a path with >= n edges improved some distance
            raise ValueError(
                "sssp_np: improvements after round n imply a negative "
                "cycle reachable from the source")
        it += 1
        # gather out-edges of the frontier only (the seminaive delta)
        segs = [(csr.offsets[u], csr.offsets[u + 1]) for u in frontier]
        idx = np.concatenate([np.arange(a, b) for a, b in segs]) if segs else np.zeros(0, np.int64)
        if len(idx) == 0:
            break
        srcs = np.repeat(frontier, [b - a for a, b in segs])
        dsts = csr.neighbors[idx]
        cand = dist[srcs] + w[idx]
        order = np.argsort(dsts, kind="stable")
        dsts_s, cand_s = dsts[order], cand[order]
        first = np.ones(len(dsts_s), bool)
        first[1:] = dsts_s[1:] != dsts_s[:-1]
        seg_id = np.cumsum(first) - 1
        best = np.full(seg_id[-1] + 1 if len(seg_id) else 0, np.inf)
        np.minimum.at(best, seg_id, cand_s)
        uniq = dsts_s[first]
        improved = best < dist[uniq]
        dist[uniq[improved]] = best[improved]
        frontier = uniq[improved]
    return dist.astype(np.float32)


# ------------------------------------- engine device-resident recursion
# The datalog engine's recursive rules (``Engine._seminaive`` /
# ``Engine._naive``) historically rebuilt a host delta trie and re-ran the
# whole Generic-Join pipeline every round.  When the rule body is a
# semiring SpMV — one binary atom E(h,r) or E(r,h), the recursive atom
# Rec(r), and optional unary annotated atoms A_i(r) — the entire fixpoint
# can instead run on device with fixed shapes: the frontier/delta is a
# masked vector over the vertex domain (mirroring :func:`sssp`) and every
# round is one step of a jitted ``lax.while_loop`` / ``fori_loop``.  The
# engine recognizes the shape and calls these entry points; anything else
# falls back to the host loop (the differential oracle).


class ExprFn:
    """Hashable, jit-stable wrapper around ``datalog.eval_expr`` with the
    scalar-relation environment snapshotted at construction.  Hash/eq key
    on (expr repr, scalar values) so ``jax.jit`` treats repeated rounds —
    and repeated queries over unchanged scalars — as the same static
    argument instead of recompiling."""

    def __init__(self, expr, scalars):
        from repro.core.datalog import eval_expr  # cycle-free at call time
        self._eval = eval_expr
        self.expr = expr
        self.scalars = {k: float(v) for k, v in scalars.items()}
        self._key = (repr(expr),
                     tuple(sorted(self.scalars.items())))

    def __call__(self, agg_value):
        return self._eval(self.expr, agg_value, self.scalars)

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, ExprFn) and self._key == other._key


@partial(jax.jit, static_argnames=("sr", "apply_expr", "max_rounds", "n"))
def _seminaive_device(sr: Semiring, apply_expr, max_rounds: int, n: int,
                      gather, scatter, edge_ann, state0, frontier0):
    """Whole seminaive fixpoint on device: fixed-shape masked delta.

    ``state`` is the annotation vector over the dense vertex domain
    (``sr.zero`` = "not derived"); ``frontier`` masks the vertices whose
    annotation improved last round (the seminaive delta).  One round:
    propagate frontier annotations along edges (gather → ⊗ edge
    annotation → segment-⨁ into the head vertex), apply the rule's
    annotation expression to derived candidates only, and merge with ⨁.
    Returns ``(state, rounds)``; nothing crosses the host boundary until
    the caller's single final ``device_get``.
    """
    zero = jnp.asarray(sr.zero, dtype=sr.dtype)

    def cond(s):
        _, frontier, it = s
        return jnp.logical_and(frontier.any(), it < max_rounds)

    def body(s):
        state, frontier, it = s
        src = jnp.where(frontier[gather], state[gather], zero)
        contrib = src if edge_ann is None else sr.mul(edge_ann, src)
        agg = sr.segment_reduce(contrib, scatter, n)
        derived = agg != zero
        cand = jnp.where(derived, apply_expr(agg).astype(sr.dtype), zero)
        new = sr.add(state, cand)
        return new, new != state, it + 1

    state, _, rounds = jax.lax.while_loop(
        cond, body, (state0, frontier0, jnp.int32(0)))
    return state, rounds


def seminaive_device_fixpoint(sr: Semiring, apply_expr: ExprFn,
                              gather: np.ndarray, scatter: np.ndarray,
                              edge_ann: Optional[np.ndarray], n: int,
                              keys0: np.ndarray, ann0: np.ndarray,
                              max_rounds: int):
    """Host entry point: densify the initial relation over [0, n), run the
    jitted while-loop, and sparsify the result back to (keys, ann).
    Exactly ONE host sync happens, after the loop."""
    dt = jnp.zeros((), sr.dtype).dtype
    state0 = jnp.full((n,), sr.zero, dtype=dt)
    state0 = state0.at[jnp.asarray(keys0)].set(
        jnp.asarray(ann0).astype(dt))
    frontier0 = jnp.zeros((n,), jnp.bool_).at[jnp.asarray(keys0)].set(True)
    ea = None if edge_ann is None else jnp.asarray(edge_ann).astype(dt)
    g, sc = jnp.asarray(gather), jnp.asarray(scatter)
    if AUDIT_LOG is not None:
        AUDIT_LOG.append(
            ("seminaive", "seminaive", sr, apply_expr, int(max_rounds),
             int(n), audit_avals((g, sc, ea, state0, frontier0))))
    state, rounds = _seminaive_device(
        sr, apply_expr, int(max_rounds), int(n), g, sc, ea, state0,
        frontier0)
    state_h, rounds_h = host_get((state, rounds))  # the one sync
    state_h = np.asarray(state_h, dtype=np.float64)
    derived = state_h != float(np.asarray(sr.zero))
    keys = np.flatnonzero(derived).astype(np.int64)
    return keys, state_h[keys], int(rounds_h)


@partial(jax.jit, static_argnames=("sr", "apply_expr", "iters", "tol",
                                   "max_rounds", "k", "factor_kinds"))
def _naive_device(sr: Semiring, apply_expr, iters: Optional[int],
                  tol: Optional[float], max_rounds: int, k: int,
                  factor_kinds: Tuple[str, ...],
                  out_idx, rec_idx, factor_anns, ann0):
    """Whole naive fixpoint on device: the head key set is FIXED across
    rounds (naive recursion re-derives every annotation), so one round is
    a fixed-shape gather → ⊗-chain → segment-⨁ → expression rewrite over
    the key positions.  ``factor_kinds`` mirrors the body-atom order of
    every annotated atom ("rec" = the recursive atom's live state,
    "static" = a round-invariant annotation gather), so the ⊗-chain
    multiplies in exactly the order the Generic-Join fold would.
    Convergence: fixed iteration count (``fori_loop``) or float
    differential checked ON DEVICE every round inside the while-loop —
    zero per-round host syncs either way."""

    assert "rec" in factor_kinds, "naive round needs the recursive factor"

    def round_body(ann):
        contrib = None
        si = 0
        for kind in factor_kinds:
            if kind == "rec":
                f = ann[rec_idx]
            else:
                f = factor_anns[si]
                si += 1
            contrib = f if contrib is None else sr.mul(contrib, f)
        agg = sr.segment_reduce(contrib, out_idx, k)
        return apply_expr(agg).astype(ann0.dtype)

    if iters is not None:
        ann = jax.lax.fori_loop(0, iters, lambda _, a: round_body(a), ann0)
        return ann, jnp.int32(iters)

    def cond(s):
        _, diff, it = s
        return jnp.logical_and(it < max_rounds, diff > tol)

    def body(s):
        ann, _, it = s
        new = round_body(ann)
        return new, jnp.max(jnp.abs(new - ann)), it + 1

    ann, _, rounds = jax.lax.while_loop(
        cond, body, (ann0, jnp.asarray(jnp.inf, ann0.dtype), jnp.int32(0)))
    return ann, rounds


def naive_device_fixpoint(sr: Semiring, apply_expr: ExprFn,
                          out_idx: np.ndarray, rec_idx: np.ndarray,
                          factor_kinds: Tuple[str, ...],
                          factor_anns: List[np.ndarray], k: int,
                          ann0: np.ndarray, iters: Optional[int],
                          tol: Optional[float], max_rounds: int):
    """Host entry point for the device naive loop; ONE final sync."""
    dt = jnp.zeros((), sr.dtype).dtype
    anns = tuple(jnp.asarray(a).astype(dt) for a in factor_anns)
    oi, ri = jnp.asarray(out_idx), jnp.asarray(rec_idx)
    a0 = jnp.asarray(ann0).astype(dt)
    if AUDIT_LOG is not None:
        AUDIT_LOG.append(
            ("naive", "naive", sr, apply_expr, iters, tol,
             int(max_rounds), int(k), tuple(factor_kinds),
             audit_avals((oi, ri, anns, a0))))
    ann, rounds = _naive_device(
        sr, apply_expr, iters, tol, int(max_rounds), int(k),
        tuple(factor_kinds), oi, ri, anns, a0)
    ann_h, rounds_h = host_get((ann, rounds))
    return np.asarray(ann_h, dtype=np.float64), int(rounds_h)


# ----------------------------------------------------- generic fixpoint API
def fixpoint(step: Callable, x0, *, iters: Optional[int] = None,
             tol: Optional[float] = None, max_iters: int = 10_000,
             check_every: int = 8, backend=None):
    """Driver matching the paper's convergence criteria: a fixed number of
    iterations (i=K) or a float differential (c=eps).

    The tolerance path no longer forces a host sync per iteration: steps
    run in blocks of ``check_every`` with the per-step differentials
    computed on device and ONE host read per block (the sync that used to
    happen every round).  The returned value is still the FIRST iterate
    at-or-past convergence — later block members are discarded, so the
    result is identical to the per-iteration check.  ``backend`` (an
    ``ExecBackend``) records the sync discipline in its dispatch counters
    (``fixpoint.host_syncs`` vs ``fixpoint.steps``).
    """
    stats = getattr(backend, "stats", None)

    def bump(key, v=1):
        if stats is not None:
            stats[key] += v

    if iters is not None:
        x = x0
        for _ in range(iters):
            x = step(x)
        bump("fixpoint.steps", iters)
        return x
    assert tol is not None
    check_every = max(1, int(check_every))
    x = x0
    done = 0
    while done < max_iters:
        block = min(check_every, max_iters - done)
        xs = [x]
        for _ in range(block):
            xs.append(step(xs[-1]))
        diffs = jnp.stack([jnp.max(jnp.abs(jnp.asarray(xs[i + 1])
                                           - jnp.asarray(xs[i])))
                           for i in range(block)])
        hit = np.asarray(diffs <= tol)  # the block's single host sync
        bump("fixpoint.host_syncs")
        done += block
        if hit.any():
            first = int(np.argmax(hit))
            bump("fixpoint.steps", first + 1)
            return xs[first + 1]
        bump("fixpoint.steps", block)
        x = xs[-1]
    return x
