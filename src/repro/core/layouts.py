"""Set-layout selection (paper Section 4.1/4.3/4.4, Algorithm 3).

EmptyHeaded chooses, **per set**, between the ``uint`` layout (sorted 32-bit
array) and the ``bitset`` layout (offset + bitvector blocks), using the rule
of Algorithm 3::

    inverse_density = S.range / |S|
    bitset  if inverse_density < SIMD_register_size else uint

The paper studied relation-/set-/block-level granularity against an oracle
(Table 4) and found set-level best; we reproduce that study in
``benchmarks/table4_layout_oracle.py``.

TPU adaptation: per-set dynamic dispatch inside one kernel launch is not
TPU-idiomatic (kernels want uniform tiles), so the same *decision* is executed
at batch granularity: sets are partitioned into a **dense cohort** (rendered
into the blocked-bitset layout) and a **sparse cohort** (kept in CSR/uint),
and intersections are routed to the (bitset×bitset | uint×bitset | uint×uint)
kernel by cohort membership. The decision rule is Algorithm 3 verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core import intersect as I
from repro.core import statistics
from repro.core.trie import CSRGraph

# Paper default: the width of an AVX register (256). TPU-native block size is
# one VREG row of int32 lanes (128 lanes * 32 bits = 4096); both supported.
SIMD_REGISTER_BITS = 256
TPU_VREG_BITS = 4096


@dataclasses.dataclass
class LayoutDecision:
    """Outcome of the set-level optimizer over a CSR adjacency."""

    dense_ids: np.ndarray    # node ids whose sets use the bitset layout
    sparse_ids: np.ndarray   # node ids whose sets stay uint
    inverse_density: np.ndarray  # per-node range/|S| (inf for empty)
    threshold: float


def set_ranges(csr: CSRGraph) -> np.ndarray:
    """Per-set value range (max - min + 1); 0 for empty sets."""
    n = csr.n
    deg = csr.degrees
    lo = np.zeros(n, dtype=np.int64)
    hi = np.zeros(n, dtype=np.int64)
    nz = deg > 0
    starts = csr.offsets[:-1][nz]
    ends = csr.offsets[1:][nz] - 1
    lo[nz] = csr.neighbors[starts]
    hi[nz] = csr.neighbors[ends]
    rng = np.zeros(n, dtype=np.int64)
    rng[nz] = hi[nz] - lo[nz] + 1
    return rng


def decide_set_level(csr: CSRGraph, threshold: float = SIMD_REGISTER_BITS) -> LayoutDecision:
    """Algorithm 3, applied to every set of the relation."""
    deg = csr.degrees
    rng = set_ranges(csr)
    inv = np.full(csr.n, np.inf)
    nz = deg > 0
    inv[nz] = rng[nz] / deg[nz]
    dense = nz & (inv < threshold)
    return LayoutDecision(
        dense_ids=np.flatnonzero(dense).astype(np.int64),
        sparse_ids=np.flatnonzero(nz & ~dense).astype(np.int64),
        inverse_density=inv,
        threshold=threshold,
    )


def decide_relation_level(csr: CSRGraph, force: str = "uint") -> LayoutDecision:
    """Relation-level granularity: one layout for every set (Table 4 row 1)."""
    nz = csr.degrees > 0
    ids = np.flatnonzero(nz).astype(np.int64)
    empty = np.zeros(0, dtype=np.int64)
    if force == "uint":
        return LayoutDecision(empty, ids, np.full(csr.n, np.inf), 0.0)
    return LayoutDecision(ids, empty, np.zeros(csr.n), np.inf)


# ----------------------------------------------------------- engine routing
# Layout mode for the execution engine's terminal intersections:
#   "set"  — Algorithm-3 set-level decisions (paper default)
#   "uint" — relation-level all-uint ("-R" ablation)
#   "off"  — bypass the store (plain search path)
_ENGINE_LAYOUT_MODE = "set"


def set_engine_layout_mode(mode: str):
    global _ENGINE_LAYOUT_MODE
    assert mode in ("set", "uint", "off"), mode
    _ENGINE_LAYOUT_MODE = mode


def engine_store_for(trie, *, word_kernel: Optional[Callable] = None,
                     uint_kernel: Optional[Callable] = None,
                     materialize_kernel: Optional[Callable] = None,
                     uint_max_len: int = 256,
                     counter=None,
                     cache_tag: str = "host",
                     threshold: Optional[float] = None,
                     ) -> Optional["HybridSetStore"]:
    """Per-trie cached HybridSetStore for the engine's binary terminal
    folds (built lazily on first use; index build time is excluded from
    query timing, as in the paper).

    ``threshold`` is the Algorithm-3 density threshold. The plan IR
    passes the statistics-driven value from its TerminalFold annotation;
    when None (legacy callers), the same statistics profile is computed
    here (``statistics.layout_threshold_for``) — either way the decision
    is data-driven, not the fixed SIMD_REGISTER_BITS constant. The
    threshold used is recorded in the dispatch counters
    (``layout.threshold_bits`` / ``layout.stats_driven``).

    Stores are cached per (layout mode, cache_tag, threshold) so the
    numpy and device backends — which inject different intersection
    kernels — each keep their own resident index on the same trie.
    ``counter`` (a Counter-like mapping) is rebound on every call so
    dispatch instrumentation always lands on the calling backend.
    """
    if _ENGINE_LAYOUT_MODE == "off":
        return None
    if _ENGINE_LAYOUT_MODE == "uint":
        thr_key = "uint"
    else:
        if threshold is None:
            threshold = statistics.layout_threshold_for(trie)
        thr_key = int(round(threshold))
    cache = getattr(trie, "_hybrid_stores", None)
    if cache is None:
        cache = trie._hybrid_stores = {}
    key = (_ENGINE_LAYOUT_MODE, cache_tag, thr_key)
    store = cache.get(key)
    if store is None:
        csr = CSRGraph.from_trie(trie)
        decision = (decide_relation_level(csr, "uint")
                    if _ENGINE_LAYOUT_MODE == "uint" else None)
        store = HybridSetStore.build(csr, threshold=threshold or SIMD_REGISTER_BITS,
                                     decision=decision,
                                     word_kernel=word_kernel,
                                     uint_kernel=uint_kernel,
                                     materialize_kernel=materialize_kernel,
                                     uint_max_len=uint_max_len)
        cache[key] = store
    if counter is not None and _ENGINE_LAYOUT_MODE == "set":
        counter["layout.stats_driven"] += 1
        counter["layout.threshold_bits"] = int(thr_key)
    store.counter = counter
    return store


@dataclasses.dataclass
class HybridSetStore:
    """The execution-engine view of one relation's second trie level:
    CSR for the sparse cohort + blocked bitset for the dense cohort, with a
    router that dispatches pairwise intersections to the right kernel.
    """

    csr: CSRGraph
    decision: LayoutDecision
    bitset: Optional[I.BlockedBitset]
    # injected word-AND-popcount (the Pallas kernel), None -> pure jnp
    word_kernel: Optional[Callable] = None
    # injected batched uint∩uint kernel ((offsets, neighbors, u, v) ->
    # counts) for short similar-cardinality pairs; None -> lockstep search
    uint_kernel: Optional[Callable] = None
    # injected materializing bitset∩bitset kernel ((bitset, a_slots,
    # b_slots) -> (pair_id, values, rank_a, rank_b)); None -> the host
    # unpackbits extraction (intersect.bitset_intersect_materialize)
    materialize_kernel: Optional[Callable] = None
    # pairs whose larger set exceeds this stay on the search path
    uint_max_len: int = 256
    # Counter-like sink recording which kernel handled each pair
    counter: Optional[object] = None

    @staticmethod
    def build(csr: CSRGraph, threshold: float = SIMD_REGISTER_BITS,
              block_bits: int = SIMD_REGISTER_BITS,
              word_kernel: Optional[Callable] = None,
              uint_kernel: Optional[Callable] = None,
              materialize_kernel: Optional[Callable] = None,
              uint_max_len: int = 256,
              decision: Optional[LayoutDecision] = None) -> "HybridSetStore":
        d = decision if decision is not None else decide_set_level(csr, threshold)
        bs = None
        if len(d.dense_ids):
            bs = I.build_blocked_bitset(csr.offsets, csr.neighbors,
                                        d.dense_ids, csr.n, block_bits)
        return HybridSetStore(csr, d, bs, word_kernel, uint_kernel,
                              materialize_kernel, uint_max_len)

    def _bump(self, key: str, n: int):
        if self.counter is not None:
            self.counter[key] += n

    def stats(self) -> dict:
        d = self.decision
        return {
            "n_dense": int(len(d.dense_ids)),
            "n_sparse": int(len(d.sparse_ids)),
            "frac_dense": float(len(d.dense_ids)) / max(1, len(d.dense_ids) + len(d.sparse_ids)),
            "bitset_bytes": int(self.bitset.nbytes()) if self.bitset else 0,
            "csr_bytes": int(self.csr.neighbors.nbytes + self.csr.offsets.nbytes),
        }

    # ------------------------------------------------------------- dispatch
    def intersect_count(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """|N(u_i) ∩ N(v_i)| routed per-pair by the cohort of each endpoint.

        Routing: both dense -> bitset∩bitset; one dense -> uint∩bitset
        (probe the sparse side into the dense side — min property); both
        sparse -> hybrid uint search.
        """
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        out = np.zeros(len(u), dtype=np.int64)
        if len(u) == 0:
            return out
        if self.bitset is None:
            return self._sparse_count(u, v)
        slot = self.bitset.slot_of
        ud = slot[u] >= 0
        vd = slot[v] >= 0

        both_d = ud & vd
        if both_d.any():
            idx = np.flatnonzero(both_d)
            out[idx] = I.bitset_intersect_count(
                self.bitset, slot[u[idx]], slot[v[idx]], self.word_kernel)
            self._bump("intersect.bitset_kernel" if self.word_kernel
                       else "intersect.bitset_jnp", len(idx))

        mixed = ud ^ vd
        if mixed.any():
            idx = np.flatnonzero(mixed)
            uu, vv = u[idx], v[idx]
            sparse_side = np.where(ud[idx], vv, uu)
            dense_side = np.where(ud[idx], uu, vv)
            out[idx] = I.uint_bitset_intersect_count(
                self.csr.offsets, self.csr.neighbors, sparse_side,
                self.bitset, slot[dense_side])
            self._bump("intersect.uint_bitset", len(idx))

        both_s = ~(ud | vd)
        if both_s.any():
            idx = np.flatnonzero(both_s)
            out[idx] = self._sparse_count(u[idx], v[idx])
        return out

    def _sparse_count(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """uint∩uint cohort: Algorithm 2's regime split — short
        similar-cardinality pairs take the membership-test kernel when one
        is injected, long/skewed pairs the lockstep binary search."""
        if self.uint_kernel is not None:
            deg = self.csr.degrees
            short = np.maximum(deg[u], deg[v]) <= self.uint_max_len
            out = np.zeros(len(u), dtype=np.int64)
            if short.any():
                idx = np.flatnonzero(short)
                out[idx] = self.uint_kernel(self.csr.offsets,
                                            self.csr.neighbors, u[idx], v[idx])
                self._bump("intersect.uint_kernel", len(idx))
            if not short.all():
                idx = np.flatnonzero(~short)
                out[idx] = I.intersect_count_uint(
                    self.csr.offsets, self.csr.neighbors, u[idx], v[idx])
                self._bump("intersect.uint_search", len(idx))
            return out
        self._bump("intersect.uint_search", len(u))
        return I.intersect_count_uint(self.csr.offsets, self.csr.neighbors,
                                      u, v)

    def intersect_materialize(self, u: np.ndarray, v: np.ndarray):
        """Materializing intersection, cohort-routed like ``intersect_count``.

        Returns ``(pair_id, value, pos_u, pos_v)`` — positions are absolute
        indices into ``csr.neighbors`` (= the trie's set-level values, for
        descent into deeper levels / annotation gathers).  Dense×dense
        pairs extract matches from the blocked-bitset layout, recovering
        positions via the per-block ``index`` field (paper Figure 6 — the
        seed ALWAYS fell back to the uint search here, leaving the hint
        unused); when a ``materialize_kernel`` is injected (the device
        backend) that extraction runs as the Pallas AND+rank kernel
        instead of the host unpackbits path.  Every other cohort takes
        the uint search path.  Pair counts land in the dispatch counters
        as ``intersect.materialize_{kernel,bitset,uint}`` — kernel vs
        bitset distinguishes who executed the dense cohort.
        """
        u = np.asarray(u, np.int64)
        v = np.asarray(v, np.int64)
        if self.bitset is None:
            self._bump("intersect.materialize_uint", len(u))
            return I.intersect_pairs_uint(self.csr.offsets,
                                          self.csr.neighbors, u, v)
        if self.materialize_kernel is not None:
            dense_mat, dense_key = (self.materialize_kernel,
                                    "intersect.materialize_kernel")
        else:
            dense_mat, dense_key = (I.bitset_intersect_materialize,
                                    "intersect.materialize_bitset")
        slot = self.bitset.slot_of
        both_dense = (slot[u] >= 0) & (slot[v] >= 0)
        if both_dense.all():
            self._bump(dense_key, len(u))
            pid, vals, ra, rb = dense_mat(self.bitset, slot[u], slot[v])
            return (pid, vals,
                    self.csr.offsets[u[pid]] + ra,
                    self.csr.offsets[v[pid]] + rb)
        di = np.flatnonzero(both_dense)
        si = np.flatnonzero(~both_dense)
        self._bump(dense_key, len(di))
        self._bump("intersect.materialize_uint", len(si))
        pid_d, vals_d, ra, rb = dense_mat(
            self.bitset, slot[u[di]], slot[v[di]])
        pos_u_d = self.csr.offsets[u[di][pid_d]] + ra
        pos_v_d = self.csr.offsets[v[di][pid_d]] + rb
        pid_s, vals_s, pu_s, pv_s = I.intersect_pairs_uint(
            self.csr.offsets, self.csr.neighbors, u[si], v[si])
        pair_id = np.concatenate([di[pid_d], si[pid_s]])
        vals = np.concatenate([vals_d, vals_s])
        pos_u = np.concatenate([pos_u_d, pu_s])
        pos_v = np.concatenate([pos_v_d, pv_s])
        # restore the canonical expansion order (pair-major, values
        # ascending within a pair) the search path produces
        order = np.lexsort((vals, pair_id))
        return pair_id[order], vals[order], pos_u[order], pos_v[order]
