"""AGM bound / fractional edge cover (paper Section 2.1, Eq. 1).

``AGM(Q) = min Π_e |R_e|^{x_e}`` over feasible fractional covers x — found
by minimizing ``Σ_e x_e · log|R_e|`` subject to ``Σ_{e ∋ v} x_e ≥ 1`` per
vertex, ``x ≥ 0`` ("take the log of Eq. 1 and solve the linear program",
footnote 3).

Query hypergraphs are tiny (≤ ~8 edges), so instead of a general simplex we
enumerate basic feasible solutions exactly: every vertex of the polyhedron
{Ax ≥ b, x ≥ 0} is the solution of |E| tight constraints chosen among the
|V| cover rows and the |E| bounds. With |E|+|V| ≤ 16 that is ≤ C(16,8) ≈ 13k
tiny linear solves — exact, and free of pivot-degeneracy corner cases.
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.hypergraph import Hypergraph


def fractional_cover(hg: Hypergraph,
                     edge_idxs: Optional[Sequence[int]] = None,
                     log_sizes: Optional[Dict[int, float]] = None,
                     ) -> Tuple[float, np.ndarray]:
    """Optimal fractional edge cover of the sub-hypergraph on ``edge_idxs``.

    Returns (objective, x) where objective = Σ x_e·w_e with w_e = log|R_e|
    (w_e = 1 when log_sizes is None — then the objective is the *fractional
    edge cover number*, i.e. the width exponent with all |R| = N).
    """
    if edge_idxs is None:
        edge_idxs = list(range(len(hg.edges)))
    edge_idxs = list(edge_idxs)
    E = len(edge_idxs)
    verts = sorted(hg.edge_vars(edge_idxs))
    V = len(verts)
    if E == 0 or V == 0:
        return 0.0, np.zeros(E)
    w = np.array([1.0 if log_sizes is None else log_sizes[e] for e in edge_idxs])
    # cover matrix A[v, e] = 1 if v in edge e
    A = np.zeros((V, E))
    for j, e in enumerate(edge_idxs):
        for i, v in enumerate(verts):
            if v in hg.edges[e].vars:
                A[i, j] = 1.0

    # Constraints: A x >= 1 (V rows) and x >= 0 (E rows).
    rows = [(A[i], 1.0) for i in range(V)] + \
           [(np.eye(E)[j], 0.0) for j in range(E)]
    best_obj, best_x = math.inf, None
    for combo in itertools.combinations(range(len(rows)), E):
        M = np.stack([rows[i][0] for i in combo])
        b = np.array([rows[i][1] for i in combo])
        try:
            x = np.linalg.solve(M, b)
        except np.linalg.LinAlgError:
            continue
        if np.any(x < -1e-9):
            continue
        if np.any(A @ x < 1.0 - 1e-9):
            continue
        obj = float(w @ x)
        if obj < best_obj - 1e-12:
            best_obj, best_x = obj, np.clip(x, 0.0, None)
    assert best_x is not None, "cover LP infeasible (isolated vertex?)"
    return best_obj, best_x


def agm_bound(hg: Hypergraph, sizes: Dict[int, int],
              edge_idxs: Optional[Sequence[int]] = None) -> float:
    """The AGM output-size bound Π |R_e|^{x_e} (data-aware)."""
    log_sizes = {e: math.log(max(2, sizes[e])) for e in
                 (edge_idxs if edge_idxs is not None else range(len(hg.edges)))}
    obj, _ = fractional_cover(hg, edge_idxs, log_sizes)
    return math.exp(obj)


def fractional_cover_number(hg: Hypergraph,
                            edge_idxs: Optional[Sequence[int]] = None) -> float:
    """ρ*: width exponent when all relations have size N (AGM = N^ρ*)."""
    obj, _ = fractional_cover(hg, edge_idxs, None)
    return obj
