"""Training loop driver: data pipeline + StepRunner (fault policy) +
checkpoint cadence + auto-resume."""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.dist.fault import FaultPolicy, StepRunner

log = logging.getLogger("repro.train")


def train_loop(step_fn: Callable, init_state: dict, batch_at: Callable,
               num_steps: int, ckpt_dir: Optional[str] = None,
               policy: Optional[FaultPolicy] = None,
               log_every: int = 10, shardings=None):
    """Runs ``num_steps`` steps. batch_at(step) -> batch pytree (host).

    Auto-resumes from the latest checkpoint in ckpt_dir if one exists —
    the data pipeline is deterministic in (seed, step), so the stream
    resumes exactly.
    """
    policy = policy or FaultPolicy()
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    state = init_state
    start = 0
    resumed_at = None
    if ckpt is not None and ckpt.latest_step() is not None:
        state, start = ckpt.restore(init_state, shardings=shardings)
        resumed_at = start
        log.info("resumed from step %d", start)
    runner = StepRunner(step_fn, ckpt, policy)

    metrics = {}
    t0 = time.monotonic()
    for step in range(start, num_steps):
        batch = batch_at(step)
        state, metrics = runner.run(state, batch, step)
        if step % log_every == 0:
            loss = float(metrics.get("loss", jnp.nan))
            log.info("step %d loss %.4f (%.2fs)", step, loss,
                     time.monotonic() - t0)
        runner.maybe_checkpoint(state, step + 1)
    # final save — unless the cadence just wrote this step, or the run was
    # a no-op resume of an already-completed checkpoint (a fresh 0-step run
    # still snapshots the init state)
    if ckpt is not None and runner.last_saved != num_steps \
            and resumed_at != num_steps:
        ckpt.save(state, num_steps)
    return state, metrics
