from repro.train.step import TrainState, make_train_step  # noqa: F401
from repro.train.loop import train_loop  # noqa: F401
