"""Train-step factory: grad (+ optional microbatch accumulation) + optimizer
apply, as one pure function suitable for jit/pjit lowering.

``make_train_step`` is model-agnostic: it takes the model's loss_fn
(params, batch, cfg) -> (loss, metrics). Gradient accumulation scans over a
leading microbatch axis that the caller reshapes into the batch — under
pjit each microbatch's collectives overlap with the next microbatch's
compute (latency-hiding scheduler).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: Any  # int32 scalar array

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state,
                "step": self.step}

    @staticmethod
    def create(params, optimizer: Optimizer):
        return TrainState(params, optimizer.init(params),
                          jnp.zeros((), jnp.int32))


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    accum_steps: int = 1):
    """Returns step(state_dict, batch) -> (state_dict, metrics).

    state_dict is the plain-dict view of TrainState (pjit-friendly pytree).
    With accum_steps > 1, every batch leaf must have a leading
    [accum_steps, ...] axis.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state: Dict, batch) -> tuple:
        params = state["params"]
        if accum_steps == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                loss_a, grads_a = carry
                loss, metrics, grads = grads_of(params, mb)
                grads_a = jax.tree.map(jnp.add, grads_a, grads)
                return (loss_a + loss, grads_a), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), metrics = jax.lax.scan(
                micro, (jnp.zeros(()), zeros), batch)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        updates, opt_state, opt_metrics = optimizer.update(
            grads, state["opt_state"], params, state["step"])
        params = jax.tree.map(lambda p, u: p - u.astype(p.dtype),
                              params, updates)
        new_state = {"params": params, "opt_state": opt_state,
                     "step": state["step"] + 1}
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out_metrics

    return step
