"""Frontier-fill Pallas kernel: one morsel chunk of the count-then-fill
expansion as a single launch.

The launch stages the chunk's working set in VMEM as ``(1, N)`` row
vectors (grid ``(1,)``, whole-array blocks with zero index maps: the
offsets/cursor-bounds rows, the seed level and every probe level) and
computes, branch-free:

1. **searchsorted offset-inversion** — a fixed-iteration upper-bound
   binary search maps each output slot ``j`` in
   ``[c*morsel, (c+1)*morsel)`` back to its source frontier row.  The
   offsets row is padded with an int32-max sentinel which compares above
   every live ``j`` (buffer capacities stay below 2^31), so the padded
   search equals the unpadded ``jnp.searchsorted(offs, j, "right")``.
2. **seed gather** — absolute seed positions
   ``p0 = lo0[row] + (j - offs[row])`` and their values.
3. **lockstep probe** — every probe atom's candidate segment is searched
   with the SAME fixed-iteration lower-bound loop as
   ``intersect.segment_searchsorted`` (identical mid/clip/where updates
   and found test, so positions and membership are bit-exact), AND-ing
   each atom's membership into the keep mask.

All arithmetic is int32; ``kernel_check`` asserts bit-equality against
the plain-jnp oracle in :mod:`.ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax.numpy as jnp
from jax import lax

# int32-max sentinel padding the offsets row: every live output slot
# index j stays below it (buffer capacities are < 2^31), so the padded
# upper-bound search returns exactly the unpadded result.
OFFS_SENTINEL = (1 << 31) - 1

# Fixed binary-search iteration count, matching
# intersect.segment_searchsorted's default (covers any int32 range).
_ITERS = 34


@functools.lru_cache(maxsize=None)
def make_fill_kernel(n_probes: int, morsel: int, cap_in: int, n0: int,
                     nks: Tuple[int, ...]):
    """Build a kernel body for one (n_probes, morsel, cap_in, n0, nks)
    geometry — every shape is baked via closure so the traced program is
    straight-line."""

    def kernel(*refs):
        c_ref, tc_ref, offs_ref, lo0_ref, seed_ref = refs[:5]
        probe_refs = refs[5:5 + 3 * n_probes]
        out_lo = 5 + 3 * n_probes
        vals_o, row_o, p0_o, keep_o = refs[out_lo:out_lo + 4]
        pos_os = refs[out_lo + 4:]

        c = c_ref[0, 0]
        total_c = tc_ref[0, 0]
        offs = offs_ref[0, :]
        lo0 = lo0_ref[0, :]
        seed = seed_ref[0, :]

        j = c * morsel + lax.broadcasted_iota(jnp.int32, (1, morsel), 1)

        # ---- offset inversion: upper bound over the live offsets
        # prefix [0, cap_in) — the sentinel-padded tail never matches
        lo_ = jnp.zeros((1, morsel), jnp.int32)
        hi_ = jnp.full((1, morsel), cap_in, jnp.int32)

        def ub_body(_, st):
            lo_b, hi_b = st
            mid = (lo_b + hi_b) >> 1
            v = offs[jnp.clip(mid, 0, cap_in - 1)]
            open_ = lo_b < hi_b
            right = v <= j
            return (jnp.where(open_ & right, mid + 1, lo_b),
                    jnp.where(open_ & (~right), mid, hi_b))

        ub, _ = lax.fori_loop(0, _ITERS, ub_body, (lo_, hi_))
        row = jnp.clip(ub - 1, 0, cap_in - 1)
        p0 = lo0[row] + (j - offs[row])
        live = j < total_c
        vals = seed[jnp.clip(p0, 0, max(n0 - 1, 0))]
        keep = live

        for k in range(n_probes):
            vk = probe_refs[3 * k][0, :]
            lo_k = probe_refs[3 * k + 1][0, :][row]
            hi_k = probe_refs[3 * k + 2][0, :][row]
            nk = nks[k]

            # segment_searchsorted's lower-bound loop, verbatim
            def lb_body(_, st, vk=vk, nk=nk):
                lo_b, hi_b = st
                mid = (lo_b + hi_b) >> 1
                v = vk[jnp.clip(mid, 0, nk - 1)]
                open_ = lo_b < hi_b
                right = v < vals
                return (jnp.where(open_ & right, mid + 1, lo_b),
                        jnp.where(open_ & (~right), mid, hi_b))

            pos_k, _hi_f = lax.fori_loop(0, _ITERS, lb_body,
                                         (lo_k, hi_k))
            in_range = pos_k < hi_k
            found = in_range & (vk[jnp.clip(pos_k, 0, nk - 1)] == vals)
            pos_os[k][...] = pos_k
            keep = keep & found

        vals_o[...] = vals
        row_o[...] = row
        p0_o[...] = p0
        keep_o[...] = keep.astype(jnp.int32)

    return kernel
