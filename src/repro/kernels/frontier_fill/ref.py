"""Plain-jnp oracle for the frontier-fill kernel.

This is verbatim the PR 7 fill-chunk computation (the body of
``backend._pipeline_step``'s morsel ``while_loop``): invert the
exclusive-scan offsets back to source frontier rows, gather the seed
values, and probe every other constraining atom with the branch-free
lockstep search.  All arithmetic is int32 and every comparison is
integral, so the Pallas kernel's outputs must match this reference
BIT-EXACTLY — ``kernel_check`` enforces equality, and the engine's
``REPRO_FRONTIER_FILL=jnp`` escape hatch runs this path directly as the
differential oracle.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from repro.core import intersect as I


def fill_chunk_ref(c, total_c, offs, lo0, seed_values,
                   probes: Sequence[Tuple], *, morsel: int):
    """One morsel chunk of the count-then-fill expansion.

    ``probes`` lists ``(values_k, lo_k, hi_k)`` per probe atom with the
    per-ROW candidate bounds (``lo_k``/``hi_k`` are indexed by the
    recovered source row, not by output slot).  Returns
    ``(vals, row, p0, keep, poss)`` — the chunk's candidate values,
    source rows, absolute seed positions, combined liveness+membership
    mask, and each probe atom's absolute positions.
    """
    offs = jnp.asarray(offs)
    lo0 = jnp.asarray(lo0)
    seed_values = jnp.asarray(seed_values)
    probes = tuple((jnp.asarray(v), jnp.asarray(lo), jnp.asarray(hi))
                   for v, lo, hi in probes)
    cap_in = offs.shape[0]
    n0 = seed_values.shape[0]
    c = jnp.asarray(c, jnp.int32)
    j = c * morsel + jnp.arange(morsel, dtype=jnp.int32)
    row = jnp.clip(jnp.searchsorted(offs, j, side="right") - 1,
                   0, cap_in - 1).astype(jnp.int32)
    p0 = lo0[row] + (j - offs[row])
    live = j < total_c
    vals = seed_values[jnp.clip(p0, 0, max(n0 - 1, 0))]
    keep = live
    poss = []
    for vals_k, lo_k, hi_k in probes:
        pk, fk = I.segment_searchsorted(vals_k, lo_k[row], hi_k[row],
                                        vals)
        poss.append(pk.astype(jnp.int32))
        keep = keep & fk
    return vals, row, p0, keep, tuple(poss)
