"""Frontier-fill Pallas package: the morsel-chunked fill stage of the
zero-sync count-then-fill extension pipeline as one kernel launch per
chunk (offset inversion -> seed gather -> branch-free lockstep probes),
bit-identical to the plain-jnp reference in :mod:`.ref`."""
from repro.kernels.frontier_fill.ops import CONTRACT, fill_chunk  # noqa: F401
