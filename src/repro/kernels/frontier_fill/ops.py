"""Dispatch + contract for the frontier-fill kernel.

``fill_chunk`` is called from INSIDE the extension pipeline's jitted
morsel ``while_loop`` (``core.backend._extend_body``): one launch per
chunk, each computing the chunk's offset inversion, seed gather and
lockstep probes entirely in-kernel.  Inputs are padded to lane-aligned
``(1, N)`` row blocks here (zero index maps, grid ``(1,)``), so the
contract checker's tiling assertions hold exactly; the offsets row pads
with ``OFFS_SENTINEL`` (int32 max), which compares above every live
output slot and leaves the upper-bound search unchanged.

The package's ``CONTRACT`` feeds ``repro.analysis.kernel_check``:
representative two-probe inputs with a non-trivial keep/position mix,
checked bit-exactly against the plain-jnp oracle in :mod:`.ref` (which
is verbatim the PR 7 fill path — so kernel parity IS engine parity).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common import LANE, interpret_default, round_up
from repro.kernels.frontier_fill.kernel import (OFFS_SENTINEL,
                                                make_fill_kernel)


def _row(x, width: int, fill=0):
    """Pad a 1-D int32 array to ``width`` and lift to a (1, width) row."""
    x = jnp.asarray(x).astype(jnp.int32)
    n = x.shape[0]
    if width > n:
        x = jnp.pad(x, (0, width - n), constant_values=fill)
    return x.reshape(1, width)


def fill_chunk(c, total_c, offs, lo0, seed_values,
               probes: Sequence[Tuple], *, morsel: int,
               interpret: Optional[bool] = None):
    """One morsel chunk of the count-then-fill expansion, in-kernel.

    Same signature and bit-identical outputs as ``ref.fill_chunk_ref``:
    returns ``(vals, row, p0, keep, poss)`` for output slots
    ``[c*morsel, (c+1)*morsel)``.
    """
    if interpret is None:
        interpret = interpret_default()
    cap_in = int(offs.shape[0])
    n0 = int(seed_values.shape[0])
    nks = tuple(int(vk.shape[0]) for vk, _lo, _hi in probes)
    P = round_up(max(cap_in, 1), LANE)

    def zmap(i):
        return (0, 0)

    scalar_spec = pl.BlockSpec((1, 1), zmap)
    row_spec = pl.BlockSpec((1, P), zmap)
    ins = [jnp.reshape(c, (1, 1)).astype(jnp.int32),
           jnp.reshape(total_c, (1, 1)).astype(jnp.int32),
           _row(offs, P, OFFS_SENTINEL),
           _row(lo0, P),
           _row(seed_values, round_up(max(n0, 1), LANE))]
    in_specs = [scalar_spec, scalar_spec, row_spec, row_spec,
                pl.BlockSpec((1, round_up(max(n0, 1), LANE)), zmap)]
    for (vk, lo_k, hi_k), nk in zip(probes, nks):
        nkp = round_up(max(nk, 1), LANE)
        ins += [_row(vk, nkp), _row(lo_k, P), _row(hi_k, P)]
        in_specs += [pl.BlockSpec((1, nkp), zmap), row_spec, row_spec]
    n_out = 4 + len(probes)
    out = pl.pallas_call(
        make_fill_kernel(len(probes), int(morsel), cap_in, n0, nks),
        grid=(1,),
        in_specs=in_specs,
        out_specs=tuple(pl.BlockSpec((1, int(morsel)), zmap)
                        for _ in range(n_out)),
        out_shape=tuple(jax.ShapeDtypeStruct((1, int(morsel)), jnp.int32)
                        for _ in range(n_out)),
        interpret=interpret,
    )(*ins)
    vals = out[0].reshape(morsel)
    row = out[1].reshape(morsel)
    p0 = out[2].reshape(morsel)
    keep = out[3].reshape(morsel).astype(bool)
    poss = tuple(o.reshape(morsel) for o in out[4:])
    return vals, row, p0, keep, poss


# ------------------------------------------------------------- contract
_CONTRACT_MORSEL = 128


def _contract_inputs():
    """Representative two-probe chunk: eight frontier rows expanding
    into overlapping seed segments, probed into two half-universe
    levels — keep is a genuine True/False mix and every output carries
    non-trivial positions (an all-zero result would make the numeric
    cross-check vacuous)."""
    rng = np.random.default_rng(0)
    cap_in, n0 = 8, 64
    seed_vals = np.sort(rng.choice(200, size=n0,
                                   replace=False)).astype(np.int32)
    lo0 = np.sort(rng.integers(0, n0 - 8, size=cap_in)).astype(np.int32)
    cnt = rng.integers(2, 8, size=cap_in).astype(np.int32)
    offs = (np.cumsum(cnt) - cnt).astype(np.int32)
    total = np.asarray(int(offs[-1] + cnt[-1]), np.int32)

    def probe(seed):
        r = np.random.default_rng(seed)
        vk = np.sort(r.choice(200, size=96,
                              replace=False)).astype(np.int32)
        return (vk, np.zeros(cap_in, np.int32),
                np.full(cap_in, len(vk), np.int32))

    v1, l1, h1 = probe(1)
    v2, l2, h2 = probe(2)
    return (np.zeros((), np.int32), total, offs, lo0, seed_vals,
            v1, l1, h1, v2, l2, h2)


def _contract_entry(c, tc, offs, lo0, seed, v1, l1, h1, v2, l2, h2):
    vals, row, p0, keep, poss = fill_chunk(
        c, tc, offs, lo0, seed, ((v1, l1, h1), (v2, l2, h2)),
        morsel=_CONTRACT_MORSEL, interpret=True)
    return (vals, row, p0, keep) + poss


def _contract_ref(c, tc, offs, lo0, seed, v1, l1, h1, v2, l2, h2):
    from repro.kernels.frontier_fill.ref import fill_chunk_ref

    vals, row, p0, keep, poss = fill_chunk_ref(
        c, tc, offs, lo0, seed, ((v1, l1, h1), (v2, l2, h2)),
        morsel=_CONTRACT_MORSEL)
    return (vals, row, p0, keep) + poss


CONTRACT = {
    "name": "frontier_fill",
    "entry": _contract_entry,
    "ref": _contract_ref,
    "make_inputs": _contract_inputs,
}


# ---------------------------------------------------------- vmap contract
_CONTRACT_BATCH = 3


def _contract_inputs_vmap():
    """Three lanes of the contract chunk with per-lane cursor state: the
    chunk index, seed bounds and probe windows all differ per lane (the
    batched pipeline carries exactly these per query), so lane collapse
    or cross-lane leakage cannot cancel out in the parity check."""
    c, tc, offs, lo0, seed, v1, l1, h1, v2, l2, h2 = _contract_inputs()
    n0 = len(seed)
    lanes = []
    for b in range(_CONTRACT_BATCH):
        lanes.append((
            np.asarray(b % 2, np.int32),          # lanes on chunk 0 AND 1
            np.clip(lo0 + b, 0, n0 - 1).astype(np.int32),
            l1, np.maximum(h1 - 7 * b, l1).astype(np.int32),
            np.minimum(l2 + 3 * b, h2).astype(np.int32), h2,
        ))
    stacked = tuple(np.stack(cols) for cols in zip(*lanes))
    return (stacked[0], tc, offs, stacked[1], seed,
            v1, stacked[2], stacked[3], v2, stacked[4], stacked[5])


def _vmap_one(c, tc, offs, lo0, seed, v1, l1, h1, v2, l2, h2):
    vals, row, p0, keep, poss = fill_chunk(
        c, tc, offs, lo0, seed, ((v1, l1, h1), (v2, l2, h2)),
        morsel=_CONTRACT_MORSEL, interpret=True)
    return (vals, row, p0, keep) + poss


def _contract_entry_vmap(c, tc, offs, lo0, seed, v1, l1, h1, v2, l2, h2):
    return jax.vmap(_vmap_one,
                    in_axes=(0, None, None, 0, None,
                             None, 0, 0, None, 0, 0))(
        c, tc, offs, lo0, seed, v1, l1, h1, v2, l2, h2)


def _contract_ref_vmap(c, tc, offs, lo0, seed, v1, l1, h1, v2, l2, h2):
    """Per-lane oracle, sequentially: what the batched launch must equal
    lane by lane."""
    from repro.kernels.frontier_fill.ref import fill_chunk_ref

    outs = []
    for b in range(c.shape[0]):
        vals, row, p0, keep, poss = fill_chunk_ref(
            c[b], tc, offs, lo0[b], seed,
            ((v1, l1[b], h1[b]), (v2, l2[b], h2[b])),
            morsel=_CONTRACT_MORSEL)
        outs.append((vals, row, p0, keep) + poss)
    return tuple(jnp.stack(col) for col in zip(*outs))


# ``jax.vmap`` DOES batch the fill launch and interpret-mode values stay
# bit-exact per lane — but the batching rule REWRITES the launch away
# from the declared contract: grid (1,) becomes (B, 1) and every batched
# operand's block gains a leading ``Mapped`` (non-integer) dim while
# closed-over operands keep rank-2 blocks.  The per-launch tiling
# assertions of ``kernel_check`` cannot certify that mixed-rank form, so
# ``core.backend._bag_program_batch`` pins ``fill_mode="jnp"``.
# ``kernel_check.check_vmap_contract`` verifies the parity half and
# raises a typed ``KernelVmapDivergence`` pinning the geometry half.
CONTRACT_VMAP = {
    "name": "frontier_fill[vmap]",
    "entry": _contract_entry_vmap,
    "ref": _contract_ref_vmap,
    "make_inputs": _contract_inputs_vmap,
    "declared_grid": (1,),
    "batch": _CONTRACT_BATCH,
}
