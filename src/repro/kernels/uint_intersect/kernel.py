"""Pallas TPU kernel: batched sorted-uint set intersection counts
(paper Section 4.2 ``UINT ∩ UINT``, SIMDShuffling side of Algorithm 2).

The CPU SIMDShuffling algorithm merges two sorted streams with cross-lane
shuffles; the TPU VPU has no cross-lane shuffle, so the adaptation is a
**tile-vs-tile membership test**: each (rows, LA) tile of set A is compared
against each (rows, LB_BLK) tile of set B with a broadcasted equality over a
third axis. Cost is O(LA * LB / lanes) per row pair — the right regime for
the similar-cardinality sets this path handles (the 32:1 cardinality-skew
regime is routed to the lockstep binary search in ``core.intersect``, which
is the min-property / SIMDGalloping analogue).

Shapes (padded by ops.py, sentinel = -1 which never matches a valid id):

  a   : [P, LA] int32 sorted, padded with -1
  b   : [P, LB] int32 sorted, padded with -1
  out : [P]     int32 |a_i ∩ b_i|

Grid: (P / rows, LB / lb_blk); the out block for row-tile i is revisited for
every j, accumulating partial counts (init at j == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import SUBLANE, cdiv


def _kernel(a_ref, b_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                      # (rows, LA)
    b = b_ref[...]                      # (rows, LB_BLK)
    valid = a >= 0
    # (rows, LA, LB_BLK) equality cube; membership = any over B axis.
    hit = (a[:, :, None] == b[:, None, :]).any(axis=2)
    out_ref[...] += (hit & valid).sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "lb_blk", "interpret"))
def uint_intersect_kernel(a, b, *, block_rows: int = 8, lb_blk: int = 128,
                          interpret: bool = False):
    p, la = a.shape
    _, lb = b.shape
    assert b.shape[0] == p
    assert p % block_rows == 0 and lb % lb_blk == 0
    assert block_rows % SUBLANE == 0
    grid = (cdiv(p, block_rows), cdiv(lb, lb_blk))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, la), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, lb_blk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.int32),
        interpret=interpret,
    )(a, b)
