from repro.kernels.uint_intersect.ops import uint_intersect_count  # noqa: F401
