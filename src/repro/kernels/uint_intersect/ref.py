"""Pure-jnp oracle for the batched uint intersection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def uint_intersect_count_ref(a, b):
    """Padded-batch intersection counts.

    a, b: [P, L*] int32 sorted rows padded with -1. Rows are sets (unique
    values), so counting membership hits of a's valid entries in b equals
    the intersection cardinality.
    """
    valid = a >= 0
    hit = (a[:, :, None] == b[:, None, :]).any(axis=2)
    return (hit & valid).sum(axis=1).astype(jnp.int32)
