"""Jit'd public wrapper for the uint-intersect kernel.

Takes ragged CSR pairs, pads the gathered neighbor sets to tile geometry,
and runs the Pallas membership-test kernel. Used by the execution engine for
similar-cardinality sparse-set batches (the SIMDShuffling regime); the
cardinality-skewed regime stays on the lockstep binary search.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import LANE, interpret_default, round_up
from repro.kernels.uint_intersect.kernel import uint_intersect_kernel

_BLOCK_ROWS = 8


def uint_intersect_count(a, b, *, interpret=None):
    """Counts for already-padded batches a [P, LA], b [P, LB] (pad = -1)."""
    if interpret is None:
        interpret = interpret_default()
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    p, la = a.shape
    if p == 0:
        return jnp.zeros((0,), jnp.int32)
    ppad = round_up(p, _BLOCK_ROWS)
    lbpad = round_up(max(b.shape[1], LANE), LANE)
    lapad = round_up(max(la, LANE), LANE)
    a2 = jnp.full((ppad, lapad), -1, jnp.int32).at[:p, :la].set(a)
    b2 = jnp.full((ppad, lbpad), -1, jnp.int32).at[:p, :b.shape[1]].set(b)
    out = uint_intersect_kernel(a2, b2, block_rows=_BLOCK_ROWS,
                                lb_blk=LANE, interpret=interpret)
    return out[:p]


def intersect_count_csr(offsets, neighbors, u, v, *, interpret=None,
                        max_len: int = 512):
    """CSR front-end: gather + pad N(u_i), N(v_i) then run the kernel.

    Pairs whose min-degree exceeds ``max_len`` should be routed to the
    search path by the caller; here they are asserted against.
    """
    return intersect_count_csr_batched(offsets, neighbors, u, v,
                                       interpret=interpret, max_len=max_len)


def _gather_pad(offsets: np.ndarray, neighbors: np.ndarray,
                ids: np.ndarray, width: int) -> np.ndarray:
    """Vectorized ragged gather: rows = N(ids[i]) right-padded with -1."""
    deg = np.diff(offsets)[ids]
    rows = np.repeat(np.arange(len(ids), dtype=np.int64), deg)
    seg_start = np.repeat(np.cumsum(deg) - deg, deg)
    local = np.arange(len(rows), dtype=np.int64) - seg_start
    elem = np.repeat(offsets[ids], deg) + local
    out = np.full((len(ids), width), -1, np.int32)
    out[rows, local] = neighbors[elem]
    return out


def _contract_inputs():
    """Representative padded batches for the kernel contract checker
    (``repro.analysis.kernel_check``): ragged sorted rows, -1 padded."""
    rng = np.random.default_rng(0)
    def rows(p, width, universe):
        out = np.full((p, width), -1, np.int32)
        for i in range(p):
            k = int(rng.integers(1, width + 1))
            out[i, :k] = np.sort(rng.choice(universe, size=k, replace=False))
        return out
    # universe small enough that some rows MUST intersect — an all-zero
    # count vector would make the numeric oracle cross-check vacuous
    a, b = rows(5, 7, 12), rows(5, 9, 12)
    assert int(np.sum([len(np.intersect1d(r[r >= 0], s[s >= 0]))
                       for r, s in zip(a, b)])) > 0
    return a, b


def _contract_ref(a, b):
    from repro.kernels.uint_intersect.ref import uint_intersect_count_ref
    return uint_intersect_count_ref(jnp.asarray(a, jnp.int32),
                                    jnp.asarray(b, jnp.int32))


# Static contract (see repro.analysis.kernel_check.check_contract): the
# dispatch entry point, the pure-jnp oracle it must agree with, and
# inputs that exercise the padding paths.
CONTRACT = {
    "name": "uint_intersect",
    "entry": lambda a, b: uint_intersect_count(a, b, interpret=True),
    "ref": _contract_ref,
    "make_inputs": _contract_inputs,
}


def intersect_count_csr_batched(offsets, neighbors, u, v, *, interpret=None,
                                max_len: int = 512) -> np.ndarray:
    """Batched cohort entry point for the execution backend: one
    vectorized gather+pad (no per-pair Python loop) and ONE kernel launch
    for the whole sparse-cohort batch. Same contract as
    :func:`intersect_count_csr` — the caller routes pairs whose larger
    set exceeds ``max_len`` to the search path."""
    offsets = np.asarray(offsets)
    neighbors = np.asarray(neighbors)
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    if len(u) == 0:
        return np.zeros(0, np.int64)
    deg = np.diff(offsets)
    la = int(max(1, deg[u].max()))
    lb = int(max(1, deg[v].max()))
    assert max(la, lb) <= max_len, "route long sets to the search path"
    a = _gather_pad(offsets, neighbors, u, la)
    b = _gather_pad(offsets, neighbors, v, lb)
    return np.asarray(uint_intersect_count(a, b, interpret=interpret),
                      np.int64)
