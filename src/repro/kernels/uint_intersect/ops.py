"""Jit'd public wrapper for the uint-intersect kernel.

Takes ragged CSR pairs, pads the gathered neighbor sets to tile geometry,
and runs the Pallas membership-test kernel. Used by the execution engine for
similar-cardinality sparse-set batches (the SIMDShuffling regime); the
cardinality-skewed regime stays on the lockstep binary search.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import LANE, interpret_default, round_up
from repro.kernels.uint_intersect.kernel import uint_intersect_kernel

_BLOCK_ROWS = 8


def uint_intersect_count(a, b, *, interpret=None):
    """Counts for already-padded batches a [P, LA], b [P, LB] (pad = -1)."""
    if interpret is None:
        interpret = interpret_default()
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    p, la = a.shape
    if p == 0:
        return jnp.zeros((0,), jnp.int32)
    ppad = round_up(p, _BLOCK_ROWS)
    lbpad = round_up(max(b.shape[1], LANE), LANE)
    lapad = round_up(max(la, LANE), LANE)
    a2 = jnp.full((ppad, lapad), -1, jnp.int32).at[:p, :la].set(a)
    b2 = jnp.full((ppad, lbpad), -1, jnp.int32).at[:p, :b.shape[1]].set(b)
    out = uint_intersect_kernel(a2, b2, block_rows=_BLOCK_ROWS,
                                lb_blk=LANE, interpret=interpret)
    return out[:p]


def intersect_count_csr(offsets, neighbors, u, v, *, interpret=None,
                        max_len: int = 512):
    """CSR front-end: gather + pad N(u_i), N(v_i) then run the kernel.

    Pairs whose min-degree exceeds ``max_len`` should be routed to the
    search path by the caller; here they are asserted against.
    """
    offsets = np.asarray(offsets)
    neighbors = np.asarray(neighbors)
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    deg = np.diff(offsets)
    la = int(max(1, deg[u].max() if len(u) else 1))
    lb = int(max(1, deg[v].max() if len(v) else 1))
    assert max(la, lb) <= max_len, "route long sets to the search path"
    a = np.full((len(u), la), -1, np.int32)
    b = np.full((len(v), lb), -1, np.int32)
    for i, (uu, vv) in enumerate(zip(u, v)):
        na = neighbors[offsets[uu]:offsets[uu + 1]]
        nb = neighbors[offsets[vv]:offsets[vv + 1]]
        a[i, :len(na)] = na
        b[i, :len(nb)] = nb
    return np.asarray(uint_intersect_count(a, b, interpret=interpret),
                      np.int64)
