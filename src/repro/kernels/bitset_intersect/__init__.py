from repro.kernels.bitset_intersect.ops import bitset_and_popcount  # noqa: F401
