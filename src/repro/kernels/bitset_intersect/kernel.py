"""Pallas TPU kernel: bitset AND + popcount (paper Section 4.2,
``BITSET ∩ BITSET``).

The paper's hot inner loop loads 256-bit AVX registers and ANDs them; the
TPU-native adaptation operates on (8, 128) int32 VREG tiles: one VPU op ANDs
8 * 128 * 32 = 32,768 set elements, two orders of magnitude wider than
AVX-256. Popcount is synthesized with the standard bit-twiddling sequence
(TPU exposes no popcnt instruction) — 11 int ops per word, amortized over the
lane width.

Inputs are the *pre-gathered* word rows of the matched blocks (the gather is
an XLA op in ops.py; see DESIGN.md §2 on why per-block scalar gathers are not
TPU-idiomatic). Shapes:

  wa, wb : [P, W] uint32  (W = words per bitset block, padded to 128 lanes)
  out    : [P]    int32   |a_i & b_i| summed over the W axis
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANE, SUBLANE, cdiv


def _popcount_u32(x):
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(wa_ref, wb_ref, out_ref):
    """One grid step: AND a (rows, W) tile pair, popcount, row-reduce."""
    anded = wa_ref[...] & wb_ref[...]
    counts = _popcount_u32(anded)            # (rows, W) int32 on the VPU
    out_ref[...] = counts.sum(axis=1)        # lane reduction -> (rows,)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bitset_and_popcount_kernel(wa, wb, *, block_rows: int = 256,
                               interpret: bool = False):
    """``pallas_call`` wrapper; P padded to block_rows, W padded to LANE."""
    p, w = wa.shape
    assert wb.shape == (p, w)
    assert p % block_rows == 0 and w % LANE == 0, (p, w)
    assert block_rows % SUBLANE == 0
    grid = (cdiv(p, block_rows),)
    spec = pl.BlockSpec((block_rows, w), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.int32),
        interpret=interpret,
    )(wa, wb)
