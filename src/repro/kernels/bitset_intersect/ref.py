"""Pure-jnp oracle for the bitset AND+popcount kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def popcount_u32(x):
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


@jax.jit
def bitset_and_popcount_ref(wa, wb):
    """out[i] = popcount(wa[i] & wb[i]) summed over words."""
    return popcount_u32(wa & wb).sum(axis=1)
