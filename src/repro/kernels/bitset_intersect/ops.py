"""Jit'd public wrapper for the bitset-intersect kernel.

``bitset_and_popcount(words, pos_a, pos_b)`` is the drop-in ``word_kernel``
for :class:`repro.core.layouts.HybridSetStore`: it gathers the matched block
rows (XLA gather), pads to hardware tile geometry, and runs the Pallas
AND+popcount kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitset_intersect.kernel import bitset_and_popcount_kernel
from repro.kernels.common import LANE, interpret_default, round_up

_BLOCK_ROWS = 256


def bitset_and_popcount(words, pos_a, pos_b, *, interpret=None):
    """out[i] = |block[pos_a[i]] & block[pos_b[i]]| (popcount of the AND).

    words : [B, W] uint32 bitvector blocks
    pos_a, pos_b : [P] int indices into the block table
    """
    if interpret is None:
        interpret = interpret_default()
    words = jnp.asarray(words)
    pos_a = jnp.asarray(pos_a)
    pos_b = jnp.asarray(pos_b)
    p = pos_a.shape[0]
    if p == 0:
        return jnp.zeros((0,), jnp.int32)
    w = words.shape[1]
    wpad = round_up(max(w, LANE), LANE)
    ppad = round_up(p, _BLOCK_ROWS)
    wa = jnp.zeros((ppad, wpad), jnp.uint32).at[:p, :w].set(words[pos_a])
    wb = jnp.zeros((ppad, wpad), jnp.uint32).at[:p, :w].set(words[pos_b])
    out = bitset_and_popcount_kernel(wa, wb, block_rows=_BLOCK_ROWS,
                                     interpret=interpret)
    return out[:p]


def as_word_kernel(interpret=None):
    """Adapter matching HybridSetStore's ``word_kernel`` callable."""
    def fn(words, pos_a, pos_b):
        return np.asarray(bitset_and_popcount(words, pos_a, pos_b,
                                              interpret=interpret))
    return fn


def _contract_inputs():
    rng = np.random.default_rng(0)
    words = rng.integers(0, 1 << 32, size=(6, 4), dtype=np.uint32)
    return (words, np.array([0, 2, 4], np.int64),
            np.array([1, 3, 5], np.int64))


def _contract_ref(words, pos_a, pos_b):
    from repro.kernels.bitset_intersect.ref import bitset_and_popcount_ref
    w = jnp.asarray(words)
    return bitset_and_popcount_ref(w[jnp.asarray(pos_a)],
                                   w[jnp.asarray(pos_b)])


# Static contract (see repro.analysis.kernel_check.check_contract).
CONTRACT = {
    "name": "bitset_intersect",
    "entry": lambda w, a, b: bitset_and_popcount(w, a, b, interpret=True),
    "ref": _contract_ref,
    "make_inputs": _contract_inputs,
}


def bitset_pair_count(bs, a_slots, b_slots, *, interpret=None,
                      word_kernel=None) -> np.ndarray:
    """Batched cohort entry point: |S_a ∩ S_b| for slot pairs of one
    :class:`~repro.core.intersect.BlockedBitset` cohort — block-id
    intersection (uint machinery) followed by the Pallas AND+popcount
    kernel over all matched blocks in one launch. Pass a prebuilt
    ``word_kernel`` (from :func:`as_word_kernel`) to reuse the adapter
    across calls."""
    from repro.core.intersect import bitset_intersect_count  # avoid cycle
    if word_kernel is None:
        word_kernel = as_word_kernel(interpret)
    return bitset_intersect_count(bs, np.asarray(a_slots),
                                  np.asarray(b_slots),
                                  word_and_popcount=word_kernel)
