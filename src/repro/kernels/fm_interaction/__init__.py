from repro.kernels.fm_interaction.ops import fm_interaction  # noqa: F401
