"""Pure-jnp oracle for the FM interaction kernel (both formulations)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def fm_interaction_ref(emb):
    """Sum-square formulation (what the kernel computes)."""
    s = emb.sum(axis=1)
    sq = (emb * emb).sum(axis=1)
    return 0.5 * (s * s - sq).sum(axis=1)


@jax.jit
def fm_interaction_pairwise_ref(emb):
    """Naive O(F^2) pairwise formulation — independent oracle."""
    g = jnp.einsum("bfd,bgd->bfg", emb, emb)
    total = g.sum(axis=(1, 2))
    diag = jnp.einsum("bfd,bfd->b", emb, emb)
    return 0.5 * (total - diag)
