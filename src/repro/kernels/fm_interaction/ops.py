"""Jit'd public wrapper for the FM interaction kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_default, round_up
from repro.kernels.fm_interaction.kernel import fm_interaction_kernel

_BLOCK_ROWS = 128


def fm_interaction(emb, *, interpret=None):
    """0.5 * sum_d((sum_f e)^2 - sum_f e^2) per batch row. emb: [B, F, D]."""
    if interpret is None:
        interpret = interpret_default()
    emb = jnp.asarray(emb, jnp.float32)
    b, f, d = emb.shape
    bpad = round_up(max(b, _BLOCK_ROWS), _BLOCK_ROWS)
    if bpad != b:
        emb = jnp.zeros((bpad, f, d), jnp.float32).at[:b].set(emb)
    out = fm_interaction_kernel(emb, block_rows=_BLOCK_ROWS,
                                interpret=interpret)
    return out[:b]
