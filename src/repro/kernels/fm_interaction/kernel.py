"""Pallas TPU kernel: FM second-order interaction (Rendle's sum-square trick).

The fm arch's hot op after the embedding lookup:

    out[b] = 0.5 * sum_d ( (sum_f e[b,f,d])^2 - sum_f e[b,f,d]^2 )

O(F*D) instead of the naive O(F^2 * D) pairwise dot. Fuses both reductions
and the elementwise square in one VMEM pass per batch tile — one HBM read of
the embeddings, no intermediate (B, D) round-trips.

  emb : [B, F, D] float32 field embeddings (e[b,f,:] = v_f * x_{b,f})
  out : [B]       float32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv


def _kernel(emb_ref, out_ref):
    e = emb_ref[...]                       # (rows, F, D)
    s = e.sum(axis=1)                      # (rows, D)
    sq = (e * e).sum(axis=1)               # (rows, D)
    out_ref[...] = 0.5 * (s * s - sq).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fm_interaction_kernel(emb, *, block_rows: int = 128,
                          interpret: bool = False):
    b, f, d = emb.shape
    assert b % block_rows == 0
    grid = (cdiv(b, block_rows),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(emb)
