"""Pallas TPU kernels for the engine's compute hot-spots.

Each kernel package is (kernel.py: pl.pallas_call + BlockSpec tiling,
ops.py: jit'd public wrapper with padding/gather glue, ref.py: pure-jnp
oracle used by the per-kernel allclose sweeps in tests/).

  bitset_intersect  paper §4.2 BITSET∩BITSET — VPU AND+popcount
  uint_intersect    paper §4.2 UINT∩UINT     — tile membership test
  materialize       paper §4.2/Fig 6 materializing BITSET∩BITSET —
                    VPU AND + MXU triangular-matmul rank extraction
  triangle_mm       beyond-paper: MXU masked-matmul triangle counting
  spmv_ell          PageRank SpMV over ELL-packed adjacency
  fm_interaction    recsys FM sum-square interaction
"""
from repro.kernels.bitset_intersect import bitset_and_popcount  # noqa: F401
from repro.kernels.fm_interaction import fm_interaction  # noqa: F401
from repro.kernels.materialize import bitset_pair_materialize  # noqa: F401
from repro.kernels.spmv_ell import spmv_ell  # noqa: F401
from repro.kernels.triangle_mm import triangle_count_dense  # noqa: F401
from repro.kernels.uint_intersect import uint_intersect_count  # noqa: F401
