"""Pure-jnp oracle for the MXU triangle-count kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def triangle_count_dense_ref(a):
    """sum((A @ A) * A) over a 0/1 float adjacency."""
    return ((a @ a) * a).sum()
